//! Offline API-compatible subset of the `anyhow` crate.
//!
//! The build environment cannot reach crates.io, so `accelkern` depends
//! on this path crate instead (DESIGN.md §9). It implements the surface
//! the repository uses — [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option` — with the same semantics as upstream:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] (so `?` works in `anyhow::Result` functions) **with its
//!   concrete type preserved**: [`Error::chain`] walks the cause chain
//!   as `&dyn std::error::Error` links, so `c.is::<T>()` /
//!   `c.downcast_ref::<T>()` recover the original error — which is how
//!   `failpoint::is_abort` finds an injected `FailpointAbort` and the
//!   driver's recovery loop classifies `AkError::{RankDead,
//!   CommTimeout}` through any number of `.context(..)` hops,
//! * `.context(..)` / `.with_context(..)` push a new message onto the
//!   cause chain without disturbing the links beneath it,
//! * `{e}` displays the top message, `{e:#}` the full chain joined by
//!   `": "` (what the repo prints in error paths).
//!
//! Swapping this for the upstream crate is a drop-in change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` — the crate-wide error-carrying result.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a boxed `std::error::Error` whose `source()` chain
/// is the cause chain. Context layers are real links in that chain, so
/// downcasting through [`Error::chain`] sees every original error.
pub struct Error {
    obj: Box<dyn StdError + Send + Sync + 'static>,
}

/// A plain-message link (what [`Error::msg`] and [`anyhow!`] build).
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context layer: displays its own message, sources the wrapped error.
struct ContextError {
    msg: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ContextError({:?})", self.msg)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

impl Error {
    /// Build an error from a typed `std::error::Error`, preserving the
    /// concrete type for later [`Error::downcast_ref`].
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { obj: Box::new(error) }
    }

    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { obj: Box::new(MessageError(message.to_string())) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { obj: Box::new(ContextError { msg: context.to_string(), source: self.obj }) }
    }

    /// The cause chain, outermost link first. Each link is the original
    /// typed error (or a context/message layer), so
    /// `chain().any(|c| c.is::<T>())` and
    /// `chain().find_map(|c| c.downcast_ref::<T>())` work as upstream.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        std::iter::successors(
            Some(self.obj.as_ref() as &(dyn StdError + 'static)),
            |e| e.source(),
        )
    }

    /// The innermost error of the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }

    /// First link in the chain that is a `T`, if any. Upstream checks
    /// the outermost error; walking the whole chain is a superset the
    /// repo's call sites (fail-point aborts behind context layers) rely
    /// on.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        self.chain().find_map(|c| c.downcast_ref::<T>())
    }

    /// True when some link in the chain is a `T`.
    pub fn is<T: StdError + 'static>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }
}

impl AsRef<dyn StdError + 'static> for Error {
    fn as_ref(&self) -> &(dyn StdError + 'static) {
        self.obj.as_ref()
    }
}

impl std::ops::Deref for Error {
    type Target = dyn StdError + Send + Sync + 'static;
    fn deref(&self) -> &Self::Target {
        self.obj.as_ref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, upstream-compatible enough for logs.
            let mut sep = "";
            for link in self.chain() {
                write!(f, "{sep}{link}")?;
                sep = ": ";
            }
            Ok(())
        } else {
            write!(f, "{}", self.obj)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.obj)?;
        let causes: Vec<_> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible to [`Error`]) and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the failure into [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Lazily-built variant of [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
        let e = e.context("reading file");
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
        assert_eq!(e.root_cause().to_string(), "gone");
    }

    #[test]
    fn downcast_survives_context_hops() {
        let e = Error::new(io_err()).context("outer").context("outermost");
        assert!(e.is::<std::io::Error>());
        let io = e.downcast_ref::<std::io::Error>().unwrap();
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.chain().any(|c| c.is::<std::io::Error>()));
        assert_eq!(e.chain().count(), 3);
        // A nested std source chain stays walkable too.
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer typed")
            }
        }
        impl StdError for Outer {
            fn source(&self) -> Option<&(dyn StdError + 'static)> {
                Some(&self.0)
            }
        }
        let e: Error = Outer(io_err()).into();
        assert!(e.is::<Outer>() && e.is::<std::io::Error>());
        assert_eq!(format!("{e:#}"), "outer typed: gone");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert!(f(7).unwrap_err().to_string().contains("x != 7"));
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("coded {}", 42);
        assert_eq!(e.to_string(), "coded 42");
    }

    #[test]
    fn question_mark_conversion() {
        fn g() -> Result<()> {
            let _ = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(())
        }
        assert!(g().is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
