//! Offline API-compatible subset of the `anyhow` crate.
//!
//! The build environment cannot reach crates.io, so `accelkern` depends
//! on this path crate instead (DESIGN.md §9). It implements the surface
//! the repository uses — [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option` — with the same semantics as upstream:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] (so `?` works in `anyhow::Result` functions),
//! * `.context(..)` / `.with_context(..)` push a new message onto the
//!   cause chain,
//! * `{e}` displays the top message, `{e:#}` the full chain joined by
//!   `": "` (what the repo prints in error paths).
//!
//! Swapping this for the upstream crate is a drop-in change.

use std::fmt;

/// `Result<T, anyhow::Error>` — the crate-wide error-carrying result.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    /// The innermost error message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, upstream-compatible enough for logs.
            write!(f, "{}", self.chain().join(": "))
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our message chain.
        let mut msgs: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible to [`Error`]) and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the failure into [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Lazily-built variant of [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
        let e = e.context("reading file");
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert!(f(7).unwrap_err().to_string().contains("x != 7"));
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("coded {}", 42);
        assert_eq!(e.to_string(), "coded 42");
    }

    #[test]
    fn question_mark_conversion() {
        fn g() -> Result<()> {
            let _ = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(())
        }
        assert!(g().is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
