//! Offline stub of the `xla-rs` API surface used by `accelkern`.
//!
//! The image this repo builds in has no PJRT plugin and no network
//! access, so this crate stands in for `xla-rs` (DESIGN.md §9). The
//! contract:
//!
//! * [`Literal`] is **fully functional** host-side: typed construction
//!   from untyped bytes, typed readback, tuple decomposition. The
//!   `accelkern::runtime::literal` unit tests run against it.
//! * [`PjRtClient::cpu`] returns an error, so `Runtime::open` fails
//!   cleanly and every caller takes its documented host fallback — the
//!   same degradation path as a checkout where `make artifacts` has not
//!   run yet.
//!
//! Replace the `xla = { path = "../vendor/xla" }` dependency with the
//! real `xla-rs` crate to enable device execution; the types and method
//! signatures here are a subset of that crate's API.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error`, so it converts into
/// `anyhow::Error` at call sites).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching xla-rs.
pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT unavailable: offline stub `xla` crate (vendor/xla); \
     swap in the real xla-rs crate to enable device execution";

/// XLA element types (the subset the artifact catalog uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrimitiveType {
    /// 16-bit signed integer.
    S16,
    /// 32-bit signed integer.
    S32,
    /// 64-bit signed integer.
    S64,
    /// 32-bit unsigned integer.
    U32,
    /// 64-bit unsigned integer.
    U64,
    /// 32-bit float.
    F32,
    /// 64-bit float.
    F64,
    /// Tuple of literals (execution results).
    Tuple,
}

impl PrimitiveType {
    fn elem_bytes(self) -> Option<usize> {
        match self {
            PrimitiveType::S16 => Some(2),
            PrimitiveType::S32 | PrimitiveType::U32 | PrimitiveType::F32 => Some(4),
            PrimitiveType::S64 | PrimitiveType::U64 | PrimitiveType::F64 => Some(8),
            PrimitiveType::Tuple => None,
        }
    }
}

/// Types that can live in a [`Literal`] (xla-rs `ArrayElement`).
pub trait ArrayElement: Copy + 'static {
    /// The XLA element type tag for this Rust type.
    const TY: PrimitiveType;
}

macro_rules! array_element {
    ($ty:ty, $tag:ident) => {
        impl ArrayElement for $ty {
            const TY: PrimitiveType = PrimitiveType::$tag;
        }
    };
}

array_element!(i16, S16);
array_element!(i32, S32);
array_element!(i64, S64);
array_element!(u32, U32);
array_element!(u64, U64);
array_element!(f32, F32);
array_element!(f64, F64);

/// A host-side typed tensor: element type + dims + raw bytes, or a tuple
/// of literals (the shape execution results come back in).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: PrimitiveType,
    dims: Vec<usize>,
    data: Vec<u8>,
    tuple: Vec<Literal>,
}

impl Literal {
    /// Build a literal from an element type, dims and raw (little-endian,
    /// host-layout) bytes. Errors when the byte count disagrees with the
    /// shape.
    pub fn create_from_shape_and_untyped_data(
        ty: PrimitiveType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let Some(esize) = ty.elem_bytes() else {
            return Err(Error::new("cannot build a tuple literal from untyped data"));
        };
        let elems: usize = dims.iter().product();
        if data.len() != elems * esize {
            return Err(Error::new(format!(
                "byte count {} does not match shape {:?} of {:?} ({} expected)",
                data.len(),
                dims,
                ty,
                elems * esize
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec(), tuple: Vec::new() })
    }

    /// Wrap literals into a tuple literal (what executions return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { ty: PrimitiveType::Tuple, dims: Vec::new(), data: Vec::new(), tuple: elems }
    }

    /// Element type of this literal.
    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }

    /// Dimensions of this literal.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Copy the data out as a typed vector. Errors on a type mismatch.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::new(format!(
                "literal holds {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let esize = std::mem::size_of::<T>();
        debug_assert_eq!(Some(esize), self.ty.elem_bytes());
        let n = self.data.len() / esize;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // Unaligned read: the byte buffer has no alignment guarantee.
            let v = unsafe { (self.data.as_ptr().add(i * esize) as *const T).read_unaligned() };
            out.push(v);
        }
        Ok(out)
    }

    /// Split a tuple literal into its components. Errors on non-tuples.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        if self.ty != PrimitiveType::Tuple {
            return Err(Error::new("decompose_tuple on a non-tuple literal"));
        }
        Ok(std::mem::take(&mut self.tuple))
    }
}

/// Parsed HLO module (stub: parsing requires the real XLA toolchain).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file. Always errors in the stub.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::new(format!(
            "cannot parse HLO text {}: {STUB_MSG}",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A PJRT device buffer holding one execution output.
#[derive(Debug)]
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals. Unreachable in the stub
    /// (no executable can be compiled), kept for API compatibility.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

/// A PJRT client bound to one platform.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create the CPU PJRT client. Always errors in the stub, which makes
    /// `Runtime::open` fail cleanly and callers take their host fallback.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(STUB_MSG))
    }

    /// Platform name of this client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Unreachable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_typed() {
        let xs: Vec<i16> = vec![-3, 0, 7, i16::MAX, i16::MIN];
        let bytes: Vec<u8> = xs.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(PrimitiveType::S16, &[5], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i16>().unwrap(), xs);
        assert_eq!(lit.dims(), &[5]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(PrimitiveType::F32, &[3], &[0u8; 8])
            .is_err());
    }

    #[test]
    fn tuple_decomposition() {
        let a = Literal::create_from_shape_and_untyped_data(PrimitiveType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let mut t = Literal::tuple(vec![a.clone(), a]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let mut scalar = parts[0].clone();
        assert!(scalar.decompose_tuple().is_err());
    }

    #[test]
    fn client_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
