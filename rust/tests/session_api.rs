//! The `Session`/`Launch` API contract (DESIGN.md §12):
//!
//! * (a) the deprecated free-function shims and the session methods are
//!   result-equivalent on every host backend;
//! * (b) `Launch` knobs actually change the *observed parallelism*
//!   (thread-id probe), never the results;
//! * (c) the typed error surface: shape mismatches, backend gaps,
//!   i128-on-device dtype gaps (artifact-gated), and empty/degenerate
//!   inputs.

use std::collections::HashSet;
use std::sync::Mutex;

use accelkern::algorithms::ReduceKind;
use accelkern::backend::Backend;
use accelkern::hybrid::{HybridEngine, HybridPlan};
use accelkern::session::{AkError, Launch, Session};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution};

fn host_backends() -> Vec<Backend> {
    vec![
        Backend::Native,
        Backend::Threaded(4),
        Backend::Hybrid(HybridEngine::new(HybridPlan::new(0.5), 3, None)),
    ]
}

// ---- (a) shim-vs-session equivalence ---------------------------------------

#[test]
#[allow(deprecated)]
fn shims_and_sessions_agree_on_every_host_backend() {
    let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 30_000);
    let fs: Vec<f32> = generate(&mut Prng::new(2), Distribution::Uniform, 30_000);
    for backend in host_backends() {
        let session = Session::from_backend(backend.clone());

        let mut a = xs.clone();
        accelkern::algorithms::sort(&backend, &mut a).unwrap();
        let mut b = xs.clone();
        session.sort(&mut b, None).unwrap();
        assert_eq!(a, b, "sort {backend:?}");

        let pa = accelkern::algorithms::sortperm(&backend, &xs).unwrap();
        let pb = session.sortperm(&xs, None).unwrap();
        assert_eq!(pa, pb, "sortperm {backend:?}");

        let ra = accelkern::algorithms::reduce(&backend, &xs, ReduceKind::Add, 0).unwrap();
        let rb = session.reduce(&xs, ReduceKind::Add, None).unwrap();
        assert_eq!(ra, rb, "reduce {backend:?}");

        let sa = accelkern::algorithms::accumulate(&backend, &xs, true).unwrap();
        let sb = session.accumulate(&xs, true, None).unwrap();
        assert_eq!(sa, sb, "accumulate {backend:?}");

        let mut hay = xs.clone();
        hay.sort_unstable();
        let qa = accelkern::algorithms::searchsorted_first(&backend, &hay, &xs[..100]).unwrap();
        let qb = session.searchsorted_first(&hay, &xs[..100], None).unwrap();
        assert_eq!(qa, qb, "searchsorted {backend:?}");

        let ga = accelkern::algorithms::any_gt(&backend, &fs, 0.5).unwrap();
        let gb = session.any_gt(&fs, 0.5f32, None).unwrap();
        assert_eq!(ga, gb, "any_gt {backend:?}");
    }
}

#[test]
#[allow(deprecated)]
fn lowmem_shim_dispatches_instead_of_ignoring_backend() {
    // The satellite fix: `sortperm_lowmem` used to ignore its backend
    // argument; it now dispatches (and the results stay identical).
    let xs: Vec<f64> = generate(&mut Prng::new(3), Distribution::DupHeavy, 20_000);
    let want = accelkern::algorithms::sortperm_lowmem(&Backend::Native, &xs).unwrap();
    for backend in host_backends() {
        let got = accelkern::algorithms::sortperm_lowmem(&backend, &xs).unwrap();
        assert_eq!(got, want, "{backend:?}");
    }
}

// ---- (b) knobs change observed parallelism ---------------------------------

/// Count distinct worker thread ids across a foreachindex sweep: the
/// parallel engine spawns scoped workers (their ids differ from the
/// caller's), the sequential engine runs on the caller thread only.
fn observed_threads(session: &Session, n: usize, launch: Option<&Launch>) -> usize {
    let seen: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
    session.foreachindex(
        n,
        |_| {
            seen.lock().unwrap().insert(std::thread::current().id());
        },
        launch,
    );
    seen.lock().unwrap().len()
}

#[test]
fn max_tasks_caps_worker_count() {
    let s = Session::threaded(4);
    let n = 1 << 16;
    assert_eq!(observed_threads(&s, n, None), 4);
    assert_eq!(observed_threads(&s, n, Some(&Launch::new().max_tasks(2))), 2);
    assert_eq!(observed_threads(&s, n, Some(&Launch::new().max_tasks(1))), 1);
}

#[test]
fn min_elems_per_task_starves_excess_workers() {
    let s = Session::threaded(8);
    let n = 40_000;
    // 40k elements at >=20k per task -> at most 2 workers.
    let l = Launch::new().min_elems_per_task(20_000);
    assert_eq!(observed_threads(&s, n, Some(&l)), 2);
}

#[test]
fn par_threshold_forces_the_sequential_engine() {
    let s = Session::threaded(4);
    let n = 1 << 16;
    let caller = std::thread::current().id();
    let seen: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
    s.foreachindex(
        n,
        |_| {
            seen.lock().unwrap().insert(std::thread::current().id());
        },
        Some(&Launch::new().prefer_parallel_threshold(usize::MAX)),
    );
    let seen = seen.into_inner().unwrap();
    assert_eq!(seen.len(), 1);
    assert!(seen.contains(&caller), "sequential path must run on the caller");
    // The hybrid host route honours the same gate.
    let hy = Session::hybrid(HybridEngine::new(HybridPlan::new(0.5), 3, None));
    let l = Launch::new().prefer_parallel_threshold(usize::MAX);
    assert_eq!(observed_threads(&hy, n, Some(&l)), 1);
}

#[test]
fn session_default_policy_applies_and_per_call_overrides() {
    let s = Session::threaded(8).with_defaults(Launch::new().max_tasks(2));
    let n = 1 << 16;
    assert_eq!(observed_threads(&s, n, None), 2); // policy
    assert_eq!(observed_threads(&s, n, Some(&Launch::new().max_tasks(4))), 4); // override
}

#[test]
fn knobs_never_change_results() {
    let xs: Vec<f64> = generate(&mut Prng::new(4), Distribution::DupHeavy, 100_000);
    let mut want = xs.clone();
    Session::native().sort(&mut want, None).unwrap();
    for backend in host_backends() {
        let s = Session::from_backend(backend);
        for l in [
            Launch::new().max_tasks(3),
            Launch::new().min_elems_per_task(10_000),
            Launch::new().prefer_parallel_threshold(16),
            Launch::new().prefer_parallel_threshold(usize::MAX),
            Launch::new().reuse_scratch(true),
        ] {
            let mut got = xs.clone();
            s.sort(&mut got, Some(&l)).unwrap();
            assert!(
                accelkern::dtype::bits_eq(&got, &want),
                "{:?} with {l:?}",
                s.backend().name()
            );
        }
    }
}

#[test]
fn scratch_reuse_is_observable_in_metrics() {
    let s = Session::threaded(4);
    let l = Launch::new().reuse_scratch(true);
    for seed in 0..3u64 {
        let mut xs: Vec<i32> = generate(&mut Prng::new(seed), Distribution::Uniform, 50_000);
        s.sort(&mut xs, Some(&l)).unwrap();
    }
    assert_eq!(s.metrics().calls(), 3);
    assert!(s.metrics().scratch_hits() >= 2, "hits {}", s.metrics().scratch_hits());
}

// ---- (c) typed errors + degenerate inputs ----------------------------------

#[test]
fn shape_mismatch_is_typed() {
    let s = Session::native();
    let mut keys = vec![1i32, 2, 3];
    let mut vals = vec![0u64; 5];
    assert!(matches!(
        s.sort_by_key(&mut keys, &mut vals, None),
        Err(AkError::ShapeMismatch { op: "sort_by_key", .. })
    ));
    assert!(matches!(s.rbf(&[1.0, 2.0], None), Err(AkError::ShapeMismatch { op: "rbf", .. })));
    assert!(matches!(
        s.ljg(&[1.0; 3], &[1.0; 6], Default::default(), None),
        Err(AkError::ShapeMismatch { .. })
    ));
}

#[test]
fn device_dtype_and_backend_gaps_are_typed() {
    // Needs `make artifacts`; skips gracefully offline like the other
    // device tests (integration.rs covers the same path).
    let Some(rt) = accelkern::runtime::Runtime::open_default().ok() else { return };
    let dev = Session::device(accelkern::runtime::Registry::new(rt));
    let mut xs: Vec<i128> = generate(&mut Prng::new(5), Distribution::Uniform, 2000);
    assert!(matches!(
        dev.sort(&mut xs, None),
        Err(AkError::UnsupportedDtype { op: "sort", .. })
    ));
    assert!(matches!(
        dev.sortperm_lowmem(&xs, None),
        Err(AkError::UnsupportedBackend { op: "sortperm_lowmem", .. })
    ));
}

#[test]
fn device_sortperm_fallback_is_strict_or_recorded() {
    // Needs `make artifacts`; skips gracefully offline.
    let Some(rt) = accelkern::runtime::Runtime::open_default().ok() else { return };
    let dev = Session::device(accelkern::runtime::Registry::new(rt));
    // i128 has no pair artifact on any runtime: the device cannot serve
    // the call, so strict sessions get the typed backend error...
    let xs: Vec<i128> = generate(&mut Prng::new(7), Distribution::Uniform, 2000);
    let strict = accelkern::session::Launch::new().strict_device(true);
    assert!(matches!(
        dev.sortperm(&xs, Some(&strict)),
        Err(AkError::UnsupportedBackend { op: "sortperm", .. })
    ));
    assert_eq!(dev.metrics().device_fallbacks(), 0);
    // ...and non-strict sessions fall back to the host engine with the
    // fallback recorded in the metrics sink (never silent).
    let perm = dev.sortperm(&xs, None).unwrap();
    assert_eq!(perm.len(), xs.len());
    assert_eq!(dev.metrics().device_fallbacks(), 1);
}

#[test]
fn lowmem_errors_are_host_gap_only() {
    // On host sessions lowmem works everywhere (no typed error).
    let xs: Vec<i64> = generate(&mut Prng::new(6), Distribution::Uniform, 5000);
    for backend in host_backends() {
        assert!(Session::from_backend(backend).sortperm_lowmem(&xs, None).is_ok());
    }
}

#[test]
fn errors_convert_into_anyhow_for_shim_callers() {
    fn caller() -> anyhow::Result<()> {
        let s = Session::native();
        s.rbf(&[1.0, 2.0], None)?;
        Ok(())
    }
    let msg = format!("{:#}", caller().unwrap_err());
    assert!(msg.contains("rbf"), "{msg}");
}

#[test]
fn empty_and_degenerate_inputs() {
    for backend in host_backends() {
        let s = Session::from_backend(backend);
        let e: Vec<i64> = vec![];
        let mut es = e.clone();
        s.sort(&mut es, None).unwrap();
        assert!(es.is_empty());
        assert!(s.sortperm(&e, None).unwrap().is_empty());
        assert_eq!(s.reduce(&e, ReduceKind::Add, None).unwrap(), 0);
        assert_eq!(s.reduce(&e, ReduceKind::Min, None).unwrap(), i64::MAX);
        assert!(s.accumulate(&e, true, None).unwrap().is_empty());
        assert!(!s.any_gt(&e, 0i64, None).unwrap());
        assert!(s.all_gt(&e, 0i64, None).unwrap()); // vacuous truth

        let mut one = vec![42i64];
        s.sort(&mut one, None).unwrap();
        assert_eq!(one, vec![42]);
        let mut k = vec![7i32];
        let mut v = vec![1u8];
        s.sort_by_key(&mut k, &mut v, None).unwrap();
    }
}

#[test]
fn hybrid_session_composes_engines() {
    // The hybrid backend through the one dispatch surface: same results,
    // co-split observable through the launch gate.
    let xs: Vec<i64> = generate(&mut Prng::new(7), Distribution::Uniform, 60_000);
    let mut want = xs.clone();
    want.sort_unstable();
    let s = Session::hybrid(HybridEngine::new(HybridPlan::new(0.4), 3, None));
    let mut got = xs.clone();
    s.sort(&mut got, None).unwrap();
    assert_eq!(got, want);
    assert_eq!(s.reduce(&xs, ReduceKind::Max, None).unwrap(), *xs.iter().max().unwrap());
    assert!(s.any_gt(&xs, *xs.iter().min().unwrap(), None).unwrap());
}
