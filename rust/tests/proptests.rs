//! Property-based tests of the coordinator invariants (DESIGN.md §6),
//! using the in-repo `prop` framework.

use accelkern::cfg::{FinalPhase, RunConfig, Sorter, TransferMode};
use accelkern::coordinator::driver::run_distributed_sort_mixed;
use accelkern::dtype::{is_sorted_total, SortKey};
use accelkern::hybrid::{co_sort, HybridEngine, HybridPlan};
use accelkern::mpisort::splitters::{initial_candidates, local_ranks, regular_samples};
use accelkern::prop::{check, Gen, PropConfig, VecGen};
use accelkern::util::Prng;

/// Generator for distributed-sort scenarios: (ranks, elems, dist_id,
/// sorter mix, transfer, final phase) — all drawn small but irregular.
#[derive(Clone, Debug)]
struct Scenario {
    ranks: usize,
    elems_per_rank: usize,
    dist_id: usize,
    sorter_ids: Vec<usize>,
    staged: bool,
    resort: bool,
    seed: u64,
}

struct ScenarioGen;

impl Gen for ScenarioGen {
    type Value = Scenario;

    fn generate(&self, rng: &mut Prng) -> Scenario {
        let ranks = 1 + rng.below(7) as usize;
        Scenario {
            ranks,
            elems_per_rank: rng.below(3000) as usize, // includes 0 and tiny shards
            dist_id: rng.below(7) as usize,
            sorter_ids: (0..ranks).map(|_| rng.below(4) as usize).collect(),
            staged: rng.below(2) == 0,
            resort: rng.below(2) == 0,
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, v: &Scenario) -> Vec<Scenario> {
        let mut out = Vec::new();
        if v.ranks > 1 {
            let mut w = v.clone();
            w.ranks /= 2;
            w.sorter_ids.truncate(w.ranks);
            out.push(w);
        }
        if v.elems_per_rank > 0 {
            let mut w = v.clone();
            w.elems_per_rank /= 2;
            out.push(w);
        }
        if v.dist_id != 0 {
            let mut w = v.clone();
            w.dist_id = 0;
            out.push(w);
        }
        out
    }
}

fn run_scenario(sc: &Scenario) -> Result<(), String> {
    use accelkern::workload::Distribution;
    let sorters: Vec<Sorter> = sc
        .sorter_ids
        .iter()
        .map(|i| [Sorter::JuliaBase, Sorter::ThrustMerge, Sorter::ThrustRadix, Sorter::Hybrid][*i])
        .collect();
    let mut cfg = RunConfig::default();
    cfg.ranks = sc.ranks;
    cfg.elems_per_rank = sc.elems_per_rank;
    cfg.dist = Distribution::ALL[sc.dist_id];
    cfg.transfer = if sc.staged { TransferMode::CpuStaged } else { TransferMode::GpuDirect };
    cfg.final_phase = if sc.resort { FinalPhase::Sort } else { FinalPhase::Merge };
    cfg.seed = sc.seed;
    cfg.refine_rounds = 3;
    // Pin the hybrid split: calibrating on every fuzz case would only add
    // noise, and correctness must hold at any fraction anyway.
    cfg.hybrid_host_fraction = Some(0.5);
    // The driver itself verifies: global order, local order, conservation.
    let out = run_distributed_sort_mixed::<i32>(&cfg, &sorters, None)
        .map_err(|e| format!("{e:#}"))?;
    let total: usize = out.out_sizes.iter().sum();
    if total != sc.ranks * sc.elems_per_rank {
        return Err(format!("lost elements: {total}"));
    }
    Ok(())
}

#[test]
fn prop_distributed_sort_invariants() {
    // The driver's internal verifier (order + permutation) is the oracle;
    // this property fuzzes the scenario space including empty shards,
    // mixed engines, both transfers, both final phases, all distributions.
    check("sihsort-invariants", &PropConfig::default(), &ScenarioGen, run_scenario);
}

#[test]
fn prop_splitter_monotonicity() {
    // Splitters from any sample pool are non-decreasing; local ranks are
    // monotone in the candidate.
    let gen = VecGen::new(2000, |r| r.range_i64(i64::MIN / 2, i64::MAX / 2));
    check("splitter-monotone", &PropConfig::default(), &gen, |xs| {
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        let samples: Vec<u128> =
            regular_samples(&sorted, 16).iter().map(|x| x.to_bits()).collect();
        for p in [2usize, 3, 5, 8] {
            let cands = initial_candidates(samples.clone(), p);
            if cands.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("candidates not monotone for p={p}"));
            }
            let ranks = local_ranks(&sorted, &cands);
            if ranks.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("ranks not monotone for p={p}"));
            }
            if let Some(&last) = ranks.last() {
                if last as usize > sorted.len() {
                    return Err("rank beyond shard".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_baseline_sorts_agree() {
    // Radix, merge and std sort agree on every input, f64 included
    // (total order, ±0.0, infinities).
    let gen = VecGen::new(3000, |r| {
        // Mix of regular values and specials.
        match r.below(12) {
            0 => f64::INFINITY,
            1 => f64::NEG_INFINITY,
            2 => 0.0,
            3 => -0.0,
            _ => (r.uniform_f64() - 0.5) * 1e9,
        }
    });
    check("baselines-agree", &PropConfig::default(), &gen, |xs| {
        let mut a = xs.clone();
        accelkern::baselines::radix_sort(&mut a);
        let mut b = xs.clone();
        accelkern::baselines::merge_sort(&mut b);
        let mut c = xs.clone();
        c.sort_unstable_by(|x, y| x.cmp_total(y));
        let bits = |v: &Vec<f64>| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        if bits(&a) != bits(&c) {
            return Err("radix != std".into());
        }
        if bits(&b) != bits(&c) {
            return Err("merge != std".into());
        }
        if !is_sorted_total(&a) {
            return Err("not sorted".into());
        }
        Ok(())
    });
}

#[test]
fn prop_kmerge_is_merge() {
    // Splitting any vector into k sorted runs and k-merging returns the
    // fully sorted vector.
    let gen = VecGen::new(4000, |r| r.next_u64() as i64);
    check("kmerge", &PropConfig::default(), &gen, |xs| {
        let mut rng = Prng::new(xs.len() as u64);
        let k = 1 + rng.below(9) as usize;
        let mut runs: Vec<Vec<i64>> = (0..k).map(|_| Vec::new()).collect();
        for &x in xs {
            runs[rng.below(k as u64) as usize].push(x);
        }
        for r in &mut runs {
            r.sort_unstable();
        }
        let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
        let got = accelkern::baselines::kmerge(&refs);
        let mut want = xs.clone();
        want.sort_unstable();
        if got != want {
            return Err(format!("kmerge mismatch (k={k})"));
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_cosort_equals_total_sort_f64() {
    // The tentpole acceptance property: hybrid co-sort output is
    // bit-identical to sort_by(cmp_total) at every split ratio —
    // degenerate (0.0 / 1.0), even (0.5), and a calibrated-style odd
    // fraction — on adversarial inputs: NaNs (both signs), infinities,
    // signed zeros, duplicates, already-sorted runs, tiny arrays. Lengths
    // range past MIN_COSPLIT so the real two-engine split is exercised,
    // not just the single-engine route.
    let gen = VecGen::new(3 * accelkern::hybrid::MIN_COSPLIT, |r| match r.below(16) {
        0 => f64::NAN,
        1 => -f64::NAN,
        2 => f64::INFINITY,
        3 => f64::NEG_INFINITY,
        4 => 0.0,
        5 => -0.0,
        6 => 1.0, // duplicate magnet
        _ => (r.uniform_f64() - 0.5) * 1e12,
    });
    check("hybrid-cosort-f64", &PropConfig::default(), &gen, |xs| {
        let mut want = xs.clone();
        want.sort_by(|a, b| a.cmp_total(b));
        let want_bits: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
        for frac in [0.0, 0.37, 0.5, 1.0] {
            let eng = HybridEngine::new(HybridPlan::new(frac), 3, None);
            let mut got = xs.clone();
            co_sort(&eng, &mut got).map_err(|e| format!("{e:#}"))?;
            let got_bits: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
            if got_bits != want_bits {
                return Err(format!("co-sort mismatch at host fraction {frac}"));
            }
        }
        // Already-sorted input stays identical.
        let eng = HybridEngine::new(HybridPlan::new(0.5), 3, None);
        let mut again = want.clone();
        co_sort(&eng, &mut again).map_err(|e| format!("{e:#}"))?;
        if again.iter().map(|x| x.to_bits()).collect::<Vec<u64>>() != want_bits {
            return Err("co-sort disturbed a sorted input".into());
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_cosort_equals_total_sort_ints() {
    // Same property over an integer dtype with duplicate-heavy values,
    // plus the calibrated-plan fraction for this machine's device model.
    let calibrated = accelkern::hybrid::calibrate_sort::<i64>(8 * 1024, 2, None)
        .map(|c| c.plan_measured(1.0).host_fraction)
        .unwrap_or(0.25);
    let gen = VecGen::new(2 * accelkern::hybrid::MIN_COSPLIT, |r| r.range_i64(-50, 50));
    check("hybrid-cosort-i64", &PropConfig::default(), &gen, move |xs| {
        let mut want = xs.clone();
        want.sort_unstable();
        for frac in [0.0, 0.5, 1.0, calibrated] {
            let eng = HybridEngine::new(HybridPlan::new(frac), 2, None);
            let mut got = xs.clone();
            co_sort(&eng, &mut got).map_err(|e| format!("{e:#}"))?;
            if got != want {
                return Err(format!("co-sort mismatch at host fraction {frac}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scan_matches_reference() {
    use accelkern::session::Session;
    let gen = VecGen::new(5000, |r| r.range_i64(-1_000_000, 1_000_000));
    check("scan-threaded", &PropConfig::default(), &gen, |xs| {
        for inclusive in [true, false] {
            let native = Session::native().accumulate(xs, inclusive, None).unwrap();
            let threaded = Session::threaded(4).accumulate(xs, inclusive, None).unwrap();
            if native != threaded {
                return Err(format!("threaded scan mismatch inclusive={inclusive}"));
            }
        }
        Ok(())
    });
}
