//! Cross-module integration tests: algorithms over real artifacts,
//! distributed sorts through the full stack, CLI config plumbing.
//!
//! Device-path tests skip gracefully when `make artifacts` has not run.

use std::sync::Arc;

use accelkern::algorithms::{LjgConsts, ReduceKind};
use accelkern::cfg::{RunConfig, Sorter, TransferMode};
use accelkern::coordinator::driver::{run_distributed_sort, run_for_config};
use accelkern::dtype::{is_sorted_total, ElemType};
use accelkern::runtime::{Registry, Runtime};
use accelkern::session::{Launch, Session};
use accelkern::util::Prng;
use accelkern::workload::{generate, points_f32, positions_f32, Distribution};

fn device_session() -> Option<Session> {
    Runtime::open_default().ok().map(|rt| Session::device(Registry::new(rt)))
}

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok()
}

// ---------- algorithms over the device backend (real artifacts) ----------

#[test]
fn device_sort_matches_native_all_xla_dtypes() {
    let Some(dev) = device_session() else { return };
    macro_rules! check {
        ($ty:ty, $seed:expr) => {{
            let xs: Vec<$ty> = generate(&mut Prng::new($seed), Distribution::Uniform, 40_000);
            let mut a = xs.clone();
            dev.sort(&mut a, None).unwrap();
            let mut b = xs;
            Session::native().sort(&mut b, None).unwrap();
            assert!(a == b, stringify!($ty));
        }};
    }
    check!(i16, 1);
    check!(i32, 2);
    check!(i64, 3);
    check!(f32, 4);
    check!(f64, 5);
}

#[test]
fn device_i128_sort_is_a_typed_error() {
    // The silent host fallback is gone: i128 on the device engine is an
    // UnsupportedDtype, caught at dispatch before any artifact call.
    let Some(dev) = device_session() else { return };
    let mut xs: Vec<i128> = generate(&mut Prng::new(99), Distribution::Uniform, 1000);
    match dev.sort(&mut xs, None) {
        Err(accelkern::session::AkError::UnsupportedDtype { dtype, .. }) => {
            assert_eq!(dtype, ElemType::I128)
        }
        other => panic!("expected UnsupportedDtype, got {other:?}"),
    }
    // And the lowmem argsort names the backend gap explicitly.
    assert!(matches!(
        dev.sortperm_lowmem(&xs, None),
        Err(accelkern::session::AkError::UnsupportedBackend { .. })
    ));
}

#[test]
fn device_block_size_knob_chunks_and_stays_correct() {
    let Some(dev) = device_session() else { return };
    let xs: Vec<i32> = generate(&mut Prng::new(41), Distribution::Uniform, 50_000);
    let mut want = xs.clone();
    want.sort_unstable();
    // A small block granule forces the chunk + host-merge path even
    // though the shard fits a single class.
    let l = Launch::new().block_size(16_384);
    let mut got = xs;
    dev.sort(&mut got, Some(&l)).unwrap();
    assert_eq!(got, want);
}

#[test]
fn device_sort_chunked_beyond_largest_class() {
    let Some(dev) = device_session() else { return };
    // Largest sort class is 2^17; force the chunk+merge path.
    let xs: Vec<i32> = generate(&mut Prng::new(7), Distribution::Uniform, (1 << 17) + 12_345);
    let mut a = xs.clone();
    dev.sort(&mut a, None).unwrap();
    assert!(is_sorted_total(&a));
    let mut b = xs;
    b.sort_unstable();
    assert_eq!(a, b);
}

#[test]
fn device_scan_reduce_search_match_host() {
    let Some(dev) = device_session() else { return };
    let host = Session::native();
    let xs: Vec<i64> = generate(&mut Prng::new(8), Distribution::Uniform, 30_000)
        .into_iter()
        .map(|x: i64| x % 1_000_000) // keep sums small
        .collect();
    let scan_d = dev.accumulate(&xs, true, None).unwrap();
    let scan_h = host.accumulate(&xs, true, None).unwrap();
    assert_eq!(scan_d, scan_h);
    let excl_d = dev.accumulate(&xs, false, None).unwrap();
    let excl_h = host.accumulate(&xs, false, None).unwrap();
    assert_eq!(excl_d, excl_h);

    let sum_d = dev.reduce(&xs, ReduceKind::Add, None).unwrap();
    let sum_h = host.reduce(&xs, ReduceKind::Add, None).unwrap();
    assert_eq!(sum_d, sum_h);
    // switch_below knob: host-finished fold must agree too.
    let sb = Launch::new().switch_below(usize::MAX);
    let sum_sb = dev.reduce(&xs, ReduceKind::Add, Some(&sb)).unwrap();
    assert_eq!(sum_sb, sum_h);

    let mut hay = xs.clone();
    hay.sort_unstable();
    let needles: Vec<i64> = generate(&mut Prng::new(9), Distribution::Uniform, 500)
        .into_iter()
        .map(|x: i64| x % 1_000_000)
        .collect();
    let f_d = dev.searchsorted_first(&hay, &needles, None).unwrap();
    let f_h = host.searchsorted_first(&hay, &needles, None).unwrap();
    assert_eq!(f_d, f_h);
    let l_d = dev.searchsorted_last(&hay, &needles, None).unwrap();
    let l_h = host.searchsorted_last(&hay, &needles, None).unwrap();
    assert_eq!(l_d, l_h);
}

#[test]
fn device_sortperm_matches_host() {
    let Some(dev) = device_session() else { return };
    let xs: Vec<i32> = generate(&mut Prng::new(10), Distribution::DupHeavy, 20_000);
    let pd = dev.sortperm(&xs, None).unwrap();
    let ph = Session::native().sortperm(&xs, None).unwrap();
    assert_eq!(pd, ph); // both stable -> identical permutation
}

#[test]
fn device_arith_kernels_match_host() {
    let Some(dev) = device_session() else { return };
    let host = Session::native();
    let pts = points_f32(&mut Prng::new(11), 50_000);
    let rd = dev.rbf(&pts, None).unwrap();
    let rh = host.rbf(&pts, None).unwrap();
    for (a, b) in rd.iter().zip(&rh) {
        assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0));
    }
    let p1 = positions_f32(&mut Prng::new(12), 50_000, 4.0);
    let p2 = positions_f32(&mut Prng::new(13), 50_000, 4.0);
    let c = LjgConsts::default();
    let ld = dev.ljg(&p1, &p2, c, None).unwrap();
    let lh = host.ljg(&p1, &p2, c, None).unwrap();
    for (i, (a, b)) in ld.iter().zip(&lh).enumerate() {
        assert!((a - b).abs() <= 2e-3 * b.abs().max(1.0), "i={i}: {a} vs {b}");
    }
}

#[test]
fn device_predicates_early_exit() {
    let Some(dev) = device_session() else { return };
    let mut xs = vec![0.0f32; 100_000];
    xs[70_000] = 5.0;
    assert!(dev.any_gt(&xs, 1.0f32, None).unwrap());
    assert!(!dev.any_gt(&xs, 10.0f32, None).unwrap());
    assert!(dev.all_gt(&xs, -0.5f32, None).unwrap()); // all > -0.5
    assert!(!dev.all_gt(&xs, 0.5f32, None).unwrap());
    // Generic device predicates: the i32 artifact family.
    let ys: Vec<i32> = (0..100_000).collect();
    assert!(dev.any_gt(&ys, 99_998i32, None).unwrap());
    assert!(!dev.any_gt(&ys, 99_999i32, None).unwrap());
}

// ---------- distributed sorts through the full stack ----------

#[test]
fn distributed_ak_sort_with_artifacts() {
    let rt = runtime();
    let mut cfg = RunConfig::default();
    cfg.ranks = 4;
    cfg.elems_per_rank = 30_000;
    cfg.sorter = Sorter::Ak;
    let out = run_distributed_sort::<i32>(&cfg, rt).unwrap();
    assert_eq!(out.out_sizes.iter().sum::<usize>(), 4 * 30_000);
    assert!(out.record.sim_total > 0.0);
}

#[test]
fn distributed_sort_20_ranks_multi_node() {
    // 20 ranks = 5 simulated trays: exercises NVLink + IB paths together.
    let mut cfg = RunConfig::default();
    cfg.ranks = 20;
    cfg.elems_per_rank = 5000;
    cfg.dtype = ElemType::I64;
    cfg.sorter = Sorter::ThrustRadix;
    let out = run_distributed_sort::<i64>(&cfg, None).unwrap();
    assert_eq!(out.out_sizes.iter().sum::<usize>(), 20 * 5000);
}

#[test]
fn message_complexity_is_minimal() {
    // SIHSort's comm pattern (paper: "least amount of MPI communication"):
    // per run: 1 sample-gather (P-1) + 1 allreduce (2(P-1)) + R rounds of
    // (bcast+gather) (2(P-1) each) + 1 alltoallv (P(P-1)) + barriers (0).
    let mut cfg = RunConfig::default();
    cfg.ranks = 6;
    cfg.elems_per_rank = 4000;
    cfg.sorter = Sorter::ThrustMerge;
    cfg.refine_rounds = 3;
    let out = run_distributed_sort::<i32>(&cfg, None).unwrap();
    let p = cfg.ranks as u64;
    let rounds = out.rounds_used as u64;
    // Upper bound: allgather is gather+bcast of concat (2(P-1)); allreduce
    // 2(P-1); rounds*(2(P-1)) + final done-bcast (P-1); alltoallv P(P-1).
    let bound = (p - 1) * (2 + 2 + 2 * rounds + 1 + 1) + p * (p - 1) + 2 * (p - 1);
    assert!(
        out.record.messages <= bound,
        "messages {} exceed bound {bound} (rounds {rounds})",
        out.record.messages
    );
}

#[test]
fn weak_scaling_flatness_above_node_size() {
    // Fig 2 shape: above one tray, weak scaling stays near-flat — but
    // only in the bandwidth-dominated regime (the paper runs 1 GB/rank;
    // its own Fig 1a shows latency-dominated small sizes scale poorly).
    // 250k i32 = 1 MB/rank keeps beta >> alpha here.
    let mut cfg = RunConfig::default();
    cfg.elems_per_rank = 250_000;
    cfg.sorter = Sorter::ThrustRadix;
    let mut times = Vec::new();
    for ranks in [8, 16, 32] {
        cfg.ranks = ranks;
        let out = run_distributed_sort::<i32>(&cfg, None).unwrap();
        times.push(out.record.sim_total);
    }
    let worst = times.iter().cloned().fold(0.0, f64::max);
    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(worst / best < 3.0, "weak scaling spread {}x: {times:?}", worst / best);
}

#[test]
fn config_file_roundtrip_drives_run() {
    let dir = std::env::temp_dir().join("ak_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "[run]\nranks = 3\ndtype = \"i16\"\nsorter = \"TM\"\nelems_per_rank = 2000\n\n[cluster]\nnvlink_gbps = 150\n",
    )
    .unwrap();
    let cli = accelkern::cli::Cli::parse(vec![
        "akbench".to_string(),
        "sort".to_string(),
        "--config".to_string(),
        path.display().to_string(),
    ])
    .unwrap();
    let cfg = cli.run_config().unwrap();
    assert_eq!(cfg.ranks, 3);
    assert_eq!(cfg.dtype, ElemType::I16);
    assert_eq!(cfg.cluster.nvlink_gbps, 150.0);
    let out = run_for_config(&cfg, None).unwrap();
    assert_eq!(out.out_sizes.iter().sum::<usize>(), 3 * 2000);
}

#[test]
fn nvlink_speedup_shape() {
    // The Fig 4 claim direction: GG must beat GC end-to-end on a
    // communication-heavy configuration.
    let mut cfg = RunConfig::default();
    cfg.ranks = 8;
    cfg.elems_per_rank = 50_000;
    cfg.sorter = Sorter::ThrustRadix;
    cfg.transfer = TransferMode::GpuDirect;
    let gg = run_distributed_sort::<i32>(&cfg, None).unwrap();
    cfg.transfer = TransferMode::CpuStaged;
    let gc = run_distributed_sort::<i32>(&cfg, None).unwrap();
    assert!(
        gc.record.sim_total > gg.record.sim_total,
        "GC {} <= GG {}",
        gc.record.sim_total,
        gg.record.sim_total
    );
}
