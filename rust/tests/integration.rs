//! Cross-module integration tests: algorithms over real artifacts,
//! distributed sorts through the full stack, CLI config plumbing.
//!
//! Device-path tests skip gracefully when `make artifacts` has not run.

use std::sync::Arc;

use accelkern::algorithms as ak;
use accelkern::backend::Backend;
use accelkern::cfg::{RunConfig, Sorter, TransferMode};
use accelkern::coordinator::driver::{run_distributed_sort, run_for_config};
use accelkern::dtype::{is_sorted_total, ElemType};
use accelkern::runtime::{Registry, Runtime};
use accelkern::util::Prng;
use accelkern::workload::{generate, points_f32, positions_f32, Distribution};

fn device_backend() -> Option<Backend> {
    Runtime::open_default().ok().map(|rt| Backend::device(Registry::new(rt)))
}

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok()
}

// ---------- algorithms over the device backend (real artifacts) ----------

#[test]
fn device_sort_matches_native_all_xla_dtypes() {
    let Some(dev) = device_backend() else { return };
    macro_rules! check {
        ($ty:ty, $seed:expr) => {{
            let xs: Vec<$ty> = generate(&mut Prng::new($seed), Distribution::Uniform, 40_000);
            let mut a = xs.clone();
            ak::sort(&dev, &mut a).unwrap();
            let mut b = xs;
            ak::sort(&Backend::Native, &mut b).unwrap();
            assert!(a == b, stringify!($ty));
        }};
    }
    check!(i16, 1);
    check!(i32, 2);
    check!(i64, 3);
    check!(f32, 4);
    check!(f64, 5);
}

#[test]
fn device_sort_chunked_beyond_largest_class() {
    let Some(dev) = device_backend() else { return };
    // Largest sort class is 2^17; force the chunk+merge path.
    let xs: Vec<i32> = generate(&mut Prng::new(7), Distribution::Uniform, (1 << 17) + 12_345);
    let mut a = xs.clone();
    ak::sort(&dev, &mut a).unwrap();
    assert!(is_sorted_total(&a));
    let mut b = xs;
    b.sort_unstable();
    assert_eq!(a, b);
}

#[test]
fn device_scan_reduce_search_match_host() {
    let Some(dev) = device_backend() else { return };
    let xs: Vec<i64> = generate(&mut Prng::new(8), Distribution::Uniform, 30_000)
        .into_iter()
        .map(|x: i64| x % 1_000_000) // keep sums small
        .collect();
    let scan_d = ak::accumulate(&dev, &xs, true).unwrap();
    let scan_h = ak::accumulate(&Backend::Native, &xs, true).unwrap();
    assert_eq!(scan_d, scan_h);
    let excl_d = ak::accumulate(&dev, &xs, false).unwrap();
    let excl_h = ak::accumulate(&Backend::Native, &xs, false).unwrap();
    assert_eq!(excl_d, excl_h);

    let sum_d = ak::reduce(&dev, &xs, ak::ReduceKind::Add, 0).unwrap();
    let sum_h = ak::reduce(&Backend::Native, &xs, ak::ReduceKind::Add, 0).unwrap();
    assert_eq!(sum_d, sum_h);
    // switch_below: host-finished fold must agree too.
    let sum_sb = ak::reduce(&dev, &xs, ak::ReduceKind::Add, usize::MAX).unwrap();
    assert_eq!(sum_sb, sum_h);

    let mut hay = xs.clone();
    hay.sort_unstable();
    let needles: Vec<i64> = generate(&mut Prng::new(9), Distribution::Uniform, 500)
        .into_iter()
        .map(|x: i64| x % 1_000_000)
        .collect();
    let f_d = ak::searchsorted_first(&dev, &hay, &needles).unwrap();
    let f_h = ak::searchsorted_first(&Backend::Native, &hay, &needles).unwrap();
    assert_eq!(f_d, f_h);
    let l_d = ak::searchsorted_last(&dev, &hay, &needles).unwrap();
    let l_h = ak::searchsorted_last(&Backend::Native, &hay, &needles).unwrap();
    assert_eq!(l_d, l_h);
}

#[test]
fn device_sortperm_matches_host() {
    let Some(dev) = device_backend() else { return };
    let xs: Vec<i32> = generate(&mut Prng::new(10), Distribution::DupHeavy, 20_000);
    let pd = ak::sortperm(&dev, &xs).unwrap();
    let ph = ak::sortperm(&Backend::Native, &xs).unwrap();
    assert_eq!(pd, ph); // both stable -> identical permutation
}

#[test]
fn device_arith_kernels_match_host() {
    let Some(dev) = device_backend() else { return };
    let pts = points_f32(&mut Prng::new(11), 50_000);
    let rd = ak::rbf(&dev, &pts).unwrap();
    let rh = ak::rbf(&Backend::Native, &pts).unwrap();
    for (a, b) in rd.iter().zip(&rh) {
        assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0));
    }
    let p1 = positions_f32(&mut Prng::new(12), 50_000, 4.0);
    let p2 = positions_f32(&mut Prng::new(13), 50_000, 4.0);
    let c = ak::LjgConsts::default();
    let ld = ak::ljg(&dev, &p1, &p2, c).unwrap();
    let lh = ak::ljg(&Backend::Native, &p1, &p2, c).unwrap();
    for (i, (a, b)) in ld.iter().zip(&lh).enumerate() {
        assert!((a - b).abs() <= 2e-3 * b.abs().max(1.0), "i={i}: {a} vs {b}");
    }
}

#[test]
fn device_predicates_early_exit() {
    let Some(dev) = device_backend() else { return };
    let mut xs = vec![0.0f32; 100_000];
    xs[70_000] = 5.0;
    assert!(ak::any_gt(&dev, &xs, 1.0).unwrap());
    assert!(!ak::any_gt(&dev, &xs, 10.0).unwrap());
    assert!(!ak::all_gt(&dev, &xs, -0.5).unwrap() == false); // all > -0.5
    assert!(!ak::all_gt(&dev, &xs, 0.5).unwrap());
}

// ---------- distributed sorts through the full stack ----------

#[test]
fn distributed_ak_sort_with_artifacts() {
    let rt = runtime();
    let mut cfg = RunConfig::default();
    cfg.ranks = 4;
    cfg.elems_per_rank = 30_000;
    cfg.sorter = Sorter::Ak;
    let out = run_distributed_sort::<i32>(&cfg, rt).unwrap();
    assert_eq!(out.out_sizes.iter().sum::<usize>(), 4 * 30_000);
    assert!(out.record.sim_total > 0.0);
}

#[test]
fn distributed_sort_20_ranks_multi_node() {
    // 20 ranks = 5 simulated trays: exercises NVLink + IB paths together.
    let mut cfg = RunConfig::default();
    cfg.ranks = 20;
    cfg.elems_per_rank = 5000;
    cfg.dtype = ElemType::I64;
    cfg.sorter = Sorter::ThrustRadix;
    let out = run_distributed_sort::<i64>(&cfg, None).unwrap();
    assert_eq!(out.out_sizes.iter().sum::<usize>(), 20 * 5000);
}

#[test]
fn message_complexity_is_minimal() {
    // SIHSort's comm pattern (paper: "least amount of MPI communication"):
    // per run: 1 sample-gather (P-1) + 1 allreduce (2(P-1)) + R rounds of
    // (bcast+gather) (2(P-1) each) + 1 alltoallv (P(P-1)) + barriers (0).
    let mut cfg = RunConfig::default();
    cfg.ranks = 6;
    cfg.elems_per_rank = 4000;
    cfg.sorter = Sorter::ThrustMerge;
    cfg.refine_rounds = 3;
    let out = run_distributed_sort::<i32>(&cfg, None).unwrap();
    let p = cfg.ranks as u64;
    let rounds = out.rounds_used as u64;
    // Upper bound: allgather is gather+bcast of concat (2(P-1)); allreduce
    // 2(P-1); rounds*(2(P-1)) + final done-bcast (P-1); alltoallv P(P-1).
    let bound = (p - 1) * (2 + 2 + 2 * rounds + 1 + 1) + p * (p - 1) + 2 * (p - 1);
    assert!(
        out.record.messages <= bound,
        "messages {} exceed bound {bound} (rounds {rounds})",
        out.record.messages
    );
}

#[test]
fn weak_scaling_flatness_above_node_size() {
    // Fig 2 shape: above one tray, weak scaling stays near-flat — but
    // only in the bandwidth-dominated regime (the paper runs 1 GB/rank;
    // its own Fig 1a shows latency-dominated small sizes scale poorly).
    // 250k i32 = 1 MB/rank keeps beta >> alpha here.
    let mut cfg = RunConfig::default();
    cfg.elems_per_rank = 250_000;
    cfg.sorter = Sorter::ThrustRadix;
    let mut times = Vec::new();
    for ranks in [8, 16, 32] {
        cfg.ranks = ranks;
        let out = run_distributed_sort::<i32>(&cfg, None).unwrap();
        times.push(out.record.sim_total);
    }
    let worst = times.iter().cloned().fold(0.0, f64::max);
    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(worst / best < 3.0, "weak scaling spread {}x: {times:?}", worst / best);
}

#[test]
fn config_file_roundtrip_drives_run() {
    let dir = std::env::temp_dir().join("ak_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "[run]\nranks = 3\ndtype = \"i16\"\nsorter = \"TM\"\nelems_per_rank = 2000\n\n[cluster]\nnvlink_gbps = 150\n",
    )
    .unwrap();
    let cli = accelkern::cli::Cli::parse(vec![
        "akbench".to_string(),
        "sort".to_string(),
        "--config".to_string(),
        path.display().to_string(),
    ])
    .unwrap();
    let cfg = cli.run_config().unwrap();
    assert_eq!(cfg.ranks, 3);
    assert_eq!(cfg.dtype, ElemType::I16);
    assert_eq!(cfg.cluster.nvlink_gbps, 150.0);
    let out = run_for_config(&cfg, None).unwrap();
    assert_eq!(out.out_sizes.iter().sum::<usize>(), 3 * 2000);
}

#[test]
fn nvlink_speedup_shape() {
    // The Fig 4 claim direction: GG must beat GC end-to-end on a
    // communication-heavy configuration.
    let mut cfg = RunConfig::default();
    cfg.ranks = 8;
    cfg.elems_per_rank = 50_000;
    cfg.sorter = Sorter::ThrustRadix;
    cfg.transfer = TransferMode::GpuDirect;
    let gg = run_distributed_sort::<i32>(&cfg, None).unwrap();
    cfg.transfer = TransferMode::CpuStaged;
    let gc = run_distributed_sort::<i32>(&cfg, None).unwrap();
    assert!(
        gc.record.sim_total > gg.record.sim_total,
        "GC {} <= GG {}",
        gc.record.sim_total,
        gg.record.sim_total
    );
}
