//! Fault-tolerance suite for the bounded, fallible fabric
//! (DESIGN.md §16).
//!
//! The contract under test: a distributed sort whose fabric loses
//! messages, stalls, or kills a rank mid-collective either *recovers
//! in-process* (bounded sender retries for transient link faults,
//! whole-collective restart + checkpoint resume for rank death) and
//! produces bitwise what one single-node `Session::sort` produces — or
//! fails with a *typed* comm error carrying rank attribution and
//! per-rank diagnostics, never a hang and never an opaque panic.
//! Alongside: seeded-randomised flow-control schedules proving the
//! per-link credit cap is a hard bound, and retry-backoff determinism.

use std::sync::atomic::Ordering;
use std::time::Duration;

use accelkern::backend::DeviceKey;
use accelkern::cfg::{RunConfig, Sorter, TransferMode};
use accelkern::cluster::ClusterSpec;
use accelkern::comm::{CommTuning, Fabric, RetryPolicy};
use accelkern::coordinator::driver::{run_distributed_sort_data, run_distributed_sort_shards};
use accelkern::dtype::{bits_eq, ElemType};
use accelkern::session::{AkError, Session};
use accelkern::stream::TempDirGuard;
use accelkern::util::Prng;
use accelkern::workload::{generate, KeyGen};

const N_PER_RANK: usize = 4000;

/// In-memory-sorter cluster config with a comm section tuned for fault
/// tests: short deadlines, generous retries, restarts allowed.
fn fault_cfg(ranks: usize, dtype: ElemType) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.ranks = ranks;
    cfg.elems_per_rank = N_PER_RANK;
    cfg.dtype = dtype;
    cfg.sorter = Sorter::ThrustRadix;
    cfg.host_threads = 2;
    cfg.comm.recv_timeout_secs = 30.0;
    cfg.comm.send_timeout_secs = 30.0;
    cfg.comm.retry_attempts = 10;
    cfg.comm.max_restarts = 2;
    // The whole fault suite runs with the happens-before / deadlock
    // detector on: any false-positive cycle under injected faults
    // would fail these tests (DESIGN.md §17).
    cfg.comm.hb_check = true;
    cfg
}

/// Switch a config to the External (out-of-core) sorter, checkpointed
/// under `dir`, with a budget that forces every rank out of core.
fn externalize(cfg: &mut RunConfig, dir: &std::path::Path) {
    cfg.sorter = Sorter::External;
    cfg.stream.budget_bytes = Some(N_PER_RANK * cfg.dtype.size_bytes() / 8);
    cfg.stream.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
}

/// The driver's deterministic seeded shards for `cfg`.
fn seeded_shards<K: KeyGen + DeviceKey>(cfg: &RunConfig) -> Vec<Vec<K>> {
    let mut root = Prng::new(cfg.seed);
    (0..cfg.ranks)
        .map(|r| {
            let mut rng = root.fork(r as u64);
            generate::<K>(&mut rng, cfg.dist, cfg.elems_per_rank)
        })
        .collect()
}

/// Adversarial f64 shards: NaN payloads (both signs), −0.0/0.0, heavy
/// duplicates, infinities — the values bitwise equivalence is hardest
/// for, injected through the caller-supplied-shards driver entry.
fn nan_shards(ranks: usize) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(4242);
    (0..ranks)
        .map(|_| {
            (0..N_PER_RANK)
                .map(|i| match i % 7 {
                    0 => f64::NAN,
                    1 => -f64::NAN,
                    2 => -0.0,
                    3 => 0.0,
                    4 => (i % 11) as f64 - 5.0,
                    5 => f64::NEG_INFINITY,
                    _ => <f64 as KeyGen>::uniform(&mut rng),
                })
                .collect()
        })
        .collect()
}

/// Single-node reference for hand-built shards.
fn reference<K: DeviceKey>(shards: &[Vec<K>]) -> Vec<K> {
    let mut all: Vec<K> = shards.iter().flatten().copied().collect();
    Session::threaded(2).sort(&mut all, None).unwrap();
    all
}

/// Run the collective with `shards` under `cfg`'s fault plan and assert
/// it recovered in-process to the bitwise single-node answer.
fn check_recovers<K: DeviceKey>(cfg: &RunConfig, shards: Vec<Vec<K>>, label: &str) {
    let want = reference(&shards);
    let sorters = vec![cfg.sorter; cfg.ranks];
    let (out, outcomes) =
        run_distributed_sort_shards::<K, _>(cfg, &sorters, None, || shards.clone())
            .unwrap_or_else(|e| panic!("{label}: job did not recover: {e:#}"));
    let got: Vec<K> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want), "{label}: recovered output diverges from single-node sort");
    assert!(
        out.record.recoveries() >= 1,
        "{label}: the kill must force at least one in-process restart"
    );
}

// ---- rank death mid-exchange: restart + resume, bitwise ------------------

#[test]
fn killed_rank_mid_exchange_recovers_in_memory() {
    for ranks in [2usize, 4] {
        // i64 through the seeded generator...
        let mut cfg = fault_cfg(ranks, ElemType::I64);
        cfg.comm.faults = Some("kill:1:2:exchange".into());
        check_recovers(&cfg, seeded_shards::<i64>(&cfg), &format!("TR/i64/ranks={ranks}"));

        // ...and f64 with NaN payloads / −0.0 through hand-built shards.
        let mut cfg = fault_cfg(ranks, ElemType::F64);
        cfg.comm.faults = Some("kill:1:2:exchange".into());
        check_recovers(&cfg, nan_shards(ranks), &format!("TR/f64/ranks={ranks}"));
    }
}

#[test]
fn killed_rank_mid_exchange_recovers_external_from_checkpoints() {
    for ranks in [2usize, 4] {
        let parent = TempDirGuard::new(None).unwrap();

        let mut cfg = fault_cfg(ranks, ElemType::I64);
        externalize(&mut cfg, &parent.path().join("i64"));
        cfg.comm.faults = Some("kill:1:2:exchange".into());
        check_recovers(&cfg, seeded_shards::<i64>(&cfg), &format!("EX/i64/ranks={ranks}"));

        let mut cfg = fault_cfg(ranks, ElemType::F64);
        externalize(&mut cfg, &parent.path().join("f64"));
        cfg.comm.faults = Some("kill:1:2:exchange".into());
        check_recovers(&cfg, nan_shards(ranks), &format!("EX/f64/ranks={ranks}"));
    }
}

#[test]
fn rank_death_without_restart_budget_is_a_typed_failure() {
    // max_restarts = 0: the kill is fatal, and it surfaces as
    // `AkError::RankDead` with rank attribution — not a panic, not a
    // hang, not a string.
    let mut cfg = fault_cfg(2, ElemType::I64);
    cfg.comm.faults = Some("kill:1:2:exchange".into());
    cfg.comm.max_restarts = 0;
    let e = run_distributed_sort_data::<i64>(&cfg, None).unwrap_err();
    let ak = e
        .chain()
        .find_map(|c| c.downcast_ref::<AkError>())
        .unwrap_or_else(|| panic!("no typed comm error in the chain: {e:#}"));
    assert!(
        matches!(ak, AkError::RankDead { rank: 1, .. }),
        "expected RankDead{{rank:1}}, got {ak:?}"
    );
}

// ---- transient link faults: bounded retries, no restart needed -----------

#[test]
fn dropped_messages_are_retried_to_completion() {
    // drop-next-3 on the leader's bcast link: deterministic — exactly 3
    // sender-side losses, each recovered by the bounded backoff without
    // burning a restart attempt.
    let mut cfg = fault_cfg(2, ElemType::I64);
    cfg.comm.faults = Some("drop:0:1:3".into());
    let want = reference(&seeded_shards::<i64>(&cfg));
    let (out, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want));
    assert_eq!(out.record.dropped(), 3, "the drop rule eats exactly its budget");
    assert!(out.record.retries() >= 3, "every loss must surface as a sender retry");
    assert_eq!(out.record.recoveries(), 0, "transient faults must not need a restart");
}

#[test]
fn flaky_link_survives_retries_and_restarts() {
    // A deterministic drop pair guarantees the counters fire; the flaky
    // tail keeps dropping with p=0.3 for the rest of the job. Retries
    // (and, if a message exhausts its attempts, a restart) must still
    // deliver the bitwise answer.
    let mut cfg = fault_cfg(2, ElemType::I64);
    cfg.comm.faults = Some("drop:0:1:2, flaky:0:1:0.3".into());
    cfg.comm.fault_seed = 11;
    let want = reference(&seeded_shards::<i64>(&cfg));
    let (out, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want));
    assert!(out.record.dropped() >= 2 && out.record.retries() >= 2, "{:?}", out.record.row());
}

#[test]
fn partition_heals_and_the_job_completes() {
    // Every cross-cut message drops until the global send-attempt
    // counter passes 6 — the retry layer itself advances that clock, so
    // the partition heals under backoff and the job finishes.
    let mut cfg = fault_cfg(2, ElemType::I64);
    cfg.comm.faults = Some("partition:1:6".into());
    let want = reference(&seeded_shards::<i64>(&cfg));
    let (out, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want));
    assert!(out.record.dropped() >= 1 && out.record.retries() >= 1, "{:?}", out.record.row());
}

// ---- watchdog: hung rank -> typed failure with diagnostics ---------------

#[test]
fn watchdog_converts_stalled_rank_into_typed_failure() {
    // Rank 1 parks on the fabric mid-exchange; every fabric deadline is
    // far longer than the watchdog, so the watchdog must fire first,
    // abort the collective, and surface per-rank phase/clock
    // diagnostics in a typed CommTimeout.
    let mut cfg = fault_cfg(2, ElemType::I64);
    cfg.comm.faults = Some("stall:1:2:exchange".into());
    cfg.comm.watchdog_secs = 0.4;
    cfg.comm.max_restarts = 0;
    let e = run_distributed_sort_data::<i64>(&cfg, None).unwrap_err();
    let ak = e
        .chain()
        .find_map(|c| c.downcast_ref::<AkError>())
        .unwrap_or_else(|| panic!("no typed comm error in the chain: {e:#}"));
    match ak {
        AkError::CommTimeout { op, detail, .. } if *op == "watchdog" => {
            assert!(
                detail.contains("rank 0") && detail.contains("rank 1"),
                "diagnostics must cover every rank: {detail}"
            );
            assert!(
                detail.contains("phase=exchange"),
                "diagnostics must carry last-known phases: {detail}"
            );
        }
        other => panic!("expected a watchdog CommTimeout, got {other:?}"),
    }
}

#[test]
fn watchdog_abort_is_recoverable_with_restart_budget() {
    // Same stall, but with a restart budget: the stall rule is one-shot
    // per job, so the restarted attempt sails through.
    let mut cfg = fault_cfg(2, ElemType::I64);
    cfg.comm.faults = Some("stall:1:2:exchange".into());
    cfg.comm.watchdog_secs = 0.4;
    cfg.comm.max_restarts = 1;
    let want = reference(&seeded_shards::<i64>(&cfg));
    let (out, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want));
    assert_eq!(out.record.recoveries(), 1);
}

// ---- flow control: the credit cap is a hard bound ------------------------

#[test]
fn in_flight_never_exceeds_cap_under_random_chunk_schedules() {
    // Seeded-randomised schedules (chunk sizes, consumption pacing)
    // over a deliberately tiny cap: peak in-flight bytes on the link
    // must never exceed the cap (every message is cap-sized or less, so
    // the oversized-idle admission cannot apply), and the slow consumer
    // must force at least one genuine credit stall.
    const CAP: usize = 4096;
    const MSGS: usize = 40;
    for seed in 0..8u64 {
        let tuning = CommTuning {
            cap_nvlink: CAP,
            cap_ib: CAP,
            cap_pcie: CAP,
            cap_hostmem: CAP,
            send_timeout_secs: 30.0,
            recv_timeout_secs: 30.0,
            // The detector must stay silent on these schedules: the
            // consumer always progresses, so no cycle ever closes.
            hb_check: true,
            ..CommTuning::default()
        };
        let mut eps = Fabric::new_with(
            ClusterSpec::baskerville(),
            TransferMode::GpuDirect,
            vec![true; 2],
            tuning,
        );
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let mut rng = Prng::new(0xF10C ^ seed);
        let sizes: Vec<usize> =
            (0..MSGS).map(|_| 64 + (rng.uniform_f64() * (CAP - 64) as f64) as usize).collect();
        let total: usize = sizes.iter().sum();
        let h = std::thread::spawn(move || {
            // Start slow so the sender outruns the consumer and stalls.
            std::thread::sleep(Duration::from_millis(20));
            let mut rng = Prng::new(0xBEEF ^ seed);
            let mut got = 0usize;
            for i in 0..MSGS {
                if rng.uniform_f64() < 0.3 {
                    std::thread::sleep(Duration::from_micros(300));
                }
                got += e1.recv_bytes(0, i as u64).unwrap().len();
            }
            e1.finish();
            got
        });
        for (i, sz) in sizes.iter().enumerate() {
            e0.send_bytes(1, i as u64, &vec![7u8; *sz]).unwrap();
        }
        assert_eq!(h.join().unwrap(), total, "seed {seed}: bytes lost");
        let peak = e0.stats().peak_link_bytes.load(Ordering::Relaxed);
        assert!(peak as usize <= CAP, "seed {seed}: peak in-flight {peak} exceeded cap {CAP}");
        assert!(
            e0.stats().credit_stalls.load(Ordering::Relaxed) >= 1,
            "seed {seed}: the slow consumer never forced a credit stall"
        );
        e0.finish();
    }
}

// ---- retry backoff: deterministic, jittered, bounded ---------------------

#[test]
fn retry_backoff_schedules_are_deterministic_and_bounded() {
    let mut rng = Prng::new(2024);
    for _ in 0..64 {
        let p = RetryPolicy {
            max_attempts: 2 + (rng.uniform_f64() * 6.0) as u32,
            base_secs: 1e-5 + rng.uniform_f64() * 1e-3,
            factor: 1.5 + rng.uniform_f64(),
            max_secs: 0.05,
            seed: (rng.uniform_f64() * 1e9) as u64,
        };
        let rank = (rng.uniform_f64() * 8.0) as usize;
        let peer = (rng.uniform_f64() * 8.0) as usize;
        let tag = (rng.uniform_f64() * 1e6) as u64;
        let s = p.schedule(rank, peer, tag);
        // Deterministic: the same (policy, link, tag) replays bit-equal.
        assert_eq!(s, p.schedule(rank, peer, tag));
        assert_eq!(s.len(), (p.max_attempts - 1) as usize);
        // Bounded: each step within [0.5, 1.0] x its capped nominal.
        let mut nominal = p.base_secs;
        for (i, w) in s.iter().enumerate() {
            let cap = nominal.min(p.max_secs);
            assert!(
                *w >= 0.5 * cap - 1e-12 && *w <= cap + 1e-12,
                "step {i}: {w} outside [{}, {cap}]",
                0.5 * cap
            );
            nominal *= p.factor;
        }
        let total: f64 = s.iter().sum();
        assert!(total <= p.max_secs * p.max_attempts as f64, "unbounded total backoff {total}");
    }
}
