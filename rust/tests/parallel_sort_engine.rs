//! Cross-engine equivalence tests for the parallel host sort engine
//! (DESIGN.md §11): the merge-path partitioned merges and the threaded
//! LSD radix must produce byte-identical output to their sequential
//! counterparts across thread counts {1, 2, 3, 7}, every workload
//! distribution, all six paper dtypes, float specials (NaN, −0.0,
//! infinities), duplicate-heavy inputs, and empty/tiny runs.

use accelkern::baselines::kmerge::kmerge_into_slice;
use accelkern::baselines::merge_path::{self, PAR_MERGE_MIN};
use accelkern::baselines::radix::{radix_sort, radix_sort_threaded, RADIX_PAR_MIN};
use accelkern::dtype::{bits_eq, SortKey};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution, KeyGen};

const THREADS: [usize; 4] = [1, 2, 3, 7];

/// Inject float specials into a generated buffer (no-op when the buffer
/// is too small). Works on the bit image for every dtype, so the integer
/// checks exercise extreme keys (image MAX collides with the old
/// exhausted-run sentinel) and the float checks get NaN/−0.0/±inf.
fn inject_specials<K: SortKey>(xs: &mut [K]) {
    let n = xs.len();
    if n < 8 {
        return;
    }
    xs[0] = K::max_key();
    xs[n / 2] = K::min_key();
    xs[n / 3] = K::max_key();
}

fn inject_float_specials_f64(xs: &mut [f64]) {
    let n = xs.len();
    if n < 8 {
        return;
    }
    xs[1] = f64::NAN;
    xs[2] = -0.0;
    xs[3] = 0.0;
    xs[n - 2] = f64::INFINITY;
    xs[n - 3] = f64::NEG_INFINITY;
}

fn split_into_runs<K: SortKey + Clone>(xs: &[K], k: usize, seed: u64) -> Vec<Vec<K>> {
    let mut rng = Prng::new(seed);
    let mut runs: Vec<Vec<K>> = (0..k).map(|_| Vec::new()).collect();
    for x in xs {
        runs[rng.below(k as u64) as usize].push(*x);
    }
    for r in &mut runs {
        r.sort_unstable_by(|a, b| a.cmp_total(b));
    }
    runs
}

/// Merge-path k-way + 2-way vs the sequential engine, all distributions
/// and thread counts for one dtype.
fn check_merge_engine<K: KeyGen>(seed: u64) {
    let n = PAR_MERGE_MIN + 1234;
    for dist in Distribution::ALL {
        let mut xs: Vec<K> = generate(&mut Prng::new(seed), dist, n);
        inject_specials(&mut xs);
        let runs = split_into_runs(&xs, 5, seed + 1);
        let refs: Vec<&[K]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut want = vec![K::min_key(); n];
        kmerge_into_slice(&refs, &mut want);
        for t in THREADS {
            let got = merge_path::kmerge_parallel(&refs, t);
            assert!(bits_eq(&got, &want), "kmerge {dist:?} t={t} {}", K::ELEM);
        }
        // 2-way co-rank path on an uneven split.
        let two = split_into_runs(&xs, 2, seed + 2);
        let mut want2 = vec![K::min_key(); n];
        kmerge_into_slice(&[&two[0], &two[1]], &mut want2);
        for t in THREADS {
            let got = merge_path::merge2_parallel(&two[0], &two[1], t);
            assert!(bits_eq(&got, &want2), "merge2 {dist:?} t={t} {}", K::ELEM);
        }
    }
}

/// Threaded radix vs the sequential passes, all distributions and thread
/// counts for one dtype.
fn check_radix_engine<K: KeyGen>(seed: u64) {
    let n = RADIX_PAR_MIN + 77;
    for dist in Distribution::ALL {
        let mut xs: Vec<K> = generate(&mut Prng::new(seed), dist, n);
        inject_specials(&mut xs);
        let mut want = xs.clone();
        radix_sort(&mut want);
        for t in THREADS {
            let mut got = xs.clone();
            radix_sort_threaded(&mut got, t);
            assert!(bits_eq(&got, &want), "radix {dist:?} t={t} {}", K::ELEM);
        }
    }
}

#[test]
fn merge_engine_i16() {
    check_merge_engine::<i16>(101);
}

#[test]
fn merge_engine_i32() {
    check_merge_engine::<i32>(102);
}

#[test]
fn merge_engine_i64() {
    check_merge_engine::<i64>(103);
}

#[test]
fn merge_engine_i128() {
    check_merge_engine::<i128>(104);
}

#[test]
fn merge_engine_f32() {
    check_merge_engine::<f32>(105);
}

#[test]
fn merge_engine_f64() {
    check_merge_engine::<f64>(106);
}

#[test]
fn radix_engine_i16() {
    check_radix_engine::<i16>(201);
}

#[test]
fn radix_engine_i32() {
    check_radix_engine::<i32>(202);
}

#[test]
fn radix_engine_i64() {
    check_radix_engine::<i64>(203);
}

#[test]
fn radix_engine_i128() {
    check_radix_engine::<i128>(204);
}

#[test]
fn radix_engine_f32() {
    check_radix_engine::<f32>(205);
}

#[test]
fn radix_engine_f64() {
    check_radix_engine::<f64>(206);
}

#[test]
fn radix_threaded_handles_nan_and_signed_zero() {
    let n = RADIX_PAR_MIN + 500;
    let mut xs: Vec<f64> = generate(&mut Prng::new(301), Distribution::DupHeavy, n);
    inject_float_specials_f64(&mut xs);
    let mut want = xs.clone();
    want.sort_unstable_by(|a, b| a.cmp_total(b));
    for t in THREADS {
        let mut got = xs.clone();
        radix_sort_threaded(&mut got, t);
        assert!(bits_eq(&got, &want), "t={t}");
    }
}

#[test]
fn merge_path_handles_nan_and_signed_zero() {
    let n = PAR_MERGE_MIN + 500;
    let mut xs: Vec<f64> = generate(&mut Prng::new(302), Distribution::Uniform, n);
    inject_float_specials_f64(&mut xs);
    let runs = split_into_runs(&xs, 3, 303);
    let refs: Vec<&[f64]> = runs.iter().map(|r| r.as_slice()).collect();
    let mut want = xs.clone();
    want.sort_unstable_by(|a, b| a.cmp_total(b));
    for t in THREADS {
        let got = merge_path::kmerge_parallel(&refs, t);
        assert!(bits_eq(&got, &want), "t={t}");
    }
}

#[test]
fn empty_and_tiny_runs_every_engine() {
    // Merge engines: empty run lists, all-empty runs, single elements.
    let empty: Vec<&[i32]> = vec![];
    assert!(merge_path::kmerge_parallel(&empty, 7).is_empty());
    let e1: Vec<i32> = vec![];
    let e2: Vec<i32> = vec![];
    assert!(merge_path::kmerge_parallel(&[&e1, &e2], 3).is_empty());
    assert!(merge_path::merge2_parallel(&e1, &e2, 3).is_empty());
    let one = vec![42i32];
    assert_eq!(merge_path::merge2_parallel(&one, &e1, 7), vec![42]);
    assert_eq!(merge_path::kmerge_parallel(&[&one, &e1, &one], 7), vec![42, 42]);
    // Radix: empty / single / pair for every thread count.
    for t in THREADS {
        let mut v: Vec<i64> = vec![];
        radix_sort_threaded(&mut v, t);
        assert!(v.is_empty());
        let mut v = vec![5i64];
        radix_sort_threaded(&mut v, t);
        assert_eq!(v, vec![5]);
        let mut v = vec![9i64, -9];
        radix_sort_threaded(&mut v, t);
        assert_eq!(v, vec![-9, 9]);
    }
}

#[test]
fn threaded_sort_matches_native_across_threads() {
    // End-to-end: the Threaded backend (chunk sort + merge-path
    // recombine) equals the Native engine for every thread count.
    let n = PAR_MERGE_MIN + 4096;
    for dist in [Distribution::Uniform, Distribution::Reverse, Distribution::DupHeavy] {
        let mut xs: Vec<f32> = generate(&mut Prng::new(400), dist, n);
        inject_specials(&mut xs);
        xs[5] = f32::NAN;
        xs[6] = -0.0;
        let mut want = xs.clone();
        accelkern::session::Session::native().sort(&mut want, None).unwrap();
        for t in THREADS {
            let mut got = xs.clone();
            accelkern::session::Session::threaded(t).sort(&mut got, None).unwrap();
            assert!(bits_eq(&got, &want), "{dist:?} t={t}");
        }
    }
}

#[test]
fn local_sorter_tr_uses_consistent_engine() {
    // The TR local sorter auto-dispatches to the threaded radix above
    // RADIX_PAR_MIN; its output must stay identical to JB's.
    use accelkern::mpisort::LocalSorter;
    let n = RADIX_PAR_MIN + 1000;
    let xs: Vec<i32> = generate(&mut Prng::new(500), Distribution::Uniform, n);
    let mut want = xs.clone();
    LocalSorter::JuliaBase.sort(&mut want, &accelkern::session::Launch::default()).unwrap();
    let mut got = xs;
    LocalSorter::ThrustRadix
        .sort(&mut got, &accelkern::session::Launch::default())
        .unwrap();
    assert_eq!(got, want);
}
