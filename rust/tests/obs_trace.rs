//! End-to-end observability suite (DESIGN.md §18).
//!
//! The contract under test: arming a [`TraceSession`] around a faulted
//! multi-rank sort yields a Chrome/Perfetto-loadable timeline — one
//! named track per rank with well-nested phase spans, instant markers
//! for every injected fault and recovery attempt, and per-link
//! in-flight counter tracks — and that property survives panics
//! (spans are RAII, the session flushes partial rings on drop) and
//! spill-dir cleanup (a trace path inside a `TempDirGuard` tree is
//! remapped outside before the guard deletes the tree).
//!
//! Tracing is armed process-wide, so every test here serialises on
//! [`SESSION_LOCK`] before starting a session.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use accelkern::cfg::{RunConfig, Sorter};
use accelkern::coordinator::driver::run_distributed_sort_data;
use accelkern::dtype::ElemType;
use accelkern::obs::{self, SpanKind, TraceSession};
use accelkern::stream::TempDirGuard;
use accelkern::util::json::Json;
use accelkern::util::Prng;

static SESSION_LOCK: Mutex<()> = Mutex::new(());

fn session_lock() -> MutexGuard<'static, ()> {
    match SESSION_LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// A unique trace path in the OS temp dir (outside any spill guard).
fn trace_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("akobs-{tag}-{}.json", std::process::id()))
}

fn read_events(path: &std::path::Path) -> Vec<Json> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("trace file {} unreadable: {e}", path.display()));
    let doc = Json::parse(&text).expect("trace file is valid JSON");
    doc.get("traceEvents").as_arr().expect("traceEvents array").to_vec()
}

/// Per-track nesting check: scanning each tid's events in file order,
/// the B/E depth never dips negative and ends at zero.
fn assert_balanced(events: &[Json]) {
    let mut depth: std::collections::BTreeMap<usize, i64> = Default::default();
    for e in events {
        let tid = e.get("tid").as_usize().unwrap_or(0);
        match e.get("ph").as_str() {
            Some("B") => *depth.entry(tid).or_insert(0) += 1,
            Some("E") => {
                let d = depth.entry(tid).or_insert(0);
                *d -= 1;
                assert!(*d >= 0, "track {tid}: E without a matching B");
            }
            _ => {}
        }
    }
    for (tid, d) in depth {
        assert_eq!(d, 0, "track {tid}: {d} span(s) left open after export");
    }
}

fn names_of<'a>(events: &'a [Json], ph: &str, cat: Option<&str>) -> Vec<&'a str> {
    events
        .iter()
        .filter(|e| e.get("ph").as_str() == Some(ph))
        .filter(|e| cat.is_none() || e.get("cat").as_str() == cat)
        .filter_map(|e| e.get("name").as_str())
        .collect()
}

// ---- the flagship run: faulted 4-rank cluster-stream sort, traced --------

#[test]
fn faulted_four_rank_run_emits_a_loadable_perfetto_timeline() {
    let _g = session_lock();
    let ckpt = TempDirGuard::new(None).unwrap();
    let out = trace_path("cluster");

    // 4 ranks on the external (out-of-core) rank-local sorter with a
    // budget an eighth of the shard, checkpointed; the fault plan drops
    // two deliveries on link 0->1 and kills rank 1 mid-exchange, so a
    // successful run must have restarted in-process at least once.
    let mut cfg = RunConfig::default();
    cfg.ranks = 4;
    cfg.elems_per_rank = 4000;
    cfg.dtype = ElemType::I64;
    cfg.sorter = Sorter::External;
    cfg.host_threads = 2;
    cfg.stream.budget_bytes = Some(4000 * cfg.dtype.size_bytes() / 8);
    cfg.stream.checkpoint_dir = Some(ckpt.path().to_string_lossy().into_owned());
    cfg.comm.recv_timeout_secs = 30.0;
    cfg.comm.send_timeout_secs = 30.0;
    cfg.comm.retry_attempts = 10;
    cfg.comm.max_restarts = 2;
    cfg.comm.faults = Some("drop:0:1:2, kill:1:2:exchange".into());

    let mut session = TraceSession::start(Some(&out), false, 1 << 16);
    let (run, _outcomes) =
        run_distributed_sort_data::<i64>(&cfg, None).expect("faulted job recovers");
    session.flush();
    assert!(run.record.recoveries() >= 1, "the kill must force a restart");
    assert!(run.record.dropped() >= 2, "the drop rule must have fired: {}", run.record.row());

    let events = read_events(&out);
    assert!(events.len() > 20, "suspiciously sparse trace: {} events", events.len());
    assert_balanced(&events);

    // One named track per rank (thread_name metadata).
    let labels: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("M"))
        .filter_map(|e| e.get("args").get("name").as_str())
        .collect();
    for rank in 0..4 {
        let want = format!("rank {rank}");
        assert!(labels.contains(&want.as_str()), "no track labelled `{want}`: {labels:?}");
    }

    // Per-rank phase spans from the fabric's note_phase stream.
    let phases = names_of(&events, "B", Some("phase"));
    for phase in ["local-sort", "splitters", "exchange", "final"] {
        assert!(phases.contains(&phase), "missing phase span `{phase}`: {phases:?}");
    }
    // The out-of-core sorter's pass spans and checkpoint writes.
    assert!(
        names_of(&events, "B", Some("pass")).iter().any(|n| n.starts_with("ext.")),
        "no external-sort pass spans"
    );
    assert!(
        names_of(&events, "B", Some("checkpoint")).contains(&"manifest.write"),
        "no manifest checkpoint spans"
    );
    assert!(!names_of(&events, "B", Some("collective")).is_empty(), "no collective spans");

    // Fault instants: both injected rules must be on the timeline, and
    // the driver's restart must leave a recovery marker.
    let faults = names_of(&events, "i", Some("fault"));
    assert!(faults.iter().filter(|n| **n == "fault.drop").count() >= 2, "{faults:?}");
    assert!(faults.contains(&"fault.kill"), "{faults:?}");
    assert!(
        names_of(&events, "i", Some("recovery")).contains(&"driver.restart"),
        "no driver.restart recovery instant"
    );

    // Per-link in-flight counter tracks, with sane names only.
    let counters: std::collections::BTreeSet<&str> = events
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("C"))
        .filter_map(|e| e.get("name").as_str())
        .collect();
    let inflight: Vec<&str> =
        counters.iter().copied().filter(|n| n.starts_with("inflight.")).collect();
    assert!(!inflight.is_empty(), "no in-flight counter tracks: {counters:?}");
    for n in &inflight {
        assert!(
            ["inflight.nvlink", "inflight.ib", "inflight.pcie", "inflight.hostmem"].contains(n),
            "unknown counter track {n}"
        );
    }

    let _ = std::fs::remove_file(&out);
}

// ---- panic safety: partial rings still flush to a loadable file ----------

#[test]
fn panicking_traced_run_flushes_partial_rings_on_drop() {
    let _g = session_lock();
    let out = trace_path("panic");

    let session = TraceSession::start(Some(&out), false, 4096);
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // keep the injected panics quiet
    let r = catch_unwind(AssertUnwindSafe(|| {
        let _outer = obs::span(SpanKind::Phase, "doomed-phase");
        let _inner = obs::span(SpanKind::Pass, "doomed-pass");
        obs::instant(SpanKind::Fault, "fault.injected");
        panic!("injected mid-span");
    }));
    std::panic::set_hook(hook);
    assert!(r.is_err());
    // Flush-on-drop is the property under test: no explicit flush call.
    drop(session);

    let events = read_events(&out);
    assert_balanced(&events);
    let spans = names_of(&events, "B", None);
    assert!(spans.contains(&"doomed-phase") && spans.contains(&"doomed-pass"), "{spans:?}");
    assert!(names_of(&events, "i", Some("fault")).contains(&"fault.injected"));
    let _ = std::fs::remove_file(&out);
}

// ---- property: open/close balance under random nesting + panics ----------

/// Randomly nested spans, each frame panicking with small probability;
/// depth and fan-out are driven by the seeded [`Prng`].
fn random_nest(rng: &mut Prng, depth: usize) {
    const NAMES: [&str; 4] = ["prop.a", "prop.b", "prop.c", "prop.d"];
    let _g = obs::span(SpanKind::Pass, NAMES[(rng.uniform_f64() * 4.0) as usize % 4]);
    if rng.uniform_f64() < 0.08 {
        panic!("injected");
    }
    if depth < 6 {
        let kids = (rng.uniform_f64() * 3.0) as usize;
        for _ in 0..kids {
            random_nest(rng, depth + 1);
        }
    }
}

#[test]
fn span_balance_survives_random_nesting_and_panics() {
    let _g = session_lock();
    let out = trace_path("prop");

    let session = TraceSession::start(Some(&out), false, 1 << 16);
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut root = Prng::new(0x0B5);
    let handles: Vec<_> = (0..3u64)
        .map(|t| {
            let mut rng = root.fork(t);
            std::thread::spawn(move || {
                for _ in 0..64 {
                    let _ = catch_unwind(AssertUnwindSafe(|| random_nest(&mut rng, 0)));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    std::panic::set_hook(hook);
    drop(session);

    let events = read_events(&out);
    assert_balanced(&events);
    // The rings were large enough that nothing was silently dropped.
    assert!(
        !names_of(&events, "i", None).contains(&"ring_dropped_events"),
        "a full ring dropped events — the balance check would be vacuous"
    );
    assert!(
        names_of(&events, "B", None).iter().any(|n| n.starts_with("prop.")),
        "the property run recorded no spans at all"
    );
    let _ = std::fs::remove_file(&out);
}

// ---- spill-dir safety: traces never land inside a guarded tree -----------

#[test]
fn trace_path_inside_a_spill_guard_is_remapped_outside() {
    let _g = session_lock();
    let parent = std::env::temp_dir().join(format!("akobs-remap-{}", std::process::id()));
    std::fs::create_dir_all(&parent).unwrap();
    let guard = TempDirGuard::new(Some(&parent)).unwrap();
    let requested = guard.path().join("deep").join("trace.json");

    let mut session = TraceSession::start(Some(&requested), false, 4096);
    let landed = session.out_path().expect("an output path survives remapping").to_path_buf();
    assert!(
        !landed.starts_with(guard.path()),
        "trace {} still inside the doomed guard tree {}",
        landed.display(),
        guard.path().display()
    );
    assert_eq!(landed, parent.join("trace.json"));

    obs::instant(SpanKind::Fault, "fault.survivor");
    session.flush();
    drop(guard); // deletes the whole spill tree
    let events = read_events(&landed);
    assert!(names_of(&events, "i", Some("fault")).contains(&"fault.survivor"));
    drop(session);
    let _ = std::fs::remove_dir_all(&parent);
}
