//! Kill-at-every-boundary crash/resume matrix (DESIGN.md §15).
//!
//! The contract under test: `external_sort_ckpt` and the checkpointed
//! SIHSort collective can be killed — by an injected error or by a
//! panic simulating abrupt process death — at *every* phase/pass
//! boundary and mid-merge, and a resume over the identical input
//! produces bitwise what the uninterrupted in-memory `Session::sort`
//! produces, leaves no orphaned spill files behind, and turns a resume
//! of an already-complete job into a no-op.
//!
//! Every test here arms a fail point and holds the process-wide fault
//! lock for its full duration (disarm-and-rearm on the same guard,
//! never drop-and-rearm), so the tests in this binary serialise and
//! never trip each other's sites. This is also the only binary that
//! arms sites shared with non-checkpointed paths (`ext.merge.mid`,
//! `sih.exchange.sent`, `driver.verify`) — arming those in the
//! equivalence suites would trip their plain-path tests.

use std::collections::HashSet;
use std::path::Path;

use accelkern::backend::DeviceKey;
use accelkern::cfg::{RunConfig, Sorter, TransferMode};
use accelkern::cluster::ClusterSpec;
use accelkern::comm::Fabric;
use accelkern::coordinator::driver::run_distributed_sort_data;
use accelkern::dtype::{bits_eq, ElemType};
use accelkern::mpisort::{sihsort_rank, LocalSorter, SihConfig, SihStreamCfg};
use accelkern::session::Session;
use accelkern::stream::manifest::load_manifest;
use accelkern::stream::{
    Checkpoint, MANIFEST_FILE, SliceSource, SpillMedium, StreamBudget, StreamCtx, TempDirGuard,
    VecSink,
};
use accelkern::util::failpoint::{self, FailMode, FailpointGuard};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution, KeyGen};

// ---- external_sort_ckpt: every boundary ----------------------------------

/// Fixture shape: 40k elements in 5000-element runs at fan-in 2 gives
/// 8 generation runs, two intermediate merge passes and a final merge —
/// every site below is reachable at skip 0.
const EXT_SITES: &[&str] = &[
    "manifest.rename",
    "ext.run",
    "ext.run.recorded",
    "ext.gen-done",
    "ext.merge.group",
    "ext.merge.mid",
    "ext.merge.retired",
    "ext.merge.pass",
    "ext.final",
    "ext.final.mid",
];

fn ext_ctx() -> StreamCtx {
    Session::threaded(2)
        .stream(StreamBudget::bytes(64))
        .run_chunk_elems(5000)
        .fan_in(2)
        .io_chunk_elems(509)
}

fn sorted_ref<K: KeyGen + DeviceKey>(data: &[K]) -> Vec<K> {
    let mut want = data.to_vec();
    Session::threaded(2).sort(&mut want, None).unwrap();
    want
}

/// Run the checkpointed sort expecting the armed site to kill it.
fn crash_external<K: DeviceKey>(ctx: &StreamCtx, data: &[K], dir: &Path, site: &str) {
    let crashed = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sink = VecSink::new();
        ctx.external_sort_ckpt(
            &mut SliceSource::new(data),
            &mut sink,
            None,
            &Checkpoint::new(dir, "matrix"),
        )
    })) {
        Ok(Ok(_)) => false,
        Ok(Err(e)) => {
            let e: anyhow::Error = e.into();
            assert!(
                failpoint::is_abort(&e),
                "{site}: genuine failure instead of the injected abort: {e:#}"
            );
            true
        }
        Err(_) => true,
    };
    assert!(crashed, "{site}: the armed fail point must kill the run");
}

/// Resume after the crash: bitwise output, all elements, then assert
/// the completed job reclaimed every spill file (only the manifest
/// remains) and that resuming it again is a no-op.
fn resume_and_verify<K: DeviceKey>(
    ctx: &StreamCtx,
    data: &[K],
    want: &[K],
    dir: &Path,
    site: &str,
) {
    let mut sink = VecSink::new();
    let stats = ctx
        .external_sort_ckpt(
            &mut SliceSource::new(data),
            &mut sink,
            None,
            &Checkpoint::new(dir, "matrix").resume(),
        )
        .unwrap_or_else(|e| panic!("{site}: resume failed: {e:#}"));
    assert_eq!(stats.elems, data.len() as u64, "{site}");
    assert!(bits_eq(&sink.out, want), "{site}: resumed output diverges from Session::sort");

    let names: Vec<String> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert_eq!(names, vec![MANIFEST_FILE.to_string()], "{site}: spill files leaked");

    // Completed-job resume is a no-op: the empty source proves the
    // engine returned before reading anything.
    let empty: Vec<K> = Vec::new();
    let mut sink = VecSink::new();
    let stats = ctx
        .external_sort_ckpt(
            &mut SliceSource::new(&empty),
            &mut sink,
            None,
            &Checkpoint::new(dir, "matrix").resume(),
        )
        .unwrap();
    assert!(stats.completed_noop, "{site}: completed job must resume as a no-op");
    assert!(sink.out.is_empty(), "{site}");
}

fn external_matrix<K: KeyGen + DeviceKey>(data: &[K], mode: FailMode, guard: &FailpointGuard) {
    let parent = TempDirGuard::new(None).unwrap();
    let ctx = ext_ctx();
    let want = sorted_ref(data);
    for (i, &site) in EXT_SITES.iter().enumerate() {
        let dir = parent.path().join(format!("cell-{i}"));
        guard.rearm(site, 0, mode);
        crash_external(&ctx, data, &dir, site);
        guard.disarm();
        resume_and_verify(&ctx, data, &want, &dir, site);
    }
}

#[test]
fn external_sort_kill_every_boundary_i64() {
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    let data: Vec<i64> = generate(&mut Prng::new(31), Distribution::Uniform, 40_000);
    external_matrix(&data, FailMode::Error, &guard);
}

#[test]
fn external_sort_kill_every_boundary_f64_nan() {
    // NaN payloads, −0.0, signed infinities and duplicates must survive
    // every kill/resume bit-exactly.
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    let mut rng = Prng::new(32);
    let data: Vec<f64> = (0..40_000usize)
        .map(|i| match i % 9 {
            0 => f64::NAN,
            1 => -f64::NAN,
            2 => -0.0,
            3 => 0.0,
            4 => f64::INFINITY,
            5 => f64::NEG_INFINITY,
            6 => (i % 13) as f64 - 6.0,
            _ => <f64 as KeyGen>::uniform(&mut rng),
        })
        .collect();
    external_matrix(&data, FailMode::Error, &guard);
}

#[test]
fn external_sort_kill_every_boundary_by_panic() {
    // The abrupt-death model: no error-path cleanup, only Drop impls.
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    let data: Vec<i64> = generate(&mut Prng::new(33), Distribution::DupHeavy, 40_000);
    external_matrix(&data, FailMode::Panic, &guard);
}

#[test]
fn run_park_crash_keeps_recorded_runs_and_sweeps_the_orphan() {
    // The satellite-1/2 regression, observed precisely: `ext.run` sits
    // after a run file is written and fsynced but before the manifest
    // references it. Killing there with two runs already recorded must
    // never delete the two checkpointed run files; the unmanifested
    // third run is reclaimed — by `Drop` during this in-process unwind,
    // and by the resume's sweep after a hard kill where no `Drop` ran
    // (simulated below by planting an orphan by hand).
    let guard = failpoint::arm("ext.run", 2, FailMode::Panic);
    let parent = TempDirGuard::new(None).unwrap();
    let dir = parent.path().join("park");
    let ctx = ext_ctx();
    let data: Vec<i64> = generate(&mut Prng::new(34), Distribution::Uniform, 40_000);
    let want = sorted_ref(&data);
    crash_external(&ctx, &data, &dir, "ext.run");
    guard.disarm();

    let m = load_manifest(&dir).unwrap().expect("manifest survives the crash");
    assert_eq!(m.runs.len(), 2, "two runs were recorded before the kill");
    assert!(!m.gen_done);
    let mut files: HashSet<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    for r in &m.runs {
        assert!(files.remove(&r.file), "checkpointed run '{}' was deleted", r.file);
    }
    assert!(files.remove(MANIFEST_FILE));
    assert!(files.is_empty(), "the unwind must reclaim the unmanifested run: {files:?}");

    // A hard kill runs no destructors: fake the orphan such a crash
    // would strand and let the resume's sweep reclaim it.
    std::fs::write(dir.join("orphan-999.bin"), b"stranded by a hard kill").unwrap();
    resume_and_verify(&ctx, &data, &want, &dir, "ext.run");
}

// ---- the checkpointed SIHSort collective: every boundary ------------------

/// Every kill site of the checkpointed rank pipeline plus the
/// post-rank driver site, in schedule order.
const SIH_SITES: &[&str] = &[
    "sih.park",
    "sih.parked",
    "sih.splitters",
    "sih.splitters.recorded",
    "sih.exchange.sent",
    "sih.exchange",
    "sih.exchange.recorded",
    "sih.final",
    "sih.final.mid",
    "sih.done",
    "driver.verify",
];

/// 8192 i64/rank against a 2048-element budget: 8 local runs at
/// fan-in 2, so every rank streams through the full multi-pass shape.
fn cluster_cfg(ranks: usize, dir: &Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.ranks = ranks;
    cfg.elems_per_rank = 8192;
    cfg.dtype = ElemType::I64;
    cfg.sorter = Sorter::External;
    cfg.host_threads = 2;
    cfg.stream.budget_bytes = Some(2048 * 8);
    cfg.stream.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    cfg
}

/// Single-node reference over the driver's deterministic shards.
fn cluster_reference(cfg: &RunConfig) -> Vec<i64> {
    let mut root = Prng::new(cfg.seed);
    let mut all: Vec<i64> = Vec::with_capacity(cfg.ranks * cfg.elems_per_rank);
    for r in 0..cfg.ranks {
        let mut rng = root.fork(r as u64);
        all.extend(generate::<i64>(&mut rng, cfg.dist, cfg.elems_per_rank));
    }
    Session::threaded(2).sort(&mut all, None).unwrap();
    all
}

/// Run the collective expecting the armed site to kill it (the fail
/// point trips on every rank — all ranks dying at the same site is the
/// simulated whole-process kill).
fn crash_driver(cfg: &RunConfig, site: &str) {
    let crashed = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_distributed_sort_data::<i64>(cfg, None)
    })) {
        Ok(Ok(_)) => false,
        Ok(Err(e)) => {
            assert!(
                failpoint::is_abort(&e),
                "{site}: genuine failure instead of the injected abort: {e:#}"
            );
            true
        }
        Err(_) => true,
    };
    assert!(crashed, "{site}: the armed fail point must kill the collective");
}

/// After a completed (resumed or uninterrupted) checkpointed collective,
/// each rank directory holds exactly its manifest plus the manifested
/// parked-shard and output files — no orphans, no stale exchange runs,
/// no leftover nested checkpoint.
fn assert_rank_dirs_clean(root: &Path, ranks: usize) {
    for r in 0..ranks {
        let dir = root.join(format!("rank-{r}"));
        let m = load_manifest(&dir).unwrap().expect("rank manifest");
        assert_eq!(m.phase, 6, "rank {r}: not committed to the final phase");
        assert!(
            m.runs.iter().all(|x| x.pass == 1 || x.pass == 6),
            "rank {r}: stale exchange runs in the manifest: {:?}",
            m.runs
        );
        let mut expect: HashSet<String> = m.runs.iter().map(|x| x.file.clone()).collect();
        expect.insert(MANIFEST_FILE.to_string());
        for e in std::fs::read_dir(&dir).unwrap() {
            let e = e.unwrap();
            let name = e.file_name().to_string_lossy().into_owned();
            assert!(
                e.file_type().unwrap().is_file(),
                "rank {r}: leftover directory '{name}' after resume"
            );
            assert!(expect.contains(&name), "rank {r}: orphan spill file '{name}'");
        }
    }
}

fn cluster_matrix(ranks: usize, mode: FailMode, sites: &[&'static str], guard: &FailpointGuard) {
    let parent = TempDirGuard::new(None).unwrap();
    for (i, &site) in sites.iter().enumerate() {
        let dir = parent.path().join(format!("cell-{i}"));
        let mut cfg = cluster_cfg(ranks, &dir);
        let want = cluster_reference(&cfg);
        guard.rearm(site, 0, mode);
        crash_driver(&cfg, site);
        guard.disarm();
        cfg.stream.resume = true;
        let (_, outcomes) = run_distributed_sort_data::<i64>(&cfg, None)
            .unwrap_or_else(|e| panic!("{site}: resume failed: {e:#}"));
        let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
        assert!(
            bits_eq(&got, &want),
            "{site} (ranks={ranks}): resumed collective diverges from the single-node sort"
        );
        assert_rank_dirs_clean(&dir, ranks);
    }
}

#[test]
fn cluster_kill_every_boundary_2_ranks() {
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    cluster_matrix(2, FailMode::Error, SIH_SITES, &guard);
}

#[test]
fn cluster_kill_every_boundary_4_ranks() {
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    cluster_matrix(4, FailMode::Error, SIH_SITES, &guard);
}

#[test]
fn cluster_kill_by_panic() {
    // Abrupt-death model across the three structurally distinct
    // regions: the per-rank park, the deadlock-free mid-exchange site
    // (all sends queued, no receive started) and the mid-final-merge
    // loop inside the measured section.
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    cluster_matrix(2, FailMode::Panic, &["sih.park", "sih.exchange.sent", "sih.final.mid"], &guard);
}

#[test]
fn completed_cluster_resume_is_a_cheap_reload() {
    // Resume a collective that already finished: every rank is at
    // phase 6 and reloads its durable output instead of recomputing;
    // the driver's verification still passes and the output is
    // unchanged.
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    guard.disarm();
    let parent = TempDirGuard::new(None).unwrap();
    let dir = parent.path().join("completed");
    let mut cfg = cluster_cfg(2, &dir);
    let want = cluster_reference(&cfg);
    let (_, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want));
    assert_rank_dirs_clean(&dir, 2);

    cfg.stream.resume = true;
    let (_, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(bits_eq(&got, &want), "reloaded outputs diverge");
    assert_rank_dirs_clean(&dir, 2);
}

// ---- adversarial values through a hand-built checkpointed collective ------

#[test]
fn nan_neg_zero_cluster_crash_resume_bitwise() {
    // The driver generates its own workloads, so NaN/−0.0 injection
    // goes through `sihsort_rank` + `LocalSorter::External` directly
    // with checkpointing on, killed mid-schedule and resumed.
    let guard = failpoint::arm("fp.matrix.hold", 0, FailMode::Error);
    let parent = TempDirGuard::new(None).unwrap();
    let ck_root = parent.path().join("nan");
    let mut rng = Prng::new(78);
    let shards: Vec<Vec<f64>> = (0..2)
        .map(|_r| {
            (0..6000usize)
                .map(|i| match i % 7 {
                    0 => f64::NAN,
                    1 => -f64::NAN,
                    2 => -0.0,
                    3 => 0.0,
                    4 => (i % 11) as f64 - 5.0,
                    5 => f64::INFINITY,
                    _ => <f64 as KeyGen>::uniform(&mut rng),
                })
                .collect()
        })
        .collect();
    let mut want: Vec<f64> = shards.iter().flatten().copied().collect();
    Session::threaded(2).sort(&mut want, None).unwrap();

    let run_once = |resume: bool| -> Vec<anyhow::Result<(usize, Vec<f64>)>> {
        let p = shards.len();
        let scfg = SihStreamCfg {
            budget: StreamBudget::bytes(2048 * 8),
            medium: SpillMedium::Disk,
            spill_dir: None,
            ckpt_dir: Some(ck_root.clone()),
            resume,
        };
        let ctx = scfg.ctx(Session::threaded(2));
        let mut cfg = SihConfig::default();
        cfg.stream = Some(scfg);
        let eps = Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![false; p]);
        let shards = shards.clone();
        std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .zip(shards)
                .map(|(mut ep, shard)| {
                    let ctx = ctx.clone();
                    let cfg = cfg.clone();
                    s.spawn(move || {
                        let sorter = LocalSorter::External(ctx);
                        let o = sihsort_rank(&mut ep, shard, &sorter, &cfg)?;
                        Ok((ep.rank(), o.data))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    };

    guard.rearm("sih.exchange", 0, FailMode::Error);
    for res in run_once(false) {
        let e = res.expect_err("every rank must die at the armed site");
        assert!(failpoint::is_abort(&e), "{e:#}");
    }
    guard.disarm();

    let mut out: Vec<Vec<f64>> = vec![Vec::new(); 2];
    for res in run_once(true) {
        let (rank, data) = res.expect("resume must complete");
        out[rank] = data;
    }
    let got: Vec<f64> = out.into_iter().flatten().collect();
    assert!(
        bits_eq(&got, &want),
        "NaN payloads / −0.0 must survive the checkpointed crash/resume bit-exactly"
    );
}
