//! Record-stream equivalence suite (DESIGN.md §19).
//!
//! The contract under test: every record workload — external
//! sort-by-key, sortperm, group-by reduce, merge-join, distinct —
//! produces exactly what the in-memory reference computes, across
//! dtypes × payload widths × spill media × multi-pass merge budgets,
//! including NaN / -0.0 / duplicate keys; and the record layout is part
//! of checkpoint identity, so a resume against a different layout is a
//! typed error while a genuine mid-job interruption resumes bitwise.
//!
//! "Exactly" means key image AND payload bits: the external record sort
//! is stable, so equal keys keep input order and the payloads pin the
//! full permutation — any instability or payload corruption fails here.

use std::collections::HashMap;

use accelkern::algorithms::ReduceKind;
use accelkern::backend::DeviceKey;
use accelkern::session::Session;
use accelkern::stream::{
    Checkpoint, ChunkSink, Payload, Record, SliceSource, StreamBudget, StreamCtx, StreamRecord,
    TempDirGuard, VecSink,
};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution, KeyGen};

/// Elements per suite dataset: ~10 runs of 1024 at fan-in 2 forces at
/// least two intermediate merge passes plus the final merge.
const N: usize = 10_240;

fn ctx(disk: bool) -> StreamCtx {
    let c = Session::threaded(2)
        .stream(StreamBudget::bytes(64))
        .run_chunk_elems(1024)
        .fan_in(2);
    if disk {
        c // Disk is the default medium.
    } else {
        c.in_memory_spill()
    }
}

/// Records with `generate`d keys and position payloads — the payload
/// pins each record's input slot, so the verifier sees any reordering.
fn indexed<K: KeyGen + DeviceKey, P: Payload>(seed: u64, n: usize) -> Vec<Record<K, P>> {
    let keys: Vec<K> = generate(&mut Prng::new(seed), Distribution::DupHeavy, n);
    keys.into_iter()
        .enumerate()
        .map(|(i, k)| Record::new(k, P::from_raw(i as u128)))
        .collect()
}

fn assert_records_eq<R: StreamRecord>(got: &[R], want: &[R], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.key_bits() == w.key_bits() && g.payload_raw() == w.payload_raw(),
            "{what}: diverges at {i}: {g:?} vs {w:?}"
        );
    }
}

/// The in-memory stable reference for one record dataset.
fn sorted_ref<K: DeviceKey, P: Payload>(data: &[Record<K, P>]) -> Vec<Record<K, P>> {
    let mut want = data.to_vec();
    Record::<K, P>::sort_chunk(&Session::threaded(2), &mut want, None).unwrap();
    want
}

fn check_sort_by_key<K: KeyGen + DeviceKey, P: Payload>(seed: u64, disk: bool) {
    let data: Vec<Record<K, P>> = indexed(seed, N);
    let want = sorted_ref(&data);
    let mut sink = VecSink::new();
    let stats =
        ctx(disk).stream_sort_by_key(&mut SliceSource::new(&data), &mut sink, None).unwrap();
    assert_eq!(stats.elems, N as u64);
    assert!(
        stats.merge_passes >= 2,
        "suite must exercise multi-pass merges ({} passes)",
        stats.merge_passes
    );
    let what = format!("sort-by-key<{}> disk={disk}", Record::<K, P>::layout_name());
    assert_records_eq(&sink.out, &want, &what);
}

#[test]
fn sort_by_key_bitwise_across_dtypes_payloads_and_media() {
    for disk in [false, true] {
        check_sort_by_key::<i32, u32>(11, disk);
        check_sort_by_key::<i32, u128>(12, disk);
        check_sort_by_key::<i64, u64>(13, disk);
        check_sort_by_key::<i128, u64>(14, disk);
        check_sort_by_key::<f32, u64>(15, disk);
        check_sort_by_key::<f64, u32>(16, disk);
    }
}

#[test]
fn sort_by_key_preserves_nan_and_negative_zero_payloads() {
    // Hand-placed specials with distinct payloads: the stable sort must
    // keep each special's payload attached and its input order among
    // bit-identical duplicates.
    let mut data: Vec<Record<f64, u64>> = indexed(21, N);
    for (i, bits) in [f64::NAN, -0.0, 0.0, f64::NAN, -0.0, f64::INFINITY, f64::NEG_INFINITY]
        .iter()
        .enumerate()
    {
        data[i * 997] = Record::new(*bits, 0xDEAD_0000 + i as u64);
    }
    let want = sorted_ref(&data);
    for disk in [false, true] {
        let mut sink = VecSink::new();
        ctx(disk).stream_sort_by_key(&mut SliceSource::new(&data), &mut sink, None).unwrap();
        assert_records_eq(&sink.out, &want, &format!("f64 specials disk={disk}"));
    }
    // The two NaNs keep input order (payload 0xDEAD_0000 before
    // 0xDEAD_0003) at the very top of the total order.
    let top2: Vec<u64> = want[want.len() - 2..].iter().map(|r| r.val).collect();
    assert_eq!(top2, vec![0xDEAD_0000, 0xDEAD_0003]);
}

#[test]
fn sortperm_matches_the_in_memory_permutation() {
    let mut keys: Vec<f64> = generate(&mut Prng::new(31), Distribution::DupHeavy, N);
    keys[17] = f64::NAN;
    keys[18] = -0.0;
    keys[19] = 0.0;
    let perm = Session::threaded(2).sortperm(&keys, None).unwrap();
    let want: Vec<Record<f64, u64>> =
        perm.iter().map(|&i| Record::new(keys[i as usize], i as u64)).collect();
    for disk in [false, true] {
        let mut sink = VecSink::new();
        let stats =
            ctx(disk).stream_sortperm(&mut SliceSource::new(&keys), &mut sink, None).unwrap();
        assert!(stats.merge_passes >= 2);
        assert_records_eq(&sink.out, &want, &format!("sortperm disk={disk}"));
    }
}

#[test]
fn group_reduce_matches_a_hashmap_fold() {
    // i32 keys, i64 payloads; Add is wrapping, so fold order can't
    // change the answer and the HashMap reference is exact.
    let data: Vec<Record<i32, i64>> = indexed::<i32, u64>(41, N)
        .into_iter()
        .map(|r| Record::new(r.key, (r.val as i64).wrapping_mul(31)))
        .collect();
    let mut want_map: HashMap<i32, i64> = HashMap::new();
    for r in &data {
        let e = want_map.entry(r.key).or_insert(0);
        *e = e.wrapping_add(r.val);
    }
    for (disk, kind) in [(false, ReduceKind::Add), (true, ReduceKind::Add), (true, ReduceKind::Max)]
    {
        let mut sink = VecSink::new();
        let stats = ctx(disk)
            .stream_group_reduce(&mut SliceSource::new(&data), kind, &mut sink, None)
            .unwrap();
        assert_eq!(stats.groups as usize, want_map.len(), "disk={disk}");
        assert_eq!(sink.out.len(), want_map.len());
        for w in sink.out.windows(2) {
            assert!(w[0].key < w[1].key, "groups must be ascending and unique");
        }
        match kind {
            ReduceKind::Add => {
                for r in &sink.out {
                    assert_eq!(r.val, want_map[&r.key], "group {}", r.key);
                }
            }
            _ => {
                for r in &sink.out {
                    let m = data
                        .iter()
                        .filter(|d| d.key == r.key)
                        .map(|d| d.val)
                        .max()
                        .unwrap();
                    assert_eq!(r.val, m, "max of group {}", r.key);
                }
            }
        }
    }
}

#[test]
fn group_identity_is_the_total_order_bit_image() {
    // -0.0 and 0.0 are distinct groups; each NaN payload pattern too.
    let data = vec![
        Record::new(-0.0f64, 1i64),
        Record::new(0.0, 2),
        Record::new(-0.0, 4),
        Record::new(f64::NAN, 8),
        Record::new(f64::NAN, 16),
        Record::new(1.5, 32),
    ];
    let mut sink = VecSink::new();
    let stats = ctx(false)
        .stream_group_reduce(&mut SliceSource::new(&data), ReduceKind::Add, &mut sink, None)
        .unwrap();
    // Groups: -0.0 {1,4}, 0.0 {2}, 1.5 {32}, NaN {8,16} (one NaN bit
    // pattern) — ascending in the total order.
    assert_eq!(stats.groups, 4);
    let vals: Vec<i64> = sink.out.iter().map(|r| r.val).collect();
    assert_eq!(vals, vec![5, 2, 32, 24]);
    assert!(sink.out[0].key.is_sign_negative() && sink.out[0].key == 0.0);
}

#[test]
fn merge_join_matches_a_nested_loop() {
    let n = 600;
    let mut left: Vec<Record<i32, u64>> = indexed(51, n);
    let mut right: Vec<Record<i32, u32>> = indexed::<i32, u64>(52, n)
        .into_iter()
        .map(|r| Record::new(r.key, r.val as u32))
        .collect();
    left.sort_by_key(|r| (r.key, r.val));
    right.sort_by_key(|r| (r.key, r.val));
    // Emitted order: keys ascending, right-major within a key, left
    // group replayed in order per right record.
    let mut want: Vec<Record<i32, (u64, u32)>> = Vec::new();
    for r in &right {
        for l in &left {
            if l.key == r.key {
                want.push(Record::new(l.key, (l.val, r.val)));
            }
        }
    }
    want.sort_by(|a, b| (a.key, a.val.1).cmp(&(b.key, b.val.1)));
    for disk in [false, true] {
        let mut sink = VecSink::new();
        let stats = ctx(disk)
            .stream_merge_join(
                &mut SliceSource::new(&left),
                &mut SliceSource::new(&right),
                &mut sink,
            )
            .unwrap();
        assert_eq!(stats.emitted as usize, want.len());
        assert_eq!(stats.left_elems as usize, left.len());
        assert_eq!(stats.right_elems as usize, right.len());
        assert_records_eq(&sink.out, &want, &format!("merge-join disk={disk}"));
    }
}

#[test]
fn distinct_keeps_the_first_record_per_key() {
    let mut data: Vec<Record<f64, u64>> = indexed(61, N);
    data[100] = Record::new(f64::NAN, 7);
    data[200] = Record::new(f64::NAN, 9); // same bit pattern, later slot
    data[300] = Record::new(-0.0, 11);
    data[400] = Record::new(0.0, 13);
    // Reference: first payload per key image, ascending by image.
    let mut first: Vec<(u128, Record<f64, u64>)> = Vec::new();
    let mut seen: HashMap<u128, ()> = HashMap::new();
    for r in &data {
        if seen.insert(r.key_bits(), ()).is_none() {
            first.push((r.key_bits(), *r));
        }
    }
    first.sort_by_key(|&(bits, _)| bits);
    let want: Vec<Record<f64, u64>> = first.into_iter().map(|(_, r)| r).collect();
    for disk in [false, true] {
        let mut sink = VecSink::new();
        let stats =
            ctx(disk).stream_distinct(&mut SliceSource::new(&data), &mut sink, None).unwrap();
        assert_eq!(stats.groups as usize, want.len());
        assert_records_eq(&sink.out, &want, &format!("distinct disk={disk}"));
    }
    // The surviving NaN carries the FIRST payload (7, not 9), and -0.0
    // and 0.0 both survive as distinct keys.
    let nan = ctx(false);
    let mut sink = VecSink::new();
    nan.stream_distinct(&mut SliceSource::new(&data), &mut sink, None).unwrap();
    let nan_rec = sink.out.iter().find(|r| r.key.is_nan()).unwrap();
    assert_eq!(nan_rec.val, 7);
    assert!(sink.out.iter().any(|r| r.key == 0.0 && r.key.is_sign_negative()));
    assert!(sink.out.iter().any(|r| r.key == 0.0 && !r.key.is_sign_negative()));
}

// ---- checkpoint identity and crash/resume --------------------------------

#[test]
fn resume_rejects_a_mismatched_record_layout() {
    let parent = TempDirGuard::new(None).unwrap();
    let dir = parent.path().join("ckpt");
    let keys: Vec<i64> = generate(&mut Prng::new(71), Distribution::Uniform, N);
    let mut sink = VecSink::new();
    ctx(true)
        .external_sort_ckpt(
            &mut SliceSource::new(&keys),
            &mut sink,
            None,
            &Checkpoint::new(&dir, "layout-check"),
        )
        .unwrap();
    // The manifest records the scalar layout "i64"; resuming the same
    // job with an (i64, u64) record layout must be a typed identity
    // error, not silent garbage.
    let recs: Vec<Record<i64, u64>> = indexed(71, N);
    let mut rsink = VecSink::new();
    let err = ctx(true)
        .external_sort_ckpt(
            &mut SliceSource::new(&recs),
            &mut rsink,
            None,
            &Checkpoint::new(&dir, "layout-check").resume(),
        )
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("record layout"), "unexpected error: {msg}");
    assert!(msg.contains("i64+p8"), "the resume layout must be named: {msg}");

    // And the mirror image: a record manifest rejects a scalar resume.
    let dir2 = parent.path().join("ckpt2");
    let mut sink = VecSink::new();
    ctx(true)
        .external_sort_ckpt(
            &mut SliceSource::new(&recs),
            &mut sink,
            None,
            &Checkpoint::new(&dir2, "layout-check"),
        )
        .unwrap();
    let mut ssink = VecSink::new();
    let err = ctx(true)
        .external_sort_ckpt(
            &mut SliceSource::new(&keys),
            &mut ssink,
            None,
            &Checkpoint::new(&dir2, "layout-check").resume(),
        )
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("i64+p8"), "the manifest layout must be named: {msg}");
}

/// Sink that fails after absorbing `fail_after` chunks — simulates a
/// consumer dying mid-final-merge without arming any fail point.
struct FailingSink<R> {
    out: Vec<R>,
    fail_after: usize,
    pushes: usize,
}

impl<R: StreamRecord> ChunkSink<R> for FailingSink<R> {
    fn push_chunk(&mut self, chunk: &[R]) -> anyhow::Result<()> {
        if self.pushes >= self.fail_after {
            anyhow::bail!("injected sink failure after {} chunks", self.pushes);
        }
        self.pushes += 1;
        self.out.extend_from_slice(chunk);
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
}

#[test]
fn interrupted_record_sort_resumes_bitwise_through_the_manifest() {
    let parent = TempDirGuard::new(None).unwrap();
    let dir = parent.path().join("ckpt");
    let data: Vec<Record<i32, u64>> = indexed(81, N);
    let want = sorted_ref(&data);
    // First incarnation dies while the final merge is draining into the
    // sink (well after run generation, so the manifest holds runs).
    let mut dying = FailingSink { out: Vec::new(), fail_after: 2, pushes: 0 };
    let err = ctx(true)
        .external_sort_ckpt(
            &mut SliceSource::new(&data),
            &mut dying,
            None,
            &Checkpoint::new(&dir, "record-resume"),
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("injected sink failure"), "{err:#}");
    // Resume with a fresh sink: the merge redoes from manifested record
    // runs — no source re-read of already-spilled elements — and the
    // output is bitwise the stable in-memory sort.
    let mut sink = VecSink::new();
    let stats = ctx(true)
        .external_sort_ckpt(
            &mut SliceSource::new(&data),
            &mut sink,
            None,
            &Checkpoint::new(&dir, "record-resume").resume(),
        )
        .unwrap();
    assert!(stats.resumed_runs > 0, "resume must reopen manifested runs");
    assert_eq!(stats.elems, N as u64);
    assert_records_eq(&sink.out, &want, "resumed record sort");
}
