//! Cluster × out-of-core equivalence suite (DESIGN.md §14).
//!
//! The contract under test: a multi-rank SIHSort whose ranks use the
//! streamed external local sorter (`LocalSorter::External`) produces,
//! concatenated in rank order, *bitwise* what one single-node
//! `Session::sort` produces on the same dataset — across rank counts,
//! budget regimes that force the in-core / 1-pass / multi-pass
//! rank-local pipelines, both spill media, four dtypes, adversarial
//! value patterns (NaN payloads, −0.0, duplicate-heavy, skewed
//! distributions with non-uniform splitters) — and that every spill
//! byte is cleaned up, on success and mid-pipeline panic alike.

use accelkern::backend::DeviceKey;
use accelkern::cfg::{RunConfig, Sorter, TransferMode};
use accelkern::cluster::ClusterSpec;
use accelkern::comm::Fabric;
use accelkern::coordinator::driver::run_distributed_sort_data;
use accelkern::dtype::{bits_eq, is_sorted_total, SortKey};
use accelkern::mpisort::{sihsort_rank, LocalSorter, RankStreamStats, SihConfig, SihStreamCfg};
use accelkern::session::Session;
use accelkern::stream::{
    ChunkSource, RunSink, SpillMedium, StreamBudget, TempDirGuard,
};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution, KeyGen};

/// Elements per rank throughout the suite (big enough that the tiny
/// budgets below force real multi-run pipelines, small enough to keep
/// the cross-product fast).
const N_PER_RANK: usize = 16_384;

/// Budget regime for the rank-local external sort, with the pipeline
/// shape it must force at [`N_PER_RANK`] (derivations: run chunk =
/// max(budget_elems/3, 1024), fan-in = clamp(budget_elems/1024, 2, 128)
/// — DESIGN.md §13).
#[derive(Clone, Copy, Debug)]
enum Regime {
    /// Budget ≥ 3n: one run, no merge pass, no intermediate spill.
    InCore,
    /// 12288 budget elems → 4 runs at fan-in 12: exactly one pass.
    OnePass,
    /// 2048 budget elems → 16 runs at fan-in 2: 3 intermediate passes
    /// + final.
    MultiPass,
}

impl Regime {
    fn budget_elems(self) -> usize {
        match self {
            Regime::InCore => 3 * N_PER_RANK + 64,
            Regime::OnePass => 12_288,
            Regime::MultiPass => 2_048,
        }
    }

    fn check(self, rank: usize, st: &RankStreamStats) {
        match self {
            Regime::InCore => {
                assert_eq!(st.local.runs, 1, "rank {rank}: in-core budget must give one run");
                assert_eq!(st.local.merge_passes, 0, "rank {rank}");
                assert_eq!(st.local.spilled_bytes, 0, "rank {rank}: no intermediate spill");
            }
            Regime::OnePass => {
                assert_eq!(st.local.runs, 4, "rank {rank}");
                assert_eq!(st.local.merge_passes, 1, "rank {rank}");
            }
            Regime::MultiPass => {
                assert_eq!(st.local.runs, 16, "rank {rank}");
                assert!(
                    st.local.merge_passes >= 2,
                    "rank {rank}: fan-in 2 over 16 runs needs multiple passes, got {}",
                    st.local.merge_passes
                );
            }
        }
    }
}

fn cluster_cfg<K: SortKey>(
    ranks: usize,
    dist: Distribution,
    regime: Regime,
    mem_spill: bool,
) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.ranks = ranks;
    cfg.elems_per_rank = N_PER_RANK;
    cfg.dtype = K::ELEM;
    cfg.dist = dist;
    cfg.sorter = Sorter::External;
    cfg.host_threads = 2;
    cfg.stream.spill_memory = mem_spill;
    cfg.stream.budget_bytes = Some(regime.budget_elems() * K::KEY_BYTES);
    cfg
}

/// Single-node reference: the driver's deterministic per-rank shards,
/// concatenated and sorted by one in-memory session.
fn reference<K: KeyGen + DeviceKey>(cfg: &RunConfig) -> Vec<K> {
    let mut root = Prng::new(cfg.seed);
    let mut all: Vec<K> = Vec::with_capacity(cfg.ranks * cfg.elems_per_rank);
    for r in 0..cfg.ranks {
        let mut rng = root.fork(r as u64);
        all.extend(generate::<K>(&mut rng, cfg.dist, cfg.elems_per_rank));
    }
    Session::threaded(2).sort(&mut all, None).unwrap();
    all
}

/// Run the driver, assert bitwise equivalence + per-rank budget
/// accounting for the regime.
fn check_cluster<K: KeyGen + DeviceKey>(
    ranks: usize,
    dist: Distribution,
    regime: Regime,
    mem_spill: bool,
) {
    let cfg = cluster_cfg::<K>(ranks, dist, regime, mem_spill);
    let (_, outcomes) = run_distributed_sort_data::<K>(&cfg, None)
        .unwrap_or_else(|e| panic!("{:?} ranks={ranks} {dist:?} {regime:?}: {e:#}", K::ELEM));
    let got: Vec<K> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    let want = reference::<K>(&cfg);
    assert!(
        bits_eq(&got, &want),
        "{:?} ranks={ranks} {dist:?} {regime:?} mem={mem_spill}: output diverges from \
         the single-node sort",
        K::ELEM
    );
    let budget_elems = regime.budget_elems();
    for (r, o) in outcomes.iter().enumerate() {
        let st = o.stream.as_ref().expect("external ranks report stream stats");
        assert_eq!(st.budget_bytes, budget_elems * K::KEY_BYTES);
        // Budget accounting: the run-generation chunk never exceeds its
        // budget derivation (a third of the budget, floored at 1024).
        assert!(
            st.local.run_chunk_elems <= (budget_elems / 3).max(1024),
            "rank {r}: run chunk {} breaks the budget derivation",
            st.local.run_chunk_elems
        );
        regime.check(r, st);
        if !mem_spill && !matches!(regime, Regime::InCore) {
            assert!(st.local.spilled_bytes > 0, "rank {r}: disk medium must spill runs");
        }
        if !mem_spill {
            assert!(st.local_run_bytes > 0, "rank {r}: the parked shard spills on disk");
        }
    }
}

// ---- the acceptance cross: ranks × regimes × media × dtypes ---------------

#[test]
fn equivalence_i32_across_ranks_budgets_media() {
    for ranks in [2usize, 4, 8] {
        for regime in [Regime::OnePass, Regime::MultiPass] {
            for mem in [true, false] {
                check_cluster::<i32>(ranks, Distribution::Uniform, regime, mem);
            }
        }
    }
}

#[test]
fn equivalence_i64_across_ranks_budgets_media() {
    for ranks in [2usize, 4, 8] {
        for regime in [Regime::OnePass, Regime::MultiPass] {
            for mem in [true, false] {
                check_cluster::<i64>(ranks, Distribution::Uniform, regime, mem);
            }
        }
    }
}

#[test]
fn equivalence_f32_across_ranks_budgets_media() {
    for ranks in [2usize, 4, 8] {
        for regime in [Regime::OnePass, Regime::MultiPass] {
            for mem in [true, false] {
                check_cluster::<f32>(ranks, Distribution::Uniform, regime, mem);
            }
        }
    }
}

#[test]
fn equivalence_f64_across_ranks_budgets_media() {
    for ranks in [2usize, 4, 8] {
        for regime in [Regime::OnePass, Regime::MultiPass] {
            for mem in [true, false] {
                check_cluster::<f64>(ranks, Distribution::Uniform, regime, mem);
            }
        }
    }
}

#[test]
fn in_core_budgets_still_verify() {
    // Budgets generous enough that every rank's shard sorts in one
    // chunk: the streamed pipeline's fast path, still collective.
    for ranks in [2usize, 4, 8] {
        for mem in [true, false] {
            check_cluster::<i32>(ranks, Distribution::Uniform, Regime::InCore, mem);
            check_cluster::<f64>(ranks, Distribution::Uniform, Regime::InCore, mem);
        }
    }
}

#[test]
fn skewed_and_duplicate_distributions() {
    // Non-uniform splitter refinement: heavy duplication (splitters land
    // on value plateaus), Zipf skew and pre-sorted input (maximally
    // unequal sample spacing) must all stay bitwise-equivalent.
    for dist in [Distribution::DupHeavy, Distribution::Zipf, Distribution::Sorted] {
        check_cluster::<i32>(4, dist, Regime::MultiPass, true);
        check_cluster::<i32>(4, dist, Regime::OnePass, false);
        check_cluster::<f64>(4, dist, Regime::MultiPass, false);
    }
}

#[test]
fn tiny_shards_with_empty_buckets() {
    // Fewer elements than samples per rank: some buckets are empty and
    // several candidate splitters coincide; the streamed exchange must
    // still route every element.
    let mut cfg = cluster_cfg::<i64>(4, Distribution::Uniform, Regime::InCore, true);
    cfg.elems_per_rank = 7;
    cfg.stream.budget_bytes = Some(1 << 16);
    let (_, outcomes) = run_distributed_sort_data::<i64>(&cfg, None).unwrap();
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    let want = reference::<i64>(&cfg);
    assert!(bits_eq(&got, &want));
}

// ---- adversarial values through a hand-built collective -------------------

/// Mini-driver: run one collective over hand-built shards (the public
/// driver generates its own workloads, so NaN/−0.0 injection goes
/// through `sihsort_rank` + `LocalSorter::External` directly, exactly
/// as the driver invokes them).
fn run_mini_cluster<K: DeviceKey>(
    shards: Vec<Vec<K>>,
    budget_bytes: usize,
    medium: SpillMedium,
) -> Vec<K> {
    let p = shards.len();
    let scfg = SihStreamCfg {
        budget: StreamBudget::bytes(budget_bytes),
        medium,
        spill_dir: None,
        ckpt_dir: None,
        resume: false,
    };
    let ctx = scfg.ctx(Session::threaded(2));
    let mut cfg = SihConfig::default();
    cfg.stream = Some(scfg);
    let eps = Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![false; p]);
    let mut out: Vec<Vec<K>> = (0..p).map(|_| Vec::new()).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = eps
            .into_iter()
            .zip(shards)
            .map(|(mut ep, shard)| {
                let ctx = ctx.clone();
                let cfg = cfg.clone();
                s.spawn(move || {
                    let sorter = LocalSorter::External(ctx);
                    let o = sihsort_rank(&mut ep, shard, &sorter, &cfg).unwrap();
                    assert!(o.stream.is_some());
                    (ep.rank(), o.data)
                })
            })
            .collect();
        for h in handles {
            let (rank, data) = h.join().unwrap();
            out[rank] = data;
        }
    });
    out.into_iter().flatten().collect()
}

#[test]
fn nan_neg_zero_and_duplicates_survive_bitwise() {
    let mut rng = Prng::new(77);
    let shards: Vec<Vec<f64>> = (0..4)
        .map(|_r| {
            let mut v: Vec<f64> = Vec::with_capacity(3000);
            for i in 0..3000usize {
                v.push(match i % 7 {
                    0 => f64::NAN,
                    1 => -f64::NAN,
                    2 => -0.0,
                    3 => 0.0,
                    4 => (i % 11) as f64 - 5.0, // heavy duplicates
                    5 => f64::INFINITY,
                    _ => <f64 as KeyGen>::uniform(&mut rng),
                });
            }
            v
        })
        .collect();
    let mut want: Vec<f64> = shards.iter().flatten().copied().collect();
    Session::threaded(2).sort(&mut want, None).unwrap();
    for medium in [SpillMedium::Memory, SpillMedium::Disk] {
        // 2048-elem budget: every rank streams (3000 > 682-elem chunks
        // would be below the floor — the 1024 floor gives 3 runs).
        let got = run_mini_cluster(shards.clone(), 2048 * 8, medium);
        assert!(is_sorted_total(&got));
        assert!(
            bits_eq(&got, &want),
            "{medium:?}: NaN payloads / −0.0 must survive the streamed collective bit-exactly"
        );
    }
}

// ---- spill hygiene --------------------------------------------------------

#[test]
fn driver_run_leaves_no_spill_behind() {
    // Point every guarded spill dir of a full driver run (local sorts +
    // exchange stores on all ranks) at one parent and assert the parent
    // is empty afterwards.
    let parent = TempDirGuard::new(None).unwrap();
    let mut cfg = cluster_cfg::<i32>(4, Distribution::Uniform, Regime::MultiPass, false);
    cfg.stream.spill_dir = Some(parent.path().to_string_lossy().into_owned());
    let (_, outcomes) = run_distributed_sort_data::<i32>(&cfg, None).unwrap();
    assert!(outcomes.iter().all(|o| o.stream.as_ref().unwrap().local_run_bytes > 0));
    let leftovers: Vec<_> = std::fs::read_dir(parent.path()).unwrap().collect();
    assert!(leftovers.is_empty(), "spill leaked: {leftovers:?}");
}

// ---- crash/resume equivalence (DESIGN.md §15) -----------------------------

use accelkern::util::failpoint::{self, FailMode};

/// Checkpointed cluster config rooted at `dir`.
fn ckpt_cfg(ranks: usize, regime: Regime, dir: &std::path::Path) -> RunConfig {
    let mut cfg = cluster_cfg::<i64>(ranks, Distribution::Uniform, regime, false);
    cfg.stream.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    cfg
}

/// Run the collective expecting the armed fail point to kill it — an
/// injected error and a simulated-process-death panic both count as
/// "the crash", but a genuine (non-injected) error does not.
fn crash_run(cfg: &RunConfig, site: &str) {
    let crashed = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_distributed_sort_data::<i64>(cfg, None)
    })) {
        Ok(Ok(_)) => false,
        Ok(Err(e)) => {
            assert!(
                failpoint::is_abort(&e),
                "{site}: genuine failure instead of the injected abort: {e:#}"
            );
            true
        }
        Err(_) => true,
    };
    assert!(crashed, "{site}: the armed fail point must kill the run");
}

#[test]
fn seeded_random_kill_site_resumes_bitwise() {
    // Resume-equivalence proptest: the kill site and abort mode are
    // drawn from a seeded Prng; wherever the collective dies, the
    // resumed run must produce bitwise the uninterrupted output. The
    // guard's fault lock is held across the whole test (disarm, not
    // drop, before each resume) so no concurrent fault test can arm a
    // site our resumed runs traverse. Sites shared with the
    // non-checkpointed paths (sih.exchange.sent, driver.verify,
    // ext.merge.mid) live in tests/crash_resume.rs, where every test
    // arms — arming them here would trip the plain-path tests running
    // concurrently in this binary.
    const SITES: &[&str] = &[
        "sih.park",
        "sih.parked",
        "sih.splitters",
        "sih.splitters.recorded",
        "sih.exchange",
        "sih.exchange.recorded",
        "sih.final",
        "sih.final.mid",
        "sih.done",
    ];
    let parent = TempDirGuard::new(None).unwrap();
    let mut rng = Prng::new(0xFA117);
    let guard = failpoint::arm("fp.cluster.hold", 0, FailMode::Error);
    for trial in 0..4u64 {
        let site = SITES[(rng.next_u64() % SITES.len() as u64) as usize];
        let mode =
            if rng.next_u64() % 2 == 0 { FailMode::Error } else { FailMode::Panic };
        let dir = parent.path().join(format!("trial-{trial}"));
        let mut cfg = ckpt_cfg(4, Regime::OnePass, &dir);
        guard.rearm(site, 0, mode);
        crash_run(&cfg, site);
        guard.disarm();
        cfg.stream.resume = true;
        let (_, outcomes) = run_distributed_sort_data::<i64>(&cfg, None)
            .unwrap_or_else(|e| panic!("resume after {site} ({mode:?}) kill: {e:#}"));
        let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
        assert!(
            bits_eq(&got, &reference::<i64>(&cfg)),
            "{site} ({mode:?}): resumed output diverges from the single-node sort"
        );
    }
}

#[test]
fn double_resume_recovers() {
    // Crash the collective, crash the *resume* at a later phase, then
    // resume again: recovery must compose.
    let parent = TempDirGuard::new(None).unwrap();
    let dir = parent.path().join("double");
    let mut cfg = ckpt_cfg(2, Regime::OnePass, &dir);
    let guard = failpoint::arm("sih.splitters.recorded", 0, FailMode::Error);
    crash_run(&cfg, "sih.splitters.recorded");
    cfg.stream.resume = true;
    guard.rearm("sih.final", 0, FailMode::Panic);
    crash_run(&cfg, "sih.final");
    guard.disarm();
    let (_, outcomes) = run_distributed_sort_data::<i64>(&cfg, None)
        .unwrap_or_else(|e| panic!("second resume: {e:#}"));
    let got: Vec<i64> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    assert!(
        bits_eq(&got, &reference::<i64>(&cfg)),
        "double resume diverges from the single-node sort"
    );
}

#[test]
fn spill_cleanup_on_panic_mid_pipeline() {
    // A source that dies mid-stream unwinds through the rank-local
    // external sort after runs have spilled; every guarded dir (the
    // pipeline's intermediate store and the rank's park/exchange store,
    // built from the same SihStreamCfg the driver threads through) must
    // vanish during the unwind.
    struct DyingSource {
        rng: Prng,
        chunks_left: usize,
    }
    impl ChunkSource<i64> for DyingSource {
        fn len_hint(&self) -> Option<u64> {
            None
        }
        fn next_chunk(&mut self, buf: &mut Vec<i64>, max: usize) -> anyhow::Result<usize> {
            assert!(self.chunks_left > 0, "mid-pipeline source failure");
            self.chunks_left -= 1;
            buf.clear();
            for _ in 0..max {
                buf.push(self.rng.next_u64() as i64);
            }
            Ok(buf.len())
        }
    }

    let parent = TempDirGuard::new(None).unwrap();
    let scfg = SihStreamCfg {
        budget: StreamBudget::bytes(2048 * 8),
        medium: SpillMedium::Disk,
        spill_dir: Some(parent.path().to_path_buf()),
        ckpt_dir: None,
        resume: false,
    };
    let ctx = scfg.ctx(Session::native());
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut store = scfg.store();
        let mut sink = RunSink::<i64>::new(&mut store).unwrap();
        // 4 chunks spill into runs, then the source panics.
        let mut src = DyingSource { rng: Prng::new(5), chunks_left: 4 };
        let _ = ctx.external_sort(&mut src, &mut sink, None);
    }));
    assert!(result.is_err(), "the dying source must abort the pipeline");
    let leftovers: Vec<_> = std::fs::read_dir(parent.path()).unwrap().collect();
    assert!(leftovers.is_empty(), "panic unwind leaked spill state: {leftovers:?}");
}
