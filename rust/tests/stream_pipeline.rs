//! Streaming-vs-in-memory equivalence suite (DESIGN.md §13).
//!
//! The contract under test: every streaming pipeline produces what its
//! in-memory `Session` counterpart produces on the concatenated input —
//! bitwise for order-canonical results (external sort, top-k) and for
//! the associative integer folds (reduce, scan), within rounding slack
//! for float folds (chunking regroups the additions, exactly as the
//! threaded in-memory engines regroup them per worker). Budgets are
//! driven through configurations that force the in-core fast path and
//! 1, 2 and 3+ merge passes, on both spill media, across all six paper
//! dtypes plus the NaN/−0.0/duplicate/empty adversarial inputs.

use accelkern::algorithms::ReduceKind;
use accelkern::backend::DeviceKey;
use accelkern::dtype::{bits_eq, is_sorted_total, SortKey};
use accelkern::prop::{check, PropConfig, VecGen};
use accelkern::session::{Launch, Session};
use accelkern::stream::{
    FileSink, FileSource, GenSource, SliceSource, StreamBudget, StreamCtx, TempDirGuard, VecSink,
};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution, KeyGen};

/// In-memory reference: session sort of the whole input.
fn sorted_ref<K: KeyGen + DeviceKey>(data: &[K]) -> Vec<K> {
    let mut want = data.to_vec();
    Session::threaded(3).sort(&mut want, None).unwrap();
    want
}

fn stream_sort<K: KeyGen + DeviceKey>(ctx: &StreamCtx, data: &[K]) -> (Vec<K>, usize) {
    let mut sink = VecSink::new();
    let stats = ctx.external_sort(&mut SliceSource::new(data), &mut sink, None).unwrap();
    (sink.out, stats.merge_passes)
}

/// The merge-pass-forcing budget grid: (run_chunk, fan_in, expected
/// merge passes) for a 40k-element input.
/// * 1 pass: 8 runs at fan-in 16 — one k-way merge.
/// * 2 passes: 8 runs at fan-in 4 — one intermediate sweep + final.
/// * 3+ passes: 40 runs at fan-in 2 — 40→20→10→5→3→2 intermediate
///   sweeps, then the final merge (6 passes total).
const PASS_GRID: [(usize, usize, usize); 3] = [(5000, 16, 1), (5000, 4, 2), (1000, 2, 6)];

fn equivalence_over_budgets<K: KeyGen + DeviceKey>(seed: u64) {
    let n = 40_000;
    let data: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
    let want = sorted_ref(&data);
    for (run_chunk, fan_in, want_passes) in PASS_GRID {
        for mem_spill in [true, false] {
            let mut ctx = Session::threaded(2)
                .stream(StreamBudget::bytes(64))
                .run_chunk_elems(run_chunk)
                .fan_in(fan_in)
                .io_chunk_elems(173);
            if mem_spill {
                ctx = ctx.in_memory_spill();
            }
            let (got, passes) = stream_sort(&ctx, &data);
            assert!(
                bits_eq(&got, &want),
                "{} diverged (chunk={run_chunk} fan_in={fan_in} mem={mem_spill})",
                std::any::type_name::<K>(),
            );
            assert_eq!(
                passes, want_passes,
                "{} pass count (chunk={run_chunk} fan_in={fan_in})",
                std::any::type_name::<K>(),
            );
        }
    }
}

#[test]
fn external_sort_equivalence_all_dtypes_and_pass_counts() {
    equivalence_over_budgets::<i16>(10);
    equivalence_over_budgets::<i32>(11);
    equivalence_over_budgets::<i64>(12);
    equivalence_over_budgets::<i128>(13);
    equivalence_over_budgets::<f32>(14);
    equivalence_over_budgets::<f64>(15);
}

#[test]
fn external_sort_adversarial_inputs() {
    let ctx = Session::threaded(2)
        .stream(StreamBudget::bytes(64))
        .in_memory_spill()
        .run_chunk_elems(100)
        .fan_in(2);
    // NaN payloads, signed zeros, infinities, duplicates.
    let mut data = vec![
        f64::NAN,
        -f64::NAN,
        -0.0,
        0.0,
        f64::INFINITY,
        f64::NEG_INFINITY,
        1.0,
        1.0,
        -1.0,
    ];
    for i in 0..500 {
        data.push(if i % 3 == 0 { f64::NAN } else { (i % 7) as f64 - 3.0 });
    }
    let want = sorted_ref(&data);
    let (got, _) = stream_sort(&ctx, &data);
    assert!(bits_eq(&got, &want), "NaN/-0.0/dup stream sort must be bit-identical");
    assert!(is_sorted_total(&got));
    // Empty and single-element streams.
    let empty: Vec<f64> = vec![];
    let (got, passes) = stream_sort(&ctx, &empty);
    assert!(got.is_empty());
    assert_eq!(passes, 0);
    let (got, _) = stream_sort(&ctx, &[42.0f64]);
    assert_eq!(got, vec![42.0]);
    // Duplicate-heavy integers across a multi-pass merge.
    let dups: Vec<i32> = generate(&mut Prng::new(77), Distribution::DupHeavy, 30_000);
    let (got, passes) = stream_sort(&ctx, &dups);
    assert!(bits_eq(&got, &sorted_ref(&dups)));
    assert!(passes >= 3, "300 runs at fan-in 2 must multi-pass (got {passes})");
}

#[test]
fn external_sort_proptest_random_budgets() {
    // Property: for any input and any (run_chunk, fan_in) shape, the
    // streamed sort is bitwise the in-memory sort.
    let gen = VecGen::new(3000, |r| r.range_i64(-1 << 40, 1 << 40));
    check("stream-sort-equivalence", &PropConfig::default(), &gen, |xs| {
        let mut rng = Prng::new(xs.len() as u64 ^ 0xC0FFEE);
        let ctx = Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .in_memory_spill()
            .run_chunk_elems(1 + rng.below(700) as usize)
            .fan_in(2 + rng.below(5) as usize);
        let want = sorted_ref(xs);
        let (got, _) = stream_sort(&ctx, xs);
        if bits_eq(&got, &want) {
            Ok(())
        } else {
            Err(format!("diverged on {} elems", xs.len()))
        }
    });
}

#[test]
fn stream_folds_proptest_integer_bitwise() {
    // Integer reduce + scan are bitwise across every chunking (wrapping
    // add is associative); the chunk size is drawn per case.
    let gen = VecGen::new(2000, |r| r.range_i64(i64::MIN / 4, i64::MAX / 4));
    check("stream-fold-equivalence", &PropConfig::default(), &gen, |xs| {
        let mut rng = Prng::new(xs.len() as u64 ^ 0xF01D);
        let ctx = Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .run_chunk_elems(1 + rng.below(500) as usize);
        let s = Session::native();
        for kind in [ReduceKind::Add, ReduceKind::Min, ReduceKind::Max] {
            let got = ctx.stream_reduce(&mut SliceSource::new(xs), kind, None).unwrap();
            let want = s.reduce(xs, kind, None).unwrap();
            if got != want {
                return Err(format!("{kind:?}: {got} != {want}"));
            }
        }
        for inclusive in [true, false] {
            let mut sink = VecSink::new();
            ctx.stream_scan(&mut SliceSource::new(xs), &mut sink, inclusive, None).unwrap();
            let want = s.accumulate(xs, inclusive, None).unwrap();
            if sink.out != want {
                return Err(format!("scan inclusive={inclusive} diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn float_folds_track_reference_within_tolerance() {
    // Chunking regroups float additions — same contract as the threaded
    // in-memory engines, so the comparison is relative, not bitwise.
    let xs: Vec<f64> = generate(&mut Prng::new(5), Distribution::Gaussian, 6000)
        .into_iter()
        .map(|x: f64| x % 100.0)
        .collect();
    let ctx = Session::threaded(2).stream(StreamBudget::bytes(64)).run_chunk_elems(311);
    let got = ctx.stream_reduce(&mut SliceSource::new(&xs), ReduceKind::Add, None).unwrap();
    let want = Session::native().reduce(&xs, ReduceKind::Add, None).unwrap();
    assert!((got - want).abs() <= 1e-6 * want.abs().max(1.0), "{got} vs {want}");
    // Min/Max are exact selections — bitwise even for floats.
    for kind in [ReduceKind::Min, ReduceKind::Max] {
        let got = ctx.stream_reduce(&mut SliceSource::new(&xs), kind, None).unwrap();
        let want = Session::native().reduce(&xs, kind, None).unwrap();
        assert_eq!(got.to_bits(), want.to_bits(), "{kind:?}");
    }
}

#[test]
fn launch_knobs_reach_the_per_chunk_engines() {
    // A per-call Launch flows into run generation: results stay
    // identical under any knob combination.
    let data: Vec<i64> = generate(&mut Prng::new(6), Distribution::Uniform, 25_000);
    let want = sorted_ref(&data);
    let ctx = Session::threaded(4)
        .stream(StreamBudget::bytes(64))
        .in_memory_spill()
        .run_chunk_elems(4000);
    for l in [
        Launch::new().max_tasks(1),
        Launch::new().min_elems_per_task(100_000),
        Launch::new().prefer_parallel_threshold(usize::MAX),
        Launch::new().reuse_scratch(true),
    ] {
        let mut sink = VecSink::new();
        ctx.external_sort(&mut SliceSource::new(&data), &mut sink, Some(&l)).unwrap();
        assert!(bits_eq(&sink.out, &want), "{l:?}");
    }
}

#[test]
fn file_to_file_pipeline_roundtrips() {
    // Dataset on disk -> external sort -> output file -> read back:
    // the full out-of-core deployment shape.
    use accelkern::stream::{ChunkSink, ChunkSource};
    let dir = TempDirGuard::new(None).unwrap();
    let input = dir.path().join("input.bin");
    let output = dir.path().join("sorted.bin");
    let data: Vec<i32> = generate(&mut Prng::new(7), Distribution::Zipf, 20_000);
    {
        // Materialise the dataset file through the sink contract.
        let mut sink = FileSink::create(&input).unwrap();
        let mut src = SliceSource::new(&data);
        let mut buf = Vec::new();
        while src.next_chunk(&mut buf, 4096).unwrap() > 0 {
            sink.push_chunk(&buf).unwrap();
        }
        sink.finish().unwrap();
    }
    let ctx = Session::threaded(2)
        .stream(StreamBudget::bytes(64))
        .spill_parent(dir.path().to_path_buf())
        .run_chunk_elems(3000)
        .fan_in(2);
    let mut src = FileSource::<i32>::open(&input).unwrap();
    let mut sink = FileSink::create(&output).unwrap();
    let stats = ctx.external_sort(&mut src, &mut sink, None).unwrap();
    assert_eq!(stats.elems, data.len() as u64);
    assert!(stats.merge_passes >= 2);
    let mut back = FileSource::<i32>::open(&output).unwrap();
    let mut out = Vec::new();
    let mut buf = Vec::new();
    while back.next_chunk(&mut buf, 1024).unwrap() > 0 {
        out.extend_from_slice(&buf);
    }
    assert!(bits_eq(&out, &sorted_ref(&data)));
}

#[test]
fn gensource_pipeline_verifies_like_the_bench() {
    // The bench-stream acceptance shape in miniature: a generated
    // dataset 8x the budget, streamed sort, bitwise equal to the
    // in-memory sort of the materialised stream.
    let n: usize = 64_000;
    let budget = StreamBudget::bytes(n * std::mem::size_of::<i64>() / 8);
    let ctx = Session::threaded(2).stream(budget);
    let mut src = GenSource::<i64>::new(99, Distribution::Uniform, n as u64);
    let mut sink = VecSink::new();
    let stats = ctx.external_sort(&mut src, &mut sink, None).unwrap();
    assert_eq!(stats.elems, n as u64);
    assert!(stats.runs > 1, "8x dataset must spill ({} runs)", stats.runs);
    assert!(stats.merge_passes >= 1);
    let replay = GenSource::<i64>::new(99, Distribution::Uniform, n as u64).materialize();
    assert!(bits_eq(&sink.out, &sorted_ref(&replay)));
}

#[test]
fn spill_dir_cleaned_on_sink_panic() {
    // A sink that panics mid-stream must not leak the guarded spill
    // directory (the TempDirGuard drops during unwinding).
    use accelkern::stream::ChunkSink;
    struct PanicSink;
    impl ChunkSink<i64> for PanicSink {
        fn push_chunk(&mut self, _chunk: &[i64]) -> anyhow::Result<()> {
            panic!("sink failure mid-stream");
        }
        fn finish(&mut self) -> anyhow::Result<()> {
            Ok(())
        }
    }
    let parent = TempDirGuard::new(None).unwrap();
    let parent_path = parent.path().to_path_buf();
    let data: Vec<i64> = generate(&mut Prng::new(8), Distribution::Uniform, 10_000);
    let result = std::panic::catch_unwind(move || {
        let ctx = Session::native()
            .stream(StreamBudget::bytes(64))
            .spill_parent(parent_path)
            .run_chunk_elems(1000)
            .fan_in(2);
        let mut sink = PanicSink;
        let _ = ctx.external_sort(&mut SliceSource::new(&data), &mut sink, None);
    });
    assert!(result.is_err(), "the sink panic must propagate");
    let leftovers: Vec<_> = std::fs::read_dir(parent.path())
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    assert!(
        leftovers.is_empty(),
        "spill dirs leaked after a mid-stream panic: {leftovers:?}"
    );
}

// ---- crash/resume equivalence (DESIGN.md §15) -----------------------------

use accelkern::stream::Checkpoint;
use accelkern::util::failpoint::{self, FailMode};

/// The resumable-sort fixture: 8 generation runs at fan-in 2 (two
/// intermediate merge passes plus the final), so every kill site below
/// is reachable.
fn ckpt_ctx() -> StreamCtx {
    Session::threaded(2)
        .stream(StreamBudget::bytes(64))
        .run_chunk_elems(5000)
        .fan_in(2)
        .io_chunk_elems(509)
}

#[test]
fn checkpointed_sort_random_kill_sites_resume_bitwise() {
    // Resume-equivalence proptest: kill site, skip depth and abort mode
    // are drawn from a seeded Prng; wherever the pipeline dies, a
    // resumed run over the identical source must produce bitwise the
    // uninterrupted output. The guard's fault lock is held across the
    // whole test (disarm, not drop, before each resume) so no
    // concurrent fault test can arm a site our resumed runs traverse.
    // `ext.merge.mid` is shared with the plain merge path the other
    // tests in this binary run concurrently, so it lives in
    // tests/crash_resume.rs, where every test arms.
    //
    // Each site is paired with the largest skip the fixture's pipeline
    // shape reaches (gen-done and the final merge run once per job).
    const SITES: &[(&str, u64)] = &[
        ("manifest.rename", 3),
        ("ext.run", 3),
        ("ext.run.recorded", 3),
        ("ext.gen-done", 0),
        ("ext.merge.group", 3),
        ("ext.merge.retired", 3),
        ("ext.merge.pass", 1),
        ("ext.final", 0),
        ("ext.final.mid", 3),
    ];
    let parent = TempDirGuard::new(None).unwrap();
    let data: Vec<i64> = generate(&mut Prng::new(21), Distribution::Uniform, 40_000);
    let want = sorted_ref(&data);
    let ctx = ckpt_ctx();
    let mut rng = Prng::new(0xFA115EED);
    let guard = failpoint::arm("fp.stream.hold", 0, FailMode::Error);
    for trial in 0..6u64 {
        let (site, max_skip) = SITES[(rng.next_u64() % SITES.len() as u64) as usize];
        let skip = if max_skip == 0 { 0 } else { rng.next_u64() % (max_skip + 1) };
        let mode =
            if rng.next_u64() % 2 == 0 { FailMode::Error } else { FailMode::Panic };
        let dir = parent.path().join(format!("trial-{trial}"));
        guard.rearm(site, skip, mode);
        let crashed = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut sink = VecSink::new();
            ctx.external_sort_ckpt(
                &mut SliceSource::new(&data),
                &mut sink,
                None,
                &Checkpoint::new(&dir, "proptest"),
            )
        })) {
            Ok(Ok(_)) => false,
            Ok(Err(e)) => {
                let e: anyhow::Error = e.into();
                assert!(
                    failpoint::is_abort(&e),
                    "{site}:{skip}: genuine failure instead of the injected abort: {e:#}"
                );
                true
            }
            Err(_) => true,
        };
        guard.disarm();
        assert!(crashed, "{site}:{skip}: the armed fail point must kill the run");
        let mut sink = VecSink::new();
        let stats = ctx
            .external_sort_ckpt(
                &mut SliceSource::new(&data),
                &mut sink,
                None,
                &Checkpoint::new(&dir, "proptest").resume(),
            )
            .unwrap_or_else(|e| panic!("resume after {site}:{skip} ({mode:?}): {e:#}"));
        assert!(!stats.completed_noop, "{site}:{skip}: the killed job cannot be complete");
        assert_eq!(stats.elems, data.len() as u64, "{site}:{skip}");
        assert!(
            bits_eq(&sink.out, &want),
            "{site}:{skip} ({mode:?}): resumed output diverges from the in-memory sort"
        );
    }
}

#[test]
fn checkpointed_sort_double_resume_then_noop() {
    // Kill run generation, kill the first resume mid-merge, finish on
    // the second resume — then resuming the *completed* job must be a
    // no-op that touches neither source nor sink.
    let parent = TempDirGuard::new(None).unwrap();
    let dir = parent.path().join("double");
    let data: Vec<i64> = generate(&mut Prng::new(22), Distribution::Uniform, 40_000);
    let want = sorted_ref(&data);
    let ctx = ckpt_ctx();

    let guard = failpoint::arm("ext.run", 3, FailMode::Error);
    let e: anyhow::Error = ctx
        .external_sort_ckpt(
            &mut SliceSource::new(&data),
            &mut VecSink::new(),
            None,
            &Checkpoint::new(&dir, "double"),
        )
        .unwrap_err()
        .into();
    assert!(failpoint::is_abort(&e), "{e:#}");

    guard.rearm("ext.merge.retired", 1, FailMode::Error);
    let e: anyhow::Error = ctx
        .external_sort_ckpt(
            &mut SliceSource::new(&data),
            &mut VecSink::new(),
            None,
            &Checkpoint::new(&dir, "double").resume(),
        )
        .unwrap_err()
        .into();
    assert!(failpoint::is_abort(&e), "{e:#}");
    guard.disarm();

    let mut sink = VecSink::new();
    let stats = ctx
        .external_sort_ckpt(
            &mut SliceSource::new(&data),
            &mut sink,
            None,
            &Checkpoint::new(&dir, "double").resume(),
        )
        .unwrap();
    assert!(stats.resumed_runs > 0, "the second resume must reuse durable runs");
    assert!(bits_eq(&sink.out, &want), "double resume diverges from the in-memory sort");

    // Completed-job resume: the empty source proves the engine returned
    // before reading anything (a real source would be re-supplied here).
    let empty: Vec<i64> = Vec::new();
    let mut sink = VecSink::new();
    let stats = ctx
        .external_sort_ckpt(
            &mut SliceSource::new(&empty),
            &mut sink,
            None,
            &Checkpoint::new(&dir, "double").resume(),
        )
        .unwrap();
    assert!(stats.completed_noop, "resuming a completed job must be a no-op");
    assert!(sink.out.is_empty());
}

#[test]
fn topk_and_histogram_streaming_equivalence() {
    let xs: Vec<f32> = generate(&mut Prng::new(9), Distribution::Gaussian, 30_000);
    let ctx = Session::threaded(2).stream(StreamBudget::bytes(64)).run_chunk_elems(997);
    // top-k vs in-memory sort-desc-take-k, bitwise.
    let mut want = xs.clone();
    Session::native().sort(&mut want, None).unwrap();
    want.reverse();
    for k in [1usize, 50, 1000] {
        let got = ctx.stream_topk(&mut SliceSource::new(&xs), k, None).unwrap();
        assert!(bits_eq(&got, &want[..k]), "k={k}");
    }
    // histogram vs a direct count on the total order.
    let edges = vec![-2.0f32, -0.5, 0.0, 0.5, 2.0];
    let got = ctx.stream_histogram(&mut SliceSource::new(&xs), &edges, None).unwrap();
    let mut expect = vec![0u64; edges.len() + 1];
    for &x in &xs {
        // NB: qualified — the *total-order* image, not f32's raw IEEE
        // bits (raw bits misorder negatives).
        let bin = edges
            .iter()
            .take_while(|&&e| SortKey::to_bits(e) <= SortKey::to_bits(x))
            .count();
        expect[bin] += 1;
    }
    assert_eq!(got, expect);
    assert_eq!(got.iter().sum::<u64>(), xs.len() as u64);
}
