//! Disabled-tracer overhead guard (DESIGN.md §18).
//!
//! The obs layer's core promise is that an *unarmed* tracer costs one
//! relaxed atomic load per call site — in particular, no heap
//! allocation. This binary installs a counting global allocator and
//! proves the whole disabled surface (spans, instants, counters,
//! phases, labels) allocates nothing. It must stay its own test binary:
//! no test here ever arms a [`accelkern::obs::TraceSession`], so the
//! process-global enabled flag is reliably off.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use accelkern::obs::{self, SpanKind};

thread_local! {
    /// Allocations made by *this* thread — immune to harness threads.
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// The count must never itself allocate: a const-initialised Cell in
// TLS is allocation-free, and `try_with` tolerates TLS teardown.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn my_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

#[test]
fn disabled_tracer_surface_allocates_nothing() {
    assert!(!obs::enabled(), "no TraceSession may be armed in this binary");

    // Sanity: the counter actually observes this thread's allocations.
    let before = my_allocs();
    let probe = std::hint::black_box(vec![7u8; 64]);
    assert!(my_allocs() > before, "the counting allocator is not installed");
    drop(probe);

    let before = my_allocs();
    for i in 0..10_000u64 {
        let g = obs::span(SpanKind::Pass, "off.pass");
        drop(g);
        let g = obs::span1(SpanKind::ExchangeChunk, "off.chunk", i);
        drop(g);
        obs::instant(SpanKind::Fault, "off.fault");
        obs::instant2(SpanKind::Retry, "off.retry", i);
        obs::counter("off.counter", i);
        obs::phase("off.phase");
        obs::phase_end();
        obs::set_thread_label("off-thread");
    }
    let after = my_allocs();
    assert_eq!(
        after - before,
        0,
        "the disabled tracer allocated {} time(s) over 10k call rounds",
        after - before
    );
}
