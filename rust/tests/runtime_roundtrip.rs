//! End-to-end runtime tests: load real AOT artifacts, compile on the PJRT
//! CPU client, execute, and check numerics against host references.
//!
//! Requires `make artifacts` (skips gracefully when the needed artifact is
//! absent so `cargo test` stays runnable mid-bootstrap).

use accelkern::dtype::ElemType;
use accelkern::runtime::{lit_from_slice, lit_from_slice_2d, lit_scalar, lit_to_vec, Runtime};
use accelkern::util::Prng;

fn runtime_or_skip(names: &[&str]) -> Option<std::sync::Arc<Runtime>> {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            return None;
        }
    };
    for n in names {
        if rt.manifest().get(n).is_none() {
            eprintln!("SKIP (artifact {n} missing — run `make artifacts`)");
            return None;
        }
    }
    Some(rt)
}

#[test]
fn sort_i32_n10_roundtrip() {
    let Some(rt) = runtime_or_skip(&["sort_i32_n10"]) else { return };
    let mut rng = Prng::new(42);
    let xs: Vec<i32> = (0..1024).map(|_| rng.range_i64(-1_000_000, 1_000_000) as i32).collect();
    let out = rt.execute("sort_i32_n10", &[lit_from_slice(&xs).unwrap()]).unwrap();
    let got = lit_to_vec::<i32>(&out[0]).unwrap();
    let mut want = xs.clone();
    want.sort_unstable();
    assert_eq!(got, want);
}

#[test]
fn sort_padding_sentinels_sink() {
    let Some(rt) = runtime_or_skip(&["sort_i32_n10"]) else { return };
    // 1000 real values + 24 max-sentinels: the real prefix must come back
    // sorted in the first 1000 lanes.
    let mut rng = Prng::new(7);
    let mut xs: Vec<i32> = (0..1000).map(|_| rng.range_i64(-500, 500) as i32).collect();
    let real = xs.clone();
    xs.resize(1024, i32::MAX);
    let out = rt.execute("sort_i32_n10", &[lit_from_slice(&xs).unwrap()]).unwrap();
    let got = lit_to_vec::<i32>(&out[0]).unwrap();
    let mut want = real;
    want.sort_unstable();
    assert_eq!(&got[..1000], &want[..]);
    assert!(got[1000..].iter().all(|&v| v == i32::MAX));
}

#[test]
fn sort_pairs_permutation() {
    let Some(rt) = runtime_or_skip(&["sort_pairs_i32_n10"]) else { return };
    let mut rng = Prng::new(3);
    let keys: Vec<i32> = (0..1024).map(|_| rng.range_i64(-100, 100) as i32).collect();
    let vals: Vec<i32> = (0..1024).collect();
    let out = rt
        .execute(
            "sort_pairs_i32_n10",
            &[lit_from_slice(&keys).unwrap(), lit_from_slice(&vals).unwrap()],
        )
        .unwrap();
    let gk = lit_to_vec::<i32>(&out[0]).unwrap();
    let gv = lit_to_vec::<i32>(&out[1]).unwrap();
    // keys sorted, and vals is the permutation that sorts the input keys.
    assert!(gk.windows(2).all(|w| w[0] <= w[1]));
    for (k, v) in gk.iter().zip(&gv) {
        assert_eq!(*k, keys[*v as usize]);
    }
    // stability: duplicate keys keep ascending payload indices.
    for w in gk.windows(2).zip(gv.windows(2)) {
        if w.0[0] == w.0[1] {
            assert!(w.1[0] < w.1[1], "unstable at key {}", w.0[0]);
        }
    }
}

#[test]
fn reduce_add_f32() {
    let Some(rt) = runtime_or_skip(&["reduce_add_f32_n14"]) else { return };
    let mut rng = Prng::new(5);
    let xs: Vec<f32> = (0..16384).map(|_| rng.uniform_f32()).collect();
    let out = rt.execute("reduce_add_f32_n14", &[lit_from_slice(&xs).unwrap()]).unwrap();
    let got = lit_to_vec::<f32>(&out[0]).unwrap()[0];
    let want: f64 = xs.iter().map(|&v| v as f64).sum();
    assert!((got as f64 - want).abs() / want < 1e-4, "got {got} want {want}");
}

#[test]
fn searchsorted_first_i32() {
    let Some(rt) = runtime_or_skip(&["searchsorted_first_i32_n14"]) else { return };
    let mut rng = Prng::new(11);
    let mut hay: Vec<i32> = (0..16384).map(|_| rng.range_i64(-10_000, 10_000) as i32).collect();
    hay.sort_unstable();
    let needles: Vec<i32> = (0..1024).map(|_| rng.range_i64(-12_000, 12_000) as i32).collect();
    let out = rt
        .execute(
            "searchsorted_first_i32_n14",
            &[lit_from_slice(&hay).unwrap(), lit_from_slice(&needles).unwrap()],
        )
        .unwrap();
    let got = lit_to_vec::<i32>(&out[0]).unwrap();
    for (i, &nd) in needles.iter().enumerate() {
        let want = hay.partition_point(|&h| h < nd) as i32;
        assert_eq!(got[i], want, "needle {nd}");
    }
}

#[test]
fn rbf_f32_matches_host() {
    let Some(rt) = runtime_or_skip(&["rbf_f32_n17"]) else { return };
    let n = 1 << 17;
    let mut rng = Prng::new(13);
    let pts: Vec<f32> = (0..3 * n).map(|_| rng.uniform_f32() * 0.5).collect();
    let out = rt
        .execute("rbf_f32_n17", &[lit_from_slice_2d(&pts, 3, n).unwrap()])
        .unwrap();
    let got = lit_to_vec::<f32>(&out[0]).unwrap();
    for i in (0..n).step_by(4097) {
        let (x, y, z) = (pts[i], pts[n + i], pts[2 * n + i]);
        let r = (x * x + y * y + z * z).sqrt();
        let want = (-1.0 / (1.0 - r)).exp();
        assert!((got[i] - want).abs() <= 1e-5 * want.abs().max(1.0), "i={i} got {} want {want}", got[i]);
    }
}

#[test]
fn ljg_f32_matches_host() {
    let Some(rt) = runtime_or_skip(&["ljg_f32_n17"]) else { return };
    let n = 1 << 17;
    let mut rng = Prng::new(17);
    let p1: Vec<f32> = (0..3 * n).map(|_| rng.uniform_f32() * 4.0).collect();
    let p2: Vec<f32> = (0..3 * n).map(|_| rng.uniform_f32() * 4.0).collect();
    let consts: Vec<f32> = vec![1.0, 1.0, 1.5, 3.0];
    let out = rt
        .execute(
            "ljg_f32_n17",
            &[
                lit_from_slice_2d(&p1, 3, n).unwrap(),
                lit_from_slice_2d(&p2, 3, n).unwrap(),
                lit_from_slice(&consts).unwrap(),
            ],
        )
        .unwrap();
    let got = lit_to_vec::<f32>(&out[0]).unwrap();
    let (eps, sigma, r0, cutoff) = (1.0f32, 1.0f32, 1.5f32, 3.0f32);
    for i in (0..n).step_by(2053) {
        let dx = p1[i] - p2[i];
        let dy = p1[n + i] - p2[n + i];
        let dz = p1[2 * n + i] - p2[2 * n + i];
        let r = (dx * dx + dy * dy + dz * dz).sqrt();
        let want = if r < cutoff {
            let sr = sigma / r;
            let sr3 = sr * sr * sr;
            let sr6 = sr3 * sr3;
            let sr12 = sr6 * sr6;
            4.0 * eps * (sr12 - sr6) - eps * (-((r - r0) * (r - r0)) / (2.0 * sigma * sigma)).exp()
        } else {
            0.0
        };
        assert!(
            (got[i] - want).abs() <= 1e-3 * want.abs().max(1.0),
            "i={i} got {} want {want}",
            got[i]
        );
    }
}

#[test]
fn executable_cache_hits() {
    let Some(rt) = runtime_or_skip(&["sort_i32_n10"]) else { return };
    let a = rt.get("sort_i32_n10").unwrap();
    let b = rt.get("sort_i32_n10").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert!(rt.cached_names().contains(&"sort_i32_n10".to_string()));
}

#[test]
fn manifest_exposes_families() {
    let Some(rt) = runtime_or_skip(&[]) else { return };
    // Whatever subset is built, families must be internally consistent.
    for a in &rt.manifest().artifacts {
        assert!(!a.inputs.is_empty());
        assert!(!a.outputs.is_empty());
        assert!(a.n.is_power_of_two(), "{} n={}", a.name, a.n);
        assert!(a.dtype.xla_supported());
        assert_ne!(a.dtype, ElemType::I128);
    }
    // Scalar-input artifact shape check (threshold input is rank-0).
    let _ = lit_scalar(0i32).unwrap();
}
