//! The unified `Session`/`Launch` API — one dispatch surface for every
//! engine (DESIGN.md §12).
//!
//! The paper's headline is not any single kernel but the call shape:
//! every algorithm is *one call* that dispatches on execution context
//! and accepts per-call tuning keywords (`block_size`, `max_tasks`,
//! `min_elems` — §III). This module is that surface for the Rust side:
//!
//! * [`Session`] owns a [`Backend`], a metrics sink and a default
//!   tuning policy. Construct one per engine —
//!   [`Session::native`] / [`Session::threaded`] / [`Session::device`] /
//!   [`Session::hybrid`] — and call algorithms as methods.
//! * [`Launch`] is the per-call knob set, merged over the session's
//!   defaults ([`Launch::merged_over`]); `None` means "session policy".
//! * Every method returns [`AkResult`], whose [`AkError`] names the
//!   failure class (dtype gap, backend gap, device outage, shape bug)
//!   instead of an opaque `anyhow` chain.
//!
//! The pre-session free functions in [`crate::algorithms`] remain as
//! `#[deprecated]` shims delegating here, so downstream code migrates
//! incrementally; in-tree code is shim-free (CI denies `deprecated`).
//!
//! ```
//! use accelkern::session::{Launch, Session};
//! let s = Session::threaded(4);
//! let mut v = vec![3i32, -1, 2, 0];
//! s.sort(&mut v, None).unwrap();
//! assert_eq!(v, vec![-1, 0, 2, 3]);
//!
//! // Per-call knobs: cap the worker count, reuse merge scratch.
//! let l = Launch::new().max_tasks(2).reuse_scratch(true);
//! let mut w = vec![9i64, 8, 7, 6];
//! s.sort(&mut w, Some(&l)).unwrap();
//! assert_eq!(w, vec![6, 7, 8, 9]);
//! ```

pub mod error;
pub mod launch;

pub use error::{AkError, AkResult};
pub use launch::{Launch, DEFAULT_PAR_THRESHOLD};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::algorithms::arith::{ljg_host, ljg_powf_host, rbf_host, LjgConsts};
use crate::algorithms::predicates::host_any;
use crate::algorithms::reduce::{host_mapreduce, host_reduce, Reducible, ReduceKind};
use crate::algorithms::scan::{host_scan, threaded_scan, ScanAdd};
use crate::algorithms::search::host_search;
use crate::algorithms::sort::{apply_permutation, threaded_sort};
use crate::algorithms::sortperm::{host_sortperm, host_sortperm_lowmem};
use crate::backend::{Backend, DeviceKey};
use crate::baselines::merge_path::PAR_MERGE_MIN;
use crate::dtype::SortKey;
use crate::hybrid::{HybridEngine, MIN_COSPLIT};
use crate::runtime::Registry;

/// Call/volume/scratch counters a [`Session`] records into. Shared by
/// clones of the session (the sink is behind an `Arc`).
#[derive(Debug, Default)]
pub struct SessionMetrics {
    calls: AtomicU64,
    elems: AtomicU64,
    scratch_hits: AtomicU64,
    scratch_misses: AtomicU64,
    device_fallbacks: AtomicU64,
}

impl SessionMetrics {
    fn record(&self, n: usize) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.elems.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Algorithm calls issued through this session (and its clones).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Total elements those calls covered.
    pub fn elems(&self) -> u64 {
        self.elems.load(Ordering::Relaxed)
    }

    /// Scratch-pool borrows that found a reusable buffer.
    pub fn scratch_hits(&self) -> u64 {
        self.scratch_hits.load(Ordering::Relaxed)
    }

    /// Scratch-pool borrows that had to allocate fresh.
    pub fn scratch_misses(&self) -> u64 {
        self.scratch_misses.load(Ordering::Relaxed)
    }

    /// Calls a device session served on its host engine because the
    /// device could not run them (missing artifact, multi-chunk
    /// `sort_pairs` plan). `Launch::strict_device` turns these into
    /// typed errors instead.
    pub fn device_fallbacks(&self) -> u64 {
        self.device_fallbacks.load(Ordering::Relaxed)
    }

    /// Registry form of these counters
    /// ([`crate::obs::SESSION_COUNTERS`]), consumed by the bench
    /// records and the `--trace-summary` tables.
    pub fn snapshot(&self) -> crate::obs::CounterSnapshot {
        let mut s = crate::obs::CounterSnapshot::new();
        s.push("calls", self.calls());
        s.push("elems", self.elems());
        s.push("scratch_hits", self.scratch_hits());
        s.push("scratch_misses", self.scratch_misses());
        s.push("device_fallbacks", self.device_fallbacks());
        s
    }
}

/// The retained allocation of a cleared `Vec<T>`, type-erased down to
/// its layout so any element type with the same (size, alignment) can
/// adopt it. Only ever constructed from an empty vector, so there are
/// no live elements to drop or transmute.
struct RawScratch {
    ptr: std::ptr::NonNull<u8>,
    cap_elems: usize,
    elem_size: usize,
    elem_align: usize,
}

// SAFETY: the allocation is exclusively owned (taken out of a `Vec<T>`
// where `T: Send`) and holds no initialised elements.
unsafe impl Send for RawScratch {}

impl Drop for RawScratch {
    fn drop(&mut self) {
        // SAFETY: `ptr` came from a `Vec<T>` with `size_of::<T>() ==
        // elem_size`, `align_of::<T>() == elem_align` and capacity
        // `cap_elems`, which is exactly this layout's allocation.
        unsafe {
            let layout = std::alloc::Layout::from_size_align_unchecked(
                self.elem_size * self.cap_elems,
                self.elem_align,
            );
            std::alloc::dealloc(self.ptr.as_ptr(), layout);
        }
    }
}

/// Reusable temporary buffers, keyed by element *layout* — (byte size,
/// alignment) — rather than `TypeId`, so mixed-dtype workloads of the
/// same width (an `f32` sort after an `i32` sort, `f64` after `i64`)
/// share one buffer instead of allocating parallel ones. One buffer is
/// retained per layout class; `Launch::reuse_scratch` opts a call in.
#[derive(Default)]
struct ScratchPool {
    bufs: Mutex<HashMap<(usize, usize), RawScratch>>,
}

impl ScratchPool {
    fn take<T: Send + 'static>(&self) -> Option<Vec<T>> {
        let key = (std::mem::size_of::<T>(), std::mem::align_of::<T>());
        if key.0 == 0 {
            return None; // ZSTs never allocate; nothing to reuse.
        }
        let buf = self.bufs.lock().unwrap().remove(&key)?;
        let buf = std::mem::ManuallyDrop::new(buf);
        // SAFETY: same (size, align) key means `Vec::<T>` with capacity
        // `cap_elems` describes the identical allocation the buffer was
        // taken from; length 0 means no element is ever read
        // uninitialised.
        Some(unsafe { Vec::from_raw_parts(buf.ptr.as_ptr() as *mut T, 0, buf.cap_elems) })
    }

    fn put<T: Send + 'static>(&self, mut v: Vec<T>) {
        v.clear();
        if std::mem::size_of::<T>() == 0 || v.capacity() == 0 {
            return; // nothing worth retaining (and nothing to dealloc).
        }
        let key = (std::mem::size_of::<T>(), std::mem::align_of::<T>());
        let mut v = std::mem::ManuallyDrop::new(v);
        let raw = RawScratch {
            // SAFETY: a non-zero-capacity Vec's pointer is non-null.
            ptr: unsafe { std::ptr::NonNull::new_unchecked(v.as_mut_ptr() as *mut u8) },
            cap_elems: v.capacity(),
            elem_size: key.0,
            elem_align: key.1,
        };
        self.bufs.lock().unwrap().insert(key, raw);
    }
}

struct SessionState {
    metrics: SessionMetrics,
    scratch: ScratchPool,
}

/// An execution session: a [`Backend`], a default tuning policy and a
/// metrics/scratch sink. Cheap to clone (clones share the sink); `Send`
/// + `Sync`, so one session can serve many rank threads.
#[derive(Clone)]
pub struct Session {
    backend: Backend,
    defaults: Launch,
    state: Arc<SessionState>,
}

impl Session {
    /// Single-thread host session.
    pub fn native() -> Session {
        Session::from_backend(Backend::Native)
    }

    /// Host session over `n` std threads.
    pub fn threaded(n: usize) -> Session {
        Session::from_backend(Backend::Threaded(n.max(1)))
    }

    /// Device session over an artifact registry (AOT engine via PJRT).
    pub fn device(reg: Registry) -> Session {
        Session::from_backend(Backend::device(reg))
    }

    /// Hybrid CPU–GPU co-processing session (DESIGN.md §10).
    pub fn hybrid(engine: HybridEngine) -> Session {
        Session::from_backend(Backend::Hybrid(engine))
    }

    /// Session over an already-built [`Backend`] handle.
    pub fn from_backend(backend: Backend) -> Session {
        Session {
            backend,
            defaults: Launch::default(),
            state: Arc::new(SessionState {
                metrics: SessionMetrics::default(),
                scratch: ScratchPool::default(),
            }),
        }
    }

    /// Replace the session's default tuning policy: per-call launches
    /// are merged *over* this ([`Launch::merged_over`]).
    pub fn with_defaults(mut self, defaults: Launch) -> Session {
        self.defaults = defaults;
        self
    }

    /// The process-default session (host thread pool at the adaptive
    /// default width) — what one-off calls and quick scripts use.
    pub fn global() -> &'static Session {
        static GLOBAL: OnceLock<Session> = OnceLock::new();
        GLOBAL.get_or_init(|| Session::threaded(crate::backend::threaded::default_threads()))
    }

    /// The session's execution backend.
    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    /// The session's default tuning policy.
    pub fn defaults(&self) -> &Launch {
        &self.defaults
    }

    /// Human-readable engine name (`Backend::name`).
    pub fn name(&self) -> String {
        self.backend.name()
    }

    /// The metrics sink (shared across clones of this session).
    pub fn metrics(&self) -> &SessionMetrics {
        &self.state.metrics
    }

    /// A bounded-memory streaming context over this session's engines
    /// (out-of-core sort, reduce, scan, histogram, top-k — DESIGN.md
    /// §13). The context clones the session, so per-chunk work runs on
    /// this backend, records into this metrics sink, and honours the
    /// same default `Launch` policy.
    ///
    /// ```
    /// use accelkern::session::Session;
    /// use accelkern::stream::{SliceSource, StreamBudget, VecSink};
    /// let data = vec![4i64, 1, 3, 2];
    /// let ctx = Session::threaded(2).stream(StreamBudget::mib(1));
    /// let mut out = VecSink::new();
    /// ctx.external_sort(&mut SliceSource::new(&data), &mut out, None).unwrap();
    /// assert_eq!(out.out, vec![1, 2, 3, 4]);
    /// ```
    pub fn stream(&self, budget: crate::stream::StreamBudget) -> crate::stream::StreamCtx {
        crate::stream::StreamCtx::new(self.clone(), budget)
    }

    fn resolve(&self, launch: Option<&Launch>) -> Launch {
        match launch {
            Some(l) => l.merged_over(&self.defaults),
            None => self.defaults.clone(),
        }
    }

    fn take_scratch<T: Send + 'static>(&self, l: &Launch) -> Vec<T> {
        if !l.reuse_scratch_on() {
            return Vec::new();
        }
        match self.state.scratch.take::<T>() {
            Some(v) => {
                self.state.metrics.scratch_hits.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                self.state.metrics.scratch_misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    fn put_scratch<T: Send + 'static>(&self, v: Vec<T>, l: &Launch) {
        if l.reuse_scratch_on() {
            self.state.scratch.put(v);
        }
    }

    // ---- sorting ----------------------------------------------------------

    /// Sort `xs` ascending (total order; NaN-safe for floats). Not
    /// stable — see `algorithms::sort` module docs for the stability
    /// contract split.
    ///
    /// ```
    /// use accelkern::session::Session;
    /// let mut f = vec![1.0f64, f64::NAN, f64::NEG_INFINITY, -0.0];
    /// Session::threaded(2).sort(&mut f, None).unwrap();
    /// assert_eq!(f[0], f64::NEG_INFINITY);
    /// assert!(f[3].is_nan());
    /// ```
    pub fn sort<K: DeviceKey>(&self, xs: &mut [K], launch: Option<&Launch>) -> AkResult<()> {
        let _span =
            crate::obs::span1(crate::obs::SpanKind::SessionOp, "session.sort", xs.len() as u64);
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native => {
                xs.sort_unstable_by(|a, b| a.cmp_total(b));
                Ok(())
            }
            Backend::Threaded(t) => {
                self.host_sort(xs, *t, &l);
                Ok(())
            }
            Backend::Device(dev) => {
                if !K::XLA {
                    return Err(AkError::unsupported_dtype(
                        K::ELEM,
                        "sort",
                        "no XLA artifact family (XLA-CPU has no s128, DESIGN.md §2)",
                    ));
                }
                dev.sort_blocked(xs, l.block_size).map_err(|e| AkError::device("sort", e))
            }
            Backend::Hybrid(h) => {
                let mut scratch = self.take_scratch::<K>(&l);
                let res = crate::hybrid::co_sort_scratch(h, xs, &l, &mut scratch);
                self.put_scratch(scratch, &l);
                res
            }
        }
    }

    fn host_sort<K: SortKey>(&self, xs: &mut [K], base_threads: usize, l: &Launch) {
        let t = l.tasks_for(base_threads, xs.len());
        let mut scratch = self.take_scratch::<K>(l);
        threaded_sort(
            xs,
            t,
            l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
            l.par_threshold_or(PAR_MERGE_MIN),
            &mut scratch,
        );
        self.put_scratch(scratch, l);
    }

    /// Sort `keys` ascending carrying `vals` along (stable payload
    /// sort): equal keys keep their input order.
    pub fn sort_by_key<K: DeviceKey, V: Copy + Send + Sync>(
        &self,
        keys: &mut [K],
        vals: &mut [V],
        launch: Option<&Launch>,
    ) -> AkResult<()> {
        if keys.len() != vals.len() {
            return Err(AkError::shape(
                "sort_by_key",
                format!("keys {} vs vals {}", keys.len(), vals.len()),
            ));
        }
        if keys.len() <= 1 {
            return Ok(());
        }
        // General payloads go through an index permutation (native work
        // is an O(n) scatter either way); the permutation inherits the
        // session's device path when one applies.
        let perm = self.sortperm(keys, launch)?;
        apply_permutation(keys, &perm);
        apply_permutation(vals, &perm);
        Ok(())
    }

    /// Permutation `p` such that `xs[p[0]] <= xs[p[1]] <= ...` (stable).
    pub fn sortperm<K: DeviceKey>(
        &self,
        xs: &[K],
        launch: Option<&Launch>,
    ) -> AkResult<Vec<u32>> {
        let l = self.resolve(launch);
        if xs.len() > u32::MAX as usize {
            return Err(AkError::shape(
                "sortperm",
                format!("index space is u32, input has {} elements", xs.len()),
            ));
        }
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native => Ok(self.host_perm(xs, 1, &l)),
            Backend::Threaded(t) => Ok(self.host_perm(xs, *t, &l)),
            Backend::Device(dev) => {
                let plan_chunks = if K::XLA {
                    dev.registry().plan("sort_pairs", K::ELEM, xs.len()).ok().map(|p| p.chunks)
                } else {
                    None
                };
                match device_sortperm_route(K::XLA, plan_chunks) {
                    DeviceRoute::Device => {
                        let vals: Vec<i32> = (0..xs.len() as i32).collect();
                        let (_, perm) = dev
                            .sort_pairs(xs, &vals)
                            .map_err(|e| AkError::device("sortperm", e))?;
                        Ok(perm.into_iter().map(|v| v as u32).collect())
                    }
                    DeviceRoute::HostFallback(why) => {
                        // The device cannot serve this call: the fallback
                        // is never silent — strict sessions get a typed
                        // error, the rest a metrics event (ROADMAP's
                        // "multi-chunk sortperm" deferred item).
                        if l.strict_device_on() {
                            return Err(AkError::unsupported_backend(
                                &self.backend,
                                "sortperm",
                                why,
                            ));
                        }
                        self.state.metrics.device_fallbacks.fetch_add(1, Ordering::Relaxed);
                        Ok(self.host_perm(xs, 1, &l))
                    }
                }
            }
            // The pair buffer cannot straddle two engines without an
            // extra gather; hybrid sortperm runs on the host pool
            // (DESIGN.md §10).
            Backend::Hybrid(h) => Ok(self.host_perm(xs, h.host_threads, &l)),
        }
    }

    fn host_perm<K: SortKey>(&self, xs: &[K], base_threads: usize, l: &Launch) -> Vec<u32> {
        let t = l.tasks_for(base_threads, xs.len());
        let mut pairs = self.take_scratch::<(u128, u32)>(l);
        let out = host_sortperm(xs, t, l.par_threshold_or(DEFAULT_PAR_THRESHOLD), &mut pairs);
        self.put_scratch(pairs, l);
        out
    }

    /// Lower-memory `sortperm` variant: sorts the index array in place
    /// with a key-indexed comparator (no `(key, index)` pair buffer).
    /// Host engines only — the indexed comparator cannot cross the AOT
    /// boundary, so the device backend returns
    /// [`AkError::UnsupportedBackend`] instead of silently degrading.
    pub fn sortperm_lowmem<K: SortKey>(
        &self,
        xs: &[K],
        launch: Option<&Launch>,
    ) -> AkResult<Vec<u32>> {
        let l = self.resolve(launch);
        if xs.len() > u32::MAX as usize {
            return Err(AkError::shape(
                "sortperm_lowmem",
                format!("index space is u32, input has {} elements", xs.len()),
            ));
        }
        self.state.metrics.record(xs.len());
        let base_threads = match &self.backend {
            Backend::Native => 1,
            Backend::Threaded(t) => *t,
            // Hybrid runs host-side like `sortperm` (same pair-buffer
            // rule); the host pool is the documented engine.
            Backend::Hybrid(h) => h.host_threads,
            Backend::Device(_) => {
                return Err(AkError::unsupported_backend(
                    &self.backend,
                    "sortperm_lowmem",
                    "indexed-comparator argsort cannot cross the AOT boundary; \
                     use `sortperm` or a host session",
                ));
            }
        };
        let t = l.tasks_for(base_threads, xs.len());
        Ok(host_sortperm_lowmem(xs, t, l.par_threshold_or(DEFAULT_PAR_THRESHOLD)))
    }

    // ---- reductions -------------------------------------------------------

    /// Reduce `xs` with `kind`. The `switch_below` launch knob routes
    /// device inputs at or below that size through the partials artifact
    /// with a host-side finish (paper §II-B device-sync masking).
    ///
    /// ```
    /// use accelkern::algorithms::ReduceKind;
    /// use accelkern::session::Session;
    /// let xs = vec![3i64, -1, 4, 1, 5];
    /// let s = Session::native();
    /// assert_eq!(s.reduce(&xs, ReduceKind::Add, None).unwrap(), 12);
    /// assert_eq!(s.reduce(&xs, ReduceKind::Min, None).unwrap(), -1);
    /// ```
    pub fn reduce<K: Reducible>(
        &self,
        xs: &[K],
        kind: ReduceKind,
        launch: Option<&Launch>,
    ) -> AkResult<K> {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native => Ok(host_reduce(xs, kind)),
            Backend::Threaded(t) => {
                let tasks = l.tasks_for(*t, xs.len());
                if tasks <= 1 || xs.len() < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
                    return Ok(host_reduce(xs, kind));
                }
                let partials = crate::backend::parallel_for_each_chunk(xs.len(), tasks, |r| {
                    host_reduce(&xs[r], kind)
                });
                Ok(partials.into_iter().fold(K::identity(kind), |a, b| K::fold(kind, a, b)))
            }
            Backend::Device(dev) => {
                if !K::XLA {
                    // Documented host fallback (unlike `sort`, there is
                    // no data-movement hazard in folding on the host).
                    return Ok(host_reduce(xs, kind));
                }
                if kind == ReduceKind::Add && xs.len() <= l.switch_below_or(0) {
                    return dev
                        .reduce_partials_add_shim(xs)
                        .map_err(|e| AkError::device("reduce", e));
                }
                dev.reduce(xs, kind.op_name(), K::identity(kind), |a, b| K::fold(kind, a, b))
                    .map_err(|e| AkError::device("reduce", e))
            }
            Backend::Hybrid(h) => crate::hybrid::co_reduce_launch(h, xs, kind, &l),
        }
    }

    /// `mapreduce(f, op, xs)`: host closures on host engines; the device
    /// backend host-executes (arbitrary lambdas cannot cross the AOT
    /// boundary — the device variants are the named-map artifacts).
    pub fn mapreduce<K: Reducible, M>(
        &self,
        xs: &[K],
        map: M,
        kind: ReduceKind,
        launch: Option<&Launch>,
    ) -> AkResult<K>
    where
        M: Fn(K) -> K + Sync,
    {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        let threads = match &self.backend {
            Backend::Native | Backend::Device(_) => 1,
            Backend::Threaded(t) => *t,
            Backend::Hybrid(h) => h.host_threads,
        };
        let tasks = l.tasks_for(threads, xs.len());
        if tasks <= 1 || xs.len() < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
            return Ok(host_mapreduce(xs, &map, kind));
        }
        let partials = crate::backend::parallel_for_each_chunk(xs.len(), tasks, |r| {
            host_mapreduce(&xs[r], &map, kind)
        });
        Ok(partials.into_iter().fold(K::identity(kind), |a, b| K::fold(kind, a, b)))
    }

    // ---- scans ------------------------------------------------------------

    /// Prefix-sum of `xs`; `inclusive` selects the scan flavour.
    pub fn accumulate<K: ScanAdd + std::ops::Add<Output = K>>(
        &self,
        xs: &[K],
        inclusive: bool,
        launch: Option<&Launch>,
    ) -> AkResult<Vec<K>> {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native => Ok(host_scan(xs, inclusive)),
            Backend::Threaded(t) => Ok(threaded_scan(
                xs,
                inclusive,
                l.tasks_for(*t, xs.len()),
                l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
            )),
            Backend::Device(dev) => {
                if K::XLA {
                    dev.scan_add(xs, inclusive).map_err(|e| AkError::device("accumulate", e))
                } else {
                    Ok(host_scan(xs, inclusive))
                }
            }
            // Carries serialise the chunk recombination, so co-processing
            // buys nothing: hybrid scans run on the host pool.
            Backend::Hybrid(h) => Ok(threaded_scan(
                xs,
                inclusive,
                l.tasks_for(h.host_threads, xs.len()),
                l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
            )),
        }
    }

    // ---- parallel loops ---------------------------------------------------

    /// Run `f(i)` for every `i in 0..len`, statically partitioned over
    /// the backend's workers. Infallible: every engine has a host
    /// execution for arbitrary closures.
    pub fn foreachindex<F>(&self, len: usize, f: F, launch: Option<&Launch>)
    where
        F: Fn(usize) + Sync,
    {
        let l = self.resolve(launch);
        self.state.metrics.record(len);
        match &self.backend {
            Backend::Native | Backend::Device(_) => {
                for i in 0..len {
                    f(i);
                }
            }
            Backend::Threaded(t) => {
                let tasks = l.tasks_for(*t, len);
                if tasks <= 1 || len < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
                    for i in 0..len {
                        f(i);
                    }
                    return;
                }
                crate::backend::parallel_for_each_chunk(len, tasks, |r| {
                    for i in r {
                        f(i);
                    }
                });
            }
            Backend::Hybrid(h) => crate::hybrid::co_foreachindex_launch(h, len, &f, &l),
        }
    }

    /// Mutating loop over a slice: `f(i, &mut xs[i])` on disjoint chunks
    /// (the dst/src copy-kernel pattern of paper Algorithm 3).
    pub fn foreach_mut<T: Send, F>(&self, xs: &mut [T], f: F, launch: Option<&Launch>)
    where
        F: Fn(usize, &mut T) + Sync,
    {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native | Backend::Device(_) => {
                for (i, x) in xs.iter_mut().enumerate() {
                    f(i, x);
                }
            }
            Backend::Threaded(t) => {
                let tasks = l.tasks_for(*t, xs.len());
                if tasks <= 1 || xs.len() < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
                    for (i, x) in xs.iter_mut().enumerate() {
                        f(i, x);
                    }
                    return;
                }
                let ranges = crate::backend::threaded::split_ranges(xs.len(), tasks);
                crate::backend::parallel_chunks(xs, tasks, |ci, chunk| {
                    let base = ranges[ci].start;
                    for (j, x) in chunk.iter_mut().enumerate() {
                        f(base + j, x);
                    }
                });
            }
            Backend::Hybrid(h) => crate::hybrid::co_foreach_mut_launch(h, xs, &f, &l),
        }
    }

    // ---- searching --------------------------------------------------------

    /// Leftmost insertion indices of `needles` into ascending `haystack`.
    pub fn searchsorted_first<K: DeviceKey>(
        &self,
        haystack: &[K],
        needles: &[K],
        launch: Option<&Launch>,
    ) -> AkResult<Vec<u32>> {
        self.searchsorted(haystack, needles, "first", launch)
    }

    /// Rightmost insertion indices (`upper_bound`).
    pub fn searchsorted_last<K: DeviceKey>(
        &self,
        haystack: &[K],
        needles: &[K],
        launch: Option<&Launch>,
    ) -> AkResult<Vec<u32>> {
        self.searchsorted(haystack, needles, "last", launch)
    }

    fn searchsorted<K: DeviceKey>(
        &self,
        haystack: &[K],
        needles: &[K],
        side: &'static str,
        launch: Option<&Launch>,
    ) -> AkResult<Vec<u32>> {
        debug_assert!(crate::dtype::is_sorted_total(haystack), "haystack must be sorted");
        let l = self.resolve(launch);
        self.state.metrics.record(needles.len());
        let seq = l.par_threshold_or(DEFAULT_PAR_THRESHOLD);
        match &self.backend {
            Backend::Native => Ok(host_search(haystack, needles, side, 1, seq)),
            Backend::Threaded(t) => {
                Ok(host_search(haystack, needles, side, l.tasks_for(*t, needles.len()), seq))
            }
            Backend::Device(dev) => {
                if K::XLA && dev.registry().supports(&format!("searchsorted_{side}"), K::ELEM) {
                    // Device artifacts cap the haystack class; oversize
                    // falls back to the host path.
                    if let Ok(plan) = dev.registry().plan(
                        &format!("searchsorted_{side}"),
                        K::ELEM,
                        haystack.len(),
                    ) {
                        if plan.chunks == 1 {
                            return dev
                                .searchsorted(haystack, needles, side)
                                .map_err(|e| AkError::device("searchsorted", e));
                        }
                    }
                }
                Ok(host_search(haystack, needles, side, 1, seq))
            }
            // Co-processing: the needle block splits between the engines
            // (both search the same haystack); results concatenate in
            // order (DESIGN.md §10).
            Backend::Hybrid(h) => {
                let min_split = l.par_threshold_or(MIN_COSPLIT);
                let split = match h.route_with(needles.len(), min_split) {
                    crate::hybrid::CoRoute::Host => {
                        return Ok(host_search(
                            haystack,
                            needles,
                            side,
                            l.tasks_for(h.host_threads, needles.len()),
                            seq,
                        ));
                    }
                    crate::hybrid::CoRoute::Device => {
                        return Session::from_backend(h.device_backend())
                            .searchsorted(haystack, needles, side, Some(&l));
                    }
                    crate::hybrid::CoRoute::Split(split) => split,
                };
                let (host_needles, dev_needles) = needles.split_at(split);
                let dev_session = Session::from_backend(h.device_backend());
                let host_tasks = l.tasks_for(h.host_threads, host_needles.len());
                let lr = &l;
                let (host_res, dev_res) = std::thread::scope(|s| {
                    let hj = s.spawn(move || {
                        host_search(haystack, host_needles, side, host_tasks, seq)
                    });
                    let dj = s.spawn(|| {
                        dev_session.searchsorted(haystack, dev_needles, side, Some(lr))
                    });
                    (hj.join(), dj.join())
                });
                let mut out =
                    host_res.map_err(|_| AkError::panicked("host", "searchsorted"))?;
                out.extend(
                    dev_res.map_err(|_| AkError::panicked("device", "searchsorted"))??,
                );
                Ok(out)
            }
        }
    }

    // ---- predicates -------------------------------------------------------

    /// `any(x > threshold)` with early exit, for every sortable dtype.
    /// IEEE comparison semantics on floats (`NaN > t` is false). The
    /// device path uses the `any_gt` artifact family when one exists for
    /// the dtype, the host reducer otherwise.
    pub fn any_gt<K: DeviceKey>(
        &self,
        xs: &[K],
        threshold: K,
        launch: Option<&Launch>,
    ) -> AkResult<bool> {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native => Ok(xs.iter().any(|&x| x > threshold)),
            Backend::Threaded(t) => Ok(host_any(
                xs,
                l.tasks_for(*t, xs.len()),
                l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
                |x| x > threshold,
            )),
            Backend::Device(dev) => {
                if K::XLA && dev.registry().supports("any_gt", K::ELEM) {
                    dev.any_gt(xs, threshold).map_err(|e| AkError::device("any_gt", e))
                } else {
                    Ok(xs.iter().any(|&x| x > threshold))
                }
            }
            Backend::Hybrid(h) => crate::hybrid::co_any_gt_launch(h, xs, threshold, &l),
        }
    }

    /// `all(x > threshold)`, for every sortable dtype. IEEE semantics:
    /// a NaN element fails the predicate, so `all` is false (every
    /// engine agrees — the pre-session threaded path did not).
    pub fn all_gt<K: DeviceKey>(
        &self,
        xs: &[K],
        threshold: K,
        launch: Option<&Launch>,
    ) -> AkResult<bool> {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        match &self.backend {
            Backend::Native => Ok(xs.iter().all(|&x| x > threshold)),
            // The racing-flag reducer hunts counterexamples: an element
            // that does NOT satisfy `x > t` (IEEE: NaN is one).
            Backend::Threaded(t) => Ok(!host_any(
                xs,
                l.tasks_for(*t, xs.len()),
                l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
                |x: K| !matches!(x.partial_cmp(&threshold), Some(std::cmp::Ordering::Greater)),
            )),
            Backend::Device(dev) => {
                if K::XLA && dev.registry().supports("all_gt", K::ELEM) {
                    dev.all_gt(xs, threshold).map_err(|e| AkError::device("all_gt", e))
                } else {
                    Ok(xs.iter().all(|&x| x > threshold))
                }
            }
            Backend::Hybrid(h) => crate::hybrid::co_all_gt_launch(h, xs, threshold, &l),
        }
    }

    /// Generic `any(pred, xs)` over the session's host workers (the
    /// paper's `any(f, itr)`): arbitrary predicates cannot cross the
    /// AOT boundary, so device/hybrid sessions run their host engine.
    pub fn any_by<T: Sync + Copy, P: Fn(&T) -> bool + Sync>(
        &self,
        xs: &[T],
        pred: P,
        launch: Option<&Launch>,
    ) -> bool {
        let l = self.resolve(launch);
        self.state.metrics.record(xs.len());
        let base = match &self.backend {
            Backend::Native | Backend::Device(_) => 1,
            Backend::Threaded(t) => *t,
            Backend::Hybrid(h) => h.host_threads,
        };
        host_any(
            xs,
            l.tasks_for(base, xs.len()),
            l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
            |x| pred(&x),
        )
    }

    /// Generic `all(pred, xs)` (see [`Session::any_by`]).
    pub fn all_by<T: Sync + Copy, P: Fn(&T) -> bool + Sync>(
        &self,
        xs: &[T],
        pred: P,
        launch: Option<&Launch>,
    ) -> bool {
        !self.any_by(xs, |x| !pred(x), launch)
    }

    // ---- arithmetic kernels -----------------------------------------------

    /// RBF over packed `(3, n)` coordinates `[x.., y.., z..]` → `(n,)`
    /// (paper Algorithm 4, Table II).
    pub fn rbf(&self, pts: &[f32], launch: Option<&Launch>) -> AkResult<Vec<f32>> {
        let l = self.resolve(launch);
        if pts.len() % 3 != 0 {
            return Err(AkError::shape("rbf", format!("(3, n) layout required, got {}", pts.len())));
        }
        let n = pts.len() / 3;
        self.state.metrics.record(n);
        match &self.backend {
            Backend::Native => Ok(rbf_host(pts, n, 1)),
            Backend::Threaded(t) => Ok(rbf_host(pts, n, l.tasks_for(*t, n))),
            Backend::Device(dev) => dev.rbf_f32(pts).map_err(|e| AkError::device("rbf", e)),
            // The (3, n) packed rows cannot split contiguously between
            // two engines without a repack: hybrid runs the host pool.
            Backend::Hybrid(h) => Ok(rbf_host(pts, n, l.tasks_for(h.host_threads, n))),
        }
    }

    /// LJG potential over two packed `(3, n)` position arrays
    /// (Algorithm 5), integer powers expanded to multiplications.
    pub fn ljg(
        &self,
        p1: &[f32],
        p2: &[f32],
        c: LjgConsts,
        launch: Option<&Launch>,
    ) -> AkResult<Vec<f32>> {
        let l = self.resolve(launch);
        if p1.len() != p2.len() || p1.len() % 3 != 0 {
            return Err(AkError::shape(
                "ljg",
                format!("matched (3, n) layouts required, got {} vs {}", p1.len(), p2.len()),
            ));
        }
        let n = p1.len() / 3;
        self.state.metrics.record(n);
        match &self.backend {
            Backend::Native => Ok(ljg_host(p1, p2, n, c, 1)),
            Backend::Threaded(t) => Ok(ljg_host(p1, p2, n, c, l.tasks_for(*t, n))),
            Backend::Device(dev) => dev
                .ljg_f32(p1, p2, [c.epsilon, c.sigma, c.r0, c.cutoff])
                .map_err(|e| AkError::device("ljg", e)),
            Backend::Hybrid(h) => Ok(ljg_host(p1, p2, n, c, l.tasks_for(h.host_threads, n))),
        }
    }

    /// The naive-C LJG variant (`powf` powers — the Table II pathology).
    /// Host-only arithmetic; device sessions run the host engine.
    pub fn ljg_powf(
        &self,
        p1: &[f32],
        p2: &[f32],
        c: LjgConsts,
        launch: Option<&Launch>,
    ) -> AkResult<Vec<f32>> {
        let l = self.resolve(launch);
        if p1.len() != p2.len() || p1.len() % 3 != 0 {
            return Err(AkError::shape(
                "ljg_powf",
                format!("matched (3, n) layouts required, got {} vs {}", p1.len(), p2.len()),
            ));
        }
        let n = p1.len() / 3;
        self.state.metrics.record(n);
        let base = match &self.backend {
            Backend::Native | Backend::Device(_) => 1,
            Backend::Threaded(t) => *t,
            Backend::Hybrid(h) => h.host_threads,
        };
        Ok(ljg_powf_host(p1, p2, n, c, l.tasks_for(base, n)))
    }
}

/// Where a device-session `sortperm` call runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DeviceRoute {
    /// The single-chunk `sort_pairs` artifact serves it.
    Device,
    /// The host engine serves it; the payload says why (strict sessions
    /// turn this into a typed error, others into a metrics event).
    HostFallback(&'static str),
}

/// Pure routing decision for `Session::device` sortperm: `plan_chunks`
/// is the registry's `sort_pairs` chunking plan for this input, `None`
/// when no artifact family exists (or the dtype has none at all).
fn device_sortperm_route(xla: bool, plan_chunks: Option<usize>) -> DeviceRoute {
    if !xla {
        return DeviceRoute::HostFallback(
            "no XLA artifact family for this dtype (sortperm runs on the host engine)",
        );
    }
    match plan_chunks {
        Some(1) => DeviceRoute::Device,
        Some(_) => DeviceRoute::HostFallback(
            "sort_pairs plan needs multiple chunks: the chunked pair path is not \
             dispatched on the device (ROADMAP deferred item) — use a host session \
             or a size class that fits one chunk",
        ),
        None => DeviceRoute::HostFallback(
            "no sort_pairs artifact for this dtype/size class",
        ),
    }
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Session({})", self.backend.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn scratch_pool_hits_after_first_call() {
        let s = Session::threaded(4);
        let l = Launch::new().reuse_scratch(true).prefer_parallel_threshold(64);
        for _ in 0..3 {
            let mut xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 20_000);
            s.sort(&mut xs, Some(&l)).unwrap();
            assert!(crate::dtype::is_sorted_total(&xs));
        }
        assert!(s.metrics().scratch_hits() >= 2, "hits {}", s.metrics().scratch_hits());
        assert_eq!(s.metrics().scratch_misses(), 1);
        assert_eq!(s.metrics().calls(), 3);
    }

    #[test]
    fn scratch_pool_reuses_across_same_layout_dtypes() {
        // The pool is keyed by (size, align), not TypeId: an f32 sort's
        // merge scratch must be adopted by a following i32 sort (same
        // 4-byte layout) instead of allocating a parallel buffer.
        let s = Session::threaded(4);
        let l = Launch::new().reuse_scratch(true).prefer_parallel_threshold(64);
        let mut f: Vec<f32> = generate(&mut Prng::new(7), Distribution::Uniform, 20_000);
        s.sort(&mut f, Some(&l)).unwrap();
        assert_eq!(s.metrics().scratch_misses(), 1);
        let mut u: Vec<i32> = generate(&mut Prng::new(8), Distribution::Uniform, 20_000);
        s.sort(&mut u, Some(&l)).unwrap();
        assert!(crate::dtype::is_sorted_total(&f) && crate::dtype::is_sorted_total(&u));
        assert_eq!(
            s.metrics().scratch_misses(),
            1,
            "i32 after f32 must reuse the same-layout buffer, not allocate"
        );
        assert_eq!(s.metrics().scratch_hits(), 1);
        // A wider dtype is a different layout class: new allocation.
        let mut d: Vec<f64> = generate(&mut Prng::new(9), Distribution::Uniform, 20_000);
        s.sort(&mut d, Some(&l)).unwrap();
        assert_eq!(s.metrics().scratch_misses(), 2);
    }

    #[test]
    fn clones_share_the_metrics_sink() {
        let s = Session::native();
        let c = s.clone();
        let mut xs = vec![3i32, 1, 2];
        c.sort(&mut xs, None).unwrap();
        assert_eq!(s.metrics().calls(), 1);
        assert_eq!(s.metrics().elems(), 3);
    }

    #[test]
    fn metrics_snapshot_covers_the_session_registry() {
        let s = Session::native();
        let mut xs = vec![3i32, 1, 2];
        s.sort(&mut xs, None).unwrap();
        let snap = s.metrics().snapshot();
        assert_eq!(snap.names(), crate::obs::SESSION_COUNTERS.to_vec());
        assert_eq!(snap.get("calls"), 1);
        assert_eq!(snap.get("elems"), 3);
    }

    #[test]
    fn device_sortperm_route_is_explicit_about_fallbacks() {
        // Single-chunk pair plans run on the device; everything else is
        // an explicit host fallback (typed error under strict_device, a
        // `device_fallbacks` metrics event otherwise) — never silent.
        assert_eq!(device_sortperm_route(true, Some(1)), DeviceRoute::Device);
        assert!(matches!(
            device_sortperm_route(true, Some(4)),
            DeviceRoute::HostFallback(why) if why.contains("multiple chunks")
        ));
        assert!(matches!(device_sortperm_route(true, None), DeviceRoute::HostFallback(_)));
        assert!(matches!(device_sortperm_route(false, None), DeviceRoute::HostFallback(_)));
    }

    #[test]
    fn global_session_sorts() {
        let mut xs = vec![5i32, -2, 9];
        Session::global().sort(&mut xs, None).unwrap();
        assert_eq!(xs, vec![-2, 5, 9]);
    }

    #[test]
    fn shape_errors_are_typed() {
        let s = Session::native();
        let mut k = vec![1i32, 2, 3];
        let mut v = vec![0u8; 2];
        match s.sort_by_key(&mut k, &mut v, None) {
            Err(AkError::ShapeMismatch { op, .. }) => assert_eq!(op, "sort_by_key"),
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        assert!(matches!(s.rbf(&[1.0, 2.0], None), Err(AkError::ShapeMismatch { .. })));
    }

    #[test]
    fn defaults_merge_under_per_call_launch() {
        let s = Session::threaded(8).with_defaults(Launch::new().max_tasks(2));
        // Session policy caps to 2; per-call override raises within the
        // backend width.
        assert_eq!(s.resolve(None).tasks_for(8, 1 << 20), 2);
        let l = Launch::new().max_tasks(4);
        assert_eq!(s.resolve(Some(&l)).tasks_for(8, 1 << 20), 4);
    }
}
