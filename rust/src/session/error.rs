//! The typed error surface of the [`super::Session`] API.
//!
//! Every algorithm method returns [`AkResult`]: callers can match on the
//! failure class (dtype gap, backend gap, device outage, shape bug)
//! instead of string-matching an `anyhow` chain. The deprecated free
//! functions in [`crate::algorithms`] convert these back into
//! `anyhow::Error` so pre-session code keeps compiling unchanged.

use crate::dtype::ElemType;

/// Result alias of the [`super::Session`] API.
pub type AkResult<T> = Result<T, AkError>;

/// Why a [`super::Session`] call could not run.
#[derive(Debug)]
pub enum AkError {
    /// The element type has no implementation on the selected engine
    /// (e.g. `i128` on the device backend: XLA has no `s128` —
    /// DESIGN.md §2).
    UnsupportedDtype {
        /// The element type of the call.
        dtype: ElemType,
        /// The algorithm that was invoked.
        op: &'static str,
        /// Why this dtype cannot run here.
        detail: &'static str,
    },
    /// The algorithm variant cannot run on the selected backend at all
    /// (e.g. `sortperm_lowmem` on the device: the pair-free argsort
    /// cannot cross the AOT boundary). Distinct from a dtype gap: no
    /// dtype would make this combination work.
    UnsupportedBackend {
        /// Engine name (`Backend::name`).
        backend: String,
        /// The algorithm that was invoked.
        op: &'static str,
        /// Why this backend cannot serve the call.
        detail: &'static str,
    },
    /// A device engine was required but could not serve the call
    /// (artifact missing, PJRT unavailable, execution failure).
    DeviceUnavailable {
        /// The algorithm that was invoked.
        op: &'static str,
        /// The underlying runtime/registry failure chain.
        detail: String,
    },
    /// Input lengths or layouts disagree (key/value length mismatch,
    /// ragged `(3, n)` packing, index space overflow).
    ShapeMismatch {
        /// The algorithm that was invoked.
        op: &'static str,
        /// What disagreed.
        detail: String,
    },
    /// A fabric operation exceeded its deadline or lost a message on a
    /// faulted link. Retryable: the sender-side backoff in
    /// [`crate::comm::RetryPolicy`] re-attempts exactly this class
    /// (DESIGN.md §16 — the simulated transport is acked, so drops and
    /// partitions surface at the *sender* as timeouts).
    CommTimeout {
        /// The fabric operation ("send", "recv", "barrier", "watchdog").
        op: &'static str,
        /// The rank whose operation timed out.
        rank: usize,
        /// The peer of a point-to-point op, if any.
        peer: Option<usize>,
        /// How long the op waited before giving up (wall seconds).
        waited_secs: f64,
        /// What was being waited for (tag, credit, diagnostics table).
        detail: String,
    },
    /// A rank died: a fault-injected kill, a peer endpoint dropped
    /// mid-collective, or the coordinated abort that follows either.
    /// Tagged with the abort epoch (the driver's restart-attempt index)
    /// so stale aborts from a previous attempt are attributable.
    RankDead {
        /// The rank that died (or was blamed by the watchdog).
        rank: usize,
        /// The coordinated-abort epoch the death was observed in.
        epoch: u64,
    },
    /// The happens-before detector ([`crate::comm::CommTuning::hb_check`])
    /// closed a wait-for cycle: every rank in `cycle` is parked on an
    /// event only another parked rank can produce. Unlike
    /// [`AkError::CommTimeout`] this is a deterministic diagnosis of a
    /// protocol bug, made the moment the cycle forms — it is never
    /// retried or recovered.
    Deadlock {
        /// The rank whose wait registration closed the cycle.
        rank: usize,
        /// The canonical cycle rendering: each hop's wait kind, link,
        /// held credit, tag, and the waiter's phase note.
        cycle: String,
    },
    /// Engine-internal failure: a worker panicked or an invariant the
    /// engines rely on was violated.
    Internal(anyhow::Error),
}

impl AkError {
    /// Shorthand for the dtype-gap variant.
    pub(crate) fn unsupported_dtype(
        dtype: ElemType,
        op: &'static str,
        detail: &'static str,
    ) -> AkError {
        AkError::UnsupportedDtype { dtype, op, detail }
    }

    /// Shorthand for the backend-gap variant.
    pub(crate) fn unsupported_backend(
        backend: &crate::backend::Backend,
        op: &'static str,
        detail: &'static str,
    ) -> AkError {
        AkError::UnsupportedBackend { backend: backend.name(), op, detail }
    }

    /// Wrap a device runtime/registry failure.
    pub(crate) fn device(op: &'static str, err: anyhow::Error) -> AkError {
        AkError::DeviceUnavailable { op, detail: format!("{err:#}") }
    }

    /// Shorthand for the shape-mismatch variant.
    pub(crate) fn shape(op: &'static str, detail: String) -> AkError {
        AkError::ShapeMismatch { op, detail }
    }

    /// Wrap a worker panic observed at a join point.
    pub(crate) fn panicked(who: &str, op: &str) -> AkError {
        AkError::Internal(anyhow::anyhow!("{who} worker panicked during {op}"))
    }
}

impl std::fmt::Display for AkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AkError::UnsupportedDtype { dtype, op, detail } => {
                write!(f, "{op}: dtype {dtype} unsupported on this engine ({detail})")
            }
            AkError::UnsupportedBackend { backend, op, detail } => {
                write!(f, "{op}: backend {backend} cannot serve this call ({detail})")
            }
            AkError::DeviceUnavailable { op, detail } => {
                write!(f, "{op}: device engine unavailable: {detail}")
            }
            AkError::ShapeMismatch { op, detail } => write!(f, "{op}: shape mismatch: {detail}"),
            AkError::CommTimeout { op, rank, peer, waited_secs, detail } => match peer {
                Some(p) => write!(
                    f,
                    "comm {op} timed out on rank {rank} (peer {p}) after {waited_secs:.3}s: {detail}"
                ),
                None => write!(
                    f,
                    "comm {op} timed out on rank {rank} after {waited_secs:.3}s: {detail}"
                ),
            },
            AkError::RankDead { rank, epoch } => {
                write!(f, "rank {rank} died (abort epoch {epoch})")
            }
            AkError::Deadlock { rank, cycle } => {
                write!(f, "deadlock detected at rank {rank}: {cycle}")
            }
            AkError::Internal(e) => write!(f, "internal error: {e}"),
        }
    }
}

impl std::error::Error for AkError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        // Keep the wrapped chain walkable: callers downcast through an
        // `Internal` (the crash/resume tests find an injected
        // `FailpointAbort` this way — `failpoint::is_abort`).
        match self {
            AkError::Internal(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

impl From<anyhow::Error> for AkError {
    fn from(e: anyhow::Error) -> AkError {
        AkError::Internal(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure_class() {
        let e = AkError::unsupported_dtype(ElemType::I128, "sort", "no XLA s128");
        assert!(e.to_string().contains("i128"));
        assert!(e.to_string().contains("sort"));
        let e = AkError::shape("sort_by_key", "keys 3 vs vals 4".into());
        assert!(e.to_string().contains("shape mismatch"));
    }

    #[test]
    fn converts_into_anyhow_for_the_shims() {
        fn old_style() -> anyhow::Result<()> {
            Err(AkError::shape("rbf", "(3, n) layout required".into()).into())
        }
        let msg = format!("{:#}", old_style().unwrap_err());
        assert!(msg.contains("rbf"), "{msg}");
    }

    #[test]
    fn comm_errors_name_rank_and_peer() {
        let e = AkError::CommTimeout {
            op: "recv",
            rank: 2,
            peer: Some(5),
            waited_secs: 1.5,
            detail: "tag 7".into(),
        };
        let s = e.to_string();
        assert!(s.contains("rank 2") && s.contains("peer 5") && s.contains("tag 7"), "{s}");
        let e = AkError::RankDead { rank: 3, epoch: 1 };
        let s = e.to_string();
        assert!(s.contains("rank 3") && s.contains("epoch 1"), "{s}");
        // Both stay downcastable through an anyhow hop — the driver's
        // recovery loop classifies rank failures this way.
        let back: anyhow::Error = AkError::RankDead { rank: 3, epoch: 1 }.into();
        assert!(back
            .chain()
            .any(|c| matches!(c.downcast_ref::<AkError>(), Some(AkError::RankDead { rank: 3, .. }))));
    }

    #[test]
    fn deadlock_display_names_rank_and_cycle() {
        let e = AkError::Deadlock {
            rank: 1,
            cycle: "wait-for cycle: rank 0 [phase=exchange] \
                    --send-credit(link 0->1, in-flight 4096/4096 bytes, tag 0x8)--> rank 1; \
                    rank 1 [phase=exchange] --recv(src 0, tag 0x3e7)--> rank 0"
                .into(),
        };
        let s = e.to_string();
        assert!(s.contains("deadlock detected at rank 1"), "{s}");
        assert!(s.contains("send-credit") && s.contains("recv"), "{s}");
    }

    #[test]
    fn internal_keeps_the_cause_chain_walkable() {
        // anyhow -> AkError::Internal -> anyhow must still expose the
        // root cause via chain() (the fault harness downcasts this way).
        #[derive(Debug)]
        struct Root;
        impl std::fmt::Display for Root {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "root cause")
            }
        }
        impl std::error::Error for Root {}
        let ak: AkError = anyhow::Error::new(Root).context("mid layer").into();
        let back: anyhow::Error = ak.into();
        assert!(back.chain().any(|c| c.is::<Root>()), "{back:#}");
    }
}
