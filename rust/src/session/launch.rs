//! Per-call launch configuration — the paper's tuning keywords
//! (`block_size`, `max_tasks`, `min_elems` — §III) as a builder.
//!
//! A [`Launch`] is pure data: every field is an `Option` whose `None`
//! means "use the session's default policy, then the engine's built-in
//! constant". Resolution is per engine — the thread-chunk gate, the
//! merge-path gate, the radix gate and the hybrid co-split gate each
//! have their own historical default (see the knob→engine table in
//! DESIGN.md §12), and one `prefer_parallel_threshold` override applies
//! to whichever gate the call reaches.

/// Default input size below which host engines stay sequential — the
/// constant previously hard-coded per algorithm (sort chunk gate, scan,
/// predicates, search, sortperm).
pub const DEFAULT_PAR_THRESHOLD: usize = 4096;

/// Per-call tuning knobs (paper §III keywords). Build with the fluent
/// setters and pass `Some(&launch)` to any [`super::Session`] method;
/// `None` uses the session's default policy.
///
/// ```
/// use accelkern::session::Launch;
/// let l = Launch::new().max_tasks(4).min_elems_per_task(64 * 1024);
/// assert_eq!(l.tasks_for(10, 1 << 20), 4);      // capped by max_tasks
/// assert_eq!(l.tasks_for(10, 100_000), 1);      // too little work per task
/// ```
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Launch {
    /// Device chunk granule (elements): caps the artifact size class one
    /// device call covers, chunking + host-recombining above it.
    pub block_size: Option<usize>,
    /// Upper bound on host worker tasks for this call (caps the
    /// backend's thread count, never raises it).
    pub max_tasks: Option<usize>,
    /// Minimum elements each host task must own: fewer tasks are spawned
    /// when the input cannot feed them all.
    pub min_elems_per_task: Option<usize>,
    /// Input size below which the call prefers its sequential engine.
    /// Overrides every parallel gate the call reaches: the per-algorithm
    /// chunk gates ([`DEFAULT_PAR_THRESHOLD`]), the merge-path gate
    /// (`baselines::merge_path::PAR_MERGE_MIN`), the radix gate
    /// (`baselines::radix::RADIX_PAR_MIN`) and the hybrid co-split gate
    /// (`hybrid::MIN_COSPLIT`).
    pub prefer_parallel_threshold: Option<usize>,
    /// `reduce` only: inputs at or below this size finish the fold on
    /// the host from device partials (the paper's device-sync-masking
    /// rule, §II-B).
    pub switch_below: Option<usize>,
    /// Borrow temporary buffers (merge scratch, sortperm pair buffers)
    /// from the session's scratch pool instead of allocating per call.
    /// Tri-state so a per-call `false` can override a session default of
    /// `true` ([`Launch::merged_over`]); `None` means "session policy,
    /// else off" — read it through [`Launch::reuse_scratch_on`].
    pub reuse_scratch: Option<bool>,
    /// Device sessions: fail with a typed
    /// [`super::AkError::UnsupportedBackend`] instead of silently
    /// running the host engine when the device cannot serve a call
    /// (no artifact for the dtype/size class, multi-chunk `sort_pairs`
    /// plan). Off (`None`/`false`), the fallback still happens but is
    /// recorded in [`super::SessionMetrics::device_fallbacks`]. Same
    /// tri-state rules as `reuse_scratch`.
    pub strict_device: Option<bool>,
}

impl Launch {
    /// An all-defaults launch (identical to `Launch::default()`).
    pub fn new() -> Launch {
        Launch::default()
    }

    /// Set the device chunk granule (elements).
    pub fn block_size(mut self, elems: usize) -> Launch {
        self.block_size = Some(elems.max(1));
        self
    }

    /// Cap the host worker tasks for this call.
    pub fn max_tasks(mut self, tasks: usize) -> Launch {
        self.max_tasks = Some(tasks.max(1));
        self
    }

    /// Require at least this many elements per host task.
    pub fn min_elems_per_task(mut self, elems: usize) -> Launch {
        self.min_elems_per_task = Some(elems.max(1));
        self
    }

    /// Stay sequential below this input size (overrides every engine
    /// gate — see the field docs).
    pub fn prefer_parallel_threshold(mut self, elems: usize) -> Launch {
        self.prefer_parallel_threshold = Some(elems);
        self
    }

    /// `reduce`: host-finish the fold at or below this input size.
    pub fn switch_below(mut self, elems: usize) -> Launch {
        self.switch_below = Some(elems);
        self
    }

    /// Borrow temporaries from the session scratch pool (or, with
    /// `false`, explicitly opt a call out of a session-default `true`).
    pub fn reuse_scratch(mut self, on: bool) -> Launch {
        self.reuse_scratch = Some(on);
        self
    }

    /// Resolved scratch-pool flag (`None` means off).
    pub fn reuse_scratch_on(&self) -> bool {
        self.reuse_scratch.unwrap_or(false)
    }

    /// Error (typed) instead of host-falling-back when the device
    /// cannot serve a call (see the field docs).
    pub fn strict_device(mut self, on: bool) -> Launch {
        self.strict_device = Some(on);
        self
    }

    /// Resolved strict-device flag (`None` means off: fall back and
    /// record a [`super::SessionMetrics::device_fallbacks`] event).
    pub fn strict_device_on(&self) -> bool {
        self.strict_device.unwrap_or(false)
    }

    /// Worker count for a host engine call over `n` elements, given the
    /// backend's base thread width: `base` capped by `max_tasks`, then by
    /// `n / min_elems_per_task` (always at least 1).
    pub fn tasks_for(&self, base: usize, n: usize) -> usize {
        let mut t = base.max(1);
        if let Some(cap) = self.max_tasks {
            t = t.min(cap.max(1));
        }
        if let Some(me) = self.min_elems_per_task {
            t = t.min((n / me.max(1)).max(1));
        }
        t
    }

    /// The sequential-engine gate: the override if set, else the calling
    /// engine's built-in default.
    pub fn par_threshold_or(&self, engine_default: usize) -> usize {
        self.prefer_parallel_threshold.unwrap_or(engine_default)
    }

    /// The reduce host-finish gate: the override if set, else `default`.
    pub fn switch_below_or(&self, default: usize) -> usize {
        self.switch_below.unwrap_or(default)
    }

    /// Overlay: fields set on `self` win, unset fields fall back to
    /// `base` (how a per-call launch composes with the session policy).
    pub fn merged_over(&self, base: &Launch) -> Launch {
        Launch {
            block_size: self.block_size.or(base.block_size),
            max_tasks: self.max_tasks.or(base.max_tasks),
            min_elems_per_task: self.min_elems_per_task.or(base.min_elems_per_task),
            prefer_parallel_threshold: self
                .prefer_parallel_threshold
                .or(base.prefer_parallel_threshold),
            switch_below: self.switch_below.or(base.switch_below),
            reuse_scratch: self.reuse_scratch.or(base.reuse_scratch),
            strict_device: self.strict_device.or(base.strict_device),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_capping_rules() {
        let l = Launch::new();
        assert_eq!(l.tasks_for(8, 1 << 20), 8); // defaults: backend width
        assert_eq!(l.tasks_for(0, 10), 1); // degenerate base
        let l = Launch::new().max_tasks(3);
        assert_eq!(l.tasks_for(8, 1 << 20), 3);
        assert_eq!(l.tasks_for(2, 1 << 20), 2); // never raises
        let l = Launch::new().min_elems_per_task(1000);
        assert_eq!(l.tasks_for(8, 2500), 2);
        assert_eq!(l.tasks_for(8, 999), 1);
    }

    #[test]
    fn threshold_fallbacks() {
        assert_eq!(Launch::new().par_threshold_or(4096), 4096);
        assert_eq!(Launch::new().prefer_parallel_threshold(64).par_threshold_or(4096), 64);
        assert_eq!(Launch::new().switch_below_or(0), 0);
        assert_eq!(Launch::new().switch_below(100).switch_below_or(0), 100);
    }

    #[test]
    fn merge_overlay_prefers_call_over_policy() {
        let policy = Launch::new().max_tasks(2).switch_below(7);
        let call = Launch::new().max_tasks(5);
        let m = call.merged_over(&policy);
        assert_eq!(m.max_tasks, Some(5));
        assert_eq!(m.switch_below, Some(7));
        assert!(!m.reuse_scratch_on());
        let m = Launch::new().reuse_scratch(true).merged_over(&policy);
        assert!(m.reuse_scratch_on());
        // A per-call `false` overrides a session default of `true`.
        let pool_on = Launch::new().reuse_scratch(true);
        let m = Launch::new().reuse_scratch(false).merged_over(&pool_on);
        assert!(!m.reuse_scratch_on());
        // And an unset call inherits the policy.
        assert!(Launch::new().merged_over(&pool_on).reuse_scratch_on());
    }

    #[test]
    fn strict_device_merges_like_the_other_tristates() {
        assert!(!Launch::new().strict_device_on());
        assert!(Launch::new().strict_device(true).strict_device_on());
        let policy = Launch::new().strict_device(true);
        assert!(Launch::new().merged_over(&policy).strict_device_on());
        assert!(!Launch::new().strict_device(false).merged_over(&policy).strict_device_on());
    }
}
