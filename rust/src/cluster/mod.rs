//! Simulated HPC cluster — the substitution for the Baskerville testbed
//! (DESIGN.md §2).
//!
//! Ranks are OS threads carrying *logical clocks*: real data is really
//! processed and really exchanged between threads, but reported times are
//! simulated — compute from measured wall time through a calibrated
//! device model, communication from an α-β (latency + bytes/bandwidth)
//! link model with Baskerville-like parameters. This is what makes
//! 200-rank scaling curves measurable on a 1-core box without faking the
//! algorithm: message counts, byte volumes and the sort itself are real.

pub mod clock;
pub mod devmodel;
pub mod topology;

pub use clock::SimClocks;
pub use devmodel::DeviceModel;
pub use topology::{ClusterSpec, LinkKind};
