//! Device model: converts *measured* host compute time into *simulated*
//! accelerator time.
//!
//! We do not have A100s; we have XLA-CPU and native Rust on one core. The
//! model applies a single calibration factor `gpu_speedup` to device-rank
//! compute (CPU ranks are reported 1:1). Crucially the factor is shared
//! by all device sorters (AK / TM / TR), so *relative* results — who wins
//! on which dtype, merge vs radix crossovers, NVLink vs staged — come
//! from real measured work, not from the model. Only the absolute scale
//! is synthetic, and it is reported as such in EXPERIMENTS.md.
//!
//! Default calibration: an A100-40 sorts ~30 GB/s locally (CUB/Thrust
//! radix on 32-bit keys, literature figure); this reference core's radix
//! manages ~0.17 GB/s — ratio ≈ 200 (ClusterSpec::baskerville carries the
//! authoritative value; this Default mirrors it).

/// Compute-time scaling for simulated device ranks.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// measured host seconds are divided by this for device ranks.
    pub gpu_speedup: f64,
}

impl DeviceModel {
    pub fn new(gpu_speedup: f64) -> Self {
        assert!(gpu_speedup > 0.0);
        Self { gpu_speedup }
    }

    /// Simulated compute seconds for a rank.
    pub fn compute_time(&self, measured_secs: f64, is_device: bool) -> f64 {
        if is_device {
            measured_secs / self.gpu_speedup
        } else {
            measured_secs
        }
    }

    /// Throughput the modelled accelerator reaches on work this host
    /// executes at `host_throughput` (same unit out as in). The hybrid
    /// planner feeds this to `cost::hybrid_host_fraction` when no real
    /// device measurement is available (DESIGN.md §10).
    pub fn device_throughput(&self, host_throughput: f64) -> f64 {
        host_throughput * self.gpu_speedup
    }

    /// Roofline estimate used in DESIGN.md §7: given bytes touched and a
    /// device HBM bandwidth, the bandwidth-bound floor for an elementwise
    /// kernel (all L1 kernels here are VPU/bandwidth bound — no matmul).
    pub fn roofline_floor_secs(bytes: f64, hbm_gbps: f64) -> f64 {
        bytes / (hbm_gbps * 1e9)
    }
}

impl Default for DeviceModel {
    fn default() -> Self {
        Self { gpu_speedup: 200.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_device_only() {
        let m = DeviceModel::new(50.0);
        assert_eq!(m.compute_time(1.0, true), 0.02);
        assert_eq!(m.compute_time(1.0, false), 1.0);
    }

    #[test]
    fn roofline() {
        // 32 GB at 1555 GB/s (A100-40 HBM) ≈ 20.6 ms
        let t = DeviceModel::roofline_floor_secs(32e9, 1555.0);
        assert!((t - 0.02058).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive() {
        DeviceModel::new(0.0);
    }

    #[test]
    fn device_throughput_scales_with_speedup() {
        assert_eq!(DeviceModel::new(50.0).device_throughput(2.0), 100.0);
        assert_eq!(DeviceModel::new(1.0).device_throughput(2.0), 2.0);
    }
}
