//! Per-rank logical clocks for the discrete-event cluster simulation.
//!
//! Each rank thread advances its own clock for compute (measured wall
//! time through the device model) and communication (link cost model).
//! Cross-rank synchronisation uses monotone max-merges: receiving a
//! message pulls the receiver's clock up to the message's arrival time,
//! and a barrier pulls everyone up to the global max — the standard
//! conservative PDES rule, which makes simulated times deterministic
//! given deterministic per-rank sequences.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared array of per-rank simulated clocks (seconds, stored as f64 bits).
#[derive(Debug)]
pub struct SimClocks {
    times: Vec<AtomicU64>,
}

impl SimClocks {
    pub fn new(ranks: usize) -> Self {
        Self { times: (0..ranks).map(|_| AtomicU64::new(0f64.to_bits())).collect() }
    }

    pub fn ranks(&self) -> usize {
        self.times.len()
    }

    /// Current simulated time of a rank.
    pub fn get(&self, rank: usize) -> f64 {
        f64::from_bits(self.times[rank].load(Ordering::SeqCst))
    }

    /// Advance a rank's clock by `dt` seconds (dt >= 0).
    pub fn advance(&self, rank: usize, dt: f64) -> f64 {
        debug_assert!(dt >= 0.0, "negative advance {dt}");
        let new = self.get(rank) + dt;
        self.times[rank].store(new.to_bits(), Ordering::SeqCst);
        new
    }

    /// Monotone max-merge: lift `rank`'s clock to at least `t`.
    pub fn merge_at_least(&self, rank: usize, t: f64) -> f64 {
        let cur = self.get(rank);
        let new = cur.max(t);
        self.times[rank].store(new.to_bits(), Ordering::SeqCst);
        new
    }

    /// Global maximum across all ranks (barrier time).
    pub fn global_max(&self) -> f64 {
        (0..self.times.len()).map(|r| self.get(r)).fold(0.0, f64::max)
    }

    /// Set every rank's clock to the global max (barrier semantics).
    /// Caller must ensure all rank threads are actually parked at the
    /// barrier (comm::Endpoint::barrier does).
    pub fn barrier_sync(&self) -> f64 {
        let t = self.global_max();
        for c in &self.times {
            c.store(t.to_bits(), Ordering::SeqCst);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_merge() {
        let c = SimClocks::new(3);
        assert_eq!(c.get(0), 0.0);
        c.advance(0, 1.5);
        c.advance(1, 0.5);
        assert_eq!(c.get(0), 1.5);
        c.merge_at_least(1, 1.0);
        assert_eq!(c.get(1), 1.0);
        c.merge_at_least(1, 0.2); // no regression
        assert_eq!(c.get(1), 1.0);
        assert_eq!(c.global_max(), 1.5);
    }

    #[test]
    fn barrier_lifts_everyone() {
        let c = SimClocks::new(4);
        c.advance(2, 7.0);
        let t = c.barrier_sync();
        assert_eq!(t, 7.0);
        for r in 0..4 {
            assert_eq!(c.get(r), 7.0);
        }
    }
}
