//! Cluster topology + link cost model.
//!
//! Baskerville (paper §IV-B): 52 SD650-N V2 trays × (2× Xeon 8360Y,
//! 512 GB RAM, 4× A100-40 on an HGX planar with an NVLink mesh), nodes
//! connected by Mellanox InfiniBand. The paper's two communication modes:
//! "NVLink Transfer" = direct GPU↔GPU (GPUDirect, intra-node NVLink or
//! inter-node GPUDirect-RDMA over IB) vs "CPU Transfer" = staged through
//! host RAM with a device↔host copy on each side.

use anyhow::Context;

use crate::cfg::{Toml, TransferMode};

/// Physical link classes in the simulated fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Intra-node GPU↔GPU NVLink mesh.
    NvLink,
    /// Inter-node InfiniBand (GPUDirect-RDMA capable).
    Infiniband,
    /// PCIe device↔host copy.
    PcieD2H,
    /// Host-RAM to host-RAM (intra-node staging / CPU ranks).
    HostMem,
}

/// Cluster shape + link parameters (all bandwidths in GB/s = 1e9 B/s,
/// latencies in seconds).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub name: String,
    pub gpus_per_node: usize,
    /// NVLink per-GPU-pair effective bandwidth.
    pub nvlink_gbps: f64,
    pub nvlink_lat: f64,
    /// Inter-node InfiniBand per-rank effective bandwidth.
    pub ib_gbps: f64,
    pub ib_lat: f64,
    /// PCIe device<->host copy bandwidth.
    pub pcie_gbps: f64,
    pub pcie_lat: f64,
    /// Host memcpy bandwidth (staging buffer hop).
    pub hostmem_gbps: f64,
    pub hostmem_lat: f64,
    /// Device-model calibration: how much faster the simulated accelerator
    /// runs compute than this host CPU core (see `devmodel`).
    pub gpu_speedup: f64,
    /// GPU-to-CPU combined capital/running/environmental cost ratio
    /// (paper Fig 5 uses 22, validated by the Birmingham ARC team).
    pub cost_ratio: f64,
}

impl ClusterSpec {
    /// Baskerville-like defaults. Bandwidths are effective (not peak):
    /// NVLink3 ~300 GB/s per pair, HDR-200 IB ~25 GB/s, PCIe4 x16
    /// ~25 GB/s, host memcpy ~50 GB/s. `gpu_speedup = 200` calibrates the
    /// device model so the simulated vendor radix sorts i32 at A100-class
    /// ~30 GB/s (measured host radix: ~170 MB/s on the reference core) —
    /// see EXPERIMENTS.md §Calibration.
    pub fn baskerville() -> Self {
        Self {
            name: "baskerville-sim".to_string(),
            gpus_per_node: 4,
            nvlink_gbps: 300.0,
            nvlink_lat: 2.0e-6,
            ib_gbps: 25.0,
            ib_lat: 5.0e-6,
            pcie_gbps: 25.0,
            pcie_lat: 10.0e-6,
            hostmem_gbps: 50.0,
            hostmem_lat: 1.0e-6,
            gpu_speedup: 200.0,
            cost_ratio: 22.0,
        }
    }

    /// Apply the `[cluster]` section of a config file.
    pub fn apply_toml(&mut self, doc: &Toml) -> anyhow::Result<()> {
        let sec = "cluster";
        let set_f = |key: &str, slot: &mut f64| -> anyhow::Result<()> {
            if let Some(v) = doc.get(sec, key) {
                *slot = v.as_f64().with_context(|| format!("cluster.{key}: expected number"))?;
            }
            Ok(())
        };
        set_f("nvlink_gbps", &mut self.nvlink_gbps)?;
        set_f("nvlink_lat", &mut self.nvlink_lat)?;
        set_f("ib_gbps", &mut self.ib_gbps)?;
        set_f("ib_lat", &mut self.ib_lat)?;
        set_f("pcie_gbps", &mut self.pcie_gbps)?;
        set_f("pcie_lat", &mut self.pcie_lat)?;
        set_f("hostmem_gbps", &mut self.hostmem_gbps)?;
        set_f("hostmem_lat", &mut self.hostmem_lat)?;
        set_f("gpu_speedup", &mut self.gpu_speedup)?;
        set_f("cost_ratio", &mut self.cost_ratio)?;
        if let Some(v) = doc.get(sec, "gpus_per_node") {
            self.gpus_per_node =
                v.as_i64().context("cluster.gpus_per_node: expected int")? as usize;
        }
        Ok(())
    }

    /// Node index hosting a rank (4 GPUs per tray on Baskerville).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    fn link(&self, kind: LinkKind) -> (f64, f64) {
        match kind {
            LinkKind::NvLink => (self.nvlink_gbps, self.nvlink_lat),
            LinkKind::Infiniband => (self.ib_gbps, self.ib_lat),
            LinkKind::PcieD2H => (self.pcie_gbps, self.pcie_lat),
            LinkKind::HostMem => (self.hostmem_gbps, self.hostmem_lat),
        }
    }

    /// α-β time of one hop.
    pub fn hop_time(&self, kind: LinkKind, bytes: usize) -> f64 {
        let (gbps, lat) = self.link(kind);
        lat + bytes as f64 / (gbps * 1e9)
    }

    /// The hop sequence of one point-to-point message, rank `src` → `dst`.
    ///
    /// * device ranks + `GpuDirect`: NVLink (same node) or GPUDirect-RDMA
    ///   over IB (cross node) — one hop, no host staging.
    /// * device ranks + `CpuStaged`: PCIe d2h, host/IB hop, PCIe h2d —
    ///   the paper's "CPU Transfer" with its device-to-host copies.
    /// * CPU ranks (is_device = false): host path only.
    pub fn hops(
        &self,
        src: usize,
        dst: usize,
        mode: TransferMode,
        is_device: bool,
    ) -> Vec<LinkKind> {
        let same = self.same_node(src, dst);
        if !is_device {
            return if same {
                vec![LinkKind::HostMem]
            } else {
                vec![LinkKind::Infiniband]
            };
        }
        match mode {
            TransferMode::GpuDirect => {
                if same {
                    vec![LinkKind::NvLink]
                } else {
                    vec![LinkKind::Infiniband]
                }
            }
            TransferMode::CpuStaged => {
                let mid = if same { LinkKind::HostMem } else { LinkKind::Infiniband };
                vec![LinkKind::PcieD2H, mid, LinkKind::PcieD2H]
            }
        }
    }

    /// Total simulated transfer time of one message.
    pub fn transfer_time(
        &self,
        src: usize,
        dst: usize,
        bytes: usize,
        mode: TransferMode,
        is_device: bool,
    ) -> f64 {
        self.hops(src, dst, mode, is_device)
            .into_iter()
            .map(|k| self.hop_time(k, bytes))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_placement() {
        let s = ClusterSpec::baskerville();
        assert_eq!(s.node_of(0), 0);
        assert_eq!(s.node_of(3), 0);
        assert_eq!(s.node_of(4), 1);
        assert!(s.same_node(0, 3));
        assert!(!s.same_node(3, 4));
    }

    #[test]
    fn nvlink_beats_staged_intra_node() {
        let s = ClusterSpec::baskerville();
        let direct = s.transfer_time(0, 1, 100 << 20, TransferMode::GpuDirect, true);
        let staged = s.transfer_time(0, 1, 100 << 20, TransferMode::CpuStaged, true);
        assert!(staged > 3.0 * direct, "staged {staged} direct {direct}");
    }

    #[test]
    fn cross_node_gap_narrows() {
        // Across nodes both modes pay IB; staged still adds 2 PCIe hops.
        let s = ClusterSpec::baskerville();
        let direct = s.transfer_time(0, 4, 100 << 20, TransferMode::GpuDirect, true);
        let staged = s.transfer_time(0, 4, 100 << 20, TransferMode::CpuStaged, true);
        assert!(staged > direct);
        assert!(staged < 4.0 * direct, "staged {staged} direct {direct}");
    }

    #[test]
    fn cpu_ranks_ignore_mode() {
        let s = ClusterSpec::baskerville();
        let a = s.transfer_time(0, 4, 1 << 20, TransferMode::GpuDirect, false);
        let b = s.transfer_time(0, 4, 1 << 20, TransferMode::CpuStaged, false);
        assert_eq!(a, b);
    }

    #[test]
    fn alpha_beta_monotone() {
        let s = ClusterSpec::baskerville();
        assert!(s.hop_time(LinkKind::NvLink, 0) > 0.0); // latency floor
        assert!(s.hop_time(LinkKind::NvLink, 1 << 30) > s.hop_time(LinkKind::NvLink, 1 << 20));
    }

    #[test]
    fn toml_overrides() {
        let doc = Toml::parse("[cluster]\nnvlink_gbps = 600\ngpus_per_node = 8\n").unwrap();
        let mut s = ClusterSpec::baskerville();
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.nvlink_gbps, 600.0);
        assert_eq!(s.gpus_per_node, 8);
    }
}
