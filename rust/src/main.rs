//! `akbench` — the leader entrypoint / CLI of the AcceleratedKernels
//! reproduction. See `akbench help` (cli::USAGE) and DESIGN.md §5 for the
//! figure-to-subcommand map.

use std::sync::Arc;

use accelkern::cfg::RunConfig;
use accelkern::cli::{Cli, USAGE};
use accelkern::coordinator::campaign;
use accelkern::coordinator::driver::run_for_config;
use accelkern::dtype::ElemType;
use accelkern::runtime::Runtime;

fn main() {
    // Deterministic fault injection for the crash/resume CI smoke:
    // AKBENCH_FAILPOINT=name[:skip[:panic]] arms one named fail point
    // for the whole process (DESIGN.md §15).
    let _failpoint_guard = accelkern::util::failpoint::arm_env();
    let cli = match Cli::parse(std::env::args()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(2);
        }
    };
    if cli.command == "help" {
        print!("{USAGE}");
        return;
    }
    // Arm tracing (DESIGN.md §18) for the whole command when requested.
    // The guard flushes the trace on drop — including a panic unwind,
    // so a crashed run still leaves a loadable partial trace.
    let trace_guard = match cli.run_config() {
        Ok(cfg) if cfg.obs.armed() => Some(accelkern::obs::TraceSession::start(
            cfg.obs.trace_out.as_deref().map(std::path::Path::new),
            cfg.obs.trace_summary,
            cfg.obs.ring_capacity,
        )),
        _ => None, // config errors surface from run() with full context
    };
    let result = run(&cli);
    // Flush before a possible process::exit — exit skips Drop.
    drop(trace_guard);
    if let Err(e) = result {
        eprintln!("akbench {}: error: {e:#}", cli.command);
        std::process::exit(1);
    }
}

fn open_runtime(cli: &Cli) -> Option<Arc<Runtime>> {
    if cli.has("no-device") {
        return None;
    }
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("warn: no device runtime ({e}); continuing host-only");
            None
        }
    }
}

fn run(cli: &Cli) -> anyhow::Result<()> {
    let quick = cli.has("quick");
    match cli.command.as_str() {
        "info" => {
            let rt = Runtime::open_default()?;
            println!("platform: {}", rt.platform());
            let m = rt.manifest();
            println!("artifact dir: {}", m.dir.display());
            println!("tile: {}", m.tile);
            println!("artifacts: {}", m.artifacts.len());
            let mut ops: Vec<&str> = m.artifacts.iter().map(|a| a.op.as_str()).collect();
            ops.sort();
            ops.dedup();
            for op in ops {
                let n = m.artifacts.iter().filter(|a| a.op == op).count();
                println!("  {op:<24} {n} variants");
            }
            Ok(())
        }
        "sort" => {
            let cfg = cli.run_config()?;
            let rt = open_runtime(cli);
            let out = run_for_config(&cfg, rt)?;
            println!("{}", out.record.row());
            println!(
                "bucket sizes: min {} max {} (ideal {}), refinement rounds {}",
                out.out_sizes.iter().min().unwrap(),
                out.out_sizes.iter().max().unwrap(),
                cfg.elems_per_rank,
                out.rounds_used
            );
            Ok(())
        }
        "table2" => {
            let n = cli.get_usize("n")?.unwrap_or(if quick { 1 << 20 } else { 1 << 22 });
            let threads = cli.get_usize("threads")?.unwrap_or(
                accelkern::backend::threaded::default_threads(),
            );
            let rt = open_runtime(cli);
            accelkern::coordinator::campaign::table2(n, threads, &rt, quick)
        }
        "fig1" => {
            let cfg = base_cfg(cli)?;
            let rt = open_runtime(cli);
            let ranks: Vec<usize> =
                if quick { vec![2, 4] } else { vec![1, 2, 4, 8, 16] };
            campaign::fig1(&cfg, &ranks, 25_000 / 4, 2_500_000 / 4, &rt)?;
            Ok(())
        }
        "fig2" => {
            let cfg = base_cfg(cli)?;
            let rt = open_runtime(cli);
            let ranks: Vec<usize> = if quick { vec![4, 8] } else { vec![4, 8, 16, 32, 64] };
            let bytes = cli
                .get_f64("mb-per-rank")?
                .map(|m| (m * 1e6) as usize)
                .unwrap_or(if quick { 1 << 20 } else { 4 << 20 });
            campaign::fig2(&cfg, &ranks, bytes, &ElemType::ALL, &rt)?;
            Ok(())
        }
        "fig3" => {
            let cfg = base_cfg(cli)?;
            let rt = open_runtime(cli);
            let ranks: Vec<usize> = if quick { vec![4, 8] } else { vec![4, 8, 16, 32, 64] };
            let total = cli
                .get_f64("total-mb")?
                .map(|m| (m * 1e6) as usize)
                .unwrap_or(if quick { 8 << 20 } else { 64 << 20 });
            campaign::fig3(&cfg, &ranks, total, &[ElemType::I32, ElemType::I64], &rt)?;
            Ok(())
        }
        "fig4" => {
            let cfg = base_cfg(cli)?;
            let rt = open_runtime(cli);
            let ranks = cli.get_usize("ranks")?.unwrap_or(if quick { 4 } else { 16 });
            let sizes: Vec<usize> =
                if quick { vec![1 << 20] } else { vec![1 << 20, 4 << 20] };
            campaign::fig4(&cfg, ranks, &sizes, &ElemType::ALL, &rt)?;
            Ok(())
        }
        "fig5" => {
            let cfg = base_cfg(cli)?;
            let rt = open_runtime(cli);
            let ranks = cli.get_usize("ranks")?.unwrap_or(4);
            let counts: Vec<usize> = if quick {
                vec![10_000, 1_000_000]
            } else {
                vec![1_000, 10_000, 100_000, 1_000_000, 10_000_000]
            };
            campaign::fig5(&cfg, ranks, &counts, &rt)?;
            Ok(())
        }
        "bench-sort" => {
            // Host sort engine throughput sweep -> BENCH_sort.json
            // (DESIGN.md §11). Also a correctness gate: cross-engine
            // divergence is a hard error, which is what CI relies on.
            // The active Launch knobs ride into the JSON metadata.
            let n = cli.get_usize("n")?.unwrap_or(if quick { 1 << 20 } else { 1 << 22 });
            let threads = cli
                .get_usize("threads")?
                .unwrap_or_else(accelkern::backend::threaded::default_threads);
            let out = cli.get("out").unwrap_or("BENCH_sort.json").to_string();
            let launch = cli.launch_overrides(accelkern::session::Launch::default())?;
            accelkern::bench::sort_bench::run_and_emit(
                n,
                threads,
                quick,
                std::path::Path::new(&out),
                &launch,
            )
        }
        "bench-stream" => {
            // Out-of-core pipeline sweep -> BENCH_stream.json (DESIGN.md
            // §13). Sorts datasets 8x/16x larger than the engine memory
            // budget; each configuration is verified bitwise against the
            // in-memory reference sort on a subsampled pass — divergence
            // is a hard error, which is what CI relies on.
            let cfg = cli.run_config()?;
            let n = cli.get_usize("n")?.unwrap_or(if quick { 1 << 20 } else { 1 << 22 });
            let threads = cli
                .get_usize("threads")?
                .unwrap_or_else(accelkern::backend::threaded::default_threads);
            let out = cli.get("out").unwrap_or("BENCH_stream.json").to_string();
            let medium = if cfg.stream.spill_memory {
                accelkern::stream::SpillMedium::Memory
            } else {
                accelkern::stream::SpillMedium::Disk
            };
            accelkern::bench::stream_bench::run_and_emit(
                n,
                threads,
                quick,
                std::path::Path::new(&out),
                &cfg.launch,
                medium,
                cfg.stream.spill_dir.clone().map(std::path::PathBuf::from),
                cfg.stream.checkpoint_dir.clone().map(std::path::PathBuf::from),
                cfg.stream.resume,
            )
        }
        "bench-records" => {
            // Record-stream (dataset engine) sweep -> BENCH_records.json
            // (DESIGN.md §19): sort-by-key across payload widths,
            // sortperm, group-reduce, distinct and merge-join, each at
            // 8x/16x dataset:budget ratios. Every configuration is
            // verified (key image + payload bits) against an in-memory
            // reference on a subsampled pass — divergence is a hard
            // error, which is what CI relies on.
            let cfg = cli.run_config()?;
            let n = cli.get_usize("n")?.unwrap_or(if quick { 1 << 19 } else { 1 << 21 });
            let threads = cli
                .get_usize("threads")?
                .unwrap_or_else(accelkern::backend::threaded::default_threads);
            let out = cli.get("out").unwrap_or("BENCH_records.json").to_string();
            let medium = if cfg.stream.spill_memory {
                accelkern::stream::SpillMedium::Memory
            } else {
                accelkern::stream::SpillMedium::Disk
            };
            accelkern::bench::record_bench::run_and_emit(
                n,
                threads,
                quick,
                std::path::Path::new(&out),
                &cfg.launch,
                medium,
                cfg.stream.spill_dir.clone().map(std::path::PathBuf::from),
            )
        }
        "bench-cluster-stream" => {
            // Multi-node x out-of-core sweep -> BENCH_cluster_stream.json
            // (DESIGN.md §14): SIHSort with the external rank-local
            // sorter over rank-counts x budget ratios x dtypes. Each
            // configuration is verified bitwise against one single-node
            // Session::sort and against the per-rank budget accounting —
            // divergence is a hard error, which is what CI relies on.
            let mut cfg = cli.run_config()?;
            if !cli.has("elems-per-rank") && !cli.has("mb-per-rank") {
                cfg.elems_per_rank = if quick { 1 << 15 } else { 1 << 17 };
            }
            let out = cli.get("out").unwrap_or("BENCH_cluster_stream.json").to_string();
            accelkern::bench::cluster_stream_bench::run_and_emit(
                &cfg,
                quick,
                std::path::Path::new(&out),
            )
        }
        "calibrate" => {
            // Measure the host:device sort throughput ratio and print the
            // hybrid co-processing split it implies (DESIGN.md §10).
            let cfg = cli.run_config()?;
            let n = cli.get_usize("n")?.unwrap_or(1 << 18);
            let rt = open_runtime(cli);
            let dev_backend = rt
                .map(|rt| accelkern::backend::Backend::device(accelkern::runtime::Registry::new(rt)));
            let dm = accelkern::cluster::DeviceModel::new(cfg.cluster.gpu_speedup);
            accelkern::dispatch_dtype!(cfg.dtype, K => {
                let dev_ops = dev_backend.as_ref().and_then(|b| b.device_ops());
                let cal = accelkern::hybrid::calibrate_sort::<K>(n, cfg.host_threads, dev_ops)?;
                println!(
                    "dtype {} over {} elements: host {:.2} Melem/s ({} threads); device {:.2} Melem/s ({})",
                    cfg.dtype,
                    cal.elems,
                    cal.host_elems_per_sec / 1e6,
                    cfg.host_threads,
                    cal.device_throughput(&dm) / 1e6,
                    if cal.device_elems_per_sec.is_some() {
                        "measured artifacts"
                    } else {
                        "device model"
                    },
                );
                println!("  model device:host ratio       {:.2}x", cal.ratio(&dm));
                println!(
                    "  executing-engine split        {:.1}% host (drives real work)",
                    cal.plan_measured(1.0).host_fraction * 100.0
                );
                println!(
                    "  model-projected split         {:.1}% host",
                    cal.plan(&dm, 1.0).host_fraction * 100.0
                );
                println!(
                    "  cost-aware projection (x{:.0})   {:.1}% host",
                    cfg.cluster.cost_ratio,
                    cal.plan(&dm, cfg.cluster.cost_ratio).host_fraction * 100.0
                );
            });
            Ok(())
        }
        "ablate" => {
            let cfg = base_cfg(cli)?;
            let rt = open_runtime(cli);
            campaign::ablations(&cfg, &rt, quick)
        }
        "selftest" => {
            let mut cfg = RunConfig::default();
            cfg.ranks = 4;
            cfg.elems_per_rank = 10_000;
            let rt = open_runtime(cli);
            for dt in ElemType::ALL {
                cfg.dtype = dt;
                let out = run_for_config(&cfg, rt.clone())?;
                println!("selftest {}: OK ({} msgs)", dt, out.record.messages);
            }
            println!("selftest: all dtypes OK");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command '{other}'\n\n{USAGE}")
        }
    }
}

fn base_cfg(cli: &Cli) -> anyhow::Result<RunConfig> {
    let mut cfg = cli.run_config()?;
    if cli.has("quick") {
        cfg.refine_rounds = cfg.refine_rounds.min(3);
    }
    Ok(cfg)
}
