//! External merge sort: bounded-memory sorting of datasets larger than
//! RAM (DESIGN.md §13).
//!
//! Three phases, all under the [`super::StreamBudget`]:
//!
//! 1. **Run generation** — budget-sized chunks are pulled from the
//!    source, sorted with the session's in-memory engine (threaded /
//!    hybrid dispatch and every `Launch` knob apply — this is the same
//!    rank-local sort the cluster pipeline runs), and spilled as sorted
//!    runs. A dataset that fits one chunk sorts in core and streams
//!    straight to the sink (no spill I/O).
//! 2. **Intermediate merge passes** — while runs outnumber the fan-in,
//!    each pass k-way merges groups of `fan_in` runs into longer runs
//!    through the resumable loser tree
//!    ([`crate::baselines::kmerge::KmergePull`]); retired input runs
//!    delete their spill files immediately.
//! 3. **Final merge** — the surviving ≤ `fan_in` runs merge once more,
//!    streaming output chunks into the sink.

use crate::baselines::kmerge::KmergePull;
use crate::obs;
use crate::session::{AkResult, Launch};
use crate::stream::record::StreamRecord;
use crate::stream::source::{ChunkSink, ChunkSource};
use crate::stream::spill::{SpillRun, SpillStore};
use crate::stream::{Checkpoint, StreamCtx, StreamPlan};
use crate::util::failpoint;

/// What a [`StreamCtx::external_sort`] run did (the bench records these
/// next to its throughput rows).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExternalSortStats {
    /// Elements sorted.
    pub elems: u64,
    /// Sorted runs generated from the source (1 = in-core fast path),
    /// or — on a resume — runs entering the merge phase.
    pub runs: usize,
    /// Merge passes over the data (0 = in-core, 1 = single k-way merge,
    /// ≥ 2 = multi-pass because runs exceeded the fan-in).
    pub merge_passes: usize,
    /// Bytes written to spill files (0 on the memory medium).
    pub spilled_bytes: u64,
    /// The fan-in the merge phases ran with.
    pub fan_in: usize,
    /// The run-generation chunk size (elements).
    pub run_chunk_elems: usize,
    /// Manifested runs reopened from a previous incarnation (resume).
    pub resumed_runs: usize,
    /// True when a resume found the job already complete and returned
    /// without touching the source or the sink.
    pub completed_noop: bool,
}

impl ExternalSortStats {
    /// The registry form of these counters
    /// ([`crate::obs::STREAM_COUNTERS`]; `completed_noop` is a flag,
    /// not a counter, and stays a struct field).
    pub fn snapshot(&self) -> obs::CounterSnapshot {
        let mut s = obs::CounterSnapshot::new();
        s.push("elems", self.elems);
        s.push("runs", self.runs as u64);
        s.push("merge_passes", self.merge_passes as u64);
        s.push("spilled_bytes", self.spilled_bytes);
        s.push("fan_in", self.fan_in as u64);
        s.push("run_chunk_elems", self.run_chunk_elems as u64);
        s.push("resumed_runs", self.resumed_runs as u64);
        s
    }
}

impl StreamCtx {
    /// Sort everything `src` yields into `sink` (ascending total order,
    /// NaN-safe — scalar output is bitwise what `Session::sort` produces
    /// on the concatenated input) while holding at most the budget in
    /// engine state. `launch` tunes the per-chunk in-memory sorts.
    ///
    /// Generic over any record layout (DESIGN.md §19): bare scalar keys
    /// run the unchanged fast path, `(key, payload)` records sort
    /// **stably** — chunks via the stable pair sort, the merge with a
    /// run-index tie-break — so record output is bitwise the stable
    /// in-memory sort of the whole stream.
    pub fn external_sort<K: StreamRecord>(
        &self,
        src: &mut dyn ChunkSource<K>,
        sink: &mut dyn ChunkSink<K>,
        launch: Option<&Launch>,
    ) -> AkResult<ExternalSortStats> {
        let plan = self.plan::<K>();
        let mut stats = ExternalSortStats {
            fan_in: plan.fan_in,
            run_chunk_elems: plan.run_chunk_elems,
            ..ExternalSortStats::default()
        };

        // ---- phase 1: run generation ----------------------------------
        let gen_span = obs::span(obs::SpanKind::Pass, "ext.run-gen");
        let mut buf: Vec<K> = Vec::new();
        let mut next: Vec<K> = Vec::new();
        if src.next_chunk(&mut buf, plan.run_chunk_elems)? == 0 {
            sink.finish()?;
            return Ok(stats);
        }
        stats.elems += buf.len() as u64;
        src.next_chunk(&mut next, plan.run_chunk_elems)?;
        K::sort_chunk(&self.session, &mut buf, launch)?;
        if next.is_empty() {
            // In-core fast path: one chunk, no spill.
            stats.runs = 1;
            for c in buf.chunks(plan.io_chunk_elems) {
                sink.push_chunk(c)?;
            }
            sink.finish()?;
            return Ok(stats);
        }
        let mut store = self.store();
        let mut runs: Vec<SpillRun<K>> = vec![store.write_run(&buf)?];
        while !next.is_empty() {
            std::mem::swap(&mut buf, &mut next);
            stats.elems += buf.len() as u64;
            K::sort_chunk(&self.session, &mut buf, launch)?;
            runs.push(store.write_run(&buf)?);
            src.next_chunk(&mut next, plan.run_chunk_elems)?;
        }
        stats.runs = runs.len();
        drop(gen_span);

        // ---- phase 2: intermediate merge passes -----------------------
        while runs.len() > plan.fan_in {
            stats.merge_passes += 1;
            let _pass_span =
                obs::span1(obs::SpanKind::Pass, "ext.merge-pass", runs.len() as u64);
            let mut merged: Vec<SpillRun<K>> = Vec::new();
            while !runs.is_empty() {
                let take = plan.fan_in.min(runs.len());
                let group: Vec<SpillRun<K>> = runs.drain(..take).collect();
                if group.len() == 1 {
                    // A lone trailing run passes through unmerged.
                    merged.extend(group);
                    continue;
                }
                merged.push(merge_group_to_store(&group, &mut store, &plan)?);
                // `group` drops here: retired runs delete their files.
            }
            runs = merged;
        }

        // ---- phase 3: final merge into the sink -----------------------
        // `runs.len() >= 2` always holds here (single-chunk datasets took
        // the in-core path; a pass over > fan_in >= 2 runs yields >= 2).
        stats.merge_passes += 1;
        let _final_span =
            obs::span1(obs::SpanKind::Pass, "ext.final-merge", runs.len() as u64);
        let mut cursors = Vec::with_capacity(runs.len());
        for r in &runs {
            cursors.push(r.cursor(plan.io_chunk_elems)?);
        }
        let mut merge = KmergePull::new(cursors);
        let mut out: Vec<K> = Vec::with_capacity(plan.io_chunk_elems);
        loop {
            out.clear();
            if merge.next_chunk(&mut out, plan.io_chunk_elems)? == 0 {
                break;
            }
            sink.push_chunk(&out)?;
        }
        sink.finish()?;
        stats.spilled_bytes = store.bytes_spilled();
        Ok(stats)
    }

    /// Crash-safe [`StreamCtx::external_sort`] (DESIGN.md §15): the
    /// same three phases, but every completed run and merge pass is
    /// recorded in an atomic manifest inside `ckpt.dir`, so a job
    /// killed at any point resumes from its last durable state with
    /// `ckpt.resume` instead of restarting from zero.
    ///
    /// Contract on resume: the caller must present the *identical*
    /// source (the engine skips exactly the elements previous
    /// incarnations already consumed) and a fresh sink (the final merge
    /// always replays into it — output depends only on the sorted key
    /// multiset, so the result is bitwise what an uninterrupted run
    /// produces). Resuming an already-complete job returns immediately
    /// with `completed_noop` set and touches neither source nor sink.
    ///
    /// Checkpointing forces the disk spill medium (memory cannot
    /// survive the crash the checkpoint exists for) and skips the
    /// in-core fast path: even a single-run dataset parks its run so
    /// the manifest always describes the full job state.
    pub fn external_sort_ckpt<K: StreamRecord>(
        &self,
        src: &mut dyn ChunkSource<K>,
        sink: &mut dyn ChunkSink<K>,
        launch: Option<&Launch>,
        ckpt: &Checkpoint,
    ) -> AkResult<ExternalSortStats> {
        let plan = self.plan::<K>();
        let mut stats = ExternalSortStats {
            fan_in: plan.fan_in,
            run_chunk_elems: plan.run_chunk_elems,
            ..ExternalSortStats::default()
        };
        let mut store = SpillStore::checkpointed(
            &ckpt.dir,
            "external_sort",
            &ckpt.tag,
            &K::layout_name(),
            plan.run_chunk_elems as u64,
            ckpt.resume,
        )?;
        let m = store
            .manifest()
            .ok_or_else(|| anyhow::anyhow!("checkpointed store lost its manifest"))?
            .clone();
        if m.complete {
            stats.completed_noop = true;
            return Ok(stats);
        }

        // Reopen whatever previous incarnations made durable, in
        // recording order. Manifested runs are disjoint and cover
        // exactly the consumed prefix, so their sizes sum to it.
        let mut runs: Vec<SpillRun<K>> = Vec::with_capacity(m.runs.len());
        for meta in &m.runs {
            runs.push(store.open_manifested_run(meta)?);
            stats.elems += meta.elems;
        }
        stats.resumed_runs = runs.len();

        // ---- phase 1: (continue) run generation -----------------------
        if !m.gen_done {
            let _gen_span = obs::span(obs::SpanKind::Pass, "ext.run-gen");
            // Merges are never recorded before `gen_done`, so every
            // manifested run is a generation run and their sum is the
            // consumed prefix to skip.
            let consumed: u64 = m.runs.iter().map(|r| r.elems).sum();
            skip_elems(src, consumed, plan.run_chunk_elems)?;
            let mut seq = runs.len() as u64;
            let mut buf: Vec<K> = Vec::new();
            loop {
                if src.next_chunk(&mut buf, plan.run_chunk_elems)? == 0 {
                    break;
                }
                stats.elems += buf.len() as u64;
                K::sort_chunk(&self.session, &mut buf, launch)?;
                let mut run = store.write_run(&buf)?;
                // The satellite-2 crash window: run data is on disk and
                // fsynced, but the manifest does not reference it yet —
                // a kill here must resume from the previous run.
                failpoint::check("ext.run")?;
                store.record_run(&mut run, 0, seq)?;
                failpoint::check("ext.run.recorded")?;
                seq += 1;
                runs.push(run);
            }
            store.update(|m| m.gen_done = true)?;
            failpoint::check("ext.gen-done")?;
        }
        stats.runs = runs.len();

        if runs.is_empty() {
            if !ckpt.defer_complete {
                store.update(|m| m.complete = true)?;
            }
            sink.finish()?;
            return Ok(stats);
        }

        // ---- phase 2: intermediate merge passes -----------------------
        let mut pass =
            store.manifest().map_or(0, |m| m.runs.iter().map(|r| r.pass).max().unwrap_or(0));
        while runs.len() > plan.fan_in {
            stats.merge_passes += 1;
            pass += 1;
            let _pass_span =
                obs::span1(obs::SpanKind::Pass, "ext.merge-pass", runs.len() as u64);
            let mut merged: Vec<SpillRun<K>> = Vec::new();
            let mut mseq = 0u64;
            while !runs.is_empty() {
                let take = plan.fan_in.min(runs.len());
                let group: Vec<SpillRun<K>> = runs.drain(..take).collect();
                if group.len() == 1 {
                    merged.extend(group);
                    continue;
                }
                failpoint::check("ext.merge.group")?;
                let mut out = merge_group_to_store(&group, &mut store, &plan)?;
                // One atomic manifest rewrite swaps the inputs for the
                // output; the input files are deleted only after it.
                store.commit_merge(&mut out, group, pass, mseq)?;
                failpoint::check("ext.merge.retired")?;
                mseq += 1;
                merged.push(out);
            }
            runs = merged;
            failpoint::check("ext.merge.pass")?;
        }

        // ---- phase 3: final merge into the sink -----------------------
        // Always replayed on resume: it mutates no durable state, and a
        // fresh sink makes the replay idempotent.
        failpoint::check("ext.final")?;
        stats.merge_passes += 1;
        let _final_span =
            obs::span1(obs::SpanKind::Pass, "ext.final-merge", runs.len() as u64);
        {
            let mut cursors = Vec::with_capacity(runs.len());
            for r in &runs {
                cursors.push(r.cursor(plan.io_chunk_elems)?);
            }
            let mut merge = KmergePull::new(cursors);
            let mut out: Vec<K> = Vec::with_capacity(plan.io_chunk_elems);
            loop {
                out.clear();
                if merge.next_chunk(&mut out, plan.io_chunk_elems)? == 0 {
                    break;
                }
                failpoint::check("ext.final.mid")?;
                sink.push_chunk(&out)?;
            }
        }
        sink.finish()?;
        stats.spilled_bytes = store.bytes_spilled();
        if !ckpt.defer_complete {
            // Job done: one rewrite drops every run from the manifest
            // and marks completion, then the files are reclaimed. Only
            // MANIFEST.json remains as the durable job-done record.
            store.update(|m| {
                m.complete = true;
                m.runs.clear();
            })?;
            for r in &mut runs {
                r.persist(false);
            }
        }
        Ok(stats)
    }
}

/// Pull and discard exactly `n` elements from `src` (the consumed
/// prefix a resumed generation phase skips). Errors if the source runs
/// dry early — the resume contract requires the identical input.
fn skip_elems<K: StreamRecord>(
    src: &mut dyn ChunkSource<K>,
    mut n: u64,
    chunk: usize,
) -> anyhow::Result<()> {
    let mut buf: Vec<K> = Vec::new();
    while n > 0 {
        let want = (chunk as u64).min(n) as usize;
        let got = src.next_chunk(&mut buf, want)?;
        anyhow::ensure!(
            got > 0,
            "resume source ended {n} elements before the checkpointed position \
             (a resumed job must re-supply the identical input)"
        );
        n -= got as u64;
    }
    Ok(())
}

/// Merge `group` (≥ 2 runs) into one new spilled run, streaming through
/// I/O-granule chunks. Also the fan-in-capping engine of the streamed
/// SIHSort rank's final phase (`mpisort::sihsort`), which pre-merges
/// received runs when the rank count exceeds the plan's fan-in.
pub(crate) fn merge_group_to_store<K: StreamRecord>(
    group: &[SpillRun<K>],
    store: &mut SpillStore,
    plan: &StreamPlan,
) -> AkResult<SpillRun<K>> {
    let mut cursors = Vec::with_capacity(group.len());
    for r in group {
        cursors.push(r.cursor(plan.io_chunk_elems)?);
    }
    let mut merge = KmergePull::new(cursors);
    let mut writer = store.run_writer::<K>()?;
    let mut out: Vec<K> = Vec::with_capacity(plan.io_chunk_elems);
    loop {
        out.clear();
        if merge.next_chunk(&mut out, plan.io_chunk_elems)? == 0 {
            break;
        }
        // Mid-merge kill site: the output run is half-written and
        // unmanifested; a resume sweeps it and redoes the group.
        failpoint::check("ext.merge.mid")?;
        writer.push_chunk(&out)?;
    }
    Ok(writer.finish()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::DeviceKey;
    use crate::dtype::bits_eq;
    use crate::session::Session;
    use crate::stream::{SliceSource, StreamBudget, VecSink};
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn reference<K: KeyGen + DeviceKey>(data: &[K]) -> Vec<K> {
        let mut want = data.to_vec();
        Session::native().sort(&mut want, None).unwrap();
        want
    }

    fn sort_streamed<K: KeyGen + DeviceKey>(
        ctx: &StreamCtx,
        data: &[K],
    ) -> (Vec<K>, ExternalSortStats) {
        let mut sink = VecSink::new();
        let stats = ctx.external_sort(&mut SliceSource::new(data), &mut sink, None).unwrap();
        (sink.out, stats)
    }

    #[test]
    fn in_core_fast_path_skips_spill() {
        let data: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 800);
        let ctx = Session::threaded(2).stream(StreamBudget::mib(1));
        let (got, stats) = sort_streamed(&ctx, &data);
        assert!(bits_eq(&got, &reference(&data)));
        assert_eq!(stats.runs, 1);
        assert_eq!(stats.merge_passes, 0);
        assert_eq!(stats.spilled_bytes, 0);
        assert_eq!(stats.elems, 800);
    }

    #[test]
    fn stats_snapshot_covers_the_stream_registry() {
        let stats = ExternalSortStats {
            elems: 9,
            runs: 3,
            merge_passes: 2,
            spilled_bytes: 1024,
            fan_in: 4,
            run_chunk_elems: 3,
            resumed_runs: 1,
            completed_noop: false,
        };
        let snap = stats.snapshot();
        assert_eq!(snap.names(), crate::obs::STREAM_COUNTERS.to_vec());
        assert_eq!(snap.get("elems"), 9);
        assert_eq!(snap.get("spilled_bytes"), 1024);
        assert_eq!(snap.get("resumed_runs"), 1);
    }

    #[test]
    fn empty_input() {
        let data: Vec<i64> = vec![];
        let ctx = Session::native().stream(StreamBudget::mib(1));
        let (got, stats) = sort_streamed(&ctx, &data);
        assert!(got.is_empty());
        assert_eq!(stats.runs, 0);
        assert_eq!(stats.merge_passes, 0);
    }

    #[test]
    fn single_merge_pass_on_memory_spill() {
        let data: Vec<i64> = generate(&mut Prng::new(2), Distribution::Uniform, 12_000);
        let ctx = Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .in_memory_spill()
            .run_chunk_elems(2000); // 6 runs, fan_in >= 2
        let (got, stats) = sort_streamed(&ctx, &data);
        assert!(bits_eq(&got, &reference(&data)));
        assert_eq!(stats.runs, 6);
        assert!(stats.merge_passes >= 1);
    }

    #[test]
    fn multi_pass_merge_on_disk() {
        // 16 runs at fan-in 2: passes 16 -> 8 -> 4 -> 2 -> final = 4.
        let data: Vec<f64> = generate(&mut Prng::new(3), Distribution::Uniform, 16_000);
        let ctx = Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .run_chunk_elems(1000)
            .fan_in(2)
            .io_chunk_elems(128);
        let (got, stats) = sort_streamed(&ctx, &data);
        assert!(bits_eq(&got, &reference(&data)));
        assert_eq!(stats.runs, 16);
        assert_eq!(stats.merge_passes, 4);
        assert!(stats.spilled_bytes > 0);
    }

    #[test]
    fn uneven_trailing_run_passes_through() {
        // 5 runs at fan-in 2: pass 1 merges (2, 2) and passes the 5th
        // through; 3 runs then (2) + pass-through; final merges 2.
        let data: Vec<i16> = generate(&mut Prng::new(4), Distribution::DupHeavy, 5000);
        let ctx = Session::native()
            .stream(StreamBudget::bytes(64))
            .in_memory_spill()
            .run_chunk_elems(1000)
            .fan_in(2);
        let (got, stats) = sort_streamed(&ctx, &data);
        assert!(bits_eq(&got, &reference(&data)));
        assert_eq!(stats.runs, 5);
        assert_eq!(stats.merge_passes, 3);
    }
}
