//! Fixed-width `(key, payload)` records for the streaming engine
//! (DESIGN.md §19).
//!
//! Every out-of-core pipeline in this crate — spills, the k-way merge,
//! the external sort, the cluster exchange — is generic over one trait,
//! [`StreamRecord`]: a `Copy` value that exposes a [`SortKey`] image to
//! order by and a raw little-endian payload to carry along. Two families
//! implement it:
//!
//! * every scalar key dtype (`PAYLOAD_BYTES = 0`) — the degenerate
//!   layout whose wire format, spill stride and manifest identity are
//!   byte-for-byte today's scalar format, so existing spills, resumes
//!   and benches are untouched;
//! * [`Record<K, P>`] — a key plus a fixed-width [`Payload`], the
//!   layout behind `stream_sort_by_key`, `stream_sortperm`, group-by
//!   reduce, merge-join and `stream_distinct`.
//!
//! Payload bytes are *raw bits*, not a sort image: they survive spills
//! bit-exactly (the key goes through the order-preserving
//! [`SortKey::to_bits`] bijection exactly as before). Chunk sorting of
//! records is **stable** (`Session::sort_by_key`), and the merge layer
//! breaks key ties by run index, so an external record sort is bitwise
//! the stable in-memory sort of the whole stream.

use crate::backend::DeviceKey;
use crate::dtype::SortKey;
use crate::session::{AkResult, Launch, Session};

/// A fixed-width payload carried alongside a sort key. `BYTES` ≤ 16;
/// the raw image is the value's own little-endian bit pattern (bit-exact
/// across spills, unlike the key's order-preserving image).
pub trait Payload: Copy + Send + Sync + std::fmt::Debug + 'static {
    /// Encoded width in bytes (0 ..= 16).
    const BYTES: usize;
    /// The value's raw bits, zero-extended into the low `BYTES` bytes.
    fn to_raw(self) -> u128;
    /// Inverse of [`Payload::to_raw`] (bits above `BYTES` are zero).
    fn from_raw(bits: u128) -> Self;
}

impl Payload for () {
    const BYTES: usize = 0;
    fn to_raw(self) -> u128 {
        0
    }
    fn from_raw(_bits: u128) -> Self {}
}

macro_rules! uint_payload {
    ($($t:ty),*) => {$(
        impl Payload for $t {
            const BYTES: usize = std::mem::size_of::<$t>();
            fn to_raw(self) -> u128 {
                self as u128
            }
            fn from_raw(bits: u128) -> Self {
                bits as $t
            }
        }
    )*};
}
uint_payload!(u32, u64, u128);

macro_rules! scalar_payload {
    ($($t:ty => $u:ty),*) => {$(
        impl Payload for $t {
            const BYTES: usize = std::mem::size_of::<$t>();
            fn to_raw(self) -> u128 {
                // Raw bit pattern (NOT the sort image): floats keep NaN
                // payloads and zero signs bit-exactly.
                <$u>::from_le_bytes(self.to_le_bytes()) as u128
            }
            fn from_raw(bits: u128) -> Self {
                <$t>::from_le_bytes((bits as $u).to_le_bytes())
            }
        }
    )*};
}
scalar_payload!(i16 => u16, i32 => u32, i64 => u64, i128 => u128, f32 => u32, f64 => u64);

/// Two payloads packed side by side (`A` in the low bytes) — the output
/// shape of a merge-join. The combined width must still fit the 16-byte
/// raw image; wider pairs fail to compile at the first use.
impl<A: Payload, B: Payload> Payload for (A, B) {
    const BYTES: usize = {
        assert!(A::BYTES + B::BYTES <= 16, "paired payload exceeds the 16-byte raw image");
        A::BYTES + B::BYTES
    };
    fn to_raw(self) -> u128 {
        let lo = self.0.to_raw();
        if A::BYTES >= 16 {
            // B is zero-width (the const assert above); a literal shift
            // by 128 would overflow even though the high half is empty.
            lo
        } else {
            lo | (self.1.to_raw() << (8 * A::BYTES as u32))
        }
    }
    fn from_raw(bits: u128) -> Self {
        if A::BYTES >= 16 {
            (A::from_raw(bits), B::from_raw(0))
        } else {
            let mask = (1u128 << (8 * A::BYTES as u32)) - 1;
            (A::from_raw(bits & mask), B::from_raw(bits >> (8 * A::BYTES as u32)))
        }
    }
}

/// One `(key, payload)` record. Ordered by the key's total order; the
/// payload rides along untouched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Record<K: SortKey, P: Payload> {
    /// The sort key.
    pub key: K,
    /// The carried payload.
    pub val: P,
}

impl<K: SortKey, P: Payload> Record<K, P> {
    /// A record from its parts.
    pub fn new(key: K, val: P) -> Record<K, P> {
        Record { key, val }
    }
}

/// The unit every streaming layer moves: a fixed-width record with a
/// [`SortKey`] to order by. See the module docs for the two families
/// (bare scalars at `PAYLOAD_BYTES = 0`, [`Record<K, P>`] otherwise)
/// and the wire-format guarantee.
pub trait StreamRecord: Copy + Send + Sync + std::fmt::Debug + 'static {
    /// The key dtype (orders the record; images feed the loser tree).
    type Key: SortKey;
    /// Payload width in bytes (0 for bare scalar keys).
    const PAYLOAD_BYTES: usize;
    /// Total encoded stride: key image then raw payload bytes.
    const REC_BYTES: usize = <Self::Key as SortKey>::KEY_BYTES + Self::PAYLOAD_BYTES;

    /// The record's key.
    fn key(&self) -> Self::Key;

    /// The key's order-preserving `u128` image (merge comparisons).
    fn key_bits(&self) -> u128 {
        self.key().to_bits()
    }

    /// The payload's raw bits, zero above `PAYLOAD_BYTES`.
    fn payload_raw(&self) -> u128;

    /// Rebuild a record from a decoded key and raw payload bits.
    fn from_parts(key: Self::Key, payload: u128) -> Self;

    /// The layout's manifest identity. Scalar layouts keep the bare
    /// dtype name (`"i64"`) so pre-record checkpoints resume cleanly;
    /// record layouts append the payload width (`"i64+p8"`), making a
    /// resume against a different layout a typed identity error instead
    /// of silent corruption.
    fn layout_name() -> String;

    /// Sort one in-memory chunk with the session's engines. Scalar
    /// chunks use `Session::sort` (unchanged fast path; ties are
    /// bit-identical so stability is moot); record chunks use the
    /// stable `Session::sort_by_key` so equal-key payloads keep input
    /// order.
    fn sort_chunk(session: &Session, buf: &mut [Self], launch: Option<&Launch>) -> AkResult<()>;
}

macro_rules! scalar_record {
    ($($t:ty),*) => {$(
        impl StreamRecord for $t {
            type Key = $t;
            const PAYLOAD_BYTES: usize = 0;
            fn key(&self) -> $t {
                *self
            }
            fn payload_raw(&self) -> u128 {
                0
            }
            fn from_parts(key: $t, _payload: u128) -> Self {
                key
            }
            fn layout_name() -> String {
                <$t as SortKey>::ELEM.name().to_string()
            }
            fn sort_chunk(
                session: &Session,
                buf: &mut [Self],
                launch: Option<&Launch>,
            ) -> AkResult<()> {
                session.sort(buf, launch)
            }
        }
    )*};
}
scalar_record!(i16, i32, i64, i128, f32, f64);

impl<K: DeviceKey, P: Payload> StreamRecord for Record<K, P> {
    type Key = K;
    const PAYLOAD_BYTES: usize = P::BYTES;

    fn key(&self) -> K {
        self.key
    }

    fn payload_raw(&self) -> u128 {
        self.val.to_raw()
    }

    fn from_parts(key: K, payload: u128) -> Self {
        Record { key, val: P::from_raw(payload) }
    }

    fn layout_name() -> String {
        format!("{}+p{}", K::ELEM.name(), P::BYTES)
    }

    fn sort_chunk(session: &Session, buf: &mut [Self], launch: Option<&Launch>) -> AkResult<()> {
        // Split into parallel key/value arrays for the stable pair sort,
        // then zip back. O(n) extra space, same as the permutation the
        // pair sort builds internally.
        let mut keys: Vec<K> = buf.iter().map(|r| r.key).collect();
        let mut vals: Vec<P> = buf.iter().map(|r| r.val).collect();
        session.sort_by_key(&mut keys, &mut vals, launch)?;
        for ((r, k), v) in buf.iter_mut().zip(keys).zip(vals) {
            r.key = k;
            r.val = v;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Session;

    #[test]
    fn scalar_layouts_are_the_bare_dtype() {
        assert_eq!(<i64 as StreamRecord>::REC_BYTES, 8);
        assert_eq!(<i64 as StreamRecord>::layout_name(), "i64");
        assert_eq!(<f32 as StreamRecord>::REC_BYTES, 4);
        let x = 42i64;
        assert_eq!(x.key_bits(), 42i64.to_bits());
        assert_eq!(x.payload_raw(), 0);
        assert_eq!(<i64 as StreamRecord>::from_parts(42, 0), 42);
    }

    #[test]
    fn record_layout_names_and_strides() {
        assert_eq!(<Record<i64, u64> as StreamRecord>::REC_BYTES, 16);
        assert_eq!(<Record<i64, u64> as StreamRecord>::layout_name(), "i64+p8");
        assert_eq!(<Record<f32, u32> as StreamRecord>::layout_name(), "f32+p4");
        assert_eq!(<Record<i32, ()> as StreamRecord>::REC_BYTES, 4);
    }

    #[test]
    fn payload_raw_bits_are_exact() {
        // Floats keep NaN payloads and the zero sign through the raw
        // image — it is the bit pattern, not the sort image.
        let nan = f64::from_bits(0x7ff8_0000_dead_beef);
        let r = Record::new(1i32, nan);
        let back = <Record<i32, f64> as StreamRecord>::from_parts(r.key, r.payload_raw());
        assert_eq!(back.val.to_bits(), nan.to_bits());
        let z = Record::new(1i32, -0.0f32);
        let back = <Record<i32, f32> as StreamRecord>::from_parts(z.key, z.payload_raw());
        assert_eq!(back.val.to_bits(), (-0.0f32).to_bits());
        // Signed payloads round-trip sign bits.
        let neg = Record::new(1i32, -7i64);
        let back = <Record<i32, i64> as StreamRecord>::from_parts(neg.key, neg.payload_raw());
        assert_eq!(back.val, -7);
    }

    #[test]
    fn paired_payloads_pack_low_then_high() {
        let p: (u32, u64) = (0xAABB_CCDD, 0x1122_3344_5566_7788);
        assert_eq!(<(u32, u64) as Payload>::BYTES, 12);
        let raw = p.to_raw();
        assert_eq!(raw & 0xFFFF_FFFF, 0xAABB_CCDD);
        let back = <(u32, u64) as Payload>::from_raw(raw);
        assert_eq!(back, p);
    }

    #[test]
    fn record_chunk_sort_is_stable() {
        let s = Session::threaded(2);
        let mut buf: Vec<Record<i32, u64>> =
            (0..1000u64).map(|i| Record::new((i % 7) as i32, i)).collect();
        <Record<i32, u64> as StreamRecord>::sort_chunk(&s, &mut buf, None).unwrap();
        for w in buf.windows(2) {
            assert!(w[0].key <= w[1].key);
            if w[0].key == w[1].key {
                assert!(w[0].val < w[1].val, "equal keys must keep input order");
            }
        }
    }
}
