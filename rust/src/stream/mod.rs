//! Bounded-memory streaming / out-of-core pipelines (DESIGN.md §13).
//!
//! Every in-memory algorithm in this crate takes its whole input as one
//! slice, so the largest problem a session can serve is bounded by one
//! host's RAM. This module removes that bound for the algorithms whose
//! access patterns stream: datasets arrive chunk by chunk from a
//! [`ChunkSource`], results leave through a [`ChunkSink`], and the
//! engines in between never hold more than a [`StreamBudget`] of state.
//!
//! * [`StreamCtx::external_sort`] — classic external merge sort: sorted
//!   runs are generated with the session's in-memory engines (threaded
//!   or hybrid — run generation is exactly the rank-local sort of the
//!   paper's cluster pipeline), spilled through a [`SpillStore`], then
//!   k-way merged by the resumable loser tree
//!   ([`crate::baselines::kmerge::KmergePull`]) with budget-aware
//!   fan-in; when runs outnumber the fan-in, intermediate merge passes
//!   reduce them first (multi-pass merge).
//! * [`StreamCtx::stream_reduce`] / [`StreamCtx::stream_scan`] /
//!   [`StreamCtx::stream_histogram`] / [`StreamCtx::stream_topk`] —
//!   single-pass folds: reduce carries one accumulator, scan carries the
//!   running prefix between chunks (chunk-at-a-time output), histogram
//!   bins each chunk via `searchsorted`, top-k keeps a 2k-element pool.
//!
//! Entry point: [`crate::session::Session::stream`] — the context
//! inherits the session's backend, metrics sink and default launch
//! policy, and every method accepts the same per-call
//! [`crate::session::Launch`] knobs and returns the same typed
//! [`crate::session::AkError`]s as the in-memory surface.
//!
//! ```
//! use accelkern::session::Session;
//! use accelkern::stream::{SliceSource, StreamBudget, VecSink};
//!
//! let data = vec![5i32, -7, 3, 0, 9, -2, 8, 1];
//! let ctx = Session::threaded(2).stream(StreamBudget::bytes(64 * 1024));
//! let mut out = VecSink::new();
//! let stats = ctx
//!     .external_sort(&mut SliceSource::new(&data), &mut out, None)
//!     .unwrap();
//! assert_eq!(out.out, vec![-7, -2, 0, 1, 3, 5, 8, 9]);
//! assert_eq!(stats.elems, 8);
//! ```

pub mod codec;
pub mod external_sort;
pub mod folds;
pub mod manifest;
pub mod record;
pub mod records;
pub mod source;
pub mod spill;

pub use external_sort::ExternalSortStats;
pub use manifest::{Manifest, RunMeta, MANIFEST_FILE, MANIFEST_VERSION};
pub use record::{Payload, Record, StreamRecord};
pub use source::{ChunkSink, ChunkSource, FileSink, FileSource, GenSource, SliceSource, VecSink};
pub use spill::{RunSink, SpillMedium, SpillRun, SpillRunSource, SpillStore, TempDirGuard};

use std::path::{Path, PathBuf};

use crate::session::Session;

/// Floor on the derived run-generation chunk (elements).
pub(crate) const MIN_RUN_CHUNK: usize = 1024;
/// Floor on each merge I/O buffer (elements per run cursor / output).
pub(crate) const MIN_IO_ELEMS: usize = 256;
/// Cap on the merge fan-in (beyond ~this, tournament depth and seek
/// churn cost more than an extra pass saves).
pub(crate) const MAX_FAN_IN: usize = 128;

/// The engine-state memory target of a streaming pipeline, in bytes.
///
/// The budget is what the *engine* may hold — chunk buffers, merge I/O
/// buffers, the scan carry — not the dataset, the spill files or the
/// caller's source/sink. Derivations (DESIGN.md §13): the run chunk
/// gets a third of the budget (the current chunk, the one-chunk
/// look-ahead and the in-memory sort's scratch each own a third at the
/// peak of run generation), the merge phase splits a quarter of it
/// across `fan_in` input cursors plus one output buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamBudget {
    bytes: usize,
}

impl StreamBudget {
    /// A budget of `n` bytes (floored to something workable per dtype).
    pub fn bytes(n: usize) -> StreamBudget {
        StreamBudget { bytes: n.max(1) }
    }

    /// A budget of `n` MiB.
    pub fn mib(n: usize) -> StreamBudget {
        StreamBudget::bytes(n.saturating_mul(1 << 20))
    }

    /// The budget in bytes.
    pub fn get(self) -> usize {
        self.bytes
    }
}

/// Crash-safe checkpoint configuration for
/// [`StreamCtx::external_sort_ckpt`] (DESIGN.md §15).
///
/// `dir` is a durable directory the caller owns (unlike the guarded
/// temp dirs of a plain external sort, it survives the process); the
/// engine keeps a [`Manifest`] there recording every completed run and
/// merge pass, so a crashed job can resume. With `resume = false` the
/// directory is cleared and the job starts fresh; with `resume = true`
/// a valid manifest continues where it left off (and an absent or
/// completed manifest degrades to fresh / no-op respectively).
///
/// The checkpoint medium is always disk regardless of the context's
/// configured spill medium — memory cannot survive the crash the
/// checkpoint exists for.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Durable checkpoint/spill directory (created if missing).
    pub dir: PathBuf,
    /// Job identity; a resume must present the same tag.
    pub tag: String,
    /// Continue from an existing manifest instead of starting fresh.
    pub resume: bool,
    /// Leave `complete = false` and keep the merged output runs: the
    /// caller owns job completion (the SIHSort rank nests its phase-1
    /// local sort this way so the parked run is never the only copy).
    pub(crate) defer_complete: bool,
}

impl Checkpoint {
    /// A checkpoint rooted at `dir` with job identity `tag`.
    pub fn new(dir: impl Into<PathBuf>, tag: impl Into<String>) -> Checkpoint {
        Checkpoint { dir: dir.into(), tag: tag.into(), resume: false, defer_complete: false }
    }

    /// Resume from an existing manifest (fresh start when none exists).
    pub fn resume(mut self) -> Checkpoint {
        self.resume = true;
        self
    }

    /// Caller-owned completion (see the type docs).
    pub(crate) fn defer_complete(mut self) -> Checkpoint {
        self.defer_complete = true;
        self
    }

    /// The checkpoint directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Resolved per-dtype pipeline shape (see [`StreamBudget`] for the
/// accounting; recorded in [`ExternalSortStats`] and `BENCH_stream.json`).
#[derive(Clone, Copy, Debug)]
pub(crate) struct StreamPlan {
    /// Elements per run-generation chunk (also the fold chunk).
    pub run_chunk_elems: usize,
    /// Maximum runs one merge consumes at once.
    pub fan_in: usize,
    /// Elements per merge I/O buffer (cursor refill / output granule).
    pub io_chunk_elems: usize,
}

/// A bounded-memory streaming context over one [`Session`]'s engines.
/// Built by [`Session::stream`]; see the module docs for the pipeline
/// inventory.
#[derive(Clone, Debug)]
pub struct StreamCtx {
    pub(crate) session: Session,
    budget: StreamBudget,
    medium: SpillMedium,
    spill_parent: Option<PathBuf>,
    run_chunk_override: Option<usize>,
    fan_in_override: Option<usize>,
    io_chunk_override: Option<usize>,
}

impl StreamCtx {
    pub(crate) fn new(session: Session, budget: StreamBudget) -> StreamCtx {
        StreamCtx {
            session,
            budget,
            medium: SpillMedium::Disk,
            spill_parent: None,
            run_chunk_override: None,
            fan_in_override: None,
            io_chunk_override: None,
        }
    }

    /// Keep spilled runs in memory (tests / datasets that happen to fit;
    /// the pipeline logic is unchanged).
    pub fn in_memory_spill(mut self) -> StreamCtx {
        self.medium = SpillMedium::Memory;
        self
    }

    /// Put the guarded spill directory under `parent` instead of the OS
    /// temp dir (e.g. a scratch filesystem).
    pub fn spill_parent(mut self, parent: PathBuf) -> StreamCtx {
        self.spill_parent = Some(parent);
        self.medium = SpillMedium::Disk;
        self
    }

    /// Override the derived run-generation chunk (elements). Tests use
    /// this to pin run counts; production callers should let the budget
    /// derive it.
    pub fn run_chunk_elems(mut self, elems: usize) -> StreamCtx {
        self.run_chunk_override = Some(elems.max(1));
        self
    }

    /// Override the derived merge fan-in (≥ 2). Lower fan-in forces more
    /// merge passes — the multi-pass equivalence tests pin it to 2.
    pub fn fan_in(mut self, fan_in: usize) -> StreamCtx {
        self.fan_in_override = Some(fan_in.max(2));
        self
    }

    /// Override the derived merge I/O buffer granule (elements).
    pub fn io_chunk_elems(mut self, elems: usize) -> StreamCtx {
        self.io_chunk_override = Some(elems.max(1));
        self
    }

    /// The session this context executes on.
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// The engine-state budget.
    pub fn budget(&self) -> StreamBudget {
        self.budget
    }

    /// Where spilled runs go.
    pub fn medium(&self) -> SpillMedium {
        self.medium
    }

    pub(crate) fn store(&self) -> SpillStore {
        SpillStore::new(self.medium, self.spill_parent.clone())
    }

    /// Budget → pipeline shape for records of layout `R` (see
    /// [`StreamBudget`] for the accounting). The budget divides by the
    /// full record stride (`REC_BYTES` = key image + payload), so wider
    /// payloads shrink every chunk the same way wider keys always have;
    /// scalar layouts (`PAYLOAD_BYTES = 0`) derive exactly the
    /// pre-record shapes.
    ///
    /// Every derivation uses `checked_*`/`saturating_*` arithmetic: a
    /// pathological budget or record width clamps to the documented
    /// floors instead of wrapping. `aklint` enforces this in the marked
    /// region.
    pub(crate) fn plan<R: StreamRecord>(&self) -> StreamPlan {
        // aklint: begin(checked-arith)
        let budget_elems = self
            .budget
            .bytes
            .checked_div(R::REC_BYTES)
            .unwrap_or(0)
            .max(MIN_IO_ELEMS.saturating_mul(2));
        let run_chunk_elems = self
            .run_chunk_override
            .unwrap_or_else(|| budget_elems.checked_div(3).unwrap_or(0).max(MIN_RUN_CHUNK));
        let fan_in = self.fan_in_override.unwrap_or_else(|| {
            budget_elems
                .checked_div(MIN_IO_ELEMS.saturating_mul(4))
                .unwrap_or(0)
                .clamp(2, MAX_FAN_IN)
        });
        let io_chunk_elems = self.io_chunk_override.unwrap_or_else(|| {
            budget_elems
                .checked_div(fan_in.saturating_add(1).saturating_mul(4))
                .unwrap_or(0)
                .max(MIN_IO_ELEMS)
        });
        // aklint: end(checked-arith)
        StreamPlan { run_chunk_elems, fan_in, io_chunk_elems }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_scales_with_budget_and_dtype() {
        let s = Session::native();
        // 1 MiB of i32: 262144 budget elements, a third to the chunk.
        let p = s.stream(StreamBudget::mib(1)).plan::<i32>();
        assert_eq!(p.run_chunk_elems, 87_381);
        assert_eq!(p.fan_in, MAX_FAN_IN);
        assert!(p.io_chunk_elems >= MIN_IO_ELEMS);
        // Same bytes, wider keys: fewer elements everywhere.
        let p16 = s.stream(StreamBudget::mib(1)).plan::<i128>();
        assert!(p16.run_chunk_elems < p.run_chunk_elems);
        // Tiny budgets clamp to the floors instead of degenerating.
        let tiny = s.stream(StreamBudget::bytes(64)).plan::<i64>();
        assert_eq!(tiny.run_chunk_elems, MIN_RUN_CHUNK);
        assert_eq!(tiny.fan_in, 2);
        assert_eq!(tiny.io_chunk_elems, MIN_IO_ELEMS);
    }

    #[test]
    fn plan_strides_by_record_width() {
        // A (i32, u32) record is 8 bytes — the plan must match the
        // 8-byte scalar plan, not the 4-byte key plan.
        let s = Session::native();
        let rec = s.stream(StreamBudget::mib(1)).plan::<Record<i32, u32>>();
        let i64p = s.stream(StreamBudget::mib(1)).plan::<i64>();
        assert_eq!(rec.run_chunk_elems, i64p.run_chunk_elems);
        assert_eq!(rec.io_chunk_elems, i64p.io_chunk_elems);
        // Scalar layouts are byte-identical to the pre-record plans.
        assert_eq!(s.stream(StreamBudget::mib(1)).plan::<i32>().run_chunk_elems, 87_381);
    }

    #[test]
    fn overrides_pin_the_plan() {
        let ctx = Session::native()
            .stream(StreamBudget::mib(4))
            .run_chunk_elems(5000)
            .fan_in(2)
            .io_chunk_elems(128);
        let p = ctx.plan::<f64>();
        assert_eq!(p.run_chunk_elems, 5000);
        assert_eq!(p.fan_in, 2);
        assert_eq!(p.io_chunk_elems, 128);
        // fan_in floor.
        let floored = Session::native().stream(StreamBudget::mib(1)).fan_in(0);
        assert_eq!(floored.plan::<i32>().fan_in, 2);
    }

    #[test]
    fn budget_constructors() {
        assert_eq!(StreamBudget::mib(2).get(), 2 << 20);
        assert_eq!(StreamBudget::bytes(0).get(), 1);
    }
}
