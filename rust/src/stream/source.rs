//! Chunked data producers and consumers for the streaming pipelines.
//!
//! A [`ChunkSource`] yields a dataset one bounded chunk at a time; a
//! [`ChunkSink`] absorbs ordered output chunks. The engines in
//! [`crate::stream`] only ever hold a budgeted number of elements from
//! either side, so a pipeline's peak memory is set by the
//! [`super::StreamBudget`] — not the dataset.
//!
//! Sources: [`SliceSource`] (an in-memory slice, read in windows),
//! [`GenSource`] (a seeded workload generator — datasets larger than RAM
//! without a file), [`FileSource`] (codec-encoded binary files, the
//! on-disk dataset format shared with [`FileSink`] and the spill store).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::Context;

use crate::stream::record::StreamRecord;
use crate::stream::codec;
use crate::util::Prng;
use crate::workload::{generate, Distribution, KeyGen};

/// A producer of one dataset, pulled in bounded chunks.
pub trait ChunkSource<K: StreamRecord> {
    /// Total elements this source will yield, when known up front.
    fn len_hint(&self) -> Option<u64>;

    /// Clear `buf` and fill it with up to `max` next elements; `Ok(0)`
    /// means the stream is exhausted.
    fn next_chunk(&mut self, buf: &mut Vec<K>, max: usize) -> anyhow::Result<usize>;
}

/// A consumer of ordered output chunks.
pub trait ChunkSink<K: StreamRecord> {
    /// Absorb the next chunk (chunks arrive in output order).
    fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()>;

    /// Flush buffered state; the pipeline calls this exactly once, after
    /// the final chunk.
    fn finish(&mut self) -> anyhow::Result<()>;
}

// ---- sources --------------------------------------------------------------

/// Source over an in-memory slice (windowed reads, no copy of the whole).
pub struct SliceSource<'a, K> {
    data: &'a [K],
    pos: usize,
}

impl<'a, K> SliceSource<'a, K> {
    /// Stream the contents of `data`.
    pub fn new(data: &'a [K]) -> Self {
        SliceSource { data, pos: 0 }
    }
}

impl<K: StreamRecord> ChunkSource<K> for SliceSource<'_, K> {
    fn len_hint(&self) -> Option<u64> {
        Some(self.data.len() as u64)
    }

    fn next_chunk(&mut self, buf: &mut Vec<K>, max: usize) -> anyhow::Result<usize> {
        buf.clear();
        let take = max.min(self.data.len() - self.pos);
        buf.extend_from_slice(&self.data[self.pos..self.pos + take]);
        self.pos += take;
        Ok(take)
    }
}

/// Elements per internal generator block of [`GenSource`]. Generation is
/// blocked at this fixed granule — NOT at the caller's chunk size — so
/// the produced dataset depends only on `(seed, dist, total)`: the same
/// source replayed under a different memory budget (hence different
/// chunk sizes) yields the identical byte stream, which is what lets a
/// bench verify a streamed sort against an in-memory reference built
/// from a second `GenSource` with the same parameters.
pub const GEN_BLOCK: usize = 1 << 16;

/// Seeded workload generator source: `total` keys of `dist`, drawn block
/// by block (distributions are applied per [`GEN_BLOCK`], so globally
/// coherent shapes like `Sorted` become blockwise-shaped — fine for the
/// sorting/fold pipelines, which never assume input order).
pub struct GenSource<K: KeyGen> {
    rng: Prng,
    dist: Distribution,
    total: u64,
    produced: u64,
    block: Vec<K>,
    block_pos: usize,
}

impl<K: KeyGen + StreamRecord> GenSource<K> {
    /// A deterministic stream of `total` keys from `dist` under `seed`.
    pub fn new(seed: u64, dist: Distribution, total: u64) -> Self {
        GenSource {
            rng: Prng::new(seed),
            dist,
            total,
            produced: 0,
            block: Vec::new(),
            block_pos: 0,
        }
    }

    /// Drain the whole stream into one vector (reference/verification
    /// helper — this is exactly what the streamed consumer sees).
    pub fn materialize(mut self) -> Vec<K> {
        let mut out = Vec::with_capacity(self.total as usize);
        let mut buf = Vec::new();
        // aklint: allow(unwrap) — GenSource::next_chunk is infallible (pure PRNG,
        // no I/O); the Result only exists to satisfy the ChunkSource trait.
        while self.next_chunk(&mut buf, GEN_BLOCK).expect("generator never errors") > 0 {
            out.extend_from_slice(&buf);
        }
        out
    }
}

impl<K: KeyGen + StreamRecord> ChunkSource<K> for GenSource<K> {
    fn len_hint(&self) -> Option<u64> {
        Some(self.total)
    }

    fn next_chunk(&mut self, buf: &mut Vec<K>, max: usize) -> anyhow::Result<usize> {
        buf.clear();
        while buf.len() < max && (self.produced < self.total || self.block_pos < self.block.len())
        {
            if self.block_pos >= self.block.len() {
                let n = GEN_BLOCK.min((self.total - self.produced) as usize);
                self.block = generate(&mut self.rng, self.dist, n);
                self.block_pos = 0;
                self.produced += n as u64;
            }
            let take = (max - buf.len()).min(self.block.len() - self.block_pos);
            buf.extend_from_slice(&self.block[self.block_pos..self.block_pos + take]);
            self.block_pos += take;
        }
        Ok(buf.len())
    }
}

/// Source over a codec-encoded binary file (the [`FileSink`] format).
pub struct FileSource<K: StreamRecord> {
    file: File,
    remaining: usize,
    raw: Vec<u8>,
    _marker: std::marker::PhantomData<K>,
}

impl<K: StreamRecord> FileSource<K> {
    /// Open `path`; the element count comes from the file size (the
    /// codec is headerless fixed-width), ragged sizes error.
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let bytes = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len() as usize;
        anyhow::ensure!(
            bytes % K::REC_BYTES == 0,
            "{}: {} bytes is not a whole number of {}-byte {} records",
            path.display(),
            bytes,
            K::REC_BYTES,
            K::layout_name(),
        );
        Ok(FileSource {
            file,
            remaining: bytes / K::REC_BYTES,
            raw: Vec::new(),
            _marker: std::marker::PhantomData,
        })
    }
}

impl<K: StreamRecord> ChunkSource<K> for FileSource<K> {
    fn len_hint(&self) -> Option<u64> {
        // Remaining, which equals the total before the first read.
        Some(self.remaining as u64)
    }

    fn next_chunk(&mut self, buf: &mut Vec<K>, max: usize) -> anyhow::Result<usize> {
        buf.clear();
        let want = max.min(self.remaining);
        if want == 0 {
            return Ok(0);
        }
        self.raw.resize(codec::encoded_len::<K>(want), 0);
        self.file.read_exact(&mut self.raw).context("reading dataset file")?;
        codec::decode_into(&self.raw, buf)?;
        self.remaining -= want;
        Ok(want)
    }
}

// ---- sinks ----------------------------------------------------------------

/// Sink collecting every chunk into one vector (tests / verification).
#[derive(Default)]
pub struct VecSink<K> {
    /// The concatenated output.
    pub out: Vec<K>,
}

impl<K> VecSink<K> {
    /// An empty collector.
    pub fn new() -> Self {
        VecSink { out: Vec::new() }
    }
}

impl<K: StreamRecord> ChunkSink<K> for VecSink<K> {
    fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        self.out.extend_from_slice(chunk);
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Sink writing codec-encoded records to a file ([`FileSource`] format).
pub struct FileSink<K: StreamRecord> {
    w: BufWriter<File>,
    raw: Vec<u8>,
    elems: u64,
    _marker: std::marker::PhantomData<K>,
}

impl<K: StreamRecord> FileSink<K> {
    /// Create/truncate `path`.
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        Ok(FileSink {
            w: BufWriter::new(file),
            raw: Vec::new(),
            elems: 0,
            _marker: std::marker::PhantomData,
        })
    }

    /// Elements written so far.
    pub fn elems(&self) -> u64 {
        self.elems
    }
}

impl<K: StreamRecord> ChunkSink<K> for FileSink<K> {
    fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        self.raw.clear();
        codec::encode_into(chunk, &mut self.raw);
        self.w.write_all(&self.raw).context("writing output file")?;
        self.elems += chunk.len() as u64;
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        self.w.flush().context("flushing output file")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::bits_eq;

    fn drain<K: StreamRecord, S: ChunkSource<K>>(mut src: S, chunk: usize) -> Vec<K> {
        let mut out = Vec::new();
        let mut buf = Vec::new();
        while src.next_chunk(&mut buf, chunk).unwrap() > 0 {
            out.extend_from_slice(&buf);
        }
        out
    }

    #[test]
    fn slice_source_windows() {
        let data: Vec<i32> = (0..1000).collect();
        assert_eq!(drain(SliceSource::new(&data), 7), data);
        assert_eq!(drain(SliceSource::new(&data), 5000), data);
        let empty: Vec<i32> = vec![];
        assert!(drain(SliceSource::new(&empty), 8).is_empty());
    }

    #[test]
    fn gen_source_is_chunk_size_invariant() {
        // The acceptance-critical property: the stream's content must
        // not depend on how the consumer chunks its reads, so two
        // budgets see the same dataset.
        let total = (GEN_BLOCK + GEN_BLOCK / 3) as u64;
        let a: Vec<i64> = drain(GenSource::new(9, Distribution::Uniform, total), 1013);
        let b: Vec<i64> = drain(GenSource::new(9, Distribution::Uniform, total), 1 << 20);
        assert_eq!(a.len() as u64, total);
        assert!(bits_eq(&a, &b));
        let c: Vec<i64> = GenSource::new(9, Distribution::Uniform, total).materialize();
        assert!(bits_eq(&a, &c));
    }

    #[test]
    fn gen_source_len_hint_and_dists() {
        for dist in [Distribution::Uniform, Distribution::DupHeavy, Distribution::Zipf] {
            let src = GenSource::<f32>::new(3, dist, 500);
            assert_eq!(src.len_hint(), Some(500));
            assert_eq!(drain(src, 64).len(), 500);
        }
    }

    #[test]
    fn file_sink_roundtrips_through_file_source() {
        let dir = crate::stream::spill::TempDirGuard::new(None).unwrap();
        let path = dir.path().join("data.bin");
        let data: Vec<f64> =
            vec![f64::NAN, -0.0, 0.0, 3.5, f64::NEG_INFINITY, -2.25, f64::INFINITY];
        let mut sink = FileSink::create(&path).unwrap();
        for chunk in data.chunks(3) {
            sink.push_chunk(chunk).unwrap();
        }
        sink.finish().unwrap();
        assert_eq!(sink.elems(), data.len() as u64);
        let src = FileSource::<f64>::open(&path).unwrap();
        assert_eq!(src.len_hint(), Some(data.len() as u64));
        assert!(bits_eq(&drain(src, 2), &data));
    }

    #[test]
    fn file_source_rejects_ragged_files() {
        let dir = crate::stream::spill::TempDirGuard::new(None).unwrap();
        let path = dir.path().join("ragged.bin");
        std::fs::write(&path, [1u8, 2, 3]).unwrap();
        assert!(FileSource::<i32>::open(&path).is_err());
    }
}
