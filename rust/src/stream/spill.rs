//! Spilled sorted runs and their bounded-memory cursors.
//!
//! A [`SpillStore`] is where `external_sort` parks sorted runs between
//! the run-generation and merge phases. Two media:
//!
//! * [`SpillMedium::Memory`] — runs stay as `Vec<K>` (for tests and
//!   datasets that happen to fit; the pipeline logic is identical).
//! * [`SpillMedium::Disk`] — runs are codec-encoded files inside a
//!   process-unique temp directory owned by a [`TempDirGuard`], which
//!   removes the whole directory on `Drop` — including during a panic
//!   unwind, so an aborted sort never leaks spill files.
//!
//! Runs are written incrementally through a [`RunWriter`] (merge output
//! never materialises in memory) and read back through a [`SpillCursor`],
//! a [`RunCursor`] whose refill buffer is the unit of budget accounting
//! for merge fan-in (DESIGN.md §13).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Context;

use crate::baselines::kmerge::RunCursor;
use crate::dtype::SortKey;
use crate::stream::codec;
use crate::stream::source::{ChunkSink, ChunkSource};

/// Where spilled runs live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillMedium {
    /// Runs held as plain vectors (no I/O).
    Memory,
    /// Runs codec-encoded into files under a guarded temp directory.
    Disk,
}

/// An owned temp directory removed on `Drop` (panic-safe: `Drop` runs
/// during unwinding, so spill files are cleaned even when a sink or
/// engine panics mid-pipeline — tested in `rust/tests/stream_pipeline.rs`).
#[derive(Debug)]
pub struct TempDirGuard {
    path: PathBuf,
}

/// Process-wide counter making sibling guard paths unique.
static GUARD_SEQ: AtomicU64 = AtomicU64::new(0);

impl TempDirGuard {
    /// Create `akstream-<pid>-<seq>` under `parent` (default: the OS
    /// temp dir).
    pub fn new(parent: Option<&Path>) -> anyhow::Result<TempDirGuard> {
        let base = parent.map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
        let seq = GUARD_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = base.join(format!("akstream-{}-{seq}", std::process::id()));
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating spill dir {}", path.display()))?;
        Ok(TempDirGuard { path })
    }

    /// The guarded directory.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDirGuard {
    fn drop(&mut self) {
        // Best effort: a failed cleanup must not turn an unwind into an
        // abort, and the OS temp dir reaps leftovers eventually.
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// One sorted run parked in the store. File-backed runs delete their
/// file on `Drop`, so intermediate runs consumed by a merge pass free
/// their disk as soon as the pass retires them.
#[derive(Debug)]
pub enum SpillRun<K: SortKey> {
    /// In-memory run.
    Mem(Vec<K>),
    /// Codec-encoded file of `elems` records.
    File {
        /// Path inside the store's guarded directory.
        path: PathBuf,
        /// Record count (validated against the file size on write).
        elems: usize,
    },
}

impl<K: SortKey> SpillRun<K> {
    /// Elements in the run.
    pub fn elems(&self) -> usize {
        match self {
            SpillRun::Mem(v) => v.len(),
            SpillRun::File { elems, .. } => *elems,
        }
    }

    /// Open a bounded-memory cursor over the run; `buf_elems` is the
    /// refill granule for file-backed runs (in-memory runs borrow).
    pub fn cursor(&self, buf_elems: usize) -> anyhow::Result<SpillCursor<'_, K>> {
        match self {
            SpillRun::Mem(v) => Ok(SpillCursor {
                mem: Some(v),
                pos: 0,
                file: None,
                remaining: 0,
                buf: Vec::new(),
                raw: Vec::new(),
                buf_elems: 0,
            }),
            SpillRun::File { path, elems } => {
                let file =
                    File::open(path).with_context(|| format!("opening run {}", path.display()))?;
                let mut c = SpillCursor {
                    mem: None,
                    pos: 0,
                    file: Some(file),
                    remaining: *elems,
                    buf: Vec::new(),
                    raw: Vec::new(),
                    buf_elems: buf_elems.max(1),
                };
                c.refill()?;
                Ok(c)
            }
        }
    }
}

impl<K: SortKey> Drop for SpillRun<K> {
    fn drop(&mut self) {
        if let SpillRun::File { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Factory + accounting for spilled runs. The store owns the temp-dir
/// guard; run files live inside it, so dropping the store (normally or
/// through a panic) removes every spill at once.
#[derive(Debug)]
pub struct SpillStore {
    medium: SpillMedium,
    /// Parent for the guarded dir (`None`: OS temp dir). Lazy so a
    /// memory-medium store never touches the filesystem.
    parent: Option<PathBuf>,
    guard: Option<TempDirGuard>,
    next_id: u64,
    runs_written: u64,
    bytes_spilled: u64,
}

impl SpillStore {
    /// A store on the given medium; `spill_parent` overrides where the
    /// disk medium puts its guarded directory.
    pub fn new(medium: SpillMedium, spill_parent: Option<PathBuf>) -> SpillStore {
        SpillStore {
            medium,
            parent: spill_parent,
            guard: None,
            next_id: 0,
            runs_written: 0,
            bytes_spilled: 0,
        }
    }

    /// Runs written so far.
    pub fn runs_written(&self) -> u64 {
        self.runs_written
    }

    /// Bytes written to disk so far (0 on the memory medium).
    pub fn bytes_spilled(&self) -> u64 {
        self.bytes_spilled
    }

    /// The guarded spill directory, if one has been created.
    pub fn dir(&self) -> Option<&Path> {
        self.guard.as_ref().map(TempDirGuard::path)
    }

    fn ensure_dir(&mut self) -> anyhow::Result<&Path> {
        if self.guard.is_none() {
            self.guard = Some(TempDirGuard::new(self.parent.as_deref())?);
        }
        Ok(self.guard.as_ref().unwrap().path())
    }

    /// Start a new run; feed it sorted chunks, then [`RunWriter::finish`].
    pub fn run_writer<K: SortKey>(&mut self) -> anyhow::Result<RunWriter<'_, K>> {
        let sink = match self.medium {
            SpillMedium::Memory => RunWriterSink::Mem(Vec::new()),
            SpillMedium::Disk => {
                let id = self.next_id;
                self.next_id += 1;
                let path = self.ensure_dir()?.join(format!("run-{id}.bin"));
                let file = File::create(&path)
                    .with_context(|| format!("creating run {}", path.display()))?;
                RunWriterSink::File { w: BufWriter::new(file), path, elems: 0, raw: Vec::new() }
            }
        };
        Ok(RunWriter { store: self, sink })
    }

    /// Write one fully-materialised sorted run (run-generation path).
    pub fn write_run<K: SortKey>(&mut self, sorted: &[K]) -> anyhow::Result<SpillRun<K>> {
        let mut w = self.run_writer::<K>()?;
        w.push_chunk(sorted)?;
        w.finish()
    }
}

enum RunWriterSink<K: SortKey> {
    Mem(Vec<K>),
    File { w: BufWriter<File>, path: PathBuf, elems: usize, raw: Vec<u8> },
}

/// Incremental writer for one spilled run (merge output streams through
/// here chunk by chunk, never materialising the full run in memory).
pub struct RunWriter<'s, K: SortKey> {
    store: &'s mut SpillStore,
    sink: RunWriterSink<K>,
}

impl<K: SortKey> RunWriter<'_, K> {
    /// Append one sorted chunk.
    pub fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        match &mut self.sink {
            RunWriterSink::Mem(v) => v.extend_from_slice(chunk),
            RunWriterSink::File { w, elems, raw, .. } => {
                raw.clear();
                codec::encode_into(chunk, raw);
                w.write_all(raw).context("writing spill run")?;
                *elems += chunk.len();
                self.store.bytes_spilled += raw.len() as u64;
            }
        }
        Ok(())
    }

    /// Flush and hand back the finished run.
    pub fn finish(self) -> anyhow::Result<SpillRun<K>> {
        self.store.runs_written += 1;
        match self.sink {
            RunWriterSink::Mem(v) => Ok(SpillRun::Mem(v)),
            RunWriterSink::File { mut w, path, elems, .. } => {
                w.flush().context("flushing spill run")?;
                Ok(SpillRun::File { path, elems })
            }
        }
    }
}

/// [`ChunkSource`] view of a parked [`SpillRun`]: lets the streaming
/// folds re-read a run under the same bounded-memory contract the merge
/// cursors obey. The streamed SIHSort rank reads its sorted shard back
/// this way — splitter sampling and histogram rank measurement consume
/// the run chunk by chunk instead of materialising it (DESIGN.md §14).
pub struct SpillRunSource<'r, K: SortKey> {
    cur: SpillCursor<'r, K>,
    remaining: u64,
}

impl<'r, K: SortKey> SpillRunSource<'r, K> {
    /// Open a chunked reader over `run`; `buf_elems` bounds the refill
    /// buffer for file-backed runs.
    pub fn new(run: &'r SpillRun<K>, buf_elems: usize) -> anyhow::Result<Self> {
        Ok(SpillRunSource { cur: run.cursor(buf_elems)?, remaining: run.elems() as u64 })
    }
}

impl<K: SortKey> ChunkSource<K> for SpillRunSource<'_, K> {
    fn len_hint(&self) -> Option<u64> {
        // Remaining, which equals the total before the first read.
        Some(self.remaining)
    }

    fn next_chunk(&mut self, buf: &mut Vec<K>, max: usize) -> anyhow::Result<usize> {
        buf.clear();
        while buf.len() < max {
            match self.cur.head() {
                Some(k) => {
                    buf.push(k);
                    self.cur.advance()?;
                }
                None => break,
            }
        }
        self.remaining -= buf.len() as u64;
        Ok(buf.len())
    }
}

/// [`ChunkSink`] writing one spilled
/// run into a [`SpillStore`] — the glue that lets `external_sort` park
/// its output as a run later pipeline stages (the streamed exchange,
/// the splitter sampler) re-read under the budget. The pipeline's
/// `finish` call seals the run; take it with [`RunSink::into_run`].
pub struct RunSink<'s, K: SortKey> {
    writer: Option<RunWriter<'s, K>>,
    run: Option<SpillRun<K>>,
}

impl<'s, K: SortKey> RunSink<'s, K> {
    /// Start a new run in `store`.
    pub fn new(store: &'s mut SpillStore) -> anyhow::Result<Self> {
        Ok(RunSink { writer: Some(store.run_writer()?), run: None })
    }

    /// The sealed run (errors if the pipeline never called `finish`).
    pub fn into_run(self) -> anyhow::Result<SpillRun<K>> {
        self.run.ok_or_else(|| anyhow::anyhow!("RunSink::into_run before finish"))
    }
}

impl<K: SortKey> ChunkSink<K> for RunSink<'_, K> {
    fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        self.writer.as_mut().context("RunSink already finished")?.push_chunk(chunk)
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        let w = self.writer.take().context("RunSink finished twice")?;
        self.run = Some(w.finish()?);
        Ok(())
    }
}

/// Bounded-memory [`RunCursor`] over a [`SpillRun`]: in-memory runs
/// borrow their vector; file runs hold one decoded buffer of at most
/// `buf_elems` keys and refill from disk as the merge drains them.
pub struct SpillCursor<'r, K: SortKey> {
    mem: Option<&'r [K]>,
    /// Position in `mem` (memory runs) or in `buf` (file runs).
    pos: usize,
    file: Option<File>,
    /// Records not yet pulled into `buf`.
    remaining: usize,
    buf: Vec<K>,
    raw: Vec<u8>,
    buf_elems: usize,
}

impl<K: SortKey> SpillCursor<'_, K> {
    fn refill(&mut self) -> anyhow::Result<()> {
        let Some(file) = self.file.as_mut() else {
            return Ok(());
        };
        self.buf.clear();
        self.pos = 0;
        let want = self.buf_elems.min(self.remaining);
        if want == 0 {
            return Ok(());
        }
        let bytes = codec::encoded_len::<K>(want);
        self.raw.resize(bytes, 0);
        file.read_exact(&mut self.raw).context("reading spill run")?;
        codec::decode_into(&self.raw, &mut self.buf)?;
        self.remaining -= want;
        Ok(())
    }
}

impl<K: SortKey> RunCursor<K> for SpillCursor<'_, K> {
    fn head(&self) -> Option<K> {
        match self.mem {
            Some(m) => m.get(self.pos).copied(),
            None => self.buf.get(self.pos).copied(),
        }
    }

    fn advance(&mut self) -> anyhow::Result<()> {
        self.pos += 1;
        if self.mem.is_none() && self.pos >= self.buf.len() && self.remaining > 0 {
            self.refill()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::bits_eq;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    fn sorted_keys(seed: u64, n: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        xs.sort_unstable_by(|a, b| a.cmp_total(b));
        xs
    }

    fn drain<K: SortKey>(run: &SpillRun<K>, buf_elems: usize) -> Vec<K> {
        let mut c = run.cursor(buf_elems).unwrap();
        let mut out = Vec::new();
        while let Some(k) = c.head() {
            out.push(k);
            c.advance().unwrap();
        }
        out
    }

    #[test]
    fn memory_and_disk_runs_roundtrip() {
        let xs = sorted_keys(1, 5000);
        for medium in [SpillMedium::Memory, SpillMedium::Disk] {
            let mut store = SpillStore::new(medium, None);
            let run = store.write_run(&xs).unwrap();
            assert_eq!(run.elems(), xs.len());
            // Tiny refill buffer exercises many refills.
            assert!(bits_eq(&drain(&run, 64), &xs), "{medium:?}");
            assert_eq!(store.runs_written(), 1);
        }
    }

    #[test]
    fn incremental_writer_equals_one_shot() {
        let xs = sorted_keys(2, 3000);
        let mut store = SpillStore::new(SpillMedium::Disk, None);
        let mut w = store.run_writer::<f64>().unwrap();
        for chunk in xs.chunks(701) {
            w.push_chunk(chunk).unwrap();
        }
        let run = w.finish().unwrap();
        assert_eq!(run.elems(), xs.len());
        assert!(bits_eq(&drain(&run, 97), &xs));
        assert_eq!(store.bytes_spilled(), codec::encoded_len::<f64>(xs.len()) as u64);
    }

    #[test]
    fn run_sink_and_source_roundtrip() {
        use crate::stream::source::{ChunkSink, ChunkSource};
        let xs = sorted_keys(3, 4000);
        for medium in [SpillMedium::Memory, SpillMedium::Disk] {
            let mut store = SpillStore::new(medium, None);
            let mut sink = RunSink::new(&mut store).unwrap();
            for c in xs.chunks(333) {
                sink.push_chunk(c).unwrap();
            }
            ChunkSink::finish(&mut sink).unwrap();
            let run = sink.into_run().unwrap();
            assert_eq!(run.elems(), xs.len());
            let mut src = SpillRunSource::new(&run, 128).unwrap();
            assert_eq!(src.len_hint(), Some(xs.len() as u64));
            let mut out = Vec::new();
            let mut buf = Vec::new();
            while src.next_chunk(&mut buf, 97).unwrap() > 0 {
                out.extend_from_slice(&buf);
            }
            assert!(bits_eq(&out, &xs), "{medium:?}");
        }
    }

    #[test]
    fn run_sink_guards_the_finish_protocol() {
        let mut store = SpillStore::new(SpillMedium::Memory, None);
        let sink = RunSink::<i32>::new(&mut store).unwrap();
        assert!(sink.into_run().is_err(), "into_run before finish must error");
    }

    #[test]
    fn run_files_deleted_on_drop() {
        let mut store = SpillStore::new(SpillMedium::Disk, None);
        let run = store.write_run(&[1i32, 2, 3]).unwrap();
        let path = match &run {
            SpillRun::File { path, .. } => path.clone(),
            _ => unreachable!("disk store produced a memory run"),
        };
        assert!(path.exists());
        drop(run);
        assert!(!path.exists(), "run file must be deleted when retired");
        // The guarded dir itself disappears with the store.
        let dir = store.dir().unwrap().to_path_buf();
        drop(store);
        assert!(!dir.exists());
    }

    #[test]
    fn tempdir_guard_cleans_on_panic() {
        // The guard's Drop must run during unwinding: a panicking
        // pipeline leaves no spill directory behind.
        let captured = std::sync::Arc::new(std::sync::Mutex::new(PathBuf::new()));
        let cap = captured.clone();
        let result = std::panic::catch_unwind(move || {
            let guard = TempDirGuard::new(None).unwrap();
            std::fs::write(guard.path().join("run-0.bin"), b"abc").unwrap();
            *cap.lock().unwrap() = guard.path().to_path_buf();
            panic!("mid-pipeline failure");
        });
        assert!(result.is_err());
        let path = captured.lock().unwrap().clone();
        assert!(!path.as_os_str().is_empty());
        assert!(!path.exists(), "guarded dir {} must be removed on panic", path.display());
    }

    #[test]
    fn memory_store_touches_no_filesystem() {
        let mut store = SpillStore::new(SpillMedium::Memory, None);
        let _ = store.write_run(&[5i64, 6]).unwrap();
        assert_eq!(store.dir(), None);
        assert_eq!(store.bytes_spilled(), 0);
    }
}
