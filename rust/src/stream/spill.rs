//! Spilled sorted runs and their bounded-memory cursors.
//!
//! A [`SpillStore`] is where `external_sort` parks sorted runs between
//! the run-generation and merge phases. Two media:
//!
//! * [`SpillMedium::Memory`] — runs stay as `Vec<K>` (for tests and
//!   datasets that happen to fit; the pipeline logic is identical).
//! * [`SpillMedium::Disk`] — runs are codec-encoded files inside a
//!   process-unique temp directory owned by a [`TempDirGuard`], which
//!   removes the whole directory on `Drop` — including during a panic
//!   unwind, so an aborted sort never leaks spill files.
//!
//! Runs are written incrementally through a [`RunWriter`] (merge output
//! never materialises in memory) and read back through a [`SpillCursor`],
//! a [`RunCursor`] whose refill buffer is the unit of budget accounting
//! for merge fan-in (DESIGN.md §13).
//!
//! A store can additionally be *checkpointed*
//! ([`SpillStore::checkpointed`]): it then lives in a caller-named
//! durable directory with a [`crate::stream::manifest::Manifest`]
//! recording which runs are real, every recorded run file is fsynced
//! before the manifest references it, and the temp-dir guard preserves
//! the directory across crashes (sweeping only unmanifested orphans)
//! instead of deleting it — the substrate of crash/resume
//! (DESIGN.md §15).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Context;

use crate::baselines::kmerge::RunCursor;
use crate::stream::record::StreamRecord;
use crate::stream::codec;
use crate::stream::manifest::{self, Manifest, RunMeta};
use crate::stream::source::{ChunkSink, ChunkSource};

/// Where spilled runs live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillMedium {
    /// Runs held as plain vectors (no I/O).
    Memory,
    /// Runs codec-encoded into files under a guarded temp directory.
    Disk,
}

/// An owned temp directory removed on `Drop` (panic-safe: `Drop` runs
/// during unwinding, so spill files are cleaned even when a sink or
/// engine panics mid-pipeline — tested in `rust/tests/stream_pipeline.rs`).
#[derive(Debug)]
pub struct TempDirGuard {
    path: PathBuf,
}

/// Process-wide counter making sibling guard paths unique.
static GUARD_SEQ: AtomicU64 = AtomicU64::new(0);

/// Name prefix of every [`TempDirGuard::new`] directory. Shared with
/// [`crate::obs`] so trace outputs requested under a spill dir can be
/// remapped outside the guard's tree before `Drop` removes it.
pub const TEMP_DIR_PREFIX: &str = "akstream-";

impl TempDirGuard {
    /// Create `akstream-<pid>-<seq>` under `parent` (default: the OS
    /// temp dir).
    pub fn new(parent: Option<&Path>) -> anyhow::Result<TempDirGuard> {
        let base = parent.map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
        let seq = GUARD_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = base.join(format!("{TEMP_DIR_PREFIX}{}-{seq}", std::process::id()));
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating spill dir {}", path.display()))?;
        Ok(TempDirGuard { path })
    }

    /// Guard a caller-named durable directory (checkpointed stores).
    /// Created if missing; unlike [`TempDirGuard::new`] dirs it is
    /// expected to outlive crashes — `Drop` keeps it whenever a
    /// manifest is present.
    pub fn at(path: &Path) -> anyhow::Result<TempDirGuard> {
        std::fs::create_dir_all(path)
            .with_context(|| format!("creating checkpoint dir {}", path.display()))?;
        Ok(TempDirGuard { path: path.to_path_buf() })
    }

    /// The guarded directory.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDirGuard {
    fn drop(&mut self) {
        // A manifest marks the directory as checkpointed state that
        // must survive this process (including a panic unwind): keep
        // it, reclaiming only files the manifest does not vouch for.
        if self.path.join(manifest::MANIFEST_FILE).exists() {
            if let Ok(Some(m)) = manifest::load_manifest(&self.path) {
                let _ = manifest::sweep_unmanifested(&self.path, &m);
            }
            return;
        }
        // Best effort: a failed cleanup must not turn an unwind into an
        // abort, and the OS temp dir reaps leftovers eventually.
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// One sorted run parked in the store. File-backed runs delete their
/// file on `Drop`, so intermediate runs consumed by a merge pass free
/// their disk as soon as the pass retires them.
#[derive(Debug)]
pub enum SpillRun<K: StreamRecord> {
    /// In-memory run.
    Mem(Vec<K>),
    /// Codec-encoded file of `elems` records.
    File {
        /// Path inside the store's guarded directory.
        path: PathBuf,
        /// Record count (validated against the file size on write).
        elems: usize,
        /// True once a manifest references the file: `Drop` then leaves
        /// it on disk for a later resume instead of deleting it.
        keep: bool,
    },
}

impl<K: StreamRecord> SpillRun<K> {
    /// Elements in the run.
    pub fn elems(&self) -> usize {
        match self {
            SpillRun::Mem(v) => v.len(),
            SpillRun::File { elems, .. } => *elems,
        }
    }

    /// Mark a file-backed run durable (`keep = true`: survives `Drop`)
    /// or reclaimable. No-op for in-memory runs.
    pub fn persist(&mut self, durable: bool) {
        if let SpillRun::File { keep, .. } = self {
            *keep = durable;
        }
    }

    /// The backing file of a disk run.
    pub fn path(&self) -> Option<&Path> {
        match self {
            SpillRun::Mem(_) => None,
            SpillRun::File { path, .. } => Some(path),
        }
    }

    /// Open a bounded-memory cursor over the run; `buf_elems` is the
    /// refill granule for file-backed runs (in-memory runs borrow).
    pub fn cursor(&self, buf_elems: usize) -> anyhow::Result<SpillCursor<'_, K>> {
        match self {
            SpillRun::Mem(v) => Ok(SpillCursor {
                mem: Some(v),
                pos: 0,
                file: None,
                remaining: 0,
                buf: Vec::new(),
                raw: Vec::new(),
                buf_elems: 0,
            }),
            SpillRun::File { path, elems, .. } => {
                crate::obs::instant2(
                    crate::obs::SpanKind::SpillRead,
                    "spill.open-cursor",
                    *elems as u64,
                );
                let file =
                    File::open(path).with_context(|| format!("opening run {}", path.display()))?;
                let mut c = SpillCursor {
                    mem: None,
                    pos: 0,
                    file: Some(file),
                    remaining: *elems,
                    buf: Vec::new(),
                    raw: Vec::new(),
                    buf_elems: buf_elems.max(1),
                };
                c.refill()?;
                Ok(c)
            }
        }
    }
}

impl<K: StreamRecord> Drop for SpillRun<K> {
    fn drop(&mut self) {
        if let SpillRun::File { path, keep, .. } = self {
            if !*keep {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

/// Factory + accounting for spilled runs. The store owns the temp-dir
/// guard; run files live inside it, so dropping the store (normally or
/// through a panic) removes every spill at once.
#[derive(Debug)]
pub struct SpillStore {
    medium: SpillMedium,
    /// Parent for the guarded dir (`None`: OS temp dir). Lazy so a
    /// memory-medium store never touches the filesystem.
    parent: Option<PathBuf>,
    guard: Option<TempDirGuard>,
    next_id: u64,
    runs_written: u64,
    bytes_spilled: u64,
    /// The durable manifest of a checkpointed store (DESIGN.md §15).
    ckpt: Option<Manifest>,
}

impl SpillStore {
    /// A store on the given medium; `spill_parent` overrides where the
    /// disk medium puts its guarded directory.
    pub fn new(medium: SpillMedium, spill_parent: Option<PathBuf>) -> SpillStore {
        SpillStore {
            medium,
            parent: spill_parent,
            guard: None,
            next_id: 0,
            runs_written: 0,
            bytes_spilled: 0,
            ckpt: None,
        }
    }

    /// A manifest-backed store rooted at the durable directory `dir`.
    ///
    /// Checkpointing implies the disk medium regardless of the job's
    /// configured spill medium — memory cannot survive the crash the
    /// checkpoint exists for. With `resume = false` any previous
    /// contents of `dir` are cleared and a fresh manifest written; with
    /// `resume = true` an existing manifest is validated against
    /// `(kind, tag, dtype, run_chunk)`, unmanifested crash orphans are
    /// swept, and recording resumes where the manifest left off (no
    /// manifest at all — e.g. a crash before the first write — starts
    /// fresh).
    ///
    /// `dtype` is the record *layout* name
    /// ([`StreamRecord::layout_name`]): bare dtype names for scalar
    /// layouts (unchanged manifest identity for every pre-record
    /// checkpoint) and `"<key>+p<bytes>"` for record layouts, so a
    /// resume against a different layout is a typed identity error
    /// here, never a mis-strided decode.
    pub fn checkpointed(
        dir: &Path,
        kind: &str,
        tag: &str,
        dtype: &str,
        run_chunk: u64,
        resume: bool,
    ) -> anyhow::Result<SpillStore> {
        let guard = TempDirGuard::at(dir)?;
        let existing = if resume { manifest::load_manifest(dir)? } else { None };
        let m = match existing {
            Some(m) => {
                anyhow::ensure!(
                    m.kind == kind && m.tag == tag,
                    "checkpoint {} holds job '{}/{}' but the resume asked for '{kind}/{tag}'",
                    dir.display(),
                    m.kind,
                    m.tag,
                );
                anyhow::ensure!(
                    m.dtype == dtype,
                    "checkpoint {} was written for record layout {} (resume runs {dtype})",
                    dir.display(),
                    m.dtype,
                );
                anyhow::ensure!(
                    m.run_chunk == run_chunk,
                    "checkpoint {} used run chunk {} (resume derived {run_chunk}; \
                     the budget must not change across a resume)",
                    dir.display(),
                    m.run_chunk,
                );
                manifest::sweep_unmanifested(dir, &m)?;
                m
            }
            None => {
                manifest::clear_dir(dir)?;
                let m = Manifest::new(kind, tag, dtype, run_chunk);
                manifest::write_manifest(dir, &m)?;
                m
            }
        };
        Ok(SpillStore {
            medium: SpillMedium::Disk,
            parent: None,
            guard: Some(guard),
            next_id: m.next_seq,
            runs_written: 0,
            bytes_spilled: 0,
            ckpt: Some(m),
        })
    }

    /// True when the store is manifest-backed.
    pub fn is_checkpointed(&self) -> bool {
        self.ckpt.is_some()
    }

    /// The durable manifest (checkpointed stores only).
    pub fn manifest(&self) -> Option<&Manifest> {
        self.ckpt.as_ref()
    }

    fn ckpt_dir(&self) -> anyhow::Result<&Path> {
        self.guard
            .as_ref()
            .map(TempDirGuard::path)
            .ok_or_else(|| anyhow::anyhow!("store is not checkpointed"))
    }

    fn persist_manifest(&self) -> anyhow::Result<()> {
        let m = self.ckpt.as_ref().ok_or_else(|| anyhow::anyhow!("store is not checkpointed"))?;
        manifest::write_manifest(self.ckpt_dir()?, m)
    }

    /// Mutate the manifest and atomically persist it in one step.
    pub fn update(&mut self, f: impl FnOnce(&mut Manifest)) -> anyhow::Result<()> {
        let m = self.ckpt.as_mut().ok_or_else(|| anyhow::anyhow!("store is not checkpointed"))?;
        f(m);
        self.persist_manifest()
    }

    /// Record a finished (fsynced) run in the manifest under
    /// `(pass, seq)` and mark it durable — after this returns, the run
    /// survives a crash and `Drop`.
    pub fn record_run<K: StreamRecord>(
        &mut self,
        run: &mut SpillRun<K>,
        pass: u32,
        seq: u64,
    ) -> anyhow::Result<()> {
        let meta = self.meta_of(run, pass, seq)?;
        let next_id = self.next_id;
        self.update(|m| {
            m.runs.push(meta);
            m.next_seq = next_id;
        })?;
        run.persist(true);
        Ok(())
    }

    /// Atomically replace `inputs` with the merged `out` run in the
    /// manifest (one rename covers retire + record), then mark `out`
    /// durable and drop the inputs, deleting their files.
    pub fn commit_merge<K: StreamRecord>(
        &mut self,
        out: &mut SpillRun<K>,
        inputs: Vec<SpillRun<K>>,
        pass: u32,
        seq: u64,
    ) -> anyhow::Result<()> {
        let meta = self.meta_of(out, pass, seq)?;
        let gone: Vec<String> = inputs
            .iter()
            .filter_map(|r| r.path())
            .filter_map(|p| p.file_name().map(|n| n.to_string_lossy().into_owned()))
            .collect();
        let next_id = self.next_id;
        self.update(|m| {
            m.runs.retain(|r| !gone.contains(&r.file));
            m.runs.push(meta);
            m.next_seq = next_id;
        })?;
        out.persist(true);
        for mut r in inputs {
            r.persist(false);
        }
        Ok(())
    }

    /// Drop every manifested run matching `pred` (stale state from a
    /// crash between batch records and the phase commit): one atomic
    /// manifest rewrite, then the files are deleted.
    pub fn retire_runs(&mut self, pred: impl Fn(&RunMeta) -> bool) -> anyhow::Result<()> {
        let retired: Vec<RunMeta> = self
            .ckpt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("store is not checkpointed"))?
            .runs
            .iter()
            .filter(|r| pred(r))
            .cloned()
            .collect();
        if retired.is_empty() {
            return Ok(());
        }
        self.update(|m| m.runs.retain(|r| !pred(r)))?;
        let dir = self.ckpt_dir()?.to_path_buf();
        for r in &retired {
            let _ = std::fs::remove_file(dir.join(&r.file));
        }
        Ok(())
    }

    /// Reopen a manifested run from a previous process incarnation,
    /// validating the file is present and exactly the recorded size.
    pub fn open_manifested_run<K: StreamRecord>(
        &self,
        meta: &RunMeta,
    ) -> anyhow::Result<SpillRun<K>> {
        let path = self.ckpt_dir()?.join(&meta.file);
        let md = std::fs::metadata(&path)
            .with_context(|| format!("manifested run {} is missing", path.display()))?;
        let want = codec::encoded_len::<K>(meta.elems as usize) as u64;
        anyhow::ensure!(
            md.len() == want,
            "manifested run {} is {} bytes, manifest says {want}",
            path.display(),
            md.len(),
        );
        Ok(SpillRun::File { path, elems: meta.elems as usize, keep: true })
    }

    fn meta_of<K: StreamRecord>(
        &self,
        run: &SpillRun<K>,
        pass: u32,
        seq: u64,
    ) -> anyhow::Result<RunMeta> {
        let path = run
            .path()
            .ok_or_else(|| anyhow::anyhow!("checkpointed runs must be file-backed"))?;
        let file = path
            .file_name()
            .ok_or_else(|| anyhow::anyhow!("run path {} has no file name", path.display()))?
            .to_string_lossy()
            .into_owned();
        Ok(RunMeta { file, elems: run.elems() as u64, pass, seq })
    }

    /// Runs written so far.
    pub fn runs_written(&self) -> u64 {
        self.runs_written
    }

    /// Bytes written to disk so far (0 on the memory medium).
    pub fn bytes_spilled(&self) -> u64 {
        self.bytes_spilled
    }

    /// The guarded spill directory, if one has been created.
    pub fn dir(&self) -> Option<&Path> {
        self.guard.as_ref().map(TempDirGuard::path)
    }

    fn ensure_dir(&mut self) -> anyhow::Result<&Path> {
        if self.guard.is_none() {
            self.guard = Some(TempDirGuard::new(self.parent.as_deref())?);
        }
        match self.guard.as_ref() {
            Some(g) => Ok(g.path()),
            None => Err(anyhow::anyhow!("spill dir guard vanished after creation")),
        }
    }

    /// Start a new run; feed it sorted chunks, then [`RunWriter::finish`].
    pub fn run_writer<K: StreamRecord>(&mut self) -> anyhow::Result<RunWriter<'_, K>> {
        let sink = match self.medium {
            SpillMedium::Memory => RunWriterSink::Mem(Vec::new()),
            SpillMedium::Disk => {
                let id = self.next_id;
                self.next_id += 1;
                let path = self.ensure_dir()?.join(format!("run-{id}.bin"));
                let file = File::create(&path)
                    .with_context(|| format!("creating run {}", path.display()))?;
                RunWriterSink::File { w: BufWriter::new(file), path, elems: 0, raw: Vec::new() }
            }
        };
        Ok(RunWriter { store: self, sink })
    }

    /// Write one fully-materialised sorted run (run-generation path).
    pub fn write_run<K: StreamRecord>(&mut self, sorted: &[K]) -> anyhow::Result<SpillRun<K>> {
        let _span = crate::obs::span1(
            crate::obs::SpanKind::SpillWrite,
            "spill.write-run",
            sorted.len() as u64,
        );
        let mut w = self.run_writer::<K>()?;
        w.push_chunk(sorted)?;
        w.finish()
    }

    /// Start a run writer that does **not** borrow the store, so several
    /// can be open at once — the interleaved streamed exchange holds one
    /// per source rank while messages arrive in credit-paced order
    /// (DESIGN.md §16). The run id/file is reserved here; byte and run
    /// accounting land at [`DetachedRunWriter::finish`].
    pub fn detached_run_writer<K: StreamRecord>(&mut self) -> anyhow::Result<DetachedRunWriter<K>> {
        let sink = match self.medium {
            SpillMedium::Memory => RunWriterSink::Mem(Vec::new()),
            SpillMedium::Disk => {
                let id = self.next_id;
                self.next_id += 1;
                let path = self.ensure_dir()?.join(format!("run-{id}.bin"));
                let file = File::create(&path)
                    .with_context(|| format!("creating run {}", path.display()))?;
                RunWriterSink::File { w: BufWriter::new(file), path, elems: 0, raw: Vec::new() }
            }
        };
        Ok(DetachedRunWriter { sink, spilled: 0 })
    }
}

enum RunWriterSink<K: StreamRecord> {
    Mem(Vec<K>),
    File { w: BufWriter<File>, path: PathBuf, elems: usize, raw: Vec<u8> },
}

/// Incremental writer for one spilled run (merge output streams through
/// here chunk by chunk, never materialising the full run in memory).
pub struct RunWriter<'s, K: StreamRecord> {
    store: &'s mut SpillStore,
    sink: RunWriterSink<K>,
}

impl<K: StreamRecord> RunWriter<'_, K> {
    /// Append one sorted chunk.
    pub fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        match &mut self.sink {
            RunWriterSink::Mem(v) => v.extend_from_slice(chunk),
            RunWriterSink::File { w, elems, raw, .. } => {
                raw.clear();
                codec::encode_into(chunk, raw);
                w.write_all(raw).context("writing spill run")?;
                *elems += chunk.len();
                self.store.bytes_spilled += raw.len() as u64;
            }
        }
        Ok(())
    }

    /// Flush and hand back the finished run. In a checkpointed store
    /// the file is fsynced here, **before** any manifest can reference
    /// it — the manifest must never vouch for bytes still in the page
    /// cache (DESIGN.md §15).
    pub fn finish(self) -> anyhow::Result<SpillRun<K>> {
        self.store.runs_written += 1;
        match self.sink {
            RunWriterSink::Mem(v) => Ok(SpillRun::Mem(v)),
            RunWriterSink::File { mut w, path, elems, .. } => {
                w.flush().context("flushing spill run")?;
                if self.store.ckpt.is_some() {
                    w.get_ref()
                        .sync_all()
                        .with_context(|| format!("fsync run {}", path.display()))?;
                }
                Ok(SpillRun::File { path, elems, keep: false })
            }
        }
    }
}

/// A run writer that owns its sink instead of borrowing the store (see
/// [`SpillStore::detached_run_writer`]): the streamed exchange keeps
/// one open per source rank simultaneously. Must be finished against
/// the store that created it so spill accounting stays consistent.
pub struct DetachedRunWriter<K: StreamRecord> {
    sink: RunWriterSink<K>,
    /// Bytes written through this writer (folded into the store's
    /// `bytes_spilled` at finish).
    spilled: u64,
}

impl<K: StreamRecord> DetachedRunWriter<K> {
    /// Append one sorted chunk.
    pub fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        match &mut self.sink {
            RunWriterSink::Mem(v) => v.extend_from_slice(chunk),
            RunWriterSink::File { w, elems, raw, .. } => {
                raw.clear();
                codec::encode_into(chunk, raw);
                w.write_all(raw).context("writing spill run")?;
                *elems += chunk.len();
                self.spilled += raw.len() as u64;
            }
        }
        Ok(())
    }

    /// Elements written so far.
    pub fn elems(&self) -> usize {
        match &self.sink {
            RunWriterSink::Mem(v) => v.len(),
            RunWriterSink::File { elems, .. } => *elems,
        }
    }

    /// Flush, settle accounting on `store`, and hand back the finished
    /// run (fsynced first when the store is checkpointed, same contract
    /// as [`RunWriter::finish`]).
    pub fn finish(self, store: &mut SpillStore) -> anyhow::Result<SpillRun<K>> {
        store.runs_written += 1;
        store.bytes_spilled += self.spilled;
        match self.sink {
            RunWriterSink::Mem(v) => Ok(SpillRun::Mem(v)),
            RunWriterSink::File { mut w, path, elems, .. } => {
                w.flush().context("flushing spill run")?;
                if store.ckpt.is_some() {
                    w.get_ref()
                        .sync_all()
                        .with_context(|| format!("fsync run {}", path.display()))?;
                }
                Ok(SpillRun::File { path, elems, keep: false })
            }
        }
    }
}

/// [`ChunkSource`] view of a parked [`SpillRun`]: lets the streaming
/// folds re-read a run under the same bounded-memory contract the merge
/// cursors obey. The streamed SIHSort rank reads its sorted shard back
/// this way — splitter sampling and histogram rank measurement consume
/// the run chunk by chunk instead of materialising it (DESIGN.md §14).
pub struct SpillRunSource<'r, K: StreamRecord> {
    cur: SpillCursor<'r, K>,
    remaining: u64,
}

impl<'r, K: StreamRecord> SpillRunSource<'r, K> {
    /// Open a chunked reader over `run`; `buf_elems` bounds the refill
    /// buffer for file-backed runs.
    pub fn new(run: &'r SpillRun<K>, buf_elems: usize) -> anyhow::Result<Self> {
        Ok(SpillRunSource { cur: run.cursor(buf_elems)?, remaining: run.elems() as u64 })
    }
}

impl<K: StreamRecord> ChunkSource<K> for SpillRunSource<'_, K> {
    fn len_hint(&self) -> Option<u64> {
        // Remaining, which equals the total before the first read.
        Some(self.remaining)
    }

    fn next_chunk(&mut self, buf: &mut Vec<K>, max: usize) -> anyhow::Result<usize> {
        buf.clear();
        while buf.len() < max {
            match self.cur.head() {
                Some(k) => {
                    buf.push(k);
                    self.cur.advance()?;
                }
                None => break,
            }
        }
        self.remaining -= buf.len() as u64;
        Ok(buf.len())
    }
}

/// [`ChunkSink`] writing one spilled
/// run into a [`SpillStore`] — the glue that lets `external_sort` park
/// its output as a run later pipeline stages (the streamed exchange,
/// the splitter sampler) re-read under the budget. The pipeline's
/// `finish` call seals the run; take it with [`RunSink::into_run`].
pub struct RunSink<'s, K: StreamRecord> {
    writer: Option<RunWriter<'s, K>>,
    run: Option<SpillRun<K>>,
}

impl<'s, K: StreamRecord> RunSink<'s, K> {
    /// Start a new run in `store`.
    pub fn new(store: &'s mut SpillStore) -> anyhow::Result<Self> {
        Ok(RunSink { writer: Some(store.run_writer()?), run: None })
    }

    /// The sealed run (errors if the pipeline never called `finish`).
    pub fn into_run(self) -> anyhow::Result<SpillRun<K>> {
        self.run.ok_or_else(|| anyhow::anyhow!("RunSink::into_run before finish"))
    }
}

impl<K: StreamRecord> ChunkSink<K> for RunSink<'_, K> {
    fn push_chunk(&mut self, chunk: &[K]) -> anyhow::Result<()> {
        self.writer.as_mut().context("RunSink already finished")?.push_chunk(chunk)
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        let w = self.writer.take().context("RunSink finished twice")?;
        self.run = Some(w.finish()?);
        Ok(())
    }
}

/// Bounded-memory [`RunCursor`] over a [`SpillRun`]: in-memory runs
/// borrow their vector; file runs hold one decoded buffer of at most
/// `buf_elems` keys and refill from disk as the merge drains them.
pub struct SpillCursor<'r, K: StreamRecord> {
    mem: Option<&'r [K]>,
    /// Position in `mem` (memory runs) or in `buf` (file runs).
    pos: usize,
    file: Option<File>,
    /// Records not yet pulled into `buf`.
    remaining: usize,
    buf: Vec<K>,
    raw: Vec<u8>,
    buf_elems: usize,
}

impl<K: StreamRecord> SpillCursor<'_, K> {
    fn refill(&mut self) -> anyhow::Result<()> {
        let Some(file) = self.file.as_mut() else {
            return Ok(());
        };
        self.buf.clear();
        self.pos = 0;
        let want = self.buf_elems.min(self.remaining);
        if want == 0 {
            return Ok(());
        }
        let bytes = codec::encoded_len::<K>(want);
        self.raw.resize(bytes, 0);
        file.read_exact(&mut self.raw).context("reading spill run")?;
        codec::decode_into(&self.raw, &mut self.buf)?;
        self.remaining -= want;
        Ok(())
    }
}

impl<K: StreamRecord> RunCursor<K> for SpillCursor<'_, K> {
    fn head(&self) -> Option<K> {
        match self.mem {
            Some(m) => m.get(self.pos).copied(),
            None => self.buf.get(self.pos).copied(),
        }
    }

    fn advance(&mut self) -> anyhow::Result<()> {
        self.pos += 1;
        if self.mem.is_none() && self.pos >= self.buf.len() && self.remaining > 0 {
            self.refill()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::{bits_eq, SortKey};
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    fn sorted_keys(seed: u64, n: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        xs.sort_unstable_by(|a, b| a.cmp_total(b));
        xs
    }

    fn drain<K: StreamRecord>(run: &SpillRun<K>, buf_elems: usize) -> Vec<K> {
        let mut c = run.cursor(buf_elems).unwrap();
        let mut out = Vec::new();
        while let Some(k) = c.head() {
            out.push(k);
            c.advance().unwrap();
        }
        out
    }

    #[test]
    fn memory_and_disk_runs_roundtrip() {
        let xs = sorted_keys(1, 5000);
        for medium in [SpillMedium::Memory, SpillMedium::Disk] {
            let mut store = SpillStore::new(medium, None);
            let run = store.write_run(&xs).unwrap();
            assert_eq!(run.elems(), xs.len());
            // Tiny refill buffer exercises many refills.
            assert!(bits_eq(&drain(&run, 64), &xs), "{medium:?}");
            assert_eq!(store.runs_written(), 1);
        }
    }

    #[test]
    fn incremental_writer_equals_one_shot() {
        let xs = sorted_keys(2, 3000);
        let mut store = SpillStore::new(SpillMedium::Disk, None);
        let mut w = store.run_writer::<f64>().unwrap();
        for chunk in xs.chunks(701) {
            w.push_chunk(chunk).unwrap();
        }
        let run = w.finish().unwrap();
        assert_eq!(run.elems(), xs.len());
        assert!(bits_eq(&drain(&run, 97), &xs));
        assert_eq!(store.bytes_spilled(), codec::encoded_len::<f64>(xs.len()) as u64);
    }

    #[test]
    fn run_sink_and_source_roundtrip() {
        use crate::stream::source::{ChunkSink, ChunkSource};
        let xs = sorted_keys(3, 4000);
        for medium in [SpillMedium::Memory, SpillMedium::Disk] {
            let mut store = SpillStore::new(medium, None);
            let mut sink = RunSink::new(&mut store).unwrap();
            for c in xs.chunks(333) {
                sink.push_chunk(c).unwrap();
            }
            ChunkSink::finish(&mut sink).unwrap();
            let run = sink.into_run().unwrap();
            assert_eq!(run.elems(), xs.len());
            let mut src = SpillRunSource::new(&run, 128).unwrap();
            assert_eq!(src.len_hint(), Some(xs.len() as u64));
            let mut out = Vec::new();
            let mut buf = Vec::new();
            while src.next_chunk(&mut buf, 97).unwrap() > 0 {
                out.extend_from_slice(&buf);
            }
            assert!(bits_eq(&out, &xs), "{medium:?}");
        }
    }

    #[test]
    fn run_sink_guards_the_finish_protocol() {
        let mut store = SpillStore::new(SpillMedium::Memory, None);
        let sink = RunSink::<i32>::new(&mut store).unwrap();
        assert!(sink.into_run().is_err(), "into_run before finish must error");
    }

    #[test]
    fn run_files_deleted_on_drop() {
        let mut store = SpillStore::new(SpillMedium::Disk, None);
        let run = store.write_run(&[1i32, 2, 3]).unwrap();
        let path = match &run {
            SpillRun::File { path, .. } => path.clone(),
            _ => unreachable!("disk store produced a memory run"),
        };
        assert!(path.exists());
        drop(run);
        assert!(!path.exists(), "run file must be deleted when retired");
        // The guarded dir itself disappears with the store.
        let dir = store.dir().unwrap().to_path_buf();
        drop(store);
        assert!(!dir.exists());
    }

    #[test]
    fn tempdir_guard_cleans_on_panic() {
        // The guard's Drop must run during unwinding: a panicking
        // pipeline leaves no spill directory behind.
        let captured = std::sync::Arc::new(std::sync::Mutex::new(PathBuf::new()));
        let cap = captured.clone();
        let result = std::panic::catch_unwind(move || {
            let guard = TempDirGuard::new(None).unwrap();
            std::fs::write(guard.path().join("run-0.bin"), b"abc").unwrap();
            *cap.lock().unwrap() = guard.path().to_path_buf();
            panic!("mid-pipeline failure");
        });
        assert!(result.is_err());
        let path = captured.lock().unwrap().clone();
        assert!(!path.as_os_str().is_empty());
        assert!(!path.exists(), "guarded dir {} must be removed on panic", path.display());
    }

    #[test]
    fn memory_store_touches_no_filesystem() {
        let mut store = SpillStore::new(SpillMedium::Memory, None);
        let _ = store.write_run(&[5i64, 6]).unwrap();
        assert_eq!(store.dir(), None);
        assert_eq!(store.bytes_spilled(), 0);
    }

    fn ckpt_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("akspill-ckpt-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn checkpointed_runs_survive_store_drop_and_resume() {
        let dir = ckpt_dir("survive");
        let xs = sorted_keys(9, 2000);
        {
            let mut store =
                SpillStore::checkpointed(&dir, "external_sort", "t", "f64", 512, false).unwrap();
            let mut run = store.write_run(&xs).unwrap();
            store.record_run(&mut run, 0, 0).unwrap();
            // Recorded runs outlive both the run handle and the store.
        }
        assert!(dir.exists(), "checkpoint dir must survive the store");
        let store =
            SpillStore::checkpointed(&dir, "external_sort", "t", "f64", 512, true).unwrap();
        let m = store.manifest().unwrap().clone();
        assert_eq!(m.runs.len(), 1);
        let run = store.open_manifested_run::<f64>(&m.runs[0]).unwrap();
        assert!(bits_eq(&drain(&run, 64), &xs));
        drop(run);
        // keep = true: reopening and dropping must not eat the file.
        assert!(store.open_manifested_run::<f64>(&m.runs[0]).is_ok());
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_resume_validates_identity_and_budget() {
        let dir = ckpt_dir("validate");
        {
            let mut store =
                SpillStore::checkpointed(&dir, "external_sort", "t", "i64", 512, false).unwrap();
            let mut run = store.write_run(&[1i64, 2]).unwrap();
            store.record_run(&mut run, 0, 0).unwrap();
        }
        for (kind, tag, dtype, chunk) in [
            ("sihsort_rank", "t", "i64", 512u64),
            ("external_sort", "other", "i64", 512),
            ("external_sort", "t", "f64", 512),
            ("external_sort", "t", "i64", 256),
        ] {
            assert!(
                SpillStore::checkpointed(&dir, kind, tag, dtype, chunk, true).is_err(),
                "resume must reject ({kind}, {tag}, {dtype}, {chunk})"
            );
        }
        // A non-resuming open of the same dir starts clean instead.
        let store =
            SpillStore::checkpointed(&dir, "sihsort_rank", "x", "f32", 99, false).unwrap();
        assert!(store.manifest().unwrap().runs.is_empty());
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn commit_merge_retires_inputs_in_one_rewrite() {
        let dir = ckpt_dir("merge");
        let a = sorted_keys(10, 500);
        let b = sorted_keys(11, 700);
        let mut store =
            SpillStore::checkpointed(&dir, "external_sort", "t", "f64", 512, false).unwrap();
        let mut ra = store.write_run(&a).unwrap();
        store.record_run(&mut ra, 0, 0).unwrap();
        let mut rb = store.write_run(&b).unwrap();
        store.record_run(&mut rb, 0, 1).unwrap();
        let (pa, pb) = (ra.path().unwrap().to_path_buf(), rb.path().unwrap().to_path_buf());
        let mut merged: Vec<f64> = a.iter().chain(&b).copied().collect();
        merged.sort_unstable_by(|x, y| x.cmp_total(y));
        let mut out = store.write_run(&merged).unwrap();
        store.commit_merge(&mut out, vec![ra, rb], 1, 0).unwrap();
        let m = store.manifest().unwrap();
        assert_eq!(m.runs.len(), 1);
        assert_eq!(m.runs[0].pass, 1);
        assert!(!pa.exists() && !pb.exists(), "retired inputs must free their disk");
        assert!(out.path().unwrap().exists());
        drop(out);
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn guard_keeps_manifested_dir_on_panic_but_sweeps_orphans() {
        // The satellite-1 regression at the spill layer: a panic after
        // a manifest write must never delete checkpointed runs, while
        // unmanifested temp files are still reclaimed.
        let dir = ckpt_dir("panic");
        let xs = sorted_keys(12, 300);
        let dir2 = dir.clone();
        let xs2 = xs.clone();
        let result = std::panic::catch_unwind(move || {
            let mut store =
                SpillStore::checkpointed(&dir2, "external_sort", "t", "f64", 512, false)
                    .unwrap();
            let mut run = store.write_run(&xs2).unwrap();
            store.record_run(&mut run, 0, 0).unwrap();
            std::mem::forget(run); // keep=true either way; exercise the guard sweep
            std::fs::write(store.dir().unwrap().join("run-orphan.bin"), b"half-written")
                .unwrap();
            panic!("mid-pipeline failure");
        });
        assert!(result.is_err());
        assert!(dir.exists(), "manifested dir must survive the unwind");
        assert!(!dir.join("run-orphan.bin").exists(), "orphan must be swept");
        let store =
            SpillStore::checkpointed(&dir, "external_sort", "t", "f64", 512, true).unwrap();
        let m = store.manifest().unwrap().clone();
        assert_eq!(m.runs.len(), 1);
        let run = store.open_manifested_run::<f64>(&m.runs[0]).unwrap();
        assert!(bits_eq(&drain(&run, 64), &xs));
        drop(run);
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
