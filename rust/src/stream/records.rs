//! Record-stream workloads: the dataset-engine surface over the
//! external sort (DESIGN.md §19).
//!
//! One layout generalization ([`crate::stream::record`]) turns the
//! out-of-core sorter into a larger-than-RAM dataset engine; this
//! module is the workload layer on top of it, all `StreamCtx` methods:
//!
//! * [`StreamCtx::stream_sort_by_key`] — external stable sort of
//!   `(key, payload)` records.
//! * [`StreamCtx::stream_sortperm`] — external argsort: keys in, sorted
//!   `(key, original-index)` records out (`u64` indices, so the stream
//!   may exceed the in-memory engine's `u32` index space).
//! * [`StreamCtx::stream_group_reduce`] — sorted-run group-by: equal-key
//!   runs of the merge output fold through the `Reducible` operators —
//!   out-of-core aggregation for the price of one sort.
//! * [`StreamCtx::stream_merge_join`] — merge-join of two sorted record
//!   streams (inner join, cross product on duplicate keys).
//! * [`StreamCtx::stream_distinct`] — run-merge dedup; the first record
//!   of each key survives (deterministic: the merge is stable).
//!
//! Group identity throughout is the key's **total-order bit image**:
//! `-0.0` and `0.0` are distinct keys, and distinct NaN payloads are
//! distinct keys — exactly the equivalence the sort itself uses, so a
//! group is always one contiguous run of the sorted stream.

use crate::algorithms::reduce::{Reducible, ReduceKind};
use crate::backend::DeviceKey;
use crate::obs;
use crate::session::{AkError, AkResult, Launch};
use crate::stream::external_sort::ExternalSortStats;
use crate::stream::record::{Payload, Record, StreamRecord};
use crate::stream::source::{ChunkSink, ChunkSource};
use crate::stream::StreamCtx;

/// What a group-by / distinct pass did, alongside the underlying sort.
#[derive(Clone, Copy, Debug, Default)]
pub struct GroupStats {
    /// Groups emitted (group-by) or records kept (distinct).
    pub groups: u64,
    /// The stats of the external sort that fed the pass.
    pub sort: ExternalSortStats,
}

/// What a merge-join produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct JoinStats {
    /// Joined records emitted (cross products included).
    pub emitted: u64,
    /// Records consumed from the left stream.
    pub left_elems: u64,
    /// Records consumed from the right stream.
    pub right_elems: u64,
}

impl StreamCtx {
    /// External **stable** sort of `(key, payload)` records: output is
    /// bitwise what the in-memory stable pair sort
    /// (`Session::sort_by_key`) produces on the concatenated stream —
    /// equal keys keep their input order, payloads ride bit-exactly.
    /// A thin alias over the record-generic [`StreamCtx::external_sort`]
    /// that pins the layout to [`Record<K, P>`].
    pub fn stream_sort_by_key<K: DeviceKey, P: Payload>(
        &self,
        src: &mut dyn ChunkSource<Record<K, P>>,
        sink: &mut dyn ChunkSink<Record<K, P>>,
        launch: Option<&Launch>,
    ) -> AkResult<ExternalSortStats> {
        self.external_sort(src, sink, launch)
    }

    /// External argsort: sorts the bare keys of `src` and emits
    /// `(key, original-index)` records in ascending key order. The
    /// index payload is `u64` (the in-memory `sortperm` tops out at
    /// `u32`), and equal keys keep ascending indices — bitwise the
    /// stable in-memory permutation applied to the stream.
    pub fn stream_sortperm<K: DeviceKey>(
        &self,
        src: &mut dyn ChunkSource<K>,
        sink: &mut dyn ChunkSink<Record<K, u64>>,
        launch: Option<&Launch>,
    ) -> AkResult<ExternalSortStats> {
        let mut indexed = IndexSource { inner: src, next: 0, buf: Vec::new() };
        self.external_sort(&mut indexed, sink, launch)
    }

    /// Sorted-run group-by reduce: externally sorts the records, then
    /// folds each equal-key run through `kind` in the same output pass
    /// (no second pass over the data), emitting one `(key, folded)`
    /// record per group in ascending key order. The fold applies the
    /// same `Reducible` operator table as `stream_reduce`; float `Add`
    /// groups in stream order, so sums regroup exactly like the chunked
    /// scalar reduce.
    pub fn stream_group_reduce<K: DeviceKey, V: Reducible + Payload>(
        &self,
        src: &mut dyn ChunkSource<Record<K, V>>,
        kind: ReduceKind,
        sink: &mut dyn ChunkSink<Record<K, V>>,
        launch: Option<&Launch>,
    ) -> AkResult<GroupStats> {
        let _span = obs::span(obs::SpanKind::Pass, "rec.group-reduce");
        let flush_at = self.plan::<Record<K, V>>().io_chunk_elems;
        let mut fold = GroupFoldSink { inner: sink, kind, cur: None, out: Vec::new(), flush_at, groups: 0 };
        let sort = self.external_sort(src, &mut fold, launch)?;
        Ok(GroupStats { groups: fold.groups, sort })
    }

    /// Run-merge dedup: externally sorts the stream and keeps the
    /// **first** record of each distinct key (the merge is stable, so
    /// "first" is first in input order — deterministic payloads).
    /// Output is ascending and duplicate-free in the key image.
    pub fn stream_distinct<R: StreamRecord>(
        &self,
        src: &mut dyn ChunkSource<R>,
        sink: &mut dyn ChunkSink<R>,
        launch: Option<&Launch>,
    ) -> AkResult<GroupStats> {
        let _span = obs::span(obs::SpanKind::Pass, "rec.distinct");
        let flush_at = self.plan::<R>().io_chunk_elems;
        let mut dedup =
            DistinctSink { inner: sink, last_bits: None, out: Vec::new(), flush_at, kept: 0 };
        let sort = self.external_sort(src, &mut dedup, launch)?;
        Ok(GroupStats { groups: dedup.kept, sort })
    }

    /// Merge-join of two **already sorted** record streams (inner join):
    /// for every key present on both sides, the cross product of the
    /// left and right groups is emitted as `(key, (left, right))`
    /// records, in ascending key order (right-major within a key: the
    /// left group replays per right record). Sortedness is validated as
    /// the streams drain — a decreasing key is a typed shape error, not
    /// silent garbage. The left group of the current key is buffered in
    /// memory (`O(max left group)`); the right side streams through.
    ///
    /// To join unsorted streams, run each through
    /// [`StreamCtx::stream_sort_by_key`] first — the classic sort-merge
    /// join, every phase out-of-core.
    pub fn stream_merge_join<K: DeviceKey, A: Payload, B: Payload>(
        &self,
        left: &mut dyn ChunkSource<Record<K, A>>,
        right: &mut dyn ChunkSource<Record<K, B>>,
        sink: &mut dyn ChunkSink<Record<K, (A, B)>>,
    ) -> AkResult<JoinStats> {
        let _span = obs::span(obs::SpanKind::Pass, "rec.merge-join");
        let chunk = self.plan::<Record<K, (A, B)>>().io_chunk_elems;
        let mut l = JoinReader { src: left, buf: Vec::new(), pos: 0, chunk, prev: None, consumed: 0, side: "left" };
        let mut r = JoinReader { src: right, buf: Vec::new(), pos: 0, chunk, prev: None, consumed: 0, side: "right" };
        let mut out: Vec<Record<K, (A, B)>> = Vec::with_capacity(chunk);
        let mut lgroup: Vec<Record<K, A>> = Vec::new();
        let mut stats = JoinStats::default();
        loop {
            let (Some(lh), Some(rh)) = (l.peek()?, r.peek()?) else {
                break;
            };
            let (lb, rb) = (lh.key_bits(), rh.key_bits());
            if lb < rb {
                l.advance()?;
                continue;
            }
            if rb < lb {
                r.advance()?;
                continue;
            }
            // Equal key: buffer the whole left group, stream the right.
            lgroup.clear();
            while let Some(rec) = l.peek()? {
                if rec.key_bits() != lb {
                    break;
                }
                lgroup.push(rec);
                l.advance()?;
            }
            while let Some(rec) = r.peek()? {
                if rec.key_bits() != lb {
                    break;
                }
                for lrec in &lgroup {
                    out.push(Record::new(lrec.key, (lrec.val, rec.val)));
                    stats.emitted += 1;
                    if out.len() >= chunk {
                        sink.push_chunk(&out)?;
                        out.clear();
                    }
                }
                r.advance()?;
            }
        }
        // Drain both tails so the sortedness validation (and the
        // consumed counts) cover the full streams.
        while l.peek()?.is_some() {
            l.advance()?;
        }
        while r.peek()?.is_some() {
            r.advance()?;
        }
        if !out.is_empty() {
            sink.push_chunk(&out)?;
        }
        sink.finish()?;
        stats.left_elems = l.consumed;
        stats.right_elems = r.consumed;
        Ok(stats)
    }
}

/// Source adapter attaching a running `u64` index to each key — the
/// input layout of `stream_sortperm`.
struct IndexSource<'a, K: DeviceKey> {
    inner: &'a mut dyn ChunkSource<K>,
    next: u64,
    buf: Vec<K>,
}

impl<K: DeviceKey> ChunkSource<Record<K, u64>> for IndexSource<'_, K> {
    fn len_hint(&self) -> Option<u64> {
        self.inner.len_hint()
    }

    fn next_chunk(&mut self, buf: &mut Vec<Record<K, u64>>, max: usize) -> anyhow::Result<usize> {
        buf.clear();
        let n = self.inner.next_chunk(&mut self.buf, max)?;
        buf.reserve(n);
        for &k in &self.buf {
            buf.push(Record::new(k, self.next));
            self.next += 1;
        }
        Ok(n)
    }
}

/// Sink adapter folding equal-key runs of sorted output through a
/// `Reducible` operator, emitting one record per group. Correct because
/// the upstream external sort emits each key's records contiguously.
struct GroupFoldSink<'a, K: DeviceKey, V: Reducible + Payload> {
    inner: &'a mut dyn ChunkSink<Record<K, V>>,
    kind: ReduceKind,
    /// The open group: its key and the fold so far.
    cur: Option<Record<K, V>>,
    out: Vec<Record<K, V>>,
    flush_at: usize,
    groups: u64,
}

impl<K: DeviceKey, V: Reducible + Payload> GroupFoldSink<'_, K, V> {
    fn emit(&mut self, done: Record<K, V>) -> anyhow::Result<()> {
        self.groups += 1;
        self.out.push(done);
        if self.out.len() >= self.flush_at {
            self.inner.push_chunk(&self.out)?;
            self.out.clear();
        }
        Ok(())
    }
}

impl<K: DeviceKey, V: Reducible + Payload> ChunkSink<Record<K, V>> for GroupFoldSink<'_, K, V> {
    fn push_chunk(&mut self, chunk: &[Record<K, V>]) -> anyhow::Result<()> {
        for &rec in chunk {
            let same = self.cur.is_some_and(|c| c.key_bits() == rec.key_bits());
            if same {
                if let Some(c) = self.cur.as_mut() {
                    c.val = V::fold(self.kind, c.val, rec.val);
                }
            } else if let Some(done) = self.cur.replace(rec) {
                self.emit(done)?;
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        if let Some(done) = self.cur.take() {
            self.emit(done)?;
        }
        if !self.out.is_empty() {
            self.inner.push_chunk(&self.out)?;
            self.out.clear();
        }
        self.inner.finish()
    }
}

/// Sink adapter keeping the first record of each distinct key image of
/// sorted output.
struct DistinctSink<'a, R: StreamRecord> {
    inner: &'a mut dyn ChunkSink<R>,
    last_bits: Option<u128>,
    out: Vec<R>,
    flush_at: usize,
    kept: u64,
}

impl<R: StreamRecord> ChunkSink<R> for DistinctSink<'_, R> {
    fn push_chunk(&mut self, chunk: &[R]) -> anyhow::Result<()> {
        for &rec in chunk {
            let bits = rec.key_bits();
            if self.last_bits != Some(bits) {
                self.last_bits = Some(bits);
                self.kept += 1;
                self.out.push(rec);
                if self.out.len() >= self.flush_at {
                    self.inner.push_chunk(&self.out)?;
                    self.out.clear();
                }
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> anyhow::Result<()> {
        if !self.out.is_empty() {
            self.inner.push_chunk(&self.out)?;
            self.out.clear();
        }
        self.inner.finish()
    }
}

/// Buffered, sortedness-validating reader over one join input.
struct JoinReader<'a, R: StreamRecord> {
    src: &'a mut dyn ChunkSource<R>,
    buf: Vec<R>,
    pos: usize,
    chunk: usize,
    /// Key image of the last record handed out (monotonicity check).
    prev: Option<u128>,
    consumed: u64,
    side: &'static str,
}

impl<R: StreamRecord> JoinReader<'_, R> {
    /// The next record without consuming it (`None` = exhausted).
    fn peek(&mut self) -> AkResult<Option<R>> {
        if self.pos >= self.buf.len() {
            self.pos = 0;
            // `next_chunk` clears the buffer; 0 leaves it empty.
            self.src.next_chunk(&mut self.buf, self.chunk)?;
        }
        Ok(self.buf.get(self.pos).copied())
    }

    /// Consume the current head, enforcing ascending key order.
    fn advance(&mut self) -> AkResult<()> {
        let Some(rec) = self.peek()? else {
            return Ok(());
        };
        let bits = rec.key_bits();
        if let Some(p) = self.prev {
            if bits < p {
                return Err(AkError::shape(
                    "stream_merge_join",
                    format!(
                        "{} input is not sorted: key image {bits:#x} after {p:#x} \
                         at record {}",
                        self.side, self.consumed
                    ),
                ));
            }
        }
        self.prev = Some(bits);
        self.consumed += 1;
        self.pos += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Session;
    use crate::stream::{SliceSource, StreamBudget, VecSink};
    use crate::util::Prng;

    fn ctx() -> StreamCtx {
        // Small chunks + fan-in 2 force multi-pass merges on tiny data.
        Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .in_memory_spill()
            .run_chunk_elems(1024)
            .fan_in(2)
    }

    fn recs(seed: u64, n: usize, key_span: u64) -> Vec<Record<i64, u64>> {
        let mut rng = Prng::new(seed);
        (0..n as u64).map(|i| Record::new((rng.below(key_span)) as i64, i)).collect()
    }

    #[test]
    fn sort_by_key_is_stable_and_bitwise() {
        let data = recs(1, 10_000, 50);
        let mut keys: Vec<i64> = data.iter().map(|r| r.key).collect();
        let mut vals: Vec<u64> = data.iter().map(|r| r.val).collect();
        Session::native().sort_by_key(&mut keys, &mut vals, None).unwrap();
        let mut sink = VecSink::new();
        let stats =
            ctx().stream_sort_by_key(&mut SliceSource::new(&data), &mut sink, None).unwrap();
        assert!(stats.merge_passes >= 2, "must exercise multi-pass merge");
        assert_eq!(sink.out.len(), data.len());
        for (i, r) in sink.out.iter().enumerate() {
            assert_eq!((r.key, r.val), (keys[i], vals[i]), "at {i}");
        }
    }

    #[test]
    fn sortperm_matches_in_memory_perm() {
        let keys: Vec<i64> = recs(2, 6000, 40).into_iter().map(|r| r.key).collect();
        let perm = Session::native().sortperm(&keys, None).unwrap();
        let mut sink = VecSink::new();
        ctx().stream_sortperm(&mut SliceSource::new(&keys), &mut sink, None).unwrap();
        assert_eq!(sink.out.len(), keys.len());
        for (i, r) in sink.out.iter().enumerate() {
            assert_eq!(r.val, perm[i] as u64, "perm at {i}");
            assert_eq!(r.key, keys[perm[i] as usize]);
        }
    }

    #[test]
    fn group_reduce_matches_hashmap() {
        use std::collections::HashMap;
        let data = recs(3, 8000, 97);
        let mut want: HashMap<i64, u64> = HashMap::new();
        for r in &data {
            *want.entry(r.key).or_insert(0) += r.val;
        }
        let mut sink = VecSink::new();
        let data_v: Vec<Record<i64, i64>> =
            data.iter().map(|r| Record::new(r.key, r.val as i64)).collect();
        let stats = ctx()
            .stream_group_reduce(&mut SliceSource::new(&data_v), ReduceKind::Add, &mut sink, None)
            .unwrap();
        assert_eq!(stats.groups as usize, want.len());
        assert_eq!(sink.out.len(), want.len());
        for w in sink.out.windows(2) {
            assert!(w[0].key < w[1].key, "groups ascending and unique");
        }
        for r in &sink.out {
            assert_eq!(r.val as u64, want[&r.key], "group {}", r.key);
        }
    }

    #[test]
    fn distinct_keeps_first_payload() {
        let data = recs(4, 5000, 23);
        let mut sink = VecSink::new();
        let stats = ctx().stream_distinct(&mut SliceSource::new(&data), &mut sink, None).unwrap();
        // Reference: first payload per key, keys ascending.
        use std::collections::BTreeMap;
        let mut want: BTreeMap<i64, u64> = BTreeMap::new();
        for r in &data {
            want.entry(r.key).or_insert(r.val);
        }
        assert_eq!(stats.groups as usize, want.len());
        let got: Vec<(i64, u64)> = sink.out.iter().map(|r| (r.key, r.val)).collect();
        let wantv: Vec<(i64, u64)> = want.into_iter().collect();
        assert_eq!(got, wantv);
    }

    #[test]
    fn merge_join_matches_nested_loop() {
        let mut left = recs(5, 700, 60);
        let mut right: Vec<Record<i64, u32>> = recs(6, 900, 60)
            .into_iter()
            .map(|r| Record::new(r.key, r.val as u32))
            .collect();
        left.sort_by_key(|r| (r.key, r.val));
        right.sort_by_key(|r| (r.key, r.val));
        // Reference nested loop in the emitted order (left-key groups,
        // right-major within a key).
        let mut want: Vec<(i64, u64, u32)> = Vec::new();
        for r in &right {
            for l in &left {
                if l.key == r.key {
                    want.push((l.key, l.val, r.val));
                }
            }
        }
        want.sort_by_key(|&(k, _, rv)| (k, rv));
        let mut sink = VecSink::new();
        let stats = ctx()
            .stream_merge_join(
                &mut SliceSource::new(&left),
                &mut SliceSource::new(&right),
                &mut sink,
            )
            .unwrap();
        assert_eq!(stats.emitted as usize, want.len());
        assert_eq!(stats.left_elems as usize, left.len());
        assert_eq!(stats.right_elems as usize, right.len());
        let got: Vec<(i64, u64, u32)> =
            sink.out.iter().map(|r| (r.key, r.val.0, r.val.1)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn merge_join_rejects_unsorted_input() {
        let left = vec![Record::new(5i64, 1u64), Record::new(3, 2)];
        let right = vec![Record::new(3i64, 9u64)];
        let mut sink = VecSink::new();
        let err = ctx()
            .stream_merge_join(
                &mut SliceSource::new(&left),
                &mut SliceSource::new(&right),
                &mut sink,
            )
            .unwrap_err();
        assert!(matches!(err, AkError::ShapeMismatch { .. }), "{err}");
    }
}
