//! Single-pass streaming folds: reduce, scan, histogram, top-k
//! (DESIGN.md §13).
//!
//! Each pipeline pulls budget-sized chunks from a [`ChunkSource`], runs
//! the session's in-memory engine on the chunk (so threaded / hybrid /
//! device dispatch and `Launch` knobs apply unchanged), and carries O(1)
//! or O(k) state across chunk boundaries:
//!
//! * `reduce` — one accumulator, folded with the operator.
//! * `scan` — the running prefix total; each output chunk is the chunk's
//!   in-memory scan plus the carry (exactly the carry phase of the
//!   three-phase block scan in `algorithms::scan`, applied across I/O
//!   chunks instead of threads). Integer scans are bitwise-identical to
//!   the in-memory engines (wrapping add is associative); float scans
//!   regroup additions per chunk, same as the threaded engine does per
//!   thread.
//! * `histogram` — per-chunk `searchsorted_last` against the bin edges
//!   (total-order semantics: NaN counts into the overflow bin).
//! * `top-k` — a 2k-element pool with a strict-greater floor filter;
//!   compaction sorts the pool with the session engine.

use crate::algorithms::reduce::{Reducible, ReduceKind};
use crate::algorithms::scan::ScanAdd;
use crate::backend::DeviceKey;
use crate::baselines::kmerge::KmergePull;
use crate::obs;
use crate::session::{AkError, AkResult, Launch};
use crate::stream::source::{ChunkSink, ChunkSource};
use crate::stream::spill::SpillRun;
use crate::stream::{StreamCtx, StreamPlan};

impl StreamCtx {
    /// Fold everything `src` yields with `kind`, holding one chunk at a
    /// time. Integer results are bitwise-identical to the in-memory
    /// `Session::reduce`; float sums may differ in rounding (chunking
    /// regroups the additions, exactly like the threaded engine).
    pub fn stream_reduce<K: Reducible>(
        &self,
        src: &mut dyn ChunkSource<K>,
        kind: ReduceKind,
        launch: Option<&Launch>,
    ) -> AkResult<K> {
        let chunk = self.plan::<K>().run_chunk_elems;
        let mut acc = K::identity(kind);
        let mut buf: Vec<K> = Vec::new();
        while src.next_chunk(&mut buf, chunk)? > 0 {
            let part = self.session.reduce(&buf, kind, launch)?;
            acc = K::fold(kind, acc, part);
        }
        Ok(acc)
    }

    /// Prefix-sum of the stream into `sink`, chunk at a time; `inclusive`
    /// selects the flavour. Returns the element count. The carry (the
    /// running total of all previous chunks) is the only cross-chunk
    /// state.
    pub fn stream_scan<K: ScanAdd + std::ops::Add<Output = K>>(
        &self,
        src: &mut dyn ChunkSource<K>,
        sink: &mut dyn ChunkSink<K>,
        inclusive: bool,
        launch: Option<&Launch>,
    ) -> AkResult<u64> {
        // Chunk + its scan output both live at once: half the fold chunk.
        let chunk = (self.plan::<K>().run_chunk_elems / 2).max(1);
        let mut carry = K::default();
        let mut buf: Vec<K> = Vec::new();
        let mut elems = 0u64;
        while src.next_chunk(&mut buf, chunk)? > 0 {
            elems += buf.len() as u64;
            let inc = self.session.accumulate(&buf, true, launch)?;
            let total = *inc
                .last()
                .ok_or_else(|| anyhow::anyhow!("accumulate returned empty for a non-empty chunk"))?;
            let out: Vec<K> = if inclusive {
                inc.iter().map(|&v| K::add(carry, v)).collect()
            } else {
                let mut o = Vec::with_capacity(buf.len());
                o.push(carry);
                o.extend(inc[..inc.len() - 1].iter().map(|&v| K::add(carry, v)));
                o
            };
            sink.push_chunk(&out)?;
            carry = K::add(carry, total);
        }
        sink.finish()?;
        Ok(elems)
    }

    /// Histogram of the stream over ascending `edges`: `counts[i]`
    /// is the number of keys `x` with `edges[i-1] <= x < edges[i]`
    /// (`counts[0]` is the underflow bin, the last slot the overflow
    /// bin), so `counts.len() == edges.len() + 1`. Edge comparison uses
    /// IEEE semantics on float dtypes — `-0.0` and `0.0` are the same
    /// value, so a `-0.0` key counts at/above a `0.0` edge (and vice
    /// versa); both are canonicalised through
    /// [`crate::dtype::SortKey::canon_ieee_zero`] before binning. NaN
    /// has no IEEE order, so it keeps its total-order position above
    /// `+inf` and always lands in the overflow bin.
    pub fn stream_histogram<K: DeviceKey>(
        &self,
        src: &mut dyn ChunkSource<K>,
        edges: &[K],
        launch: Option<&Launch>,
    ) -> AkResult<Vec<u64>> {
        let is_float = matches!(K::ELEM, crate::dtype::ElemType::F32 | crate::dtype::ElemType::F64);
        let canon: Vec<K>;
        let edges: &[K] = if is_float {
            canon = edges.iter().map(|e| e.canon_ieee_zero()).collect();
            &canon
        } else {
            edges
        };
        if !crate::dtype::is_sorted_total(edges) {
            return Err(AkError::shape(
                "stream_histogram",
                "bin edges must be ascending in the total order".into(),
            ));
        }
        let chunk = self.plan::<K>().run_chunk_elems;
        let mut counts = vec![0u64; edges.len() + 1];
        let mut buf: Vec<K> = Vec::new();
        while src.next_chunk(&mut buf, chunk)? > 0 {
            if is_float {
                for x in buf.iter_mut() {
                    *x = x.canon_ieee_zero();
                }
            }
            let bins = self.session.searchsorted_last(edges, &buf, launch)?;
            for b in bins {
                counts[b as usize] += 1;
            }
        }
        Ok(counts)
    }

    /// The `k` largest keys of the stream, descending (total order, so
    /// NaN outranks +inf — same rule as `external_sort`'s tail). The
    /// result is bitwise what "in-memory sort descending, take `k`"
    /// produces.
    ///
    /// Small `k` (a `2k` pool fits the chunk budget) runs entirely in
    /// memory: at most `2k` candidates plus one input chunk. Large `k`
    /// — up to and past the stream length — spills each chunk's top-`k`
    /// tail as a sorted candidate run and finishes through the same
    /// k-way merge machinery as `external_sort`, holding only `k`
    /// survivors plus the merge I/O buffers.
    pub fn stream_topk<K: DeviceKey>(
        &self,
        src: &mut dyn ChunkSource<K>,
        k: usize,
        launch: Option<&Launch>,
    ) -> AkResult<Vec<K>> {
        if k == 0 {
            return Ok(Vec::new());
        }
        let plan = self.plan::<K>();
        if k.saturating_mul(2) > plan.run_chunk_elems {
            return self.topk_spilled(src, k, &plan, launch);
        }
        let chunk = plan.run_chunk_elems;
        let mut pool: Vec<K> = Vec::with_capacity(2 * k);
        // Once the pool has been compacted to k survivors, only keys
        // strictly above the smallest survivor can alter the answer
        // (ties are bit-identical under the total order, so dropping
        // them is exact).
        let mut floor: Option<K> = None;
        let mut buf: Vec<K> = Vec::new();
        while src.next_chunk(&mut buf, chunk)? > 0 {
            for &x in &buf {
                let keep = match floor {
                    None => true,
                    Some(f) => x.cmp_total(&f) == std::cmp::Ordering::Greater,
                };
                if keep {
                    pool.push(x);
                    if pool.len() >= 2 * k {
                        compact_pool(self, &mut pool, k, launch)?;
                        floor = Some(pool[0]);
                    }
                }
            }
        }
        self.session.sort(&mut pool, launch)?;
        let start = pool.len().saturating_sub(k);
        let mut top = pool.split_off(start);
        top.reverse();
        Ok(top)
    }

    /// Large-`k` tail of [`StreamCtx::stream_topk`]: a `2k` pool would
    /// bust the chunk budget, so each input chunk is sorted and its
    /// top-`k` tail spilled as a candidate run; merge passes then fold
    /// candidate runs back down to one top-`k`, never holding more than
    /// `k` survivors at once.
    fn topk_spilled<K: DeviceKey>(
        &self,
        src: &mut dyn ChunkSource<K>,
        k: usize,
        plan: &StreamPlan,
        launch: Option<&Launch>,
    ) -> AkResult<Vec<K>> {
        let _span = obs::span1(obs::SpanKind::Pass, "topk.spill", k as u64);
        let mut store = self.store();
        let mut runs: Vec<SpillRun<K>> = Vec::new();
        let mut buf: Vec<K> = Vec::new();
        while src.next_chunk(&mut buf, plan.run_chunk_elems)? > 0 {
            self.session.sort(&mut buf, launch)?;
            runs.push(store.write_run(&buf[buf.len().saturating_sub(k)..])?);
        }
        if runs.is_empty() {
            return Ok(Vec::new());
        }
        // Merge passes mirror `external_sort`: while the candidate set
        // exceeds the fan-in, fold fan-in-sized groups down to their own
        // top-`k` (each re-spilled run is at most `k` elements).
        while runs.len() > plan.fan_in {
            let mut merged: Vec<SpillRun<K>> = Vec::new();
            while !runs.is_empty() {
                let take = plan.fan_in.min(runs.len());
                let group: Vec<SpillRun<K>> = runs.drain(..take).collect();
                if group.len() == 1 {
                    merged.extend(group);
                    continue;
                }
                let top = merge_top_tail(&group, k, plan)?;
                merged.push(store.write_run(&top)?);
                // `group` drops here: retired runs delete their files.
            }
            runs = merged;
        }
        let mut top = merge_top_tail(&runs, k, plan)?;
        top.reverse();
        Ok(top)
    }
}

/// Merge ascending candidate runs, keeping only the last (largest) `k`
/// keys — a rolling window over the k-way merge output, so peak memory
/// is `k` plus the merge I/O buffers.
fn merge_top_tail<K: DeviceKey>(
    runs: &[SpillRun<K>],
    k: usize,
    plan: &StreamPlan,
) -> AkResult<Vec<K>> {
    let mut cursors = Vec::with_capacity(runs.len());
    for r in runs {
        cursors.push(r.cursor(plan.io_chunk_elems)?);
    }
    let mut merge = KmergePull::new(cursors);
    let mut keep: Vec<K> = Vec::new();
    let mut out: Vec<K> = Vec::with_capacity(plan.io_chunk_elems);
    loop {
        out.clear();
        if merge.next_chunk(&mut out, plan.io_chunk_elems)? == 0 {
            break;
        }
        keep.extend_from_slice(&out);
        if keep.len() > k {
            keep.drain(..keep.len() - k);
        }
    }
    Ok(keep)
}

/// Sort the pool and keep its top `k` (ascending afterwards).
fn compact_pool<K: DeviceKey>(
    ctx: &StreamCtx,
    pool: &mut Vec<K>,
    k: usize,
    launch: Option<&Launch>,
) -> AkResult<()> {
    ctx.session.sort(pool, launch)?;
    let cut = pool.len() - k;
    pool.drain(..cut);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::bits_eq;
    use crate::session::Session;
    use crate::stream::{SliceSource, StreamBudget, VecSink};
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    fn small_ctx() -> StreamCtx {
        // Tiny chunks force many carry hand-offs.
        Session::threaded(2).stream(StreamBudget::bytes(64)).run_chunk_elems(257)
    }

    #[test]
    fn reduce_matches_in_memory_for_ints() {
        let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 10_000);
        let want = Session::native().reduce(&xs, ReduceKind::Add, None).unwrap();
        for kind in [ReduceKind::Add, ReduceKind::Min, ReduceKind::Max] {
            let got = small_ctx().stream_reduce(&mut SliceSource::new(&xs), kind, None).unwrap();
            let reference = Session::native().reduce(&xs, kind, None).unwrap();
            assert_eq!(got, reference, "{kind:?}");
        }
        assert_eq!(
            small_ctx().stream_reduce(&mut SliceSource::new(&xs), ReduceKind::Add, None).unwrap(),
            want
        );
        // Empty stream folds to the identity.
        let empty: Vec<i64> = vec![];
        let got = small_ctx()
            .stream_reduce(&mut SliceSource::new(&empty), ReduceKind::Min, None)
            .unwrap();
        assert_eq!(got, i64::MAX);
    }

    #[test]
    fn scan_matches_in_memory_for_ints() {
        let xs: Vec<i32> = generate(&mut Prng::new(2), Distribution::Uniform, 5003);
        for inclusive in [true, false] {
            let want = Session::native().accumulate(&xs, inclusive, None).unwrap();
            let mut sink = VecSink::new();
            let n = small_ctx()
                .stream_scan(&mut SliceSource::new(&xs), &mut sink, inclusive, None)
                .unwrap();
            assert_eq!(n, xs.len() as u64);
            assert_eq!(sink.out, want, "inclusive={inclusive}");
        }
    }

    #[test]
    fn float_scan_tracks_reference_within_tolerance() {
        // Chunking regroups float additions (same as the threaded
        // engine), so the comparison is relative, not bitwise.
        let xs: Vec<f64> = generate(&mut Prng::new(3), Distribution::Gaussian, 4000)
            .into_iter()
            .map(|x: f64| x % 1000.0)
            .collect();
        let want = Session::native().accumulate(&xs, true, None).unwrap();
        let mut sink = VecSink::new();
        small_ctx().stream_scan(&mut SliceSource::new(&xs), &mut sink, true, None).unwrap();
        for (g, w) in sink.out.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-6 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn histogram_counts_by_total_order() {
        let xs: Vec<f32> =
            vec![-1.0, 0.5, 2.0, 2.0, 7.5, f32::NAN, f32::INFINITY, -f32::INFINITY, 1.99];
        let edges = vec![0.0f32, 2.0, 5.0];
        let got = small_ctx().stream_histogram(&mut SliceSource::new(&xs), &edges, None).unwrap();
        // Bins: (..., 0) | [0, 2) | [2, 5) | [5, ...); NaN > +inf in
        // the total order, so it overflows into the last bin alongside
        // 7.5 and +inf.
        assert_eq!(got, vec![2, 2, 2, 3]);
        // Unsorted edges are a typed shape error.
        let bad = small_ctx().stream_histogram(&mut SliceSource::new(&xs), &[5.0f32, 0.0], None);
        assert!(matches!(bad, Err(AkError::ShapeMismatch { .. })));
        // Empty edge list: everything lands in the single bin.
        let all = small_ctx().stream_histogram(&mut SliceSource::new(&xs), &[], None).unwrap();
        assert_eq!(all, vec![xs.len() as u64]);
    }

    #[test]
    fn histogram_zero_edges_use_ieee_semantics() {
        // -0.0 == 0.0 under IEEE: a -0.0 key must count at/above a 0.0
        // edge (the total order alone would put it strictly below), and
        // a -0.0 edge must behave exactly like a 0.0 edge.
        let keys = vec![-1.0f64, -0.0, 0.0, 1.0];
        let got =
            small_ctx().stream_histogram(&mut SliceSource::new(&keys), &[0.0f64], None).unwrap();
        assert_eq!(got, vec![1, 3], "-0.0 lands at/above the 0.0 edge");
        let got =
            small_ctx().stream_histogram(&mut SliceSource::new(&keys), &[-0.0f64], None).unwrap();
        assert_eq!(got, vec![1, 3], "a -0.0 edge equals a 0.0 edge");
        // Edges that differ only in zero sign canonicalise to duplicates
        // and are accepted ([0.0, -0.0] is IEEE-ascending).
        let got = small_ctx()
            .stream_histogram(&mut SliceSource::new(&keys), &[0.0f64, -0.0], None)
            .unwrap();
        assert_eq!(got.iter().sum::<u64>(), keys.len() as u64);
        assert_eq!(got[0], 1);
        // NaN keeps its documented overflow-bin position.
        let nan = vec![f64::NAN, -0.0];
        let got =
            small_ctx().stream_histogram(&mut SliceSource::new(&nan), &[0.0f64], None).unwrap();
        assert_eq!(got, vec![0, 2]);
    }

    #[test]
    fn topk_matches_sort_desc_take_k() {
        let xs: Vec<i32> = generate(&mut Prng::new(4), Distribution::DupHeavy, 20_000);
        let mut want = xs.clone();
        Session::native().sort(&mut want, None).unwrap();
        want.reverse();
        for k in [1usize, 7, 100, 2048] {
            let got = small_ctx().stream_topk(&mut SliceSource::new(&xs), k, None).unwrap();
            assert!(bits_eq(&got, &want[..k.min(want.len())]), "k={k}");
        }
        // k larger than the stream returns everything, descending.
        let tiny = vec![3i32, 9, 1];
        let got = small_ctx().stream_topk(&mut SliceSource::new(&tiny), 10, None).unwrap();
        assert_eq!(got, vec![9, 3, 1]);
        // k = 0.
        assert!(small_ctx().stream_topk(&mut SliceSource::new(&tiny), 0, None).unwrap().is_empty());
    }

    #[test]
    fn topk_spills_when_k_approaches_n() {
        // 2k far exceeds the 257-element chunk budget, so these take the
        // spilled-candidate-run path; k ≈ n (and k > n) must still be
        // bitwise "sort descending, take k".
        let xs: Vec<i32> = generate(&mut Prng::new(5), Distribution::DupHeavy, 20_000);
        let mut want = xs.clone();
        Session::native().sort(&mut want, None).unwrap();
        want.reverse();
        for k in [129usize, 3000, 19_000, 20_000, 25_000] {
            let got = small_ctx().stream_topk(&mut SliceSource::new(&xs), k, None).unwrap();
            assert_eq!(got.len(), k.min(xs.len()), "k={k}");
            assert!(bits_eq(&got, &want[..k.min(want.len())]), "k={k}");
        }
        // `small_ctx` spills to disk (the default medium); cover the
        // memory medium too — same pipeline, different run store.
        let ctx = Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .in_memory_spill()
            .run_chunk_elems(257);
        let got = ctx.stream_topk(&mut SliceSource::new(&xs), 19_000, None).unwrap();
        assert!(bits_eq(&got, &want[..19_000]));
        // Floats with NaN/-0.0 through the spill path: total order holds.
        let mut f: Vec<f64> = generate(&mut Prng::new(6), Distribution::Gaussian, 700);
        f[13] = f64::NAN;
        f[99] = -0.0;
        f[100] = 0.0;
        let got = small_ctx().stream_topk(&mut SliceSource::new(&f), 650, None).unwrap();
        let mut wantf = f.clone();
        Session::native().sort(&mut wantf, None).unwrap();
        wantf.reverse();
        assert!(bits_eq(&got, &wantf[..650]));
    }

    #[test]
    fn topk_total_order_on_floats() {
        let xs = vec![1.0f64, f64::NAN, f64::INFINITY, -0.0, 0.0, 5.0];
        let got = small_ctx().stream_topk(&mut SliceSource::new(&xs), 3, None).unwrap();
        assert!(got[0].is_nan());
        assert_eq!(got[1], f64::INFINITY);
        assert_eq!(got[2], 5.0);
    }
}
