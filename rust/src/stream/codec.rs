//! Compact fixed-width record codec for spills and binary datasets.
//!
//! One record is the little-endian [`SortKey::to_bits`] image truncated
//! to `K::KEY_BYTES` — 2 bytes per `i16` key, 16 per `i128`. The image
//! transform is a bijection, so the round trip is exact for every bit
//! pattern (NaN payloads and `-0.0` survive spills byte-identically:
//! the streaming-vs-in-memory equivalence tests rely on this).
//!
//! The format is deliberately headerless: a run file's element count is
//! `len / KEY_BYTES`, checked on open ([`decode_into`] rejects ragged
//! tails), and the dtype is part of the surrounding context (spill runs
//! are typed, `FileSource`/`FileSink` are generic over `K`).

use anyhow::ensure;

use crate::dtype::SortKey;

/// Encoded size in bytes of `n` records of type `K`.
pub fn encoded_len<K: SortKey>(n: usize) -> usize {
    n * K::KEY_BYTES
}

/// Append the records of `keys` to `out` (little-endian bit images).
pub fn encode_into<K: SortKey>(keys: &[K], out: &mut Vec<u8>) {
    out.reserve(encoded_len::<K>(keys.len()));
    for &k in keys {
        let bits = k.to_bits().to_le_bytes();
        out.extend_from_slice(&bits[..K::KEY_BYTES]);
    }
}

/// Decode every record in `bytes`, appending to `out`; errors on a
/// ragged tail (truncated spill / foreign file).
pub fn decode_into<K: SortKey>(bytes: &[u8], out: &mut Vec<K>) -> anyhow::Result<usize> {
    ensure!(
        bytes.len() % K::KEY_BYTES == 0,
        "record codec: {} bytes is not a multiple of the {}-byte {} record",
        bytes.len(),
        K::KEY_BYTES,
        K::ELEM,
    );
    let n = bytes.len() / K::KEY_BYTES;
    out.reserve(n);
    for rec in bytes.chunks_exact(K::KEY_BYTES) {
        let mut wide = [0u8; 16];
        wide[..K::KEY_BYTES].copy_from_slice(rec);
        out.push(K::from_bits(u128::from_le_bytes(wide)));
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::bits_eq;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn roundtrip<K: KeyGen>(seed: u64, n: usize) {
        let xs: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        assert_eq!(bytes.len(), encoded_len::<K>(n));
        let mut back: Vec<K> = Vec::new();
        assert_eq!(decode_into(&bytes, &mut back).unwrap(), n);
        assert!(bits_eq(&xs, &back));
    }

    #[test]
    fn all_dtypes_roundtrip() {
        roundtrip::<i16>(1, 500);
        roundtrip::<i32>(2, 500);
        roundtrip::<i64>(3, 500);
        roundtrip::<i128>(4, 500);
        roundtrip::<f32>(5, 500);
        roundtrip::<f64>(6, 500);
    }

    #[test]
    fn ieee_oddities_survive_bit_exactly() {
        let xs = vec![f64::NAN, -f64::NAN, -0.0, 0.0, f64::INFINITY, f64::NEG_INFINITY, 1.5];
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        let mut back: Vec<f64> = Vec::new();
        decode_into(&bytes, &mut back).unwrap();
        assert!(bits_eq(&xs, &back));
        // Raw IEEE bits (not just the sort image) are preserved.
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ragged_tail_rejected() {
        let xs = vec![7i32, 8];
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        bytes.pop();
        let mut back: Vec<i32> = Vec::new();
        assert!(decode_into(&bytes, &mut back).is_err());
    }

    #[test]
    fn decode_appends() {
        let mut bytes = Vec::new();
        encode_into(&[1i16, 2], &mut bytes);
        let mut out = vec![0i16];
        decode_into(&bytes, &mut out).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }
}
