//! Compact fixed-width record codec for spills and binary datasets.
//!
//! One record is the little-endian [`SortKey::to_bits`] image of the
//! key truncated to `KEY_BYTES`, immediately followed by
//! `PAYLOAD_BYTES` of raw payload bits (see
//! [`crate::stream::record::StreamRecord`]). The key image transform is
//! a bijection and the payload bytes are the value's own bit pattern,
//! so the round trip is exact for every bit pattern (NaN payloads and
//! `-0.0` survive spills byte-identically in both halves: the
//! streaming-vs-in-memory equivalence tests rely on this).
//!
//! Scalar layouts have `PAYLOAD_BYTES = 0`, which makes the record
//! stride exactly `KEY_BYTES`: the wire format of every pre-record
//! spill, dataset file and bench is preserved byte for byte.
//!
//! The format is deliberately headerless: a run file's record count is
//! `len / REC_BYTES`, checked on open ([`decode_into`] rejects ragged
//! tails), and the layout is part of the surrounding context (spill
//! runs are typed, `FileSource`/`FileSink` are generic over the record,
//! checkpoint manifests carry the layout name in their identity).

use anyhow::ensure;

use crate::dtype::SortKey;
use crate::stream::record::StreamRecord;

/// Encoded size in bytes of `n` records of layout `R`.
pub fn encoded_len<R: StreamRecord>(n: usize) -> usize {
    n * R::REC_BYTES
}

/// Append the records of `recs` to `out` (little-endian key image, then
/// raw payload bytes).
pub fn encode_into<R: StreamRecord>(recs: &[R], out: &mut Vec<u8>) {
    out.reserve(encoded_len::<R>(recs.len()));
    for r in recs {
        let bits = r.key_bits().to_le_bytes();
        out.extend_from_slice(&bits[..<R::Key as SortKey>::KEY_BYTES]);
        if R::PAYLOAD_BYTES > 0 {
            let payload = r.payload_raw().to_le_bytes();
            out.extend_from_slice(&payload[..R::PAYLOAD_BYTES]);
        }
    }
}

/// Decode every record in `bytes`, appending to `out`; errors on a
/// ragged tail (truncated spill / foreign file / wrong layout).
pub fn decode_into<R: StreamRecord>(bytes: &[u8], out: &mut Vec<R>) -> anyhow::Result<usize> {
    let kb = <R::Key as SortKey>::KEY_BYTES;
    ensure!(
        bytes.len() % R::REC_BYTES == 0,
        "record codec: {} bytes is not a multiple of the {}-byte {} record",
        bytes.len(),
        R::REC_BYTES,
        R::layout_name(),
    );
    let n = bytes.len() / R::REC_BYTES;
    out.reserve(n);
    for rec in bytes.chunks_exact(R::REC_BYTES) {
        let mut wide = [0u8; 16];
        wide[..kb].copy_from_slice(&rec[..kb]);
        let key = R::Key::from_bits(u128::from_le_bytes(wide));
        let mut praw = [0u8; 16];
        praw[..R::PAYLOAD_BYTES].copy_from_slice(&rec[kb..]);
        out.push(R::from_parts(key, u128::from_le_bytes(praw)));
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::bits_eq;
    use crate::stream::record::Record;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn roundtrip<K: KeyGen + StreamRecord>(seed: u64, n: usize) {
        let xs: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        assert_eq!(bytes.len(), encoded_len::<K>(n));
        let mut back: Vec<K> = Vec::new();
        assert_eq!(decode_into(&bytes, &mut back).unwrap(), n);
        assert!(bits_eq(&xs, &back));
    }

    #[test]
    fn all_dtypes_roundtrip() {
        roundtrip::<i16>(1, 500);
        roundtrip::<i32>(2, 500);
        roundtrip::<i64>(3, 500);
        roundtrip::<i128>(4, 500);
        roundtrip::<f32>(5, 500);
        roundtrip::<f64>(6, 500);
    }

    #[test]
    fn ieee_oddities_survive_bit_exactly() {
        let xs = vec![f64::NAN, -f64::NAN, -0.0, 0.0, f64::INFINITY, f64::NEG_INFINITY, 1.5];
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        let mut back: Vec<f64> = Vec::new();
        decode_into(&bytes, &mut back).unwrap();
        assert!(bits_eq(&xs, &back));
        // Raw IEEE bits (not just the sort image) are preserved.
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ragged_tail_rejected() {
        let xs = vec![7i32, 8];
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        bytes.pop();
        let mut back: Vec<i32> = Vec::new();
        assert!(decode_into(&bytes, &mut back).is_err());
    }

    #[test]
    fn decode_appends() {
        let mut bytes = Vec::new();
        encode_into(&[1i16, 2], &mut bytes);
        let mut out = vec![0i16];
        decode_into(&bytes, &mut out).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn scalar_wire_format_is_the_pre_record_format() {
        // payload_bytes = 0 must encode exactly the bare key images —
        // the compatibility guarantee that keeps old spills readable.
        let xs = vec![-3i32, 0, 7];
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        let mut want = Vec::new();
        for &k in &xs {
            want.extend_from_slice(&k.to_bits().to_le_bytes()[..4]);
        }
        assert_eq!(bytes, want);
    }

    #[test]
    fn record_layouts_roundtrip_with_payloads() {
        let xs: Vec<Record<f64, u64>> = vec![
            Record::new(f64::NAN, 1),
            Record::new(-0.0, u64::MAX),
            Record::new(0.0, 0),
            Record::new(-1.5, 0xDEAD_BEEF),
        ];
        let mut bytes = Vec::new();
        encode_into(&xs, &mut bytes);
        assert_eq!(bytes.len(), xs.len() * 16);
        let mut back: Vec<Record<f64, u64>> = Vec::new();
        assert_eq!(decode_into(&bytes, &mut back).unwrap(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.key.to_bits(), b.key.to_bits());
            assert_eq!(a.val, b.val);
        }
        // Payload truncation is a ragged tail, not silent corruption.
        bytes.pop();
        let mut bad: Vec<Record<f64, u64>> = Vec::new();
        assert!(decode_into(&bytes, &mut bad).is_err());
    }
}
