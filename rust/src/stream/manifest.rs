//! The crash-safe run manifest (DESIGN.md §15).
//!
//! A checkpointed spill directory carries one `MANIFEST.json` — the
//! single durable source of truth for which spilled runs are *real*.
//! Every mutation is atomic: the new manifest is written to
//! `MANIFEST.json.tmp`, fsynced, and renamed over the old one (POSIX
//! rename is atomic), then the directory is fsynced so the rename
//! itself is durable. A crash therefore leaves either the old or the
//! new manifest on disk, never a torn one — and any run file the
//! surviving manifest does not reference is, by definition, garbage
//! that the next resume sweeps.
//!
//! The manifest is versioned (`MANIFEST_VERSION`): a resume of a spill
//! directory written by a future incompatible format fails loudly
//! instead of misreading it, and old directories stay readable for as
//! long as their version is supported.
//!
//! Serialisation rides [`crate::util::json`]; splitter bit images are
//! `u128` and `Json::Num` is an `f64`, so splitters serialise as
//! decimal *strings*. Element/byte counts stay well under 2^53 and are
//! stored as plain numbers.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::failpoint;
use crate::util::json::Json;

/// Current manifest format version.
pub const MANIFEST_VERSION: u64 = 1;
/// Manifest file name inside a checkpointed spill directory.
pub const MANIFEST_FILE: &str = "MANIFEST.json";
const MANIFEST_TMP: &str = "MANIFEST.json.tmp";

/// One durable sorted run the manifest vouches for.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunMeta {
    /// File name relative to the spill directory.
    pub file: String,
    /// Elements in the run.
    pub elems: u64,
    /// Producer tier: 0 = generated run, 1.. = merge pass outputs; the
    /// SIHSort rank manifest reuses it as the phase that produced the
    /// run (1 = parked shard, 5 = exchange runs, 6 = final output).
    pub pass: u32,
    /// Stable ordering key within a pass (generation order, or the
    /// source rank for exchange runs).
    pub seq: u64,
}

/// Durable job state for one checkpointed sort (external or per-rank).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Format version ([`MANIFEST_VERSION`]).
    pub version: u64,
    /// Job kind: `"external_sort"` or `"sihsort_rank"`.
    pub kind: String,
    /// Caller tag; a resume must present the same tag (guards against
    /// resuming rank 2's directory as rank 0).
    pub tag: String,
    /// Element type name; a resume must sort the same dtype.
    pub dtype: String,
    /// Run-generation chunk size the job started with; a resume must
    /// derive the same value or the skip arithmetic would be wrong.
    pub run_chunk: u64,
    /// True once run generation consumed the whole input.
    pub gen_done: bool,
    /// True once the job's output was delivered; resuming is a no-op.
    pub complete: bool,
    /// SIHSort rank phase high-water mark (0 for external sorts).
    pub phase: u32,
    /// Splitter refinement rounds used (recorded with `splitters`).
    pub rounds_used: u64,
    /// Chosen splitter bit images (SIHSort phase 3 state).
    pub splitters: Vec<u128>,
    /// Every durable run, in recording order.
    pub runs: Vec<RunMeta>,
    /// Next spill-file id, so resumed writers never reuse a name.
    pub next_seq: u64,
}

impl Manifest {
    /// Fresh manifest for a new job.
    pub fn new(kind: &str, tag: &str, dtype: &str, run_chunk: u64) -> Manifest {
        Manifest {
            version: MANIFEST_VERSION,
            kind: kind.to_string(),
            tag: tag.to_string(),
            dtype: dtype.to_string(),
            run_chunk,
            gen_done: false,
            complete: false,
            phase: 0,
            rounds_used: 0,
            splitters: Vec::new(),
            runs: Vec::new(),
            next_seq: 0,
        }
    }

    /// Serialise (schema version [`MANIFEST_VERSION`]).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"version\": {},\n", self.version));
        s.push_str(&format!("  \"kind\": \"{}\",\n", self.kind));
        s.push_str(&format!("  \"tag\": \"{}\",\n", self.tag));
        s.push_str(&format!("  \"dtype\": \"{}\",\n", self.dtype));
        s.push_str(&format!("  \"run_chunk\": {},\n", self.run_chunk));
        s.push_str(&format!("  \"gen_done\": {},\n", self.gen_done));
        s.push_str(&format!("  \"complete\": {},\n", self.complete));
        s.push_str(&format!("  \"phase\": {},\n", self.phase));
        s.push_str(&format!("  \"rounds_used\": {},\n", self.rounds_used));
        let spl: Vec<String> =
            self.splitters.iter().map(|b| format!("\"{b}\"")).collect();
        s.push_str(&format!("  \"splitters\": [{}],\n", spl.join(", ")));
        s.push_str("  \"runs\": [\n");
        for (i, r) in self.runs.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"file\": \"{}\", \"elems\": {}, \"pass\": {}, \"seq\": {}}}{}\n",
                r.file,
                r.elems,
                r.pass,
                r.seq,
                if i + 1 == self.runs.len() { "" } else { "," },
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"next_seq\": {}\n", self.next_seq));
        s.push_str("}\n");
        s
    }

    /// Parse a serialised manifest, verifying the version is supported.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).context("parsing spill manifest")?;
        let version = j
            .get("version")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("manifest missing version"))?
            as u64;
        anyhow::ensure!(
            version <= MANIFEST_VERSION,
            "spill manifest version {version} is newer than supported {MANIFEST_VERSION}"
        );
        let field = |k: &str| -> anyhow::Result<u64> {
            j.get(k)
                .as_usize()
                .map(|v| v as u64)
                .ok_or_else(|| anyhow::anyhow!("manifest missing numeric '{k}'"))
        };
        let text_field = |k: &str| -> anyhow::Result<String> {
            j.get(k)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("manifest missing string '{k}'"))
        };
        let flag = |k: &str| -> anyhow::Result<bool> {
            match j.get(k) {
                Json::Bool(b) => Ok(*b),
                _ => Err(anyhow::anyhow!("manifest missing flag '{k}'")),
            }
        };
        let mut splitters = Vec::new();
        for s in j.get("splitters").as_arr().unwrap_or(&[]) {
            let txt = s
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest splitter is not a string"))?;
            splitters
                .push(txt.parse::<u128>().with_context(|| format!("splitter '{txt}'"))?);
        }
        let mut runs = Vec::new();
        for r in j.get("runs").as_arr().unwrap_or(&[]) {
            runs.push(RunMeta {
                file: r
                    .get("file")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("manifest run missing file"))?
                    .to_string(),
                elems: r
                    .get("elems")
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("manifest run missing elems"))?
                    as u64,
                pass: r
                    .get("pass")
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("manifest run missing pass"))?
                    as u32,
                seq: r
                    .get("seq")
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("manifest run missing seq"))?
                    as u64,
            });
        }
        Ok(Manifest {
            version,
            kind: text_field("kind")?,
            tag: text_field("tag")?,
            dtype: text_field("dtype")?,
            run_chunk: field("run_chunk")?,
            gen_done: flag("gen_done")?,
            complete: flag("complete")?,
            phase: field("phase")? as u32,
            rounds_used: field("rounds_used")?,
            splitters,
            runs,
            next_seq: field("next_seq")?,
        })
    }
}

/// Atomically persist `m` as `dir/MANIFEST.json`: write the temp file,
/// fsync it, rename over the live manifest, fsync the directory. The
/// `manifest.rename` fail point sits exactly in the crash window the
/// protocol defends — after the temp write, before the rename.
pub fn write_manifest(dir: &Path, m: &Manifest) -> anyhow::Result<()> {
    let _span = crate::obs::span1(
        crate::obs::SpanKind::Checkpoint,
        "manifest.write",
        m.runs.len() as u64,
    );
    let tmp = dir.join(MANIFEST_TMP);
    let live = dir.join(MANIFEST_FILE);
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        use std::io::Write;
        f.write_all(m.to_json().as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    failpoint::check("manifest.rename")?;
    fs::rename(&tmp, &live)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), live.display()))?;
    // Make the rename itself durable. Directory fsync is best-effort:
    // not every filesystem supports opening a directory for sync.
    if let Ok(d) = fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Load `dir/MANIFEST.json` if present. A leftover temp file from a
/// crash mid-write is ignored (and later swept); only the renamed
/// manifest counts.
pub fn load_manifest(dir: &Path) -> anyhow::Result<Option<Manifest>> {
    let live = dir.join(MANIFEST_FILE);
    let text = match fs::read_to_string(&live) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading {}", live.display())),
    };
    Manifest::parse(&text).with_context(|| live.display().to_string()).map(Some)
}

/// Delete every regular file in `dir` the manifest does not reference
/// (crash orphans: half-written runs, stale temp manifests).
/// Subdirectories are left alone — a SIHSort rank directory nests its
/// phase-1 `local/` checkpoint, which has its own manifest.
pub fn sweep_unmanifested(dir: &Path, m: &Manifest) -> anyhow::Result<()> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    for entry in entries {
        let entry = entry?;
        if entry.file_type()?.is_dir() {
            continue;
        }
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == MANIFEST_FILE || m.runs.iter().any(|r| r.file == name) {
            continue;
        }
        fs::remove_file(entry.path())
            .with_context(|| format!("sweeping {}", entry.path().display()))?;
    }
    Ok(())
}

/// Remove everything inside `dir` (a fresh, non-resuming checkpointed
/// job starts from a clean slate). The directory itself survives.
pub fn clear_dir(dir: &Path) -> anyhow::Result<()> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    for entry in entries {
        let entry = entry?;
        if entry.file_type()?.is_dir() {
            fs::remove_dir_all(entry.path())
                .with_context(|| format!("clearing {}", entry.path().display()))?;
        } else {
            fs::remove_file(entry.path())
                .with_context(|| format!("clearing {}", entry.path().display()))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let mut m = Manifest::new("sihsort_rank", "p4-r2", "f64", 4096);
        m.gen_done = true;
        m.phase = 5;
        m.rounds_used = 3;
        m.splitters = vec![0, u128::MAX, 1 << 90];
        m.runs = vec![
            RunMeta { file: "run-0.bin".into(), elems: 4096, pass: 0, seq: 0 },
            RunMeta { file: "run-7.bin".into(), elems: 123, pass: 5, seq: 3 },
        ];
        m.next_seq = 8;
        m
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let m = sample();
        let back = Manifest::parse(&m.to_json()).unwrap();
        assert_eq!(m, back);
        // u128 splitters survive exactly (they exceed f64 precision).
        assert_eq!(back.splitters[1], u128::MAX);
    }

    #[test]
    fn future_version_rejected() {
        let mut m = sample();
        m.version = MANIFEST_VERSION + 1;
        let err = Manifest::parse(&m.to_json()).unwrap_err();
        assert!(err.to_string().contains("newer than supported"), "{err}");
    }

    #[test]
    fn write_load_sweep() {
        let dir = std::env::temp_dir().join(format!("akmanifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample();
        write_manifest(&dir, &m).unwrap();
        assert_eq!(load_manifest(&dir).unwrap().unwrap(), m);
        // Orphans (crash leftovers) are swept; manifested files and
        // subdirectories survive.
        std::fs::write(dir.join("run-0.bin"), b"keep").unwrap();
        std::fs::write(dir.join("run-99.bin"), b"orphan").unwrap();
        std::fs::write(dir.join(MANIFEST_TMP), b"{}").unwrap();
        std::fs::create_dir_all(dir.join("local")).unwrap();
        std::fs::write(dir.join("local").join("nested.bin"), b"nested").unwrap();
        sweep_unmanifested(&dir, &m).unwrap();
        assert!(dir.join("run-0.bin").exists());
        assert!(!dir.join("run-99.bin").exists());
        assert!(!dir.join(MANIFEST_TMP).exists());
        assert!(dir.join("local").join("nested.bin").exists());
        assert!(dir.join(MANIFEST_FILE).exists());
        clear_dir(&dir).unwrap();
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        assert!(load_manifest(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_is_none() {
        let dir = std::env::temp_dir().join("akmanifest-none-nonexistent");
        assert!(load_manifest(&dir).unwrap().is_none());
    }
}
