//! # accelkern — cross-architecture parallel algorithms, AOT-transpiled
//!
//! A Rust + JAX + Pallas reproduction of *"AcceleratedKernels.jl:
//! Cross-Architecture Parallel Algorithms from a Unified, Transpiled
//! Codebase"* (CS.DC 2025). See `DESIGN.md` for the full system inventory
//! and the paper→module map.
//!
//! Three layers:
//! * **L1** — Pallas kernels (`python/compile/kernels/`): bitonic tile
//!   sort, block scan/reduce, branch-free binary search, RBF & LJG
//!   arithmetic kernels.
//! * **L2** — JAX graphs (`python/compile/model.py`) composing the
//!   kernels, AOT-lowered once to HLO text (`artifacts/`).
//! * **L3** — this crate: the [`runtime`] loads the artifacts via PJRT,
//!   the [`session`] API ([`Session`]/[`Launch`]) exposes the paper's
//!   unified call surface — per-call tuning knobs, typed [`AkError`]s —
//!   over pluggable [`backend`]s (host engines live in [`algorithms`]),
//!   [`hybrid`] composes host and device engines into one CPU–GPU
//!   co-processing call (DESIGN.md §10), [`stream`] pipelines the same
//!   engines over datasets larger than RAM under a fixed memory budget
//!   (DESIGN.md §13), and [`mpisort`] implements the SIHSort multi-node
//!   sorting coordinator over a simulated HPC [`cluster`] with an
//!   MPI-like [`comm`] layer.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
#![warn(missing_docs)]

pub mod algorithms;
pub mod backend;
pub mod baselines;
pub mod bench;
pub mod cfg;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod coordinator;
pub mod cost;
pub mod dtype;
pub mod hybrid;
pub mod metrics;
pub mod mpisort;
pub mod obs;
pub mod prop;
pub mod runtime;
pub mod session;
pub mod stream;
pub mod util;
pub mod workload;

pub use session::{AkError, AkResult, Launch, Session};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Locate the `artifacts/` directory: `$ACCELKERN_ARTIFACTS` if set, else
/// `<repo root>/artifacts` — the default output of
/// `python -m compile.aot` (`make artifacts`) — resolved relative to the
/// crate manifest.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("ACCELKERN_ARTIFACTS") {
        return dir.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}
