//! The distributed-sort driver: spawns one thread per simulated rank,
//! runs SIHSort collectively, verifies global order + conservation, and
//! aggregates the run record.
//!
//! Fault tolerance (DESIGN.md §16): each job is an *attempt* on a fresh
//! fabric. A watchdog thread converts a hung collective into a typed
//! failure with per-rank diagnostics, and recoverable comm failures
//! (rank death, comm timeout) restart the whole collective in-process —
//! up to `[comm] max_restarts` times — against the *same* persistent
//! fault-injection state, resuming checkpointed ranks from their
//! manifests. Shards are regenerated deterministically per attempt.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::{Backend, DeviceKey};
use crate::cfg::{RunConfig, Sorter};
use crate::cluster::DeviceModel;
use crate::comm::{CommTuning, Fabric, FaultCounters};
use crate::dtype::SortKey;
use crate::hybrid::{calibrate_sort, HybridEngine, HybridPlan};
use crate::metrics::{legend_dtype, SortRunRecord};
use crate::mpisort::sihsort::checksum;
use crate::mpisort::{sihsort_rank, LocalSorter, RankOutcome, SihConfig};
use crate::runtime::{Registry, Runtime};
use crate::session::AkError;
use crate::util::Prng;
use crate::workload::{generate, KeyGen};

/// Full output of one distributed sort (record + verification data).
pub struct DistSortOutput {
    /// The paper-style run record (phase breakdown + fabric stats).
    pub record: SortRunRecord,
    /// Per-rank output sizes (bucket balance check).
    pub out_sizes: Vec<usize>,
    /// Splitter refinement rounds used.
    pub rounds_used: usize,
}

/// Run one homogeneous distributed sort per `cfg` (all ranks use
/// `cfg.sorter`). `runtime`: required iff the sorter is AK on an
/// XLA-supported dtype.
pub fn run_distributed_sort<K: DeviceKey + KeyGen>(
    cfg: &RunConfig,
    runtime: Option<Arc<Runtime>>,
) -> anyhow::Result<DistSortOutput> {
    let sorters = vec![cfg.sorter; cfg.ranks];
    run_distributed_sort_mixed::<K>(cfg, &sorters, runtime)
}

/// [`run_distributed_sort`] keeping the per-rank outcomes (sorted
/// shards + streaming stats): what the cluster-stream bench and the
/// equivalence tests verify bitwise against a single `Session::sort`.
pub fn run_distributed_sort_data<K: DeviceKey + KeyGen>(
    cfg: &RunConfig,
    runtime: Option<Arc<Runtime>>,
) -> anyhow::Result<(DistSortOutput, Vec<RankOutcome<K>>)> {
    let sorters = vec![cfg.sorter; cfg.ranks];
    run_distributed_sort_full::<K>(cfg, &sorters, runtime)
}

/// Heterogeneous variant: per-rank sorter assignment — the paper's
/// CPU-GPU *co-sorting* composability demo (examples/cosort.rs) uses CPU
/// JB ranks next to device ranks in one collective sort.
pub fn run_distributed_sort_mixed<K: DeviceKey + KeyGen>(
    cfg: &RunConfig,
    sorters: &[Sorter],
    runtime: Option<Arc<Runtime>>,
) -> anyhow::Result<DistSortOutput> {
    Ok(run_distributed_sort_full::<K>(cfg, sorters, runtime)?.0)
}

/// The full driver: heterogeneous sorters, outcomes returned alongside
/// the aggregate record. Shards are the seeded workload, regenerated
/// identically per restart attempt.
pub fn run_distributed_sort_full<K: DeviceKey + KeyGen>(
    cfg: &RunConfig,
    sorters: &[Sorter],
    runtime: Option<Arc<Runtime>>,
) -> anyhow::Result<(DistSortOutput, Vec<RankOutcome<K>>)> {
    run_distributed_sort_shards::<K, _>(cfg, sorters, runtime, || {
        let mut root = Prng::new(cfg.seed);
        (0..cfg.ranks)
            .map(|r| {
                let mut rng = root.fork(r as u64);
                generate::<K>(&mut rng, cfg.dist, cfg.elems_per_rank)
            })
            .collect()
    })
}

/// [`run_distributed_sort_full`] with caller-supplied shards: the fault
/// and equivalence suites inject adversarial payloads (NaN / -0.0
/// floats) that the seeded generator cannot produce. `make_shards` runs
/// once per restart attempt and must be deterministic — recovery
/// replays the identical input (checkpointed ranks validate it against
/// their manifests).
pub fn run_distributed_sort_shards<K: DeviceKey, F>(
    cfg: &RunConfig,
    sorters: &[Sorter],
    runtime: Option<Arc<Runtime>>,
    make_shards: F,
) -> anyhow::Result<(DistSortOutput, Vec<RankOutcome<K>>)>
where
    F: Fn() -> Vec<Vec<K>>,
{
    anyhow::ensure!(sorters.len() == cfg.ranks, "one sorter per rank");
    // The streamed exchange speaks a chunked wire protocol (k data
    // messages + end marker per peer) where alltoallv sends exactly one
    // message per peer — the two cannot share a collective, so External
    // is all-or-nothing across ranks.
    let n_external = sorters.iter().filter(|s| matches!(s, Sorter::External)).count();
    anyhow::ensure!(
        n_external == 0 || n_external == sorters.len(),
        "the external (streamed) sorter cannot mix with in-memory sorters in one \
         collective: its chunked exchange protocol differs from alltoallv"
    );
    anyhow::ensure!(
        K::ELEM == cfg.dtype,
        "type parameter {} disagrees with cfg.dtype {} (labels/byte counts would lie)",
        K::ELEM,
        cfg.dtype
    );
    let needs_ak = sorters.iter().any(|s| matches!(s, Sorter::Ak | Sorter::Hybrid));
    let device_backend: Option<Backend> = if needs_ak {
        match (&runtime, K::XLA) {
            (Some(rt), true) => {
                // Pre-warm the sort executables: XLA compiles lazily on
                // first use, and a multi-second compile inside one rank's
                // measured local-sort section would corrupt that run's
                // simulated time (it is a one-time build cost, not work).
                for a in rt.manifest().family("sort", K::ELEM) {
                    let _ = rt.get(&a.name);
                }
                Some(Backend::device(Registry::new(rt.clone())))
            }
            // No artifacts (or i128): AK degrades to its host merge path —
            // the same chunk-sort + merge structure, host engine. Keeps
            // everything runnable pre-`make artifacts`; benches pass the
            // real runtime.
            _ => Some(Backend::Threaded(1)),
        }
    } else {
        None
    };

    // Hybrid ranks share one engine, calibrated once per run (or pinned
    // by --host-fraction): measuring inside each rank's sort section
    // would pollute the simulated times (DESIGN.md §10).
    let hybrid_engine: Option<HybridEngine> = if sorters.iter().any(|s| *s == Sorter::Hybrid) {
        let plan = match cfg.hybrid_host_fraction {
            Some(f) => HybridPlan::new(f),
            None => {
                let device_ops = device_backend.as_ref().and_then(|b| b.device_ops());
                let cal = calibrate_sort::<K>(32 * 1024, cfg.host_threads, device_ops)?;
                // Split for the engines as they actually execute (real
                // artifacts or the 1-thread stand-in): the rank's
                // simulated time scales its *measured* wall clock, so
                // minimising the wall clock minimises simulated time too.
                // Model projections and the cost-normalised variant are
                // exposed via `akbench calibrate` and the plan API.
                cal.plan_measured(1.0)
            }
        };
        Some(HybridEngine::from_backends(plan, cfg.host_threads, device_backend.clone()))
    } else {
        None
    };

    // External (out-of-core) ranks: resolve the [stream] knobs once and
    // share one StreamCtx across ranks (sessions are cheap to clone and
    // Sync; each rank still gets its own spill stores). Default budget:
    // a quarter of the per-rank shard — `--local-sorter external`
    // without an explicit `--stream-budget-mb` actually streams.
    let stream_cfg: Option<crate::mpisort::SihStreamCfg> =
        if sorters.iter().any(|s| *s == Sorter::External) {
            let budget = cfg
                .stream
                .budget_bytes
                .unwrap_or_else(|| (cfg.elems_per_rank * cfg.dtype.size_bytes() / 4).max(1));
            Some(crate::mpisort::SihStreamCfg {
                budget: crate::stream::StreamBudget::bytes(budget),
                medium: if cfg.stream.spill_memory {
                    crate::stream::SpillMedium::Memory
                } else {
                    crate::stream::SpillMedium::Disk
                },
                spill_dir: cfg.stream.spill_dir.clone().map(std::path::PathBuf::from),
                ckpt_dir: cfg.stream.checkpoint_dir.clone().map(std::path::PathBuf::from),
                resume: cfg.stream.resume,
            })
        } else {
            None
        };
    // Checkpointing lives in the streamed rank pipeline: every rank
    // must be External for `[stream] checkpoint` / `--resume` to mean
    // anything — fail loudly instead of silently not checkpointing.
    anyhow::ensure!(
        cfg.stream.checkpoint_dir.is_none() || n_external == sorters.len(),
        "checkpoint/resume requires the external sorter on every rank \
         (--sorter EX / --local-sorter external)"
    );
    anyhow::ensure!(
        !cfg.stream.resume || cfg.stream.checkpoint_dir.is_some(),
        "--resume requires a checkpoint directory ([stream] checkpoint / --checkpoint-dir)"
    );
    let stream_ctx: Option<crate::stream::StreamCtx> = stream_cfg.as_ref().map(|s| {
        let session = crate::session::Session::threaded(cfg.host_threads)
            .with_defaults(cfg.launch.clone());
        s.ctx(session)
    });

    let sih_base = SihConfig {
        samples_per_rank: cfg.samples_per_rank,
        refine_rounds: cfg.refine_rounds,
        balance_tol: cfg.balance_tol,
        final_phase: cfg.final_phase,
        devmodel: DeviceModel::new(cfg.cluster.gpu_speedup),
        launch: cfg.launch.clone(),
        stream: stream_cfg,
    };

    // Fault-injection state persists across restart attempts: one-shot
    // kill/stall rules stay fired, drop budgets stay spent, and the
    // global send-op counter keeps healing partitions — a restarted job
    // faces the *rest* of the fault schedule, not a fresh copy of it.
    let mut base_tuning = cfg.comm.tuning();
    base_tuning.faults = cfg.comm.fault_plan()?.map(|p| p.state());

    let wall0 = Instant::now();
    let mut fault_totals = FaultCounters::default();
    let mut recoveries = 0u64;
    let mut attempt = 0u64;
    loop {
        let mut tuning = base_tuning.clone();
        tuning.epoch = attempt;
        // Restart attempts of a checkpointed job resume from the
        // per-rank manifests instead of redoing committed phases.
        let mut sih = sih_base.clone();
        if attempt > 0 {
            if let Some(s) = sih.stream.as_mut() {
                if s.ckpt_dir.is_some() {
                    s.resume = true;
                }
            }
        }
        let (res, counters) = run_attempt::<K, F>(
            cfg,
            sorters,
            &sih,
            tuning,
            &make_shards,
            &device_backend,
            &hybrid_engine,
            &stream_ctx,
        );
        fault_totals.add(counters);
        match res {
            Ok((mut out, outcomes)) => {
                out.record.wall_secs = wall0.elapsed().as_secs_f64();
                out.record.fabric = fault_totals.snapshot_with_recoveries(recoveries);
                return Ok((out, outcomes));
            }
            Err(e) => {
                if attempt >= u64::from(cfg.comm.max_restarts) || !recoverable_comm_error(&e) {
                    return Err(e);
                }
                attempt += 1;
                recoveries += 1;
                crate::obs::instant2(
                    crate::obs::SpanKind::Recovery,
                    "driver.restart",
                    attempt,
                );
            }
        }
    }
}

/// One collective attempt on a fresh fabric. Returns the attempt's
/// result alongside its fabric fault counters (captured even on
/// failure, so the driver can sum them across attempts).
#[allow(clippy::too_many_arguments)]
fn run_attempt<K: DeviceKey, F: Fn() -> Vec<Vec<K>>>(
    cfg: &RunConfig,
    sorters: &[Sorter],
    sih: &SihConfig,
    tuning: CommTuning,
    make_shards: &F,
    device_backend: &Option<Backend>,
    hybrid_engine: &Option<HybridEngine>,
    stream_ctx: &Option<crate::stream::StreamCtx>,
) -> (anyhow::Result<(DistSortOutput, Vec<RankOutcome<K>>)>, FaultCounters) {
    let shards = make_shards();
    debug_assert_eq!(shards.len(), cfg.ranks);
    let in_checksum = shards.iter().map(|s| checksum(s)).fold((0u64, 0u128), |a, b| {
        (a.0 + b.0, a.1.wrapping_add(b.1))
    });

    let device_flags: Vec<bool> = sorters.iter().map(|s| s.is_device()).collect();
    let eps = Fabric::new_with(cfg.cluster.clone(), cfg.transfer, device_flags, tuning);
    let ctl = eps[0].ctl();

    let wall0 = Instant::now();
    let results: Mutex<Vec<(usize, anyhow::Result<(RankOutcome<K>, f64, u64, u64)>)>> =
        Mutex::new(Vec::with_capacity(cfg.ranks));
    // Rank threads that *ended* — by pushing a result or by unwinding
    // (drop guard). The watchdog waits on this, not on `results`, so an
    // injected panic on every rank releases it immediately instead of
    // stalling the join until the watchdog deadline.
    let ended = AtomicUsize::new(0);
    let wd_fired = AtomicBool::new(false);
    let wd_blamed = AtomicUsize::new(0);
    let wd_detail: Mutex<String> = Mutex::new(String::new());

    /// Counts a rank thread as ended on both return and unwind.
    struct EndGuard<'a>(&'a AtomicUsize);
    impl Drop for EndGuard<'_> {
        fn drop(&mut self) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    std::thread::scope(|s| {
        for ((mut ep, shard), sorter_kind) in
            eps.into_iter().zip(shards.into_iter()).zip(sorters.iter().copied())
        {
            let sih = sih.clone();
            let results = &results;
            let ended = &ended;
            let device_backend = device_backend.clone();
            let hybrid_engine = hybrid_engine.clone();
            let stream_ctx = stream_ctx.clone();
            s.spawn(move || {
                let _end = EndGuard(ended);
                let rank = ep.rank();
                let run = (|| {
                    let sorter = LocalSorter::from_cfg(
                        sorter_kind,
                        device_backend,
                        hybrid_engine,
                        stream_ctx,
                    )?;
                    let outcome = sihsort_rank(&mut ep, shard, &sorter, &sih)?;
                    let (msgs, wire) = ep.stats().snapshot();
                    Ok((outcome, ep.sim_makespan(), msgs, wire))
                })();
                results.lock().unwrap().push((rank, run));
            });
        }

        // Driver watchdog: a rank wedged outside the fabric's own
        // deadlines (e.g. stuck in a compute section) would hang the
        // join forever — convert it into a coordinated abort and a
        // typed failure carrying per-rank phase/clock diagnostics.
        let ctl_w = ctl.clone();
        let ended_ref = &ended;
        let (fired, blamed, detail) = (&wd_fired, &wd_blamed, &wd_detail);
        let ranks = cfg.ranks;
        let deadline = Duration::from_secs_f64(cfg.comm.watchdog_secs);
        s.spawn(move || {
            let t0 = Instant::now();
            while ended_ref.load(Ordering::SeqCst) < ranks {
                if t0.elapsed() >= deadline {
                    // Attach the live span stacks: what each traced
                    // thread was inside when the watchdog fired.
                    let mut d = ctl_w.diag_table();
                    let stacks = crate::obs::live_stacks_table();
                    if !stacks.is_empty() {
                        d.push('\n');
                        d.push_str(&stacks);
                    }
                    *detail.lock().unwrap() = d;
                    let blame = ctl_w.unfinished_ranks().first().copied().unwrap_or(0);
                    blamed.store(blame, Ordering::SeqCst);
                    fired.store(true, Ordering::SeqCst);
                    ctl_w.abort_all(blame);
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
    });
    let wall_secs = wall0.elapsed().as_secs_f64();
    let counters = ctl.stats().fault_counters();

    if wd_fired.load(Ordering::SeqCst) {
        let err = AkError::CommTimeout {
            op: "watchdog",
            rank: wd_blamed.load(Ordering::SeqCst),
            peer: None,
            waited_secs: cfg.comm.watchdog_secs,
            detail: wd_detail.into_inner().unwrap(),
        };
        return (Err(anyhow::Error::new(err)), counters);
    }

    let mut per_rank = results.into_inner().unwrap();
    per_rank.sort_by_key(|(r, _)| *r);
    let mut outcomes = Vec::with_capacity(cfg.ranks);
    let mut makespan = 0.0f64;
    let (mut msgs, mut wire) = (0u64, 0u64);
    // When several ranks fail, prefer the root cause over the secondary
    // RankDead/CommTimeout errors an abort fanned out to the survivors:
    // a failpoint abort first (the crash/resume suite classifies on
    // it), then a detected deadlock (the named cycle beats the peers'
    // RankDead wake-ups), then the lowest-rank error.
    fn is_deadlock(e: &anyhow::Error) -> bool {
        e.chain().any(|c| matches!(c.downcast_ref::<AkError>(), Some(AkError::Deadlock { .. })))
    }
    fn err_priority(e: &anyhow::Error) -> u8 {
        if crate::util::failpoint::is_abort(e) {
            2
        } else if is_deadlock(e) {
            1
        } else {
            0
        }
    }
    let mut first_err: Option<(usize, anyhow::Error)> = None;
    for (rank, res) in per_rank {
        match res {
            Ok((o, mk, m, w)) => {
                makespan = makespan.max(mk);
                msgs = m; // shared counters: any rank's final snapshot is global
                wire = w;
                outcomes.push(o);
            }
            Err(e) => {
                let replaces = match &first_err {
                    None => true,
                    Some((_, prev)) => err_priority(&e) > err_priority(prev),
                };
                if replaces {
                    first_err = Some((rank, e));
                }
            }
        }
    }
    if let Some((rank, e)) = first_err {
        return (Err(e.context(format!("rank {rank}"))), counters);
    }

    let res = (|| {
        // Post-rank kill site: every rank committed phase 6, the driver
        // dies before verifying — a resume must reload all outputs
        // cheaply and still pass verification.
        crate::util::failpoint::check("driver.verify")?;
        verify_outcomes(&outcomes, in_checksum)?;

        let phase_max = |f: fn(&RankOutcome<K>) -> f64| {
            outcomes.iter().map(f).fold(0.0f64, f64::max)
        };
        let record = SortRunRecord {
            label: legend_dtype(cfg),
            ranks: cfg.ranks,
            total_bytes: cfg.total_bytes(),
            sim_total: makespan,
            sim_local_sort: phase_max(|o| o.sim_local_sort),
            sim_splitters: phase_max(|o| o.sim_splitters),
            sim_exchange: phase_max(|o| o.sim_exchange),
            sim_final: phase_max(|o| o.sim_final),
            messages: msgs,
            wire_bytes: wire,
            fabric: crate::obs::CounterSnapshot::zeroed(&crate::obs::FABRIC_COUNTERS),
            wall_secs,
        };
        Ok((
            DistSortOutput {
                out_sizes: outcomes.iter().map(|o| o.data.len()).collect(),
                rounds_used: outcomes.iter().map(|o| o.rounds_used).max().unwrap_or(0),
                record,
            },
            outcomes,
        ))
    })();
    (res, counters)
}

/// True when `e` is a comm-layer failure the driver may retry: a dead
/// rank or a timed-out operation. Injected failpoint crashes are *not*
/// recoverable — the crash/resume suite drives resume explicitly.
fn recoverable_comm_error(e: &anyhow::Error) -> bool {
    if crate::util::failpoint::is_abort(e) {
        return false;
    }
    e.chain().any(|c| {
        matches!(
            c.downcast_ref::<AkError>(),
            Some(AkError::RankDead { .. } | AkError::CommTimeout { .. })
        )
    })
}

/// Global correctness: every shard ascending, shard boundaries ordered,
/// and input/output conservation by checksum.
fn verify_outcomes<K: SortKey>(
    outcomes: &[RankOutcome<K>],
    in_checksum: (u64, u128),
) -> anyhow::Result<()> {
    let mut out_count = 0u64;
    let mut out_sum = 0u128;
    let mut prev_max: Option<u128> = None;
    for (r, o) in outcomes.iter().enumerate() {
        anyhow::ensure!(
            crate::dtype::is_sorted_total(&o.data),
            "rank {r}: local output not sorted"
        );
        if let (Some(pm), Some(first)) = (prev_max, o.data.first()) {
            anyhow::ensure!(
                pm <= first.to_bits(),
                "rank {r}: global order violated at boundary"
            );
        }
        if let Some(last) = o.data.last() {
            prev_max = Some(last.to_bits());
        }
        let (c, s) = checksum(&o.data);
        out_count += c;
        out_sum = out_sum.wrapping_add(s);
    }
    anyhow::ensure!(
        (out_count, out_sum) == in_checksum,
        "conservation violated: in {:?} out {:?}",
        in_checksum,
        (out_count, out_sum)
    );
    Ok(())
}

/// Convenience: dtype-dispatched homogeneous run (for CLI/benches).
pub fn run_for_config(
    cfg: &RunConfig,
    runtime: Option<Arc<Runtime>>,
) -> anyhow::Result<DistSortOutput> {
    crate::dispatch_dtype!(cfg.dtype, K => run_distributed_sort::<K>(cfg, runtime))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::{FinalPhase, TransferMode};
    use crate::dtype::ElemType;
    use crate::workload::Distribution;

    fn small_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.ranks = 6;
        cfg.elems_per_rank = 5000;
        cfg.dtype = ElemType::I32;
        cfg.sorter = Sorter::ThrustRadix;
        cfg.transfer = TransferMode::GpuDirect;
        cfg
    }

    #[test]
    fn homogeneous_sort_verifies() {
        let out = run_distributed_sort::<i32>(&small_cfg(), None).unwrap();
        assert_eq!(out.out_sizes.iter().sum::<usize>(), 6 * 5000);
        assert!(out.record.sim_total > 0.0);
        assert!(out.record.messages > 0);
    }

    #[test]
    fn balance_within_tolerance() {
        let mut cfg = small_cfg();
        cfg.ranks = 4;
        cfg.elems_per_rank = 20_000;
        cfg.balance_tol = 0.05;
        cfg.refine_rounds = 8;
        cfg.dtype = ElemType::I64;
        let out = run_distributed_sort::<i64>(&cfg, None).unwrap();
        let ideal = (4 * 20_000) as f64 / 4.0;
        for sz in &out.out_sizes {
            let err = (*sz as f64 - ideal).abs() / ideal;
            assert!(err < 0.12, "bucket size {sz} vs ideal {ideal}");
        }
    }

    #[test]
    fn all_dtypes_sort() {
        for dt in ElemType::ALL {
            let mut cfg = small_cfg();
            cfg.ranks = 3;
            cfg.elems_per_rank = 2000;
            cfg.dtype = dt;
            run_for_config(&cfg, None).unwrap();
        }
    }

    #[test]
    fn final_phase_variants_agree() {
        let mut cfg = small_cfg();
        cfg.final_phase = FinalPhase::Merge;
        let a = run_distributed_sort::<i32>(&cfg, None).unwrap();
        cfg.final_phase = FinalPhase::Sort;
        let b = run_distributed_sort::<i32>(&cfg, None).unwrap();
        assert_eq!(a.out_sizes, b.out_sizes);
    }

    #[test]
    fn mixed_cpu_gpu_cosort() {
        let cfg = small_cfg();
        let sorters = vec![
            Sorter::JuliaBase,
            Sorter::ThrustRadix,
            Sorter::ThrustMerge,
            Sorter::JuliaBase,
            Sorter::ThrustRadix,
            Sorter::ThrustMerge,
        ];
        let out = run_distributed_sort_mixed::<i32>(&cfg, &sorters, None).unwrap();
        assert_eq!(out.out_sizes.iter().sum::<usize>(), 6 * 5000);
    }

    #[test]
    fn hybrid_ranks_cosort_in_collective() {
        // HY ranks co-sort their shards inside the same collective as CPU
        // and vendor ranks (DESIGN.md §10); the driver's verifier is the
        // oracle for order + conservation.
        let mut cfg = small_cfg();
        cfg.elems_per_rank = 20_000;
        let sorters = vec![
            Sorter::Hybrid,
            Sorter::JuliaBase,
            Sorter::Hybrid,
            Sorter::ThrustRadix,
            Sorter::Hybrid,
            Sorter::ThrustMerge,
        ];
        let out = run_distributed_sort_mixed::<i32>(&cfg, &sorters, None).unwrap();
        assert_eq!(out.out_sizes.iter().sum::<usize>(), 6 * 20_000);
    }

    #[test]
    fn homogeneous_hybrid_with_fixed_fraction() {
        let mut cfg = small_cfg();
        cfg.sorter = Sorter::Hybrid;
        cfg.hybrid_host_fraction = Some(0.5); // skip calibration in tests
        cfg.dtype = ElemType::F64;
        let out = run_distributed_sort::<f64>(&cfg, None).unwrap();
        assert_eq!(out.out_sizes.iter().sum::<usize>(), 6 * 5000);
        assert!(out.record.sim_total > 0.0);
    }

    #[test]
    fn external_ranks_sort_out_of_core_in_collective() {
        // EX ranks stream: a tiny budget forces multiple runs + merge
        // passes per rank; the driver's verifier is the oracle for
        // order + conservation, the stream stats for budget accounting.
        let mut cfg = small_cfg();
        cfg.sorter = Sorter::External;
        cfg.stream.spill_memory = true;
        cfg.stream.budget_bytes = Some(4 * 1024);
        let (out, outcomes) =
            run_distributed_sort_data::<i32>(&cfg, None).unwrap();
        assert_eq!(out.out_sizes.iter().sum::<usize>(), 6 * 5000);
        for o in &outcomes {
            let st = o.stream.as_ref().expect("external ranks report stream stats");
            assert_eq!(st.budget_bytes, 4 * 1024);
            assert!(st.local.runs > 1, "5000 elems under a 1k-elem chunk must spill runs");
            assert!(st.local.merge_passes >= 1);
        }
        // Mixing EX with in-memory ranks is rejected up front: the
        // chunked exchange protocol cannot share a collective with the
        // one-message-per-peer alltoallv.
        let sorters = vec![
            Sorter::External,
            Sorter::JuliaBase,
            Sorter::External,
            Sorter::ThrustRadix,
            Sorter::External,
            Sorter::ThrustMerge,
        ];
        let err = run_distributed_sort_mixed::<i32>(&cfg, &sorters, None).unwrap_err();
        assert!(format!("{err:#}").contains("cannot mix"), "{err:#}");
    }

    #[test]
    fn adversarial_distributions() {
        for dist in [Distribution::Sorted, Distribution::Reverse, Distribution::DupHeavy, Distribution::Zipf] {
            let mut cfg = small_cfg();
            cfg.dist = dist;
            cfg.ranks = 4;
            cfg.elems_per_rank = 4000;
            run_distributed_sort::<i32>(&cfg, None)
                .unwrap_or_else(|e| panic!("{dist:?}: {e:#}"));
        }
    }

    #[test]
    fn staged_slower_than_direct() {
        let mut cfg = small_cfg();
        cfg.ranks = 8;
        cfg.elems_per_rank = 30_000;
        cfg.transfer = TransferMode::GpuDirect;
        let direct = run_distributed_sort::<i32>(&cfg, None).unwrap();
        cfg.transfer = TransferMode::CpuStaged;
        let staged = run_distributed_sort::<i32>(&cfg, None).unwrap();
        assert!(
            staged.record.sim_exchange > direct.record.sim_exchange,
            "staged {} direct {}",
            staged.record.sim_exchange,
            direct.record.sim_exchange
        );
    }
}
