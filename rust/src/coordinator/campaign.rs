//! Figure campaigns: the parameter sweeps behind every evaluation figure
//! (paper §IV-C), shared by the `akbench` CLI and `cargo bench` targets.
//!
//! Scale note: per-rank sizes default far below the paper's 1 GB/rank so
//! a laptop-class box finishes in minutes; every knob is overridable
//! (`--ranks`, `--elems-per-rank`, `--gpu-speedup`, ...). Shapes — who
//! wins, crossovers, scaling slopes — are the reproduction target
//! (DESIGN.md §5).

use std::sync::Arc;

use crate::cfg::{RunConfig, Sorter, TransferMode};
use crate::cost::normalised_time;
use crate::dtype::ElemType;
use crate::metrics::{dump_csv, legend, render_series_table, Series};
use crate::runtime::Runtime;

use super::driver::run_for_config;

/// Sorter×transfer grid of the paper's GPU figures.
pub const GPU_GRID: [(Sorter, TransferMode); 6] = [
    (Sorter::Ak, TransferMode::GpuDirect),
    (Sorter::ThrustMerge, TransferMode::GpuDirect),
    (Sorter::ThrustRadix, TransferMode::GpuDirect),
    (Sorter::Ak, TransferMode::CpuStaged),
    (Sorter::ThrustMerge, TransferMode::CpuStaged),
    (Sorter::ThrustRadix, TransferMode::CpuStaged),
];

fn run_one(
    base: &RunConfig,
    ranks: usize,
    elems_per_rank: usize,
    sorter: Sorter,
    transfer: TransferMode,
    dtype: ElemType,
    rt: &Option<Arc<Runtime>>,
) -> anyhow::Result<crate::metrics::SortRunRecord> {
    let mut cfg = base.clone();
    cfg.ranks = ranks;
    cfg.elems_per_rank = elems_per_rank;
    cfg.sorter = sorter;
    cfg.transfer = transfer;
    cfg.dtype = dtype;
    let out = run_for_config(&cfg, rt.clone())?;
    eprintln!("  {}", out.record.row());
    Ok(out.record)
}

/// Fig 1: weak scaling at small per-rank sizes — CPU vs GPU algorithms.
/// Panel (a): `small_elems` per rank; panel (b): `large_elems` per rank.
pub fn fig1(
    base: &RunConfig,
    rank_counts: &[usize],
    small_elems: usize,
    large_elems: usize,
    rt: &Option<Arc<Runtime>>,
) -> anyhow::Result<Vec<Series>> {
    let mut all = Vec::new();
    for (panel, elems) in [("a", small_elems), ("b", large_elems)] {
        // CPU baseline + GPU grid, Int32 (the paper's Fig 1 dtype).
        let mut algos: Vec<(Sorter, TransferMode)> =
            vec![(Sorter::JuliaBase, TransferMode::CpuStaged)];
        algos.extend_from_slice(&GPU_GRID);
        for (sorter, transfer) in algos {
            let mut s = Series::new(format!("f1{panel}:{}", legend(sorter, transfer)));
            for &ranks in rank_counts {
                let rec =
                    run_one(base, ranks, elems, sorter, transfer, ElemType::I32, rt)?;
                s.push(ranks as f64, rec.sim_total);
            }
            all.push(s);
        }
    }
    print!("{}", render_series_table("Fig 1: weak scaling, small sizes", "ranks", "sim seconds", &all));
    dump_csv("fig1_weak_small", &all);
    Ok(all)
}

/// Fig 2: weak scaling at a fixed per-rank size, per dtype, GPU grid.
pub fn fig2(
    base: &RunConfig,
    rank_counts: &[usize],
    elems_per_rank_bytes: usize,
    dtypes: &[ElemType],
    rt: &Option<Arc<Runtime>>,
) -> anyhow::Result<Vec<Series>> {
    let mut all = Vec::new();
    for &dt in dtypes {
        let elems = (elems_per_rank_bytes / dt.size_bytes()).max(1);
        for (sorter, transfer) in GPU_GRID {
            let mut s =
                Series::new(format!("{}/{}", legend(sorter, transfer), dt.paper_name()));
            for &ranks in rank_counts {
                let rec = run_one(base, ranks, elems, sorter, transfer, dt, rt)?;
                s.push(ranks as f64, rec.sim_total);
            }
            all.push(s);
        }
    }
    print!("{}", render_series_table("Fig 2: weak scaling by dtype", "ranks", "sim seconds", &all));
    dump_csv("fig2_weak_dtypes", &all);
    Ok(all)
}

/// Fig 3: strong scaling — fixed total bytes divided over the ranks.
pub fn fig3(
    base: &RunConfig,
    rank_counts: &[usize],
    total_bytes: usize,
    dtypes: &[ElemType],
    rt: &Option<Arc<Runtime>>,
) -> anyhow::Result<Vec<Series>> {
    let mut all = Vec::new();
    for &dt in dtypes {
        for (sorter, transfer) in GPU_GRID {
            let mut s =
                Series::new(format!("{}/{}", legend(sorter, transfer), dt.paper_name()));
            for &ranks in rank_counts {
                let elems = (total_bytes / dt.size_bytes() / ranks).max(1);
                let rec = run_one(base, ranks, elems, sorter, transfer, dt, rt)?;
                s.push(ranks as f64, rec.sim_total);
            }
            all.push(s);
        }
    }
    print!("{}", render_series_table("Fig 3: strong scaling", "ranks", "sim seconds", &all));
    dump_csv("fig3_strong", &all);
    Ok(all)
}

/// Fig 4: max throughput per algorithm across a (dtype, size) sweep;
/// returns (legend, best GB/s, argmax description) rows.
pub fn fig4(
    base: &RunConfig,
    ranks: usize,
    per_rank_bytes: &[usize],
    dtypes: &[ElemType],
    rt: &Option<Arc<Runtime>>,
) -> anyhow::Result<Vec<(String, f64, String)>> {
    let mut rows = Vec::new();
    let mut algos: Vec<(Sorter, TransferMode)> =
        vec![(Sorter::JuliaBase, TransferMode::CpuStaged)];
    algos.extend_from_slice(&GPU_GRID);
    for (sorter, transfer) in algos {
        let mut best = 0.0f64;
        let mut at = String::new();
        for &dt in dtypes {
            // i128 exercises the no-vendor-special-case path on device
            // sorters via the host fallback (DESIGN.md §2).
            for &bytes in per_rank_bytes {
                let elems = (bytes / dt.size_bytes()).max(1);
                let rec = run_one(base, ranks, elems, sorter, transfer, dt, rt)?;
                let bps = rec.throughput_bps();
                if bps > best {
                    best = bps;
                    at = format!("{} @ {}/rank", dt.paper_name(), crate::util::fmt_bytes(bytes as f64));
                }
            }
        }
        let label = legend(sorter, transfer);
        println!("Fig4  {label:<8} max {:>14}  ({at})", crate::util::fmt_throughput(best));
        rows.push((label, best, at));
    }
    let series: Vec<Series> = rows
        .iter()
        .enumerate()
        .map(|(i, (l, b, _))| {
            let mut s = Series::new(l.clone());
            s.push(i as f64, *b);
            s
        })
        .collect();
    dump_csv("fig4_throughput", &series);
    Ok(rows)
}

/// Fig 5: cost-normalised (×cost_ratio) sorting times vs element count,
/// CC-JB vs GC-AK vs GG-AK, Float32 and Int64.
pub fn fig5(
    base: &RunConfig,
    ranks: usize,
    element_counts: &[usize],
    rt: &Option<Arc<Runtime>>,
) -> anyhow::Result<Vec<Series>> {
    let mut all = Vec::new();
    for dt in [ElemType::F32, ElemType::I64] {
        for (sorter, transfer) in [
            (Sorter::JuliaBase, TransferMode::CpuStaged),
            (Sorter::Ak, TransferMode::CpuStaged),
            (Sorter::Ak, TransferMode::GpuDirect),
        ] {
            let mut s = Series::new(format!(
                "{}/{} (norm)",
                legend(sorter, transfer),
                dt.paper_name()
            ));
            for &n in element_counts {
                let elems = (n / ranks).max(1);
                let rec = run_one(base, ranks, elems, sorter, transfer, dt, rt)?;
                s.push(n as f64, normalised_time(rec.sim_total, sorter, base.cluster.cost_ratio));
            }
            all.push(s);
        }
    }
    print!("{}", render_series_table(
        "Fig 5: cost-normalised times (x22 device factor)",
        "elements",
        "normalised seconds",
        &all,
    ));
    dump_csv("fig5_cost", &all);
    Ok(all)
}

/// Table II: the RBF + LJG arithmetic kernels across the implementation
/// matrix (single-thread expanded / single-thread powf "naive C" /
/// threaded / device artifact). Prints mean ±σ rows like the paper.
pub fn table2(
    n: usize,
    threads: usize,
    rt: &Option<Arc<Runtime>>,
    quick: bool,
) -> anyhow::Result<()> {
    use crate::algorithms::LjgConsts;
    use crate::bench::{BenchOpts, Bencher};
    use crate::session::Session;
    use crate::util::Prng;
    use crate::workload::{points_f32, positions_f32};

    println!("\n== Table II: arithmetic kernels (n = {n}, {threads} threads) ==");
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() }.scaled_from_env();
    let mut b = Bencher::new(opts);
    let mut rng = Prng::new(7);
    let pts = points_f32(&mut rng, n);
    let p1 = positions_f32(&mut rng, n, 4.0);
    let p2 = positions_f32(&mut rng, n, 4.0);
    let c = LjgConsts::default();
    let bytes = Some((3 * n * 4) as f64);
    let native = Session::native();
    let pool = Session::threaded(threads);
    let device =
        rt.as_ref().map(|rt| Session::device(crate::runtime::Registry::new(rt.clone())));

    println!("-- Radial Basis Function kernel --");
    b.run("rbf/native-1t        (Julia Base / C row)", bytes, || {
        let _ = native.rbf(&pts, None).unwrap();
    });
    b.run(&format!("rbf/threaded-{threads}t       (C OpenMP / AK-CPU row)"), bytes, || {
        let _ = pool.rbf(&pts, None).unwrap();
    });
    if let Some(dev) = &device {
        b.run("rbf/device            (AK GPU row, XLA artifact)", bytes, || {
            let _ = dev.rbf(&pts, None).unwrap();
        });
    }

    println!("-- Lennard-Jones-Gauss potential kernel --");
    b.run("ljg/native-1t-mult    (Julia Base row: expanded powers)", bytes, || {
        let _ = native.ljg(&p1, &p2, c, None).unwrap();
    });
    b.run("ljg/native-1t-powf    (naive C row: libm powf)", bytes, || {
        let _ = native.ljg_powf(&p1, &p2, c, None).unwrap();
    });
    b.run(&format!("ljg/threaded-{threads}t       (C OpenMP / AK-CPU row)"), bytes, || {
        let _ = pool.ljg(&p1, &p2, c, None).unwrap();
    });
    if let Some(dev) = &device {
        b.run("ljg/device            (AK GPU row, XLA artifact)", bytes, || {
            let _ = dev.ljg(&p1, &p2, c, None).unwrap();
        });
    }

    // The paper's §III-B analysis figures.
    if let (Some(mult), Some(powf)) =
        (b.get("ljg/native-1t-mult    (Julia Base row: expanded powers)"),
         b.get("ljg/native-1t-powf    (naive C row: libm powf)"))
    {
        println!(
            "\npowf pathology: expanded-multiplication is {:.2}x faster than powf \
             (paper: 2.94x ARM / 1.23x x86)",
            powf.time.mean / mult.time.mean
        );
    }
    let mut series = Vec::new();
    for r in &b.results {
        let mut s = Series::new(r.name.clone());
        s.push(0.0, r.time.mean);
        series.push(s);
    }
    dump_csv("table2_arithmetic", &series);
    Ok(())
}

/// Design-choice ablations called out in DESIGN.md §6: SIHSort final
/// phase (merge vs re-sort), radix digit width, sampling density and
/// refinement budget.
pub fn ablations(base: &RunConfig, rt: &Option<Arc<Runtime>>, quick: bool) -> anyhow::Result<()> {
    use crate::baselines::radix::radix_sort_by_digit_bits;
    use crate::bench::{BenchOpts, Bencher};
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    let elems = if quick { 20_000 } else { 200_000 };
    let ranks = if quick { 4 } else { 8 };

    println!("\n== Ablation: SIHSort final phase (merge vs full re-sort) ==");
    for phase in [crate::cfg::FinalPhase::Merge, crate::cfg::FinalPhase::Sort] {
        let mut cfg = base.clone();
        cfg.ranks = ranks;
        cfg.elems_per_rank = elems;
        cfg.final_phase = phase;
        cfg.sorter = Sorter::ThrustRadix;
        let out = run_for_config(&cfg, rt.clone())?;
        println!("  final={phase:?}: sim_final = {:.6}s  total = {:.6}s",
                 out.record.sim_final, out.record.sim_total);
    }

    println!("\n== Ablation: radix digit width ==");
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() }.scaled_from_env();
    let mut b = Bencher::new(opts);
    let xs: Vec<i64> = generate(&mut Prng::new(3), Distribution::Uniform, elems * 4);
    for bits in [8u32, 11, 16] {
        b.run_with_setup(
            &format!("radix/{bits}-bit digits"),
            Some((xs.len() * 8) as f64),
            || xs.clone(),
            |mut v| radix_sort_by_digit_bits(&mut v, bits),
        );
    }

    println!("\n== Ablation: samples per rank (splitter quality) ==");
    for samples in [8usize, 32, 128, 512] {
        let mut cfg = base.clone();
        cfg.ranks = ranks;
        cfg.elems_per_rank = elems;
        cfg.samples_per_rank = samples;
        cfg.sorter = Sorter::ThrustRadix;
        let out = run_for_config(&cfg, rt.clone())?;
        let max = *out.out_sizes.iter().max().unwrap() as f64;
        let imbalance = max / cfg.elems_per_rank as f64 - 1.0;
        println!(
            "  samples={samples:<4} rounds_used={} imbalance={:+.3} total={:.6}s",
            out.rounds_used, imbalance, out.record.sim_total
        );
    }

    println!("\n== Ablation: refinement round budget ==");
    for rounds in [0usize, 1, 2, 4, 8] {
        let mut cfg = base.clone();
        cfg.ranks = ranks;
        cfg.elems_per_rank = elems;
        cfg.refine_rounds = rounds;
        cfg.dist = Distribution::Zipf; // skew stresses refinement
        cfg.sorter = Sorter::ThrustRadix;
        let out = run_for_config(&cfg, rt.clone())?;
        let max = *out.out_sizes.iter().max().unwrap() as f64;
        println!(
            "  rounds<={rounds} used={} max-bucket={:.2}x ideal, splitter phase {:.6}s",
            out.rounds_used,
            max / cfg.elems_per_rank as f64,
            out.record.sim_splitters
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_smoke_tiny() {
        let mut base = RunConfig::default();
        base.refine_rounds = 2;
        let series = fig1(&base, &[2], 200, 1000, &None).unwrap();
        assert_eq!(series.len(), 14); // 7 algos x 2 panels
        assert!(series.iter().all(|s| s.points.len() == 1));
    }

    #[test]
    fn fig5_normalisation_applied() {
        let mut base = RunConfig::default();
        base.refine_rounds = 1;
        let series = fig5(&base, 2, &[2000], &None).unwrap();
        // GC-AK normalised must exceed its raw time; CC-JB must not be scaled.
        assert_eq!(series.len(), 6);
    }
}
