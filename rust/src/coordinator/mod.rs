//! Campaign orchestration: builds the simulated cluster from a
//! [`crate::cfg::RunConfig`], runs distributed sorts across rank threads,
//! verifies the results, and sweeps the parameter grids behind every
//! paper figure.

pub mod campaign;
pub mod driver;

pub use driver::{run_distributed_sort, run_distributed_sort_mixed, DistSortOutput};

/// Dispatch a generic function over the runtime dtype tag.
///
/// ```ignore
/// let rec = dispatch_dtype!(cfg.dtype, K => run::<K>(&cfg));
/// ```
#[macro_export]
macro_rules! dispatch_dtype {
    ($dtype:expr, $K:ident => $body:expr) => {
        match $dtype {
            $crate::dtype::ElemType::I16 => {
                type $K = i16;
                $body
            }
            $crate::dtype::ElemType::I32 => {
                type $K = i32;
                $body
            }
            $crate::dtype::ElemType::I64 => {
                type $K = i64;
                $body
            }
            $crate::dtype::ElemType::I128 => {
                type $K = i128;
                $body
            }
            $crate::dtype::ElemType::F32 => {
                type $K = f32;
                $body
            }
            $crate::dtype::ElemType::F64 => {
                type $K = f64;
                $body
            }
        }
    };
}
