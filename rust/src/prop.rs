//! Minimal property-testing framework (proptest is unavailable offline —
//! DESIGN.md §9).
//!
//! Deterministic seed-driven case generation with greedy shrinking:
//! on failure the input is shrunk (halving lengths / simplifying values)
//! until a locally-minimal counterexample remains, which is printed with
//! the seed for replay. Used by `rust/tests/proptests.rs` for the
//! coordinator invariants (DESIGN.md §6).

use crate::util::Prng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Override case count with AK_PROP_CASES for deeper local runs.
        let cases = std::env::var("AK_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(32);
        Self { cases, seed: 0xACCE55, max_shrink_steps: 200 }
    }
}

/// A generator produces a case from randomness; a shrinker yields smaller
/// candidate cases.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Prng) -> Self::Value;
    /// Candidate simplifications, most aggressive first.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value>;
}

/// Run `prop` over `cfg.cases` generated inputs; panics with a shrunk
/// counterexample on failure.
pub fn check<G: Gen, P: Fn(&G::Value) -> Result<(), String>>(name: &str, cfg: &PropConfig, gen: &G, prop: P) {
    let mut rng = Prng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng_case = rng.fork(case as u64);
        let value = gen.generate(&mut rng_case);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {}):\n  input: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Generator: `Vec<T>` with length in [0, max_len], elements from `f`.
pub struct VecGen<T, F: Fn(&mut Prng) -> T> {
    pub max_len: usize,
    pub make: F,
    pub _t: std::marker::PhantomData<T>,
}

impl<T, F: Fn(&mut Prng) -> T> VecGen<T, F> {
    pub fn new(max_len: usize, make: F) -> Self {
        Self { max_len, make, _t: std::marker::PhantomData }
    }
}

impl<T: Clone + std::fmt::Debug, F: Fn(&mut Prng) -> T> Gen for VecGen<T, F> {
    type Value = Vec<T>;

    fn generate(&self, rng: &mut Prng) -> Vec<T> {
        let len = rng.below(self.max_len as u64 + 1) as usize;
        (0..len).map(|_| (self.make)(rng)).collect()
    }

    fn shrink(&self, v: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        let n = v.len();
        if n == 0 {
            return out;
        }
        // Halves first (aggressive), then drop-one (fine-grained).
        out.push(v[..n / 2].to_vec());
        out.push(v[n / 2..].to_vec());
        if n <= 8 {
            for i in 0..n {
                let mut w = v.clone();
                w.remove(i);
                out.push(w);
            }
        }
        out
    }
}

/// Generator: a pair of independent values.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Prng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = VecGen::new(100, |r| r.range_i64(-50, 50) as i32);
        check("sorted-after-sort", &PropConfig::default(), &gen, |xs| {
            let mut v = xs.clone();
            v.sort_unstable();
            if v.windows(2).all(|w| w[0] <= w[1]) {
                Ok(())
            } else {
                Err("not sorted".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let gen = VecGen::new(64, |r| r.range_i64(0, 1000) as i32);
        let result = std::panic::catch_unwind(|| {
            check(
                "no-big-values",
                &PropConfig { cases: 50, seed: 7, max_shrink_steps: 500 },
                &gen,
                |xs| {
                    if xs.iter().any(|&x| x > 500) {
                        Err("contains big value".into())
                    } else {
                        Ok(())
                    }
                },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("no-big-values"), "{msg}");
        // Shrinking should reduce to a very small witness.
        let input_line = msg.lines().find(|l| l.contains("input")).unwrap().to_string();
        let commas = input_line.matches(',').count();
        assert!(commas <= 2, "not shrunk enough: {input_line}");
    }

    #[test]
    fn pair_gen_composes() {
        let gen = PairGen(
            VecGen::new(10, |r| r.next_u32() as i32),
            VecGen::new(10, |r| r.uniform_f32()),
        );
        check("pair-smoke", &PropConfig { cases: 10, ..Default::default() }, &gen, |(a, b)| {
            if a.len() <= 10 && b.len() <= 10 {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }
}
