//! Splitter selection by sampling + interpolated-histogram refinement
//! (the "SIH" in SIHSort).
//!
//! Round 0: every rank contributes `p` regular samples of its sorted
//! shard; the leader sorts the P·p samples and takes initial splitter
//! candidates at the bucket quantiles. Refinement rounds then measure the
//! *exact* global rank of each candidate (sum over ranks of
//! `searchsortedlast(shard, candidate)` — one u64 counter per candidate,
//! appended to the splitter broadcast payload: the paper's
//! counters-hidden-in-the-array trick) and move each candidate by
//! interpolating within its bracketing histogram bin until every bucket
//! is within `balance_tol` of ideal or the round budget is exhausted.
//!
//! Everything runs on the key *bit image* (u128): one code path for all
//! six dtypes, floats included (monotone transform).

use crate::backend::DeviceKey;
use crate::dtype::SortKey;
use crate::session::Launch;
use crate::stream::{ChunkSource, SpillRun, SpillRunSource, StreamCtx, StreamRecord};

/// Leader-side state for one refinement round.
#[derive(Clone, Debug)]
pub struct RefineState {
    /// Candidate splitters (bit-image space), length P-1.
    pub candidates: Vec<u128>,
    /// Bracketing intervals per candidate: (lo_bits, hi_bits, lo_rank, hi_rank).
    pub brackets: Vec<(u128, u128, u64, u64)>,
}

/// Take `p` regular samples of an ascending-sorted shard.
pub fn regular_samples<K: SortKey>(sorted: &[K], p: usize) -> Vec<K> {
    let n = sorted.len();
    if n == 0 || p == 0 {
        return Vec::new();
    }
    (0..p)
        .map(|i| {
            // Sample at (i + 1) / (p + 1) quantiles — interior points.
            let idx = ((i + 1) * n) / (p + 1);
            sorted[idx.min(n - 1)]
        })
        .collect()
}

/// Initial candidates from the pooled samples: quantile cuts for P buckets.
pub fn initial_candidates(mut pooled_bits: Vec<u128>, ranks: usize) -> Vec<u128> {
    pooled_bits.sort_unstable();
    let m = pooled_bits.len();
    if ranks <= 1 {
        return Vec::new();
    }
    (1..ranks)
        .map(|b| {
            if m == 0 {
                // Degenerate: no samples (all shards empty) — spread over
                // the full key space.
                (u128::MAX / ranks as u128) * b as u128
            } else {
                let idx = (b * m) / ranks;
                pooled_bits[idx.min(m - 1)]
            }
        })
        .collect()
}

/// Exact local rank of each candidate within a sorted shard:
/// `searchsortedlast` (elements <= candidate), run on the bit image.
pub fn local_ranks<K: SortKey>(sorted: &[K], candidates: &[u128]) -> Vec<u64> {
    candidates
        .iter()
        .map(|&c| sorted.partition_point(|x| x.to_bits() <= c) as u64)
        .collect()
}

/// [`regular_samples`] over a *streamed* sorted shard: one forward pass
/// over the [`ChunkSource`], picking the elements at the same quantile
/// offsets the in-memory sampler indexes, never holding more than one
/// chunk. `total` is the stream's element count (a [`SpillRun`] knows
/// its length).
pub fn regular_samples_streamed<K: SortKey + StreamRecord>(
    src: &mut dyn ChunkSource<K>,
    total: u64,
    p: usize,
    chunk: usize,
) -> anyhow::Result<Vec<K>> {
    if total == 0 || p == 0 {
        return Ok(Vec::new());
    }
    // Identical targets to `regular_samples`: (i + 1) / (p + 1)
    // quantiles, clamped interior (non-decreasing, duplicates allowed).
    let targets: Vec<u64> = (0..p as u64)
        .map(|i| (((i + 1) * total) / (p as u64 + 1)).min(total - 1))
        .collect();
    let mut out = Vec::with_capacity(p);
    let mut buf: Vec<K> = Vec::new();
    let mut pos = 0u64;
    let mut t = 0usize;
    while t < targets.len() && src.next_chunk(&mut buf, chunk.max(1))? > 0 {
        let end = pos + buf.len() as u64;
        while t < targets.len() && targets[t] < end {
            out.push(buf[(targets[t] - pos) as usize]);
            t += 1;
        }
        pos = end;
    }
    anyhow::ensure!(t == targets.len(), "stream ended at {pos} before the last sample target");
    Ok(out)
}

/// Candidate-rank measurement over a *streamed* sorted shard, reusing
/// the streaming histogram: the candidate bit images (clamped into the
/// dtype's image space) become the bin edges, and the cumulative bin
/// counts are the candidate ranks. The histogram bins by
/// `searchsorted_last` against the edges, so the measured rank is the
/// *strict* count `#{x < c}` — off from the in-memory
/// `searchsortedlast` rank by the candidate's duplicate mass (and, on
/// float dtypes, by the histogram's IEEE `-0.0 == 0.0` edge rule).
/// That slack only steers bucket-balance refinement; the partition
/// itself (`exchange::partition_points`) stays exact total-order `<=`,
/// so global sortedness never depends on it.
pub fn local_ranks_streamed<K: DeviceKey>(
    ctx: &StreamCtx,
    run: &SpillRun<K>,
    candidates: &[u128],
    io_chunk: usize,
    launch: &Launch,
) -> anyhow::Result<Vec<u64>> {
    // The dtype's image space is the full KEY_BYTES-wide integer range
    // (for floats that tops out at the max-payload NaN, above
    // `max_key().to_bits()` = +inf); clamping into it keeps `from_bits`
    // exact for every in-range candidate.
    let max_img = if K::KEY_BYTES >= 16 {
        u128::MAX
    } else {
        (1u128 << (8 * K::KEY_BYTES)) - 1
    };
    let edges: Vec<K> = candidates.iter().map(|&c| K::from_bits(c.min(max_img))).collect();
    let mut src = SpillRunSource::new(run, io_chunk)?;
    let counts = ctx.stream_histogram(&mut src, &edges, Some(launch))?;
    let mut ranks = Vec::with_capacity(candidates.len());
    let mut acc = 0u64;
    for c in counts.iter().take(candidates.len()) {
        acc += c;
        ranks.push(acc);
    }
    Ok(ranks)
}

/// One leader-side refinement step: move candidates whose global rank is
/// outside tolerance by linear interpolation inside their bracket.
/// Returns (new state, worst relative imbalance).
pub fn refine(
    state: &RefineState,
    global_ranks: &[u64],
    total: u64,
    ranks: usize,
    _tol: f64,
) -> (RefineState, f64) {
    let ideal = total as f64 / ranks as f64;
    let mut worst = 0.0f64;
    let mut next = state.clone();
    for (i, (&cand, &got)) in state.candidates.iter().zip(global_ranks.iter()).enumerate() {
        let want = (ideal * (i + 1) as f64).round() as i128;
        let err = (got as i128 - want).unsigned_abs() as f64 / ideal.max(1.0);
        worst = worst.max(err);
        let (mut lo, mut hi, mut lo_rank, mut hi_rank) = next.brackets[i];
        // Tighten the bracket with the measurement.
        if (got as i128) < want {
            lo = cand;
            lo_rank = got;
        } else {
            hi = cand;
            hi_rank = got;
        }
        // Interpolate the next candidate position within the bracket
        // (assume locally-uniform rank density — the "interpolated
        // histogram" step; falls back to bisection on degenerate spans).
        let new_cand = if hi_rank > lo_rank && hi > lo {
            let frac = (want as f64 - lo_rank as f64) / (hi_rank as f64 - lo_rank as f64);
            let frac = frac.clamp(0.0, 1.0);
            let span = hi - lo;
            lo + (span as f64 * frac) as u128
        } else {
            lo / 2 + hi / 2 + (lo & hi & 1)
        };
        next.candidates[i] = new_cand.clamp(lo, hi);
        next.brackets[i] = (lo, hi, lo_rank, hi_rank);
    }
    // Candidates refine independently and can cross on skewed data;
    // buckets require non-decreasing splitters (running max, cheap and
    // deterministic — every rank would apply the same fix).
    for i in 1..next.candidates.len() {
        if next.candidates[i] < next.candidates[i - 1] {
            next.candidates[i] = next.candidates[i - 1];
        }
    }
    (next, worst)
}

/// Initial brackets: full key space with rank bounds [0, total].
pub fn initial_brackets(candidates: &[u128], total: u64) -> Vec<(u128, u128, u64, u64)> {
    candidates.iter().map(|_| (0u128, u128::MAX, 0u64, total)).collect()
}

/// Pack candidates + a round-continuation flag into one broadcast payload
/// (u128 LE words; the flag rides as the last word — the paper's hidden
/// counter).
pub fn pack_candidates(candidates: &[u128], done: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 * (candidates.len() + 1));
    for c in candidates {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out.extend_from_slice(&(done as u128).to_le_bytes());
    out
}

/// Inverse of [`pack_candidates`].
pub fn unpack_candidates(bytes: &[u8]) -> (Vec<u128>, bool) {
    assert!(bytes.len() % 16 == 0 && !bytes.is_empty());
    let words = bytes.len() / 16;
    let mut cands = Vec::with_capacity(words - 1);
    for w in 0..words - 1 {
        let mut b = [0u8; 16];
        b.copy_from_slice(&bytes[16 * w..16 * (w + 1)]);
        cands.push(u128::from_le_bytes(b));
    }
    let mut b = [0u8; 16];
    b.copy_from_slice(&bytes[16 * (words - 1)..]);
    (cands, u128::from_le_bytes(b) != 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn regular_samples_are_interior_and_sorted() {
        let mut xs: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 1000);
        xs.sort_unstable();
        let s = regular_samples(&xs, 16);
        assert_eq!(s.len(), 16);
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
        assert!(s[0] >= xs[0] && *s.last().unwrap() <= *xs.last().unwrap());
    }

    #[test]
    fn initial_candidates_quantiles() {
        let bits: Vec<u128> = (0..100u128).collect();
        let c = initial_candidates(bits, 4);
        assert_eq!(c.len(), 3);
        assert!(c[0] < c[1] && c[1] < c[2]);
        assert!((20..30).contains(&(c[0] as i32)));
    }

    #[test]
    fn local_ranks_match_partition_point() {
        let mut xs: Vec<i32> = generate(&mut Prng::new(2), Distribution::DupHeavy, 500);
        xs.sort_unstable();
        let cands: Vec<u128> = xs.iter().step_by(100).map(|x| x.to_bits()).collect();
        let ranks = local_ranks(&xs, &cands);
        for (c, r) in cands.iter().zip(&ranks) {
            assert_eq!(*r as usize, xs.iter().filter(|x| x.to_bits() <= *c).count());
        }
    }

    #[test]
    fn refine_converges_on_uniform() {
        // Synthetic single-shard refinement: global rank == local rank.
        let mut xs: Vec<i64> = generate(&mut Prng::new(3), Distribution::Uniform, 10_000);
        xs.sort_unstable();
        let ranks = 8;
        let samples: Vec<u128> = regular_samples(&xs, 32).iter().map(|x| x.to_bits()).collect();
        let cands = initial_candidates(samples, ranks);
        let mut state = RefineState {
            brackets: initial_brackets(&cands, xs.len() as u64),
            candidates: cands,
        };
        let mut worst = f64::INFINITY;
        for _ in 0..6 {
            let gr = local_ranks(&xs, &state.candidates);
            let (next, w) = refine(&state, &gr, xs.len() as u64, ranks, 0.01);
            state = next;
            worst = w;
            if worst < 0.01 {
                break;
            }
        }
        assert!(worst < 0.05, "imbalance {worst}");
    }

    #[test]
    fn streamed_samples_match_in_memory() {
        use crate::stream::SliceSource;
        let mut xs: Vec<i64> = generate(&mut Prng::new(9), Distribution::Uniform, 4321);
        xs.sort_unstable();
        let want = regular_samples(&xs, 16);
        for chunk in [7usize, 100, 10_000] {
            let got = regular_samples_streamed(
                &mut SliceSource::new(&xs),
                xs.len() as u64,
                16,
                chunk,
            )
            .unwrap();
            assert_eq!(got, want, "chunk {chunk}");
        }
        // Degenerate inputs mirror the in-memory sampler.
        let empty: Vec<i64> = vec![];
        assert!(regular_samples_streamed(&mut SliceSource::new(&empty), 0, 8, 64)
            .unwrap()
            .is_empty());
        assert!(regular_samples_streamed(&mut SliceSource::new(&xs), xs.len() as u64, 0, 64)
            .unwrap()
            .is_empty());
    }

    #[test]
    fn streamed_ranks_match_strict_counts() {
        use crate::session::Session;
        use crate::stream::{SpillMedium, SpillStore, StreamBudget};
        let mut xs: Vec<i32> = generate(&mut Prng::new(10), Distribution::DupHeavy, 3000);
        xs.sort_unstable();
        let mut store = SpillStore::new(SpillMedium::Memory, None);
        let run = store.write_run(&xs).unwrap();
        let ctx = Session::native().stream(StreamBudget::mib(1));
        let cands: Vec<u128> = xs.iter().step_by(500).map(|x| x.to_bits()).collect();
        let got =
            local_ranks_streamed(&ctx, &run, &cands, 128, &Launch::default()).unwrap();
        for (c, r) in cands.iter().zip(&got) {
            // Histogram ranks are the strict count #{x < c} (see docs).
            assert_eq!(*r as usize, xs.iter().filter(|x| x.to_bits() < *c).count());
            // ...and never exceed the partition's `<=` count.
            assert!(*r as usize <= xs.iter().filter(|x| x.to_bits() <= *c).count());
        }
    }

    #[test]
    fn pack_roundtrip() {
        let cands = vec![1u128, u128::MAX / 2, u128::MAX];
        let (got, done) = unpack_candidates(&pack_candidates(&cands, true));
        assert_eq!(got, cands);
        assert!(done);
        let (got2, done2) = unpack_candidates(&pack_candidates(&[], false));
        assert!(got2.is_empty());
        assert!(!done2);
    }

    #[test]
    fn degenerate_empty_samples() {
        let c = initial_candidates(vec![], 4);
        assert_eq!(c.len(), 3);
        assert!(c[0] < c[1] && c[1] < c[2]);
    }
}
