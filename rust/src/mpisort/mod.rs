//! SIHSort — "Sampling with Interpolated Histograms Sort" (paper §IV-A),
//! the MPISort.jl reproduction and this repo's L3 coordination
//! contribution.
//!
//! Sample-sort derivative over P ranks:
//! 1. local sort of each rank's shard (pluggable sorter: CC-JB / AK /
//!    TM / TR — `local_sort`),
//! 2. regular sampling of each sorted shard,
//! 3. splitter selection by *interpolated histograms*: the leader builds
//!    a global sample histogram, interpolates candidate splitters, and
//!    refines them over a bounded number of rounds against exact local
//!    ranks (computed with `searchsortedlast`) until buckets balance
//!    (`splitters`),
//! 4. partition: each rank cuts its sorted shard at the splitters —
//!    binary search, zero element copies (`exchange`),
//! 5. one `alltoallv` moves bucket j to rank j (`exchange`),
//! 6. final phase: k-way merge of the received sorted runs, or the
//!    paper's full re-sort (`FinalPhase`, ablated in the benches).
//!
//! The paper's low-communication claims hold by construction: one
//! allgather of samples, `refine_rounds` × (bcast + gather) of counters
//! — with the counters appended to the splitter payload, the paper's
//! "counters hidden at the end of integer arrays" trick — and exactly
//! one all-to-all data exchange. The proptests assert global order,
//! permutation preservation and bucket balance.
//!
//! Ranks using [`LocalSorter::External`] run the same schedule fully
//! *streamed* (DESIGN.md §14): the local sort is
//! `stream::external_sort` into a spilled run, sampling and splitter
//! rank measurement re-read that run chunk by chunk, and the exchange
//! ships codec-encoded chunks — so each simulated rank handles shards
//! larger than its memory budget (the paper-scale cluster ×
//! out-of-core composition).

pub mod exchange;
pub mod local_sort;
pub mod sihsort;
pub mod splitters;

pub use local_sort::LocalSorter;
pub use sihsort::{sihsort_rank, RankOutcome, RankStreamStats, SihConfig, SihStreamCfg};
