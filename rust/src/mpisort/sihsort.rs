//! The per-rank SIHSort algorithm (see module docs in `mod.rs`).

use std::time::Instant;

use crate::backend::DeviceKey;
use crate::baselines::merge_path;
use crate::cfg::FinalPhase;
use crate::cluster::DeviceModel;
use crate::comm::Endpoint;
use crate::dtype::SortKey;

use super::exchange::{buckets, partition_points};
use super::local_sort::LocalSorter;
use super::splitters::{
    initial_brackets, initial_candidates, local_ranks, pack_candidates, refine, regular_samples,
    unpack_candidates, RefineState,
};

/// SIHSort tuning parameters.
#[derive(Clone, Debug)]
pub struct SihConfig {
    /// Regular samples each rank contributes per refinement round.
    pub samples_per_rank: usize,
    /// Maximum splitter-refinement rounds.
    pub refine_rounds: usize,
    /// Bucket balance tolerance (fraction of ideal bucket size).
    pub balance_tol: f64,
    /// Final-phase strategy (k-way merge vs full re-sort).
    pub final_phase: FinalPhase,
    /// Compute-time scaling for device ranks.
    pub devmodel: DeviceModel,
    /// Per-call tuning knobs for the rank-local sorts and the final
    /// recombine (`Session`/`Launch` API, DESIGN.md §12).
    pub launch: crate::session::Launch,
}

impl Default for SihConfig {
    fn default() -> Self {
        Self {
            samples_per_rank: 64,
            refine_rounds: 4,
            balance_tol: 0.10,
            final_phase: FinalPhase::Merge,
            devmodel: DeviceModel::default(),
            launch: crate::session::Launch::default(),
        }
    }
}

/// Per-rank result: the globally-sorted shard + phase breakdown
/// (simulated seconds for this rank).
#[derive(Clone, Debug)]
pub struct RankOutcome<K> {
    /// The rank's globally-positioned, locally-sorted shard.
    pub data: Vec<K>,
    /// Simulated seconds in the local-sort phase.
    pub sim_local_sort: f64,
    /// Simulated seconds in sampling + splitter refinement.
    pub sim_splitters: f64,
    /// Simulated seconds in partition + alltoallv.
    pub sim_exchange: f64,
    /// Simulated seconds in the final combine.
    pub sim_final: f64,
    /// Host wall-clock this rank actually consumed.
    pub wall_secs: f64,
    /// Splitter refinement rounds actually used (leader-reported).
    pub rounds_used: usize,
}

const LEADER: usize = 0;

/// Run SIHSort on this rank's shard. Every rank of the fabric must call
/// this collectively (same config). Returns the rank's final shard:
/// ascending locally, and globally `outcome[r].data <= outcome[r+1].data`.
pub fn sihsort_rank<K: DeviceKey>(
    ep: &mut Endpoint,
    shard: Vec<K>,
    sorter: &LocalSorter,
    cfg: &SihConfig,
) -> anyhow::Result<RankOutcome<K>> {
    let wall0 = Instant::now();
    let p = ep.nranks();
    let is_dev = sorter.is_device();
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };

    // ---- Phase 1: local sort ------------------------------------------------
    let t_phase = ep.now();
    // Measured under the fabric's compute token: wall time reflects this
    // rank's work alone, not host-core oversubscription (fabric docs).
    let ((sorted, sort_res), secs) = ep.measured(move || {
        let mut s = shard;
        let r = sorter.sort(&mut s, &cfg.launch);
        (s, r)
    });
    sort_res?;
    charge(ep, secs);
    ep.barrier();
    let sim_local_sort = ep.now() - t_phase;

    // ---- Phase 2+3: sampling + interpolated-histogram refinement -----------
    let t_phase = ep.now();
    let (splitters, rounds_used) = select_splitters(ep, &sorted, cfg, is_dev)?;
    let sim_splitters = ep.now() - t_phase;

    // ---- Phase 4+5: partition + single alltoallv ----------------------------
    let t_phase = ep.now();
    let (parts, secs) = ep.measured(|| {
        let cuts = partition_points(&sorted, &splitters);
        buckets(&sorted, &cuts).into_iter().map(|b| b.to_vec()).collect::<Vec<Vec<K>>>()
    });
    debug_assert_eq!(parts.len(), p);
    charge(ep, secs);
    let received = ep.alltoallv(parts);
    drop(sorted);
    let sim_exchange = ep.now() - t_phase;

    // ---- Phase 6: final combine ---------------------------------------------
    let t_phase = ep.now();
    let (data, secs) = ep.measured(|| -> anyhow::Result<Vec<K>> {
        match cfg.final_phase {
            FinalPhase::Merge => {
                // Received runs are each sorted: merge-path partitioned
                // k-way merge (DESIGN.md §11) over the full host pool.
                // Safe to fan out here: this closure runs under the
                // fabric's compute token (one rank's measured section at
                // a time), so the workers never contend with other rank
                // threads and the measured seconds model a rank owning
                // its node's cores.
                let refs: Vec<&[K]> = received.iter().map(|r| r.as_slice()).collect();
                let total: usize = refs.iter().map(|r| r.len()).sum();
                Ok(merge_path::kmerge_parallel_with(
                    &refs,
                    cfg.launch
                        .tasks_for(crate::backend::threaded::default_threads(), total),
                    cfg.launch.par_threshold_or(merge_path::PAR_MERGE_MIN),
                ))
            }
            FinalPhase::Sort => {
                // The paper's described variant: concatenate + full re-sort.
                let mut all: Vec<K> = received.iter().flatten().copied().collect();
                sorter.sort(&mut all, &cfg.launch)?;
                Ok(all)
            }
        }
    });
    let data = data?;
    charge(ep, secs);
    ep.barrier();
    let sim_final = ep.now() - t_phase;

    Ok(RankOutcome {
        data,
        sim_local_sort,
        sim_splitters,
        sim_exchange,
        sim_final,
        wall_secs: wall0.elapsed().as_secs_f64(),
        rounds_used,
    })
}

/// Collective splitter selection; returns P-1 splitters in bit-image
/// space and the number of refinement rounds used.
fn select_splitters<K: SortKey>(
    ep: &mut Endpoint,
    sorted: &[K],
    cfg: &SihConfig,
    is_dev: bool,
) -> anyhow::Result<(Vec<u128>, usize)> {
    let p = ep.nranks();
    if p == 1 {
        return Ok((Vec::new(), 0));
    }
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };

    // Sampling: gather p regular samples (as bit images) at the leader.
    let (samples, secs) = ep.measured(|| {
        regular_samples(sorted, cfg.samples_per_rank)
            .into_iter()
            .map(|x| x.to_bits())
            .collect::<Vec<u128>>()
    });
    charge(ep, secs);
    let sample_bytes = u128s_to_bytes(&samples);
    let gathered = ep.gather_bytes(LEADER, sample_bytes);

    // Global element count rides an allreduce (one u64).
    let total = ep.allreduce_u64(sorted.len() as u64, crate::comm::collectives::ReduceOp::Sum);

    let mut leader_state: Option<RefineState> = if ep.rank() == LEADER {
        let pooled: Vec<u128> =
            gathered.unwrap().iter().flat_map(|b| bytes_to_u128s(b)).collect();
        let candidates = initial_candidates(pooled, p);
        let brackets = initial_brackets(&candidates, total);
        Some(RefineState { candidates, brackets })
    } else {
        None
    };

    // Refinement rounds (lockstep on every rank).
    let mut done_next = false;
    let mut rounds_used = 0usize;
    for round in 0..=cfg.refine_rounds {
        let is_last = round == cfg.refine_rounds || done_next;
        // Leader broadcasts candidates (+ done flag hidden at the tail).
        let payload = if ep.rank() == LEADER {
            pack_candidates(&leader_state.as_ref().unwrap().candidates, is_last)
        } else {
            Vec::new()
        };
        let (candidates, done) = unpack_candidates(&ep.bcast_bytes(LEADER, payload));
        if done {
            return Ok((candidates, rounds_used));
        }
        rounds_used = round + 1;

        // Every rank measures exact local ranks (searchsortedlast).
        let (lranks, secs) = ep.measured(|| local_ranks(sorted, &candidates));
        charge(ep, secs);
        let gathered = ep.gather_bytes(LEADER, u64s_to_bytes(&lranks));

        if ep.rank() == LEADER {
            let per_rank: Vec<Vec<u64>> =
                gathered.unwrap().iter().map(|b| bytes_to_u64s(b)).collect();
            let mut global = vec![0u64; candidates.len()];
            for pr in &per_rank {
                for (g, v) in global.iter_mut().zip(pr.iter()) {
                    *g += v;
                }
            }
            let state = leader_state.as_mut().unwrap();
            // Measurements correspond to the *broadcast* candidates.
            state.candidates = candidates;
            let (next, worst) = refine(state, &global, total, p, cfg.balance_tol);
            if worst <= cfg.balance_tol {
                // Measured candidates are balanced: finalise them next round.
                done_next = true;
            } else {
                *state = next;
            }
        }
        // Non-leaders learn about termination from the next bcast's flag.
    }
    unreachable!("refinement loop always terminates via the done broadcast")
}

// -- byte helpers (wire format for counters/samples) -------------------------

pub(super) fn u128s_to_bytes(xs: &[u128]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub(super) fn bytes_to_u128s(b: &[u8]) -> Vec<u128> {
    assert_eq!(b.len() % 16, 0);
    b.chunks_exact(16)
        .map(|c| {
            let mut a = [0u8; 16];
            a.copy_from_slice(c);
            u128::from_le_bytes(a)
        })
        .collect()
}

pub(super) fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub(super) fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    assert_eq!(b.len() % 8, 0);
    b.chunks_exact(8)
        .map(|c| {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            u64::from_le_bytes(a)
        })
        .collect()
}

/// Input/output conservation checksum: (count, wrapping sum of bit
/// images). Equal checksums + equal counts make "output is a permutation
/// of input" overwhelmingly likely; tests on small inputs compare
/// multisets exactly.
pub fn checksum<K: SortKey>(xs: &[K]) -> (u64, u128) {
    (xs.len() as u64, xs.iter().fold(0u128, |a, x| a.wrapping_add(x.to_bits())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_helpers_roundtrip() {
        let a = vec![0u128, 1, u128::MAX];
        assert_eq!(bytes_to_u128s(&u128s_to_bytes(&a)), a);
        let b = vec![0u64, 42, u64::MAX];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&b)), b);
    }

    #[test]
    fn checksum_permutation_invariant() {
        let xs = vec![3i32, -1, 7, 3];
        let ys = vec![7i32, 3, 3, -1];
        assert_eq!(checksum(&xs), checksum(&ys));
        let zs = vec![7i32, 3, 3, -2];
        assert_ne!(checksum(&xs), checksum(&zs));
    }
}
