//! The per-rank SIHSort algorithm (see module docs in `mod.rs`).
//!
//! Two pipelines share the collective schedule:
//!
//! * the classic in-memory rank ([`sihsort_rank`]'s main body) sorts
//!   its shard in place and partitions slices, and
//! * the **streamed** rank (`LocalSorter::External`, DESIGN.md §14)
//!   never holds its shard sorted in memory: the local sort is
//!   `stream::external_sort` into a spilled run, splitter sampling and
//!   rank measurement re-read that run chunk by chunk
//!   (`splitters::regular_samples_streamed` /
//!   `splitters::local_ranks_streamed` over the streaming histogram),
//!   the exchange ships codec-encoded chunks
//!   (`exchange::streamed_exchange`), and the final phase k-way merges
//!   the received spilled runs. Engine state stays bounded by the
//!   [`crate::stream::StreamBudget`]; only the rank's *output* shard
//!   materialises (it is the caller-owned result, same rule as a
//!   `VecSink`).

use std::path::PathBuf;
use std::time::Instant;

use crate::backend::DeviceKey;
use crate::baselines::kmerge::KmergePull;
use crate::baselines::merge_path;
use crate::cfg::FinalPhase;
use crate::cluster::DeviceModel;
use crate::comm::Endpoint;
use crate::dtype::SortKey;
use crate::obs;
use crate::session::Session;
use crate::comm::collectives::ReduceOp;
use crate::stream::external_sort::merge_group_to_store;
use crate::stream::{
    Checkpoint, ChunkSource, ExternalSortStats, RunMeta, RunSink, SliceSource, SpillMedium,
    SpillRun, SpillStore, StreamBudget, StreamCtx,
};
use crate::util::failpoint;

use super::exchange::{buckets, partition_points, streamed_exchange};
use super::local_sort::LocalSorter;
use super::splitters::{
    initial_brackets, initial_candidates, local_ranks, local_ranks_streamed, pack_candidates,
    refine, regular_samples, regular_samples_streamed, unpack_candidates, RefineState,
};

/// Streaming knobs for out-of-core ranks: the per-rank engine budget
/// and where spilled runs live. The driver fills this from the
/// `[stream]` config / `--stream-budget-mb` / `--spill*` flags whenever
/// the run uses `--local-sorter external`, and builds the matching
/// [`StreamCtx`] for [`LocalSorter::External`] through
/// [`SihStreamCfg::ctx`]. Inside `sihsort_rank` it also provides the
/// exchange-side spill store.
#[derive(Clone, Debug)]
pub struct SihStreamCfg {
    /// Engine-state budget of each rank's streaming pipelines.
    pub budget: StreamBudget,
    /// Spill medium for the rank-local sort and the exchange.
    pub medium: SpillMedium,
    /// Parent directory for guarded spill dirs (disk medium).
    pub spill_dir: Option<PathBuf>,
    /// Durable checkpoint root (DESIGN.md §15): when set, every rank
    /// keeps a `rank-<r>/` manifest directory under it and commits each
    /// phase boundary, making the whole distributed sort resumable
    /// after a crash. Checkpointing implies disk spill for the
    /// manifested state regardless of `medium`.
    pub ckpt_dir: Option<PathBuf>,
    /// Resume from the manifests in `ckpt_dir` instead of starting
    /// fresh (a directory with no manifest still starts fresh).
    pub resume: bool,
}

impl SihStreamCfg {
    /// Build the rank-local [`StreamCtx`] these knobs describe over
    /// `session`'s engines.
    pub fn ctx(&self, session: Session) -> StreamCtx {
        let mut ctx = session.stream(self.budget);
        match self.medium {
            SpillMedium::Memory => ctx = ctx.in_memory_spill(),
            SpillMedium::Disk => {
                if let Some(dir) = &self.spill_dir {
                    ctx = ctx.spill_parent(dir.clone());
                }
            }
        }
        ctx
    }

    /// A fresh spill store on these knobs (exchange side).
    pub fn store(&self) -> SpillStore {
        SpillStore::new(self.medium, self.spill_dir.clone())
    }
}

/// SIHSort tuning parameters.
#[derive(Clone, Debug)]
pub struct SihConfig {
    /// Regular samples each rank contributes per refinement round.
    pub samples_per_rank: usize,
    /// Maximum splitter-refinement rounds.
    pub refine_rounds: usize,
    /// Bucket balance tolerance (fraction of ideal bucket size).
    pub balance_tol: f64,
    /// Final-phase strategy (k-way merge vs full re-sort). Streamed
    /// (`External`) ranks always merge: their received runs are spilled,
    /// and a second full external sort would only redo the merge's work.
    pub final_phase: FinalPhase,
    /// Compute-time scaling for device ranks.
    pub devmodel: DeviceModel,
    /// Per-call tuning knobs for the rank-local sorts and the final
    /// recombine (`Session`/`Launch` API, DESIGN.md §12).
    pub launch: crate::session::Launch,
    /// Streaming knobs for out-of-core ranks (`None` on in-memory
    /// runs). See [`SihStreamCfg`].
    pub stream: Option<SihStreamCfg>,
}

impl Default for SihConfig {
    fn default() -> Self {
        Self {
            samples_per_rank: 64,
            refine_rounds: 4,
            balance_tol: 0.10,
            final_phase: FinalPhase::Merge,
            devmodel: DeviceModel::default(),
            launch: crate::session::Launch::default(),
            stream: None,
        }
    }
}

/// What a streamed (out-of-core) rank did, for budget/spill accounting
/// — the bench and the equivalence tests assert against these.
#[derive(Clone, Debug)]
pub struct RankStreamStats {
    /// The rank-local external sort's pipeline shape (runs, merge
    /// passes, intermediate spill volume, budget-derived granules).
    pub local: ExternalSortStats,
    /// Bytes the rank spilled parking its sorted shard (phase-1 output
    /// run; 0 on the memory medium).
    pub local_run_bytes: u64,
    /// Bytes the rank spilled buffering received exchange runs, plus
    /// the final phase's fan-in-capping pre-merge passes when the rank
    /// count exceeds the budget's merge fan-in (0 on the memory
    /// medium).
    pub exchange_spilled_bytes: u64,
    /// The engine-state budget the rank ran under.
    pub budget_bytes: usize,
}

impl RankStreamStats {
    /// Registry form: the rank-local external sort's
    /// [`crate::obs::STREAM_COUNTERS`] followed by the rank's own
    /// spill/budget accounting.
    pub fn snapshot(&self) -> obs::CounterSnapshot {
        let mut s = self.local.snapshot();
        s.push("local_run_bytes", self.local_run_bytes);
        s.push("exchange_spilled_bytes", self.exchange_spilled_bytes);
        s.push("budget_bytes", self.budget_bytes as u64);
        s
    }
}

/// Per-rank result: the globally-sorted shard + phase breakdown
/// (simulated seconds for this rank).
#[derive(Clone, Debug)]
pub struct RankOutcome<K> {
    /// The rank's globally-positioned, locally-sorted shard.
    pub data: Vec<K>,
    /// Simulated seconds in the local-sort phase.
    pub sim_local_sort: f64,
    /// Simulated seconds in sampling + splitter refinement.
    pub sim_splitters: f64,
    /// Simulated seconds in partition + alltoallv.
    pub sim_exchange: f64,
    /// Simulated seconds in the final combine.
    pub sim_final: f64,
    /// Host wall-clock this rank actually consumed.
    pub wall_secs: f64,
    /// Splitter refinement rounds actually used (leader-reported).
    pub rounds_used: usize,
    /// Streaming accounting when this rank ran out-of-core
    /// (`LocalSorter::External`); `None` on the in-memory pipelines.
    pub stream: Option<RankStreamStats>,
}

const LEADER: usize = 0;

/// Run SIHSort on this rank's shard. Every rank of the fabric must call
/// this collectively (same config). Returns the rank's final shard:
/// ascending locally, and globally `outcome[r].data <= outcome[r+1].data`.
pub fn sihsort_rank<K: DeviceKey>(
    ep: &mut Endpoint,
    shard: Vec<K>,
    sorter: &LocalSorter,
    cfg: &SihConfig,
) -> anyhow::Result<RankOutcome<K>> {
    if let LocalSorter::External(ctx) = sorter {
        // Out-of-core rank: the fully streamed pipeline (DESIGN.md §14).
        return sihsort_rank_streamed(ep, shard, ctx, cfg);
    }
    let wall0 = Instant::now();
    let p = ep.nranks();
    let is_dev = sorter.is_device();
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };

    // ---- Phase 1: local sort ------------------------------------------------
    ep.note_phase("local-sort");
    let t_phase = ep.now();
    // Measured under the fabric's compute token: wall time reflects this
    // rank's work alone, not host-core oversubscription (fabric docs).
    let ((sorted, sort_res), secs) = ep.measured(move || {
        let mut s = shard;
        let r = sorter.sort(&mut s, &cfg.launch);
        (s, r)
    });
    sort_res?;
    charge(ep, secs);
    ep.barrier()?;
    let sim_local_sort = ep.now() - t_phase;

    // ---- Phase 2+3: sampling + interpolated-histogram refinement -----------
    ep.note_phase("splitters");
    let t_phase = ep.now();
    let (splitters, rounds_used) = select_splitters(ep, &sorted, cfg, is_dev)?;
    let sim_splitters = ep.now() - t_phase;

    // ---- Phase 4+5: partition + single alltoallv ----------------------------
    ep.note_phase("exchange");
    let t_phase = ep.now();
    let (parts, secs) = ep.measured(|| {
        let cuts = partition_points(&sorted, &splitters);
        buckets(&sorted, &cuts).into_iter().map(|b| b.to_vec()).collect::<Vec<Vec<K>>>()
    });
    debug_assert_eq!(parts.len(), p);
    charge(ep, secs);
    let received = ep.alltoallv(parts)?;
    drop(sorted);
    let sim_exchange = ep.now() - t_phase;

    // ---- Phase 6: final combine ---------------------------------------------
    ep.note_phase("final");
    let t_phase = ep.now();
    let (data, secs) = ep.measured(|| -> anyhow::Result<Vec<K>> {
        match cfg.final_phase {
            FinalPhase::Merge => {
                // Received runs are each sorted: merge-path partitioned
                // k-way merge (DESIGN.md §11) over the full host pool.
                // Safe to fan out here: this closure runs under the
                // fabric's compute token (one rank's measured section at
                // a time), so the workers never contend with other rank
                // threads and the measured seconds model a rank owning
                // its node's cores.
                let refs: Vec<&[K]> = received.iter().map(|r| r.as_slice()).collect();
                let total: usize = refs.iter().map(|r| r.len()).sum();
                Ok(merge_path::kmerge_parallel_with(
                    &refs,
                    cfg.launch
                        .tasks_for(crate::backend::threaded::default_threads(), total),
                    cfg.launch.par_threshold_or(merge_path::PAR_MERGE_MIN),
                ))
            }
            FinalPhase::Sort => {
                // The paper's described variant: concatenate + full re-sort.
                let mut all: Vec<K> = received.iter().flatten().copied().collect();
                sorter.sort(&mut all, &cfg.launch)?;
                Ok(all)
            }
        }
    });
    let data = data?;
    charge(ep, secs);
    ep.barrier()?;
    let sim_final = ep.now() - t_phase;

    ep.finish();
    Ok(RankOutcome {
        data,
        sim_local_sort,
        sim_splitters,
        sim_exchange,
        sim_final,
        wall_secs: wall0.elapsed().as_secs_f64(),
        rounds_used,
        stream: None,
    })
}

/// The streamed SIHSort rank: same collective schedule as
/// [`sihsort_rank`], but the shard never sits sorted in memory — it is
/// external-sorted into a spilled run, re-read chunk by chunk for
/// splitter work, exchanged chunk-at-a-time, and the received runs are
/// k-way merged into the output (pre-merged in fan-in groups when the
/// rank count exceeds the budget's merge fan-in). Engine state is
/// bounded by the [`StreamCtx`]'s budget throughout; only the input
/// shard (owned by the driver), the output shard (the result), and the
/// in-flight exchange chunks in the fabric's channels (the network
/// stand-in — see `exchange`) live outside it.
fn sihsort_rank_streamed<K: DeviceKey>(
    ep: &mut Endpoint,
    shard: Vec<K>,
    ctx: &StreamCtx,
    cfg: &SihConfig,
) -> anyhow::Result<RankOutcome<K>> {
    if let Some(scfg) = cfg.stream.as_ref().filter(|s| s.ckpt_dir.is_some()) {
        // Crash-safe variant: every phase boundary commits to a durable
        // per-rank manifest (DESIGN.md §15).
        return sihsort_rank_streamed_ckpt(ep, shard, ctx, cfg, scfg);
    }
    let wall0 = Instant::now();
    // External ranks are CPU-class (`LocalSorter::is_device`).
    let is_dev = false;
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };
    let io_chunk = ctx.plan::<K>().io_chunk_elems;

    // ---- Phase 1: budget-bounded rank-local external sort -------------
    ep.note_phase("local-sort");
    let t_phase = ep.now();
    let mut local_store = ctx.store();
    let (sorted_res, secs) = {
        let store = &mut local_store;
        ep.measured(move || -> anyhow::Result<(SpillRun<K>, ExternalSortStats)> {
            let mut src = SliceSource::new(&shard);
            let mut sink = RunSink::new(store)?;
            let stats = ctx.external_sort(&mut src, &mut sink, Some(&cfg.launch))?;
            Ok((sink.into_run()?, stats))
        })
    };
    let (run, local_stats) = sorted_res?;
    charge(ep, secs);
    ep.barrier()?;
    let sim_local_sort = ep.now() - t_phase;
    let local_run_bytes = local_store.bytes_spilled();

    // ---- Phase 2+3: splitters over the streamed shard -----------------
    ep.note_phase("splitters");
    let t_phase = ep.now();
    let local_len = run.elems() as u64;
    let (splitters, rounds_used) = select_splitters_core(
        ep,
        cfg,
        is_dev,
        local_len,
        || {
            let mut src = crate::stream::SpillRunSource::new(&run, io_chunk)?;
            Ok(regular_samples_streamed(&mut src, local_len, cfg.samples_per_rank, io_chunk)?
                .into_iter()
                .map(|x| x.to_bits())
                .collect())
        },
        |cands| local_ranks_streamed(ctx, &run, cands, io_chunk, &cfg.launch),
    )?;
    let sim_splitters = ep.now() - t_phase;

    // ---- Phase 4+5: streamed chunk-at-a-time exchange -----------------
    ep.note_phase("exchange");
    let t_phase = ep.now();
    let mut xstore = match &cfg.stream {
        Some(s) => s.store(),
        None => ctx.store(),
    };
    let (recv_runs, secs) = streamed_exchange(ep, &run, &splitters, io_chunk, &mut xstore)?;
    // The parked input shard is consumed: free its spill before merging.
    drop(run);
    drop(local_store);
    charge(ep, secs);
    let sim_exchange = ep.now() - t_phase;

    // ---- Phase 6: final k-way merge of the received runs --------------
    ep.note_phase("final");
    let t_phase = ep.now();
    let plan = ctx.plan::<K>();
    let (data_res, secs) = {
        let xstore_ref = &mut xstore;
        ep.measured(move || -> anyhow::Result<Vec<K>> {
            let _span = obs::span(obs::SpanKind::Pass, "sih.final-merge");
            // The rank count can exceed the budget's merge fan-in, and
            // every open cursor owns an io-granule refill buffer — so
            // pre-merge received runs in fan-in-sized groups (the same
            // rule as `external_sort`'s intermediate passes) until one
            // merge fits the budget.
            let mut runs = recv_runs;
            while runs.len() > plan.fan_in {
                let mut merged: Vec<SpillRun<K>> = Vec::new();
                while !runs.is_empty() {
                    let take = plan.fan_in.min(runs.len());
                    let group: Vec<SpillRun<K>> = runs.drain(..take).collect();
                    if group.len() == 1 {
                        merged.extend(group);
                        continue;
                    }
                    merged.push(merge_group_to_store(&group, xstore_ref, &plan)?);
                }
                runs = merged;
            }
            let mut cursors = Vec::with_capacity(runs.len());
            for r in &runs {
                cursors.push(r.cursor(io_chunk)?);
            }
            let mut merge = KmergePull::new(cursors);
            let total: usize = runs.iter().map(SpillRun::elems).sum();
            let mut data = Vec::with_capacity(total);
            let mut chunk: Vec<K> = Vec::with_capacity(io_chunk);
            loop {
                chunk.clear();
                if merge.next_chunk(&mut chunk, io_chunk)? == 0 {
                    break;
                }
                data.extend_from_slice(&chunk);
            }
            Ok(data)
        })
    };
    let data = data_res?;
    let exchange_spilled_bytes = xstore.bytes_spilled();
    drop(xstore);
    charge(ep, secs);
    ep.barrier()?;
    let sim_final = ep.now() - t_phase;

    ep.finish();
    Ok(RankOutcome {
        data,
        sim_local_sort,
        sim_splitters,
        sim_exchange,
        sim_final,
        wall_secs: wall0.elapsed().as_secs_f64(),
        rounds_used,
        stream: Some(RankStreamStats {
            local: local_stats,
            local_run_bytes,
            exchange_spilled_bytes,
            budget_bytes: ctx.budget().get(),
        }),
    })
}

/// The crash-safe streamed rank (DESIGN.md §15): the pipeline of
/// [`sihsort_rank_streamed`], with every phase boundary committed to a
/// durable per-rank manifest under `<ckpt_dir>/rank-<r>/` so a killed
/// job resumes (`SihStreamCfg::resume`) instead of restarting. The
/// recovery model is *idempotent ranks*: redoing work a crash lost is
/// always acceptable, losing committed work never is.
///
/// Per-rank vs collective state: phases 1 (park the locally sorted
/// shard) and 6 (final merge) are rank-local, so each rank skips them
/// individually once its own manifest passed them. Phases 2–5 are
/// collectives — a rank can only skip them when **every** rank
/// committed them, so the skip decision rides an allreduce-Min over the
/// manifest phases; a rank that already committed a collective phase
/// re-executes it identically (the schedule is deterministic given the
/// parked runs) whenever any peer still needs it, retiring its own
/// stale downstream state first. The parked pass-1 run is deliberately
/// never retired: it is what makes any such redo possible regardless of
/// the phase skew the crash left behind.
///
/// Resume contract: the driver re-supplies the identical input shard
/// (`workload` generation is seeded) and every rank resumes with the
/// same budget; both are validated against the manifest.
fn sihsort_rank_streamed_ckpt<K: DeviceKey>(
    ep: &mut Endpoint,
    shard: Vec<K>,
    ctx: &StreamCtx,
    cfg: &SihConfig,
    scfg: &SihStreamCfg,
) -> anyhow::Result<RankOutcome<K>> {
    let wall0 = Instant::now();
    // External ranks are CPU-class (`LocalSorter::is_device`).
    let is_dev = false;
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };
    let plan = ctx.plan::<K>();
    let io_chunk = plan.io_chunk_elems;
    let p = ep.nranks();
    let rank = ep.rank();
    let ck_root = scfg
        .ckpt_dir
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("rank {rank}: checkpointed run without a checkpoint dir"))?;
    let rank_dir = ck_root.join(format!("rank-{rank}"));
    // The phase-1 local sort nests its own checkpoint in a subdirectory
    // (the manifest sweep leaves subdirectories alone).
    let local_dir = rank_dir.join("local");
    let tag = format!("p{p}-r{rank}");

    let mut store = SpillStore::checkpointed(
        &rank_dir,
        "sihsort_rank",
        &tag,
        // The record layout name — identical to the bare dtype name for
        // the scalar keys this rank sorts, so pre-record checkpoints
        // resume unchanged (DESIGN.md §19).
        &<K as crate::stream::StreamRecord>::layout_name(),
        plan.run_chunk_elems as u64,
        scfg.resume,
    )?;
    let my_phase = store
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("rank {rank}: checkpointed store lost its manifest"))?
        .phase;
    // Collective skip decisions must be uniform across ranks (see the
    // function docs): agree on the slowest rank's committed phase.
    let start = ep.allreduce_u64(my_phase as u64, ReduceOp::Min)? as u32;

    // ---- Phase 1: park the external-sorted shard (per-rank skip) ------
    ep.note_phase("local-sort");
    let t_phase = ep.now();
    let (run, local_stats, secs) = if my_phase >= 1 {
        // The parked run is durable and input-deterministic: reopen it.
        let meta = store
            .manifest()
            .and_then(|m| m.runs.iter().find(|r| r.pass == 1).cloned())
            .ok_or_else(|| {
                anyhow::anyhow!("rank {rank}: manifest at phase >= 1 without a parked run")
            })?;
        let run = store.open_manifested_run::<K>(&meta)?;
        let stats = ExternalSortStats {
            elems: meta.elems,
            fan_in: plan.fan_in,
            run_chunk_elems: plan.run_chunk_elems,
            completed_noop: true,
            ..ExternalSortStats::default()
        };
        drop(shard);
        let _ = std::fs::remove_dir_all(&local_dir); // stale nested state
        (run, stats, 0.0)
    } else {
        // A crash between the park record and the phase commit leaves a
        // manifested pass-1 run with phase still 0: retire it, or the
        // re-park below would record a duplicate.
        store.retire_runs(|r| r.pass >= 1)?;
        let local_ck = Checkpoint::new(&local_dir, tag.as_str()).resume().defer_complete();
        let (res, secs) = {
            let store_ref = &mut store;
            ep.measured(move || -> anyhow::Result<(SpillRun<K>, ExternalSortStats)> {
                let mut src = SliceSource::new(&shard);
                let mut sink = RunSink::new(store_ref)?;
                let stats =
                    ctx.external_sort_ckpt(&mut src, &mut sink, Some(&cfg.launch), &local_ck)?;
                Ok((sink.into_run()?, stats))
            })
        };
        let (mut run, stats) = res?;
        // Satellite-1 crash window: the park is on disk (fsynced) but
        // unmanifested — a kill here sweeps it on resume, and the
        // nested checkpoint's merged runs make the re-park cheap.
        failpoint::check("sih.park")?;
        store.record_run(&mut run, 1, 0)?;
        store.update(|m| m.phase = 1)?;
        // The parked run supersedes the nested checkpoint.
        let _ = std::fs::remove_dir_all(&local_dir);
        failpoint::check("sih.parked")?;
        (run, stats, secs)
    };
    charge(ep, secs);
    ep.barrier()?;
    let sim_local_sort = ep.now() - t_phase;
    let local_run_bytes = store.bytes_spilled();

    // ---- Phase 2+3: splitters (collective; uniform skip) --------------
    ep.note_phase("splitters");
    let t_phase = ep.now();
    let (splitters, rounds_used) = if start >= 3 {
        let m = store
            .manifest()
            .ok_or_else(|| anyhow::anyhow!("rank {rank}: checkpointed store lost its manifest"))?;
        (m.splitters.clone(), m.rounds_used as usize)
    } else {
        let local_len = run.elems() as u64;
        let (splitters, rounds_used) = select_splitters_core(
            ep,
            cfg,
            is_dev,
            local_len,
            || {
                let mut src = crate::stream::SpillRunSource::new(&run, io_chunk)?;
                Ok(regular_samples_streamed(&mut src, local_len, cfg.samples_per_rank, io_chunk)?
                    .into_iter()
                    .map(|x| x.to_bits())
                    .collect())
            },
            |cands| local_ranks_streamed(ctx, &run, cands, io_chunk, &cfg.launch),
        )?;
        failpoint::check("sih.splitters")?;
        let spl = splitters.clone();
        let ru = rounds_used as u64;
        store.update(move |m| {
            m.splitters = spl;
            m.rounds_used = ru;
            m.phase = 3;
        })?;
        failpoint::check("sih.splitters.recorded")?;
        (splitters, rounds_used)
    };
    let sim_splitters = ep.now() - t_phase;

    // ---- Phase 4+5: streamed exchange (collective; uniform skip) ------
    ep.note_phase("exchange");
    let t_phase = ep.now();
    let (recv_runs, secs) = if start >= 5 {
        let committed = store
            .manifest()
            .ok_or_else(|| anyhow::anyhow!("rank {rank}: checkpointed store lost its manifest"))?
            .phase;
        if committed >= 6 {
            // This rank's output is already durable (and its exchange
            // runs may be retired); phase 6 reloads the output instead.
            (Vec::new(), 0.0)
        } else {
            let metas: Vec<RunMeta> = {
                let m = store.manifest().ok_or_else(|| {
                    anyhow::anyhow!("rank {rank}: checkpointed store lost its manifest")
                })?;
                let mut v: Vec<RunMeta> =
                    m.runs.iter().filter(|r| r.pass == 5).cloned().collect();
                // seq is the source rank: restore exchange order.
                v.sort_by_key(|r| r.seq);
                v
            };
            anyhow::ensure!(
                metas.len() == p,
                "rank {rank}: manifest at phase >= 5 holds {} of {p} exchange runs",
                metas.len(),
            );
            let mut runs = Vec::with_capacity(p);
            for meta in &metas {
                runs.push(store.open_manifested_run::<K>(meta)?);
            }
            (runs, 0.0)
        }
    } else {
        // Stale downstream state — partial exchange batches from a
        // crash between records and the phase commit, or a committed
        // exchange/output this rank must redo because a peer lost its
        // copy — retires first; the collective then replays.
        store.retire_runs(|r| r.pass >= 5)?;
        let (mut runs, secs) = streamed_exchange(ep, &run, &splitters, io_chunk, &mut store)?;
        failpoint::check("sih.exchange")?;
        for (src, r) in runs.iter_mut().enumerate() {
            store.record_run(r, 5, src as u64)?;
        }
        failpoint::check("sih.exchange.recorded")?;
        store.update(|m| m.phase = 5)?;
        (runs, secs)
    };
    // The parked run handle drops here, but its file stays durable
    // (never retired — see the function docs).
    drop(run);
    charge(ep, secs);
    let sim_exchange = ep.now() - t_phase;

    // ---- Phase 6: final merge + durable output (per-rank skip) --------
    ep.note_phase("final");
    let t_phase = ep.now();
    let my_phase = store
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("rank {rank}: checkpointed store lost its manifest"))?
        .phase;
    let (data, secs) = if my_phase >= 6 {
        let meta = store
            .manifest()
            .and_then(|m| m.runs.iter().find(|r| r.pass == 6).cloned())
            .ok_or_else(|| {
                anyhow::anyhow!("rank {rank}: manifest at phase 6 without an output run")
            })?;
        // A crash between the output commit and the exchange-run retire
        // leaves stale pass-5 runs; reclaim them now.
        store.retire_runs(|r| r.pass == 5)?;
        drop(recv_runs);
        let (res, secs) = {
            let store_ref = &store;
            ep.measured(move || -> anyhow::Result<Vec<K>> {
                let out_run = store_ref.open_manifested_run::<K>(&meta)?;
                let mut src = crate::stream::SpillRunSource::new(&out_run, io_chunk)?;
                let mut data = Vec::with_capacity(out_run.elems());
                let mut chunk: Vec<K> = Vec::new();
                while src.next_chunk(&mut chunk, io_chunk)? > 0 {
                    data.extend_from_slice(&chunk);
                }
                Ok(data)
            })
        };
        (res?, secs)
    } else {
        failpoint::check("sih.final")?;
        // A crash between the output record and the phase-6 commit
        // leaves a manifested pass-6 run with phase still 5: retire it,
        // or the redo below would record a duplicate.
        store.retire_runs(|r| r.pass == 6)?;
        let (res, secs) = {
            let store_ref = &mut store;
            ep.measured(move || -> anyhow::Result<(Vec<K>, SpillRun<K>)> {
                let _span = obs::span(obs::SpanKind::Pass, "sih.final-merge");
                // Fan-in-capped pre-merge, as in the non-ckpt rank. The
                // intermediate merged runs stay unmanifested (keep =
                // false): a crash sweeps them and phase 6 redoes from
                // the manifested pass-5 runs, whose files survive the
                // group drop.
                let mut runs = recv_runs;
                while runs.len() > plan.fan_in {
                    let mut merged: Vec<SpillRun<K>> = Vec::new();
                    while !runs.is_empty() {
                        let take = plan.fan_in.min(runs.len());
                        let group: Vec<SpillRun<K>> = runs.drain(..take).collect();
                        if group.len() == 1 {
                            merged.extend(group);
                            continue;
                        }
                        merged.push(merge_group_to_store(&group, store_ref, &plan)?);
                    }
                    runs = merged;
                }
                let total: usize = runs.iter().map(SpillRun::elems).sum();
                let mut data = Vec::with_capacity(total);
                let mut cursors = Vec::with_capacity(runs.len());
                for r in &runs {
                    cursors.push(r.cursor(io_chunk)?);
                }
                let mut merge = KmergePull::new(cursors);
                // Tee the merge: the caller gets the output vector, the
                // manifest gets a durable copy so a completed rank can
                // resume by reload instead of redoing the merge.
                let mut writer = store_ref.run_writer::<K>()?;
                let mut chunk: Vec<K> = Vec::with_capacity(io_chunk);
                loop {
                    chunk.clear();
                    if merge.next_chunk(&mut chunk, io_chunk)? == 0 {
                        break;
                    }
                    failpoint::check("sih.final.mid")?;
                    data.extend_from_slice(&chunk);
                    writer.push_chunk(&chunk)?;
                }
                let out = writer.finish()?;
                drop(merge);
                Ok((data, out))
            })
        };
        let (data, mut out_run) = res?;
        store.record_run(&mut out_run, 6, 0)?;
        // Commit point: phase 6 means "output durable". A crash before
        // this line is redone from the pass-5 runs (the stale pass-6
        // record retires above); a crash after it reloads the output.
        store.update(|m| m.phase = 6)?;
        // The exchange runs are superseded by the output.
        store.retire_runs(|r| r.pass == 5)?;
        failpoint::check("sih.done")?;
        (data, secs)
    };
    let exchange_spilled_bytes = store.bytes_spilled().saturating_sub(local_run_bytes);
    charge(ep, secs);
    ep.barrier()?;
    let sim_final = ep.now() - t_phase;

    ep.finish();
    Ok(RankOutcome {
        data,
        sim_local_sort,
        sim_splitters,
        sim_exchange,
        sim_final,
        wall_secs: wall0.elapsed().as_secs_f64(),
        rounds_used,
        stream: Some(RankStreamStats {
            local: local_stats,
            local_run_bytes,
            exchange_spilled_bytes,
            budget_bytes: ctx.budget().get(),
        }),
    })
}

/// Collective splitter selection over an in-memory sorted shard;
/// returns P-1 splitters in bit-image space and the number of
/// refinement rounds used.
fn select_splitters<K: SortKey>(
    ep: &mut Endpoint,
    sorted: &[K],
    cfg: &SihConfig,
    is_dev: bool,
) -> anyhow::Result<(Vec<u128>, usize)> {
    select_splitters_core(
        ep,
        cfg,
        is_dev,
        sorted.len() as u64,
        || {
            Ok(regular_samples(sorted, cfg.samples_per_rank)
                .into_iter()
                .map(|x| x.to_bits())
                .collect())
        },
        |cands| Ok(local_ranks(sorted, cands)),
    )
}

/// The collective splitter-selection schedule, generic over how a rank
/// measures itself: `sample` draws this rank's regular samples (bit
/// images) and `ranks_of` the local ranks of candidate splitters. The
/// in-memory path indexes its sorted slice; the streamed path re-reads
/// its spilled run ([`sihsort_rank_streamed`]). Both measurements run
/// under the fabric's compute token.
fn select_splitters_core<S, R>(
    ep: &mut Endpoint,
    cfg: &SihConfig,
    is_dev: bool,
    local_len: u64,
    mut sample: S,
    mut ranks_of: R,
) -> anyhow::Result<(Vec<u128>, usize)>
where
    S: FnMut() -> anyhow::Result<Vec<u128>>,
    R: FnMut(&[u128]) -> anyhow::Result<Vec<u64>>,
{
    let p = ep.nranks();
    if p == 1 {
        return Ok((Vec::new(), 0));
    }
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };

    // Sampling: gather p regular samples (as bit images) at the leader.
    let (samples, secs) = ep.measured(&mut sample);
    let samples = samples?;
    charge(ep, secs);
    let sample_bytes = u128s_to_bytes(&samples);
    let gathered = ep.gather_bytes(LEADER, sample_bytes)?;

    // Global element count rides an allreduce (one u64).
    let total = ep.allreduce_u64(local_len, crate::comm::collectives::ReduceOp::Sum)?;

    let mut leader_state: Option<RefineState> = if ep.rank() == LEADER {
        let gathered = gathered
            .ok_or_else(|| anyhow::anyhow!("sample gather returned no payload at the leader"))?;
        let pooled: Vec<u128> = gathered.iter().flat_map(|b| bytes_to_u128s(b)).collect();
        let candidates = initial_candidates(pooled, p);
        let brackets = initial_brackets(&candidates, total);
        Some(RefineState { candidates, brackets })
    } else {
        None
    };

    // Refinement rounds (lockstep on every rank).
    let mut done_next = false;
    let mut rounds_used = 0usize;
    for round in 0..=cfg.refine_rounds {
        let is_last = round == cfg.refine_rounds || done_next;
        // Leader broadcasts candidates (+ done flag hidden at the tail).
        let payload = if ep.rank() == LEADER {
            let state = leader_state
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("leader lost its refine state"))?;
            pack_candidates(&state.candidates, is_last)
        } else {
            Vec::new()
        };
        let (candidates, done) = unpack_candidates(&ep.bcast_bytes(LEADER, payload)?);
        if done {
            return Ok((candidates, rounds_used));
        }
        rounds_used = round + 1;

        // Every rank measures its local candidate ranks.
        let (lranks, secs) = ep.measured(|| ranks_of(&candidates));
        let lranks = lranks?;
        charge(ep, secs);
        let gathered = ep.gather_bytes(LEADER, u64s_to_bytes(&lranks))?;

        if ep.rank() == LEADER {
            let gathered = gathered
                .ok_or_else(|| anyhow::anyhow!("rank gather returned no payload at the leader"))?;
            let per_rank: Vec<Vec<u64>> = gathered.iter().map(|b| bytes_to_u64s(b)).collect();
            let mut global = vec![0u64; candidates.len()];
            for pr in &per_rank {
                for (g, v) in global.iter_mut().zip(pr.iter()) {
                    *g += v;
                }
            }
            let state = leader_state
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("leader lost its refine state"))?;
            // Measurements correspond to the *broadcast* candidates.
            state.candidates = candidates;
            let (next, worst) = refine(state, &global, total, p, cfg.balance_tol);
            if worst <= cfg.balance_tol {
                // Measured candidates are balanced: finalise them next round.
                done_next = true;
            } else {
                *state = next;
            }
        }
        // Non-leaders learn about termination from the next bcast's flag.
    }
    unreachable!("refinement loop always terminates via the done broadcast")
}

// -- byte helpers (wire format for counters/samples) -------------------------

pub(super) fn u128s_to_bytes(xs: &[u128]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub(super) fn bytes_to_u128s(b: &[u8]) -> Vec<u128> {
    assert_eq!(b.len() % 16, 0);
    b.chunks_exact(16)
        .map(|c| {
            let mut a = [0u8; 16];
            a.copy_from_slice(c);
            u128::from_le_bytes(a)
        })
        .collect()
}

pub(super) fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub(super) fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    assert_eq!(b.len() % 8, 0);
    b.chunks_exact(8)
        .map(|c| {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            u64::from_le_bytes(a)
        })
        .collect()
}

/// Input/output conservation checksum: (count, wrapping sum of bit
/// images). Equal checksums + equal counts make "output is a permutation
/// of input" overwhelmingly likely; tests on small inputs compare
/// multisets exactly.
pub fn checksum<K: SortKey>(xs: &[K]) -> (u64, u128) {
    (xs.len() as u64, xs.iter().fold(0u128, |a, x| a.wrapping_add(x.to_bits())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_helpers_roundtrip() {
        let a = vec![0u128, 1, u128::MAX];
        assert_eq!(bytes_to_u128s(&u128s_to_bytes(&a)), a);
        let b = vec![0u64, 42, u64::MAX];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&b)), b);
    }

    #[test]
    fn checksum_permutation_invariant() {
        let xs = vec![3i32, -1, 7, 3];
        let ys = vec![7i32, 3, 3, -1];
        assert_eq!(checksum(&xs), checksum(&ys));
        let zs = vec![7i32, 3, 3, -2];
        assert_ne!(checksum(&xs), checksum(&zs));
    }
}
