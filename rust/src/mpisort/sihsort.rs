//! The per-rank SIHSort algorithm (see module docs in `mod.rs`).
//!
//! Two pipelines share the collective schedule:
//!
//! * the classic in-memory rank ([`sihsort_rank`]'s main body) sorts
//!   its shard in place and partitions slices, and
//! * the **streamed** rank (`LocalSorter::External`, DESIGN.md §14)
//!   never holds its shard sorted in memory: the local sort is
//!   `stream::external_sort` into a spilled run, splitter sampling and
//!   rank measurement re-read that run chunk by chunk
//!   (`splitters::regular_samples_streamed` /
//!   `splitters::local_ranks_streamed` over the streaming histogram),
//!   the exchange ships codec-encoded chunks
//!   (`exchange::streamed_exchange`), and the final phase k-way merges
//!   the received spilled runs. Engine state stays bounded by the
//!   [`crate::stream::StreamBudget`]; only the rank's *output* shard
//!   materialises (it is the caller-owned result, same rule as a
//!   `VecSink`).

use std::path::PathBuf;
use std::time::Instant;

use crate::backend::DeviceKey;
use crate::baselines::kmerge::KmergePull;
use crate::baselines::merge_path;
use crate::cfg::FinalPhase;
use crate::cluster::DeviceModel;
use crate::comm::Endpoint;
use crate::dtype::SortKey;
use crate::session::Session;
use crate::stream::external_sort::merge_group_to_store;
use crate::stream::{
    ExternalSortStats, RunSink, SliceSource, SpillMedium, SpillRun, SpillStore, StreamBudget,
    StreamCtx,
};

use super::exchange::{buckets, partition_points, streamed_exchange};
use super::local_sort::LocalSorter;
use super::splitters::{
    initial_brackets, initial_candidates, local_ranks, local_ranks_streamed, pack_candidates,
    refine, regular_samples, regular_samples_streamed, unpack_candidates, RefineState,
};

/// Streaming knobs for out-of-core ranks: the per-rank engine budget
/// and where spilled runs live. The driver fills this from the
/// `[stream]` config / `--stream-budget-mb` / `--spill*` flags whenever
/// the run uses `--local-sorter external`, and builds the matching
/// [`StreamCtx`] for [`LocalSorter::External`] through
/// [`SihStreamCfg::ctx`]. Inside `sihsort_rank` it also provides the
/// exchange-side spill store.
#[derive(Clone, Debug)]
pub struct SihStreamCfg {
    /// Engine-state budget of each rank's streaming pipelines.
    pub budget: StreamBudget,
    /// Spill medium for the rank-local sort and the exchange.
    pub medium: SpillMedium,
    /// Parent directory for guarded spill dirs (disk medium).
    pub spill_dir: Option<PathBuf>,
}

impl SihStreamCfg {
    /// Build the rank-local [`StreamCtx`] these knobs describe over
    /// `session`'s engines.
    pub fn ctx(&self, session: Session) -> StreamCtx {
        let mut ctx = session.stream(self.budget);
        match self.medium {
            SpillMedium::Memory => ctx = ctx.in_memory_spill(),
            SpillMedium::Disk => {
                if let Some(dir) = &self.spill_dir {
                    ctx = ctx.spill_parent(dir.clone());
                }
            }
        }
        ctx
    }

    /// A fresh spill store on these knobs (exchange side).
    pub fn store(&self) -> SpillStore {
        SpillStore::new(self.medium, self.spill_dir.clone())
    }
}

/// SIHSort tuning parameters.
#[derive(Clone, Debug)]
pub struct SihConfig {
    /// Regular samples each rank contributes per refinement round.
    pub samples_per_rank: usize,
    /// Maximum splitter-refinement rounds.
    pub refine_rounds: usize,
    /// Bucket balance tolerance (fraction of ideal bucket size).
    pub balance_tol: f64,
    /// Final-phase strategy (k-way merge vs full re-sort). Streamed
    /// (`External`) ranks always merge: their received runs are spilled,
    /// and a second full external sort would only redo the merge's work.
    pub final_phase: FinalPhase,
    /// Compute-time scaling for device ranks.
    pub devmodel: DeviceModel,
    /// Per-call tuning knobs for the rank-local sorts and the final
    /// recombine (`Session`/`Launch` API, DESIGN.md §12).
    pub launch: crate::session::Launch,
    /// Streaming knobs for out-of-core ranks (`None` on in-memory
    /// runs). See [`SihStreamCfg`].
    pub stream: Option<SihStreamCfg>,
}

impl Default for SihConfig {
    fn default() -> Self {
        Self {
            samples_per_rank: 64,
            refine_rounds: 4,
            balance_tol: 0.10,
            final_phase: FinalPhase::Merge,
            devmodel: DeviceModel::default(),
            launch: crate::session::Launch::default(),
            stream: None,
        }
    }
}

/// What a streamed (out-of-core) rank did, for budget/spill accounting
/// — the bench and the equivalence tests assert against these.
#[derive(Clone, Debug)]
pub struct RankStreamStats {
    /// The rank-local external sort's pipeline shape (runs, merge
    /// passes, intermediate spill volume, budget-derived granules).
    pub local: ExternalSortStats,
    /// Bytes the rank spilled parking its sorted shard (phase-1 output
    /// run; 0 on the memory medium).
    pub local_run_bytes: u64,
    /// Bytes the rank spilled buffering received exchange runs, plus
    /// the final phase's fan-in-capping pre-merge passes when the rank
    /// count exceeds the budget's merge fan-in (0 on the memory
    /// medium).
    pub exchange_spilled_bytes: u64,
    /// The engine-state budget the rank ran under.
    pub budget_bytes: usize,
}

/// Per-rank result: the globally-sorted shard + phase breakdown
/// (simulated seconds for this rank).
#[derive(Clone, Debug)]
pub struct RankOutcome<K> {
    /// The rank's globally-positioned, locally-sorted shard.
    pub data: Vec<K>,
    /// Simulated seconds in the local-sort phase.
    pub sim_local_sort: f64,
    /// Simulated seconds in sampling + splitter refinement.
    pub sim_splitters: f64,
    /// Simulated seconds in partition + alltoallv.
    pub sim_exchange: f64,
    /// Simulated seconds in the final combine.
    pub sim_final: f64,
    /// Host wall-clock this rank actually consumed.
    pub wall_secs: f64,
    /// Splitter refinement rounds actually used (leader-reported).
    pub rounds_used: usize,
    /// Streaming accounting when this rank ran out-of-core
    /// (`LocalSorter::External`); `None` on the in-memory pipelines.
    pub stream: Option<RankStreamStats>,
}

const LEADER: usize = 0;

/// Run SIHSort on this rank's shard. Every rank of the fabric must call
/// this collectively (same config). Returns the rank's final shard:
/// ascending locally, and globally `outcome[r].data <= outcome[r+1].data`.
pub fn sihsort_rank<K: DeviceKey>(
    ep: &mut Endpoint,
    shard: Vec<K>,
    sorter: &LocalSorter,
    cfg: &SihConfig,
) -> anyhow::Result<RankOutcome<K>> {
    if let LocalSorter::External(ctx) = sorter {
        // Out-of-core rank: the fully streamed pipeline (DESIGN.md §14).
        return sihsort_rank_streamed(ep, shard, ctx, cfg);
    }
    let wall0 = Instant::now();
    let p = ep.nranks();
    let is_dev = sorter.is_device();
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };

    // ---- Phase 1: local sort ------------------------------------------------
    let t_phase = ep.now();
    // Measured under the fabric's compute token: wall time reflects this
    // rank's work alone, not host-core oversubscription (fabric docs).
    let ((sorted, sort_res), secs) = ep.measured(move || {
        let mut s = shard;
        let r = sorter.sort(&mut s, &cfg.launch);
        (s, r)
    });
    sort_res?;
    charge(ep, secs);
    ep.barrier();
    let sim_local_sort = ep.now() - t_phase;

    // ---- Phase 2+3: sampling + interpolated-histogram refinement -----------
    let t_phase = ep.now();
    let (splitters, rounds_used) = select_splitters(ep, &sorted, cfg, is_dev)?;
    let sim_splitters = ep.now() - t_phase;

    // ---- Phase 4+5: partition + single alltoallv ----------------------------
    let t_phase = ep.now();
    let (parts, secs) = ep.measured(|| {
        let cuts = partition_points(&sorted, &splitters);
        buckets(&sorted, &cuts).into_iter().map(|b| b.to_vec()).collect::<Vec<Vec<K>>>()
    });
    debug_assert_eq!(parts.len(), p);
    charge(ep, secs);
    let received = ep.alltoallv(parts);
    drop(sorted);
    let sim_exchange = ep.now() - t_phase;

    // ---- Phase 6: final combine ---------------------------------------------
    let t_phase = ep.now();
    let (data, secs) = ep.measured(|| -> anyhow::Result<Vec<K>> {
        match cfg.final_phase {
            FinalPhase::Merge => {
                // Received runs are each sorted: merge-path partitioned
                // k-way merge (DESIGN.md §11) over the full host pool.
                // Safe to fan out here: this closure runs under the
                // fabric's compute token (one rank's measured section at
                // a time), so the workers never contend with other rank
                // threads and the measured seconds model a rank owning
                // its node's cores.
                let refs: Vec<&[K]> = received.iter().map(|r| r.as_slice()).collect();
                let total: usize = refs.iter().map(|r| r.len()).sum();
                Ok(merge_path::kmerge_parallel_with(
                    &refs,
                    cfg.launch
                        .tasks_for(crate::backend::threaded::default_threads(), total),
                    cfg.launch.par_threshold_or(merge_path::PAR_MERGE_MIN),
                ))
            }
            FinalPhase::Sort => {
                // The paper's described variant: concatenate + full re-sort.
                let mut all: Vec<K> = received.iter().flatten().copied().collect();
                sorter.sort(&mut all, &cfg.launch)?;
                Ok(all)
            }
        }
    });
    let data = data?;
    charge(ep, secs);
    ep.barrier();
    let sim_final = ep.now() - t_phase;

    Ok(RankOutcome {
        data,
        sim_local_sort,
        sim_splitters,
        sim_exchange,
        sim_final,
        wall_secs: wall0.elapsed().as_secs_f64(),
        rounds_used,
        stream: None,
    })
}

/// The streamed SIHSort rank: same collective schedule as
/// [`sihsort_rank`], but the shard never sits sorted in memory — it is
/// external-sorted into a spilled run, re-read chunk by chunk for
/// splitter work, exchanged chunk-at-a-time, and the received runs are
/// k-way merged into the output (pre-merged in fan-in groups when the
/// rank count exceeds the budget's merge fan-in). Engine state is
/// bounded by the [`StreamCtx`]'s budget throughout; only the input
/// shard (owned by the driver), the output shard (the result), and the
/// in-flight exchange chunks in the fabric's channels (the network
/// stand-in — see `exchange`) live outside it.
fn sihsort_rank_streamed<K: DeviceKey>(
    ep: &mut Endpoint,
    shard: Vec<K>,
    ctx: &StreamCtx,
    cfg: &SihConfig,
) -> anyhow::Result<RankOutcome<K>> {
    let wall0 = Instant::now();
    // External ranks are CPU-class (`LocalSorter::is_device`).
    let is_dev = false;
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };
    let io_chunk = ctx.plan::<K>().io_chunk_elems;

    // ---- Phase 1: budget-bounded rank-local external sort -------------
    let t_phase = ep.now();
    let mut local_store = ctx.store();
    let (sorted_res, secs) = {
        let store = &mut local_store;
        ep.measured(move || -> anyhow::Result<(SpillRun<K>, ExternalSortStats)> {
            let mut src = SliceSource::new(&shard);
            let mut sink = RunSink::new(store)?;
            let stats = ctx.external_sort(&mut src, &mut sink, Some(&cfg.launch))?;
            Ok((sink.into_run()?, stats))
        })
    };
    let (run, local_stats) = sorted_res?;
    charge(ep, secs);
    ep.barrier();
    let sim_local_sort = ep.now() - t_phase;
    let local_run_bytes = local_store.bytes_spilled();

    // ---- Phase 2+3: splitters over the streamed shard -----------------
    let t_phase = ep.now();
    let local_len = run.elems() as u64;
    let (splitters, rounds_used) = select_splitters_core(
        ep,
        cfg,
        is_dev,
        local_len,
        || {
            let mut src = crate::stream::SpillRunSource::new(&run, io_chunk)?;
            Ok(regular_samples_streamed(&mut src, local_len, cfg.samples_per_rank, io_chunk)?
                .into_iter()
                .map(|x| x.to_bits())
                .collect())
        },
        |cands| local_ranks_streamed(ctx, &run, cands, io_chunk, &cfg.launch),
    )?;
    let sim_splitters = ep.now() - t_phase;

    // ---- Phase 4+5: streamed chunk-at-a-time exchange -----------------
    let t_phase = ep.now();
    let mut xstore = match &cfg.stream {
        Some(s) => s.store(),
        None => ctx.store(),
    };
    let (recv_runs, secs) = streamed_exchange(ep, &run, &splitters, io_chunk, &mut xstore)?;
    // The parked input shard is consumed: free its spill before merging.
    drop(run);
    drop(local_store);
    charge(ep, secs);
    let sim_exchange = ep.now() - t_phase;

    // ---- Phase 6: final k-way merge of the received runs --------------
    let t_phase = ep.now();
    let plan = ctx.plan::<K>();
    let (data_res, secs) = {
        let xstore_ref = &mut xstore;
        ep.measured(move || -> anyhow::Result<Vec<K>> {
            // The rank count can exceed the budget's merge fan-in, and
            // every open cursor owns an io-granule refill buffer — so
            // pre-merge received runs in fan-in-sized groups (the same
            // rule as `external_sort`'s intermediate passes) until one
            // merge fits the budget.
            let mut runs = recv_runs;
            while runs.len() > plan.fan_in {
                let mut merged: Vec<SpillRun<K>> = Vec::new();
                while !runs.is_empty() {
                    let take = plan.fan_in.min(runs.len());
                    let group: Vec<SpillRun<K>> = runs.drain(..take).collect();
                    if group.len() == 1 {
                        merged.extend(group);
                        continue;
                    }
                    merged.push(merge_group_to_store(&group, xstore_ref, &plan)?);
                }
                runs = merged;
            }
            let mut cursors = Vec::with_capacity(runs.len());
            for r in &runs {
                cursors.push(r.cursor(io_chunk)?);
            }
            let mut merge = KmergePull::new(cursors);
            let total: usize = runs.iter().map(SpillRun::elems).sum();
            let mut data = Vec::with_capacity(total);
            let mut chunk: Vec<K> = Vec::with_capacity(io_chunk);
            loop {
                chunk.clear();
                if merge.next_chunk(&mut chunk, io_chunk)? == 0 {
                    break;
                }
                data.extend_from_slice(&chunk);
            }
            Ok(data)
        })
    };
    let data = data_res?;
    let exchange_spilled_bytes = xstore.bytes_spilled();
    drop(xstore);
    charge(ep, secs);
    ep.barrier();
    let sim_final = ep.now() - t_phase;

    Ok(RankOutcome {
        data,
        sim_local_sort,
        sim_splitters,
        sim_exchange,
        sim_final,
        wall_secs: wall0.elapsed().as_secs_f64(),
        rounds_used,
        stream: Some(RankStreamStats {
            local: local_stats,
            local_run_bytes,
            exchange_spilled_bytes,
            budget_bytes: ctx.budget().get(),
        }),
    })
}

/// Collective splitter selection over an in-memory sorted shard;
/// returns P-1 splitters in bit-image space and the number of
/// refinement rounds used.
fn select_splitters<K: SortKey>(
    ep: &mut Endpoint,
    sorted: &[K],
    cfg: &SihConfig,
    is_dev: bool,
) -> anyhow::Result<(Vec<u128>, usize)> {
    select_splitters_core(
        ep,
        cfg,
        is_dev,
        sorted.len() as u64,
        || {
            Ok(regular_samples(sorted, cfg.samples_per_rank)
                .into_iter()
                .map(|x| x.to_bits())
                .collect())
        },
        |cands| Ok(local_ranks(sorted, cands)),
    )
}

/// The collective splitter-selection schedule, generic over how a rank
/// measures itself: `sample` draws this rank's regular samples (bit
/// images) and `ranks_of` the local ranks of candidate splitters. The
/// in-memory path indexes its sorted slice; the streamed path re-reads
/// its spilled run ([`sihsort_rank_streamed`]). Both measurements run
/// under the fabric's compute token.
fn select_splitters_core<S, R>(
    ep: &mut Endpoint,
    cfg: &SihConfig,
    is_dev: bool,
    local_len: u64,
    mut sample: S,
    mut ranks_of: R,
) -> anyhow::Result<(Vec<u128>, usize)>
where
    S: FnMut() -> anyhow::Result<Vec<u128>>,
    R: FnMut(&[u128]) -> anyhow::Result<Vec<u64>>,
{
    let p = ep.nranks();
    if p == 1 {
        return Ok((Vec::new(), 0));
    }
    let charge = |ep: &Endpoint, measured: f64| {
        ep.advance(cfg.devmodel.compute_time(measured, is_dev));
    };

    // Sampling: gather p regular samples (as bit images) at the leader.
    let (samples, secs) = ep.measured(&mut sample);
    let samples = samples?;
    charge(ep, secs);
    let sample_bytes = u128s_to_bytes(&samples);
    let gathered = ep.gather_bytes(LEADER, sample_bytes);

    // Global element count rides an allreduce (one u64).
    let total = ep.allreduce_u64(local_len, crate::comm::collectives::ReduceOp::Sum);

    let mut leader_state: Option<RefineState> = if ep.rank() == LEADER {
        let pooled: Vec<u128> =
            gathered.unwrap().iter().flat_map(|b| bytes_to_u128s(b)).collect();
        let candidates = initial_candidates(pooled, p);
        let brackets = initial_brackets(&candidates, total);
        Some(RefineState { candidates, brackets })
    } else {
        None
    };

    // Refinement rounds (lockstep on every rank).
    let mut done_next = false;
    let mut rounds_used = 0usize;
    for round in 0..=cfg.refine_rounds {
        let is_last = round == cfg.refine_rounds || done_next;
        // Leader broadcasts candidates (+ done flag hidden at the tail).
        let payload = if ep.rank() == LEADER {
            pack_candidates(&leader_state.as_ref().unwrap().candidates, is_last)
        } else {
            Vec::new()
        };
        let (candidates, done) = unpack_candidates(&ep.bcast_bytes(LEADER, payload));
        if done {
            return Ok((candidates, rounds_used));
        }
        rounds_used = round + 1;

        // Every rank measures its local candidate ranks.
        let (lranks, secs) = ep.measured(|| ranks_of(&candidates));
        let lranks = lranks?;
        charge(ep, secs);
        let gathered = ep.gather_bytes(LEADER, u64s_to_bytes(&lranks));

        if ep.rank() == LEADER {
            let per_rank: Vec<Vec<u64>> =
                gathered.unwrap().iter().map(|b| bytes_to_u64s(b)).collect();
            let mut global = vec![0u64; candidates.len()];
            for pr in &per_rank {
                for (g, v) in global.iter_mut().zip(pr.iter()) {
                    *g += v;
                }
            }
            let state = leader_state.as_mut().unwrap();
            // Measurements correspond to the *broadcast* candidates.
            state.candidates = candidates;
            let (next, worst) = refine(state, &global, total, p, cfg.balance_tol);
            if worst <= cfg.balance_tol {
                // Measured candidates are balanced: finalise them next round.
                done_next = true;
            } else {
                *state = next;
            }
        }
        // Non-leaders learn about termination from the next bcast's flag.
    }
    unreachable!("refinement loop always terminates via the done broadcast")
}

// -- byte helpers (wire format for counters/samples) -------------------------

pub(super) fn u128s_to_bytes(xs: &[u128]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub(super) fn bytes_to_u128s(b: &[u8]) -> Vec<u128> {
    assert_eq!(b.len() % 16, 0);
    b.chunks_exact(16)
        .map(|c| {
            let mut a = [0u8; 16];
            a.copy_from_slice(c);
            u128::from_le_bytes(a)
        })
        .collect()
}

pub(super) fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub(super) fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    assert_eq!(b.len() % 8, 0);
    b.chunks_exact(8)
        .map(|c| {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            u64::from_le_bytes(a)
        })
        .collect()
}

/// Input/output conservation checksum: (count, wrapping sum of bit
/// images). Equal checksums + equal counts make "output is a permutation
/// of input" overwhelmingly likely; tests on small inputs compare
/// multisets exactly.
pub fn checksum<K: SortKey>(xs: &[K]) -> (u64, u128) {
    (xs.len() as u64, xs.iter().fold(0u128, |a, x| a.wrapping_add(x.to_bits())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_helpers_roundtrip() {
        let a = vec![0u128, 1, u128::MAX];
        assert_eq!(bytes_to_u128s(&u128s_to_bytes(&a)), a);
        let b = vec![0u64, 42, u64::MAX];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&b)), b);
    }

    #[test]
    fn checksum_permutation_invariant() {
        let xs = vec![3i32, -1, 7, 3];
        let ys = vec![7i32, 3, 3, -1];
        assert_eq!(checksum(&xs), checksum(&ys));
        let zs = vec![7i32, 3, 3, -2];
        assert_ne!(checksum(&xs), checksum(&zs));
    }
}
