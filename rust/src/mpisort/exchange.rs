//! Partition + data exchange (SIHSort steps 4–5).
//!
//! Partitioning a *sorted* shard at the splitters is P-1 binary searches
//! (zero element copies — we slice). The exchange is exactly one
//! `alltoallv`: bucket j of every rank lands on rank j.
//!
//! The streamed variant ([`streamed_exchange`]) keeps the same
//! semantics for shards parked in a [`SpillRun`]: the run streams
//! through in I/O-granule chunks, each chunk partitions at the
//! splitters (still binary searches — chunks of a sorted run are
//! sorted), and every non-empty sub-bucket ships immediately as one
//! codec-encoded message. Receivers append each source's chunks to a
//! spilled run in arrival order, so what lands is again P sorted runs —
//! ready for the final k-way merge. The rank's own *engine* state stays
//! a few I/O granules (one partition chunk + one decode buffer); bytes
//! in flight ride the fabric's unbounded channels, which stand in for
//! the network exactly as they do for `alltoallv`'s whole-bucket
//! messages — credit-based flow control for a bounded-transport port is
//! future work (DESIGN.md §14).

use std::time::Instant;

use crate::comm::Endpoint;
use crate::dtype::SortKey;
use crate::stream::codec;
use crate::stream::{ChunkSource, SpillRun, SpillRunSource, SpillStore};
use crate::util::failpoint;

/// Cut points of a sorted shard at the splitters (bit image): bucket `j`
/// is `sorted[cuts[j]..cuts[j+1]]` with implicit cuts[0]=0,
/// cuts[P-1]=len. Elements equal to splitter j go to bucket j (<=, i.e.
/// `searchsortedlast` semantics, matching `splitters::local_ranks`).
pub fn partition_points<K: SortKey>(sorted: &[K], splitters_bits: &[u128]) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(splitters_bits.len());
    let mut floor = 0usize;
    for &s in splitters_bits {
        // Running max guards against (already-prevented) non-monotone
        // splitters ever producing invalid slice bounds.
        floor = floor.max(sorted.partition_point(|x| x.to_bits() <= s));
        cuts.push(floor);
    }
    cuts
}

/// Split a sorted shard into P bucket slices by the cut points.
pub fn buckets<'a, K: SortKey>(sorted: &'a [K], cuts: &[usize]) -> Vec<&'a [K]> {
    let p = cuts.len() + 1;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0usize;
    for &c in cuts {
        out.push(&sorted[lo..c]);
        lo = c;
    }
    out.push(&sorted[lo..]);
    out
}

/// Streamed chunk-at-a-time alltoallv of a sorted [`SpillRun`] (see the
/// module docs). Collective: every rank calls this at the same point.
/// Received bucket `j` of every source rank lands on rank `j` as one
/// spilled sorted run per source, written into `store`. Returns the
/// per-source runs (indexed by source rank) and the host seconds this
/// rank spent on partition/codec compute — the caller charges those to
/// the simulated clock (transfer time is charged by the fabric itself).
/// The compute is timed with a plain clock rather than the fabric's
/// compute token: the token must not be held across sends/recvs, and
/// the per-chunk work here is I/O-dominated either way.
pub fn streamed_exchange<K: SortKey>(
    ep: &mut Endpoint,
    run: &SpillRun<K>,
    splitters_bits: &[u128],
    io_chunk: usize,
    store: &mut SpillStore,
) -> anyhow::Result<(Vec<SpillRun<K>>, f64)> {
    let p = ep.nranks();
    debug_assert_eq!(splitters_bits.len() + 1, p, "P-1 splitters for P ranks");
    let tag = ep.collective_tag();
    let io_chunk = io_chunk.max(1);
    let mut compute = 0.0f64;

    // Send side: stream the run, partition each chunk, ship sub-buckets.
    let mut src = SpillRunSource::new(run, io_chunk)?;
    let mut buf: Vec<K> = Vec::with_capacity(io_chunk);
    let mut payloads: Vec<Vec<u8>> = Vec::new();
    loop {
        let t0 = Instant::now();
        if src.next_chunk(&mut buf, io_chunk)? == 0 {
            break;
        }
        let cuts = partition_points(&buf, splitters_bits);
        payloads.clear();
        for b in buckets(&buf, &cuts) {
            let mut raw = Vec::new();
            if !b.is_empty() {
                codec::encode_into(b, &mut raw);
            }
            payloads.push(raw);
        }
        compute += t0.elapsed().as_secs_f64();
        for (dst, raw) in payloads.drain(..).enumerate() {
            // Data chunks are never empty, so empty unambiguously means
            // end-of-stream below.
            if !raw.is_empty() {
                ep.send_bytes(dst, tag, raw);
            }
        }
    }
    // End-of-stream marker per destination. All sends complete before
    // any receive (the fabric's channels are unbounded), so the
    // collective cannot deadlock.
    for dst in 0..p {
        ep.send_bytes(dst, tag, Vec::new());
    }
    // Mid-exchange kill site, placed at the one point where dying is
    // deadlock-free by construction: every send (including the end
    // markers) is already queued, no receive has started, and the fail
    // point trips on every rank — in-flight bytes drop with the
    // channels and a resume replays the whole collective.
    failpoint::check("sih.exchange.sent")?;

    // Receive side: append each source's chunks (in order — per-source
    // FIFO) to one spilled run; chunks of a sorted stream concatenate
    // to a sorted run.
    let mut runs: Vec<SpillRun<K>> = Vec::with_capacity(p);
    let mut decode: Vec<K> = Vec::new();
    for src in 0..p {
        let mut w = store.run_writer::<K>()?;
        loop {
            let bytes = ep.recv_bytes(src, tag);
            if bytes.is_empty() {
                break;
            }
            let t0 = Instant::now();
            decode.clear();
            codec::decode_into(&bytes, &mut decode)?;
            w.push_chunk(&decode)?;
            compute += t0.elapsed().as_secs_f64();
        }
        runs.push(w.finish()?);
    }
    Ok((runs, compute))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn buckets_cover_and_order() {
        let mut xs: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 5000);
        xs.sort_unstable();
        let splitters: Vec<u128> =
            vec![(-500_000i32).to_bits(), 0i32.to_bits(), 500_000i32.to_bits()];
        let cuts = partition_points(&xs, &splitters);
        let bs = buckets(&xs, &cuts);
        assert_eq!(bs.len(), 4);
        assert_eq!(bs.iter().map(|b| b.len()).sum::<usize>(), xs.len());
        // Every element in bucket j is <= splitter j; > splitter j-1.
        for (j, b) in bs.iter().enumerate() {
            for x in *b {
                if j < splitters.len() {
                    assert!(x.to_bits() <= splitters[j]);
                }
                if j > 0 {
                    assert!(x.to_bits() > splitters[j - 1]);
                }
            }
        }
    }

    #[test]
    fn duplicates_at_splitter_go_left() {
        let xs = vec![1i32, 2, 2, 2, 3];
        let cuts = partition_points(&xs, &[2i32.to_bits()]);
        assert_eq!(cuts, vec![4]); // all 2s included left
    }

    #[test]
    fn empty_shard() {
        let xs: Vec<i64> = vec![];
        let cuts = partition_points(&xs, &[0i64.to_bits()]);
        assert_eq!(cuts, vec![0]);
        let bs = buckets(&xs, &cuts);
        assert!(bs.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn streamed_exchange_matches_in_memory_partition() {
        use crate::cfg::TransferMode;
        use crate::cluster::ClusterSpec;
        use crate::comm::Fabric;
        use crate::dtype::bits_eq;
        use crate::stream::{SpillMedium, SpillStore};

        let p = 3usize;
        let shards: Vec<Vec<i32>> = (0..p)
            .map(|r| {
                let mut v: Vec<i32> =
                    generate(&mut Prng::new(r as u64 + 1), Distribution::Uniform, 4000);
                v.sort_unstable();
                v
            })
            .collect();
        let splitters: Vec<u128> = vec![(-400_000i32).to_bits(), 300_000i32.to_bits()];

        let eps = Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![false; p]);
        let results: Vec<Vec<Vec<i32>>> = std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .zip(shards.clone())
                .map(|(mut ep, shard)| {
                    let splitters = splitters.clone();
                    s.spawn(move || {
                        // Tiny io granule: many chunk messages per peer.
                        let mut store = SpillStore::new(SpillMedium::Memory, None);
                        let run = store.write_run(&shard).unwrap();
                        let (runs, secs) =
                            streamed_exchange(&mut ep, &run, &splitters, 256, &mut store)
                                .unwrap();
                        assert!(secs >= 0.0);
                        (
                            ep.rank(),
                            runs.iter()
                                .map(|r| {
                                    let mut c = r.cursor(64).unwrap();
                                    let mut out = Vec::new();
                                    while let Some(k) = c.head() {
                                        out.push(k);
                                        c.advance().unwrap();
                                    }
                                    out
                                })
                                .collect::<Vec<Vec<i32>>>(),
                        )
                    })
                })
                .collect();
            let mut res = vec![Vec::new(); p];
            for h in handles {
                let (rank, runs) = h.join().unwrap();
                res[rank] = runs;
            }
            res
        });

        // Rank d's run from source s must be exactly source s's bucket d.
        for (d, per_source) in results.iter().enumerate() {
            assert_eq!(per_source.len(), p);
            for (src, got) in per_source.iter().enumerate() {
                let cuts = partition_points(&shards[src], &splitters);
                let want = buckets(&shards[src], &cuts)[d].to_vec();
                assert!(bits_eq(got, &want), "dst {d} src {src}");
                assert!(crate::dtype::is_sorted_total(got));
            }
        }
    }
}
