//! Partition + data exchange (SIHSort steps 4–5).
//!
//! Partitioning a *sorted* shard at the splitters is P-1 binary searches
//! (zero element copies — we slice). The exchange is exactly one
//! `alltoallv`: bucket j of every rank lands on rank j.

use crate::dtype::SortKey;

/// Cut points of a sorted shard at the splitters (bit image): bucket `j`
/// is `sorted[cuts[j]..cuts[j+1]]` with implicit cuts[0]=0,
/// cuts[P-1]=len. Elements equal to splitter j go to bucket j (<=, i.e.
/// `searchsortedlast` semantics, matching `splitters::local_ranks`).
pub fn partition_points<K: SortKey>(sorted: &[K], splitters_bits: &[u128]) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(splitters_bits.len());
    let mut floor = 0usize;
    for &s in splitters_bits {
        // Running max guards against (already-prevented) non-monotone
        // splitters ever producing invalid slice bounds.
        floor = floor.max(sorted.partition_point(|x| x.to_bits() <= s));
        cuts.push(floor);
    }
    cuts
}

/// Split a sorted shard into P bucket slices by the cut points.
pub fn buckets<'a, K: SortKey>(sorted: &'a [K], cuts: &[usize]) -> Vec<&'a [K]> {
    let p = cuts.len() + 1;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0usize;
    for &c in cuts {
        out.push(&sorted[lo..c]);
        lo = c;
    }
    out.push(&sorted[lo..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn buckets_cover_and_order() {
        let mut xs: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 5000);
        xs.sort_unstable();
        let splitters: Vec<u128> =
            vec![(-500_000i32).to_bits(), 0i32.to_bits(), 500_000i32.to_bits()];
        let cuts = partition_points(&xs, &splitters);
        let bs = buckets(&xs, &cuts);
        assert_eq!(bs.len(), 4);
        assert_eq!(bs.iter().map(|b| b.len()).sum::<usize>(), xs.len());
        // Every element in bucket j is <= splitter j; > splitter j-1.
        for (j, b) in bs.iter().enumerate() {
            for x in *b {
                if j < splitters.len() {
                    assert!(x.to_bits() <= splitters[j]);
                }
                if j > 0 {
                    assert!(x.to_bits() > splitters[j - 1]);
                }
            }
        }
    }

    #[test]
    fn duplicates_at_splitter_go_left() {
        let xs = vec![1i32, 2, 2, 2, 3];
        let cuts = partition_points(&xs, &[2i32.to_bits()]);
        assert_eq!(cuts, vec![4]); // all 2s included left
    }

    #[test]
    fn empty_shard() {
        let xs: Vec<i64> = vec![];
        let cuts = partition_points(&xs, &[0i64.to_bits()]);
        assert_eq!(cuts, vec![0]);
        let bs = buckets(&xs, &cuts);
        assert!(bs.iter().all(|b| b.is_empty()));
    }
}
