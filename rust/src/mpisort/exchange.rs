//! Partition + data exchange (SIHSort steps 4–5).
//!
//! Partitioning a *sorted* shard at the splitters is P-1 binary searches
//! (zero element copies — we slice). The exchange is exactly one
//! `alltoallv`: bucket j of every rank lands on rank j.
//!
//! The streamed variant ([`streamed_exchange`]) keeps the same
//! semantics for shards parked in a [`SpillRun`]: the run streams
//! through in I/O-granule chunks, each chunk partitions at the
//! splitters (still binary searches — chunks of a sorted run are
//! sorted), and every non-empty sub-bucket ships immediately as one
//! codec-encoded message. Receivers append each source's chunks to a
//! spilled run in arrival order, so what lands is again P sorted runs —
//! ready for the final k-way merge.
//!
//! Since PR 7 the fabric is credit-bounded (DESIGN.md §16), so the
//! exchange runs an **interleaved progress loop**: each iteration tries
//! to admit queued sends ([`crate::comm::TrySend::Full`] means the
//! link's credit is exhausted), drains every arrived message into
//! per-source [`DetachedRunWriter`]s (consumption is what returns
//! credit to the senders), and parks on fabric activity when neither
//! direction can move. Send-side state stays bounded at ≤ P messages of
//! about one I/O granule each; receive-side state is bounded by the
//! inbound credit caps. Transient link faults are retried here with the
//! fabric's bounded-backoff policy; a dead rank or a global progress
//! deadline surfaces as a typed error.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::comm::{Endpoint, TrySend};
use crate::stream::StreamRecord;
use crate::obs;
use crate::session::AkError;
use crate::stream::codec;
use crate::stream::spill::DetachedRunWriter;
use crate::stream::{ChunkSource, SpillRun, SpillRunSource, SpillStore};
use crate::util::failpoint;

/// Cut points of a sorted shard at the splitters (bit image): bucket `j`
/// is `sorted[cuts[j]..cuts[j+1]]` with implicit cuts[0]=0,
/// cuts[P-1]=len. Elements equal to splitter j go to bucket j (<=, i.e.
/// `searchsortedlast` semantics, matching `splitters::local_ranks`).
pub fn partition_points<K: StreamRecord>(sorted: &[K], splitters_bits: &[u128]) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(splitters_bits.len());
    let mut floor = 0usize;
    for &s in splitters_bits {
        // Running max guards against (already-prevented) non-monotone
        // splitters ever producing invalid slice bounds.
        floor = floor.max(sorted.partition_point(|x| x.key_bits() <= s));
        cuts.push(floor);
    }
    cuts
}

/// Split a sorted shard into P bucket slices by the cut points.
pub fn buckets<'a, K>(sorted: &'a [K], cuts: &[usize]) -> Vec<&'a [K]> {
    let p = cuts.len() + 1;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0usize;
    for &c in cuts {
        out.push(&sorted[lo..c]);
        lo = c;
    }
    out.push(&sorted[lo..]);
    out
}

/// Streamed chunk-at-a-time alltoallv of a sorted [`SpillRun`] (see the
/// module docs). Collective: every rank calls this at the same point.
/// Received bucket `j` of every source rank lands on rank `j` as one
/// spilled sorted run per source, written into `store`. Returns the
/// per-source runs (indexed by source rank) and the host seconds this
/// rank spent on partition/codec compute — the caller charges those to
/// the simulated clock (transfer time is charged by the fabric itself).
/// The compute is timed with a plain clock rather than the fabric's
/// compute token: the token must not be held across sends/recvs, and
/// the per-chunk work here is I/O-dominated either way.
pub fn streamed_exchange<K: StreamRecord>(
    ep: &mut Endpoint,
    run: &SpillRun<K>,
    splitters_bits: &[u128],
    io_chunk: usize,
    store: &mut SpillStore,
) -> anyhow::Result<(Vec<SpillRun<K>>, f64)> {
    let p = ep.nranks();
    debug_assert_eq!(splitters_bits.len() + 1, p, "P-1 splitters for P ranks");
    let tag = ep.collective_tag();
    let io_chunk = io_chunk.max(1);
    let mut compute = 0.0f64;
    let policy = ep.retry_policy();

    // Send side: stream the run, partition each chunk, queue sub-bucket
    // messages. The out-queue holds at most one chunk's worth (≤ P
    // messages of about one I/O granule) — refilled only when drained,
    // so send-side state is bounded no matter how slow the links are.
    let mut src = SpillRunSource::new(run, io_chunk)?;
    let mut buf: Vec<K> = Vec::with_capacity(io_chunk);
    let mut outq: VecDeque<(usize, Vec<u8>)> = VecDeque::new();
    let mut markers_queued = false;
    let mut front_attempts = 1u32;
    let mut front_was_full = false;

    // Receive side: one detached writer per source (they interleave in
    // arrival order under flow control; per-link FIFO keeps each
    // source's run sorted). Consuming arrivals promptly is what returns
    // credit to the senders — that is the loop's liveness argument.
    let mut writers: Vec<DetachedRunWriter<K>> = Vec::with_capacity(p);
    for _ in 0..p {
        writers.push(store.detached_run_writer::<K>()?);
    }
    let mut open = p; // sources whose end-of-stream marker is pending
    let mut decode: Vec<K> = Vec::new();

    // Global progress deadline: reset on any progress in either
    // direction; hitting it means the exchange is wedged (typed error,
    // not a hang).
    let progress_timeout = ep.recv_timeout();
    let mut last_progress = Instant::now();

    while open > 0 || !(markers_queued && outq.is_empty()) {
        let mut progressed = false;

        // 1. Refill the out-queue from the next chunk of the run.
        if outq.is_empty() && !markers_queued {
            let t0 = Instant::now();
            if src.next_chunk(&mut buf, io_chunk)? == 0 {
                // Data chunks are never empty, so an empty message
                // unambiguously means end-of-stream.
                for dst in 0..p {
                    outq.push_back((dst, Vec::new()));
                }
                markers_queued = true;
            } else {
                let _span =
                    obs::span1(obs::SpanKind::ExchangeChunk, "exchange.chunk", buf.len() as u64);
                let cuts = partition_points(&buf, splitters_bits);
                for (dst, b) in buckets(&buf, &cuts).into_iter().enumerate() {
                    if !b.is_empty() {
                        let mut raw = Vec::new();
                        codec::encode_into(b, &mut raw);
                        outq.push_back((dst, raw));
                    }
                }
            }
            compute += t0.elapsed().as_secs_f64();
            progressed = true;
        }

        // 2. Admit queued sends; a faulted link retries with the
        // fabric's bounded backoff (deterministic jitter, sim-clock
        // wait); exhausted credit pauses sending until credit returns.
        while let Some((dst, raw)) = outq.front() {
            let dst = *dst;
            match ep.try_send_bytes(dst, tag, raw) {
                Ok(TrySend::Sent) => {
                    if front_was_full {
                        // The stall is honest in simulated time too.
                        ep.sync_link_release(dst);
                    }
                    outq.pop_front();
                    front_attempts = 1;
                    front_was_full = false;
                    progressed = true;
                }
                Ok(TrySend::Full) => {
                    if !front_was_full {
                        front_was_full = true;
                        ep.stats().credit_stalls.fetch_add(1, Ordering::Relaxed);
                    }
                    break;
                }
                Err(AkError::CommTimeout { .. }) if front_attempts < policy.max_attempts => {
                    let wait = policy.backoff_secs(ep.rank(), dst, tag, front_attempts);
                    ep.advance(wait);
                    ep.stats().retries.fetch_add(1, Ordering::Relaxed);
                    front_attempts += 1;
                    progressed = true; // bounded: max_attempts then error
                }
                Err(e) => return Err(e.into()),
            }
        }

        // 3. Drain every arrival into its source's writer.
        while let Some((from, bytes)) = ep.try_recv_any(tag)? {
            progressed = true;
            if bytes.is_empty() {
                open -= 1;
                continue;
            }
            let t0 = Instant::now();
            decode.clear();
            codec::decode_into(&bytes, &mut decode)?;
            writers[from].push_chunk(&decode)?;
            compute += t0.elapsed().as_secs_f64();
        }

        // 4. Park when stuck (waking on arrival/credit/abort).
        if progressed {
            last_progress = Instant::now();
        } else {
            if last_progress.elapsed() >= progress_timeout {
                let detail = format!(
                    "exchange wedged: {open} sources still open, {} messages queued",
                    outq.len()
                );
                return Err(ep.deadline_exceeded("exchange", progress_timeout, detail).into());
            }
            ep.wait_activity(Duration::from_millis(2))?;
        }
    }

    // Mid-exchange kill site, placed where dying is deadlock-free by
    // construction: the transport is fully drained on this rank (all
    // sends delivered, all end markers consumed) and the fail point
    // trips on every rank — a resume replays the whole collective.
    failpoint::check("sih.exchange.sent")?;

    let mut runs: Vec<SpillRun<K>> = Vec::with_capacity(p);
    for w in writers {
        runs.push(w.finish(store)?);
    }
    Ok((runs, compute))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::SortKey;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn buckets_cover_and_order() {
        let mut xs: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 5000);
        xs.sort_unstable();
        let splitters: Vec<u128> =
            vec![(-500_000i32).to_bits(), 0i32.to_bits(), 500_000i32.to_bits()];
        let cuts = partition_points(&xs, &splitters);
        let bs = buckets(&xs, &cuts);
        assert_eq!(bs.len(), 4);
        assert_eq!(bs.iter().map(|b| b.len()).sum::<usize>(), xs.len());
        // Every element in bucket j is <= splitter j; > splitter j-1.
        for (j, b) in bs.iter().enumerate() {
            for x in *b {
                if j < splitters.len() {
                    assert!(x.to_bits() <= splitters[j]);
                }
                if j > 0 {
                    assert!(x.to_bits() > splitters[j - 1]);
                }
            }
        }
    }

    #[test]
    fn duplicates_at_splitter_go_left() {
        let xs = vec![1i32, 2, 2, 2, 3];
        let cuts = partition_points(&xs, &[2i32.to_bits()]);
        assert_eq!(cuts, vec![4]); // all 2s included left
    }

    #[test]
    fn empty_shard() {
        let xs: Vec<i64> = vec![];
        let cuts = partition_points(&xs, &[0i64.to_bits()]);
        assert_eq!(cuts, vec![0]);
        let bs = buckets(&xs, &cuts);
        assert!(bs.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn streamed_exchange_matches_in_memory_partition() {
        use crate::cfg::TransferMode;
        use crate::cluster::ClusterSpec;
        use crate::comm::Fabric;
        use crate::dtype::bits_eq;
        use crate::stream::{SpillMedium, SpillStore};

        let p = 3usize;
        let shards: Vec<Vec<i32>> = (0..p)
            .map(|r| {
                let mut v: Vec<i32> =
                    generate(&mut Prng::new(r as u64 + 1), Distribution::Uniform, 4000);
                v.sort_unstable();
                v
            })
            .collect();
        let splitters: Vec<u128> = vec![(-400_000i32).to_bits(), 300_000i32.to_bits()];

        let eps = Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![false; p]);
        let results: Vec<Vec<Vec<i32>>> = std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .zip(shards.clone())
                .map(|(mut ep, shard)| {
                    let splitters = splitters.clone();
                    s.spawn(move || {
                        // Tiny io granule: many chunk messages per peer.
                        let mut store = SpillStore::new(SpillMedium::Memory, None);
                        let run = store.write_run(&shard).unwrap();
                        let (runs, secs) =
                            streamed_exchange(&mut ep, &run, &splitters, 256, &mut store)
                                .unwrap();
                        assert!(secs >= 0.0);
                        (
                            ep.rank(),
                            runs.iter()
                                .map(|r| {
                                    let mut c = r.cursor(64).unwrap();
                                    let mut out = Vec::new();
                                    while let Some(k) = c.head() {
                                        out.push(k);
                                        c.advance().unwrap();
                                    }
                                    out
                                })
                                .collect::<Vec<Vec<i32>>>(),
                        )
                    })
                })
                .collect();
            let mut res = vec![Vec::new(); p];
            for h in handles {
                let (rank, runs) = h.join().unwrap();
                res[rank] = runs;
            }
            res
        });

        // Rank d's run from source s must be exactly source s's bucket d.
        for (d, per_source) in results.iter().enumerate() {
            assert_eq!(per_source.len(), p);
            for (src, got) in per_source.iter().enumerate() {
                let cuts = partition_points(&shards[src], &splitters);
                let want = buckets(&shards[src], &cuts)[d].to_vec();
                assert!(bits_eq(got, &want), "dst {d} src {src}");
                assert!(crate::dtype::is_sorted_total(got));
            }
        }
    }
}
