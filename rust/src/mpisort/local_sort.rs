//! Pluggable rank-local sorters: the paper's CC-JB / AK / TM / TR legend
//! plus this repo's HY hybrid co-sorter (DESIGN.md §10).
//!
//! * `JuliaBase` — single-thread comparison sort on a CPU rank.
//! * `Ak` — the AcceleratedKernels merge sort: a [`Session`] over the
//!   Pallas/XLA artifact engine (or its host stand-in pre-artifacts).
//! * `ThrustMerge` / `ThrustRadix` — the vendor-primitive analogs
//!   (`baselines`); TR's worker count and parallel gate follow the
//!   run's [`Launch`] knobs.
//! * `Hybrid` — a [`Session`] over the hybrid engine: the rank's host
//!   thread pool and its device engine sort disjoint sub-shards
//!   concurrently and merge (`crate::hybrid::co_sort`).
//! * `External` — the out-of-core engine: a [`StreamCtx`] (session +
//!   [`crate::stream::StreamBudget`] + spill medium) whose
//!   `external_sort` lets the rank sort a shard larger than its memory
//!   budget; `sihsort_rank` routes such ranks through the fully
//!   streamed pipeline (DESIGN.md §14).
//!
//! Each sorter measures its own wall time; the caller converts it to
//! simulated device time through `cluster::DeviceModel`.

use std::time::Instant;

use crate::backend::{Backend, DeviceKey};
use crate::baselines;
use crate::cfg::Sorter;
use crate::hybrid::HybridEngine;
use crate::session::{Launch, Session};
use crate::stream::{SliceSource, StreamCtx, VecSink};

/// A rank's local sorting engine.
#[derive(Clone)]
pub enum LocalSorter {
    /// Single-thread comparison sort ("CC-JB").
    JuliaBase,
    /// AcceleratedKernels merge sort over a session ("AK").
    Ak(Session),
    /// Vendor merge-sort analog ("TM").
    ThrustMerge,
    /// Vendor radix-sort analog ("TR").
    ThrustRadix,
    /// Hybrid CPU–GPU co-sort session ("HY", DESIGN.md §10).
    Hybrid(Session),
    /// Out-of-core external sorter ("EX", DESIGN.md §14): the rank's
    /// shard streams through `StreamCtx::external_sort` under the
    /// context's memory budget instead of sorting in place.
    External(StreamCtx),
}

impl LocalSorter {
    /// Build from config; `Ak` needs the device backend handle, `Hybrid`
    /// a prepared engine (the driver calibrates it once per run),
    /// `External` a prepared streaming context (budget + spill medium,
    /// built from the `[stream]` config by the driver).
    pub fn from_cfg(
        sorter: Sorter,
        device_backend: Option<Backend>,
        hybrid: Option<HybridEngine>,
        stream: Option<StreamCtx>,
    ) -> anyhow::Result<Self> {
        Ok(match sorter {
            Sorter::JuliaBase => LocalSorter::JuliaBase,
            Sorter::Ak => LocalSorter::Ak(Session::from_backend(
                device_backend
                    .ok_or_else(|| anyhow::anyhow!("AK sorter requires the device backend"))?,
            )),
            Sorter::ThrustMerge => LocalSorter::ThrustMerge,
            Sorter::ThrustRadix => LocalSorter::ThrustRadix,
            Sorter::Hybrid => LocalSorter::Hybrid(Session::hybrid(hybrid.ok_or_else(|| {
                anyhow::anyhow!("hybrid sorter requires a prepared HybridEngine")
            })?)),
            Sorter::External => LocalSorter::External(stream.ok_or_else(|| {
                anyhow::anyhow!("external sorter requires a prepared StreamCtx (budget/spill)")
            })?),
        })
    }

    /// Legend code of this engine.
    pub fn code(&self) -> &'static str {
        match self {
            LocalSorter::JuliaBase => "JB",
            LocalSorter::Ak(_) => "AK",
            LocalSorter::ThrustMerge => "TM",
            LocalSorter::ThrustRadix => "TR",
            LocalSorter::Hybrid(_) => "HY",
            LocalSorter::External(_) => "EX",
        }
    }

    /// Runs on a device (GPU-class) rank? Hybrid ranks own a device, so
    /// they are device-class for link selection and the device model;
    /// JB and the out-of-core external sorter are CPU-class.
    pub fn is_device(&self) -> bool {
        !matches!(self, LocalSorter::JuliaBase | LocalSorter::External(_))
    }

    /// Sort in place under the run's [`Launch`] knobs; returns measured
    /// host wall seconds.
    pub fn sort<K: DeviceKey>(&self, xs: &mut [K], launch: &Launch) -> anyhow::Result<f64> {
        let t0 = Instant::now();
        match self {
            LocalSorter::JuliaBase => xs.sort_by(|a, b| a.cmp_total(b)),
            LocalSorter::Ak(session) | LocalSorter::Hybrid(session) => {
                session.sort(xs, Some(launch))?
            }
            LocalSorter::ThrustMerge => baselines::merge_sort(xs),
            // TR dispatches by size: the threaded LSD radix above the
            // parallel gate (DESIGN.md §11), sequential passes below —
            // so calibration and the cost model see the engine that will
            // actually run. Worker count and gate follow the knobs.
            LocalSorter::ThrustRadix => baselines::radix_sort_auto_with(
                xs,
                launch.tasks_for(crate::backend::threaded::default_threads(), xs.len()),
                launch.par_threshold_or(baselines::radix::RADIX_PAR_MIN),
            ),
            // In-place slice entry point for the external engine (the
            // FinalPhase::Sort path and tests). `sihsort_rank` never
            // takes this for its main phase — external ranks run the
            // fully streamed pipeline instead (DESIGN.md §14).
            LocalSorter::External(ctx) => {
                let sorted = {
                    let mut src = SliceSource::new(&xs[..]);
                    let mut sink = VecSink::new();
                    ctx.external_sort(&mut src, &mut sink, Some(launch))?;
                    sink.out
                };
                xs.copy_from_slice(&sorted);
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::hybrid::HybridPlan;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    fn hybrid_sorter(frac: f64) -> LocalSorter {
        LocalSorter::Hybrid(Session::hybrid(HybridEngine::new(HybridPlan::new(frac), 2, None)))
    }

    #[test]
    fn host_sorters_agree() {
        let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 4000);
        let mut want = xs.clone();
        want.sort_unstable();
        for s in [
            LocalSorter::JuliaBase,
            LocalSorter::ThrustMerge,
            LocalSorter::ThrustRadix,
            hybrid_sorter(0.5),
        ] {
            let mut got = xs.clone();
            let secs = s.sort(&mut got, &Launch::default()).unwrap();
            assert!(got == want, "{}", s.code());
            assert!(secs >= 0.0);
        }
    }

    #[test]
    fn i128_works_on_host_sorters() {
        let xs: Vec<i128> = generate(&mut Prng::new(2), Distribution::Uniform, 1000);
        for s in [
            LocalSorter::JuliaBase,
            LocalSorter::ThrustMerge,
            LocalSorter::ThrustRadix,
            hybrid_sorter(0.4),
        ] {
            let mut got = xs.clone();
            s.sort(&mut got, &Launch::default()).unwrap();
            assert!(is_sorted_total(&got));
        }
    }

    #[test]
    fn launch_knobs_reach_tr_and_hy() {
        let xs: Vec<i32> = generate(&mut Prng::new(3), Distribution::Uniform, 80_000);
        let mut want = xs.clone();
        want.sort_unstable();
        let l = Launch::new().max_tasks(2).prefer_parallel_threshold(1024);
        for s in [LocalSorter::ThrustRadix, hybrid_sorter(0.5)] {
            let mut got = xs.clone();
            s.sort(&mut got, &l).unwrap();
            assert_eq!(got, want, "{}", s.code());
        }
    }

    #[test]
    fn ak_requires_backend() {
        assert!(LocalSorter::from_cfg(Sorter::Ak, None, None, None).is_err());
        assert!(LocalSorter::from_cfg(Sorter::JuliaBase, None, None, None).is_ok());
    }

    #[test]
    fn hybrid_requires_engine() {
        assert!(LocalSorter::from_cfg(Sorter::Hybrid, None, None, None).is_err());
        let eng = HybridEngine::new(HybridPlan::new(0.5), 2, None);
        let s = LocalSorter::from_cfg(Sorter::Hybrid, None, Some(eng), None).unwrap();
        assert_eq!(s.code(), "HY");
        assert!(s.is_device());
    }

    #[test]
    fn external_requires_ctx_and_sorts_out_of_core() {
        use crate::stream::StreamBudget;
        assert!(LocalSorter::from_cfg(Sorter::External, None, None, None).is_err());
        // Tiny budget + in-memory spill: the slice path must still be a
        // faithful sort (multiple runs merged back bitwise-correct).
        let ctx = Session::threaded(2)
            .stream(StreamBudget::bytes(64))
            .in_memory_spill()
            .run_chunk_elems(1000);
        let s = LocalSorter::from_cfg(Sorter::External, None, None, Some(ctx)).unwrap();
        assert_eq!(s.code(), "EX");
        assert!(!s.is_device(), "external ranks are CPU-class");
        let xs: Vec<i64> = generate(&mut Prng::new(4), Distribution::DupHeavy, 5000);
        let mut want = xs.clone();
        want.sort_unstable();
        let mut got = xs.clone();
        s.sort(&mut got, &Launch::default()).unwrap();
        assert_eq!(got, want);
    }
}
