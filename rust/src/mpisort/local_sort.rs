//! Pluggable rank-local sorters: the paper's CC-JB / AK / TM / TR legend.
//!
//! * `JuliaBase` — single-thread comparison sort on a CPU rank.
//! * `Ak` — the AcceleratedKernels merge sort: our Pallas/XLA artifact
//!   through PJRT (i128: host merge fallback, DESIGN.md §2).
//! * `ThrustMerge` / `ThrustRadix` — the vendor-primitive analogs
//!   (`baselines`).
//!
//! Each sorter measures its own wall time; the caller converts it to
//! simulated device time through `cluster::DeviceModel`.

use std::time::Instant;

use crate::backend::{Backend, DeviceKey};
use crate::baselines;
use crate::cfg::Sorter;

/// A rank's local sorting engine.
#[derive(Clone)]
pub enum LocalSorter {
    JuliaBase,
    Ak(Backend),
    ThrustMerge,
    ThrustRadix,
}

impl LocalSorter {
    /// Build from config; `Ak` needs the device backend handle.
    pub fn from_cfg(sorter: Sorter, device_backend: Option<Backend>) -> anyhow::Result<Self> {
        Ok(match sorter {
            Sorter::JuliaBase => LocalSorter::JuliaBase,
            Sorter::Ak => LocalSorter::Ak(
                device_backend
                    .ok_or_else(|| anyhow::anyhow!("AK sorter requires the device backend"))?,
            ),
            Sorter::ThrustMerge => LocalSorter::ThrustMerge,
            Sorter::ThrustRadix => LocalSorter::ThrustRadix,
        })
    }

    pub fn code(&self) -> &'static str {
        match self {
            LocalSorter::JuliaBase => "JB",
            LocalSorter::Ak(_) => "AK",
            LocalSorter::ThrustMerge => "TM",
            LocalSorter::ThrustRadix => "TR",
        }
    }

    /// Runs on a device (GPU-class) rank?
    pub fn is_device(&self) -> bool {
        !matches!(self, LocalSorter::JuliaBase)
    }

    /// Sort in place; returns measured host wall seconds.
    pub fn sort<K: DeviceKey>(&self, xs: &mut [K]) -> anyhow::Result<f64> {
        let t0 = Instant::now();
        match self {
            LocalSorter::JuliaBase => xs.sort_by(|a, b| a.cmp_total(b)),
            LocalSorter::Ak(backend) => crate::algorithms::sort(backend, xs)?,
            LocalSorter::ThrustMerge => baselines::merge_sort(xs),
            LocalSorter::ThrustRadix => baselines::radix_sort(xs),
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn host_sorters_agree() {
        let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 4000);
        let mut want = xs.clone();
        want.sort_unstable();
        for s in [LocalSorter::JuliaBase, LocalSorter::ThrustMerge, LocalSorter::ThrustRadix] {
            let mut got = xs.clone();
            let secs = s.sort(&mut got).unwrap();
            assert!(got == want, "{}", s.code());
            assert!(secs >= 0.0);
        }
    }

    #[test]
    fn i128_works_on_host_sorters() {
        let xs: Vec<i128> = generate(&mut Prng::new(2), Distribution::Uniform, 1000);
        for s in [LocalSorter::JuliaBase, LocalSorter::ThrustMerge, LocalSorter::ThrustRadix] {
            let mut got = xs.clone();
            s.sort(&mut got).unwrap();
            assert!(is_sorted_total(&got));
        }
    }

    #[test]
    fn ak_requires_backend() {
        assert!(LocalSorter::from_cfg(Sorter::Ak, None).is_err());
        assert!(LocalSorter::from_cfg(Sorter::JuliaBase, None).is_ok());
    }
}
