//! Unified tracing & metrics (DESIGN.md §18).
//!
//! The observability substrate every layer records into: typed spans
//! and instant events land in lock-free per-thread ring buffers
//! ([`tracer`]), scattered counter families unify into one named
//! snapshot type ([`registry`]), and a finished trace exports as
//! Chrome/Perfetto trace-event JSON or a human phase table
//! ([`export`]).
//!
//! Design rules:
//!
//! * **Near-zero cost when off.** Tracing is armed process-wide by a
//!   [`TraceSession`] (CLI `--trace-out` / `[obs]` config). Every
//!   recording entry point checks one relaxed [`AtomicBool`] first and
//!   returns an inert guard without allocating — the no-allocation
//!   property is enforced by `tests/obs_noalloc.rs`.
//! * **Never blocks the traced thread.** Each thread owns a
//!   fixed-capacity single-writer ring; a full ring drops the newest
//!   event and counts it, it never wraps or waits.
//! * **Panic-safe.** Spans are RAII drop guards, so unwinding balances
//!   every open with a close; the [`TraceSession`] flushes whatever the
//!   rings hold on drop, including mid-panic.
//! * **Diagnostics-ready.** Each ring mirrors its live span stack
//!   behind a mutex so the driver watchdog and deadlock reporter can
//!   read *other* threads' current position ([`live_stacks_table`]).
//!
//! [`AtomicBool`]: std::sync::atomic::AtomicBool

pub mod export;
pub mod registry;
pub mod tracer;

pub use export::{chrome_trace_json, summary_table};
pub use registry::{
    Counter, CounterSnapshot, FABRIC_COUNTERS, SESSION_COUNTERS, STREAM_COUNTERS,
};
pub use tracer::{
    counter, enabled, instant, instant2, live_stacks, live_stacks_table, phase, phase_end,
    set_thread_label, span, span1, SpanGuard, SpanKind, TraceSession,
};
