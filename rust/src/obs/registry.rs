//! The unified counter registry (DESIGN.md §18).
//!
//! Every layer used to carry its own counter struct with its own field
//! names — `SessionMetrics` atomics, `ExternalSortStats`, the fabric's
//! `FaultCounters` — and every consumer (run records, both bench JSON
//! schemas) hand-copied the fields it knew about. Adding a counter
//! silently left stale consumers behind. A [`CounterSnapshot`] is the
//! one interchange type instead: an *ordered* list of named, optionally
//! labelled values that consumers iterate rather than enumerate, so a
//! new counter flows to every record and JSON row by construction.
//!
//! The registered name lists ([`FABRIC_COUNTERS`], [`SESSION_COUNTERS`],
//! [`STREAM_COUNTERS`]) are the schema contract: the producing module's
//! `snapshot()` asserts against its list in tests, and the bench tests
//! assert the emitted JSON rows carry exactly the registered names —
//! no silent additions or omissions in either direction.

/// Fabric flow/fault counter names, in emission order. `recoveries`
/// (in-process restarts) is accounted by the driver, the rest by
/// [`crate::comm::CommStats`].
pub const FABRIC_COUNTERS: [&str; 5] =
    ["credit_stalls", "retries", "timeouts", "dropped", "recoveries"];

/// [`crate::session::SessionMetrics`] counter names, in emission order.
pub const SESSION_COUNTERS: [&str; 5] =
    ["calls", "elems", "scratch_hits", "scratch_misses", "device_fallbacks"];

/// [`crate::stream::ExternalSortStats`] counter names, in emission
/// order (shape counters of one external-sort run).
pub const STREAM_COUNTERS: [&str; 7] = [
    "elems",
    "runs",
    "merge_passes",
    "spilled_bytes",
    "fan_in",
    "run_chunk_elems",
    "resumed_runs",
];

/// One named counter value; `label` distinguishes instances of the same
/// name (a link, a rank, a phase).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Counter {
    /// Registered counter name (one of the `*_COUNTERS` lists).
    pub name: &'static str,
    /// Optional instance label (`"rank 3"`, `"nvlink"`); `None` for the
    /// job-level total.
    pub label: Option<String>,
    /// The sampled value.
    pub value: u64,
}

/// An ordered set of named counters — the snapshot every record and
/// bench row carries instead of hand-copied fields.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    entries: Vec<Counter>,
}

impl CounterSnapshot {
    /// Empty snapshot.
    pub fn new() -> CounterSnapshot {
        CounterSnapshot::default()
    }

    /// A snapshot carrying every name of `names` at zero — the shape a
    /// consumer can rely on before any producer ran.
    pub fn zeroed(names: &[&'static str]) -> CounterSnapshot {
        CounterSnapshot {
            entries: names.iter().map(|n| Counter { name: n, label: None, value: 0 }).collect(),
        }
    }

    /// Append an unlabelled counter.
    pub fn push(&mut self, name: &'static str, value: u64) {
        self.entries.push(Counter { name, label: None, value });
    }

    /// Append a labelled counter instance.
    pub fn push_labelled(&mut self, name: &'static str, label: &str, value: u64) {
        self.entries.push(Counter { name, label: Some(label.to_string()), value });
    }

    /// Sum of every entry named `name` (0 when absent).
    pub fn get(&self, name: &str) -> u64 {
        self.entries.iter().filter(|c| c.name == name).map(|c| c.value).sum()
    }

    /// Set the unlabelled entry `name`, appending it if absent.
    pub fn set(&mut self, name: &'static str, value: u64) {
        match self.entries.iter_mut().find(|c| c.name == name && c.label.is_none()) {
            Some(c) => c.value = value,
            None => self.push(name, value),
        }
    }

    /// Merge `other` into `self`: matching `(name, label)` entries add,
    /// unmatched entries append in `other`'s order.
    pub fn merge(&mut self, other: &CounterSnapshot) {
        for c in &other.entries {
            match self.entries.iter_mut().find(|m| m.name == c.name && m.label == c.label) {
                Some(m) => m.value = m.value.saturating_add(c.value),
                None => self.entries.push(c.clone()),
            }
        }
    }

    /// Iterate the entries in emission order.
    pub fn iter(&self) -> impl Iterator<Item = &Counter> {
        self.entries.iter()
    }

    /// The distinct names present, in first-appearance order.
    pub fn names(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for c in &self.entries {
            if !out.contains(&c.name) {
                out.push(c.name);
            }
        }
        out
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entry is present.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True when any value is non-zero.
    pub fn any_nonzero(&self) -> bool {
        self.entries.iter().any(|c| c.value > 0)
    }

    /// JSON object fields (`"name": value` or `"name[label]": value`,
    /// comma-separated, no braces) — how bench rows emit the snapshot
    /// so every registered counter reaches the schema by iteration.
    pub fn json_fields(&self) -> String {
        let mut out = String::new();
        for (i, c) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            match &c.label {
                Some(l) => out.push_str(&format!("\"{}[{}]\": {}", c.name, l, c.value)),
                None => out.push_str(&format!("\"{}\": {}", c.name, c.value)),
            }
        }
        out
    }

    /// Compact human rendering of the non-zero entries
    /// (`a=1 b=2`; empty string when all zero).
    pub fn render_nonzero(&self) -> String {
        let mut out = String::new();
        for c in &self.entries {
            if c.value == 0 {
                continue;
            }
            if !out.is_empty() {
                out.push(' ');
            }
            match &c.label {
                Some(l) => out.push_str(&format!("{}[{}]={}", c.name, l, c.value)),
                None => out.push_str(&format!("{}={}", c.name, c.value)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_covers_all_names_in_order() {
        let s = CounterSnapshot::zeroed(&FABRIC_COUNTERS);
        assert_eq!(s.names(), FABRIC_COUNTERS.to_vec());
        assert!(!s.any_nonzero());
        assert_eq!(s.get("retries"), 0);
        assert_eq!(s.get("no-such"), 0);
    }

    #[test]
    fn merge_adds_matching_and_appends_new() {
        let mut a = CounterSnapshot::zeroed(&["x", "y"]);
        a.set("x", 2);
        let mut b = CounterSnapshot::new();
        b.push("x", 3);
        b.push_labelled("z", "nvlink", 7);
        a.merge(&b);
        assert_eq!(a.get("x"), 5);
        assert_eq!(a.get("y"), 0);
        assert_eq!(a.get("z"), 7);
        assert_eq!(a.names(), vec!["x", "y", "z"]);
    }

    #[test]
    fn labelled_entries_sum_under_get() {
        let mut s = CounterSnapshot::new();
        s.push_labelled("bytes", "nvlink", 10);
        s.push_labelled("bytes", "pcie", 5);
        assert_eq!(s.get("bytes"), 15);
        assert_eq!(s.names(), vec!["bytes"]);
    }

    #[test]
    fn json_fields_and_render() {
        let mut s = CounterSnapshot::zeroed(&["a", "b"]);
        s.set("b", 4);
        s.push_labelled("c", "ib", 1);
        assert_eq!(s.json_fields(), "\"a\": 0, \"b\": 4, \"c[ib]\": 1");
        assert_eq!(s.render_nonzero(), "b=4 c[ib]=1");
        assert_eq!(CounterSnapshot::new().render_nonzero(), "");
    }
}
