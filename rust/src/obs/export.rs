//! Trace exporters (DESIGN.md §18): Chrome/Perfetto trace-event JSON
//! and the human `--trace-summary` phase table.
//!
//! The JSON follows the Chrome trace-event format that Perfetto loads
//! directly: one `{"traceEvents": [...]}` object, `"B"`/`"E"` duration
//! events per span (one track per traced thread, named via `"M"`
//! thread-name metadata), `"i"` instant events for faults/retries/
//! recoveries, and `"C"` counter events — one counter track per
//! distinct counter name, which is how per-`LinkKind` in-flight bytes
//! become link-utilisation timelines.
//!
//! The exporter is defensive about balance: a flush can catch spans
//! still open (a stalled rank mid-phase), so unmatched `"B"` events
//! get a synthesized `"E"` at the ring's last timestamp and unmatched
//! `"E"` events are dropped — the emitted JSON is always well nested
//! per track, which the schema test relies on.

use super::tracer::{Event, EventKind, RingSnapshot};

/// Serialise ring snapshots as Chrome trace-event JSON (one process,
/// one track per ring).
pub fn chrome_trace_json(rings: &[RingSnapshot]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |line: String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        out.push_str(&line);
        *first = false;
    };
    for r in rings {
        push(
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
                 \"args\":{{\"name\":{}}}}}",
                r.tid,
                json_str(&r.label)
            ),
            &mut first,
        );
        let mut depth: usize = 0;
        let mut last_ts = 0u64;
        for ev in &r.events {
            last_ts = last_ts.max(ev.t_us);
            match ev.kind {
                EventKind::Begin(kind) => {
                    depth += 1;
                    push(
                        format!(
                            "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"B\",\"pid\":1,\
                             \"tid\":{},\"ts\":{}{}}}",
                            json_str(ev.name),
                            kind.cat(),
                            r.tid,
                            ev.t_us,
                            args_of(ev)
                        ),
                        &mut first,
                    );
                }
                EventKind::End => {
                    // An unmatched close (span opened in a previous
                    // session) would corrupt nesting — drop it.
                    if depth == 0 {
                        continue;
                    }
                    depth -= 1;
                    push(
                        format!(
                            "{{\"ph\":\"E\",\"pid\":1,\"tid\":{},\"ts\":{}}}",
                            r.tid, ev.t_us
                        ),
                        &mut first,
                    );
                }
                EventKind::Instant(kind) => push(
                    format!(
                        "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\
                         \"pid\":1,\"tid\":{},\"ts\":{}{}}}",
                        json_str(ev.name),
                        kind.cat(),
                        r.tid,
                        ev.t_us,
                        args_of(ev)
                    ),
                    &mut first,
                ),
                EventKind::Counter => push(
                    format!(
                        "{{\"name\":{},\"ph\":\"C\",\"pid\":1,\"tid\":{},\"ts\":{},\
                         \"args\":{{\"value\":{}}}}}",
                        json_str(ev.name),
                        r.tid,
                        ev.t_us,
                        ev.arg.unwrap_or(0)
                    ),
                    &mut first,
                ),
            }
        }
        // Close whatever the flush caught mid-span.
        for _ in 0..depth {
            push(
                format!("{{\"ph\":\"E\",\"pid\":1,\"tid\":{},\"ts\":{}}}", r.tid, last_ts),
                &mut first,
            );
        }
        if r.dropped > 0 {
            push(
                format!(
                    "{{\"name\":\"ring_dropped_events\",\"cat\":\"meta\",\"ph\":\"i\",\
                     \"s\":\"t\",\"pid\":1,\"tid\":{},\"ts\":{},\
                     \"args\":{{\"value\":{}}}}}",
                    r.tid, last_ts, r.dropped
                ),
                &mut first,
            );
        }
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

fn args_of(ev: &Event) -> String {
    match ev.arg {
        Some(v) => format!(",\"args\":{{\"value\":{v}}}"),
        None => String::new(),
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// The `--trace-summary` table: per-track totals of every top-level
/// span (phases first), with counts and inclusive milliseconds.
pub fn summary_table(rings: &[RingSnapshot]) -> String {
    let mut out = String::from("trace summary (inclusive ms of top-level spans per track)\n");
    for r in rings {
        let mut rows: Vec<(&'static str, u64, u64)> = Vec::new(); // name, count, total_us
        let mut stack: Vec<(&'static str, u64)> = Vec::new();
        let mut last_ts = 0u64;
        for ev in &r.events {
            last_ts = last_ts.max(ev.t_us);
            match ev.kind {
                EventKind::Begin(_) => stack.push((ev.name, ev.t_us)),
                EventKind::End => {
                    if let Some((name, t0)) = stack.pop() {
                        if stack.is_empty() {
                            note(&mut rows, name, ev.t_us.saturating_sub(t0));
                        }
                    }
                }
                _ => {}
            }
        }
        // Spans the flush caught still open count up to the last event.
        while let Some((name, t0)) = stack.pop() {
            if stack.is_empty() {
                note(&mut rows, name, last_ts.saturating_sub(t0));
            }
        }
        if rows.is_empty() {
            continue;
        }
        out.push_str(&format!("  {}:\n", r.label));
        for (name, count, total_us) in rows {
            out.push_str(&format!(
                "    {name:<24} x{count:<5} {:>10.3} ms\n",
                total_us as f64 / 1e3
            ));
        }
        if r.dropped > 0 {
            out.push_str(&format!("    (ring dropped {} events)\n", r.dropped));
        }
    }
    out
}

fn note(rows: &mut Vec<(&'static str, u64, u64)>, name: &'static str, dur_us: u64) {
    match rows.iter_mut().find(|(n, _, _)| *n == name) {
        Some((_, count, total)) => {
            *count += 1;
            *total += dur_us;
        }
        None => rows.push((name, 1, dur_us)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::SpanKind;
    use crate::util::json::Json;

    fn ev(t_us: u64, kind: EventKind, name: &'static str, arg: Option<u64>) -> Event {
        Event { t_us, kind, name, arg }
    }

    fn ring(events: Vec<Event>) -> RingSnapshot {
        RingSnapshot { tid: 7, label: "rank 0".into(), dropped: 0, events }
    }

    #[test]
    fn balanced_spans_round_trip_through_the_parser() {
        let r = ring(vec![
            ev(0, EventKind::Begin(SpanKind::Phase), "local-sort", None),
            ev(5, EventKind::Instant(SpanKind::Fault), "fault.drop", Some(3)),
            ev(9, EventKind::Counter, "inflight.nvlink", Some(4096)),
            ev(10, EventKind::End, "", None),
        ]);
        let json = chrome_trace_json(&[r]);
        let j = Json::parse(&json).expect("valid JSON");
        let evs = j.get("traceEvents").as_arr().expect("traceEvents array");
        // thread_name metadata + B + i + C + E.
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].get("ph").as_str(), Some("M"));
        assert_eq!(evs[1].get("ph").as_str(), Some("B"));
        assert_eq!(evs[1].get("cat").as_str(), Some("phase"));
        assert_eq!(evs[2].get("ph").as_str(), Some("i"));
        assert_eq!(evs[2].get("args").get("value").as_usize(), Some(3));
        assert_eq!(evs[3].get("ph").as_str(), Some("C"));
        assert_eq!(evs[4].get("ph").as_str(), Some("E"));
    }

    #[test]
    fn unbalanced_rings_are_repaired() {
        // An unmatched E is dropped; an unmatched B gets a synthesized E.
        let r = ring(vec![
            ev(1, EventKind::End, "", None),
            ev(2, EventKind::Begin(SpanKind::Pass), "merge", None),
            ev(8, EventKind::Begin(SpanKind::SpillWrite), "spill.write", None),
        ]);
        let json = chrome_trace_json(&[r]);
        let j = Json::parse(&json).expect("valid JSON");
        let evs = j.get("traceEvents").as_arr().expect("array");
        let begins = evs.iter().filter(|e| e.get("ph").as_str() == Some("B")).count();
        let ends = evs.iter().filter(|e| e.get("ph").as_str() == Some("E")).count();
        assert_eq!(begins, 2);
        assert_eq!(ends, 2, "every B must have a matching E after repair");
    }

    #[test]
    fn summary_counts_top_level_spans_only() {
        let r = ring(vec![
            ev(0, EventKind::Begin(SpanKind::Phase), "exchange", None),
            ev(1, EventKind::Begin(SpanKind::ExchangeChunk), "exchange.chunk", None),
            ev(4, EventKind::End, "", None),
            ev(10, EventKind::End, "", None),
            ev(20, EventKind::Begin(SpanKind::Phase), "final", None),
        ]);
        let table = summary_table(&[r]);
        assert!(table.contains("rank 0"));
        assert!(table.contains("exchange"));
        // The nested chunk span is inclusive in "exchange", not a row of
        // its own; the still-open "final" span counts to the last event.
        assert!(!table.contains("exchange.chunk"));
        assert!(table.contains("final"));
    }

    #[test]
    fn strings_escape() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
    }
}
