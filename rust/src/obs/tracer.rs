//! Lock-free per-thread trace rings and RAII spans (DESIGN.md §18).
//!
//! One [`ThreadRing`] per traced thread: a fixed-capacity slot array
//! with a single writer (the owning thread) publishing a monotone
//! event count. The ring **never wraps** — a full ring drops the
//! newest event and counts the loss — so a reader that snapshots the
//! published prefix observes immutable, fully-written slots without
//! any locking on the hot path.
//!
//! Arming is process-wide ([`TraceSession`]): every recording entry
//! point is gated on one relaxed atomic load and returns an inert
//! guard when tracing is off, allocating nothing (enforced by
//! `tests/obs_noalloc.rs`).

use std::cell::{RefCell, UnsafeCell};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default per-thread ring capacity in events.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

/// Typed category of a span or instant event. The exporter maps it to
/// the Chrome trace `cat` field so Perfetto can colour/filter by layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// A `Session` API call (sort, reduce, ...).
    SessionOp,
    /// A coarse pipeline phase (SIHSort phase, external-sort phase).
    Phase,
    /// One pass/stage inside a phase (merge pass, exchange stream).
    Pass,
    /// Reading spilled runs back from the store.
    SpillRead,
    /// Writing a sorted run to the spill store.
    SpillWrite,
    /// One streamed-exchange chunk (partition + encode + enqueue).
    ExchangeChunk,
    /// An MPI-style collective (bcast, gather, alltoallv, barrier).
    Collective,
    /// A sender retry / credit stall (bounded-backoff events).
    Retry,
    /// Durable checkpoint work (manifest writes).
    Checkpoint,
    /// An in-process recovery attempt (driver restart).
    Recovery,
    /// An injected fault firing (`FaultPlan` drop/delay/kill/stall).
    Fault,
}

impl SpanKind {
    /// Chrome trace `cat` string.
    pub fn cat(self) -> &'static str {
        match self {
            SpanKind::SessionOp => "session",
            SpanKind::Phase => "phase",
            SpanKind::Pass => "pass",
            SpanKind::SpillRead => "spill-read",
            SpanKind::SpillWrite => "spill-write",
            SpanKind::ExchangeChunk => "exchange",
            SpanKind::Collective => "collective",
            SpanKind::Retry => "retry",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::Recovery => "recovery",
            SpanKind::Fault => "fault",
        }
    }
}

/// What one ring slot records.
#[derive(Clone, Copy, Debug)]
pub enum EventKind {
    /// Span open (matched by a later [`EventKind::End`] on the same
    /// thread).
    Begin(SpanKind),
    /// Span close.
    End,
    /// A point event.
    Instant(SpanKind),
    /// A counter sample: `name` is the counter track, `arg` the value.
    Counter,
}

/// One recorded event (fixed-size, `Copy` — ring slots are plain
/// memory).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Microseconds since the session epoch.
    pub t_us: u64,
    /// Event discriminator.
    pub kind: EventKind,
    /// Span/instant/counter name (empty for `End`).
    pub name: &'static str,
    /// Optional numeric payload (peer rank, bytes, attempt, value).
    pub arg: Option<u64>,
}

const DUMMY_EVENT: Event = Event { t_us: 0, kind: EventKind::End, name: "", arg: None };

/// One thread's trace ring plus its mirrored live span stack.
pub(crate) struct ThreadRing {
    tid: u64,
    epoch: Instant,
    label: Mutex<String>,
    /// Published event count; slots `0..len` are immutable.
    len: AtomicUsize,
    dropped: AtomicU64,
    slots: Box<[UnsafeCell<Event>]>,
    /// Live span stack, readable cross-thread by diagnostics
    /// ([`live_stacks`]): watchdog/deadlock reports show where each
    /// blamed rank currently is.
    stack: Mutex<Vec<&'static str>>,
}

// SAFETY: the only mutation of `slots` happens in `push`, which is
// called exclusively by the ring's owning thread (the ring lives in
// that thread's TLS and is never handed to another writer). The owner
// writes slot `len` and then publishes with a Release store; readers
// load `len` with Acquire and only read slots below it, which are
// fully written and never written again (the ring does not wrap).
// Every other field is an atomic or behind a Mutex.
unsafe impl Send for ThreadRing {}
// SAFETY: see the `Send` argument above — single writer, prefix-only
// readers, Release/Acquire publication.
unsafe impl Sync for ThreadRing {}

impl ThreadRing {
    fn new(tid: u64, epoch: Instant, capacity: usize, label: String) -> ThreadRing {
        ThreadRing {
            tid,
            epoch,
            label: Mutex::new(label),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            slots: (0..capacity.max(16)).map(|_| UnsafeCell::new(DUMMY_EVENT)).collect(),
            stack: Mutex::new(Vec::new()),
        }
    }

    /// Owner-thread-only append; drops the newest event when full.
    fn push(&self, kind: EventKind, name: &'static str, arg: Option<u64>) {
        let n = self.len.load(Ordering::Relaxed);
        if n >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let ev = Event {
            t_us: self.epoch.elapsed().as_micros() as u64,
            kind,
            name,
            arg,
        };
        // SAFETY: single-writer — only the owning thread calls `push`,
        // and slot `n` is above the published prefix, so no reader
        // touches it until the Release store below.
        unsafe {
            *self.slots[n].get() = ev;
        }
        self.len.store(n + 1, Ordering::Release);
    }
}

/// Immutable copy of one ring, taken at flush time.
pub struct RingSnapshot {
    /// Stable per-thread track id.
    pub tid: u64,
    /// Track label (`"rank 3"`, `"main"`, ...).
    pub label: String,
    /// Events dropped because the ring filled up.
    pub dropped: u64,
    /// The published events, in record order.
    pub events: Vec<Event>,
}

// ---- global session state ---------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static GENERATION: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

struct SessionState {
    epoch: Instant,
    capacity: usize,
    rings: Vec<Arc<ThreadRing>>,
}

fn state() -> &'static Mutex<SessionState> {
    static STATE: std::sync::OnceLock<Mutex<SessionState>> = std::sync::OnceLock::new();
    STATE.get_or_init(|| {
        Mutex::new(SessionState {
            epoch: Instant::now(),
            capacity: DEFAULT_RING_CAPACITY,
            rings: Vec::new(),
        })
    })
}

struct TlsState {
    generation: u64,
    ring: Option<Arc<ThreadRing>>,
    phase_open: bool,
}

thread_local! {
    static TLS: RefCell<TlsState> =
        const { RefCell::new(TlsState { generation: 0, ring: None, phase_open: false }) };
}

/// True while a [`TraceSession`] is armed. One relaxed atomic load —
/// this is the entire cost of every `obs::` call when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Run `f` with this thread's (ring-carrying) TLS state for the
/// current session, creating and registering the ring on first use.
/// `f` is skipped entirely when the TLS slot is unreachable (thread
/// teardown). Holds the single `RefCell` borrow for the whole call —
/// callers must not re-enter the tracer from `f`.
fn with_tls<R>(f: impl FnOnce(&mut TlsState) -> R) -> Option<R> {
    TLS.try_with(|tls| {
        let generation = GENERATION.load(Ordering::Relaxed);
        let mut t = tls.borrow_mut();
        if t.generation != generation || t.ring.is_none() {
            let mut st = match state().lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let ring = Arc::new(ThreadRing::new(
                NEXT_TID.fetch_add(1, Ordering::Relaxed),
                st.epoch,
                st.capacity,
                format!("thread-{:?}", std::thread::current().id()),
            ));
            st.rings.push(Arc::clone(&ring));
            t.generation = generation;
            t.ring = Some(ring);
            t.phase_open = false;
        }
        f(&mut t)
    })
    .ok()
}

fn lock_stack(ring: &ThreadRing) -> std::sync::MutexGuard<'_, Vec<&'static str>> {
    match ring.stack.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// RAII span: records the close (and pops the live stack) on drop —
/// including during a panic unwind, which is what keeps per-thread
/// open/close nesting balanced no matter how a phase exits.
#[must_use = "a span measures the scope holding the guard"]
pub struct SpanGuard {
    active: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        with_tls(|t| {
            if let Some(ring) = &t.ring {
                ring.push(EventKind::End, "", None);
                lock_stack(ring).pop();
            }
        });
    }
}

/// Open a span; the returned guard closes it on drop. Inert (no
/// allocation, no TLS access) when tracing is off.
#[inline]
pub fn span(kind: SpanKind, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { active: false };
    }
    span_slow(kind, name, None)
}

/// [`span`] with a numeric payload (bytes, peer rank, attempt).
#[inline]
pub fn span1(kind: SpanKind, name: &'static str, arg: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard { active: false };
    }
    span_slow(kind, name, Some(arg))
}

#[cold]
fn span_slow(kind: SpanKind, name: &'static str, arg: Option<u64>) -> SpanGuard {
    let pushed = with_tls(|t| {
        if let Some(ring) = &t.ring {
            ring.push(EventKind::Begin(kind), name, arg);
            lock_stack(ring).push(name);
            true
        } else {
            false
        }
    })
    .unwrap_or(false);
    SpanGuard { active: pushed }
}

/// Record a point event. Inert when tracing is off.
#[inline]
pub fn instant(kind: SpanKind, name: &'static str) {
    if enabled() {
        instant_slow(kind, name, None);
    }
}

/// [`instant`] with a numeric payload.
#[inline]
pub fn instant2(kind: SpanKind, name: &'static str, arg: u64) {
    if enabled() {
        instant_slow(kind, name, Some(arg));
    }
}

#[cold]
fn instant_slow(kind: SpanKind, name: &'static str, arg: Option<u64>) {
    with_tls(|t| {
        if let Some(ring) = &t.ring {
            ring.push(EventKind::Instant(kind), name, arg);
        }
    });
}

/// Sample a counter track (`name`) at `value`. The exporter turns each
/// distinct name into one Chrome counter track — per-`LinkKind`
/// in-flight bytes are the flagship use. Inert when tracing is off.
#[inline]
pub fn counter(name: &'static str, value: u64) {
    if enabled() {
        counter_slow(name, value);
    }
}

#[cold]
fn counter_slow(name: &'static str, value: u64) {
    with_tls(|t| {
        if let Some(ring) = &t.ring {
            ring.push(EventKind::Counter, name, Some(value));
        }
    });
}

/// Enter the named pipeline phase on this thread: closes the previous
/// phase span (if any) and opens a new one. Driven by the fabric's
/// `Endpoint::note_phase`, so every rank pipeline gets a contiguous
/// phase track without threading guards through its control flow.
#[inline]
pub fn phase(name: &'static str) {
    if !enabled() {
        return;
    }
    with_tls(|t| {
        if let Some(ring) = &t.ring {
            if t.phase_open {
                ring.push(EventKind::End, "", None);
                lock_stack(ring).pop();
            }
            ring.push(EventKind::Begin(SpanKind::Phase), name, None);
            lock_stack(ring).push(name);
            t.phase_open = true;
        }
    });
}

/// Close the current phase span, if one is open on this thread.
#[inline]
pub fn phase_end() {
    if !enabled() {
        return;
    }
    with_tls(|t| {
        if !t.phase_open {
            return;
        }
        if let Some(ring) = &t.ring {
            ring.push(EventKind::End, "", None);
            lock_stack(ring).pop();
        }
        t.phase_open = false;
    });
}

/// Name this thread's track (`"rank 3"`). Inert when tracing is off.
pub fn set_thread_label(label: &str) {
    if !enabled() {
        return;
    }
    with_tls(|t| {
        if let Some(ring) = &t.ring {
            let mut l = match ring.label.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            *l = label.to_string();
        }
    });
}

/// Every registered thread's `(label, live span stack)`, for watchdog
/// and deadlock diagnostics. Empty when tracing is off (stack
/// mirroring is part of the traced path).
pub fn live_stacks() -> Vec<(String, Vec<&'static str>)> {
    let st = match state().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    st.rings
        .iter()
        .map(|r| {
            let label = match r.label.lock() {
                Ok(g) => g.clone(),
                Err(p) => p.into_inner().clone(),
            };
            (label, lock_stack(r).clone())
        })
        .collect()
}

/// Human rendering of [`live_stacks`] (one `label: a > b > c` line per
/// thread with a non-empty stack); empty string when nothing is open.
pub fn live_stacks_table() -> String {
    let mut out = String::new();
    for (label, stack) in live_stacks() {
        if stack.is_empty() {
            continue;
        }
        out.push_str(&format!("  {label}: {}\n", stack.join(" > ")));
    }
    out
}

/// Snapshot every ring of the current session (published prefixes
/// only — safe while traced threads are still running).
pub(crate) fn drain_snapshots() -> Vec<RingSnapshot> {
    let st = match state().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    st.rings
        .iter()
        .map(|r| {
            let n = r.len.load(Ordering::Acquire);
            // SAFETY: slots below the Acquire-loaded `len` were fully
            // written before the owner's Release store and are never
            // written again (the ring does not wrap), so reading them
            // from this thread is race-free.
            let events = (0..n).map(|i| unsafe { *r.slots[i].get() }).collect();
            let label = match r.label.lock() {
                Ok(g) => g.clone(),
                Err(p) => p.into_inner().clone(),
            };
            RingSnapshot { tid: r.tid, label, dropped: r.dropped.load(Ordering::Relaxed), events }
        })
        .collect()
}

// ---- the session guard ------------------------------------------------

/// Arms process-wide tracing for its lifetime and flushes on drop.
///
/// Flush-on-drop runs during panic unwinds too, so a crashed traced
/// run still leaves a loadable (partial) trace behind. A `trace_out`
/// path that points inside a [`crate::stream::TempDirGuard`] spill
/// tree is remapped to the guard's parent — the guard deletes its
/// whole tree on drop, and the trace must survive the cleanup.
pub struct TraceSession {
    out: Option<PathBuf>,
    summary: bool,
}

impl TraceSession {
    /// Arm tracing. `ring_capacity` is events per thread (clamped to a
    /// sane floor). Any previous session's rings are discarded.
    pub fn start(
        trace_out: Option<&Path>,
        summary: bool,
        ring_capacity: usize,
    ) -> TraceSession {
        let out = trace_out.map(remap_outside_guard);
        {
            let mut st = match state().lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            st.epoch = Instant::now();
            st.capacity = ring_capacity.max(1024);
            st.rings.clear();
        }
        GENERATION.fetch_add(1, Ordering::Relaxed);
        ENABLED.store(true, Ordering::Relaxed);
        TraceSession { out, summary }
    }

    /// The (possibly remapped) trace output path.
    pub fn out_path(&self) -> Option<&Path> {
        self.out.as_deref()
    }

    /// Disarm, export, and (optionally) print the phase summary.
    /// Idempotent; also runs from `Drop`.
    pub fn flush(&mut self) {
        if !ENABLED.swap(false, Ordering::Relaxed) {
            return;
        }
        let rings = drain_snapshots();
        if let Some(path) = self.out.take() {
            if let Some(parent) = path.parent() {
                if !parent.as_os_str().is_empty() {
                    let _ = std::fs::create_dir_all(parent);
                }
            }
            let json = super::export::chrome_trace_json(&rings);
            match std::fs::write(&path, json) {
                Ok(()) => eprintln!("trace: wrote {}", path.display()),
                Err(e) => eprintln!("trace: cannot write {}: {e}", path.display()),
            }
        }
        if self.summary {
            print!("{}", super::export::summary_table(&rings));
        }
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Land a trace path outside any `TempDirGuard`-owned directory: if a
/// path component carries the guarded spill prefix, the file moves to
/// that component's parent under the same file name.
fn remap_outside_guard(p: &Path) -> PathBuf {
    let mut out = PathBuf::new();
    for c in p.components() {
        if let std::path::Component::Normal(os) = c {
            if os.to_string_lossy().starts_with(crate::stream::spill::TEMP_DIR_PREFIX) {
                out.push(p.file_name().unwrap_or(os));
                return out;
            }
        }
        out.push(c.as_os_str());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_newest_when_full_and_counts() {
        let ring = ThreadRing::new(0, Instant::now(), 16, "t".into());
        for i in 0..40 {
            ring.push(EventKind::Instant(SpanKind::Fault), "x", Some(i));
        }
        let n = ring.len.load(Ordering::Acquire);
        assert_eq!(n, 16);
        assert_eq!(ring.dropped.load(Ordering::Relaxed), 24);
        // The *oldest* events survive (drop-newest policy).
        // SAFETY: reading below the published prefix, single-threaded.
        let first = unsafe { *ring.slots[0].get() };
        assert_eq!(first.arg, Some(0));
    }

    #[test]
    fn disabled_paths_are_inert() {
        // These must be callable with tracing off and do nothing; the
        // no-allocation property is enforced by tests/obs_noalloc.rs.
        if enabled() {
            return; // another test armed a session concurrently
        }
        let g = span(SpanKind::Phase, "p");
        assert!(!g.active);
        drop(g);
        instant(SpanKind::Fault, "f");
        counter("c", 1);
        phase("p");
        phase_end();
        set_thread_label("x");
    }

    #[test]
    fn remap_lands_outside_guard_trees() {
        let prefix = crate::stream::spill::TEMP_DIR_PREFIX;
        let inside = PathBuf::from(format!("/tmp/scratch/{prefix}123-4/deep/trace.json"));
        assert_eq!(remap_outside_guard(&inside), PathBuf::from("/tmp/scratch/trace.json"));
        let outside = PathBuf::from("/tmp/scratch/trace.json");
        assert_eq!(remap_outside_guard(&outside), outside);
        let relative = PathBuf::from(format!("{prefix}9-9/trace.json"));
        assert_eq!(remap_outside_guard(&relative), PathBuf::from("trace.json"));
    }
}
