//! Cost-normalised comparison (paper Fig 5) and cost-aware split
//! planning for the hybrid subsystem (DESIGN.md §10).
//!
//! GPUs cost more to buy, power and cool: the paper folds capital,
//! running and environmental costs into a single ×22 GPU:CPU ratio
//! (validated by the Birmingham ARC team for BlueBEAR vs Baskerville) and
//! multiplies GPU sorting times by it. A GPU algorithm is *economically
//! viable* only where its normalised time still beats the CPU algorithm.
//! The same ratio, inverted, tells the hybrid planner how much of a shard
//! a device engine should own when optimising cost rather than makespan
//! ([`hybrid_host_fraction`]).

use crate::cfg::Sorter;

/// Fig 5 normalisation: multiply device-rank times by the cost ratio.
pub fn normalised_time(sim_secs: f64, sorter: Sorter, cost_ratio: f64) -> f64 {
    if sorter.is_device() {
        sim_secs * cost_ratio
    } else {
        sim_secs
    }
}

/// Relative tolerance for matching grid points across curves: n-grids
/// built by different generators (`10f64.powi(k)` vs repeated `* 10.0`
/// vs literal `1e6`) agree only to a few ulps, far inside 1e-9 relative.
pub const GRID_MATCH_RTOL: f64 = 1e-9;

/// Do two grid abscissae name the same n? Exact matches (including both
/// zero) pass; otherwise the difference must be within
/// [`GRID_MATCH_RTOL`] of the larger magnitude.
fn same_grid_n(a: f64, b: f64) -> bool {
    a == b || (a - b).abs() <= GRID_MATCH_RTOL * a.abs().max(b.abs())
}

/// Crossover analysis: given (n, cpu_time) and (n, gpu_time) curves,
/// return the smallest n where the *normalised* GPU time beats CPU, if
/// any (the paper's "economically justifiable above ~1e6 elements" for
/// GG variants). Grid points are matched with a relative tolerance
/// ([`GRID_MATCH_RTOL`]) instead of float equality, so curves whose
/// n-grids came from different generators (and so differ by an ulp)
/// still pair up instead of silently missing every point.
pub fn crossover_n(
    cpu: &[(f64, f64)],
    gpu: &[(f64, f64)],
    cost_ratio: f64,
) -> Option<f64> {
    for (n, g) in gpu {
        if let Some((_, c)) = cpu.iter().find(|(cn, _)| same_grid_n(*cn, *n)) {
            if g * cost_ratio < *c {
                return Some(*n);
            }
        }
    }
    None
}

/// Split planning for `hybrid` (DESIGN.md §10): the host-side work
/// fraction that equalises *cost-normalised* completion time between a
/// host engine of throughput `host_tput` and a device engine of
/// `device_tput` (any consistent unit — elements/s, bytes/s).
///
/// The device throughput is first deflated by `cost_ratio` (Fig 5's ×22
/// for economic planning; pass `1.0` to optimise pure makespan), then the
/// work splits proportionally to effective throughput:
/// `f_host = T_h / (T_h + T_d / cost_ratio)`. A higher cost ratio or a
/// slower device model therefore shifts work back onto the host — the
/// invariant the hybrid plan tests assert.
pub fn hybrid_host_fraction(host_tput: f64, device_tput: f64, cost_ratio: f64) -> f64 {
    assert!(host_tput >= 0.0 && host_tput.is_finite(), "bad host throughput {host_tput}");
    assert!(device_tput >= 0.0 && device_tput.is_finite(), "bad device throughput {device_tput}");
    assert!(cost_ratio > 0.0 && cost_ratio.is_finite(), "bad cost ratio {cost_ratio}");
    let effective_dev = device_tput / cost_ratio;
    if host_tput + effective_dev <= 0.0 {
        return 0.5; // no information: split evenly
    }
    host_tput / (host_tput + effective_dev)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_times_scaled() {
        assert_eq!(normalised_time(1.0, Sorter::Ak, 22.0), 22.0);
        assert_eq!(normalised_time(1.0, Sorter::ThrustRadix, 22.0), 22.0);
        assert_eq!(normalised_time(1.0, Sorter::JuliaBase, 22.0), 1.0);
    }

    #[test]
    fn crossover_found() {
        // GPU 30x faster above n=1e6, 2x faster below: with ratio 22 only
        // the former is viable.
        let cpu = vec![(1e5, 1.0), (1e6, 10.0), (1e7, 100.0)];
        let gpu = vec![(1e5, 0.5), (1e6, 0.33), (1e7, 3.3)];
        assert_eq!(crossover_n(&cpu, &gpu, 22.0), Some(1e6));
    }

    #[test]
    fn crossover_absent() {
        let cpu = vec![(1e5, 1.0)];
        let gpu = vec![(1e5, 0.5)]; // 2x faster — not enough at ×22
        assert_eq!(crossover_n(&cpu, &gpu, 22.0), None);
    }

    #[test]
    fn crossover_matches_grids_from_different_generators() {
        // The CPU grid from literals, the GPU grid from powi/multiplied
        // generators: abscissae differ by ulps, not values. Exact float
        // equality silently missed every point (and reported None).
        let cpu = vec![(1e5, 1.0), (1e6, 10.0), (1e7, 100.0)];
        let mut x = 1.0f64;
        let gpu: Vec<(f64, f64)> = [(5, 0.5), (6, 0.33), (7, 3.3)]
            .iter()
            .map(|&(k, t)| {
                while x < 10f64.powi(k) * 0.999 {
                    x *= 10.0;
                }
                (x * (1.0 + 1e-15), t) // a-few-ulps perturbation
            })
            .collect();
        assert!(gpu.iter().zip(&cpu).all(|(g, c)| g.0 != c.0), "grids must differ in bits");
        assert_eq!(crossover_n(&cpu, &gpu, 22.0), Some(gpu[1].0));
        // But genuinely different n never pair up.
        let far = vec![(2e6, 0.01)];
        assert_eq!(crossover_n(&cpu, &far, 22.0), None);
        assert!(same_grid_n(0.0, 0.0));
    }

    #[test]
    fn host_fraction_proportional_to_throughput() {
        // Equal engines at unit cost split evenly.
        assert!((hybrid_host_fraction(1.0, 1.0, 1.0) - 0.5).abs() < 1e-12);
        // A 3x device takes 3/4 of the work.
        assert!((hybrid_host_fraction(1.0, 3.0, 1.0) - 0.25).abs() < 1e-12);
        // Degenerate engines.
        assert_eq!(hybrid_host_fraction(0.0, 1.0, 1.0), 0.0);
        assert_eq!(hybrid_host_fraction(1.0, 0.0, 1.0), 1.0);
        assert_eq!(hybrid_host_fraction(0.0, 0.0, 22.0), 0.5);
    }

    #[test]
    fn host_fraction_monotone_in_cost_ratio() {
        // The paper's ×22 pushes work back onto the CPU: with a 22x-faster
        // device, cost-normalised planning splits evenly.
        let makespan = hybrid_host_fraction(1.0, 22.0, 1.0);
        let economic = hybrid_host_fraction(1.0, 22.0, 22.0);
        assert!(makespan < economic, "{makespan} !< {economic}");
        assert!((economic - 0.5).abs() < 1e-12);
        // Strictly monotone across a ratio sweep.
        let mut prev = 0.0;
        for ratio in [1.0, 2.0, 5.0, 22.0, 100.0] {
            let f = hybrid_host_fraction(1.0, 22.0, ratio);
            assert!(f > prev, "fraction not increasing at ratio {ratio}");
            prev = f;
        }
    }
}
