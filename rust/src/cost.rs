//! Cost-normalised comparison (paper Fig 5).
//!
//! GPUs cost more to buy, power and cool: the paper folds capital,
//! running and environmental costs into a single ×22 GPU:CPU ratio
//! (validated by the Birmingham ARC team for BlueBEAR vs Baskerville) and
//! multiplies GPU sorting times by it. A GPU algorithm is *economically
//! viable* only where its normalised time still beats the CPU algorithm.

use crate::cfg::Sorter;

/// Fig 5 normalisation: multiply device-rank times by the cost ratio.
pub fn normalised_time(sim_secs: f64, sorter: Sorter, cost_ratio: f64) -> f64 {
    if sorter.is_device() {
        sim_secs * cost_ratio
    } else {
        sim_secs
    }
}

/// Crossover analysis: given (n, cpu_time) and (n, gpu_time) curves,
/// return the smallest n where the *normalised* GPU time beats CPU, if
/// any (the paper's "economically justifiable above ~1e6 elements" for
/// GG variants).
pub fn crossover_n(
    cpu: &[(f64, f64)],
    gpu: &[(f64, f64)],
    cost_ratio: f64,
) -> Option<f64> {
    for (n, g) in gpu {
        if let Some((_, c)) = cpu.iter().find(|(cn, _)| cn == n) {
            if g * cost_ratio < *c {
                return Some(*n);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_times_scaled() {
        assert_eq!(normalised_time(1.0, Sorter::Ak, 22.0), 22.0);
        assert_eq!(normalised_time(1.0, Sorter::ThrustRadix, 22.0), 22.0);
        assert_eq!(normalised_time(1.0, Sorter::JuliaBase, 22.0), 1.0);
    }

    #[test]
    fn crossover_found() {
        // GPU 30x faster above n=1e6, 2x faster below: with ratio 22 only
        // the former is viable.
        let cpu = vec![(1e5, 1.0), (1e6, 10.0), (1e7, 100.0)];
        let gpu = vec![(1e5, 0.5), (1e6, 0.33), (1e7, 3.3)];
        assert_eq!(crossover_n(&cpu, &gpu, 22.0), Some(1e6));
    }

    #[test]
    fn crossover_absent() {
        let cpu = vec![(1e5, 1.0)];
        let gpu = vec![(1e5, 0.5)]; // 2x faster — not enough at ×22
        assert_eq!(crossover_n(&cpu, &gpu, 22.0), None);
    }
}
