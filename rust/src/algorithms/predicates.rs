//! `any` / `all` predicates (paper §II-B) with early exit.
//!
//! The paper ships two algorithms: a concurrent-write one (all threads
//! race to set a flag — well-defined on modern GPUs) and a conservative
//! mapreduce for older hardware. Host backends here use the racing-flag
//! formulation (AtomicBool, relaxed — any thread may publish `true`);
//! the device path evaluates chunk predicates with host-side early exit
//! (see `DeviceOps::any_gt_f32`).

use std::sync::atomic::{AtomicBool, Ordering};

use crate::backend::Backend;

/// `any(x > threshold)` over f32 (the artifact-covered predicate).
pub fn any_gt(backend: &Backend, xs: &[f32], threshold: f32) -> anyhow::Result<bool> {
    match backend {
        Backend::Native => Ok(xs.iter().any(|&x| x > threshold)),
        Backend::Threaded(t) => Ok(host_any(xs, *t, |x| x > threshold)),
        Backend::Device(dev) => dev.any_gt_f32(xs, threshold),
        Backend::Hybrid(h) => crate::hybrid::co_any_gt(h, xs, threshold),
    }
}

/// `all(x > threshold)` over f32.
pub fn all_gt(backend: &Backend, xs: &[f32], threshold: f32) -> anyhow::Result<bool> {
    match backend {
        Backend::Native => Ok(xs.iter().all(|&x| x > threshold)),
        Backend::Threaded(t) => Ok(!host_any(xs, *t, |x| x <= threshold)),
        Backend::Device(dev) => dev.all_gt_f32(xs, threshold),
        Backend::Hybrid(h) => crate::hybrid::co_all_gt(h, xs, threshold),
    }
}

/// Generic host `any` with an arbitrary predicate (the paper's `any(f, itr)`).
pub fn any_by<T: Sync + Copy, P: Fn(&T) -> bool + Sync>(
    backend: &Backend,
    xs: &[T],
    pred: P,
) -> bool {
    match backend {
        Backend::Native | Backend::Device(_) => xs.iter().any(|x| pred(x)),
        Backend::Threaded(t) => host_any(xs, *t, |x| pred(&x)),
        // Arbitrary predicates cannot cross the AOT boundary; the hybrid
        // generic path runs on the host pool (DESIGN.md §10).
        Backend::Hybrid(h) => host_any(xs, h.host_threads.max(1), |x| pred(&x)),
    }
}

/// Generic host `all`.
pub fn all_by<T: Sync + Copy, P: Fn(&T) -> bool + Sync>(
    backend: &Backend,
    xs: &[T],
    pred: P,
) -> bool {
    !any_by(backend, xs, |x| !pred(x))
}

/// Racing-flag parallel any: every worker checks the shared flag
/// periodically and stops early once someone published `true` — the
/// concurrent-write algorithm of the paper, with the benign-race made
/// explicit through an atomic.
fn host_any<T: Sync + Copy>(xs: &[T], threads: usize, pred: impl Fn(T) -> bool + Sync) -> bool {
    if threads <= 1 || xs.len() < 4096 {
        return xs.iter().any(|&x| pred(x));
    }
    let found = AtomicBool::new(false);
    crate::backend::parallel_for_each_chunk(xs.len(), threads, |r| {
        for (k, &x) in xs[r].iter().enumerate() {
            // Check the flag every 1024 elements: cheap early exit
            // without per-element synchronisation traffic.
            if k % 1024 == 0 && found.load(Ordering::Relaxed) {
                return;
            }
            if pred(x) {
                found.store(true, Ordering::Relaxed);
                return;
            }
        }
    });
    found.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn any_all_basic() {
        let xs: Vec<f32> = (0..10_000).map(|i| i as f32 / 10_000.0).collect();
        for b in [Backend::Native, Backend::Threaded(4)] {
            assert!(any_gt(&b, &xs, 0.9995).unwrap());
            assert!(!any_gt(&b, &xs, 2.0).unwrap());
            assert!(all_gt(&b, &xs, -0.1).unwrap());
            assert!(!all_gt(&b, &xs, 0.5).unwrap());
        }
    }

    #[test]
    fn generic_predicates() {
        let xs: Vec<i64> = (0..5000).collect();
        for b in [Backend::Native, Backend::Threaded(4)] {
            assert!(any_by(&b, &xs, |&x| x == 4999));
            assert!(!any_by(&b, &xs, |&x| x < 0));
            assert!(all_by(&b, &xs, |&x| x >= 0));
            assert!(!all_by(&b, &xs, |&x| x % 2 == 0));
        }
    }

    #[test]
    fn empty_semantics() {
        let e: Vec<f32> = vec![];
        assert!(!any_gt(&Backend::Native, &e, 0.0).unwrap());
        assert!(all_gt(&Backend::Native, &e, 0.0).unwrap()); // vacuous truth
    }
}
