//! `any` / `all` predicate engines (paper §II-B) with early exit.
//!
//! The paper ships two algorithms: a concurrent-write one (all threads
//! race to set a flag — well-defined on modern GPUs) and a conservative
//! mapreduce for older hardware. Host backends here use the racing-flag
//! formulation through one shared reducer (`host_any`); the device
//! path evaluates chunk predicates with host-side early exit for every
//! dtype with an `any_gt`/`all_gt` artifact family (no longer f32-only).
//!
//! Dispatch lives on [`crate::session::Session::any_gt`] /
//! [`crate::session::Session::all_gt`] /
//! [`crate::session::Session::any_by`] /
//! [`crate::session::Session::all_by`]; this module keeps the reducer
//! plus `#[deprecated]` free-function shims (f32-typed, as before).

use std::sync::atomic::{AtomicBool, Ordering};

use crate::backend::Backend;
use crate::session::Session;

/// The one short-circuiting reducer behind every host predicate
/// (`any_gt`, `all_gt`, `any_by`, `all_by`): racing-flag parallel any.
/// Every worker checks the shared flag periodically and stops early once
/// someone published `true` — the concurrent-write algorithm of the
/// paper, with the benign race made explicit through an atomic.
/// `seq_below` gates the fan-out (a `Launch` knob at the session layer).
pub(crate) fn host_any<T: Sync + Copy>(
    xs: &[T],
    threads: usize,
    seq_below: usize,
    pred: impl Fn(T) -> bool + Sync,
) -> bool {
    if threads <= 1 || xs.len() < seq_below.max(2) {
        return xs.iter().any(|&x| pred(x));
    }
    let found = AtomicBool::new(false);
    crate::backend::parallel_for_each_chunk(xs.len(), threads, |r| {
        for (k, &x) in xs[r].iter().enumerate() {
            // Check the flag every 1024 elements: cheap early exit
            // without per-element synchronisation traffic.
            if k % 1024 == 0 && found.load(Ordering::Relaxed) {
                return;
            }
            if pred(x) {
                found.store(true, Ordering::Relaxed);
                return;
            }
        }
    });
    found.load(Ordering::Relaxed)
}

/// `any(x > threshold)` over f32.
#[deprecated(note = "use `Session::any_gt` (`accelkern::session`) — generic over dtypes")]
pub fn any_gt(backend: &Backend, xs: &[f32], threshold: f32) -> anyhow::Result<bool> {
    Ok(Session::from_backend(backend.clone()).any_gt(xs, threshold, None)?)
}

/// `all(x > threshold)` over f32.
#[deprecated(note = "use `Session::all_gt` (`accelkern::session`) — generic over dtypes")]
pub fn all_gt(backend: &Backend, xs: &[f32], threshold: f32) -> anyhow::Result<bool> {
    Ok(Session::from_backend(backend.clone()).all_gt(xs, threshold, None)?)
}

/// Generic host `any` with an arbitrary predicate (the paper's
/// `any(f, itr)`).
#[deprecated(note = "use `Session::any_by` (`accelkern::session`)")]
pub fn any_by<T: Sync + Copy, P: Fn(&T) -> bool + Sync>(
    backend: &Backend,
    xs: &[T],
    pred: P,
) -> bool {
    Session::from_backend(backend.clone()).any_by(xs, pred, None)
}

/// Generic host `all`.
#[deprecated(note = "use `Session::all_by` (`accelkern::session`)")]
pub fn all_by<T: Sync + Copy, P: Fn(&T) -> bool + Sync>(
    backend: &Backend,
    xs: &[T],
    pred: P,
) -> bool {
    Session::from_backend(backend.clone()).all_by(xs, pred, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn any_all_basic() {
        let xs: Vec<f32> = (0..10_000).map(|i| i as f32 / 10_000.0).collect();
        for s in [Session::native(), Session::threaded(4)] {
            assert!(s.any_gt(&xs, 0.9995f32, None).unwrap());
            assert!(!s.any_gt(&xs, 2.0f32, None).unwrap());
            assert!(s.all_gt(&xs, -0.1f32, None).unwrap());
            assert!(!s.all_gt(&xs, 0.5f32, None).unwrap());
        }
    }

    #[test]
    fn generic_dtypes_beyond_f32() {
        // The satellite fix: one generic reducer, every sortable dtype.
        let xs: Vec<i64> = (0..8192).collect();
        for s in [Session::native(), Session::threaded(4)] {
            assert!(s.any_gt(&xs, 8190i64, None).unwrap());
            assert!(!s.any_gt(&xs, 8191i64, None).unwrap());
            assert!(s.all_gt(&xs, -1i64, None).unwrap());
            assert!(!s.all_gt(&xs, 0i64, None).unwrap());
        }
        let ys: Vec<i16> = vec![3, 7, -2];
        assert!(Session::native().any_gt(&ys, 6i16, None).unwrap());
    }

    #[test]
    fn nan_fails_all_gt_on_every_engine() {
        // IEEE semantics: NaN > t is false, so `all` must be false. The
        // pre-session threaded path disagreed with native here.
        let mut xs = vec![1.0f64; 10_000];
        xs[7777] = f64::NAN;
        for s in [Session::native(), Session::threaded(4)] {
            assert!(!s.all_gt(&xs, 0.0f64, None).unwrap(), "{s:?}");
            assert!(!s.any_gt(&xs, 2.0f64, None).unwrap(), "{s:?}");
        }
    }

    #[test]
    fn generic_predicates() {
        let xs: Vec<i64> = (0..5000).collect();
        for s in [Session::native(), Session::threaded(4)] {
            assert!(s.any_by(&xs, |&x| x == 4999, None));
            assert!(!s.any_by(&xs, |&x| x < 0, None));
            assert!(s.all_by(&xs, |&x| x >= 0, None));
            assert!(!s.all_by(&xs, |&x| x % 2 == 0, None));
        }
    }

    #[test]
    fn empty_semantics() {
        let e: Vec<f32> = vec![];
        let s = Session::native();
        assert!(!s.any_gt(&e, 0.0f32, None).unwrap());
        assert!(s.all_gt(&e, 0.0f32, None).unwrap()); // vacuous truth
    }
}
