//! The AcceleratedKernels algorithm suite (paper §II-B), backend-generic.
//!
//! One function family per paper primitive, each dispatching over
//! [`crate::backend::Backend`]:
//!
//! | paper                        | here                                   |
//! |------------------------------|----------------------------------------|
//! | `foreachindex`               | [`foreach::foreachindex`]              |
//! | `merge_sort`                 | [`sort::sort`]                         |
//! | `merge_sort_by_key`          | [`sort::sort_by_key`]                  |
//! | `sortperm` / `_lowmem`       | [`sortperm::sortperm`] / `_lowmem`     |
//! | `reduce`                     | [`reduce::reduce`] (+ `switch_below`)  |
//! | `mapreduce`                  | [`reduce::mapreduce`]                  |
//! | `accumulate`                 | [`scan::accumulate`]                   |
//! | `searchsortedfirst/last`     | [`search::searchsorted_first/last`]    |
//! | `any` / `all`                | [`predicates::any_gt/all_gt`] etc.     |
//! | Table II arithmetic kernels  | [`arith::rbf`] / [`arith::ljg`]        |
//!
//! Temporary buffers are exposed or internally reused, and every
//! algorithm's extra memory is a predictable function of the input size
//! (paper §II-B's closing requirement).

pub mod arith;
pub mod foreach;
pub mod predicates;
pub mod reduce;
pub mod scan;
pub mod search;
pub mod sort;
pub mod sortperm;

pub use arith::{ljg, ljg_powf, rbf, LjgConsts};
pub use foreach::foreachindex;
pub use predicates::{all_gt, any_gt};
pub use reduce::{mapreduce, reduce, ReduceKind};
pub use scan::accumulate;
pub use search::{searchsorted_first, searchsorted_last};
pub use sort::{sort, sort_by_key};
pub use sortperm::{sortperm, sortperm_lowmem};
