//! The AcceleratedKernels algorithm suite (paper §II-B): host engines,
//! numeric glue and the *deprecated* free-function surface.
//!
//! The dispatching API now lives on [`crate::session::Session`] — one
//! method per paper primitive, each taking an optional
//! [`crate::session::Launch`] of per-call tuning knobs and returning a
//! typed [`crate::session::AkError`]:
//!
//! | paper                        | session method                           |
//! |------------------------------|------------------------------------------|
//! | `foreachindex`               | `Session::foreachindex` / `foreach_mut`  |
//! | `merge_sort`                 | `Session::sort`                          |
//! | `merge_sort_by_key`          | `Session::sort_by_key`                   |
//! | `sortperm` / `_lowmem`       | `Session::sortperm` / `sortperm_lowmem`  |
//! | `reduce`                     | `Session::reduce` (+ `switch_below`)     |
//! | `mapreduce`                  | `Session::mapreduce`                     |
//! | `accumulate`                 | `Session::accumulate`                    |
//! | `searchsortedfirst/last`     | `Session::searchsorted_first/last`       |
//! | `any` / `all`                | `Session::any_gt/all_gt` + `any_by/all_by` |
//! | Table II arithmetic kernels  | `Session::rbf` / `Session::ljg`          |
//!
//! The pre-session free functions remain here as `#[deprecated]` shims
//! delegating to a per-call session over the given backend, so external
//! code migrates at its own pace; in-tree code is shim-free (CI denies
//! `deprecated`). Temporary buffers are exposed or internally reused
//! (`Launch::reuse_scratch`), and every algorithm's extra memory is a
//! predictable function of the input size (paper §II-B's closing
//! requirement).

pub mod arith;
pub mod foreach;
pub mod predicates;
pub mod reduce;
pub mod scan;
pub mod search;
pub mod sort;
pub mod sortperm;

#[allow(deprecated)]
pub use arith::{ljg, ljg_powf, rbf};
pub use arith::LjgConsts;
#[allow(deprecated)]
pub use foreach::foreachindex;
#[allow(deprecated)]
pub use predicates::{all_gt, any_gt};
#[allow(deprecated)]
pub use reduce::{mapreduce, reduce};
pub use reduce::ReduceKind;
#[allow(deprecated)]
pub use scan::accumulate;
#[allow(deprecated)]
pub use search::{searchsorted_first, searchsorted_last};
#[allow(deprecated)]
pub use sort::{sort, sort_by_key};
#[allow(deprecated)]
pub use sortperm::{sortperm, sortperm_lowmem};
