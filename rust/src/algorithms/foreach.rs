//! `foreachindex` (paper §II-B, Algorithms 2–3): the general parallel
//! loop. Host closures run per index on Native/Threaded backends; the
//! Device backend's "foreachindex bodies" are the AOT-compiled named
//! kernels (rbf/ljg in `arith`), since arbitrary closures cannot cross
//! the transpile-once boundary — our `make artifacts` is the analog of
//! Julia's kernel compilation at first use.
//!
//! Dispatch lives on [`crate::session::Session::foreachindex`] /
//! [`crate::session::Session::foreach_mut`]; this module keeps the
//! `#[deprecated]` free-function shims.

use crate::backend::Backend;
use crate::session::Session;

/// Run `f(i)` for every `i in 0..len`, statically partitioned over the
/// backend's threads.
#[deprecated(note = "use `Session::foreachindex` (`accelkern::session`)")]
pub fn foreachindex<F>(backend: &Backend, len: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    Session::from_backend(backend.clone()).foreachindex(len, f, None)
}

/// Mutating variant over a slice: `f(i, &mut xs[i])` with disjoint
/// chunks (the dst/src copy-kernel pattern of paper Algorithm 3).
#[deprecated(note = "use `Session::foreach_mut` (`accelkern::session`)")]
pub fn foreach_mut<T: Send, F>(backend: &Backend, xs: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    Session::from_backend(backend.clone()).foreach_mut(xs, f, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Launch;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn visits_every_index_once() {
        for s in [Session::native(), Session::threaded(4)] {
            let hits: Vec<AtomicU64> = (0..10_000).map(|_| AtomicU64::new(0)).collect();
            s.foreachindex(
                10_000,
                |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                },
                None,
            );
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "{s:?}");
        }
    }

    #[test]
    fn copy_kernel_algorithm3() {
        // The paper's copy_parallel!: dst[i] = src[i]. Forced parallel
        // via the threshold knob so the chunked path is exercised.
        let src: Vec<i32> = (0..5000).collect();
        let l = Launch::new().prefer_parallel_threshold(64);
        for s in [Session::native(), Session::threaded(3)] {
            let mut dst = vec![0i32; 5000];
            s.foreach_mut(&mut dst, |i, d| *d = src[i], Some(&l));
            assert_eq!(dst, src, "{s:?}");
        }
    }

    #[test]
    fn zero_len() {
        Session::threaded(4).foreachindex(0, |_| panic!("must not run"), None);
    }
}
