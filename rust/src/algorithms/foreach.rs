//! `foreachindex` (paper §II-B, Algorithms 2–3): the general parallel
//! loop. Host closures run per index on Native/Threaded backends; the
//! Device backend's "foreachindex bodies" are the AOT-compiled named
//! kernels (rbf/ljg in `arith`), since arbitrary closures cannot cross
//! the transpile-once boundary — our `make artifacts` is the analog of
//! Julia's kernel compilation at first use.

use crate::backend::Backend;

/// Run `f(i)` for every `i in 0..len`, statically partitioned over the
/// backend's threads (one thread per chunk, matching the paper's CPU
/// scheduling; GPUs run one iteration per thread which we emulate by
/// vectorised artifacts instead).
pub fn foreachindex<F>(backend: &Backend, len: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    match backend {
        Backend::Native | Backend::Device(_) => {
            for i in 0..len {
                f(i);
            }
        }
        Backend::Threaded(t) => {
            crate::backend::parallel_for_each_chunk(len, *t, |r| {
                for i in r {
                    f(i);
                }
            });
        }
        // Co-processing: host thread pool and device-engine emulation walk
        // disjoint index shards concurrently (DESIGN.md §10).
        Backend::Hybrid(h) => crate::hybrid::co_foreachindex(h, len, f),
    }
}

/// Mutating variant over a slice: `f(i, &mut xs[i])` with disjoint chunks
/// (the dst/src copy-kernel pattern of paper Algorithm 3).
pub fn foreach_mut<T: Send, F>(backend: &Backend, xs: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    match backend {
        Backend::Native | Backend::Device(_) => {
            for (i, x) in xs.iter_mut().enumerate() {
                f(i, x);
            }
        }
        Backend::Threaded(t) => {
            let ranges = crate::backend::threaded::split_ranges(xs.len(), *t);
            crate::backend::parallel_chunks(xs, *t, |ci, chunk| {
                let base = ranges[ci].start;
                for (j, x) in chunk.iter_mut().enumerate() {
                    f(base + j, x);
                }
            });
        }
        Backend::Hybrid(h) => crate::hybrid::co_foreach_mut(h, xs, f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn visits_every_index_once() {
        for b in [Backend::Native, Backend::Threaded(4)] {
            let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
            foreachindex(&b, 1000, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "{b:?}");
        }
    }

    #[test]
    fn copy_kernel_algorithm3() {
        // The paper's copy_parallel!: dst[i] = src[i].
        let src: Vec<i32> = (0..5000).collect();
        for b in [Backend::Native, Backend::Threaded(3)] {
            let mut dst = vec![0i32; 5000];
            foreach_mut(&b, &mut dst, |i, d| *d = src[i]);
            assert_eq!(dst, src, "{b:?}");
        }
    }

    #[test]
    fn zero_len() {
        foreachindex(&Backend::Threaded(4), 0, |_| panic!("must not run"));
    }
}
