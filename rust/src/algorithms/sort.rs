//! `merge_sort` / `merge_sort_by_key` (paper §II-B).
//!
//! * Native: unstable std sort on the total-order key image.
//! * Threaded: per-chunk sort + merge-path partitioned parallel k-way
//!   merge (the paper's CPU path is statically-partitioned threads;
//!   the recombine engine is DESIGN.md §11).
//! * Device: the AOT bitonic merge-sort artifact via PJRT; i128 falls
//!   back to the threaded path (no s128 in XLA — DESIGN.md §2).
//!
//! **Stability contract:** [`sort`] is *not* stable — its keys are plain
//! scalars, so equal keys are indistinguishable and the unstable std
//! sort's lower memory traffic is free throughput. Stability is part of
//! the contract of [`super::sortperm::sortperm`] and [`sort_by_key`]
//! only, where equal keys carry distinguishable payloads/indices.

use crate::backend::{Backend, DeviceKey};
use crate::baselines::merge_path;
use crate::dtype::SortKey;

/// Sort `xs` ascending (total order; NaN-safe for floats). Not stable —
/// see the module docs for the stability contract split.
///
/// ```
/// use accelkern::backend::Backend;
/// let mut v = vec![3i32, -1, 2, 0];
/// accelkern::algorithms::sort(&Backend::Native, &mut v).unwrap();
/// assert_eq!(v, vec![-1, 0, 2, 3]);
///
/// // Floats sort in the IEEE total order: NaN sinks past +inf.
/// let mut f = vec![1.0f64, f64::NAN, f64::NEG_INFINITY, -0.0];
/// accelkern::algorithms::sort(&Backend::Threaded(2), &mut f).unwrap();
/// assert_eq!(f[0], f64::NEG_INFINITY);
/// assert!(f[3].is_nan());
/// ```
pub fn sort<K: DeviceKey>(backend: &Backend, xs: &mut [K]) -> anyhow::Result<()> {
    match backend {
        Backend::Native => {
            xs.sort_unstable_by(|a, b| a.cmp_total(b));
            Ok(())
        }
        Backend::Threaded(t) => {
            threaded_sort(xs, *t);
            Ok(())
        }
        Backend::Device(dev) => {
            if K::XLA {
                dev.sort(xs)
            } else {
                // Device fallback for i128: host merge path (the "AK" code
                // still owns the shard; only the engine differs).
                threaded_sort(xs, 1);
                Ok(())
            }
        }
        // Co-processing: both engines sort disjoint shards concurrently,
        // then a 2-way merge recombines (DESIGN.md §10).
        Backend::Hybrid(h) => crate::hybrid::co_sort(h, xs),
    }
}

fn threaded_sort<K: SortKey>(xs: &mut [K], threads: usize) {
    let t = threads.max(1);
    if t == 1 || xs.len() < 4096 {
        xs.sort_unstable_by(|a, b| a.cmp_total(b));
        return;
    }
    crate::backend::parallel_chunks(xs, t, |_, chunk| {
        chunk.sort_unstable_by(|a, b| a.cmp_total(b));
    });
    // Recombine the t sorted chunks with the merge-path partitioned
    // parallel merge (DESIGN.md §11): merge into scratch on all t
    // workers, then copy back in parallel. The whole sort stays parallel
    // end to end instead of funnelling through one sequential k-merge.
    let ranges = crate::backend::threaded::split_ranges(xs.len(), t);
    let bounds: Vec<usize> = ranges.iter().skip(1).map(|r| r.start).collect();
    merge_path::merge_runs_in_place(xs, &bounds, t);
}

/// Sort `keys` ascending carrying `vals` along (payload sort).
/// Stable: equal keys keep their input order.
pub fn sort_by_key<K: DeviceKey, V: Copy + Send + Sync>(
    backend: &Backend,
    keys: &mut [K],
    vals: &mut [V],
) -> anyhow::Result<()> {
    anyhow::ensure!(keys.len() == vals.len(), "key/val length mismatch");
    let n = keys.len();
    if n <= 1 {
        return Ok(());
    }
    // Device path only exists for i32 payloads within one size class;
    // general payloads go through an index permutation (native work is
    // O(n) scatter either way).
    let perm = super::sortperm::sortperm(backend, keys)?;
    apply_permutation(keys, &perm);
    apply_permutation(vals, &perm);
    Ok(())
}

/// Apply `perm` (out-of-place gather) to `xs`.
pub fn apply_permutation<T: Copy>(xs: &mut [T], perm: &[u32]) {
    debug_assert_eq!(xs.len(), perm.len());
    let src = xs.to_vec();
    for (dst, &p) in xs.iter_mut().zip(perm.iter()) {
        *dst = src[p as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn hosts() -> Vec<Backend> {
        vec![Backend::Native, Backend::Threaded(4)]
    }

    fn check_host<K: KeyGen + PartialEq + DeviceKey>(seed: u64, n: usize) {
        for b in hosts() {
            for dist in [Distribution::Uniform, Distribution::Reverse, Distribution::DupHeavy] {
                let orig: Vec<K> = generate(&mut Prng::new(seed), dist, n);
                let mut xs = orig.clone();
                sort(&b, &mut xs).unwrap();
                let mut want = orig.clone();
                want.sort_by(|a, b| a.cmp_total(b));
                assert!(xs == want, "{b:?} {dist:?}");
            }
        }
    }

    #[test]
    fn host_backends_i32() {
        check_host::<i32>(1, 10_000);
    }

    #[test]
    fn host_backends_i128() {
        check_host::<i128>(2, 5000);
    }

    #[test]
    fn host_backends_f64() {
        check_host::<f64>(3, 8000);
    }

    #[test]
    fn sort_by_key_carries_payloads() {
        let keys_orig: Vec<i32> = generate(&mut Prng::new(4), Distribution::Uniform, 3000);
        for b in hosts() {
            let mut keys = keys_orig.clone();
            let mut vals: Vec<usize> = (0..keys.len()).collect();
            sort_by_key(&b, &mut keys, &mut vals).unwrap();
            assert!(is_sorted_total(&keys));
            for (k, v) in keys.iter().zip(&vals) {
                assert_eq!(*k, keys_orig[*v]);
            }
        }
    }

    #[test]
    fn stability_of_by_key() {
        let keys_orig = vec![3i32, 1, 3, 1, 3];
        let mut keys = keys_orig.clone();
        let mut vals: Vec<usize> = (0..5).collect();
        sort_by_key(&Backend::Native, &mut keys, &mut vals).unwrap();
        assert_eq!(keys, vec![1, 1, 3, 3, 3]);
        assert_eq!(vals, vec![1, 3, 0, 2, 4]); // equal keys keep input order
    }

    #[test]
    fn permutation_application() {
        let mut xs = vec![10, 20, 30];
        apply_permutation(&mut xs, &[2, 0, 1]);
        assert_eq!(xs, vec![30, 10, 20]);
    }
}
