//! `merge_sort` / `merge_sort_by_key` engines (paper §II-B).
//!
//! * Native: unstable std sort on the total-order key image.
//! * Threaded: per-chunk sort + merge-path partitioned parallel k-way
//!   merge (the paper's CPU path is statically-partitioned threads;
//!   the recombine engine is DESIGN.md §11).
//! * Device: the AOT bitonic merge-sort artifact via PJRT; i128 returns
//!   `AkError::UnsupportedDtype` (no s128 in XLA — DESIGN.md §2).
//!
//! Dispatch lives on [`crate::session::Session::sort`] /
//! [`crate::session::Session::sort_by_key`]; this module keeps the host
//! engines plus `#[deprecated]` free-function shims.
//!
//! **Stability contract:** `sort` is *not* stable — its keys are plain
//! scalars, so equal keys are indistinguishable and the unstable std
//! sort's lower memory traffic is free throughput. Stability is part of
//! the contract of `sortperm` and `sort_by_key` only, where equal keys
//! carry distinguishable payloads/indices.

use crate::backend::{Backend, DeviceKey};
use crate::baselines::merge_path;
use crate::dtype::SortKey;
use crate::session::Session;

/// The threaded host sort engine: per-chunk unstable sorts over
/// `threads` workers, recombined by the merge-path partitioned parallel
/// merge. `seq_below` gates the chunk fan-out, `merge_par_min` the
/// recombine fan-out (both overridable via `Launch`); `scratch` is the
/// merge buffer, reusable across calls.
pub(crate) fn threaded_sort<K: SortKey>(
    xs: &mut [K],
    threads: usize,
    seq_below: usize,
    merge_par_min: usize,
    scratch: &mut Vec<K>,
) {
    let t = threads.max(1);
    if t == 1 || xs.len() < seq_below.max(2) {
        xs.sort_unstable_by(|a, b| a.cmp_total(b));
        return;
    }
    crate::backend::parallel_chunks(xs, t, |_, chunk| {
        chunk.sort_unstable_by(|a, b| a.cmp_total(b));
    });
    // Recombine the t sorted chunks with the merge-path partitioned
    // parallel merge (DESIGN.md §11): merge into scratch on all t
    // workers, then copy back in parallel. The whole sort stays parallel
    // end to end instead of funnelling through one sequential k-merge.
    let ranges = crate::backend::threaded::split_ranges(xs.len(), t);
    let bounds: Vec<usize> = ranges.iter().skip(1).map(|r| r.start).collect();
    merge_path::merge_runs_in_place_with(xs, &bounds, t, merge_par_min, scratch);
}

/// Sort `xs` ascending (total order; NaN-safe for floats).
#[deprecated(note = "use `Session::sort` (`accelkern::session`)")]
pub fn sort<K: DeviceKey>(backend: &Backend, xs: &mut [K]) -> anyhow::Result<()> {
    Ok(Session::from_backend(backend.clone()).sort(xs, None)?)
}

/// Sort `keys` ascending carrying `vals` along (stable payload sort).
#[deprecated(note = "use `Session::sort_by_key` (`accelkern::session`)")]
pub fn sort_by_key<K: DeviceKey, V: Copy + Send + Sync>(
    backend: &Backend,
    keys: &mut [K],
    vals: &mut [V],
) -> anyhow::Result<()> {
    Ok(Session::from_backend(backend.clone()).sort_by_key(keys, vals, None)?)
}

/// Apply `perm` (out-of-place gather) to `xs`.
pub fn apply_permutation<T: Copy>(xs: &mut [T], perm: &[u32]) {
    debug_assert_eq!(xs.len(), perm.len());
    let src = xs.to_vec();
    for (dst, &p) in xs.iter_mut().zip(perm.iter()) {
        *dst = src[p as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn hosts() -> Vec<Session> {
        vec![Session::native(), Session::threaded(4)]
    }

    fn check_host<K: KeyGen + PartialEq + DeviceKey>(seed: u64, n: usize) {
        for s in hosts() {
            for dist in [Distribution::Uniform, Distribution::Reverse, Distribution::DupHeavy] {
                let orig: Vec<K> = generate(&mut Prng::new(seed), dist, n);
                let mut xs = orig.clone();
                s.sort(&mut xs, None).unwrap();
                let mut want = orig.clone();
                want.sort_by(|a, b| a.cmp_total(b));
                assert!(xs == want, "{s:?} {dist:?}");
            }
        }
    }

    #[test]
    fn host_backends_i32() {
        check_host::<i32>(1, 10_000);
    }

    #[test]
    fn host_backends_i128() {
        check_host::<i128>(2, 5000);
    }

    #[test]
    fn host_backends_f64() {
        check_host::<f64>(3, 8000);
    }

    #[test]
    fn sort_by_key_carries_payloads() {
        let keys_orig: Vec<i32> = generate(&mut Prng::new(4), Distribution::Uniform, 3000);
        for s in hosts() {
            let mut keys = keys_orig.clone();
            let mut vals: Vec<usize> = (0..keys.len()).collect();
            s.sort_by_key(&mut keys, &mut vals, None).unwrap();
            assert!(is_sorted_total(&keys));
            for (k, v) in keys.iter().zip(&vals) {
                assert_eq!(*k, keys_orig[*v]);
            }
        }
    }

    #[test]
    fn stability_of_by_key() {
        let keys_orig = vec![3i32, 1, 3, 1, 3];
        let mut keys = keys_orig.clone();
        let mut vals: Vec<usize> = (0..5).collect();
        Session::native().sort_by_key(&mut keys, &mut vals, None).unwrap();
        assert_eq!(keys, vec![1, 1, 3, 3, 3]);
        assert_eq!(vals, vec![1, 3, 0, 2, 4]); // equal keys keep input order
    }

    #[test]
    fn permutation_application() {
        let mut xs = vec![10, 20, 30];
        apply_permutation(&mut xs, &[2, 0, 1]);
        assert_eq!(xs, vec![30, 10, 20]);
    }

    // The shim surface stays behaviour-identical while the tree
    // migrates, except the two documented typed-error fixes (i128 on
    // the device sort, `sortperm_lowmem` on the device backend —
    // DESIGN.md §12); session_api.rs asserts the equivalence matrix.
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_sort() {
        let mut xs = vec![4i32, 1, 3, 2];
        sort(&Backend::Native, &mut xs).unwrap();
        assert_eq!(xs, vec![1, 2, 3, 4]);
    }
}
