//! Table II arithmetic kernel engines: Radial Basis Function (§III-A)
//! and Lennard-Jones-Gauss potential (§III-B).
//!
//! Host variants mirror the paper's implementation matrix:
//! * `rbf` / `ljg` — integer powers expanded to multiplications (what
//!   Julia emits; the "Julia Base" and "C (hand-written powf)" rows).
//! * `ljg_powf` — calls `powf` like naive portable C; the paper found
//!   GCC/Clang emit 10 `powf` calls here, costing up to 5.7× on ARM. The
//!   Table II bench reproduces that C-vs-Julia consistency story.
//! * Threaded versions ("C OpenMP" / AK-CPU rows) via worker-count knobs.
//! * Device versions run the Pallas artifacts (`DeviceOps::{rbf,ljg}_f32`).
//!
//! Dispatch lives on [`crate::session::Session::rbf`] /
//! [`crate::session::Session::ljg`] /
//! [`crate::session::Session::ljg_powf`]; this module keeps the host
//! engines plus `#[deprecated]` free-function shims.

use crate::backend::Backend;
use crate::session::Session;

/// Runtime LJG constants (passed at runtime so constant propagation
/// can't fold them — paper §III-B).
#[derive(Clone, Copy, Debug)]
pub struct LjgConsts {
    /// Well depth ε.
    pub epsilon: f32,
    /// Length scale σ.
    pub sigma: f32,
    /// Gaussian centre r₀.
    pub r0: f32,
    /// Interaction cutoff radius.
    pub cutoff: f32,
}

impl Default for LjgConsts {
    fn default() -> Self {
        // The paper's constants: epsilon=1, sigma=1, r0=1.5, cutoff=3.
        Self { epsilon: 1.0, sigma: 1.0, r0: 1.5, cutoff: 3.0 }
    }
}

/// RBF over packed `(3, n)` coordinates `[x.., y.., z..]` → `(n,)`:
/// `rbf[i] = exp(-1 / (1 - sqrt(x² + y² + z²)))` (paper Algorithm 4).
#[deprecated(note = "use `Session::rbf` (`accelkern::session`)")]
pub fn rbf(backend: &Backend, pts: &[f32]) -> anyhow::Result<Vec<f32>> {
    Ok(Session::from_backend(backend.clone()).rbf(pts, None)?)
}

/// LJG potential over packed `(3, n)` position arrays (Algorithm 5),
/// integer powers expanded to multiplications.
#[deprecated(note = "use `Session::ljg` (`accelkern::session`)")]
pub fn ljg(backend: &Backend, p1: &[f32], p2: &[f32], c: LjgConsts) -> anyhow::Result<Vec<f32>> {
    Ok(Session::from_backend(backend.clone()).ljg(p1, p2, c, None)?)
}

/// The naive-C variant: `powf(sigma/r, 6)` etc. — iterative libm powers,
/// the pathology the paper measured (Table II "C" row, §III-B analysis).
/// Host-only (no artifact is built for it; the AOT path always expands).
#[deprecated(note = "use `Session::ljg_powf` (`accelkern::session`)")]
pub fn ljg_powf(
    backend: &Backend,
    p1: &[f32],
    p2: &[f32],
    c: LjgConsts,
) -> anyhow::Result<Vec<f32>> {
    Ok(Session::from_backend(backend.clone()).ljg_powf(p1, p2, c, None)?)
}

/// The RBF host engine over `threads` workers (1 = the paper's
/// single-thread rows).
pub(crate) fn rbf_host(pts: &[f32], n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    let ranges = crate::backend::threaded::split_ranges(n, threads.max(1));
    crate::backend::parallel_chunks(&mut out, threads.max(1), |ci, chunk| {
        let r = ranges[ci].clone();
        rbf_range(pts, n, chunk, r);
    });
    out
}

#[inline]
fn rbf_range(pts: &[f32], n: usize, out: &mut [f32], r: std::ops::Range<usize>) {
    let (xs, ys, zs) = (&pts[..n], &pts[n..2 * n], &pts[2 * n..]);
    for (o, i) in out.iter_mut().zip(r) {
        // x*x not powf: the transformation every compiler managed for ^2.
        let rad = (xs[i] * xs[i] + ys[i] * ys[i] + zs[i] * zs[i]).sqrt();
        *o = (-1.0 / (1.0 - rad)).exp();
    }
}

/// The expanded-powers LJG host engine over `threads` workers.
pub(crate) fn ljg_host(p1: &[f32], p2: &[f32], n: usize, c: LjgConsts, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    let ranges = crate::backend::threaded::split_ranges(n, threads.max(1));
    crate::backend::parallel_chunks(&mut out, threads.max(1), |ci, chunk| {
        ljg_range(p1, p2, n, c, chunk, ranges[ci].clone());
    });
    out
}

#[inline]
fn ljg_range(
    p1: &[f32],
    p2: &[f32],
    n: usize,
    c: LjgConsts,
    out: &mut [f32],
    r: std::ops::Range<usize>,
) {
    for (o, i) in out.iter_mut().zip(r) {
        let dx = p1[i] - p2[i];
        let dy = p1[n + i] - p2[n + i];
        let dz = p1[2 * n + i] - p2[2 * n + i];
        let rad = (dx * dx + dy * dy + dz * dz).sqrt();
        *o = if rad < c.cutoff {
            let sr = c.sigma / rad;
            let sr3 = sr * sr * sr;
            let sr6 = sr3 * sr3;
            let sr12 = sr6 * sr6;
            let gauss =
                c.epsilon * (-((rad - c.r0) * (rad - c.r0)) / (2.0 * c.sigma * c.sigma)).exp();
            4.0 * c.epsilon * (sr12 - sr6) - gauss
        } else {
            0.0
        };
    }
}

/// The naive-C (`powf`) LJG host engine over `threads` workers.
pub(crate) fn ljg_powf_host(
    p1: &[f32],
    p2: &[f32],
    n: usize,
    c: LjgConsts,
    threads: usize,
) -> Vec<f32> {
    let body = |out: &mut [f32], r: std::ops::Range<usize>| {
        for (o, i) in out.iter_mut().zip(r) {
            let dx = p1[i] - p2[i];
            let dy = p1[n + i] - p2[n + i];
            let dz = p1[2 * n + i] - p2[2 * n + i];
            let rad = (dx * dx + dy * dy + dz * dz).sqrt();
            *o = if rad < c.cutoff {
                let sr6 = (c.sigma / rad).powf(6.0);
                let sr12 = (c.sigma / rad).powf(12.0);
                let gauss =
                    c.epsilon * (-(rad - c.r0).powf(2.0) / (2.0 * c.sigma.powf(2.0))).exp();
                4.0 * c.epsilon * (sr12 - sr6) - gauss
            } else {
                0.0
            };
        }
    };
    let mut out = vec![0.0f32; n];
    let ranges = crate::backend::threaded::split_ranges(n, threads.max(1));
    crate::backend::parallel_chunks(&mut out, threads.max(1), |ci, chunk| {
        body(chunk, ranges[ci].clone());
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::AkError;
    use crate::util::Prng;
    use crate::workload::{points_f32, positions_f32};

    #[test]
    fn rbf_native_vs_threaded() {
        let pts = points_f32(&mut Prng::new(1), 10_000);
        let a = Session::native().rbf(&pts, None).unwrap();
        let b = Session::threaded(4).rbf(&pts, None).unwrap();
        assert_eq!(a.len(), 10_000);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // Spot-check one value.
        let r = (pts[0] * pts[0] + pts[10_000] * pts[10_000] + pts[20_000] * pts[20_000]).sqrt();
        assert!((a[0] - (-1.0 / (1.0 - r)).exp()).abs() < 1e-6);
    }

    #[test]
    fn ljg_powf_matches_expanded() {
        let p1 = positions_f32(&mut Prng::new(2), 5000, 4.0);
        let p2 = positions_f32(&mut Prng::new(3), 5000, 4.0);
        let c = LjgConsts::default();
        let s = Session::native();
        let a = s.ljg(&p1, &p2, c, None).unwrap();
        let b = s.ljg_powf(&p1, &p2, c, None).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() <= 2e-3 * x.abs().max(1.0), "i={i}: {x} vs {y}");
        }
    }

    #[test]
    fn ljg_cutoff_zeroes() {
        // Two atoms farther apart than cutoff must contribute 0.
        let p1 = vec![0.0f32, 0.0, 0.0]; // one atom at origin (3,1) layout
        let p2 = vec![10.0f32, 0.0, 0.0];
        let out = Session::native().ljg(&p1, &p2, LjgConsts::default(), None).unwrap();
        assert_eq!(out, vec![0.0]);
    }

    #[test]
    fn ljg_branch_sides_differ() {
        let c = LjgConsts::default();
        let p1 = vec![0.0f32, 0.0, 0.0];
        let p2 = vec![1.2f32, 0.0, 0.0]; // inside cutoff
        let out = Session::native().ljg(&p1, &p2, c, None).unwrap();
        assert!(out[0] != 0.0);
    }

    #[test]
    fn rejects_ragged_layouts_with_typed_errors() {
        let s = Session::native();
        assert!(matches!(s.rbf(&[1.0, 2.0], None), Err(AkError::ShapeMismatch { .. })));
        assert!(matches!(
            s.ljg(&[1.0; 3], &[1.0; 6], LjgConsts::default(), None),
            Err(AkError::ShapeMismatch { .. })
        ));
    }
}
