//! Table II arithmetic kernels: Radial Basis Function (§III-A) and
//! Lennard-Jones-Gauss potential (§III-B).
//!
//! Host variants mirror the paper's implementation matrix:
//! * [`rbf`] / [`ljg`] — integer powers expanded to multiplications (what
//!   Julia emits; the "Julia Base" and "C (hand-written powf)" rows).
//! * [`ljg_powf`] — calls `powf` like naive portable C; the paper found
//!   GCC/Clang emit 10 `powf` calls here, costing up to 5.7× on ARM. The
//!   Table II bench reproduces that C-vs-Julia consistency story.
//! * Threaded versions ("C OpenMP" / AK-CPU rows) via `Backend::Threaded`.
//! * Device versions run the Pallas artifacts (`DeviceOps::{rbf,ljg}_f32`).

use crate::backend::Backend;

/// Runtime LJG constants (passed at runtime so constant propagation can't
/// fold them — paper §III-B).
#[derive(Clone, Copy, Debug)]
pub struct LjgConsts {
    /// Well depth ε.
    pub epsilon: f32,
    /// Length scale σ.
    pub sigma: f32,
    /// Gaussian centre r₀.
    pub r0: f32,
    /// Interaction cutoff radius.
    pub cutoff: f32,
}

impl Default for LjgConsts {
    fn default() -> Self {
        // The paper's constants: epsilon=1, sigma=1, r0=1.5, cutoff=3.
        Self { epsilon: 1.0, sigma: 1.0, r0: 1.5, cutoff: 3.0 }
    }
}

/// RBF over packed `(3, n)` coordinates `[x.., y.., z..]` → `(n,)`:
/// `rbf[i] = exp(-1 / (1 - sqrt(x² + y² + z²)))` (paper Algorithm 4).
pub fn rbf(backend: &Backend, pts: &[f32]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(pts.len() % 3 == 0, "(3, n) packed layout required");
    let n = pts.len() / 3;
    match backend {
        Backend::Native => {
            let mut out = vec![0.0f32; n];
            rbf_range(pts, n, &mut out, 0..n);
            Ok(out)
        }
        Backend::Threaded(t) => Ok(rbf_threaded(pts, n, *t)),
        Backend::Device(dev) => dev.rbf_f32(pts),
        // The (3, n) packed rows cannot split contiguously between two
        // engines without a repack; the hybrid path runs on the host pool
        // (co-processing covers the index-splittable primitives —
        // DESIGN.md §10).
        Backend::Hybrid(h) => Ok(rbf_threaded(pts, n, h.host_threads.max(1))),
    }
}

fn rbf_threaded(pts: &[f32], n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    let ranges = crate::backend::threaded::split_ranges(n, threads);
    crate::backend::parallel_chunks(&mut out, threads, |ci, chunk| {
        let r = ranges[ci].clone();
        rbf_range(pts, n, chunk, r);
    });
    out
}

#[inline]
fn rbf_range(pts: &[f32], n: usize, out: &mut [f32], r: std::ops::Range<usize>) {
    let (xs, ys, zs) = (&pts[..n], &pts[n..2 * n], &pts[2 * n..]);
    for (o, i) in out.iter_mut().zip(r) {
        // x*x not powf: the transformation every compiler managed for ^2.
        let rad = (xs[i] * xs[i] + ys[i] * ys[i] + zs[i] * zs[i]).sqrt();
        *o = (-1.0 / (1.0 - rad)).exp();
    }
}

/// LJG potential over packed `(3, n)` position arrays (Algorithm 5),
/// integer powers expanded to multiplications.
pub fn ljg(
    backend: &Backend,
    p1: &[f32],
    p2: &[f32],
    c: LjgConsts,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(p1.len() == p2.len() && p1.len() % 3 == 0);
    let n = p1.len() / 3;
    match backend {
        Backend::Native => {
            let mut out = vec![0.0f32; n];
            ljg_range(p1, p2, n, c, &mut out, 0..n);
            Ok(out)
        }
        Backend::Threaded(t) => Ok(ljg_threaded(p1, p2, n, c, *t)),
        Backend::Device(dev) => dev.ljg_f32(p1, p2, [c.epsilon, c.sigma, c.r0, c.cutoff]),
        // Same packed-layout rule as `rbf`: hybrid runs on the host pool.
        Backend::Hybrid(h) => Ok(ljg_threaded(p1, p2, n, c, h.host_threads.max(1))),
    }
}

fn ljg_threaded(p1: &[f32], p2: &[f32], n: usize, c: LjgConsts, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    let ranges = crate::backend::threaded::split_ranges(n, threads);
    crate::backend::parallel_chunks(&mut out, threads, |ci, chunk| {
        ljg_range(p1, p2, n, c, chunk, ranges[ci].clone());
    });
    out
}

#[inline]
fn ljg_range(
    p1: &[f32],
    p2: &[f32],
    n: usize,
    c: LjgConsts,
    out: &mut [f32],
    r: std::ops::Range<usize>,
) {
    for (o, i) in out.iter_mut().zip(r) {
        let dx = p1[i] - p2[i];
        let dy = p1[n + i] - p2[n + i];
        let dz = p1[2 * n + i] - p2[2 * n + i];
        let rad = (dx * dx + dy * dy + dz * dz).sqrt();
        *o = if rad < c.cutoff {
            let sr = c.sigma / rad;
            let sr3 = sr * sr * sr;
            let sr6 = sr3 * sr3;
            let sr12 = sr6 * sr6;
            let gauss =
                c.epsilon * (-((rad - c.r0) * (rad - c.r0)) / (2.0 * c.sigma * c.sigma)).exp();
            4.0 * c.epsilon * (sr12 - sr6) - gauss
        } else {
            0.0
        };
    }
}

/// The naive-C variant: `powf(sigma/r, 6)` etc. — iterative libm powers,
/// the pathology the paper measured (Table II "C" row, §III-B analysis).
/// Host-only (no artifact is built for it; the AOT path always expands).
pub fn ljg_powf(backend: &Backend, p1: &[f32], p2: &[f32], c: LjgConsts) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(p1.len() == p2.len() && p1.len() % 3 == 0);
    let n = p1.len() / 3;
    let body = |out: &mut [f32], r: std::ops::Range<usize>| {
        for (o, i) in out.iter_mut().zip(r) {
            let dx = p1[i] - p2[i];
            let dy = p1[n + i] - p2[n + i];
            let dz = p1[2 * n + i] - p2[2 * n + i];
            let rad = (dx * dx + dy * dy + dz * dz).sqrt();
            *o = if rad < c.cutoff {
                let sr6 = (c.sigma / rad).powf(6.0);
                let sr12 = (c.sigma / rad).powf(12.0);
                let gauss = c.epsilon
                    * (-(rad - c.r0).powf(2.0) / (2.0 * c.sigma.powf(2.0))).exp();
                4.0 * c.epsilon * (sr12 - sr6) - gauss
            } else {
                0.0
            };
        }
    };
    let mut out = vec![0.0f32; n];
    let threaded = |out: &mut Vec<f32>, t: usize| {
        let ranges = crate::backend::threaded::split_ranges(n, t);
        crate::backend::parallel_chunks(out, t, |ci, chunk| {
            body(chunk, ranges[ci].clone());
        });
    };
    match backend {
        Backend::Native | Backend::Device(_) => body(&mut out, 0..n),
        Backend::Threaded(t) => threaded(&mut out, *t),
        Backend::Hybrid(h) => threaded(&mut out, h.host_threads.max(1)),
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{points_f32, positions_f32};

    #[test]
    fn rbf_native_vs_threaded() {
        let pts = points_f32(&mut Prng::new(1), 10_000);
        let a = rbf(&Backend::Native, &pts).unwrap();
        let b = rbf(&Backend::Threaded(4), &pts).unwrap();
        assert_eq!(a.len(), 10_000);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // Spot-check one value.
        let r = (pts[0] * pts[0] + pts[10_000] * pts[10_000] + pts[20_000] * pts[20_000]).sqrt();
        assert!((a[0] - (-1.0 / (1.0 - r)).exp()).abs() < 1e-6);
    }

    #[test]
    fn ljg_powf_matches_expanded() {
        let p1 = positions_f32(&mut Prng::new(2), 5000, 4.0);
        let p2 = positions_f32(&mut Prng::new(3), 5000, 4.0);
        let c = LjgConsts::default();
        let a = ljg(&Backend::Native, &p1, &p2, c).unwrap();
        let b = ljg_powf(&Backend::Native, &p1, &p2, c).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() <= 2e-3 * x.abs().max(1.0), "i={i}: {x} vs {y}");
        }
    }

    #[test]
    fn ljg_cutoff_zeroes() {
        // Two atoms farther apart than cutoff must contribute 0.
        let p1 = vec![0.0f32, 0.0, 0.0]; // one atom at origin (3,1) layout
        let p2 = vec![10.0f32, 0.0, 0.0];
        let out = ljg(&Backend::Native, &p1, &p2, LjgConsts::default()).unwrap();
        assert_eq!(out, vec![0.0]);
    }

    #[test]
    fn ljg_branch_sides_differ() {
        let c = LjgConsts::default();
        let p1 = vec![0.0f32, 0.0, 0.0];
        let p2 = vec![1.2f32, 0.0, 0.0]; // inside cutoff
        let out = ljg(&Backend::Native, &p1, &p2, c).unwrap();
        assert!(out[0] != 0.0);
    }

    #[test]
    fn rejects_ragged_layouts() {
        assert!(rbf(&Backend::Native, &[1.0, 2.0]).is_err());
        assert!(ljg(&Backend::Native, &[1.0; 3], &[1.0; 6], LjgConsts::default()).is_err());
    }
}
