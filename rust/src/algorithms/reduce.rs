//! `reduce` / `mapreduce` (paper §II-B).
//!
//! The device path reduces per-tile on the accelerator; the
//! `switch_below` argument (paper's device-sync-masking optimisation)
//! routes small inputs through the partials artifact and finishes the
//! fold on the host, skipping the device-side tree pass.

use crate::backend::{Backend, DeviceKey};

/// Supported reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceKind {
    /// Sum (wrapping for integers).
    Add,
    /// Minimum.
    Min,
    /// Maximum.
    Max,
}

impl ReduceKind {
    fn op_name(self) -> &'static str {
        match self {
            ReduceKind::Add => "add",
            ReduceKind::Min => "min",
            ReduceKind::Max => "max",
        }
    }
}

/// Numeric glue for reductions (identity + fold per operator).
pub trait Reducible: DeviceKey {
    /// The operator's identity element (0, MAX, MIN respectively).
    fn identity(kind: ReduceKind) -> Self;
    /// Apply the operator to two values.
    fn fold(kind: ReduceKind, a: Self, b: Self) -> Self;
}

macro_rules! reducible_int {
    ($ty:ty) => {
        impl Reducible for $ty {
            fn identity(kind: ReduceKind) -> Self {
                match kind {
                    ReduceKind::Add => 0,
                    ReduceKind::Min => <$ty>::MAX,
                    ReduceKind::Max => <$ty>::MIN,
                }
            }
            fn fold(kind: ReduceKind, a: Self, b: Self) -> Self {
                match kind {
                    ReduceKind::Add => a.wrapping_add(b),
                    ReduceKind::Min => a.min(b),
                    ReduceKind::Max => a.max(b),
                }
            }
        }
    };
}

macro_rules! reducible_float {
    ($ty:ty) => {
        impl Reducible for $ty {
            fn identity(kind: ReduceKind) -> Self {
                match kind {
                    ReduceKind::Add => 0.0,
                    ReduceKind::Min => <$ty>::INFINITY,
                    ReduceKind::Max => <$ty>::NEG_INFINITY,
                }
            }
            fn fold(kind: ReduceKind, a: Self, b: Self) -> Self {
                match kind {
                    ReduceKind::Add => a + b,
                    ReduceKind::Min => a.min(b),
                    ReduceKind::Max => a.max(b),
                }
            }
        }
    };
}

reducible_int!(i16);
reducible_int!(i32);
reducible_int!(i64);
reducible_int!(i128);
reducible_float!(f32);
reducible_float!(f64);

/// Reduce `xs` with `kind`. `switch_below`: inputs with at most this many
/// elements finish the fold on the host (device partials only).
///
/// ```
/// use accelkern::algorithms::{reduce, ReduceKind};
/// use accelkern::backend::Backend;
/// let xs = vec![3i64, -1, 4, 1, 5];
/// assert_eq!(reduce(&Backend::Native, &xs, ReduceKind::Add, 0).unwrap(), 12);
/// assert_eq!(reduce(&Backend::Threaded(2), &xs, ReduceKind::Min, 0).unwrap(), -1);
/// assert_eq!(reduce(&Backend::Native, &xs, ReduceKind::Max, 0).unwrap(), 5);
/// ```
pub fn reduce<K: Reducible>(
    backend: &Backend,
    xs: &[K],
    kind: ReduceKind,
    switch_below: usize,
) -> anyhow::Result<K> {
    match backend {
        Backend::Native => Ok(host_reduce(xs, kind)),
        Backend::Threaded(t) => {
            let partials =
                crate::backend::parallel_for_each_chunk(xs.len(), *t, |r| host_reduce(&xs[r], kind));
            Ok(partials.into_iter().fold(K::identity(kind), |a, b| K::fold(kind, a, b)))
        }
        // Co-processing: both engines reduce disjoint shards concurrently,
        // partials fold on the host (DESIGN.md §10).
        Backend::Hybrid(h) => crate::hybrid::co_reduce(h, xs, kind, switch_below),
        Backend::Device(dev) => {
            if !K::XLA {
                return Ok(host_reduce(xs, kind));
            }
            if kind == ReduceKind::Add && xs.len() <= switch_below {
                // switch_below: device emits per-tile partials, host folds.
                return dev.reduce_partials_add_shim(xs);
            }
            dev.reduce(xs, kind.op_name(), K::identity(kind), |a, b| K::fold(kind, a, b))
        }
    }
}

/// `mapreduce(f, op, xs)`: host closures on host backends; the device
/// path exposes the AOT-compiled named maps (paper: arbitrary lambdas are
/// inlined at transpile time — our transpile time is `make artifacts`).
pub fn mapreduce<K: Reducible, M>(
    backend: &Backend,
    xs: &[K],
    map: M,
    kind: ReduceKind,
) -> anyhow::Result<K>
where
    M: Fn(K) -> K + Sync,
{
    match backend {
        Backend::Native => Ok(host_mapreduce(xs, &map, kind)),
        Backend::Threaded(t) => {
            let partials = crate::backend::parallel_for_each_chunk(xs.len(), *t, |r| {
                host_mapreduce(&xs[r], &map, kind)
            });
            Ok(partials.into_iter().fold(K::identity(kind), |a, b| K::fold(kind, a, b)))
        }
        // Arbitrary host closures cannot cross the AOT boundary; the
        // device variant is the named-map artifact (`mapreduce_sumsq`
        // etc., see `DeviceOps`). Host-execute here.
        Backend::Device(_) => Ok(host_mapreduce(xs, &map, kind)),
        // Same AOT-boundary rule: hybrid mapreduce runs on the host pool.
        Backend::Hybrid(h) => {
            let t = h.host_threads.max(1);
            let partials = crate::backend::parallel_for_each_chunk(xs.len(), t, |r| {
                host_mapreduce(&xs[r], &map, kind)
            });
            Ok(partials.into_iter().fold(K::identity(kind), |a, b| K::fold(kind, a, b)))
        }
    }
}

fn host_reduce<K: Reducible>(xs: &[K], kind: ReduceKind) -> K {
    xs.iter().copied().fold(K::identity(kind), |a, b| K::fold(kind, a, b))
}

fn host_mapreduce<K: Reducible, M: Fn(K) -> K>(xs: &[K], map: &M, kind: ReduceKind) -> K {
    xs.iter().copied().map(map).fold(K::identity(kind), |a, b| K::fold(kind, a, b))
}

// Small shim so `reduce` can call the partials path without naming the
// Add/Default bounds at the call site.
impl crate::backend::DeviceOps {
    fn reduce_partials_add_shim<K: Reducible>(&self, xs: &[K]) -> anyhow::Result<K> {
        // Only Add reaches here; identity(Add) is the additive zero.
        let mut acc = K::identity(ReduceKind::Add);
        // Reuse the generic reduce with op add on partials artifacts when
        // available; otherwise a plain host fold (semantically identical).
        match self.reduce_partials_add_raw(xs) {
            Ok(parts) => {
                for p in parts {
                    acc = K::fold(ReduceKind::Add, acc, p);
                }
                Ok(acc)
            }
            Err(_) => Ok(host_reduce(xs, ReduceKind::Add)),
        }
    }

    fn reduce_partials_add_raw<K: Reducible>(&self, xs: &[K]) -> anyhow::Result<Vec<K>> {
        use crate::backend::device::artifact_name;
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        let plan = self.registry().plan("reduce_partials_add", K::ELEM, xs.len())?;
        let cap = plan.chunk_capacity();
        let mut out = Vec::new();
        for chunk in xs.chunks(cap) {
            let mut padded = chunk.to_vec();
            padded.resize(cap, K::identity(ReduceKind::Add));
            let res = self.registry().runtime().execute(
                &artifact_name("reduce_partials_add", K::ELEM, cap),
                &[K::to_literal(&padded)?],
            )?;
            out.extend(K::from_literal(&res[0])?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn host_reduce_matches_iter() {
        let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 10_000);
        for b in [Backend::Native, Backend::Threaded(4)] {
            let sum = reduce(&b, &xs, ReduceKind::Add, 0).unwrap();
            let want: i64 = xs.iter().fold(0i64, |a, &b| a.wrapping_add(b));
            assert_eq!(sum, want, "{b:?}");
            assert_eq!(reduce(&b, &xs, ReduceKind::Min, 0).unwrap(), *xs.iter().min().unwrap());
            assert_eq!(reduce(&b, &xs, ReduceKind::Max, 0).unwrap(), *xs.iter().max().unwrap());
        }
    }

    #[test]
    fn empty_input_identity() {
        let e: Vec<f32> = vec![];
        assert_eq!(reduce(&Backend::Native, &e, ReduceKind::Add, 0).unwrap(), 0.0);
        assert_eq!(reduce(&Backend::Native, &e, ReduceKind::Min, 0).unwrap(), f32::INFINITY);
    }

    #[test]
    fn mapreduce_square_sum() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let got = mapreduce(&Backend::Threaded(3), &xs, |x| x * x, ReduceKind::Add).unwrap();
        let want: f64 = xs.iter().map(|x| x * x).sum();
        assert!((got - want).abs() < 1e-9 * want);
    }

    #[test]
    fn i128_host_everywhere() {
        let xs: Vec<i128> = generate(&mut Prng::new(2), Distribution::Uniform, 1000);
        let want: i128 = xs.iter().fold(0i128, |a, &b| a.wrapping_add(b));
        assert_eq!(reduce(&Backend::Native, &xs, ReduceKind::Add, 0).unwrap(), want);
    }
}
