//! `reduce` / `mapreduce` engines (paper §II-B).
//!
//! The device path reduces per-tile on the accelerator; the
//! `switch_below` launch knob (paper's device-sync-masking optimisation)
//! routes small inputs through the partials artifact and finishes the
//! fold on the host, skipping the device-side tree pass.
//!
//! Dispatch lives on [`crate::session::Session::reduce`] /
//! [`crate::session::Session::mapreduce`]; this module keeps the
//! numeric glue ([`Reducible`]), the host folds and `#[deprecated]`
//! free-function shims.

use crate::backend::{Backend, DeviceKey};
use crate::session::{Launch, Session};

/// Supported reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceKind {
    /// Sum (wrapping for integers).
    Add,
    /// Minimum.
    Min,
    /// Maximum.
    Max,
}

impl ReduceKind {
    /// Artifact-family suffix of the operator (`reduce_{add,min,max}`).
    pub(crate) fn op_name(self) -> &'static str {
        match self {
            ReduceKind::Add => "add",
            ReduceKind::Min => "min",
            ReduceKind::Max => "max",
        }
    }
}

/// Numeric glue for reductions (identity + fold per operator).
pub trait Reducible: DeviceKey {
    /// The operator's identity element (0, MAX, MIN respectively).
    fn identity(kind: ReduceKind) -> Self;
    /// Apply the operator to two values.
    fn fold(kind: ReduceKind, a: Self, b: Self) -> Self;
}

macro_rules! reducible_int {
    ($ty:ty) => {
        impl Reducible for $ty {
            fn identity(kind: ReduceKind) -> Self {
                match kind {
                    ReduceKind::Add => 0,
                    ReduceKind::Min => <$ty>::MAX,
                    ReduceKind::Max => <$ty>::MIN,
                }
            }
            fn fold(kind: ReduceKind, a: Self, b: Self) -> Self {
                match kind {
                    ReduceKind::Add => a.wrapping_add(b),
                    ReduceKind::Min => a.min(b),
                    ReduceKind::Max => a.max(b),
                }
            }
        }
    };
}

macro_rules! reducible_float {
    ($ty:ty) => {
        impl Reducible for $ty {
            fn identity(kind: ReduceKind) -> Self {
                match kind {
                    ReduceKind::Add => 0.0,
                    ReduceKind::Min => <$ty>::INFINITY,
                    ReduceKind::Max => <$ty>::NEG_INFINITY,
                }
            }
            fn fold(kind: ReduceKind, a: Self, b: Self) -> Self {
                match kind {
                    ReduceKind::Add => a + b,
                    ReduceKind::Min => a.min(b),
                    ReduceKind::Max => a.max(b),
                }
            }
        }
    };
}

reducible_int!(i16);
reducible_int!(i32);
reducible_int!(i64);
reducible_int!(i128);
reducible_float!(f32);
reducible_float!(f64);

/// Reduce `xs` with `kind`. `switch_below`: inputs with at most this
/// many elements finish the fold on the host (device partials only) —
/// forwarded as the `Launch::switch_below` knob.
#[deprecated(note = "use `Session::reduce` with `Launch::switch_below` (`accelkern::session`)")]
pub fn reduce<K: Reducible>(
    backend: &Backend,
    xs: &[K],
    kind: ReduceKind,
    switch_below: usize,
) -> anyhow::Result<K> {
    let l = Launch::new().switch_below(switch_below);
    Ok(Session::from_backend(backend.clone()).reduce(xs, kind, Some(&l))?)
}

/// `mapreduce(f, op, xs)`: host closures on host backends; the device
/// path exposes the AOT-compiled named maps (paper: arbitrary lambdas
/// are inlined at transpile time — our transpile time is
/// `make artifacts`).
#[deprecated(note = "use `Session::mapreduce` (`accelkern::session`)")]
pub fn mapreduce<K: Reducible, M>(
    backend: &Backend,
    xs: &[K],
    map: M,
    kind: ReduceKind,
) -> anyhow::Result<K>
where
    M: Fn(K) -> K + Sync,
{
    Ok(Session::from_backend(backend.clone()).mapreduce(xs, map, kind, None)?)
}

/// Sequential fold over the operator (the per-chunk engine).
pub(crate) fn host_reduce<K: Reducible>(xs: &[K], kind: ReduceKind) -> K {
    xs.iter().copied().fold(K::identity(kind), |a, b| K::fold(kind, a, b))
}

/// Sequential map+fold (the per-chunk `mapreduce` engine).
pub(crate) fn host_mapreduce<K: Reducible, M: Fn(K) -> K>(xs: &[K], map: &M, kind: ReduceKind) -> K {
    xs.iter().copied().map(map).fold(K::identity(kind), |a, b| K::fold(kind, a, b))
}

// Small shim so the session `reduce` can call the partials path without
// naming the Add/Default bounds at the call site.
impl crate::backend::DeviceOps {
    pub(crate) fn reduce_partials_add_shim<K: Reducible>(&self, xs: &[K]) -> anyhow::Result<K> {
        // Only Add reaches here; identity(Add) is the additive zero.
        let mut acc = K::identity(ReduceKind::Add);
        // Reuse the generic reduce with op add on partials artifacts when
        // available; otherwise a plain host fold (semantically identical).
        match self.reduce_partials_add_raw(xs) {
            Ok(parts) => {
                for p in parts {
                    acc = K::fold(ReduceKind::Add, acc, p);
                }
                Ok(acc)
            }
            Err(_) => Ok(host_reduce(xs, ReduceKind::Add)),
        }
    }

    fn reduce_partials_add_raw<K: Reducible>(&self, xs: &[K]) -> anyhow::Result<Vec<K>> {
        use crate::backend::device::artifact_name;
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        let plan = self.registry().plan("reduce_partials_add", K::ELEM, xs.len())?;
        let cap = plan.chunk_capacity();
        let mut out = Vec::new();
        for chunk in xs.chunks(cap) {
            let mut padded = chunk.to_vec();
            padded.resize(cap, K::identity(ReduceKind::Add));
            let res = self.registry().runtime().execute(
                &artifact_name("reduce_partials_add", K::ELEM, cap),
                &[K::to_literal(&padded)?],
            )?;
            out.extend(K::from_literal(&res[0])?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn host_reduce_matches_iter() {
        let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 10_000);
        for s in [Session::native(), Session::threaded(4)] {
            let sum = s.reduce(&xs, ReduceKind::Add, None).unwrap();
            let want: i64 = xs.iter().fold(0i64, |a, &b| a.wrapping_add(b));
            assert_eq!(sum, want, "{s:?}");
            assert_eq!(
                s.reduce(&xs, ReduceKind::Min, None).unwrap(),
                *xs.iter().min().unwrap()
            );
            assert_eq!(
                s.reduce(&xs, ReduceKind::Max, None).unwrap(),
                *xs.iter().max().unwrap()
            );
        }
    }

    #[test]
    fn empty_input_identity() {
        let e: Vec<f32> = vec![];
        let s = Session::native();
        assert_eq!(s.reduce(&e, ReduceKind::Add, None).unwrap(), 0.0);
        assert_eq!(s.reduce(&e, ReduceKind::Min, None).unwrap(), f32::INFINITY);
    }

    #[test]
    fn mapreduce_square_sum() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let got = Session::threaded(3)
            .mapreduce(&xs, |x| x * x, ReduceKind::Add, None)
            .unwrap();
        let want: f64 = xs.iter().map(|x| x * x).sum();
        assert!((got - want).abs() < 1e-9 * want);
    }

    #[test]
    fn i128_host_everywhere() {
        let xs: Vec<i128> = generate(&mut Prng::new(2), Distribution::Uniform, 1000);
        let want: i128 = xs.iter().fold(0i128, |a, &b| a.wrapping_add(b));
        assert_eq!(Session::native().reduce(&xs, ReduceKind::Add, None).unwrap(), want);
    }

    #[test]
    fn reduce_knobs_do_not_change_results() {
        let xs: Vec<i64> = generate(&mut Prng::new(3), Distribution::Uniform, 50_000);
        let want = Session::native().reduce(&xs, ReduceKind::Add, None).unwrap();
        let s = Session::threaded(8);
        for l in [
            Launch::new().max_tasks(2),
            Launch::new().min_elems_per_task(20_000),
            Launch::new().prefer_parallel_threshold(usize::MAX),
        ] {
            assert_eq!(s.reduce(&xs, ReduceKind::Add, Some(&l)).unwrap(), want, "{l:?}");
        }
    }
}
