//! `accumulate` — inclusive/exclusive prefix-scan engines (paper §II-B).
//!
//! Host paths implement the same three-phase block scan the device
//! artifact uses (per-chunk scan, carry scan, carry application), so the
//! threaded variant parallelises exactly like the paper's GPU algorithm.
//!
//! Dispatch lives on [`crate::session::Session::accumulate`]; this
//! module keeps the scan glue ([`ScanAdd`]), the host engines and a
//! `#[deprecated]` free-function shim.

use crate::backend::{Backend, DeviceKey};
use crate::session::Session;

/// Additive scan glue (the artifact family covers op=add; host min/max
/// scans are available through the generic `accumulate_by`).
pub trait ScanAdd: DeviceKey + Default {
    /// Associative addition (wrapping for integers).
    fn add(a: Self, b: Self) -> Self;
}

macro_rules! scan_int {
    ($ty:ty) => {
        impl ScanAdd for $ty {
            fn add(a: Self, b: Self) -> Self {
                a.wrapping_add(b)
            }
        }
    };
}
scan_int!(i16);
scan_int!(i32);
scan_int!(i64);
scan_int!(i128);
impl ScanAdd for f32 {
    fn add(a: Self, b: Self) -> Self {
        a + b
    }
}
impl ScanAdd for f64 {
    fn add(a: Self, b: Self) -> Self {
        a + b
    }
}

/// Prefix-sum of `xs`; `inclusive` selects the scan flavour.
#[deprecated(note = "use `Session::accumulate` (`accelkern::session`)")]
pub fn accumulate<K: ScanAdd + std::ops::Add<Output = K>>(
    backend: &Backend,
    xs: &[K],
    inclusive: bool,
) -> anyhow::Result<Vec<K>> {
    Ok(Session::from_backend(backend.clone()).accumulate(xs, inclusive, None)?)
}

/// Generic-operator host scan (`accumulate(op, ...)` in the paper; the
/// device families cover add, so min/max run on host backends).
pub fn accumulate_by<K: Copy, F: Fn(K, K) -> K>(
    xs: &[K],
    identity: K,
    op: F,
    inclusive: bool,
) -> Vec<K> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = identity;
    for &x in xs {
        if inclusive {
            acc = op(acc, x);
            out.push(acc);
        } else {
            out.push(acc);
            acc = op(acc, x);
        }
    }
    out
}

/// Sequential additive scan (the per-chunk engine).
pub(crate) fn host_scan<K: ScanAdd>(xs: &[K], inclusive: bool) -> Vec<K> {
    accumulate_by(xs, K::default(), K::add, inclusive)
}

/// The three-phase threaded block scan. `seq_below` gates the fan-out
/// (a `Launch` knob at the session layer).
pub(crate) fn threaded_scan<K: ScanAdd>(
    xs: &[K],
    inclusive: bool,
    threads: usize,
    seq_below: usize,
) -> Vec<K> {
    let n = xs.len();
    if threads <= 1 || n < seq_below.max(2) {
        return host_scan(xs, inclusive);
    }
    let ranges = crate::backend::threaded::split_ranges(n, threads);
    // Phase 1: per-chunk inclusive scans (parallel).
    let chunks: Vec<Vec<K>> = crate::backend::parallel_for_each_chunk(n, threads, |r| {
        accumulate_by(&xs[r], K::default(), K::add, true)
    });
    // Phase 2: carries = exclusive scan of chunk totals.
    let mut carries = Vec::with_capacity(ranges.len());
    let mut acc = K::default();
    for c in &chunks {
        carries.push(acc);
        if let Some(&last) = c.last() {
            acc = K::add(acc, last);
        }
    }
    // Phase 3: apply carries (+ exclusivity shift on emit).
    let mut out = Vec::with_capacity(n);
    for (ci, c) in chunks.iter().enumerate() {
        let carry = carries[ci];
        if inclusive {
            out.extend(c.iter().map(|&v| K::add(v, carry)));
        } else {
            for (i, _) in c.iter().enumerate() {
                if i == 0 {
                    out.push(carry);
                } else {
                    out.push(K::add(c[i - 1], carry));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn inclusive_matches_reference() {
        let xs: Vec<i64> = generate(&mut Prng::new(1), Distribution::Uniform, 9001);
        for s in [Session::native(), Session::threaded(4)] {
            let got = s.accumulate(&xs, true, None).unwrap();
            let mut acc = 0i64;
            for (i, &x) in xs.iter().enumerate() {
                acc = acc.wrapping_add(x);
                assert_eq!(got[i], acc, "{s:?} at {i}");
            }
        }
    }

    #[test]
    fn exclusive_shifts() {
        let xs = vec![1i32, 2, 3, 4];
        let got = Session::native().accumulate(&xs, false, None).unwrap();
        assert_eq!(got, vec![0, 1, 3, 6]);
        let got_t = Session::threaded(2).accumulate(&xs, false, None).unwrap();
        assert_eq!(got_t, got);
    }

    #[test]
    fn threaded_equals_native_large() {
        let xs: Vec<f64> = generate(&mut Prng::new(2), Distribution::Gaussian, 50_000)
            .into_iter()
            .map(|x: f64| x % 1000.0)
            .collect();
        let a = Session::native().accumulate(&xs, true, None).unwrap();
        let b = Session::threaded(8).accumulate(&xs, true, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-6 * x.abs().max(1.0));
        }
    }

    #[test]
    fn generic_operator_max_scan() {
        let xs = vec![3i32, 1, 4, 1, 5];
        let got = accumulate_by(&xs, i32::MIN, |a, b| a.max(b), true);
        assert_eq!(got, vec![3, 3, 4, 4, 5]);
    }

    #[test]
    fn empty() {
        let e: Vec<i32> = vec![];
        assert!(Session::native().accumulate(&e, true, None).unwrap().is_empty());
    }
}
