//! `sortperm` / `sortperm_lowmem` engines (paper §II-B): the index
//! permutation that sorts a collection — the primitive the paper notes
//! is *absent* from Kokkos/RAJA without extra copies.
//!
//! * `sortperm`: key-value sort of (keys, iota) — faster, but
//!   materialises a key copy (the paper's "50% more memory" variant).
//! * `sortperm_lowmem`: argsort by sorting indices with a key-indexed
//!   comparator — no key copy, slightly slower (more indirection). Host
//!   engines only: the indexed comparator cannot cross the AOT
//!   boundary, so the device backend returns
//!   `AkError::UnsupportedBackend` (it used to *silently* ignore its
//!   backend argument — typed refusal replaced the silent fallback).
//!
//! Dispatch lives on [`crate::session::Session::sortperm`] /
//! [`crate::session::Session::sortperm_lowmem`]; this module keeps the
//! host engines plus `#[deprecated]` shims.

use crate::backend::{Backend, DeviceKey};
use crate::dtype::SortKey;
use crate::session::Session;

/// The pair-sort host engine: (bit-image, index) pairs — the paper's
/// faster/more-memory variant. `pairs` is the reusable pair buffer
/// (scratch pool); `seq_below` gates the parallel chunk sort.
pub(crate) fn host_sortperm<K: SortKey>(
    xs: &[K],
    threads: usize,
    seq_below: usize,
    pairs: &mut Vec<(u128, u32)>,
) -> Vec<u32> {
    pairs.clear();
    pairs.extend(xs.iter().enumerate().map(|(i, k)| (k.to_bits(), i as u32)));
    if threads > 1 && pairs.len() >= seq_below.max(2) {
        crate::backend::parallel_chunks(pairs, threads, |_, chunk| {
            chunk.sort_unstable();
        });
        // Merge chunk runs (pairs are unique via the index component).
        pairs.sort(); // final pass; already mostly sorted, std sort exploits runs
    } else {
        pairs.sort_unstable();
    }
    pairs.iter().map(|&(_, i)| i).collect()
}

/// The index-sort host engine behind `sortperm_lowmem`: sorts `0..n`
/// with a key-indexed comparator — parallel chunk sorts plus a
/// run-exploiting final pass above the gate, one `sort_by` below it.
pub(crate) fn host_sortperm_lowmem<K: SortKey>(
    xs: &[K],
    threads: usize,
    seq_below: usize,
) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
    let by_key = |a: &u32, b: &u32| {
        xs[*a as usize]
            .cmp_total(&xs[*b as usize])
            .then(a.cmp(b)) // stability tie-break
    };
    if threads > 1 && idx.len() >= seq_below.max(2) {
        crate::backend::parallel_chunks(&mut idx, threads, |_, chunk| {
            chunk.sort_by(by_key);
        });
        idx.sort_by(by_key); // run-exploiting recombine pass
    } else {
        idx.sort_by(by_key);
    }
    idx
}

/// Permutation `p` such that `xs[p[0]] <= xs[p[1]] <= ...` (stable).
#[deprecated(note = "use `Session::sortperm` (`accelkern::session`)")]
pub fn sortperm<K: DeviceKey>(backend: &Backend, xs: &[K]) -> anyhow::Result<Vec<u32>> {
    Ok(Session::from_backend(backend.clone()).sortperm(xs, None)?)
}

/// Lower-memory variant: sorts the index array in place with an indexed
/// comparator (no (key, index) pair buffer). Unlike the pre-session
/// version this *dispatches on the backend* (parallel on host pools)
/// and errors on the device backend instead of silently ignoring it.
#[deprecated(note = "use `Session::sortperm_lowmem` (`accelkern::session`)")]
pub fn sortperm_lowmem<K: SortKey>(backend: &Backend, xs: &[K]) -> anyhow::Result<Vec<u32>> {
    Ok(Session::from_backend(backend.clone()).sortperm_lowmem(xs, None)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn perm_sorts_input() {
        let xs: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 5000);
        for s in [Session::native(), Session::threaded(4)] {
            let p = s.sortperm(&xs, None).unwrap();
            let sorted: Vec<i32> = p.iter().map(|&i| xs[i as usize]).collect();
            assert!(crate::dtype::is_sorted_total(&sorted), "{s:?}");
            // p is a permutation.
            let mut q = p.clone();
            q.sort_unstable();
            assert!(q.iter().enumerate().all(|(i, &v)| v == i as u32));
        }
    }

    #[test]
    fn lowmem_matches_fast_path_on_every_host_engine() {
        let xs: Vec<f64> = generate(&mut Prng::new(2), Distribution::DupHeavy, 9000);
        let a = Session::native().sortperm(&xs, None).unwrap();
        for s in [Session::native(), Session::threaded(4)] {
            let b = s.sortperm_lowmem(&xs, None).unwrap();
            assert_eq!(a, b, "{s:?}");
        }
    }

    #[test]
    fn lowmem_threaded_respects_knobs() {
        let xs: Vec<i64> = generate(&mut Prng::new(7), Distribution::Uniform, 20_000);
        let want = host_sortperm_lowmem(&xs, 1, usize::MAX);
        for t in [2usize, 3, 8] {
            assert_eq!(host_sortperm_lowmem(&xs, t, 64), want, "threads {t}");
        }
    }

    #[test]
    fn stable_on_duplicates() {
        let xs = vec![5i32, 1, 5, 1];
        let p = Session::native().sortperm(&xs, None).unwrap();
        assert_eq!(p, vec![1, 3, 0, 2]);
        let q = Session::threaded(2).sortperm_lowmem(&xs, None).unwrap();
        assert_eq!(q, p);
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<i32> = vec![];
        let s = Session::native();
        assert!(s.sortperm(&e, None).unwrap().is_empty());
        assert_eq!(s.sortperm(&[7i32], None).unwrap(), vec![0]);
        assert!(s.sortperm_lowmem(&e, None).unwrap().is_empty());
    }
}
