//! `sortperm` / `sortperm_lowmem` (paper §II-B): the index permutation
//! that sorts a collection — the primitive the paper notes is *absent*
//! from Kokkos/RAJA without extra copies.
//!
//! * `sortperm`: key-value sort of (keys, iota) — faster, but materialises
//!   a key copy (the paper's "50% more memory" variant).
//! * `sortperm_lowmem`: argsort by sorting indices with a key-indexed
//!   comparator — no key copy, slightly slower (more indirection).
//!
//! Device path uses the `sort_pairs` artifact when the dtype and size
//! class allow; otherwise falls back to the host algorithm.

use crate::backend::{Backend, DeviceKey};
use crate::dtype::SortKey;

/// Permutation `p` such that `xs[p[0]] <= xs[p[1]] <= ...` (stable).
pub fn sortperm<K: DeviceKey>(backend: &Backend, xs: &[K]) -> anyhow::Result<Vec<u32>> {
    anyhow::ensure!(xs.len() <= u32::MAX as usize, "sortperm index space is u32");
    match backend {
        Backend::Native => Ok(host_sortperm(xs, 1)),
        Backend::Threaded(t) => Ok(host_sortperm(xs, *t)),
        Backend::Device(dev) => {
            if K::XLA {
                if let Ok(plan) = dev.registry().plan("sort_pairs", K::ELEM, xs.len()) {
                    if plan.chunks == 1 {
                        let vals: Vec<i32> = (0..xs.len() as i32).collect();
                        let (_, perm) = dev.sort_pairs(xs, &vals)?;
                        return Ok(perm.into_iter().map(|v| v as u32).collect());
                    }
                }
            }
            Ok(host_sortperm(xs, 1))
        }
        // The pair buffer cannot straddle two engines without an extra
        // gather; the hybrid sortperm runs on the host pool
        // (DESIGN.md §10).
        Backend::Hybrid(h) => Ok(host_sortperm(xs, h.host_threads.max(1))),
    }
}

/// Lower-memory variant: sorts the index array in place with an indexed
/// comparator (no (key, index) pair buffer).
pub fn sortperm_lowmem<K: SortKey>(_backend: &Backend, xs: &[K]) -> anyhow::Result<Vec<u32>> {
    anyhow::ensure!(xs.len() <= u32::MAX as usize, "sortperm index space is u32");
    let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        xs[a as usize]
            .cmp_total(&xs[b as usize])
            .then(a.cmp(&b)) // stability tie-break
    });
    Ok(idx)
}

fn host_sortperm<K: SortKey>(xs: &[K], threads: usize) -> Vec<u32> {
    // (key, index) pairs — the paper's faster/more-memory variant.
    let mut pairs: Vec<(u128, u32)> =
        xs.iter().enumerate().map(|(i, k)| (k.to_bits(), i as u32)).collect();
    if threads > 1 && pairs.len() >= 4096 {
        crate::backend::parallel_chunks(&mut pairs, threads, |_, chunk| {
            chunk.sort_unstable();
        });
        // Merge chunk runs (pairs are unique via the index component).
        pairs.sort(); // final pass; already mostly sorted, std sort exploits runs
    } else {
        pairs.sort_unstable();
    }
    pairs.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    #[test]
    fn perm_sorts_input() {
        let xs: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 5000);
        for b in [Backend::Native, Backend::Threaded(4)] {
            let p = sortperm(&b, &xs).unwrap();
            let sorted: Vec<i32> = p.iter().map(|&i| xs[i as usize]).collect();
            assert!(crate::dtype::is_sorted_total(&sorted), "{b:?}");
            // p is a permutation.
            let mut q = p.clone();
            q.sort_unstable();
            assert!(q.iter().enumerate().all(|(i, &v)| v == i as u32));
        }
    }

    #[test]
    fn lowmem_matches_fast_path() {
        let xs: Vec<f64> = generate(&mut Prng::new(2), Distribution::DupHeavy, 3000);
        let a = sortperm(&Backend::Native, &xs).unwrap();
        let b = sortperm_lowmem(&Backend::Native, &xs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn stable_on_duplicates() {
        let xs = vec![5i32, 1, 5, 1];
        let p = sortperm(&Backend::Native, &xs).unwrap();
        assert_eq!(p, vec![1, 3, 0, 2]);
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<i32> = vec![];
        assert!(sortperm(&Backend::Native, &e).unwrap().is_empty());
        assert_eq!(sortperm(&Backend::Native, &[7i32]).unwrap(), vec![0]);
    }
}
