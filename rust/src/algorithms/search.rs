//! `searchsortedfirst` / `searchsortedlast` engines (paper §II-B) — the
//! lower/upper-bound primitives SIHSort's partition step runs on, and
//! the ones the paper calls out as missing from Kokkos/RAJA.
//!
//! Dispatch lives on [`crate::session::Session::searchsorted_first`] /
//! [`crate::session::Session::searchsorted_last`]; this module keeps the
//! host engine plus `#[deprecated]` free-function shims.

use crate::backend::{Backend, DeviceKey};
use crate::dtype::SortKey;
use crate::session::Session;

/// Leftmost insertion indices of `needles` into ascending `haystack`.
#[deprecated(note = "use `Session::searchsorted_first` (`accelkern::session`)")]
pub fn searchsorted_first<K: DeviceKey>(
    backend: &Backend,
    haystack: &[K],
    needles: &[K],
) -> anyhow::Result<Vec<u32>> {
    Ok(Session::from_backend(backend.clone()).searchsorted_first(haystack, needles, None)?)
}

/// Rightmost insertion indices (`upper_bound`).
#[deprecated(note = "use `Session::searchsorted_last` (`accelkern::session`)")]
pub fn searchsorted_last<K: DeviceKey>(
    backend: &Backend,
    haystack: &[K],
    needles: &[K],
) -> anyhow::Result<Vec<u32>> {
    Ok(Session::from_backend(backend.clone()).searchsorted_last(haystack, needles, None)?)
}

/// Host binary-search engine: per-needle `partition_point` on the bit
/// image, fanned out over `threads` workers above `seq_below`.
pub(crate) fn host_search<K: SortKey>(
    haystack: &[K],
    needles: &[K],
    side: &str,
    threads: usize,
    seq_below: usize,
) -> Vec<u32> {
    let one = |nd: &K| -> u32 {
        let nb = nd.to_bits();
        let idx = if side == "first" {
            haystack.partition_point(|h| h.to_bits() < nb)
        } else {
            haystack.partition_point(|h| h.to_bits() <= nb)
        };
        idx as u32
    };
    if threads <= 1 || needles.len() < seq_below.max(2) {
        needles.iter().map(one).collect()
    } else {
        crate::backend::parallel_for_each_chunk(needles.len(), threads, |r| {
            needles[r].iter().map(one).collect::<Vec<u32>>()
        })
        .concat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    fn sorted_hay(seed: u64, n: usize) -> Vec<i32> {
        let mut h: Vec<i32> = generate(&mut Prng::new(seed), Distribution::DupHeavy, n);
        h.sort_unstable();
        h
    }

    #[test]
    fn first_last_bracket_duplicates() {
        let hay = vec![1i32, 3, 3, 3, 7];
        let s = Session::native();
        assert_eq!(s.searchsorted_first(&hay, &[3], None).unwrap(), vec![1]);
        assert_eq!(s.searchsorted_last(&hay, &[3], None).unwrap(), vec![4]);
        assert_eq!(s.searchsorted_first(&hay, &[0], None).unwrap(), vec![0]);
        assert_eq!(s.searchsorted_last(&hay, &[9], None).unwrap(), vec![5]);
    }

    #[test]
    fn matches_std_partition_point() {
        let hay = sorted_hay(1, 5000);
        let needles: Vec<i32> = generate(&mut Prng::new(2), Distribution::Uniform, 1000);
        for s in [Session::native(), Session::threaded(4)] {
            let f = s.searchsorted_first(&hay, &needles, None).unwrap();
            let l = s.searchsorted_last(&hay, &needles, None).unwrap();
            for (i, nd) in needles.iter().enumerate() {
                assert_eq!(f[i] as usize, hay.partition_point(|&h| h < *nd));
                assert_eq!(l[i] as usize, hay.partition_point(|&h| h <= *nd));
            }
        }
    }

    #[test]
    fn float_total_order_on_infinities() {
        let hay = vec![f32::NEG_INFINITY, -1.0, 0.0, 1.0, f32::INFINITY];
        let s = Session::native();
        let f = s.searchsorted_first(&hay, &[f32::INFINITY], None).unwrap();
        assert_eq!(f, vec![4]);
        let l = s.searchsorted_last(&hay, &[f32::NEG_INFINITY], None).unwrap();
        assert_eq!(l, vec![1]);
    }

    #[test]
    fn partition_counts_sum_to_n() {
        // The SIHSort property: splitter ranks partition the shard.
        let hay = sorted_hay(3, 4096);
        let splitters = vec![-500_000i32, 0, 500_000];
        let cuts = Session::native().searchsorted_last(&hay, &splitters, None).unwrap();
        assert!(cuts.windows(2).all(|w| w[0] <= w[1]));
        assert!(*cuts.last().unwrap() as usize <= hay.len());
    }
}
