//! `searchsortedfirst` / `searchsortedlast` (paper §II-B) — the
//! lower/upper-bound primitives SIHSort's partition step runs on, and the
//! ones the paper calls out as missing from Kokkos/RAJA.

use crate::backend::{Backend, DeviceKey};
use crate::dtype::SortKey;

/// Leftmost insertion indices of `needles` into ascending `haystack`.
pub fn searchsorted_first<K: DeviceKey>(
    backend: &Backend,
    haystack: &[K],
    needles: &[K],
) -> anyhow::Result<Vec<u32>> {
    dispatch(backend, haystack, needles, "first")
}

/// Rightmost insertion indices (`upper_bound`).
pub fn searchsorted_last<K: DeviceKey>(
    backend: &Backend,
    haystack: &[K],
    needles: &[K],
) -> anyhow::Result<Vec<u32>> {
    dispatch(backend, haystack, needles, "last")
}

fn dispatch<K: DeviceKey>(
    backend: &Backend,
    haystack: &[K],
    needles: &[K],
    side: &str,
) -> anyhow::Result<Vec<u32>> {
    debug_assert!(crate::dtype::is_sorted_total(haystack), "haystack must be sorted");
    match backend {
        Backend::Native => Ok(host_search(haystack, needles, side, 1)),
        Backend::Threaded(t) => Ok(host_search(haystack, needles, side, *t)),
        Backend::Device(dev) => {
            if K::XLA && dev.registry().supports(&format!("searchsorted_{side}"), K::ELEM) {
                // Device artifacts cap the haystack class; oversize falls back.
                if let Ok(plan) =
                    dev.registry().plan(&format!("searchsorted_{side}"), K::ELEM, haystack.len())
                {
                    if plan.chunks == 1 {
                        return dev.searchsorted(haystack, needles, side);
                    }
                }
            }
            Ok(host_search(haystack, needles, side, 1))
        }
        // Co-processing: the needle block splits between engines (both
        // search the same haystack), results concatenate in order
        // (DESIGN.md §10).
        Backend::Hybrid(h) => {
            let split = match h.route(needles.len()) {
                crate::hybrid::CoRoute::Host => {
                    return dispatch(&h.host_backend(), haystack, needles, side)
                }
                crate::hybrid::CoRoute::Device => {
                    return dispatch(&h.device_backend(), haystack, needles, side)
                }
                crate::hybrid::CoRoute::Split(split) => split,
            };
            let host_backend = h.host_backend();
            let dev_backend = h.device_backend();
            let (host_needles, dev_needles) = needles.split_at(split);
            let (host_res, dev_res) = std::thread::scope(|s| {
                let hj = s.spawn(move || dispatch(&host_backend, haystack, host_needles, side));
                let dj = s.spawn(move || dispatch(&dev_backend, haystack, dev_needles, side));
                (hj.join(), dj.join())
            });
            let mut out = host_res
                .map_err(|_| anyhow::anyhow!("host co-search worker panicked"))??;
            out.extend(
                dev_res.map_err(|_| anyhow::anyhow!("device co-search worker panicked"))??,
            );
            Ok(out)
        }
    }
}

fn host_search<K: SortKey>(haystack: &[K], needles: &[K], side: &str, threads: usize) -> Vec<u32> {
    let one = |nd: &K| -> u32 {
        let nb = nd.to_bits();
        let idx = if side == "first" {
            haystack.partition_point(|h| h.to_bits() < nb)
        } else {
            haystack.partition_point(|h| h.to_bits() <= nb)
        };
        idx as u32
    };
    if threads <= 1 || needles.len() < 4096 {
        needles.iter().map(one).collect()
    } else {
        crate::backend::parallel_for_each_chunk(needles.len(), threads, |r| {
            needles[r].iter().map(one).collect::<Vec<u32>>()
        })
        .concat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution};

    fn sorted_hay(seed: u64, n: usize) -> Vec<i32> {
        let mut h: Vec<i32> = generate(&mut Prng::new(seed), Distribution::DupHeavy, n);
        h.sort_unstable();
        h
    }

    #[test]
    fn first_last_bracket_duplicates() {
        let hay = vec![1i32, 3, 3, 3, 7];
        assert_eq!(searchsorted_first(&Backend::Native, &hay, &[3]).unwrap(), vec![1]);
        assert_eq!(searchsorted_last(&Backend::Native, &hay, &[3]).unwrap(), vec![4]);
        assert_eq!(searchsorted_first(&Backend::Native, &hay, &[0]).unwrap(), vec![0]);
        assert_eq!(searchsorted_last(&Backend::Native, &hay, &[9]).unwrap(), vec![5]);
    }

    #[test]
    fn matches_std_partition_point() {
        let hay = sorted_hay(1, 5000);
        let needles: Vec<i32> = generate(&mut Prng::new(2), Distribution::Uniform, 1000);
        for b in [Backend::Native, Backend::Threaded(4)] {
            let f = searchsorted_first(&b, &hay, &needles).unwrap();
            let l = searchsorted_last(&b, &hay, &needles).unwrap();
            for (i, nd) in needles.iter().enumerate() {
                assert_eq!(f[i] as usize, hay.partition_point(|&h| h < *nd));
                assert_eq!(l[i] as usize, hay.partition_point(|&h| h <= *nd));
            }
        }
    }

    #[test]
    fn float_total_order_on_infinities() {
        let hay = vec![f32::NEG_INFINITY, -1.0, 0.0, 1.0, f32::INFINITY];
        let f = searchsorted_first(&Backend::Native, &hay, &[f32::INFINITY]).unwrap();
        assert_eq!(f, vec![4]);
        let l = searchsorted_last(&Backend::Native, &hay, &[f32::NEG_INFINITY]).unwrap();
        assert_eq!(l, vec![1]);
    }

    #[test]
    fn partition_counts_sum_to_n() {
        // The SIHSort property: splitter ranks partition the shard.
        let hay = sorted_hay(3, 4096);
        let splitters = vec![-500_000i32, 0, 500_000];
        let cuts = searchsorted_last(&Backend::Native, &hay, &splitters).unwrap();
        assert!(cuts.windows(2).all(|w| w[0] <= w[1]));
        assert!(*cuts.last().unwrap() as usize <= hay.len());
    }
}
