//! `akbench bench-stream` — the out-of-core pipeline throughput tracker.
//!
//! Sorts datasets a fixed multiple (≥ 8×) larger than the engine's
//! memory budget through [`crate::stream`]'s external sort, per memory
//! budget × dtype, and emits `BENCH_stream.json` so the streaming
//! subsystem's perf trajectory is tracked from commit to commit next to
//! `BENCH_sort.json`. Every measured configuration doubles as a
//! correctness gate: the streamed output must be bitwise-identical to
//! the in-memory `Session::sort` reference on a subsampled verification
//! pass (plus full-length and boundary checks) — any divergence is a
//! hard error, which CI relies on.
//!
//! Engine legend:
//! * `external-sort`   — run generation + budgeted k-way merge over the
//!   configured spill medium ([`crate::stream::StreamCtx::external_sort`]).
//! * `stream-reduce`   — single-pass budgeted fold (the pipeline
//!   overhead floor: no spill, no merge).
//! * `sort-inmem[ref]` — the in-memory session sort of the same dataset
//!   (the budget-free baseline the streaming engines are normalised
//!   against).

use std::path::{Path, PathBuf};

use crate::algorithms::ReduceKind;
use crate::backend::DeviceKey;
use crate::bench::{verify_subsampled, BenchOpts, Bencher};
use crate::dtype::ElemType;
use crate::obs::{CounterSnapshot, STREAM_COUNTERS};
use crate::session::{Launch, Session};
use crate::stream::{Checkpoint, GenSource, SliceSource, SpillMedium, StreamBudget, VecSink};
use crate::workload::{Distribution, KeyGen};

/// Dataset-bytes : budget-bytes ratios measured per dtype. The first
/// entry is the acceptance-critical ≥ 8× out-of-core configuration.
pub const FULL_RATIOS: [usize; 2] = [8, 16];
/// `--quick` ratio grid.
pub const QUICK_RATIOS: [usize; 1] = [8];

/// Verification sample count per configuration (subsampled bitwise
/// comparison against the in-memory reference).
const VERIFY_SAMPLES: usize = 2048;

/// One measured row of the stream bench.
#[derive(Clone, Debug)]
pub struct StreamBenchRecord {
    /// Engine name (see the module docs legend).
    pub engine: String,
    /// Element type processed.
    pub dtype: ElemType,
    /// Elements per iteration.
    pub n: usize,
    /// Engine memory budget in bytes (0 for the budget-free reference).
    pub budget_bytes: usize,
    /// Dataset bytes / budget bytes (0 for the reference row).
    pub ratio: usize,
    /// Pipeline-shape counters of the verification pass — the
    /// registered [`STREAM_COUNTERS`] (runs, merge passes, spill
    /// volume, …) carried as a registry snapshot (DESIGN.md §18); all
    /// zero on the non-streaming rows. The JSON row emits it by
    /// iteration, so a newly registered counter reaches the schema
    /// without touching this file.
    pub stream: CounterSnapshot,
    /// Output positions bitwise-verified against the reference.
    pub verified: usize,
    /// Mean seconds per iteration.
    pub secs_mean: f64,
    /// Standard deviation of the per-iteration seconds.
    pub secs_std: f64,
    /// Throughput in bytes/second (n × key bytes / mean seconds).
    pub bytes_per_sec: f64,
    /// Recorded samples.
    pub samples: usize,
}

impl StreamBenchRecord {
    /// Sorted runs generated (external-sort rows).
    pub fn runs(&self) -> usize {
        self.stream.get("runs") as usize
    }

    /// Merge passes executed (external-sort rows).
    pub fn merge_passes(&self) -> usize {
        self.stream.get("merge_passes") as usize
    }

    /// Merge fan-in the run used (external-sort rows).
    pub fn fan_in(&self) -> usize {
        self.stream.get("fan_in") as usize
    }

    /// Bytes spilled to disk per iteration (external-sort rows).
    pub fn spilled_bytes(&self) -> u64 {
        self.stream.get("spilled_bytes")
    }
}

/// The full bench outcome.
#[derive(Clone, Debug, Default)]
pub struct StreamBenchReport {
    /// Elements per iteration.
    pub n: usize,
    /// Host threads the per-chunk engines ran with.
    pub threads: usize,
    /// Spill medium of the external sorts.
    pub spill: &'static str,
    /// Seed of the subsampled verification passes — recorded so any
    /// reported `verified` count is reproducible from the JSON alone.
    pub verify_seed: u64,
    /// The launch knobs the per-chunk engines ran with.
    pub launch: Launch,
    /// All measured rows.
    pub records: Vec<StreamBenchRecord>,
}

impl StreamBenchReport {
    /// Find a record by engine name, dtype and ratio.
    pub fn get(&self, engine: &str, dtype: ElemType, ratio: usize) -> Option<&StreamBenchRecord> {
        self.records
            .iter()
            .find(|r| r.engine == engine && r.dtype == dtype && r.ratio == ratio)
    }

    /// Serialise as JSON (`BENCH_stream.json`, schema version 2: v2
    /// replaces the hand-enumerated `runs`/`merge_passes`/`fan_in`/
    /// `spilled_bytes` row fields with the full registered
    /// [`STREAM_COUNTERS`] set, emitted by registry iteration).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"version\": 2,\n");
        s.push_str(&format!(
            "  \"n\": {},\n  \"threads\": {},\n  \"spill\": \"{}\",\n  \"verify_seed\": {},\n",
            self.n, self.threads, self.spill, self.verify_seed
        ));
        s.push_str(&format!("  \"launch\": {},\n", crate::bench::launch_json(&self.launch)));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"engine\": \"{}\", \"dtype\": \"{}\", \"n\": {}, \"budget_bytes\": {}, \
                 \"ratio\": {}, {}, \"verified\": {}, \"secs_mean\": {:.9}, \
                 \"secs_std\": {:.9}, \"gbps\": {:.6}, \"samples\": {}}}{}\n",
                r.engine,
                r.dtype.name(),
                r.n,
                r.budget_bytes,
                r.ratio,
                r.stream.json_fields(),
                r.verified,
                r.secs_mean,
                r.secs_std,
                r.bytes_per_sec / 1e9,
                r.samples,
                if i + 1 == self.records.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

struct DtypeGrid<'a> {
    n: usize,
    threads: usize,
    ratios: &'a [usize],
    seed: u64,
    medium: SpillMedium,
    spill_parent: Option<PathBuf>,
    ckpt_dir: Option<PathBuf>,
    resume: bool,
    launch: &'a Launch,
    opts: &'a BenchOpts,
}

/// Measure one dtype over every budget ratio and append rows.
fn bench_dtype<K: KeyGen + DeviceKey>(
    grid: &DtypeGrid<'_>,
    report: &mut StreamBenchReport,
) -> anyhow::Result<()> {
    let dtype = K::ELEM;
    let n = grid.n;
    let bytes = (n * K::KEY_BYTES) as f64;
    let session = Session::threaded(grid.threads).with_defaults(grid.launch.clone());
    // The dataset a GenSource yields is chunk-size invariant, so the
    // reference sees byte-identical input to every streamed run.
    let data: Vec<K> = GenSource::new(grid.seed, Distribution::Uniform, n as u64).materialize();
    let mut want = data.clone();
    session.sort(&mut want, None)?;

    let mut bencher = Bencher::new(grid.opts.clone());

    // Budget-free in-memory reference row.
    let label = format!("sort-inmem[ref]/{dtype}");
    bencher.run_with_setup(&label, Some(bytes), || data.clone(), |mut v| {
        session.sort(&mut v, None).expect("in-memory reference sort");
    });
    {
        let r = bencher.get(&label).expect("bench result recorded");
        report.records.push(StreamBenchRecord {
            engine: "sort-inmem[ref]".into(),
            dtype,
            n,
            budget_bytes: 0,
            ratio: 0,
            stream: CounterSnapshot::zeroed(&STREAM_COUNTERS),
            verified: 0,
            secs_mean: r.time.mean,
            secs_std: r.time.std,
            bytes_per_sec: r.throughput_bps().unwrap_or(0.0),
            samples: r.time.n,
        });
    }

    for &ratio in grid.ratios {
        let budget_bytes = ((n * K::KEY_BYTES) / ratio).max(1);
        eprintln!(
            "-- bench-stream {dtype} n={n} budget={budget_bytes}B (x{ratio}) threads={}",
            grid.threads
        );
        let mut ctx = session.stream(StreamBudget::bytes(budget_bytes));
        ctx = match grid.medium {
            SpillMedium::Memory => ctx.in_memory_spill(),
            SpillMedium::Disk => match &grid.spill_parent {
                Some(p) => ctx.spill_parent(p.clone()),
                None => ctx,
            },
        };

        // Verification run first (correctness gate + pipeline-shape
        // stats): a divergence — or an `AKBENCH_FAILPOINT` trip — aborts
        // before any measurement time is spent. With a checkpoint dir
        // the gate runs crash-safe through `external_sort_ckpt`, which
        // is what the CI smoke relies on: kill it mid-merge via the env
        // fail point, rerun with `--resume`, and the gate finishes from
        // the manifest instead of from zero.
        let mut src = GenSource::<K>::new(grid.seed, Distribution::Uniform, n as u64);
        let mut sink = VecSink::new();
        let stats = match &grid.ckpt_dir {
            Some(root) => {
                let cell = root.join(format!("{dtype}-x{ratio}"));
                let tag = format!("bench-stream/{dtype}/x{ratio}");
                let mut ck = Checkpoint::new(&cell, tag.as_str());
                if grid.resume {
                    ck = ck.resume();
                }
                let mut stats = ctx.external_sort_ckpt(&mut src, &mut sink, None, &ck)?;
                if stats.completed_noop {
                    // A previous incarnation already finished this cell;
                    // resuming it is a no-op that leaves the sink empty,
                    // so redo the cell fresh — the gate must always check
                    // real output.
                    src = GenSource::new(grid.seed, Distribution::Uniform, n as u64);
                    sink = VecSink::new();
                    stats = ctx.external_sort_ckpt(
                        &mut src,
                        &mut sink,
                        None,
                        &Checkpoint::new(&cell, tag.as_str()),
                    )?;
                }
                stats
            }
            None => ctx.external_sort(&mut src, &mut sink, None)?,
        };
        let verified = verify_subsampled(&sink.out, &want, VERIFY_SAMPLES, grid.seed ^ 0x5EED)?;
        anyhow::ensure!(
            stats.elems == n as u64,
            "external sort consumed {} of {} elements",
            stats.elems,
            n
        );

        // external-sort: measured from a fresh generator each iteration
        // (the engine streams; only the budget lives in memory). The
        // timed pass never checkpoints — manifest fsyncs are not what
        // this bench tracks.
        let label = format!("external-sort/{dtype}/x{ratio}");
        bencher.run(&label, Some(bytes), || {
            let mut src = GenSource::<K>::new(grid.seed, Distribution::Uniform, n as u64);
            let mut sink = VecSink::new();
            ctx.external_sort(&mut src, &mut sink, None).expect("external sort");
        });
        let r = bencher.get(&label).expect("bench result recorded");
        report.records.push(StreamBenchRecord {
            engine: "external-sort".into(),
            dtype,
            n,
            budget_bytes,
            ratio,
            stream: stats.snapshot(),
            verified,
            secs_mean: r.time.mean,
            secs_std: r.time.std,
            bytes_per_sec: r.throughput_bps().unwrap_or(0.0),
            samples: r.time.n,
        });

        // stream-reduce: the single-pass overhead floor, gated against
        // the in-memory fold (bitwise for integers, relative for floats
        // — chunking regroups float additions).
        let label = format!("stream-reduce/{dtype}/x{ratio}");
        bencher.run(&label, Some(bytes), || {
            let mut src = SliceSource::new(&data);
            ctx.stream_reduce(&mut src, ReduceKind::Add, None).expect("stream reduce");
        });
        let got = ctx.stream_reduce(&mut SliceSource::new(&data), ReduceKind::Add, None)?;
        let reference = session.reduce(&data, ReduceKind::Add, None)?;
        anyhow::ensure!(
            reduce_close(got, reference, &data),
            "stream-reduce diverged from the in-memory reduce on {dtype}: {got:?} vs {reference:?}"
        );
        let r = bencher.get(&label).expect("bench result recorded");
        report.records.push(StreamBenchRecord {
            engine: "stream-reduce".into(),
            dtype,
            n,
            budget_bytes,
            ratio,
            stream: CounterSnapshot::zeroed(&STREAM_COUNTERS),
            verified: 1,
            secs_mean: r.time.mean,
            secs_std: r.time.std,
            bytes_per_sec: r.throughput_bps().unwrap_or(0.0),
            samples: r.time.n,
        });
    }
    Ok(())
}

/// Integer sums must match bitwise. Float sums compare within a slack
/// scaled by the dataset's absolute mass `Σ|x|`, not the total: the
/// rounding error of regrouped summation grows like `√n·ε·Σ|x|`, while
/// the total itself nearly cancels for the ±uniform bench workload — a
/// fixed relative-to-total tolerance would reject correct f32 runs at
/// the full-bench n = 2^22 (one f32 ulp at the partial-sum magnitude
/// dwarfs 1e-6 of the cancelled total).
fn reduce_close<K: DeviceKey>(got: K, want: K, data: &[K]) -> bool {
    if !matches!(K::ELEM, ElemType::F32 | ElemType::F64) {
        return got.to_bits() == want.to_bits();
    }
    let abs_mass: f64 = data.iter().map(|&x| float_of(x).abs()).sum();
    let (g, w) = (float_of(got), float_of(want));
    (g - w).abs() <= 1e-3 * abs_mass.max(1.0)
}

fn float_of<K: DeviceKey>(k: K) -> f64 {
    // Round-trip through the bit image: exact for f32/f64 keys.
    match K::ELEM {
        ElemType::F32 => f32::from_bits_key(k.to_bits()) as f64,
        ElemType::F64 => f64::from_bits_key(k.to_bits()),
        _ => 0.0,
    }
}

trait FromBitsKey {
    fn from_bits_key(bits: u128) -> Self;
}
impl FromBitsKey for f32 {
    fn from_bits_key(bits: u128) -> Self {
        <f32 as crate::dtype::SortKey>::from_bits(bits)
    }
}
impl FromBitsKey for f64 {
    fn from_bits_key(bits: u128) -> Self {
        <f64 as crate::dtype::SortKey>::from_bits(bits)
    }
}

/// Run the stream bench over `dtypes` × `ratios` and return the report.
#[allow(clippy::too_many_arguments)]
pub fn run_stream_bench(
    n: usize,
    threads: usize,
    ratios: &[usize],
    dtypes: &[ElemType],
    opts: &BenchOpts,
    launch: &Launch,
    medium: SpillMedium,
    spill_parent: Option<PathBuf>,
    ckpt_dir: Option<PathBuf>,
    resume: bool,
) -> anyhow::Result<StreamBenchReport> {
    let seed = 0x57AE4B_u64;
    let mut report = StreamBenchReport {
        n,
        threads: threads.max(1),
        spill: match medium {
            SpillMedium::Memory => "memory",
            SpillMedium::Disk => "disk",
        },
        verify_seed: seed ^ 0x5EED,
        launch: launch.clone(),
        records: Vec::new(),
    };
    let grid = DtypeGrid {
        n,
        threads: report.threads,
        ratios,
        seed,
        medium,
        spill_parent,
        ckpt_dir,
        resume,
        launch,
        opts,
    };
    for &dt in dtypes {
        crate::dispatch_dtype!(dt, K => bench_dtype::<K>(&grid, &mut report)?);
    }
    Ok(report)
}

/// CLI entry point: run the grid (`--quick` trims dtypes, ratios and
/// sampling), print a summary, and emit the JSON report to `out`.
#[allow(clippy::too_many_arguments)]
pub fn run_and_emit(
    n: usize,
    threads: usize,
    quick: bool,
    out: &Path,
    launch: &Launch,
    medium: SpillMedium,
    spill_parent: Option<PathBuf>,
    ckpt_dir: Option<PathBuf>,
    resume: bool,
) -> anyhow::Result<()> {
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() }.scaled_from_env();
    let dtypes: &[ElemType] =
        if quick { &[ElemType::I32, ElemType::F64] } else { &ElemType::ALL };
    let ratios: &[usize] = if quick { &QUICK_RATIOS } else { &FULL_RATIOS };
    let report = run_stream_bench(
        n,
        threads,
        ratios,
        dtypes,
        &opts,
        launch,
        medium,
        spill_parent,
        ckpt_dir,
        resume,
    )?;
    report.write_json(out)?;
    println!(
        "bench-stream: {} rows (n={}, threads={}, spill={}) -> {}",
        report.records.len(),
        report.n,
        report.threads,
        report.spill,
        out.display()
    );
    for &dt in dtypes {
        for &ratio in ratios {
            if let (Some(ext), Some(inm)) =
                (report.get("external-sort", dt, ratio), report.get("sort-inmem[ref]", dt, 0))
            {
                if ext.secs_mean > 0.0 && inm.secs_mean > 0.0 {
                    println!(
                        "  {dt:<5} x{ratio:<3} external-sort {:.2} GB/s ({} runs, {} passes) \
                         vs in-mem {:.2} GB/s ({:.2}x overhead, {} positions verified)",
                        ext.bytes_per_sec / 1e9,
                        ext.runs(),
                        ext.merge_passes(),
                        inm.bytes_per_sec / 1e9,
                        ext.secs_mean / inm.secs_mean,
                        ext.verified,
                    );
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> BenchOpts {
        BenchOpts {
            warmup: std::time::Duration::from_millis(2),
            budget: std::time::Duration::from_millis(30),
            min_samples: 2,
            max_samples: 3,
        }
    }

    #[test]
    fn report_covers_engines_and_json_parses() {
        let launch = Launch::new().max_tasks(2);
        let report = run_stream_bench(
            40_000,
            2,
            &[8],
            &[ElemType::I32],
            &tiny_opts(),
            &launch,
            SpillMedium::Memory,
            None,
            None,
            false,
        )
        .unwrap();
        // 1 reference row + (external-sort + stream-reduce) per ratio.
        assert_eq!(report.records.len(), 3);
        let ext = report.get("external-sort", ElemType::I32, 8).unwrap();
        // The acceptance property: dataset is 8x the budget, so the
        // pipeline must actually go out of core and verify clean.
        assert!(ext.runs() > 1, "dataset must exceed one run ({} runs)", ext.runs());
        assert!(ext.merge_passes() >= 1);
        assert!(ext.verified > 2);
        assert_eq!(ext.budget_bytes, 40_000 * 4 / 8);
        let j = crate::util::json::Json::parse(&report.to_json()).unwrap();
        assert_eq!(j.get("version").as_usize(), Some(2));
        assert_eq!(j.get("spill").as_str(), Some("memory"));
        // The verification seed is part of the report so `verified`
        // counts are reproducible from the JSON alone.
        assert_eq!(j.get("verify_seed").as_usize(), Some((0x57AE4B ^ 0x5EED) as usize));
        let rows = j.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(j.get("launch").get("max_tasks").as_usize(), Some(2));
        // Schema v2, coverage contract: every *registered* stream
        // counter appears on every row, iterated from the registry
        // list — a newly registered name fails here until the rows
        // carry it.
        for row in rows {
            for key in STREAM_COUNTERS {
                assert!(row.get(key).as_usize().is_some(), "row key {key}");
            }
        }
    }

    #[test]
    fn disk_spill_roundtrips_under_bench_harness() {
        let report = run_stream_bench(
            20_000,
            2,
            &[8],
            &[ElemType::F64],
            &tiny_opts(),
            &Launch::default(),
            SpillMedium::Disk,
            None,
            None,
            false,
        )
        .unwrap();
        let ext = report.get("external-sort", ElemType::F64, 8).unwrap();
        assert!(ext.spilled_bytes() > 0, "disk medium must actually spill");
        assert!(ext.verified > 2);
    }
}
