//! In-repo micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9).
//!
//! Criterion-like protocol: warm-up phase, then adaptive sampling until
//! either `max_samples` measurements or the time budget is reached; each
//! sample may batch several iterations when the routine is fast. Results
//! carry mean ± σ (the paper's Table II format) and optional processed
//! bytes for GB/s reporting.

pub mod runner;
pub mod sort_bench;
pub mod stream_bench;

pub use runner::{benchmark, benchmark_with_setup, BenchOpts, BenchResult, Bencher};
pub use sort_bench::{run_sort_bench, SortBenchRecord, SortBenchReport};
pub use stream_bench::{run_stream_bench, StreamBenchRecord, StreamBenchReport};

/// JSON object for the active launch knobs — one serialisation shared
/// by every bench report writer, so `BENCH_sort.json` and
/// `BENCH_stream.json` cannot drift apart when a knob is added.
pub(crate) fn launch_json(l: &crate::session::Launch) -> String {
    fn opt(v: Option<usize>) -> String {
        match v {
            Some(x) => x.to_string(),
            None => "null".to_string(),
        }
    }
    format!(
        "{{\"block_size\": {}, \"max_tasks\": {}, \"min_elems_per_task\": {}, \
         \"par_threshold\": {}, \"switch_below\": {}, \"reuse_scratch\": {}}}",
        opt(l.block_size),
        opt(l.max_tasks),
        opt(l.min_elems_per_task),
        opt(l.prefer_parallel_threshold),
        opt(l.switch_below),
        l.reuse_scratch_on(),
    )
}
