//! In-repo micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9).
//!
//! Criterion-like protocol: warm-up phase, then adaptive sampling until
//! either `max_samples` measurements or the time budget is reached; each
//! sample may batch several iterations when the routine is fast. Results
//! carry mean ± σ (the paper's Table II format) and optional processed
//! bytes for GB/s reporting.

pub mod cluster_stream_bench;
pub mod record_bench;
pub mod runner;
pub mod sort_bench;
pub mod stream_bench;

pub use cluster_stream_bench::{
    run_cluster_stream_bench, ClusterStreamRecord, ClusterStreamReport,
};
pub use record_bench::{run_record_bench, RecordBenchRecord, RecordBenchReport};
pub use runner::{benchmark, benchmark_with_setup, BenchOpts, BenchResult, Bencher};
pub use sort_bench::{run_sort_bench, SortBenchRecord, SortBenchReport};
pub use stream_bench::{run_stream_bench, StreamBenchRecord, StreamBenchReport};

/// JSON object for the active launch knobs — one serialisation shared
/// by every bench report writer, so `BENCH_sort.json` and
/// `BENCH_stream.json` cannot drift apart when a knob is added.
pub(crate) fn launch_json(l: &crate::session::Launch) -> String {
    fn opt(v: Option<usize>) -> String {
        match v {
            Some(x) => x.to_string(),
            None => "null".to_string(),
        }
    }
    format!(
        "{{\"block_size\": {}, \"max_tasks\": {}, \"min_elems_per_task\": {}, \
         \"par_threshold\": {}, \"switch_below\": {}, \"reuse_scratch\": {}, \
         \"strict_device\": {}}}",
        opt(l.block_size),
        opt(l.max_tasks),
        opt(l.min_elems_per_task),
        opt(l.prefer_parallel_threshold),
        opt(l.switch_below),
        l.reuse_scratch_on(),
        l.strict_device_on(),
    )
}

/// Bitwise-compare `got` against `want` at `samples` seeded positions
/// plus both boundaries; errors on any mismatch. Returns positions
/// checked. Generic over any record layout — scalar keys compare their
/// key image, `(key, payload)` records compare key image AND payload
/// bits — so it is the one correctness gate shared by every streaming
/// bench (`bench-stream`, `bench-cluster-stream`, `bench-records`).
pub(crate) fn verify_subsampled<R: crate::stream::StreamRecord>(
    got: &[R],
    want: &[R],
    samples: usize,
    seed: u64,
) -> anyhow::Result<usize> {
    anyhow::ensure!(
        got.len() == want.len(),
        "streamed output has {} elements, reference has {}",
        got.len(),
        want.len()
    );
    if got.is_empty() {
        return Ok(0);
    }
    let mut rng = crate::util::Prng::new(seed);
    let mut checked = 0;
    let mut check = |i: usize| -> anyhow::Result<()> {
        anyhow::ensure!(
            got[i].key_bits() == want[i].key_bits()
                && got[i].payload_raw() == want[i].payload_raw(),
            "streamed output diverges from the in-memory reference at index {i}: \
             {:?} vs {:?}",
            got[i],
            want[i],
        );
        Ok(())
    };
    check(0)?;
    check(got.len() - 1)?;
    checked += 2;
    for _ in 0..samples {
        check(rng.below(got.len() as u64) as usize)?;
        checked += 1;
    }
    Ok(checked)
}
