//! In-repo micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9).
//!
//! Criterion-like protocol: warm-up phase, then adaptive sampling until
//! either `max_samples` measurements or the time budget is reached; each
//! sample may batch several iterations when the routine is fast. Results
//! carry mean ± σ (the paper's Table II format) and optional processed
//! bytes for GB/s reporting.

pub mod runner;
pub mod sort_bench;

pub use runner::{benchmark, benchmark_with_setup, BenchOpts, BenchResult, Bencher};
pub use sort_bench::{run_sort_bench, SortBenchRecord, SortBenchReport};
