//! `akbench bench-records` — the record-stream (dataset engine)
//! throughput tracker.
//!
//! Runs every record workload of DESIGN.md §19 — sort-by-key across
//! payload widths, sortperm, group-by reduce, distinct, merge-join —
//! per memory-budget ratio, and emits `BENCH_records.json` so the
//! dataset-engine perf trajectory is tracked commit to commit next to
//! `BENCH_stream.json`. Every measured configuration doubles as a
//! correctness gate: the streamed output must match the in-memory
//! reference (key image AND payload bits) on a subsampled verification
//! pass — any divergence is a hard error, which CI relies on.
//!
//! Workload legend (all through [`crate::stream::StreamCtx`]):
//! * `sort-by-key/pN` — external stable sort of `(i64, N-byte payload)`
//!   records, N ∈ {4, 8, 16}.
//! * `sortperm`       — external argsort: `i64` keys in, `(key, u64
//!   index)` records out.
//! * `group-reduce`   — sorted-run group-by `Add` over `(i64, i64)`
//!   records.
//! * `distinct`       — run-merge dedup of `(i64, u64)` records.
//! * `merge-join`     — merge-join of two pre-sorted record streams
//!   (the only workload not built on the external sort: pure
//!   streaming two-pointer).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::algorithms::ReduceKind;
use crate::bench::{verify_subsampled, BenchOpts, Bencher};
use crate::obs::{CounterSnapshot, STREAM_COUNTERS};
use crate::session::{Launch, Session};
use crate::stream::{
    Payload, Record, SliceSource, SpillMedium, StreamBudget, StreamCtx, StreamRecord, VecSink,
};
use crate::util::Prng;

/// Dataset-bytes : budget-bytes ratios measured per workload. The first
/// entry is the acceptance-critical ≥ 8× out-of-core configuration.
pub const FULL_RATIOS: [usize; 2] = [8, 16];
/// `--quick` ratio grid.
pub const QUICK_RATIOS: [usize; 1] = [8];

/// Verification sample count per configuration.
const VERIFY_SAMPLES: usize = 2048;

/// One measured row of the records bench.
#[derive(Clone, Debug)]
pub struct RecordBenchRecord {
    /// Workload name (see the module docs legend).
    pub workload: String,
    /// Payload bytes per record (the key is always 8-byte `i64`).
    pub payload_bytes: usize,
    /// Full record stride in bytes.
    pub rec_bytes: usize,
    /// Input records per iteration (per side for `merge-join`).
    pub n: usize,
    /// Engine memory budget in bytes.
    pub budget_bytes: usize,
    /// Dataset bytes / budget bytes.
    pub ratio: usize,
    /// Pipeline-shape counters of the verification pass (zeroed for
    /// `merge-join`, which never spills).
    pub stream: CounterSnapshot,
    /// Output positions verified (key image + payload bits).
    pub verified: usize,
    /// Mean seconds per iteration.
    pub secs_mean: f64,
    /// Standard deviation of the per-iteration seconds.
    pub secs_std: f64,
    /// Throughput in bytes/second (input records × stride / mean secs).
    pub bytes_per_sec: f64,
    /// Recorded samples.
    pub samples: usize,
}

/// The full bench outcome.
#[derive(Clone, Debug, Default)]
pub struct RecordBenchReport {
    /// Input records per iteration.
    pub n: usize,
    /// Host threads the per-chunk engines ran with.
    pub threads: usize,
    /// Spill medium of the external sorts.
    pub spill: &'static str,
    /// Seed of the subsampled verification passes.
    pub verify_seed: u64,
    /// The launch knobs the per-chunk engines ran with.
    pub launch: Launch,
    /// All measured rows.
    pub records: Vec<RecordBenchRecord>,
}

impl RecordBenchReport {
    /// Find a record by workload name and ratio.
    pub fn get(&self, workload: &str, ratio: usize) -> Option<&RecordBenchRecord> {
        self.records.iter().find(|r| r.workload == workload && r.ratio == ratio)
    }

    /// Serialise as JSON (`BENCH_records.json`, schema version 1; rows
    /// carry the registered [`STREAM_COUNTERS`] by iteration, like
    /// `BENCH_stream.json` v2).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"version\": 1,\n");
        s.push_str(&format!(
            "  \"n\": {},\n  \"threads\": {},\n  \"spill\": \"{}\",\n  \"verify_seed\": {},\n",
            self.n, self.threads, self.spill, self.verify_seed
        ));
        s.push_str(&format!("  \"launch\": {},\n", crate::bench::launch_json(&self.launch)));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"workload\": \"{}\", \"payload_bytes\": {}, \"rec_bytes\": {}, \
                 \"n\": {}, \"budget_bytes\": {}, \"ratio\": {}, {}, \"verified\": {}, \
                 \"secs_mean\": {:.9}, \"secs_std\": {:.9}, \"gbps\": {:.6}, \
                 \"samples\": {}}}{}\n",
                r.workload,
                r.payload_bytes,
                r.rec_bytes,
                r.n,
                r.budget_bytes,
                r.ratio,
                r.stream.json_fields(),
                r.verified,
                r.secs_mean,
                r.secs_std,
                r.bytes_per_sec / 1e9,
                r.samples,
                if i + 1 == self.records.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Deterministic record dataset: keys drawn from `[0, key_span)` (so
/// duplicate density is `n / key_span`), payloads are the record's
/// input position — which makes stability violations and payload
/// corruption both visible to the bitwise verifier.
fn gen_records<P: Payload>(seed: u64, n: usize, key_span: u64) -> Vec<Record<i64, P>> {
    let mut rng = Prng::new(seed);
    (0..n).map(|i| Record::new(rng.below(key_span) as i64, P::from_raw(i as u128))).collect()
}

struct Grid<'a> {
    n: usize,
    seed: u64,
    session: &'a Session,
    medium: SpillMedium,
    spill_parent: &'a Option<PathBuf>,
    ratio: usize,
}

impl Grid<'_> {
    /// A streaming context whose budget is `1/ratio` of `dataset_bytes`.
    fn ctx(&self, dataset_bytes: usize) -> (StreamCtx, usize) {
        let budget_bytes = (dataset_bytes / self.ratio).max(1);
        let mut ctx = self.session.stream(StreamBudget::bytes(budget_bytes));
        ctx = match self.medium {
            SpillMedium::Memory => ctx.in_memory_spill(),
            SpillMedium::Disk => match self.spill_parent {
                Some(p) => ctx.spill_parent(p.clone()),
                None => ctx,
            },
        };
        (ctx, budget_bytes)
    }
}

/// Measure one workload: `verify` runs once (gate + pipeline counters),
/// `timed` is the measured iteration.
#[allow(clippy::too_many_arguments)]
fn measure(
    grid: &Grid<'_>,
    bencher: &mut Bencher,
    report: &mut RecordBenchReport,
    workload: String,
    payload_bytes: usize,
    rec_bytes: usize,
    budget_bytes: usize,
    bytes: f64,
    stream: CounterSnapshot,
    verified: usize,
    timed: impl FnMut(),
) {
    bencher.run(&workload, Some(bytes), timed);
    let r = bencher.get(&workload).expect("bench result recorded");
    report.records.push(RecordBenchRecord {
        workload,
        payload_bytes,
        rec_bytes,
        n: grid.n,
        budget_bytes,
        ratio: grid.ratio,
        stream,
        verified,
        secs_mean: r.time.mean,
        secs_std: r.time.std,
        bytes_per_sec: r.throughput_bps().unwrap_or(0.0),
        samples: r.time.n,
    });
}

/// sort-by-key at one payload width: verify bitwise against the
/// in-memory stable pair sort, then time the streamed sort.
fn bench_sort_by_key<P: Payload>(
    grid: &Grid<'_>,
    bencher: &mut Bencher,
    report: &mut RecordBenchReport,
) -> anyhow::Result<()> {
    type R<P> = Record<i64, P>;
    let n = grid.n;
    let data: Vec<R<P>> = gen_records(grid.seed, n, (n as u64 / 8).max(1));
    let bytes = (n * R::<P>::REC_BYTES) as f64;
    let (ctx, budget_bytes) = grid.ctx(n * R::<P>::REC_BYTES);

    let mut want = data.clone();
    R::<P>::sort_chunk(grid.session, &mut want, None)?;
    let mut sink = VecSink::new();
    let stats = ctx.stream_sort_by_key(&mut SliceSource::new(&data), &mut sink, None)?;
    let verified = verify_subsampled(&sink.out, &want, VERIFY_SAMPLES, grid.seed ^ 0x5EED)?;
    anyhow::ensure!(stats.runs > 1, "dataset must exceed one run ({} runs)", stats.runs);

    measure(
        grid,
        bencher,
        report,
        format!("sort-by-key/p{}/x{}", P::BYTES, grid.ratio),
        P::BYTES,
        R::<P>::REC_BYTES,
        budget_bytes,
        bytes,
        stats.snapshot(),
        verified,
        || {
            let mut sink = VecSink::new();
            ctx.stream_sort_by_key(&mut SliceSource::new(&data), &mut sink, None)
                .expect("stream sort_by_key");
        },
    );
    Ok(())
}

/// Run every workload at one budget ratio.
fn bench_ratio(
    grid: &Grid<'_>,
    bencher: &mut Bencher,
    report: &mut RecordBenchReport,
) -> anyhow::Result<()> {
    let n = grid.n;
    let session = grid.session;
    eprintln!("-- bench-records n={n} x{} threads={}", grid.ratio, report.threads);

    // sort-by-key across payload widths.
    bench_sort_by_key::<u32>(grid, bencher, report)?;
    bench_sort_by_key::<u64>(grid, bencher, report)?;
    bench_sort_by_key::<u128>(grid, bencher, report)?;

    // sortperm: bare keys in, (key, index) records out.
    {
        type R = Record<i64, u64>;
        let keys: Vec<i64> =
            gen_records::<()>(grid.seed ^ 1, n, (n as u64 / 8).max(1)).iter().map(|r| r.key).collect();
        let bytes = (n * R::REC_BYTES) as f64;
        let (ctx, budget_bytes) = grid.ctx(n * R::REC_BYTES);
        let perm = session.sortperm(&keys, None)?;
        let want: Vec<R> =
            perm.iter().map(|&i| Record::new(keys[i as usize], i as u64)).collect();
        let mut sink = VecSink::new();
        let stats = ctx.stream_sortperm(&mut SliceSource::new(&keys), &mut sink, None)?;
        let verified = verify_subsampled(&sink.out, &want, VERIFY_SAMPLES, grid.seed ^ 0x5EED)?;
        measure(
            grid,
            bencher,
            report,
            format!("sortperm/x{}", grid.ratio),
            8,
            R::REC_BYTES,
            budget_bytes,
            bytes,
            stats.snapshot(),
            verified,
            || {
                let mut sink = VecSink::new();
                ctx.stream_sortperm(&mut SliceSource::new(&keys), &mut sink, None)
                    .expect("stream sortperm");
            },
        );
    }

    // group-reduce: Add over (i64, i64) records (wrapping add is
    // order-independent, so a HashMap fold is an exact reference).
    {
        type R = Record<i64, i64>;
        let data: Vec<R> = gen_records::<u64>(grid.seed ^ 2, n, (n as u64 / 64).max(1))
            .iter()
            .map(|r| Record::new(r.key, r.val as i64))
            .collect();
        let bytes = (n * R::REC_BYTES) as f64;
        let (ctx, budget_bytes) = grid.ctx(n * R::REC_BYTES);
        let mut folded: HashMap<i64, i64> = HashMap::new();
        for r in &data {
            let e = folded.entry(r.key).or_insert(0);
            *e = e.wrapping_add(r.val);
        }
        let mut want: Vec<R> = folded.iter().map(|(&k, &v)| Record::new(k, v)).collect();
        want.sort_by_key(|r| r.key);
        let mut sink = VecSink::new();
        let stats = ctx.stream_group_reduce(
            &mut SliceSource::new(&data),
            ReduceKind::Add,
            &mut sink,
            None,
        )?;
        anyhow::ensure!(
            stats.groups as usize == want.len(),
            "group-reduce found {} groups, reference has {}",
            stats.groups,
            want.len()
        );
        let verified = verify_subsampled(&sink.out, &want, VERIFY_SAMPLES, grid.seed ^ 0x5EED)?;
        measure(
            grid,
            bencher,
            report,
            format!("group-reduce/x{}", grid.ratio),
            8,
            R::REC_BYTES,
            budget_bytes,
            bytes,
            stats.sort.snapshot(),
            verified,
            || {
                let mut sink = VecSink::new();
                ctx.stream_group_reduce(
                    &mut SliceSource::new(&data),
                    ReduceKind::Add,
                    &mut sink,
                    None,
                )
                .expect("stream group_reduce");
            },
        );
    }

    // distinct: first record per key survives.
    {
        type R = Record<i64, u64>;
        let data: Vec<R> = gen_records(grid.seed ^ 3, n, (n as u64 / 16).max(1));
        let bytes = (n * R::REC_BYTES) as f64;
        let (ctx, budget_bytes) = grid.ctx(n * R::REC_BYTES);
        let mut first: HashMap<i64, u64> = HashMap::new();
        for r in &data {
            first.entry(r.key).or_insert(r.val);
        }
        let mut want: Vec<R> = first.iter().map(|(&k, &v)| Record::new(k, v)).collect();
        want.sort_by_key(|r| r.key);
        let mut sink = VecSink::new();
        let stats = ctx.stream_distinct(&mut SliceSource::new(&data), &mut sink, None)?;
        anyhow::ensure!(
            stats.groups as usize == want.len(),
            "distinct kept {} keys, reference has {}",
            stats.groups,
            want.len()
        );
        let verified = verify_subsampled(&sink.out, &want, VERIFY_SAMPLES, grid.seed ^ 0x5EED)?;
        measure(
            grid,
            bencher,
            report,
            format!("distinct/x{}", grid.ratio),
            8,
            R::REC_BYTES,
            budget_bytes,
            bytes,
            stats.sort.snapshot(),
            verified,
            || {
                let mut sink = VecSink::new();
                ctx.stream_distinct(&mut SliceSource::new(&data), &mut sink, None)
                    .expect("stream distinct");
            },
        );
    }

    // merge-join: two pre-sorted n-record sides; sparse keys keep the
    // cross-product output near n. The reference is an in-memory
    // two-pointer join over the same sorted inputs.
    {
        let mut left: Vec<Record<i64, u64>> = gen_records(grid.seed ^ 4, n, n as u64);
        let mut right: Vec<Record<i64, u32>> = gen_records(grid.seed ^ 5, n, n as u64);
        left.sort_by_key(|r| (r.key, r.val));
        right.sort_by_key(|r| (r.key, r.val));
        let rec_bytes = Record::<i64, (u64, u32)>::REC_BYTES;
        let in_bytes =
            n * Record::<i64, u64>::REC_BYTES + n * Record::<i64, u32>::REC_BYTES;
        let (ctx, budget_bytes) = grid.ctx(in_bytes);
        let mut want: Vec<Record<i64, (u64, u32)>> = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < left.len() && j < right.len() {
            if left[i].key < right[j].key {
                i += 1;
            } else if right[j].key < left[i].key {
                j += 1;
            } else {
                let k = left[i].key;
                let gi = i;
                while i < left.len() && left[i].key == k {
                    i += 1;
                }
                while j < right.len() && right[j].key == k {
                    for l in &left[gi..i] {
                        want.push(Record::new(k, (l.val, right[j].val)));
                    }
                    j += 1;
                }
            }
        }
        let mut sink = VecSink::new();
        let stats = ctx.stream_merge_join(
            &mut SliceSource::new(&left),
            &mut SliceSource::new(&right),
            &mut sink,
        )?;
        anyhow::ensure!(
            stats.emitted as usize == want.len(),
            "merge-join emitted {} records, reference has {}",
            stats.emitted,
            want.len()
        );
        let verified = verify_subsampled(&sink.out, &want, VERIFY_SAMPLES, grid.seed ^ 0x5EED)?;
        measure(
            grid,
            bencher,
            report,
            format!("merge-join/x{}", grid.ratio),
            12,
            rec_bytes,
            budget_bytes,
            in_bytes as f64,
            CounterSnapshot::zeroed(&STREAM_COUNTERS),
            verified,
            || {
                let mut sink = VecSink::new();
                ctx.stream_merge_join(
                    &mut SliceSource::new(&left),
                    &mut SliceSource::new(&right),
                    &mut sink,
                )
                .expect("stream merge_join");
            },
        );
    }
    Ok(())
}

/// Run the records bench over every ratio and return the report.
pub fn run_record_bench(
    n: usize,
    threads: usize,
    ratios: &[usize],
    opts: &BenchOpts,
    launch: &Launch,
    medium: SpillMedium,
    spill_parent: Option<PathBuf>,
) -> anyhow::Result<RecordBenchReport> {
    let seed = 0x4EC04D_u64;
    let mut report = RecordBenchReport {
        n,
        threads: threads.max(1),
        spill: match medium {
            SpillMedium::Memory => "memory",
            SpillMedium::Disk => "disk",
        },
        verify_seed: seed ^ 0x5EED,
        launch: launch.clone(),
        records: Vec::new(),
    };
    let session = Session::threaded(report.threads).with_defaults(launch.clone());
    let mut bencher = Bencher::new(opts.clone());
    for &ratio in ratios {
        let grid = Grid {
            n,
            seed,
            session: &session,
            medium,
            spill_parent: &spill_parent,
            ratio,
        };
        bench_ratio(&grid, &mut bencher, &mut report)?;
    }
    Ok(report)
}

/// CLI entry point: run the grid (`--quick` trims ratios and sampling),
/// print a summary, and emit the JSON report to `out`.
pub fn run_and_emit(
    n: usize,
    threads: usize,
    quick: bool,
    out: &Path,
    launch: &Launch,
    medium: SpillMedium,
    spill_parent: Option<PathBuf>,
) -> anyhow::Result<()> {
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() }.scaled_from_env();
    let ratios: &[usize] = if quick { &QUICK_RATIOS } else { &FULL_RATIOS };
    let report = run_record_bench(n, threads, ratios, &opts, launch, medium, spill_parent)?;
    report.write_json(out)?;
    println!(
        "bench-records: {} rows (n={}, threads={}, spill={}) -> {}",
        report.records.len(),
        report.n,
        report.threads,
        report.spill,
        out.display()
    );
    for r in &report.records {
        println!(
            "  {:<22} {:>2}B payload  {:.2} GB/s  ({} positions verified)",
            r.workload,
            r.payload_bytes,
            r.bytes_per_sec / 1e9,
            r.verified,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> BenchOpts {
        BenchOpts {
            warmup: std::time::Duration::from_millis(2),
            budget: std::time::Duration::from_millis(30),
            min_samples: 2,
            max_samples: 3,
        }
    }

    #[test]
    fn report_covers_workloads_and_json_parses() {
        let report = run_record_bench(
            20_000,
            2,
            &[8],
            &tiny_opts(),
            &Launch::default(),
            SpillMedium::Memory,
            None,
        )
        .unwrap();
        // 3 sort-by-key widths + sortperm + group-reduce + distinct +
        // merge-join per ratio.
        assert_eq!(report.records.len(), 7);
        for w in ["sort-by-key/p4", "sort-by-key/p8", "sort-by-key/p16"] {
            let r = report.get(&format!("{w}/x8"), 8).unwrap();
            assert!(r.verified > 2, "{w} must verify");
            assert!(r.rec_bytes > 8, "{w} strides past the key");
        }
        let j = crate::util::json::Json::parse(&report.to_json()).unwrap();
        assert_eq!(j.get("version").as_usize(), Some(1));
        let rows = j.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 7);
        for row in rows {
            for key in STREAM_COUNTERS {
                assert!(row.get(key).as_usize().is_some(), "row key {key}");
            }
            assert!(row.get("verified").as_usize().unwrap() > 0);
        }
    }

    #[test]
    fn disk_spill_roundtrips_records_under_bench_harness() {
        let report = run_record_bench(
            12_000,
            2,
            &[8],
            &tiny_opts(),
            &Launch::default(),
            SpillMedium::Disk,
            None,
        )
        .unwrap();
        let r = report.get("sort-by-key/p16/x8", 8).unwrap();
        assert!(r.stream.get("spilled_bytes") > 0, "disk medium must actually spill");
    }
}
