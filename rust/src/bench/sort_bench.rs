//! `akbench bench-sort` — the host sort engine throughput tracker.
//!
//! Measures GB/s for every host sort engine (sequential and parallel
//! counterparts side by side) per dtype × threads and emits
//! `BENCH_sort.json`, so the perf trajectory of the parallel host engine
//! (DESIGN.md §11) is tracked from commit to commit. The run doubles as
//! a cross-engine correctness gate: every engine's output is compared
//! against the std reference sort and any divergence is a hard error —
//! CI fails on it.
//!
//! Engines run through the [`Session`]/[`Launch`] API; the active
//! launch knobs are recorded in the JSON metadata so a bench run is
//! reproducible from its artifact alone.
//!
//! Engine legend (sequential counterpart → parallel engine):
//! * `sort-native`    → `sort-threaded`   (per-chunk sort + merge-path
//!   partitioned k-way recombine, `Session::sort`)
//! * `radix-seq[TR]`  → `radix-par[TR]`   (threaded LSD radix,
//!   `baselines::radix`)
//! * `kmerge-seq`     → `kmerge-par`      (recombine phase alone, over
//!   pre-sorted runs — isolates the merge-path speedup)
//! * `merge-seq[TM]`  — the bottom-up vendor-merge baseline, for scale.

use std::path::Path;

use crate::backend::threaded::split_ranges;
use crate::backend::DeviceKey;
use crate::baselines::{kmerge, merge_path, merge_sort, radix};
use crate::bench::{BenchOpts, Bencher};
use crate::dtype::{bits_eq, ElemType, SortKey};
use crate::session::{Launch, Session};
use crate::util::Prng;
use crate::workload::{generate, Distribution, KeyGen};

/// One measured engine row of the sort bench.
#[derive(Clone, Debug)]
pub struct SortBenchRecord {
    /// Engine name (see the module docs legend).
    pub engine: String,
    /// Element type sorted.
    pub dtype: ElemType,
    /// Elements per iteration.
    pub n: usize,
    /// Worker threads the engine ran with (1 for sequential engines).
    pub threads: usize,
    /// Mean seconds per iteration.
    pub secs_mean: f64,
    /// Standard deviation of the per-iteration seconds.
    pub secs_std: f64,
    /// Throughput in bytes/second (n × key bytes / mean seconds).
    pub bytes_per_sec: f64,
    /// Recorded samples.
    pub samples: usize,
}

/// The full bench outcome: every record plus the grid it ran over.
#[derive(Clone, Debug, Default)]
pub struct SortBenchReport {
    /// Elements per iteration.
    pub n: usize,
    /// Parallel-engine thread count.
    pub threads: usize,
    /// The launch knobs the parallel engines ran with (recorded in the
    /// JSON metadata for reproducibility).
    pub launch: Launch,
    /// All measured rows.
    pub records: Vec<SortBenchRecord>,
}

impl SortBenchReport {
    /// Find a record by engine name and dtype.
    pub fn get(&self, engine: &str, dtype: ElemType) -> Option<&SortBenchRecord> {
        self.records.iter().find(|r| r.engine == engine && r.dtype == dtype)
    }

    /// Serialise as JSON (`BENCH_sort.json` schema, version 2: adds the
    /// `launch` metadata object).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"version\": 2,\n");
        s.push_str(&format!("  \"n\": {},\n  \"threads\": {},\n", self.n, self.threads));
        s.push_str(&format!("  \"launch\": {},\n", crate::bench::launch_json(&self.launch)));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"engine\": \"{}\", \"dtype\": \"{}\", \"n\": {}, \"threads\": {}, \
                 \"secs_mean\": {:.9}, \"secs_std\": {:.9}, \"gbps\": {:.6}, \"samples\": {}}}{}\n",
                r.engine,
                r.dtype.name(),
                r.n,
                r.threads,
                r.secs_mean,
                r.secs_std,
                r.bytes_per_sec / 1e9,
                r.samples,
                if i + 1 == self.records.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// An in-place sort engine under measurement: `(buffer, threads)`.
type SortFn<K> = Box<dyn Fn(&mut Vec<K>, usize)>;

/// Measure every engine for one dtype and append the rows to `report`.
/// Errors if any engine's output diverges from the std reference sort.
fn bench_dtype<K: KeyGen + DeviceKey>(
    n: usize,
    threads: usize,
    launch: &Launch,
    opts: &BenchOpts,
    report: &mut SortBenchReport,
) -> anyhow::Result<()> {
    let dtype = K::ELEM;
    let bytes = (n * K::KEY_BYTES) as f64;
    let xs: Vec<K> = generate(&mut Prng::new(0xBE7C4 + n as u64), Distribution::Uniform, n);
    let mut want = xs.clone();
    want.sort_unstable_by(|a, b| a.cmp_total(b));
    eprintln!("-- bench-sort {dtype} n={n} threads={threads}");

    let native = Session::native().with_defaults(launch.clone());
    let threaded = Session::threaded(threads).with_defaults(launch.clone());
    let radix_par_min = launch.par_threshold_or(radix::RADIX_PAR_MIN);
    // Effective parallel worker count after the launch knobs: recorded in
    // the rows and fed to the engines that take an explicit count, so the
    // JSON metadata really reproduces the run.
    let par_threads = launch.tasks_for(threads, n);

    // In-place sort engines: (name, threads, routine). Each consumes a
    // fresh clone per iteration (setup excluded from timing).
    let engines: Vec<(&str, usize, SortFn<K>)> = vec![
        ("sort-native", 1, {
            let native = native.clone();
            Box::new(move |v, _| {
                native.sort(v, None).expect("native sort");
            })
        }),
        ("sort-threaded", par_threads, {
            let threaded = threaded.clone();
            Box::new(move |v, _| {
                threaded.sort(v, None).expect("threaded sort");
            })
        }),
        ("merge-seq[TM]", 1, Box::new(|v, _| merge_sort(v))),
        ("radix-seq[TR]", 1, Box::new(|v, _| radix::radix_sort(v))),
        ("radix-par[TR]", par_threads, Box::new(move |v, t| {
            radix::radix_sort_threaded_with(v, t, radix_par_min)
        })),
    ];
    let mut bencher = Bencher::new(opts.clone());
    for (name, t, routine) in &engines {
        let label = format!("{name}/{dtype}");
        bencher.run_with_setup(&label, Some(bytes), || xs.clone(), |mut v| routine(&mut v, *t));
        // Correctness gate: one fresh run against the reference, compared
        // on bit images so total-order violations can't slip through.
        let mut check = xs.clone();
        routine(&mut check, *t);
        anyhow::ensure!(
            bits_eq(&check, &want),
            "engine {name} diverged from the reference sort on {dtype} (n={n}, threads={t})"
        );
        push_record(report, &bencher, &label, name, dtype, n, *t);
    }

    // Recombine-phase engines over pre-sorted runs: isolates the
    // merge-path speedup from the chunk-sort phase.
    let runs: Vec<Vec<K>> = {
        let mut sorted_chunks: Vec<Vec<K>> = split_ranges(n, threads.max(2))
            .into_iter()
            .map(|r| xs[r].to_vec())
            .collect();
        for c in &mut sorted_chunks {
            c.sort_unstable_by(|a, b| a.cmp_total(b));
        }
        sorted_chunks
    };
    let refs: Vec<&[K]> = runs.iter().map(|r| r.as_slice()).collect();
    let merge_par_min = launch.par_threshold_or(merge_path::PAR_MERGE_MIN);
    let run_merge = |out: &mut [K], t: usize| {
        if t == 1 {
            kmerge::kmerge_into_slice(&refs, out);
        } else {
            merge_path::kmerge_parallel_into_slice_with(&refs, out, t, merge_par_min);
        }
    };
    let mut out: Vec<K> = vec![K::min_key(); n];
    for (name, t) in [("kmerge-seq", 1usize), ("kmerge-par", par_threads)] {
        let label = format!("{name}/{dtype}");
        bencher.run(&label, Some(bytes), || run_merge(&mut out[..], t));
        // Correctness gate on a poisoned buffer: a silently no-op'ing
        // engine cannot pass by leaving stale (correct) output behind.
        out.iter_mut().for_each(|x| *x = K::min_key());
        run_merge(&mut out[..], t);
        anyhow::ensure!(
            bits_eq(&out, &want),
            "engine {name} diverged from the reference merge on {dtype} (n={n}, threads={t})"
        );
        push_record(report, &bencher, &label, name, dtype, n, t);
    }
    Ok(())
}

fn push_record(
    report: &mut SortBenchReport,
    bencher: &Bencher,
    label: &str,
    name: &str,
    dtype: ElemType,
    n: usize,
    threads: usize,
) {
    let r = bencher.get(label).expect("bench result recorded");
    report.records.push(SortBenchRecord {
        engine: name.to_string(),
        dtype,
        n,
        threads,
        secs_mean: r.time.mean,
        secs_std: r.time.std,
        bytes_per_sec: r.throughput_bps().unwrap_or(0.0),
        samples: r.time.n,
    });
}

/// Run the sort bench over `dtypes` with the given launch knobs and
/// return the report.
pub fn run_sort_bench(
    n: usize,
    threads: usize,
    dtypes: &[ElemType],
    opts: &BenchOpts,
    launch: &Launch,
) -> anyhow::Result<SortBenchReport> {
    let mut report = SortBenchReport {
        n,
        threads: threads.max(1),
        launch: launch.clone(),
        records: Vec::new(),
    };
    for &dt in dtypes {
        crate::dispatch_dtype!(dt, K => bench_dtype::<K>(n, report.threads, launch, opts, &mut report)?);
    }
    Ok(report)
}

/// CLI entry point: run the grid (`--quick` trims dtypes and sampling),
/// print a summary, and emit the JSON report to `out`.
pub fn run_and_emit(
    n: usize,
    threads: usize,
    quick: bool,
    out: &Path,
    launch: &Launch,
) -> anyhow::Result<()> {
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() }.scaled_from_env();
    let dtypes: &[ElemType] =
        if quick { &[ElemType::I32, ElemType::F64] } else { &ElemType::ALL };
    let report = run_sort_bench(n, threads, dtypes, &opts, launch)?;
    report.write_json(out)?;
    println!(
        "bench-sort: {} rows (n={}, threads={}) -> {}",
        report.records.len(),
        report.n,
        report.threads,
        out.display()
    );
    // Headline ratios for the log: parallel engine vs its sequential
    // counterpart, per dtype.
    let pairs = [
        ("sort-threaded", "sort-native"),
        ("radix-par[TR]", "radix-seq[TR]"),
        ("kmerge-par", "kmerge-seq"),
    ];
    for &dt in dtypes {
        for (par, seq) in pairs {
            if let (Some(p), Some(s)) = (report.get(par, dt), report.get(seq, dt)) {
                if s.secs_mean > 0.0 && p.secs_mean > 0.0 {
                    println!(
                        "  {dt:<5} {par:<14} vs {seq:<14} speedup {:.2}x ({:.2} vs {:.2} GB/s)",
                        s.secs_mean / p.secs_mean,
                        p.bytes_per_sec / 1e9,
                        s.bytes_per_sec / 1e9,
                    );
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> BenchOpts {
        BenchOpts {
            warmup: std::time::Duration::from_millis(2),
            budget: std::time::Duration::from_millis(20),
            min_samples: 2,
            max_samples: 3,
        }
    }

    #[test]
    fn report_covers_engines_and_json_parses() {
        let launch = Launch::new().max_tasks(2);
        let report =
            run_sort_bench(20_000, 2, &[ElemType::I32], &tiny_opts(), &launch).unwrap();
        // 5 in-place engines + 2 recombine engines.
        assert_eq!(report.records.len(), 7);
        assert!(report.get("sort-threaded", ElemType::I32).is_some());
        assert!(report.get("kmerge-par", ElemType::I32).is_some());
        assert!(report.records.iter().all(|r| r.bytes_per_sec > 0.0));
        // The emitted JSON round-trips through the in-repo parser,
        // including the launch metadata (reproducibility record).
        let j = crate::util::json::Json::parse(&report.to_json()).unwrap();
        assert_eq!(j.get("version").as_usize(), Some(2));
        assert_eq!(j.get("results").as_arr().unwrap().len(), 7);
        assert_eq!(
            j.get("results").as_arr().unwrap()[0].get("engine").as_str(),
            Some("sort-native")
        );
        assert_eq!(j.get("launch").get("max_tasks").as_usize(), Some(2));
        assert_eq!(j.get("launch").get("block_size"), &crate::util::json::Json::Null);
    }
}
