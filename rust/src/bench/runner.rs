//! The measurement engine.

use std::time::{Duration, Instant};

use crate::util::{fmt_duration, fmt_throughput, Summary};

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// Warm-up budget before any sample is recorded.
    pub warmup: Duration,
    /// Total sampling budget.
    pub budget: Duration,
    /// Minimum / maximum number of recorded samples.
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(3),
            min_samples: 5,
            max_samples: 50,
        }
    }
}

impl BenchOpts {
    /// A faster profile for CI / smoke runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(700),
            min_samples: 3,
            max_samples: 15,
        }
    }

    /// Scale budgets by the `AK_BENCH_SCALE` env var (e.g. 0.2 for smoke).
    pub fn scaled_from_env(mut self) -> Self {
        if let Ok(s) = std::env::var("AK_BENCH_SCALE") {
            if let Ok(f) = s.parse::<f64>() {
                let f = f.clamp(0.01, 100.0);
                self.warmup = Duration::from_secs_f64(self.warmup.as_secs_f64() * f);
                self.budget = Duration::from_secs_f64(self.budget.as_secs_f64() * f);
            }
        }
        self
    }
}

/// One benchmark's outcome.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time statistics (seconds).
    pub time: Summary,
    /// Bytes processed per iteration, if meaningful (enables GB/s).
    pub bytes: Option<f64>,
    pub iterations: u64,
}

impl BenchResult {
    pub fn throughput_bps(&self) -> Option<f64> {
        self.bytes.filter(|_| self.time.mean > 0.0).map(|b| b / self.time.mean)
    }

    /// One human-readable row: `name  mean ±σ  [GB/s]`.
    pub fn row(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12} ±{:>10}  (n={})",
            self.name,
            fmt_duration(self.time.mean),
            fmt_duration(self.time.std),
            self.time.n
        );
        if let Some(bps) = self.throughput_bps() {
            s.push_str(&format!("  {}", fmt_throughput(bps)));
        }
        s
    }
}

/// Measure `routine` (no per-iteration setup). Batches iterations when the
/// routine is faster than ~50 µs so timer overhead stays negligible.
pub fn benchmark<F: FnMut()>(name: &str, opts: &BenchOpts, mut routine: F) -> BenchResult {
    // Warm-up and batch-size estimation.
    let w0 = Instant::now();
    let mut once = Duration::ZERO;
    let mut warm_iters: u64 = 0;
    while w0.elapsed() < opts.warmup || warm_iters == 0 {
        let t = Instant::now();
        routine();
        once = t.elapsed();
        warm_iters += 1;
    }
    let batch = if once < Duration::from_micros(50) {
        (Duration::from_micros(200).as_nanos() / once.as_nanos().max(1)).max(1) as u64
    } else {
        1
    };

    let mut samples = Vec::new();
    let mut iterations = warm_iters;
    let s0 = Instant::now();
    while (samples.len() < opts.min_samples)
        || (samples.len() < opts.max_samples && s0.elapsed() < opts.budget)
    {
        let t = Instant::now();
        for _ in 0..batch {
            routine();
        }
        let dt = t.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        iterations += batch;
    }
    BenchResult { name: name.to_string(), time: Summary::of(&samples), bytes: None, iterations }
}

/// Measure with fresh per-iteration state: `setup` is excluded from the
/// timing (needed for in-place sorts, which consume their input).
pub fn benchmark_with_setup<S, T, F>(
    name: &str,
    opts: &BenchOpts,
    mut setup: S,
    mut routine: F,
) -> BenchResult
where
    S: FnMut() -> T,
    F: FnMut(T),
{
    // Warm-up.
    let w0 = Instant::now();
    let mut warm_iters = 0u64;
    loop {
        let input = setup();
        let t = Instant::now();
        routine(input);
        let _ = t.elapsed();
        warm_iters += 1;
        if w0.elapsed() >= opts.warmup && warm_iters > 0 {
            break;
        }
    }

    let mut samples = Vec::new();
    let s0 = Instant::now();
    while (samples.len() < opts.min_samples)
        || (samples.len() < opts.max_samples && s0.elapsed() < opts.budget)
    {
        let input = setup();
        let t = Instant::now();
        routine(input);
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        time: Summary::of(&samples),
        bytes: None,
        iterations: warm_iters + samples.len() as u64,
    }
}

/// Collects results and renders a table (one per paper table/figure).
#[derive(Default)]
pub struct Bencher {
    pub opts: BenchOpts,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(opts: BenchOpts) -> Self {
        Self { opts, results: Vec::new() }
    }

    /// Run and record; `bytes` enables GB/s in the printed row.
    pub fn run<F: FnMut()>(&mut self, name: &str, bytes: Option<f64>, routine: F) -> &BenchResult {
        let mut r = benchmark(name, &self.opts, routine);
        r.bytes = bytes;
        eprintln!("  {}", r.row());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Run with per-iteration setup.
    pub fn run_with_setup<S, T, F>(
        &mut self,
        name: &str,
        bytes: Option<f64>,
        setup: S,
        routine: F,
    ) -> &BenchResult
    where
        S: FnMut() -> T,
        F: FnMut(T),
    {
        let mut r = benchmark_with_setup(name, &self.opts, setup, routine);
        r.bytes = bytes;
        eprintln!("  {}", r.row());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Find a recorded result by exact name.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchOpts {
        BenchOpts {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(30),
            min_samples: 3,
            max_samples: 8,
        }
    }

    #[test]
    fn measures_sleepy_routine() {
        let r = benchmark("sleep", &tiny(), || std::thread::sleep(Duration::from_micros(300)));
        assert!(r.time.mean >= 250e-6, "mean {}", r.time.mean);
        assert!(r.time.n >= 3);
    }

    #[test]
    fn batches_fast_routines() {
        let mut x = 0u64;
        let r = benchmark("fast", &tiny(), || x = x.wrapping_add(1));
        assert!(r.iterations > 100, "iterations {}", r.iterations);
    }

    #[test]
    fn setup_excluded_from_timing() {
        // Generous margins: sleep() on a loaded 1-core box overshoots.
        let r = benchmark_with_setup(
            "setup-heavy",
            &tiny(),
            || std::thread::sleep(Duration::from_millis(8)),
            |_| std::thread::sleep(Duration::from_micros(100)),
        );
        // Routine is ~0.1 ms; if setup leaked into timing mean would be >8 ms.
        assert!(r.time.mean < 5e-3, "mean {}", r.time.mean);
    }

    #[test]
    fn throughput_row() {
        let mut b = Bencher::new(tiny());
        b.run("with-bytes", Some(1e6), || std::thread::sleep(Duration::from_micros(200)));
        let r = b.get("with-bytes").unwrap();
        let gbps = r.throughput_bps().unwrap();
        assert!(gbps > 1e8 && gbps < 1e11, "{gbps}");
        assert!(r.row().contains("GB/s"));
    }
}
