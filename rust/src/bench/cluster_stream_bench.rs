//! `akbench bench-cluster-stream` — the multi-node × out-of-core sort
//! tracker (DESIGN.md §14): SIHSort with the external rank-local sorter
//! (`LocalSorter::External`) over rank-counts × budget ratios × dtypes,
//! emitting `BENCH_cluster_stream.json` next to `BENCH_stream.json`.
//!
//! Every configuration doubles as a correctness gate, which CI relies
//! on: the concatenated rank outputs must be bitwise-identical to one
//! single-node `Session::sort` of the same dataset on a subsampled
//! verification pass, every rank must report stream stats whose
//! pipeline shape respects the configured budget (run chunk within the
//! budget's derivation, genuinely out-of-core at ratio ≥ 8), and on the
//! disk medium every rank must actually spill. Any violation is a hard
//! error.
//!
//! Throughput is the paper's unit — total bytes / simulated makespan
//! (GB sorted per simulated second) — with host wall seconds recorded
//! alongside.

use std::path::Path;

use crate::backend::DeviceKey;
use crate::bench::verify_subsampled;
use crate::cfg::{RunConfig, Sorter};
use crate::coordinator::driver::run_distributed_sort_data;
use crate::dtype::ElemType;
use crate::obs::{CounterSnapshot, FABRIC_COUNTERS};
use crate::session::{Launch, Session};
use crate::stream::{MIN_IO_ELEMS, MIN_RUN_CHUNK};
use crate::util::Prng;
use crate::workload::{generate, KeyGen};

/// Rank grid of the full bench (the acceptance-critical scaling axis).
pub const FULL_RANKS: [usize; 3] = [2, 4, 8];
/// `--quick` rank grid (the CI smoke: 2 ranks).
pub const QUICK_RANKS: [usize; 1] = [2];
/// Per-rank shard-bytes : budget-bytes ratios. The first entry is the
/// acceptance-critical ≥ 8× out-of-core configuration.
pub const FULL_RATIOS: [usize; 2] = [8, 16];
/// `--quick` ratio grid.
pub const QUICK_RATIOS: [usize; 1] = [8];

/// Verification sample count per configuration.
const VERIFY_SAMPLES: usize = 2048;

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct ClusterStreamRecord {
    /// Simulated ranks in the collective.
    pub ranks: usize,
    /// Element type sorted.
    pub dtype: ElemType,
    /// Elements per rank.
    pub elems_per_rank: usize,
    /// Per-rank engine budget in bytes.
    pub budget_bytes: usize,
    /// Per-rank shard bytes / budget bytes.
    pub ratio: usize,
    /// Max sorted runs any rank generated locally.
    pub runs_max: usize,
    /// Max merge passes any rank ran locally.
    pub merge_passes_max: usize,
    /// Total bytes spilled by rank-local sorts (intermediate runs + the
    /// parked sorted shards), summed over ranks.
    pub local_spilled_bytes: u64,
    /// Total bytes spilled buffering exchange runs, summed over ranks.
    pub exchange_spilled_bytes: u64,
    /// Output positions bitwise-verified against the single-node sort.
    pub verified: usize,
    /// Splitter refinement rounds used.
    pub rounds_used: usize,
    /// Simulated end-to-end makespan (seconds).
    pub sim_secs: f64,
    /// Throughput in bytes / simulated second (the paper's unit).
    pub bytes_per_sim_sec: f64,
    /// Host wall seconds the whole collective took.
    pub wall_secs: f64,
    /// Fault/flow counters summed over driver restart attempts
    /// (DESIGN.md §16, §18): the registered
    /// [`FABRIC_COUNTERS`] carried as a registry snapshot — the JSON
    /// row emits it by iteration, so a newly registered counter
    /// reaches the schema without touching this file.
    pub fabric: CounterSnapshot,
}

impl ClusterStreamRecord {
    /// Sends that blocked on exhausted link credit.
    pub fn credit_stalls(&self) -> u64 {
        self.fabric.get("credit_stalls")
    }

    /// Sender-side retries after transient link faults.
    pub fn retries(&self) -> u64 {
        self.fabric.get("retries")
    }

    /// Deadline/fault timeouts.
    pub fn timeouts(&self) -> u64 {
        self.fabric.get("timeouts")
    }

    /// Messages eaten by injected link faults.
    pub fn dropped(&self) -> u64 {
        self.fabric.get("dropped")
    }

    /// In-process driver restarts that went on to finish the job.
    pub fn recoveries(&self) -> u64 {
        self.fabric.get("recoveries")
    }
}

/// The full bench outcome.
#[derive(Clone, Debug, Default)]
pub struct ClusterStreamReport {
    /// Elements per rank.
    pub elems_per_rank: usize,
    /// Host threads per rank-local streaming session.
    pub threads: usize,
    /// Spill medium of the streaming ranks.
    pub spill: &'static str,
    /// Seed of the subsampled verification passes — recorded so any
    /// reported `verified` count is reproducible from the JSON alone.
    pub verify_seed: u64,
    /// The launch knobs the per-chunk engines ran with.
    pub launch: Launch,
    /// All measured rows.
    pub records: Vec<ClusterStreamRecord>,
}

impl ClusterStreamReport {
    /// Find a record by rank count, dtype and budget ratio.
    pub fn get(
        &self,
        ranks: usize,
        dtype: ElemType,
        ratio: usize,
    ) -> Option<&ClusterStreamRecord> {
        self.records
            .iter()
            .find(|r| r.ranks == ranks && r.dtype == dtype && r.ratio == ratio)
    }

    /// Serialise as JSON (`BENCH_cluster_stream.json`, schema version 2:
    /// v2 adds the per-row fault/flow counters `credit_stalls`,
    /// `retries`, `timeouts`, `dropped` and `recoveries`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"version\": 2,\n");
        s.push_str(&format!(
            "  \"elems_per_rank\": {},\n  \"threads\": {},\n  \"spill\": \"{}\",\n  \
             \"verify_seed\": {},\n",
            self.elems_per_rank, self.threads, self.spill, self.verify_seed
        ));
        s.push_str(&format!("  \"launch\": {},\n", crate::bench::launch_json(&self.launch)));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"ranks\": {}, \"dtype\": \"{}\", \"elems_per_rank\": {}, \
                 \"budget_bytes\": {}, \"ratio\": {}, \"runs_max\": {}, \
                 \"merge_passes_max\": {}, \"local_spilled_bytes\": {}, \
                 \"exchange_spilled_bytes\": {}, \"verified\": {}, \"rounds_used\": {}, \
                 \"sim_secs\": {:.9}, \"gbps\": {:.6}, \"wall_secs\": {:.6}, {}}}{}\n",
                r.ranks,
                r.dtype.name(),
                r.elems_per_rank,
                r.budget_bytes,
                r.ratio,
                r.runs_max,
                r.merge_passes_max,
                r.local_spilled_bytes,
                r.exchange_spilled_bytes,
                r.verified,
                r.rounds_used,
                r.sim_secs,
                r.bytes_per_sim_sec / 1e9,
                r.wall_secs,
                r.fabric.json_fields(),
                if i + 1 == self.records.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Run one (ranks, ratio) configuration for dtype `K` and append the
/// verified row.
fn bench_config<K: KeyGen + DeviceKey>(
    base: &RunConfig,
    ranks: usize,
    ratio: usize,
    report: &mut ClusterStreamReport,
) -> anyhow::Result<()> {
    let dtype = K::ELEM;
    let shard_bytes = base.elems_per_rank * K::KEY_BYTES;
    let budget_bytes = (shard_bytes / ratio).max(1);
    let mut cfg = base.clone();
    cfg.ranks = ranks;
    cfg.dtype = dtype;
    cfg.sorter = Sorter::External;
    cfg.stream.budget_bytes = Some(budget_bytes);
    eprintln!(
        "-- bench-cluster-stream {dtype} ranks={ranks} n/rank={} budget={budget_bytes}B \
         (x{ratio}) spill={}",
        cfg.elems_per_rank,
        if cfg.stream.spill_memory { "memory" } else { "disk" },
    );

    let (out, outcomes) = run_distributed_sort_data::<K>(&cfg, None)?;

    // Correctness gate 1: bitwise vs one single-node Session::sort of
    // the identical dataset (the driver's shard generation is
    // deterministic in (seed, rank)).
    let got: Vec<K> = outcomes.iter().flat_map(|o| o.data.iter().copied()).collect();
    let mut root = Prng::new(cfg.seed);
    let mut want: Vec<K> = Vec::with_capacity(ranks * cfg.elems_per_rank);
    for r in 0..ranks {
        let mut rng = root.fork(r as u64);
        want.extend(generate::<K>(&mut rng, cfg.dist, cfg.elems_per_rank));
    }
    let session = Session::threaded(cfg.host_threads).with_defaults(cfg.launch.clone());
    session.sort(&mut want, None)?;
    let verified = verify_subsampled(&got, &want, VERIFY_SAMPLES, cfg.seed ^ 0xC157)?;
    drop(got);
    drop(want);

    // Correctness gate 2: every rank ran the streamed pipeline under
    // the configured budget (pipeline-shape accounting).
    let budget_elems = (budget_bytes / K::KEY_BYTES).max(2 * MIN_IO_ELEMS);
    let run_chunk_cap = (budget_elems / 3).max(MIN_RUN_CHUNK);
    let mut runs_max = 0usize;
    let mut merge_passes_max = 0usize;
    let mut local_spilled = 0u64;
    let mut exchange_spilled = 0u64;
    for (r, o) in outcomes.iter().enumerate() {
        let st = o
            .stream
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("rank {r}: external rank without stream stats"))?;
        anyhow::ensure!(
            st.budget_bytes == budget_bytes,
            "rank {r}: ran budget {} instead of {budget_bytes}",
            st.budget_bytes
        );
        anyhow::ensure!(
            st.local.run_chunk_elems <= run_chunk_cap,
            "rank {r}: run chunk {} exceeds the budget derivation cap {run_chunk_cap}",
            st.local.run_chunk_elems
        );
        if ratio >= 8 {
            anyhow::ensure!(
                st.local.runs > 1,
                "rank {r}: x{ratio} budget must force an out-of-core local sort \
                 ({} runs)",
                st.local.runs
            );
        }
        if !cfg.stream.spill_memory {
            anyhow::ensure!(
                st.local_run_bytes > 0,
                "rank {r}: disk medium must spill the parked sorted shard"
            );
        }
        runs_max = runs_max.max(st.local.runs);
        merge_passes_max = merge_passes_max.max(st.local.merge_passes);
        local_spilled += st.local.spilled_bytes + st.local_run_bytes;
        exchange_spilled += st.exchange_spilled_bytes;
    }

    // Correctness gate 3 (`--faults` smoke): when a fault plan is
    // injected the run must both verify bitwise (gate 1 above already
    // hard-errored otherwise) AND show the faults actually fired —
    // a clean counter set means the injection never exercised the
    // recovery machinery and the smoke proved nothing.
    if cfg.comm.faults.is_some() {
        anyhow::ensure!(
            out.record.retries() > 0
                || out.record.timeouts() > 0
                || out.record.dropped() > 0
                || out.record.recoveries() > 0,
            "--faults {:?} injected but no fault counter fired \
             (retries/timeouts/dropped/recoveries all zero)",
            cfg.comm.faults.as_deref().unwrap_or("")
        );
    }

    report.records.push(ClusterStreamRecord {
        ranks,
        dtype,
        elems_per_rank: cfg.elems_per_rank,
        budget_bytes,
        ratio,
        runs_max,
        merge_passes_max,
        local_spilled_bytes: local_spilled,
        exchange_spilled_bytes: exchange_spilled,
        verified,
        rounds_used: out.rounds_used,
        sim_secs: out.record.sim_total,
        bytes_per_sim_sec: out.record.throughput_bps(),
        wall_secs: out.record.wall_secs,
        fabric: out.record.fabric.clone(),
    });
    Ok(())
}

/// Run the grid: ranks × ratios × dtypes, one verified collective each.
pub fn run_cluster_stream_bench(
    base: &RunConfig,
    ranks_list: &[usize],
    ratios: &[usize],
    dtypes: &[ElemType],
) -> anyhow::Result<ClusterStreamReport> {
    let mut report = ClusterStreamReport {
        elems_per_rank: base.elems_per_rank,
        threads: base.host_threads.max(1),
        spill: if base.stream.spill_memory { "memory" } else { "disk" },
        verify_seed: base.seed ^ 0xC157,
        launch: base.launch.clone(),
        records: Vec::new(),
    };
    for &dt in dtypes {
        for &ranks in ranks_list {
            for &ratio in ratios {
                crate::dispatch_dtype!(dt, K => {
                    bench_config::<K>(base, ranks, ratio, &mut report)?
                });
            }
        }
    }
    Ok(report)
}

/// CLI entry point: run the grid (`--quick` trims ranks, ratios, dtypes
/// and the per-rank size), print a summary, and emit the JSON report.
pub fn run_and_emit(base: &RunConfig, quick: bool, out: &Path) -> anyhow::Result<()> {
    let dtypes: &[ElemType] =
        if quick { &[ElemType::I32, ElemType::F64] } else { &ElemType::ALL };
    let ranks_list: &[usize] = if quick { &QUICK_RANKS } else { &FULL_RANKS };
    let ratios: &[usize] = if quick { &QUICK_RATIOS } else { &FULL_RATIOS };
    let report = run_cluster_stream_bench(base, ranks_list, ratios, dtypes)?;
    report.write_json(out)?;
    println!(
        "bench-cluster-stream: {} rows (n/rank={}, threads={}, spill={}) -> {}",
        report.records.len(),
        report.elems_per_rank,
        report.threads,
        report.spill,
        out.display()
    );
    for r in &report.records {
        println!(
            "  {:<5} ranks={:<3} x{:<3} {:>8.3} GB/s sim ({} runs, {} passes, {} rounds, \
             {} positions verified, wall {:.2}s)",
            r.dtype.name(),
            r.ranks,
            r.ratio,
            r.bytes_per_sim_sec / 1e9,
            r.runs_max,
            r.merge_passes_max,
            r.rounds_used,
            r.verified,
            r.wall_secs,
        );
        if r.fabric.any_nonzero() {
            println!("        faults: {}", r.fabric.render_nonzero());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_rows_verify_and_json_parses() {
        let mut base = RunConfig::default();
        base.elems_per_rank = 12_000;
        base.host_threads = 2;
        base.stream.spill_memory = true;
        let report =
            run_cluster_stream_bench(&base, &[2], &[8], &[ElemType::I32]).unwrap();
        assert_eq!(report.records.len(), 1);
        let r = report.get(2, ElemType::I32, 8).unwrap();
        // The acceptance property: each rank's shard is 8x its budget,
        // so every rank went out of core and still verified bitwise.
        assert!(r.runs_max > 1, "{} runs", r.runs_max);
        assert!(r.merge_passes_max >= 1);
        assert!(r.verified > 2);
        assert_eq!(r.budget_bytes, 12_000 * 4 / 8);
        let j = crate::util::json::Json::parse(&report.to_json()).unwrap();
        assert_eq!(j.get("version").as_usize(), Some(2));
        assert_eq!(j.get("spill").as_str(), Some("memory"));
        // The verification seed is part of the report so `verified`
        // counts are reproducible from the JSON alone.
        assert_eq!(j.get("verify_seed").as_usize(), Some((base.seed ^ 0xC157) as usize));
        let rows = j.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        // Schema v2, coverage contract: every *registered* fabric
        // counter appears on every row (iterated from the registry, so
        // a newly registered name fails here until the row carries it),
        // and a fault-free run reports them all zero.
        for key in FABRIC_COUNTERS {
            assert_eq!(rows[0].get(key).as_usize(), Some(0), "row key {key}");
        }
        assert_eq!(r.fabric.names(), FABRIC_COUNTERS.to_vec());
    }

    #[test]
    fn faults_smoke_fires_counters_and_verifies() {
        // The CI `--faults` smoke in miniature: a lossy link through a
        // full External-sorter collective must still verify bitwise and
        // must show non-zero fault counters (else bench_config bails).
        // The drop rule makes the counters deterministic; the flaky
        // rule keeps some seeded chaos on top.
        let mut base = RunConfig::default();
        base.elems_per_rank = 6_000;
        base.host_threads = 2;
        base.stream.spill_memory = true;
        base.comm.faults = Some("drop:0:1:2, flaky:0:1:0.25".into());
        base.comm.fault_seed = 7;
        base.comm.retry_attempts = 10;
        base.comm.max_restarts = 2;
        let report =
            run_cluster_stream_bench(&base, &[2], &[8], &[ElemType::I64]).unwrap();
        let r = report.get(2, ElemType::I64, 8).unwrap();
        assert!(r.verified > 2);
        assert!(
            r.dropped() >= 2 && r.retries() >= 2,
            "lossy link fired nothing: {r:?}"
        );
    }

    #[test]
    fn disk_spill_accounts_bytes() {
        let mut base = RunConfig::default();
        base.elems_per_rank = 8_000;
        base.host_threads = 2;
        let report =
            run_cluster_stream_bench(&base, &[2], &[8], &[ElemType::F64]).unwrap();
        let r = report.get(2, ElemType::F64, 8).unwrap();
        assert!(r.local_spilled_bytes > 0, "disk medium must spill locally");
        assert!(r.verified > 2);
    }
}
