//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §9).
//!
//! `akbench <subcommand> [flags]`; every figure/table is a subcommand so
//! `cargo bench` targets and interactive runs share one code path
//! (`coordinator::campaign`).

use std::collections::BTreeMap;

use anyhow::{bail, Context};

use crate::cfg::{BackendKind, RunConfig, Sorter, Toml, TransferMode};
use crate::dtype::ElemType;
use crate::workload::Distribution;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// The subcommand (first argument).
    pub command: String,
    /// `--flag value` pairs (boolean flags map to `"true"`).
    pub flags: BTreeMap<String, String>,
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
}

/// `akbench help` text: the command + flag reference.
pub const USAGE: &str = "\
akbench — AcceleratedKernels reproduction driver

USAGE: akbench <command> [--flag value]...

COMMANDS
  info                 artifact catalog + runtime platform summary
  sort                 one distributed sort run (prints the full record)
  table2               Table II arithmetic kernel benchmark
  fig1 .. fig5         regenerate the paper's figures (text + CSV)
  calibrate            measure host:device sort throughput and print the
                       hybrid co-processing split (DESIGN.md §10)
  bench-sort           host sort engine throughput sweep (sequential vs
                       parallel merge-path / threaded radix, DESIGN.md
                       §11) -> BENCH_sort.json; --out overrides the path
  bench-stream         out-of-core pipeline throughput sweep: external
                       sort of datasets 8x/16x larger than the memory
                       budget, verified bitwise against the in-memory
                       sort (DESIGN.md §13) -> BENCH_stream.json
  bench-records        record-stream (dataset engine) sweep: sort-by-key
                       across payload widths, sortperm, group-reduce,
                       distinct, merge-join, each verified against an
                       in-memory reference (DESIGN.md §19)
                       -> BENCH_records.json
  bench-cluster-stream multi-node out-of-core sweep: SIHSort with the
                       external rank-local sorter over rank-counts x
                       budget ratios x dtypes, verified bitwise against
                       one Session::sort (DESIGN.md §14)
                       -> BENCH_cluster_stream.json
  ablate               design-choice ablations (final phase, digit width,
                       samples/rank, refinement rounds)
  selftest             quick end-to-end health check

COMMON FLAGS
  --config PATH        TOML config ([run] + [cluster] sections)
  --ranks N            number of simulated ranks        (default 8)
  --dtype T            i16|i32|i64|i128|f32|f64         (default i32)
  --dist D             uniform|sorted|reverse|nearly-sorted|dup-heavy|zipf|gaussian
  --sorter S           JB|AK|TM|TR|HY                   (default AK)
  --backend B          native|threaded|device|hybrid (implies the sorter:
                       hybrid ranks co-sort on CPU+GPU at once)
  --host-fraction X    hybrid: fixed host share in [0,1] (default: calibrated)
  --transfer M         direct|staged                    (default direct)
  --elems-per-rank N   elements per rank                (default 1Mi)
  --mb-per-rank X      per-rank size in MB (overrides elems)
  --seed N             workload seed                    (default 42)
  --gpu-speedup X      device model calibration         (default 50)
  --final P            merge|sort (SIHSort final phase)
  --quick              smaller grids / shorter sampling
  --no-device          skip artifact loading (host paths only)
  --n N                element count for table2/calibrate/examples
  --threads N          host thread count: table2 rows and the hybrid
                       rank pool (sort/calibrate/figs)
  --spill M            streaming runs: disk|memory spill medium
                       (default disk; [stream] spill in TOML)
  --spill-dir PATH     streaming runs: parent dir for the guarded spill
                       directory (default OS temp; [stream] spill_dir)
  --local-sorter S     rank-local sorter by long name; `external`
                       streams each rank's shard through the budgeted
                       out-of-core engine (alias of --sorter EX,
                       DESIGN.md §14)
  --stream-budget-mb X per-rank engine-state budget in MB for the
                       external sorter ([stream] budget_mb; default:
                       a quarter of the per-rank shard)
  --checkpoint-dir P   crash-safe checkpoint root for external/cluster
                       sorts ([stream] checkpoint; requires --sorter EX /
                       --local-sorter external, DESIGN.md §15)
  --resume             resume a killed run from the manifests under
                       --checkpoint-dir instead of starting fresh; the
                       same config (seed, dtype, budget) must be given
                       ([stream] resume)

COMM / FAULT FLAGS (bounded fallible fabric — DESIGN.md §16)
  --comm-cap-mb X      per-link in-flight credit cap in MB for every
                       link kind ([comm] cap_mb; per-kind keys
                       cap_nvlink_mb/cap_ib_mb/cap_pcie_mb/
                       cap_hostmem_mb in TOML; default 64)
  --recv-timeout SECS  deadline of every blocking receive/barrier
                       ([comm] recv_timeout_secs; default 600)
  --watchdog-secs SECS driver watchdog: abort + per-rank diagnostics if
                       the collective has not joined by then
                       ([comm] watchdog_secs; default 300)
  --max-restarts N     in-process restart attempts after a recoverable
                       rank death / comm timeout; checkpointed runs
                       resume from their manifests ([comm] max_restarts;
                       default 0)
  --faults SPEC        deterministic fault plan, comma-separated rules:
                       drop:SRC:DST:N, flaky:SRC:DST:P, delay:SRC:DST:S,
                       partition:K:OPS, kill:RANK:N[:PHASE],
                       stall:RANK:N[:PHASE]  ([comm] faults)
  --fault-seed N       seed for the plan's random draws ([comm]
                       fault_seed; default 0)
  --hb-check           happens-before debug mode: vector clocks,
                       per-channel delivery monotonicity checks, and a
                       wait-for graph that reports a deadlock as a named
                       cycle the moment it closes ([comm] hb_check;
                       DESIGN.md §17)

OBSERVABILITY FLAGS (tracing & metrics — DESIGN.md §18)
  --trace-out PATH     write a Chrome/Perfetto trace-event JSON timeline
                       of the run (per-rank phase spans, fault/retry
                       instants, per-link in-flight counter tracks) to
                       PATH ([obs] trace_out; paths inside a guarded
                       spill dir are remapped outside it)
  --trace-summary      print a per-track phase table after the run
                       ([obs] trace_summary; arms tracing even without
                       --trace-out)
  --trace-ring-capacity N  per-thread trace ring capacity in events
                       ([obs] ring_capacity; default 65536 — a full
                       ring drops the newest events and reports it)

LAUNCH KNOBS (per-call tuning, Session/Launch API — DESIGN.md §12)
  --max-tasks N        cap host worker tasks per call
  --min-elems-per-task N  spawn no task for fewer elements
  --par-threshold N    stay sequential below N elements (overrides the
                       engine gates: chunk / merge-path / radix / co-split)
  --block-size N       device chunk granule (elements per artifact call)
  --reuse-scratch      reuse temp buffers across calls (session pool)
";

impl Cli {
    /// Parse `std::env::args()`-style input (program name included).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Cli> {
        let mut it = args.into_iter().skip(1);
        let mut cli = Cli::default();
        let Some(cmd) = it.next() else {
            bail!("missing command\n\n{USAGE}");
        };
        if cmd == "--help" || cmd == "-h" || cmd == "help" {
            cli.command = "help".into();
            return Ok(cli);
        }
        cli.command = cmd;
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // Boolean flags take no value; detect by peeking semantics:
                // known boolean names are listed here.
                if matches!(
                    name,
                    "quick" | "no-device" | "help" | "verify" | "reuse-scratch" | "resume"
                        | "hb-check" | "trace-summary"
                ) {
                    cli.flags.insert(name.to_string(), "true".to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("flag --{name} expects a value\n\n{USAGE}"))?;
                    cli.flags.insert(name.to_string(), v);
                }
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    /// Raw value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Integer value of `--name` (`_` separators allowed), if present.
    pub fn get_usize(&self, name: &str) -> anyhow::Result<Option<usize>> {
        self.get(name)
            .map(|v| v.replace('_', "").parse::<usize>().with_context(|| format!("--{name}: bad integer '{v}'")))
            .transpose()
    }

    /// Float value of `--name`, if present.
    pub fn get_f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{name}: bad number '{v}'")))
            .transpose()
    }

    /// Was `--name` passed (boolean flags included)?
    pub fn has(&self, name: &str) -> bool {
        self.get(name).is_some()
    }

    /// Build the RunConfig: defaults ← config file ← CLI flags.
    pub fn run_config(&self) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = self.get("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            let doc = Toml::parse(&text).with_context(|| format!("parsing config {path}"))?;
            cfg.apply_toml(&doc)?;
        }
        if let Some(v) = self.get_usize("ranks")? {
            cfg.ranks = v;
        }
        if let Some(v) = self.get("dtype") {
            cfg.dtype = ElemType::parse(v).with_context(|| format!("--dtype: unknown '{v}'"))?;
        }
        if let Some(v) = self.get("dist") {
            cfg.dist =
                Distribution::parse(v).with_context(|| format!("--dist: unknown '{v}'"))?;
        }
        if let Some(v) = self.get("backend") {
            let kind =
                BackendKind::parse(v).with_context(|| format!("--backend: unknown '{v}'"))?;
            cfg.backend = Some(kind);
            cfg.sorter = kind.sorter();
        }
        if let Some(v) = self.get("sorter") {
            cfg.sorter = Sorter::parse(v).with_context(|| format!("--sorter: unknown '{v}'"))?;
        }
        if let Some(v) = self.get("local-sorter") {
            cfg.sorter =
                Sorter::parse(v).with_context(|| format!("--local-sorter: unknown '{v}'"))?;
        }
        if let Some(v) = self.get_f64("host-fraction")? {
            anyhow::ensure!(
                (0.0..=1.0).contains(&v),
                "--host-fraction: expected a value in [0, 1], got {v}"
            );
            cfg.hybrid_host_fraction = Some(v);
        }
        if let Some(v) = self.get_usize("threads")? {
            cfg.host_threads = v.max(1);
        }
        if let Some(v) = self.get("transfer") {
            cfg.transfer =
                TransferMode::parse(v).with_context(|| format!("--transfer: unknown '{v}'"))?;
        }
        if let Some(v) = self.get_usize("elems-per-rank")? {
            cfg.elems_per_rank = v;
        }
        if let Some(v) = self.get_f64("mb-per-rank")? {
            cfg.elems_per_rank = ((v * 1e6) as usize / cfg.dtype.size_bytes()).max(1);
        }
        if let Some(v) = self.get_usize("seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = self.get_f64("gpu-speedup")? {
            cfg.cluster.gpu_speedup = v;
        }
        if let Some(v) = self.get("final") {
            cfg.final_phase = match v {
                "merge" => crate::cfg::FinalPhase::Merge,
                "sort" => crate::cfg::FinalPhase::Sort,
                _ => bail!("--final: expected merge|sort"),
            };
        }
        if let Some(v) = self.get_usize("samples-per-rank")? {
            cfg.samples_per_rank = v;
        }
        if let Some(v) = self.get_usize("refine-rounds")? {
            cfg.refine_rounds = v;
        }
        if let Some(v) = self.get("spill") {
            cfg.stream.spill_memory = crate::cfg::StreamCfg::parse_spill(v)
                .with_context(|| format!("--spill: bad value '{v}'"))?;
        }
        if let Some(v) = self.get("spill-dir") {
            cfg.stream.spill_dir = Some(v.to_string());
        }
        if let Some(v) = self.get_f64("stream-budget-mb")? {
            anyhow::ensure!(v > 0.0, "--stream-budget-mb: expected a positive size, got {v}");
            cfg.stream.budget_bytes = Some(((v * 1e6) as usize).max(1));
        }
        if let Some(v) = self.get("checkpoint-dir") {
            cfg.stream.checkpoint_dir = Some(v.to_string());
        }
        if self.has("resume") {
            cfg.stream.resume = true;
        }
        // Comm / fault flags (DESIGN.md §16).
        if let Some(v) = self.get_f64("comm-cap-mb")? {
            anyhow::ensure!(v > 0.0, "--comm-cap-mb: expected a positive size, got {v}");
            cfg.comm.set_all_caps_mb(v);
        }
        if let Some(v) = self.get_f64("recv-timeout")? {
            anyhow::ensure!(v > 0.0, "--recv-timeout: expected positive seconds, got {v}");
            cfg.comm.recv_timeout_secs = v;
        }
        if let Some(v) = self.get_f64("watchdog-secs")? {
            anyhow::ensure!(v > 0.0, "--watchdog-secs: expected positive seconds, got {v}");
            cfg.comm.watchdog_secs = v;
        }
        if let Some(v) = self.get_usize("max-restarts")? {
            cfg.comm.max_restarts = v as u32;
        }
        if let Some(v) = self.get("faults") {
            cfg.comm.faults = Some(v.to_string());
        }
        if let Some(v) = self.get_usize("fault-seed")? {
            cfg.comm.fault_seed = v as u64;
        }
        if self.has("hb-check") {
            cfg.comm.hb_check = true;
        }
        // Observability flags (DESIGN.md §18).
        if let Some(v) = self.get("trace-out") {
            cfg.obs.trace_out = Some(v.to_string());
        }
        if self.has("trace-summary") {
            cfg.obs.trace_summary = true;
        }
        if let Some(v) = self.get_usize("trace-ring-capacity")? {
            anyhow::ensure!(v > 0, "--trace-ring-capacity: expected a positive count");
            cfg.obs.ring_capacity = v;
        }
        // Unparsable fault specs fail at flag-parse time, not mid-run.
        cfg.comm.fault_plan().context("--faults")?;
        cfg.launch = self.launch_overrides(cfg.launch.clone())?;
        Ok(cfg)
    }

    /// Overlay the launch-knob flags onto `base` (config-file values).
    pub fn launch_overrides(
        &self,
        mut base: crate::session::Launch,
    ) -> anyhow::Result<crate::session::Launch> {
        if let Some(v) = self.get_usize("max-tasks")? {
            base.max_tasks = Some(v.max(1));
        }
        if let Some(v) = self.get_usize("min-elems-per-task")? {
            base.min_elems_per_task = Some(v.max(1));
        }
        if let Some(v) = self.get_usize("par-threshold")? {
            base.prefer_parallel_threshold = Some(v);
        }
        if let Some(v) = self.get_usize("block-size")? {
            base.block_size = Some(v.max(1));
        }
        if self.has("reuse-scratch") {
            base.reuse_scratch = Some(true);
        }
        Ok(base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        std::iter::once("akbench".to_string())
            .chain(s.split_whitespace().map(String::from))
            .collect()
    }

    #[test]
    fn parses_flags_and_positional() {
        let c = Cli::parse(args("sort --ranks 16 --dtype f64 extra")).unwrap();
        assert_eq!(c.command, "sort");
        assert_eq!(c.get("ranks"), Some("16"));
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn bool_flags_take_no_value() {
        let c = Cli::parse(args("fig2 --quick --ranks 4")).unwrap();
        assert!(c.has("quick"));
        assert_eq!(c.get_usize("ranks").unwrap(), Some(4));
    }

    #[test]
    fn config_precedence() {
        let c = Cli::parse(args("sort --dtype i64 --mb-per-rank 2")).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.dtype, ElemType::I64);
        assert_eq!(cfg.elems_per_rank, 2_000_000 / 8);
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Cli::parse(args("sort --ranks")).is_err());
        assert!(Cli::parse(vec!["akbench".to_string()]).is_err());
    }

    #[test]
    fn bad_enum_values_error() {
        let c = Cli::parse(args("sort --dtype nope")).unwrap();
        assert!(c.run_config().is_err());
    }

    #[test]
    fn launch_flags_flow_into_config() {
        let c = Cli::parse(args(
            "sort --max-tasks 3 --min-elems-per-task 2048 --par-threshold 512 --block-size 65536 --reuse-scratch",
        ))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.launch.max_tasks, Some(3));
        assert_eq!(cfg.launch.min_elems_per_task, Some(2048));
        assert_eq!(cfg.launch.prefer_parallel_threshold, Some(512));
        assert_eq!(cfg.launch.block_size, Some(65536));
        assert_eq!(cfg.launch.reuse_scratch, Some(true));
        // Bool flag takes no value: the next token stays positional.
        let c = Cli::parse(args("sort --reuse-scratch extra")).unwrap();
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn stream_flags_flow_into_config() {
        let c = Cli::parse(args("bench-stream --spill memory --spill-dir /scratch")).unwrap();
        let cfg = c.run_config().unwrap();
        assert!(cfg.stream.spill_memory);
        assert_eq!(cfg.stream.spill_dir.as_deref(), Some("/scratch"));
        // Default medium is disk; bad values error.
        let default_cfg = Cli::parse(args("bench-stream")).unwrap().run_config().unwrap();
        assert!(!default_cfg.stream.spill_memory);
        assert_eq!(default_cfg.stream.checkpoint_dir, None);
        assert!(!default_cfg.stream.resume);
        let c = Cli::parse(args("bench-stream --spill tape")).unwrap();
        assert!(c.run_config().is_err());
    }

    #[test]
    fn checkpoint_flags_flow_into_config() {
        // --resume is boolean: the path after it stays positional.
        let c = Cli::parse(args("sort --checkpoint-dir /scratch/ckpt --resume extra")).unwrap();
        assert_eq!(c.positional, vec!["extra"]);
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.stream.checkpoint_dir.as_deref(), Some("/scratch/ckpt"));
        assert!(cfg.stream.resume);
    }

    #[test]
    fn local_sorter_external_flows_into_config() {
        let c = Cli::parse(args("sort --local-sorter external --stream-budget-mb 2.5")).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.sorter, crate::cfg::Sorter::External);
        assert_eq!(cfg.stream.budget_bytes, Some(2_500_000));
        // --local-sorter wins over --backend's implied sorter, like
        // --sorter does.
        let c = Cli::parse(args("sort --backend hybrid --local-sorter external")).unwrap();
        assert_eq!(c.run_config().unwrap().sorter, crate::cfg::Sorter::External);
        // Bad values error.
        assert!(Cli::parse(args("sort --local-sorter nope")).unwrap().run_config().is_err());
        assert!(Cli::parse(args("sort --stream-budget-mb -1")).unwrap().run_config().is_err());
    }

    #[test]
    fn comm_flags_flow_into_config() {
        let c = Cli::parse(args(
            "sort --comm-cap-mb 4 --recv-timeout 30 --watchdog-secs 20 --max-restarts 2 \
             --faults flaky:0:1:0.1,kill:1:3:exchange --fault-seed 9 --hb-check",
        ))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.comm.cap_nvlink_mb, 4.0);
        assert_eq!(cfg.comm.cap_hostmem_mb, 4.0);
        assert_eq!(cfg.comm.recv_timeout_secs, 30.0);
        assert_eq!(cfg.comm.watchdog_secs, 20.0);
        assert_eq!(cfg.comm.max_restarts, 2);
        assert_eq!(cfg.comm.fault_seed, 9);
        assert!(cfg.comm.hb_check);
        assert_eq!(cfg.comm.fault_plan().unwrap().unwrap().rules.len(), 2);
        // Defaults hold with no flags.
        let cfg = Cli::parse(args("sort")).unwrap().run_config().unwrap();
        assert_eq!(cfg.comm, crate::cfg::CommCfg::default());
        // Bad specs and non-positive caps error at parse time.
        assert!(Cli::parse(args("sort --faults melt:0")).unwrap().run_config().is_err());
        assert!(Cli::parse(args("sort --comm-cap-mb 0")).unwrap().run_config().is_err());
    }

    #[test]
    fn obs_flags_flow_into_config() {
        // --trace-summary is boolean: the next token stays positional.
        let c = Cli::parse(args(
            "sort --trace-out target/trace.json --trace-ring-capacity 4096 --trace-summary extra",
        ))
        .unwrap();
        assert_eq!(c.positional, vec!["extra"]);
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.obs.trace_out.as_deref(), Some("target/trace.json"));
        assert!(cfg.obs.trace_summary);
        assert_eq!(cfg.obs.ring_capacity, 4096);
        assert!(cfg.obs.armed());
        // Defaults hold with no flags: tracer disarmed.
        let cfg = Cli::parse(args("sort")).unwrap().run_config().unwrap();
        assert_eq!(cfg.obs, crate::cfg::ObsCfg::default());
        assert!(!cfg.obs.armed());
        // Zero ring capacity errors at parse time.
        let c = Cli::parse(args("sort --trace-ring-capacity 0")).unwrap();
        assert!(c.run_config().is_err());
    }

    #[test]
    fn backend_hybrid_selects_hybrid_sorter() {
        let c = Cli::parse(args("sort --backend hybrid --host-fraction 0.3 --threads 6")).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.backend, Some(crate::cfg::BackendKind::Hybrid));
        assert_eq!(cfg.sorter, Sorter::Hybrid);
        assert_eq!(cfg.hybrid_host_fraction, Some(0.3));
        assert_eq!(cfg.host_threads, 6);
        // An explicit --sorter still wins over the implied one.
        let c = Cli::parse(args("sort --backend hybrid --sorter TR")).unwrap();
        assert_eq!(c.run_config().unwrap().sorter, Sorter::ThrustRadix);
        // Out-of-range fractions are rejected.
        let c = Cli::parse(args("sort --host-fraction 1.5")).unwrap();
        assert!(c.run_config().is_err());
    }
}
