//! LSD radix sort over the `SortKey` bit image — the "Thrust radix" (TR)
//! baseline.
//!
//! 8-bit digits, one counting pass per key byte, ping-pong buffers.
//! Works for every paper dtype including i128 and (via the sign-flip bit
//! image) floats with IEEE total order. Like Thrust, cost scales with the
//! key *width*: i16 takes 2 passes, i128 takes 16 — which is exactly the
//! Fig 2 effect where radix dominates on small types and loses its edge
//! on big ones.
//!
//! `radix_sort_by_digit_bits` exposes the digit width for the ablation
//! bench (8 vs 11 vs 16 bits).

use crate::dtype::SortKey;

/// Sort in place, ascending under the total order.
pub fn radix_sort<K: SortKey>(xs: &mut [K]) {
    radix_sort_by_digit_bits(xs, 8);
}

/// Radix sort with a configurable digit width in {1..16} bits.
pub fn radix_sort_by_digit_bits<K: SortKey>(xs: &mut [K], digit_bits: u32) {
    assert!((1..=16).contains(&digit_bits), "digit width {digit_bits}");
    let n = xs.len();
    if n < 2 {
        return;
    }
    // Small inputs: comparison sort beats counting-pass overheads.
    if n < 64 {
        xs.sort_unstable_by(|a, b| a.cmp_total(b));
        return;
    }

    // §Perf L3: keys up to 8 bytes run the passes on a u64 bit image —
    // the u128 shifts/masks of the generic path cost ~35% throughput on
    // i32 (EXPERIMENTS.md §Perf).
    if K::KEY_BYTES <= 8 {
        radix_passes::<K, u64>(xs, digit_bits, |k| k.to_bits() as u64);
    } else {
        radix_passes::<K, u128>(xs, digit_bits, |k| k.to_bits());
    }
}

/// Unsigned image abstraction for the pass loop.
trait RadixImage: Copy {
    fn digit(self, shift: u32, mask: u64) -> usize;
}

impl RadixImage for u64 {
    #[inline(always)]
    fn digit(self, shift: u32, mask: u64) -> usize {
        ((self >> shift) & mask) as usize
    }
}

impl RadixImage for u128 {
    #[inline(always)]
    fn digit(self, shift: u32, mask: u64) -> usize {
        ((self >> shift) as u64 & mask) as usize
    }
}

fn radix_passes<K: SortKey, U: RadixImage>(
    xs: &mut [K],
    digit_bits: u32,
    image: impl Fn(K) -> U,
) {
    let n = xs.len();
    let key_bits = (K::KEY_BYTES * 8) as u32;
    let passes = key_bits.div_ceil(digit_bits);
    let radix = 1usize << digit_bits;
    let mask = (radix - 1) as u64;

    // Keys stay in place (materialising (image, key) pairs was tried and
    // *lost* ~3x to the extra memory traffic — §Perf L3 iteration log);
    // the image is recomputed per access, which for integers is one xor.
    let mut src: Vec<K> = xs.to_vec();
    let mut dst: Vec<K> = Vec::with_capacity(n);
    #[allow(clippy::uninit_vec)]
    unsafe {
        dst.set_len(n);
    }

    let mut counts = vec![0usize; radix];
    for pass in 0..passes {
        let shift = pass * digit_bits;
        // Skip passes whose digit is constant across the input (common for
        // narrow-range data — a standard radix optimisation).
        counts.iter_mut().for_each(|c| *c = 0);
        for x in &src {
            counts[image(*x).digit(shift, mask)] += 1;
        }
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        // Exclusive prefix -> bucket offsets.
        let mut sum = 0usize;
        for c in counts.iter_mut() {
            let t = *c;
            *c = sum;
            sum += t;
        }
        for &x in src.iter() {
            let slot = &mut counts[image(x).digit(shift, mask)];
            dst[*slot] = x;
            *slot += 1;
        }
        std::mem::swap(&mut src, &mut dst);
    }
    xs.copy_from_slice(&src);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn check<K: KeyGen + PartialEq>(seed: u64, n: usize) {
        for dist in Distribution::ALL {
            let xs: Vec<K> = generate(&mut Prng::new(seed), dist, n);
            let mut got = xs.clone();
            radix_sort(&mut got);
            let mut want = xs.clone();
            want.sort_unstable_by(|a, b| a.cmp_total(b));
            assert!(is_sorted_total(&got), "{dist:?}");
            assert!(got == want, "{dist:?}");
        }
    }

    #[test]
    fn i16_all_dists() {
        check::<i16>(1, 3000);
    }

    #[test]
    fn i32_all_dists() {
        check::<i32>(2, 3000);
    }

    #[test]
    fn i64_all_dists() {
        check::<i64>(3, 2000);
    }

    #[test]
    fn i128_all_dists() {
        check::<i128>(4, 1500);
    }

    #[test]
    fn f32_all_dists() {
        check::<f32>(5, 3000);
    }

    #[test]
    fn f64_all_dists() {
        check::<f64>(6, 2000);
    }

    #[test]
    fn negative_and_special_floats() {
        let mut xs = vec![3.5f32, -0.0, 0.0, f32::INFINITY, -2.5, f32::NEG_INFINITY, 1e-40];
        radix_sort(&mut xs);
        assert_eq!(xs[0], f32::NEG_INFINITY);
        assert_eq!(*xs.last().unwrap(), f32::INFINITY);
        assert!(is_sorted_total(&xs));
    }

    #[test]
    fn digit_widths_agree() {
        let xs: Vec<i64> = generate(&mut Prng::new(7), Distribution::Uniform, 5000);
        let mut a = xs.clone();
        let mut b = xs.clone();
        let mut c = xs;
        radix_sort_by_digit_bits(&mut a, 8);
        radix_sort_by_digit_bits(&mut b, 11);
        radix_sort_by_digit_bits(&mut c, 16);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn tiny_inputs() {
        let mut e: Vec<i32> = vec![];
        radix_sort(&mut e);
        let mut one = vec![5i32];
        radix_sort(&mut one);
        assert_eq!(one, vec![5]);
        let mut two = vec![7i32, -7];
        radix_sort(&mut two);
        assert_eq!(two, vec![-7, 7]);
    }
}
