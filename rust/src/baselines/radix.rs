//! LSD radix sort over the `SortKey` bit image — the "Thrust radix" (TR)
//! baseline.
//!
//! 8-bit digits, one counting pass per key byte, ping-pong buffers.
//! Works for every paper dtype including i128 and (via the sign-flip bit
//! image) floats with IEEE total order. Like Thrust, cost scales with the
//! key *width*: i16 takes 2 passes, i128 takes 16 — which is exactly the
//! Fig 2 effect where radix dominates on small types and loses its edge
//! on big ones.
//!
//! `radix_sort_by_digit_bits` exposes the digit width for the ablation
//! bench (8 vs 11 vs 16 bits). [`radix_sort_threaded`] is the
//! multi-threaded LSD variant (per-thread digit histograms over static
//! chunks, an exclusive scan over the thread × digit count matrix, and a
//! parallel stable scatter with per-thread bucket cursors — DESIGN.md
//! §11); [`radix_sort_auto`] picks between the two by input size and is
//! what `mpisort::LocalSorter::ThrustRadix` runs, so calibration and the
//! cost model see the faster engine.

use crate::backend::threaded::{
    default_threads, parallel_chunks_with_scratch, parallel_for_each_chunk, split_ranges,
};
use crate::dtype::SortKey;

/// Minimum input length before [`radix_sort_auto`] fans out to the
/// threaded engine: below this, per-pass thread spawns and the cursor
/// matrix scan cost more than they save.
pub const RADIX_PAR_MIN: usize = 1 << 15;

/// Sort in place, ascending under the total order (single-threaded).
pub fn radix_sort<K: SortKey>(xs: &mut [K]) {
    radix_sort_by_digit_bits(xs, 8);
}

/// The TR engine as dispatched by `LocalSorter`: threaded LSD radix for
/// inputs at or above [`RADIX_PAR_MIN`] (over the default host thread
/// count), the sequential passes below it.
pub fn radix_sort_auto<K: SortKey>(xs: &mut [K]) {
    radix_sort_threaded(xs, default_threads());
}

/// [`radix_sort_auto`] with explicit worker-count and parallel-gate
/// knobs (`Launch::max_tasks` / `prefer_parallel_threshold` reach the
/// TR engine through this).
pub fn radix_sort_auto_with<K: SortKey>(xs: &mut [K], threads: usize, par_min: usize) {
    radix_sort_threaded_with(xs, threads, par_min);
}

/// Multi-threaded LSD radix sort (8-bit digits) over up to `threads`
/// workers. Per pass: (1) each worker histograms its static chunk of the
/// input; (2) one exclusive scan over the (digit-major, thread-minor)
/// count matrix turns the histograms into per-worker bucket cursors —
/// digit-major order keeps the scatter stable, since within one digit an
/// earlier chunk's elements land before a later chunk's; (3) workers
/// scatter their chunk in input order through their private cursors, so
/// no two writes alias. Falls back to the sequential engine below
/// [`RADIX_PAR_MIN`] or at one thread.
pub fn radix_sort_threaded<K: SortKey>(xs: &mut [K], threads: usize) {
    radix_sort_threaded_with(xs, threads, RADIX_PAR_MIN);
}

/// [`radix_sort_threaded`] with an explicit sequential-fallback gate.
pub fn radix_sort_threaded_with<K: SortKey>(xs: &mut [K], threads: usize, par_min: usize) {
    let t = threads.max(1).min(xs.len().max(1));
    if t == 1 || xs.len() < par_min.max(2) {
        radix_sort(xs);
        return;
    }
    // §Perf L3: same u64-image fast path as the sequential engine.
    if K::KEY_BYTES <= 8 {
        radix_passes_parallel::<K, u64>(xs, t, |k| k.to_bits() as u64);
    } else {
        radix_passes_parallel::<K, u128>(xs, t, |k| k.to_bits());
    }
}

/// Radix sort with a configurable digit width in {1..16} bits.
pub fn radix_sort_by_digit_bits<K: SortKey>(xs: &mut [K], digit_bits: u32) {
    assert!((1..=16).contains(&digit_bits), "digit width {digit_bits}");
    let n = xs.len();
    if n < 2 {
        return;
    }
    // Small inputs: comparison sort beats counting-pass overheads.
    if n < 64 {
        xs.sort_unstable_by(|a, b| a.cmp_total(b));
        return;
    }

    // §Perf L3: keys up to 8 bytes run the passes on a u64 bit image —
    // the u128 shifts/masks of the generic path cost ~35% throughput on
    // i32 (EXPERIMENTS.md §Perf).
    if K::KEY_BYTES <= 8 {
        radix_passes::<K, u64>(xs, digit_bits, |k| k.to_bits() as u64);
    } else {
        radix_passes::<K, u128>(xs, digit_bits, |k| k.to_bits());
    }
}

/// Unsigned image abstraction for the pass loop.
trait RadixImage: Copy {
    fn digit(self, shift: u32, mask: u64) -> usize;
}

impl RadixImage for u64 {
    #[inline(always)]
    fn digit(self, shift: u32, mask: u64) -> usize {
        ((self >> shift) & mask) as usize
    }
}

impl RadixImage for u128 {
    #[inline(always)]
    fn digit(self, shift: u32, mask: u64) -> usize {
        ((self >> shift) as u64 & mask) as usize
    }
}

fn radix_passes<K: SortKey, U: RadixImage>(
    xs: &mut [K],
    digit_bits: u32,
    image: impl Fn(K) -> U,
) {
    let n = xs.len();
    let key_bits = (K::KEY_BYTES * 8) as u32;
    let passes = key_bits.div_ceil(digit_bits);
    let radix = 1usize << digit_bits;
    let mask = (radix - 1) as u64;

    // Keys stay in place (materialising (image, key) pairs was tried and
    // *lost* ~3x to the extra memory traffic — §Perf L3 iteration log);
    // the image is recomputed per access, which for integers is one xor.
    let mut src: Vec<K> = xs.to_vec();
    let mut dst: Vec<K> = Vec::new();
    crate::dtype::resize_for_overwrite(&mut dst, n);

    let mut counts = vec![0usize; radix];
    for pass in 0..passes {
        let shift = pass * digit_bits;
        // Skip passes whose digit is constant across the input (common for
        // narrow-range data — a standard radix optimisation).
        counts.iter_mut().for_each(|c| *c = 0);
        for x in &src {
            counts[image(*x).digit(shift, mask)] += 1;
        }
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        // Exclusive prefix -> bucket offsets.
        let mut sum = 0usize;
        for c in counts.iter_mut() {
            let t = *c;
            *c = sum;
            sum += t;
        }
        for &x in src.iter() {
            let slot = &mut counts[image(x).digit(shift, mask)];
            dst[*slot] = x;
            *slot += 1;
        }
        std::mem::swap(&mut src, &mut dst);
    }
    xs.copy_from_slice(&src);
}

/// Shared-destination pointer for the parallel scatter. SAFETY contract:
/// every worker writes only slots inside its own (thread, digit) bucket
/// ranges, which partition `0..n` by construction of the exclusive scan.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: the wrapped pointer is only dereferenced inside the scatter's
// scoped threads, each writing its own disjoint (thread, digit) bucket
// ranges (the contract above) — no two workers alias a slot.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: same disjoint-bucket contract; shared references never read
// through the pointer.
unsafe impl<T> Sync for SendPtr<T> {}

fn radix_passes_parallel<K: SortKey, U: RadixImage>(
    xs: &mut [K],
    threads: usize,
    image: impl Fn(K) -> U + Sync,
) {
    const DIGIT_BITS: u32 = 8;
    const RADIX: usize = 1 << DIGIT_BITS;
    const MASK: u64 = (RADIX - 1) as u64;
    let n = xs.len();
    let key_bits = (K::KEY_BYTES * 8) as u32;
    let passes = key_bits.div_ceil(DIGIT_BITS);

    let mut src: Vec<K> = xs.to_vec();
    // Every pass's scatter overwrites every dst slot (scan sums to n).
    let mut dst: Vec<K> = Vec::new();
    crate::dtype::resize_for_overwrite(&mut dst, n);
    // Static chunking shared by the histogram and scatter phases
    // (identical to `parallel_for_each_chunk`'s internal split).
    let ranges = split_ranges(n, threads);

    for pass in 0..passes {
        let shift = pass * DIGIT_BITS;
        // Phase 1: per-worker digit histograms over static chunks.
        let histos: Vec<Vec<usize>> = {
            let src_ref = &src;
            let image_ref = &image;
            parallel_for_each_chunk(n, threads, move |r| {
                let mut h = vec![0usize; RADIX];
                for x in &src_ref[r] {
                    h[image_ref(*x).digit(shift, MASK)] += 1;
                }
                h
            })
        };
        debug_assert_eq!(histos.len(), ranges.len());
        // Skip passes whose digit is constant across the input (the same
        // narrow-range optimisation as the sequential engine).
        if (0..RADIX).any(|d| histos.iter().map(|h| h[d]).sum::<usize>() == n) {
            continue;
        }
        // Phase 2: exclusive scan over the (digit-major, thread-minor)
        // count matrix -> per-worker bucket cursors.
        let mut cursors: Vec<Vec<usize>> = vec![vec![0usize; RADIX]; histos.len()];
        let mut sum = 0usize;
        for d in 0..RADIX {
            for (w, h) in histos.iter().enumerate() {
                cursors[w][d] = sum;
                sum += h[d];
            }
        }
        debug_assert_eq!(sum, n);
        // Phase 3: parallel stable scatter through private cursors.
        let dst_ptr = SendPtr(dst.as_mut_ptr());
        std::thread::scope(|s| {
            let src_ref = &src;
            let image_ref = &image;
            for (r, mut cur) in ranges.iter().cloned().zip(cursors.into_iter()) {
                s.spawn(move || {
                    // Rebind the whole wrapper so edition-2021 disjoint
                    // capture doesn't grab the bare (non-Send) `*mut K`
                    // field instead of the Send/Sync `SendPtr`.
                    let out = dst_ptr;
                    for &x in &src_ref[r] {
                        let d = image_ref(x).digit(shift, MASK);
                        // SAFETY: cur[d] walks this worker's disjoint
                        // bucket range (see SendPtr contract).
                        unsafe {
                            *out.0.add(cur[d]) = x;
                        }
                        cur[d] += 1;
                    }
                });
            }
        });
        std::mem::swap(&mut src, &mut dst);
    }
    // Parallel copy-back: with only 2–16 full-array sweeps per sort, a
    // sequential final copy would run a whole sweep on one core.
    parallel_chunks_with_scratch(xs, &mut src, threads, |_, out, from| {
        out.copy_from_slice(from);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn check<K: KeyGen + PartialEq>(seed: u64, n: usize) {
        for dist in Distribution::ALL {
            let xs: Vec<K> = generate(&mut Prng::new(seed), dist, n);
            let mut got = xs.clone();
            radix_sort(&mut got);
            let mut want = xs.clone();
            want.sort_unstable_by(|a, b| a.cmp_total(b));
            assert!(is_sorted_total(&got), "{dist:?}");
            assert!(got == want, "{dist:?}");
        }
    }

    #[test]
    fn i16_all_dists() {
        check::<i16>(1, 3000);
    }

    #[test]
    fn i32_all_dists() {
        check::<i32>(2, 3000);
    }

    #[test]
    fn i64_all_dists() {
        check::<i64>(3, 2000);
    }

    #[test]
    fn i128_all_dists() {
        check::<i128>(4, 1500);
    }

    #[test]
    fn f32_all_dists() {
        check::<f32>(5, 3000);
    }

    #[test]
    fn f64_all_dists() {
        check::<f64>(6, 2000);
    }

    #[test]
    fn negative_and_special_floats() {
        let mut xs = vec![3.5f32, -0.0, 0.0, f32::INFINITY, -2.5, f32::NEG_INFINITY, 1e-40];
        radix_sort(&mut xs);
        assert_eq!(xs[0], f32::NEG_INFINITY);
        assert_eq!(*xs.last().unwrap(), f32::INFINITY);
        assert!(is_sorted_total(&xs));
    }

    #[test]
    fn digit_widths_agree() {
        let xs: Vec<i64> = generate(&mut Prng::new(7), Distribution::Uniform, 5000);
        let mut a = xs.clone();
        let mut b = xs.clone();
        let mut c = xs;
        radix_sort_by_digit_bits(&mut a, 8);
        radix_sort_by_digit_bits(&mut b, 11);
        radix_sort_by_digit_bits(&mut c, 16);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn threaded_matches_sequential_above_threshold() {
        // Above RADIX_PAR_MIN the parallel histogram/scan/scatter engine
        // engages; outputs must be byte-identical to the sequential one.
        let n = RADIX_PAR_MIN + 1777;
        for threads in [1usize, 2, 3, 7] {
            let xs: Vec<i32> = generate(&mut Prng::new(20), Distribution::Uniform, n);
            let mut par = xs.clone();
            let mut seq = xs;
            radix_sort_threaded(&mut par, threads);
            radix_sort(&mut seq);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn threaded_f64_specials_and_dups() {
        let n = RADIX_PAR_MIN + 512;
        let mut xs: Vec<f64> = generate(&mut Prng::new(21), Distribution::DupHeavy, n);
        xs[7] = f64::NAN;
        xs[1000] = -0.0;
        xs[2000] = 0.0;
        xs[3000] = f64::NEG_INFINITY;
        let mut want = xs.clone();
        want.sort_unstable_by(|a, b| a.cmp_total(b));
        radix_sort_threaded(&mut xs, 4);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&xs), bits(&want));
    }

    #[test]
    fn auto_path_small_inputs_fall_back() {
        // Below RADIX_PAR_MIN the auto engine is exactly the sequential
        // one (including empty/tiny inputs).
        for n in [0usize, 1, 2, 63, 64, 1000] {
            let xs: Vec<i64> = generate(&mut Prng::new(22), Distribution::Uniform, n);
            let mut a = xs.clone();
            let mut b = xs;
            radix_sort_auto(&mut a);
            radix_sort(&mut b);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn tiny_inputs() {
        let mut e: Vec<i32> = vec![];
        radix_sort(&mut e);
        let mut one = vec![5i32];
        radix_sort(&mut one);
        assert_eq!(one, vec![5]);
        let mut two = vec![7i32, -7];
        radix_sort(&mut two);
        assert_eq!(two, vec![-7, 7]);
    }
}
