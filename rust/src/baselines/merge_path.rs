//! Merge-path partitioned parallel merges — the parallel host recombine
//! engine (DESIGN.md §11).
//!
//! Every host-side recombine in this repo used to funnel through one
//! sequential k-way merge, capping `threaded_sort`, hybrid `co_sort` and
//! SIHSort's final phase at a single core's memory bandwidth. This module
//! splits a merge's *output* into `p` equal contiguous segments and lets
//! each worker produce its segment independently:
//!
//! * 2-way merges use the classic **merge-path / diagonal co-rank**
//!   binary search ([`co_rank`]): output position `m` corresponds to the
//!   unique `(i, j)` with `i + j = m` on the merge matrix's diagonal, so
//!   each boundary costs `O(log min(|a|, |b|))` comparisons.
//! * k-way merges cut by **value rank** ([`kway_cuts`]): a binary search
//!   over the shared `to_bits` image space finds the key at global rank
//!   `m`, per-run `partition_point`s place the cut inside every run, and
//!   ties distribute greedily in run order. Because `to_bits` is
//!   injective, equal images are equal *values*, so any tie split yields
//!   the byte-identical output sequence.
//!
//! Each segment is then merged sequentially (branchless 2-way /
//! loser tree from `kmerge`) straight into its slice of the output — no
//! locks, no atomics, no inter-worker traffic after partitioning.

use crate::backend::threaded::split_ranges;
use crate::dtype::SortKey;

use super::kmerge::{kmerge_into_slice, merge2_into_slice};

/// Minimum total elements before the partitioned parallel merge engages.
/// Below this, thread-spawn latency (~10s of µs per worker) exceeds the
/// single-core merge time, so `kmerge_into` and the explicit `*_parallel`
/// entry points all fall back to the sequential engines.
pub const PAR_MERGE_MIN: usize = 1 << 14;

/// Diagonal co-rank: for output position `diag` of the stable 2-way merge
/// of sorted runs `a` and `b` (ties take from `a` first), return the
/// unique `(i, j)` with `i + j = diag` such that the first `diag` merged
/// elements are exactly `merge(a[..i], b[..j])`.
pub fn co_rank<K: SortKey>(diag: usize, a: &[K], b: &[K]) -> (usize, usize) {
    debug_assert!(diag <= a.len() + b.len());
    let mut lo = diag.saturating_sub(b.len());
    let mut hi = diag.min(a.len());
    // Invariant: the answer i* lies in [lo, hi]. For any probe i in
    // [lo, hi), both a[i] and b[diag - i - 1] exist.
    while lo < hi {
        let i = lo + (hi - lo) / 2;
        let j = diag - i;
        if b[j - 1].to_bits() >= a[i].to_bits() {
            // b[j-1] may not precede a[i] (ties take a first): the cut
            // needs more elements from `a`.
            lo = i + 1;
        } else {
            hi = i;
        }
    }
    (lo, diag - lo)
}

/// Per-run cut positions for global output rank `m` of the k-way merge of
/// `runs`: returns `cuts` with `sum(cuts) == m` such that the merged
/// prefix of length `m` is exactly the multiset `∪ runs[r][..cuts[r]]`.
pub fn kway_cuts<K: SortKey>(runs: &[&[K]], m: usize) -> Vec<usize> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    debug_assert!(m <= total);
    if m == 0 {
        return vec![0; runs.len()];
    }
    if m == total {
        return runs.iter().map(|r| r.len()).collect();
    }
    // Binary search the bit-image space for the key at rank m: the
    // smallest image t with |{x : to_bits(x) <= t}| >= m. ~128 probes of
    // k `partition_point`s — negligible against the merge itself.
    let mut lo: u128 = 0;
    let mut hi: u128 = u128::MAX;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let le: usize = runs.iter().map(|r| r.partition_point(|x| x.to_bits() <= mid)).sum();
        if le >= m {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let t = lo;
    // Take every element strictly below t, then distribute the elements
    // equal to t greedily in run order (equal image ⇒ equal value, so the
    // output sequence is independent of which run supplies them).
    let mut cuts: Vec<usize> =
        runs.iter().map(|r| r.partition_point(|x| x.to_bits() < t)).collect();
    let mut need = m - cuts.iter().sum::<usize>();
    for (cut, run) in cuts.iter_mut().zip(runs.iter()) {
        if need == 0 {
            break;
        }
        let equal = run.partition_point(|x| x.to_bits() <= t) - *cut;
        let take = equal.min(need);
        *cut += take;
        need -= take;
    }
    debug_assert_eq!(need, 0, "rank {m} not reachable at image threshold");
    cuts
}

/// Merge two ascending runs into `out` (`out.len() == a.len() + b.len()`,
/// every slot overwritten) using up to `threads` workers, each producing
/// one contiguous output segment located by [`co_rank`]. Falls back to
/// the sequential branchless merge below [`PAR_MERGE_MIN`].
pub fn merge2_parallel_into<K: SortKey>(a: &[K], b: &[K], out: &mut [K], threads: usize) {
    merge2_parallel_into_with(a, b, out, threads, PAR_MERGE_MIN);
}

/// [`merge2_parallel_into`] with an explicit sequential-fallback gate
/// (`Launch::prefer_parallel_threshold` reaches the engine through this).
pub fn merge2_parallel_into_with<K: SortKey>(
    a: &[K],
    b: &[K],
    out: &mut [K],
    threads: usize,
    par_min: usize,
) {
    assert_eq!(a.len() + b.len(), out.len(), "output length mismatch");
    let total = out.len();
    let t = threads.max(1);
    if t == 1 || total < par_min.max(2) {
        merge2_into_slice(a, b, out);
        return;
    }
    // Segment boundaries on the output, co-ranked back onto (a, b).
    let ranges = split_ranges(total, t);
    let mut cuts: Vec<(usize, usize)> =
        ranges.iter().map(|r| co_rank(r.start, a, b)).collect();
    cuts.push((a.len(), b.len()));
    crate::backend::threaded::parallel_chunks(out, t, |s, seg| {
        let (a0, b0) = cuts[s];
        let (a1, b1) = cuts[s + 1];
        merge2_into_slice(&a[a0..a1], &b[b0..b1], seg);
    });
}

/// Merge two ascending runs into a fresh vector with up to `threads`
/// workers (see [`merge2_parallel_into`]).
pub fn merge2_parallel<K: SortKey>(a: &[K], b: &[K], threads: usize) -> Vec<K> {
    let mut out = alloc_out::<K>(a.len() + b.len());
    merge2_parallel_into(a, b, &mut out, threads);
    out
}

/// K-way merge of ascending `runs` into `out` (`out.len()` = total run
/// length, every slot overwritten) using up to `threads` workers: the
/// output is cut into equal segments by [`kway_cuts`] and each worker
/// runs the sequential loser tree over its sub-runs. Falls back to the
/// sequential engine below [`PAR_MERGE_MIN`].
pub fn kmerge_parallel_into_slice<K: SortKey>(runs: &[&[K]], out: &mut [K], threads: usize) {
    kmerge_parallel_into_slice_with(runs, out, threads, PAR_MERGE_MIN);
}

/// [`kmerge_parallel_into_slice`] with an explicit sequential-fallback
/// gate (`Launch::prefer_parallel_threshold` reaches the engine here).
pub fn kmerge_parallel_into_slice_with<K: SortKey>(
    runs: &[&[K]],
    out: &mut [K],
    threads: usize,
    par_min: usize,
) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert_eq!(total, out.len(), "output length mismatch");
    let t = threads.max(1);
    if t == 1 || total < par_min.max(2) {
        kmerge_into_slice(runs, out);
        return;
    }
    if runs.iter().filter(|r| !r.is_empty()).count() == 2 {
        // Prefer diagonal co-ranking for the 2-run case: boundary cost is
        // O(log n) instead of the 128-probe image search.
        let live: Vec<&[K]> = runs.iter().copied().filter(|r| !r.is_empty()).collect();
        merge2_parallel_into_with(live[0], live[1], out, t, par_min);
        return;
    }
    let ranges = split_ranges(total, t);
    let mut cuts: Vec<Vec<usize>> = ranges.iter().map(|r| kway_cuts(runs, r.start)).collect();
    cuts.push(runs.iter().map(|r| r.len()).collect());
    crate::backend::threaded::parallel_chunks(out, t, |s, seg| {
        let subs: Vec<&[K]> = runs
            .iter()
            .enumerate()
            .map(|(r, run)| &run[cuts[s][r]..cuts[s + 1][r]])
            .collect();
        kmerge_into_slice(&subs, seg);
    });
}

/// K-way merge into a fresh vector with up to `threads` workers (see
/// [`kmerge_parallel_into_slice`]).
pub fn kmerge_parallel<K: SortKey>(runs: &[&[K]], threads: usize) -> Vec<K> {
    kmerge_parallel_with(runs, threads, PAR_MERGE_MIN)
}

/// [`kmerge_parallel`] with an explicit sequential-fallback gate.
pub fn kmerge_parallel_with<K: SortKey>(runs: &[&[K]], threads: usize, par_min: usize) -> Vec<K> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let mut out = alloc_out::<K>(total);
    kmerge_parallel_into_slice_with(runs, &mut out, threads, par_min);
    out
}

/// Merge the consecutive sorted runs of `xs` *in place*: run `r` spans
/// `bounds[r-1]..bounds[r]` (with implicit `0` and `xs.len()`
/// endpoints; `bounds` must be ascending). Partitioned parallel merge
/// into a scratch buffer followed by a parallel copy-back, so no sweep
/// of the recombine runs at single-core bandwidth. This is the one
/// scratch-dance shared by `threaded_sort`'s and `co_sort`'s recombine.
pub fn merge_runs_in_place<K: SortKey>(xs: &mut [K], bounds: &[usize], threads: usize) {
    let mut scratch: Vec<K> = Vec::new();
    merge_runs_in_place_with(xs, bounds, threads, PAR_MERGE_MIN, &mut scratch);
}

/// [`merge_runs_in_place`] with an explicit sequential-fallback gate and
/// a caller-owned scratch buffer (resized to `xs.len()`, capacity kept
/// across calls — the `Launch::reuse_scratch` pool hands buffers in
/// through here).
pub fn merge_runs_in_place_with<K: SortKey>(
    xs: &mut [K],
    bounds: &[usize],
    threads: usize,
    par_min: usize,
    scratch: &mut Vec<K>,
) {
    debug_assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds must be ascending");
    let t = threads.max(1);
    crate::dtype::resize_for_overwrite(scratch, xs.len());
    {
        let mut cuts: Vec<usize> = Vec::with_capacity(bounds.len() + 2);
        cuts.push(0);
        cuts.extend(bounds.iter().copied().filter(|&b| b > 0 && b < xs.len()));
        cuts.push(xs.len());
        let refs: Vec<&[K]> = cuts.windows(2).map(|w| &xs[w[0]..w[1]]).collect();
        kmerge_parallel_into_slice_with(&refs, scratch, t, par_min);
    }
    crate::backend::threaded::parallel_chunks_with_scratch(xs, scratch, t, |_, dst, src| {
        dst.copy_from_slice(src);
    });
}

/// Uninitialised output vector of `len` keys; every caller overwrites
/// every slot before the vector escapes (`dtype::resize_for_overwrite`).
fn alloc_out<K: SortKey>(len: usize) -> Vec<K> {
    let mut out: Vec<K> = Vec::new();
    crate::dtype::resize_for_overwrite(&mut out, len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn sorted_pair<K: KeyGen>(seed: u64, na: usize, nb: usize) -> (Vec<K>, Vec<K>) {
        let mut a: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, na);
        let mut b: Vec<K> = generate(&mut Prng::new(seed + 1), Distribution::DupHeavy, nb);
        a.sort_unstable_by(|x, y| x.cmp_total(y));
        b.sort_unstable_by(|x, y| x.cmp_total(y));
        (a, b)
    }

    #[test]
    fn co_rank_prefixes_are_exact() {
        let (a, b) = sorted_pair::<i32>(1, 300, 200);
        let mut want = [a.clone(), b.clone()].concat();
        want.sort_unstable_by(|x, y| x.cmp_total(y));
        for diag in 0..=a.len() + b.len() {
            let (i, j) = co_rank(diag, &a, &b);
            assert_eq!(i + j, diag);
            let mut prefix = [a[..i].to_vec(), b[..j].to_vec()].concat();
            prefix.sort_unstable_by(|x, y| x.cmp_total(y));
            assert_eq!(prefix, want[..diag].to_vec(), "diag {diag}");
        }
    }

    #[test]
    fn co_rank_degenerate_runs() {
        let a = vec![1i32, 2, 3];
        let empty: Vec<i32> = vec![];
        assert_eq!(co_rank(2, &a, &empty), (2, 0));
        assert_eq!(co_rank(2, &empty, &a), (0, 2));
        assert_eq!(co_rank(0, &a, &a), (0, 0));
        // All-duplicates: any valid (i, j) yields the same output; the
        // search must still terminate with i + j = diag.
        let d = vec![5i32; 40];
        let (i, j) = co_rank(33, &d, &d);
        assert_eq!(i + j, 33);
    }

    #[test]
    fn kway_cuts_rank_exact() {
        let (runs, _) = split_runs::<i64>(2, 4000, 5);
        let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut all: Vec<i64> = runs.iter().flatten().copied().collect();
        all.sort_unstable_by(|x, y| x.cmp_total(y));
        for m in [0usize, 1, 17, 1999, 2000, 3999, 4000] {
            let cuts = kway_cuts(&refs, m);
            assert_eq!(cuts.iter().sum::<usize>(), m);
            let mut prefix: Vec<i64> = refs
                .iter()
                .zip(cuts.iter())
                .flat_map(|(r, &c)| r[..c].iter().copied())
                .collect();
            prefix.sort_unstable_by(|x, y| x.cmp_total(y));
            assert_eq!(prefix, all[..m].to_vec(), "m={m}");
        }
    }

    fn split_runs<K: KeyGen>(seed: u64, n: usize, k: usize) -> (Vec<Vec<K>>, Vec<K>) {
        let xs: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        let mut want = xs.clone();
        want.sort_unstable_by(|a, b| a.cmp_total(b));
        let mut rng = Prng::new(seed + 99);
        let mut runs: Vec<Vec<K>> = (0..k).map(|_| Vec::new()).collect();
        for x in xs {
            let r = rng.below(k as u64) as usize;
            runs[r].push(x);
        }
        for r in &mut runs {
            r.sort_unstable_by(|a, b| a.cmp_total(b));
        }
        (runs, want)
    }

    #[test]
    fn merge2_parallel_matches_sequential() {
        // Big enough to clear PAR_MERGE_MIN so workers actually fan out.
        let (a, b) = sorted_pair::<i64>(3, PAR_MERGE_MIN, PAR_MERGE_MIN / 2);
        let want = {
            let mut w = [a.clone(), b.clone()].concat();
            w.sort_unstable_by(|x, y| x.cmp_total(y));
            w
        };
        for threads in [1usize, 2, 3, 7] {
            let got = merge2_parallel(&a, &b, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn kmerge_parallel_matches_sequential() {
        for k in [1usize, 3, 5, 16] {
            let (runs, want) = split_runs::<i32>(4 + k as u64, PAR_MERGE_MIN * 2, k);
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            for threads in [1usize, 2, 3, 7] {
                let got = kmerge_parallel(&refs, threads);
                assert_eq!(got, want, "k={k} threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_handles_empty_and_tiny() {
        let empty: Vec<&[i32]> = vec![];
        assert!(kmerge_parallel(&empty, 4).is_empty());
        let a = vec![3i32, 9];
        let b: Vec<i32> = vec![];
        let c = vec![1i32];
        assert_eq!(kmerge_parallel(&[&a, &b, &c], 7), vec![1, 3, 9]);
        assert_eq!(merge2_parallel(&a, &c, 7), vec![1, 3, 9]);
    }

    #[test]
    fn parallel_float_specials() {
        let n = PAR_MERGE_MIN;
        let mut a: Vec<f64> = generate(&mut Prng::new(5), Distribution::Uniform, n);
        let mut b: Vec<f64> = generate(&mut Prng::new(6), Distribution::Uniform, n);
        a[0] = f64::NAN;
        a[1] = -0.0;
        b[0] = f64::INFINITY;
        b[1] = f64::NEG_INFINITY;
        a.sort_unstable_by(|x, y| x.cmp_total(y));
        b.sort_unstable_by(|x, y| x.cmp_total(y));
        let got = merge2_parallel(&a, &b, 4);
        assert!(is_sorted_total(&got));
        let mut want = [a, b].concat();
        want.sort_unstable_by(|x, y| x.cmp_total(y));
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn merge_runs_in_place_matches_sort() {
        let n = PAR_MERGE_MIN + 999;
        for k in [2usize, 3, 7] {
            let mut xs: Vec<i32> = generate(&mut Prng::new(40 + k as u64), Distribution::Uniform, n);
            let mut want = xs.clone();
            want.sort_unstable_by(|a, b| a.cmp_total(b));
            // Sort k consecutive chunks, then merge them in place.
            let bounds: Vec<usize> = (1..k).map(|i| i * n / k).collect();
            let mut cuts = vec![0];
            cuts.extend(bounds.iter().copied());
            cuts.push(n);
            for w in cuts.windows(2) {
                xs[w[0]..w[1]].sort_unstable_by(|a, b| a.cmp_total(b));
            }
            merge_runs_in_place(&mut xs, &bounds, 3);
            assert_eq!(xs, want, "k={k}");
        }
        // Degenerate bounds (0, len, empty list) are tolerated.
        let mut xs = vec![3i32, 1, 2];
        xs.sort_unstable();
        merge_runs_in_place(&mut xs, &[0, 3], 4);
        assert_eq!(xs, vec![1, 2, 3]);
        let mut e: Vec<i32> = vec![];
        merge_runs_in_place(&mut e, &[], 4);
        assert!(e.is_empty());
    }

    #[test]
    fn kway_cuts_handle_image_max_keys() {
        // i64::MAX sits at the very top of the image space; the rank
        // search must not overflow or mis-place it.
        let a = vec![0i64, i64::MAX, i64::MAX];
        let b = vec![i64::MIN, i64::MAX];
        let c = vec![1i64];
        let refs: Vec<&[i64]> = vec![&a, &b, &c];
        for m in 0..=6 {
            let cuts = kway_cuts(&refs, m);
            assert_eq!(cuts.iter().sum::<usize>(), m, "m={m}");
        }
        assert_eq!(
            kmerge_parallel(&refs, 3),
            vec![i64::MIN, 0, 1, i64::MAX, i64::MAX, i64::MAX]
        );
    }
}
