//! Vendor-primitive baselines: the role NVIDIA Thrust plays in the paper.
//!
//! The paper exposes Thrust's merge sort ("TM") and radix sort ("TR") via
//! C FFI and benchmarks them against the AcceleratedKernels merge sort.
//! Here the same slot is filled by hand-optimised native Rust sorts:
//! an LSD radix sort (special-cased per key width, exactly the property
//! that makes Thrust win on small integer types in Fig 2) and a bottom-up
//! merge sort. `kmerge` is the shared k-way merge used by chunked device
//! sorting and SIHSort's final phase; `merge_path` is its partitioned
//! parallel engine (diagonal co-rank / value-rank output splitting,
//! DESIGN.md §11), and `radix::radix_sort_threaded` the multi-threaded
//! LSD variant — together the parallel host sort engine that keeps the
//! recombine phases off the single-core memory-bandwidth ceiling.

pub mod kmerge;
pub mod merge;
pub mod merge_path;
pub mod radix;

pub use kmerge::{kmerge, KmergePull, RunCursor, SliceCursor};
pub use merge::merge_sort;
pub use merge_path::{kmerge_parallel, merge2_parallel};
pub use radix::{radix_sort, radix_sort_auto, radix_sort_auto_with, radix_sort_threaded};
