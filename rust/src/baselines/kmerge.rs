//! K-way merge of sorted runs (loser-tree tournament).
//!
//! Used by (a) the device backend when a shard exceeds the largest sort
//! artifact size class — sorted chunks are merged on the host — and
//! (b) SIHSort's final phase, merging the sorted runs received from every
//! peer rank (cheaper than the paper's full second local sort; both are
//! implemented and ablated, see `mpisort`).

use crate::dtype::SortKey;

/// Merge ascending-sorted `runs` into one ascending vector.
pub fn kmerge<K: SortKey>(runs: &[&[K]]) -> Vec<K> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let mut out = Vec::with_capacity(total);
    kmerge_into(runs, &mut out);
    out
}

/// Merge into a caller-provided buffer (cleared first). Allocation-free on
/// the element path when `out` has capacity.
pub fn kmerge_into<K: SortKey>(runs: &[&[K]], out: &mut Vec<K>) {
    out.clear();
    let live: Vec<&[K]> = runs.iter().copied().filter(|r| !r.is_empty()).collect();
    match live.len() {
        0 => return,
        1 => {
            out.extend_from_slice(live[0]);
            return;
        }
        2 => {
            merge2_into(live[0], live[1], out);
            return;
        }
        _ => {}
    }

    // Loser tree over k runs: internal nodes hold the *loser* of each
    // match; the winner bubbles to the root. Pop/replace is O(log k) with
    // no branching on heap shape.
    let k = live.len();
    let mut idx = vec![0usize; k]; // next unconsumed element per run
    let tree_size = k.next_power_of_two();
    // leaders[i]: the run currently winning at leaf slot i (usize::MAX = exhausted).
    const EXHAUSTED: u128 = u128::MAX;
    let key_of = |run: usize, idx: &[usize]| -> u128 {
        if run >= k || idx[run] >= live[run].len() {
            EXHAUSTED
        } else {
            live[run][idx[run]].to_bits()
        }
    };

    // Internal nodes: losers[1..tree_size]; winner propagated from leaves.
    let mut losers = vec![usize::MAX; tree_size]; // run ids
    // Build: play leaves pairwise up the tree.
    let mut winner_at = vec![usize::MAX; 2 * tree_size];
    for leaf in 0..tree_size {
        winner_at[tree_size + leaf] = if leaf < k { leaf } else { usize::MAX };
    }
    for node in (1..tree_size).rev() {
        let a = winner_at[2 * node];
        let b = winner_at[2 * node + 1];
        let (win, lose) = if key_of_or(a, &idx, &live, k) <= key_of_or(b, &idx, &live, k) {
            (a, b)
        } else {
            (b, a)
        };
        winner_at[node] = win;
        losers[node] = lose;
    }
    let mut winner = winner_at[1];

    while winner != usize::MAX && key_of(winner, &idx) != EXHAUSTED {
        out.push(live[winner][idx[winner]]);
        idx[winner] += 1;
        // Replay from the winner's leaf up to the root.
        let mut node = (tree_size + winner) / 2;
        let mut cur = winner;
        while node >= 1 {
            let opp = losers[node];
            if key_of_or(opp, &idx, &live, k) < key_of_or(cur, &idx, &live, k) {
                losers[node] = cur;
                cur = opp;
            }
            if node == 1 {
                break;
            }
            node /= 2;
        }
        winner = cur;
    }
}

#[inline]
fn key_of_or<K: SortKey>(run: usize, idx: &[usize], live: &[&[K]], k: usize) -> u128 {
    if run == usize::MAX || run >= k || idx[run] >= live[run].len() {
        u128::MAX
    } else {
        live[run][idx[run]].to_bits()
    }
}

#[inline]
fn merge2_into<K: SortKey>(a: &[K], b: &[K], out: &mut Vec<K>) {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i].to_bits() <= b[j].to_bits() {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn split_sorted<K: KeyGen>(seed: u64, n: usize, k: usize) -> (Vec<Vec<K>>, Vec<K>) {
        let xs: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        let mut want = xs.clone();
        want.sort_unstable_by(|a, b| a.cmp_total(b));
        let mut rng = Prng::new(seed + 1);
        let mut runs: Vec<Vec<K>> = (0..k).map(|_| Vec::new()).collect();
        for x in xs {
            let r = rng.below(k as u64) as usize;
            runs[r].push(x);
        }
        for r in &mut runs {
            r.sort_unstable_by(|a, b| a.cmp_total(b));
        }
        (runs, want)
    }

    #[test]
    fn merges_various_k() {
        for k in [1usize, 2, 3, 4, 5, 7, 8, 13, 16, 33] {
            let (runs, want) = split_sorted::<i32>(100 + k as u64, 5000, k);
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            let got = kmerge(&refs);
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn handles_empty_runs() {
        let a = vec![1i32, 5, 9];
        let b: Vec<i32> = vec![];
        let c = vec![2i32, 3];
        let got = kmerge(&[&a, &b, &c]);
        assert_eq!(got, vec![1, 2, 3, 5, 9]);
        let empty: Vec<&[i32]> = vec![];
        assert!(kmerge(&empty).is_empty());
    }

    #[test]
    fn floats_total_order() {
        let (runs, want) = split_sorted::<f64>(7, 3000, 5);
        let refs: Vec<&[f64]> = runs.iter().map(|r| r.as_slice()).collect();
        let got = kmerge(&refs);
        assert!(is_sorted_total(&got));
        assert_eq!(got, want);
    }

    #[test]
    fn i128_wide_keys() {
        let (runs, want) = split_sorted::<i128>(8, 2000, 9);
        let refs: Vec<&[i128]> = runs.iter().map(|r| r.as_slice()).collect();
        assert_eq!(kmerge(&refs), want);
    }

    #[test]
    fn into_buffer_reuse() {
        let (runs, want) = split_sorted::<i32>(9, 1000, 4);
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut buf = Vec::new();
        kmerge_into(&refs, &mut buf);
        assert_eq!(buf, want);
        kmerge_into(&refs, &mut buf); // reused
        assert_eq!(buf, want);
    }
}
