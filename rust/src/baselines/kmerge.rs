//! K-way merge of sorted runs (loser-tree tournament).
//!
//! Used by (a) the device backend when a shard exceeds the largest sort
//! artifact size class — sorted chunks are merged on the host — and
//! (b) SIHSort's final phase, merging the sorted runs received from every
//! peer rank (cheaper than the paper's full second local sort; both are
//! implemented and ablated, see `mpisort`).
//!
//! Inputs at or above [`super::merge_path::PAR_MERGE_MIN`] elements are
//! delegated to the merge-path partitioned parallel engine
//! (`baselines::merge_path`, DESIGN.md §11); below it the sequential
//! loser tree runs. Keys of ≤ 8 bytes play their matches on a `u64` bit
//! image instead of the generic `u128` — the same §Perf L3 trick as
//! `radix.rs` (the wide shifts/compares cost ~35% throughput on i32).

use crate::dtype::SortKey;

/// Merge ascending-sorted `runs` into one ascending vector.
pub fn kmerge<K: SortKey>(runs: &[&[K]]) -> Vec<K> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let mut out = Vec::with_capacity(total);
    kmerge_into(runs, &mut out);
    out
}

/// Merge into a caller-provided buffer (cleared first). Allocation-free on
/// the element path when `out` has capacity. Threshold-gated: large
/// merges run the merge-path partitioned parallel engine over the default
/// host thread count (DESIGN.md §11); callers that know their pool width
/// use `merge_path::kmerge_parallel_into_slice` directly.
pub fn kmerge_into<K: SortKey>(runs: &[&[K]], out: &mut Vec<K>) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    out.clear();
    if total == 0 {
        return;
    }
    // Both merge engines overwrite every slot (`dtype::resize_for_overwrite`).
    crate::dtype::resize_for_overwrite(out, total);
    let threads = crate::backend::threaded::default_threads();
    if total >= super::merge_path::PAR_MERGE_MIN && threads > 1 {
        super::merge_path::kmerge_parallel_into_slice(runs, &mut out[..], threads);
    } else {
        kmerge_into_slice(runs, &mut out[..]);
    }
}

/// Sequential k-way merge into an exactly-sized output slice (every slot
/// is overwritten). This is the per-segment engine the merge-path
/// partitioner fans out over.
pub fn kmerge_into_slice<K: SortKey>(runs: &[&[K]], out: &mut [K]) {
    let live: Vec<&[K]> = runs.iter().copied().filter(|r| !r.is_empty()).collect();
    debug_assert_eq!(live.iter().map(|r| r.len()).sum::<usize>(), out.len());
    match live.len() {
        0 => (),
        1 => out.copy_from_slice(live[0]),
        2 => merge2_into_slice(live[0], live[1], out),
        _ => {
            // §Perf L3: ≤8-byte keys run the tournament on u64 images.
            if K::KEY_BYTES <= 8 {
                loser_tree_merge::<K, u64>(&live, out);
            } else {
                loser_tree_merge::<K, u128>(&live, out);
            }
        }
    }
}

/// Unsigned comparison image for the loser tree (u64 for keys up to
/// 8 bytes, u128 beyond). `MAX` is only a tie-break floor for exhausted
/// runs — exhaustion itself is a separate flag, so a *real* key whose
/// image equals `MAX` (e.g. `i64::MAX`, `i128::MAX`) still merges
/// correctly (a sentinel-in-band scheme would drop it).
pub(super) trait MergeImage: Copy + Ord {
    /// Largest image value (exhausted-run placeholder).
    const MAX: Self;
    /// The key's image.
    fn of<K: SortKey>(k: K) -> Self;
}

impl MergeImage for u64 {
    const MAX: Self = u64::MAX;
    #[inline(always)]
    fn of<K: SortKey>(k: K) -> Self {
        // KEY_BYTES <= 8 ⇒ the image fits the low 64 bits; truncation
        // preserves order.
        k.to_bits() as u64
    }
}

impl MergeImage for u128 {
    const MAX: Self = u128::MAX;
    #[inline(always)]
    fn of<K: SortKey>(k: K) -> Self {
        k.to_bits()
    }
}

/// Loser tree over k ≥ 3 non-empty runs: internal nodes hold the *loser*
/// of each match; the winner bubbles to the root. Pop/replace is O(log k)
/// with no branching on heap shape. Matches compare `(image, exhausted)`
/// pairs so a live run always beats an exhausted one, even at image MAX.
fn loser_tree_merge<K: SortKey, U: MergeImage>(live: &[&[K]], out: &mut [K]) {
    let k = live.len();
    let tree_size = k.next_power_of_two();
    let mut idx = vec![0usize; k]; // next unconsumed element per run
    let key = |run: usize, idx: &[usize]| -> (U, bool) {
        if run >= k || idx[run] >= live[run].len() {
            (U::MAX, true)
        } else {
            (U::of(live[run][idx[run]]), false)
        }
    };

    // Internal nodes: losers[1..tree_size]; winner propagated from leaves.
    let mut losers = vec![usize::MAX; tree_size]; // run ids
    let mut winner_at = vec![usize::MAX; 2 * tree_size];
    for leaf in 0..tree_size {
        winner_at[tree_size + leaf] = if leaf < k { leaf } else { usize::MAX };
    }
    for node in (1..tree_size).rev() {
        let a = winner_at[2 * node];
        let b = winner_at[2 * node + 1];
        let (win, lose) = if key(a, &idx) <= key(b, &idx) { (a, b) } else { (b, a) };
        winner_at[node] = win;
        losers[node] = lose;
    }
    let mut winner = winner_at[1];

    // Exactly out.len() elements remain, and a live run always wins over
    // an exhausted one, so `winner` is live at every iteration.
    for slot in out.iter_mut() {
        *slot = live[winner][idx[winner]];
        idx[winner] += 1;
        // Replay from the winner's leaf up to the root.
        let mut node = (tree_size + winner) / 2;
        let mut cur = winner;
        while node >= 1 {
            let opp = losers[node];
            if key(opp, &idx) < key(cur, &idx) {
                losers[node] = cur;
                cur = opp;
            }
            if node == 1 {
                break;
            }
            node /= 2;
        }
        winner = cur;
    }
}

/// A pull-based cursor over one ascending-sorted run — the streaming
/// counterpart of a `&[K]` run reference. `head` peeks the next key;
/// `advance` consumes it and may refill an internal buffer (file-backed
/// cursors in `crate::stream` do exactly that), which is why it is
/// fallible: an I/O error surfaces at the merge call site instead of
/// silently truncating the run. Generic over whole stream records —
/// a cursor hands back `(key, payload)` units; bare scalar keys are the
/// degenerate zero-payload record.
pub trait RunCursor<K: crate::stream::StreamRecord> {
    /// The next unconsumed record, or `None` when the run is exhausted.
    fn head(&self) -> Option<K>;
    /// Consume the current head (no-op once exhausted).
    fn advance(&mut self) -> anyhow::Result<()>;
}

/// In-memory [`RunCursor`] over a sorted slice.
pub struct SliceCursor<'a, K> {
    run: &'a [K],
    pos: usize,
}

impl<'a, K> SliceCursor<'a, K> {
    /// Cursor at the start of `run` (must be ascending-sorted).
    pub fn new(run: &'a [K]) -> Self {
        SliceCursor { run, pos: 0 }
    }
}

impl<K: crate::stream::StreamRecord> RunCursor<K> for SliceCursor<'_, K> {
    fn head(&self) -> Option<K> {
        self.run.get(self.pos).copied()
    }

    fn advance(&mut self) -> anyhow::Result<()> {
        self.pos = (self.pos + 1).min(self.run.len());
        Ok(())
    }
}

/// Resumable k-way merge: the same loser tree as [`kmerge_into_slice`],
/// but pull-based — output is yielded in caller-sized chunks instead of
/// filling one output slice, so a consumer (the out-of-core merge in
/// `crate::stream`, a network writer) can drain it incrementally under a
/// memory budget. Matches compare `(key image, exhausted, run index)`
/// triples: a real key whose image is all-ones (`i64::MAX`, `i128::MAX`)
/// still merges correctly (the same no-sentinel-in-band rule as the
/// slice engine), and key ties break toward the lower run index, which
/// makes the merge **stable** across runs — records from earlier runs
/// drain first. Scalar merges are bit-identical with or without the
/// tie-break (tied keys have equal images); record merges rely on it
/// for the bitwise stable-sort equivalence (DESIGN.md §19).
pub struct KmergePull<K: crate::stream::StreamRecord, C: RunCursor<K>> {
    cursors: Vec<C>,
    /// Internal nodes hold match losers (run ids); `winner` is the root.
    losers: Vec<usize>,
    winner: usize,
    tree_size: usize,
    _marker: std::marker::PhantomData<K>,
}

impl<K: crate::stream::StreamRecord, C: RunCursor<K>> KmergePull<K, C> {
    /// Build the tournament over `cursors` (each ascending-sorted).
    pub fn new(cursors: Vec<C>) -> Self {
        let k = cursors.len();
        let tree_size = k.next_power_of_two().max(1);
        let mut merge = KmergePull {
            cursors,
            losers: vec![usize::MAX; tree_size],
            winner: usize::MAX,
            tree_size,
            _marker: std::marker::PhantomData,
        };
        // Seed the bracket exactly like the slice engine: leaves are run
        // ids (usize::MAX pads to a power of two), internal nodes keep
        // the loser, the winner propagates to the root.
        let mut winner_at = vec![usize::MAX; 2 * tree_size];
        for leaf in 0..tree_size {
            winner_at[tree_size + leaf] = if leaf < k { leaf } else { usize::MAX };
        }
        for node in (1..tree_size).rev() {
            let a = winner_at[2 * node];
            let b = winner_at[2 * node + 1];
            let (win, lose) = if merge.key_of(a) <= merge.key_of(b) { (a, b) } else { (b, a) };
            winner_at[node] = win;
            merge.losers[node] = lose;
        }
        // Root at index 1 (for tree_size == 1 that slot IS the only
        // leaf, so 0- and 1-run merges need no special casing).
        merge.winner = winner_at[1];
        merge
    }

    /// `(image, exhausted, run)` match key of a run id (padding ids and
    /// exhausted cursors sort after every live key; the trailing run
    /// index breaks key ties toward earlier runs — merge stability).
    fn key_of(&self, run: usize) -> (u128, bool, usize) {
        match self.cursors.get(run).and_then(|c| c.head()) {
            Some(k) => (k.key_bits(), false, run),
            None => (u128::MAX, true, run),
        }
    }

    /// Has every run been fully drained?
    pub fn is_done(&self) -> bool {
        self.winner == usize::MAX || self.cursors[self.winner].head().is_none()
    }

    /// Append up to `max` merged elements to `out`; returns how many were
    /// produced (0 means every run is exhausted). Calling again resumes
    /// where the previous chunk stopped.
    pub fn next_chunk(&mut self, out: &mut Vec<K>, max: usize) -> anyhow::Result<usize> {
        let mut produced = 0;
        while produced < max {
            let w = self.winner;
            let Some(head) = self.cursors.get(w).and_then(|c| c.head()) else {
                break;
            };
            out.push(head);
            produced += 1;
            self.cursors[w].advance()?;
            // Replay from the winner's leaf up to the root.
            let mut node = (self.tree_size + w) / 2;
            let mut cur = w;
            let mut cur_key = self.key_of(cur);
            while node >= 1 {
                let opp = self.losers[node];
                let opp_key = self.key_of(opp);
                if opp_key < cur_key {
                    self.losers[node] = cur;
                    cur = opp;
                    cur_key = opp_key;
                }
                if node == 1 {
                    break;
                }
                node /= 2;
            }
            self.winner = cur;
        }
        Ok(produced)
    }
}

/// 2-way merge into an exactly-sized output slice.
#[inline]
pub(super) fn merge2_into_slice<K: SortKey>(a: &[K], b: &[K], out: &mut [K]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j, mut o) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        let av = a[i];
        let bv = b[j];
        // Branchless select (§Perf L3, same shape as `merge.rs`); `<=`
        // keeps ties taking from the left run first.
        let take_a = av.to_bits() <= bv.to_bits();
        out[o] = if take_a { av } else { bv };
        i += take_a as usize;
        j += !take_a as usize;
        o += 1;
    }
    out[o..o + (a.len() - i)].copy_from_slice(&a[i..]);
    let o2 = o + (a.len() - i);
    out[o2..o2 + (b.len() - j)].copy_from_slice(&b[j..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn split_sorted<K: KeyGen>(seed: u64, n: usize, k: usize) -> (Vec<Vec<K>>, Vec<K>) {
        let xs: Vec<K> = generate(&mut Prng::new(seed), Distribution::Uniform, n);
        let mut want = xs.clone();
        want.sort_unstable_by(|a, b| a.cmp_total(b));
        let mut rng = Prng::new(seed + 1);
        let mut runs: Vec<Vec<K>> = (0..k).map(|_| Vec::new()).collect();
        for x in xs {
            let r = rng.below(k as u64) as usize;
            runs[r].push(x);
        }
        for r in &mut runs {
            r.sort_unstable_by(|a, b| a.cmp_total(b));
        }
        (runs, want)
    }

    #[test]
    fn merges_various_k() {
        for k in [1usize, 2, 3, 4, 5, 7, 8, 13, 16, 33] {
            let (runs, want) = split_sorted::<i32>(100 + k as u64, 5000, k);
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            let got = kmerge(&refs);
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn handles_empty_runs() {
        let a = vec![1i32, 5, 9];
        let b: Vec<i32> = vec![];
        let c = vec![2i32, 3];
        let got = kmerge(&[&a, &b, &c]);
        assert_eq!(got, vec![1, 2, 3, 5, 9]);
        let empty: Vec<&[i32]> = vec![];
        assert!(kmerge(&empty).is_empty());
    }

    #[test]
    fn floats_total_order() {
        let (runs, want) = split_sorted::<f64>(7, 3000, 5);
        let refs: Vec<&[f64]> = runs.iter().map(|r| r.as_slice()).collect();
        let got = kmerge(&refs);
        assert!(is_sorted_total(&got));
        assert_eq!(got, want);
    }

    #[test]
    fn i128_wide_keys() {
        let (runs, want) = split_sorted::<i128>(8, 2000, 9);
        let refs: Vec<&[i128]> = runs.iter().map(|r| r.as_slice()).collect();
        assert_eq!(kmerge(&refs), want);
    }

    #[test]
    fn max_keys_are_not_sentinels() {
        // Regression: i128::MAX / i64::MAX have all-ones bit images that
        // collided with the old in-band EXHAUSTED sentinel and were
        // silently dropped mid-merge.
        let a = vec![1i128, i128::MAX, i128::MAX];
        let b = vec![0i128, 2, i128::MAX];
        let c = vec![i128::MAX];
        let got = kmerge(&[&a, &b, &c]);
        assert_eq!(got, vec![0, 1, 2, i128::MAX, i128::MAX, i128::MAX, i128::MAX]);

        let a = vec![-5i64, i64::MAX];
        let b = vec![i64::MAX, i64::MAX];
        let c = vec![7i64];
        let got = kmerge(&[&a, &b, &c]);
        assert_eq!(got, vec![-5, 7, i64::MAX, i64::MAX, i64::MAX]);
    }

    #[test]
    fn into_buffer_reuse() {
        let (runs, want) = split_sorted::<i32>(9, 1000, 4);
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut buf = Vec::new();
        kmerge_into(&refs, &mut buf);
        assert_eq!(buf, want);
        kmerge_into(&refs, &mut buf); // reused
        assert_eq!(buf, want);
    }

    #[test]
    fn pull_merge_matches_batch_engine_across_chunk_sizes() {
        // The resumable engine must produce exactly the batch engine's
        // output regardless of how the consumer slices its pulls.
        for k in [1usize, 2, 3, 5, 8, 13] {
            let (runs, want) = split_sorted::<i32>(40 + k as u64, 3000, k);
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            for chunk in [1usize, 3, 64, 1000, 10_000] {
                let cursors: Vec<SliceCursor<i32>> =
                    refs.iter().map(|r| SliceCursor::new(r)).collect();
                let mut m = KmergePull::new(cursors);
                let mut got = Vec::new();
                loop {
                    let n = m.next_chunk(&mut got, chunk).unwrap();
                    if n == 0 {
                        break;
                    }
                    assert!(n <= chunk);
                }
                assert!(m.is_done());
                assert_eq!(m.next_chunk(&mut got, 16).unwrap(), 0, "drained merge yields 0");
                assert_eq!(got, want, "k={k} chunk={chunk}");
            }
        }
    }

    #[test]
    fn pull_merge_resumes_mid_run() {
        // Interleave differently-sized pulls; the boundary must never
        // duplicate or drop an element.
        let (runs, want) = split_sorted::<f64>(77, 2000, 4);
        let refs: Vec<&[f64]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut m = KmergePull::new(refs.iter().map(|r| SliceCursor::new(r)).collect());
        let mut got = Vec::new();
        for (i, sz) in [7usize, 1, 400, 3, 1999].iter().cycle().enumerate() {
            if m.next_chunk(&mut got, *sz).unwrap() == 0 {
                break;
            }
            assert!(i < 10_000, "merge failed to terminate");
        }
        assert_eq!(got, want);
    }

    #[test]
    fn pull_merge_handles_degenerate_inputs() {
        // Zero runs.
        let mut m = KmergePull::<i32, SliceCursor<i32>>::new(vec![]);
        let mut out = Vec::new();
        assert!(m.is_done());
        assert_eq!(m.next_chunk(&mut out, 8).unwrap(), 0);
        // One run (fast path through the same tree).
        let a = vec![1i32, 2, 3];
        let mut m = KmergePull::new(vec![SliceCursor::new(&a)]);
        assert_eq!(m.next_chunk(&mut out, 100).unwrap(), 3);
        assert_eq!(out, vec![1, 2, 3]);
        // Empty runs among live ones.
        let b: Vec<i32> = vec![];
        let c = vec![0i32, 9];
        let mut m =
            KmergePull::new(vec![SliceCursor::new(&a), SliceCursor::new(&b), SliceCursor::new(&c)]);
        let mut out2 = Vec::new();
        while m.next_chunk(&mut out2, 2).unwrap() > 0 {}
        assert_eq!(out2, vec![0, 1, 2, 3, 9]);
    }

    #[test]
    fn pull_merge_max_keys_are_not_sentinels() {
        // Same regression as the batch engine: all-ones images are real
        // keys, not exhaustion markers.
        let a = vec![1i64, i64::MAX];
        let b = vec![i64::MAX, i64::MAX];
        let mut m = KmergePull::new(vec![SliceCursor::new(&a), SliceCursor::new(&b)]);
        let mut out = Vec::new();
        while m.next_chunk(&mut out, 1).unwrap() > 0 {}
        assert_eq!(out, vec![1, i64::MAX, i64::MAX, i64::MAX]);
    }

    #[test]
    fn large_merge_crosses_parallel_threshold() {
        // Above PAR_MERGE_MIN the auto path fans out; output must be
        // identical to a plain total-order sort.
        let n = super::super::merge_path::PAR_MERGE_MIN + 4321;
        let (runs, want) = split_sorted::<i32>(10, n, 6);
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        assert_eq!(kmerge(&refs), want);
    }
}
