//! Bottom-up merge sort — the "Thrust merge" (TM) baseline.
//!
//! Iterative (no recursion), one scratch buffer, ping-pong between runs.
//! Insertion sort below a small cutoff seeds the initial runs, mirroring
//! how production merge sorts (incl. Thrust's) seed with an in-block sort.

use crate::dtype::SortKey;

const RUN: usize = 32;

/// Sort in place, ascending under the total order. Stable.
pub fn merge_sort<K: SortKey>(xs: &mut [K]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    // Seed runs with insertion sort.
    let mut start = 0;
    while start < n {
        let end = (start + RUN).min(n);
        insertion_sort(&mut xs[start..end]);
        start = end;
    }
    if n <= RUN {
        return;
    }

    let mut buf: Vec<K> = xs.to_vec();
    merge_rounds(xs, &mut buf, RUN);
}

fn merge_rounds<K: SortKey>(xs: &mut [K], buf: &mut [K], seed: usize) {
    let n = xs.len();
    let mut width = seed;
    let mut in_xs = true;
    while width < n {
        {
            let (src, dst): (&mut [K], &mut [K]) =
                if in_xs { (&mut *xs, &mut *buf) } else { (&mut *buf, &mut *xs) };
            let mut lo = 0;
            while lo < n {
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                merge_into(&src[lo..mid], &src[mid..hi], &mut dst[lo..hi]);
                lo = hi;
            }
        }
        in_xs = !in_xs;
        width *= 2;
    }
    if !in_xs {
        xs.copy_from_slice(buf);
    }
}

#[inline]
fn merge_into<K: SortKey>(a: &[K], b: &[K], out: &mut [K]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    // Hot loop: both runs non-empty — one comparison, no tail checks
    // (§Perf L3: the original per-slot dual-bounds form ran at 34 MB/s;
    // this + bulk tail copies reaches ~3x that on i32).
    let (mut i, mut j, mut o) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        let av = a[i];
        let bv = b[j];
        // Branchless select: the comparison outcome is ~random on real
        // merges, so a cmov beats a 50%-mispredicted branch (§Perf L3).
        // `<=` keeps stability (equal keys take the left run first).
        let take_a = av.to_bits() <= bv.to_bits();
        out[o] = if take_a { av } else { bv };
        i += take_a as usize;
        j += !take_a as usize;
        o += 1;
    }
    out[o..o + (a.len() - i)].copy_from_slice(&a[i..]);
    let o2 = o + (a.len() - i);
    out[o2..o2 + (b.len() - j)].copy_from_slice(&b[j..]);
}

#[inline]
fn insertion_sort<K: SortKey>(xs: &mut [K]) {
    for i in 1..xs.len() {
        let v = xs[i];
        let vb = v.to_bits();
        let mut j = i;
        while j > 0 && xs[j - 1].to_bits() > vb {
            xs[j] = xs[j - 1];
            j -= 1;
        }
        xs[j] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn check<K: KeyGen + PartialEq>(seed: u64, n: usize) {
        for dist in Distribution::ALL {
            let xs: Vec<K> = generate(&mut Prng::new(seed), dist, n);
            let mut got = xs.clone();
            merge_sort(&mut got);
            let mut want = xs.clone();
            want.sort_unstable_by(|a, b| a.cmp_total(b));
            assert!(is_sorted_total(&got), "{dist:?}");
            assert!(got == want, "{dist:?}");
        }
    }

    #[test]
    fn i32_all_dists() {
        check::<i32>(11, 3000);
    }

    #[test]
    fn i128_all_dists() {
        check::<i128>(12, 1000);
    }

    #[test]
    fn f64_all_dists() {
        check::<f64>(13, 2500);
    }

    #[test]
    fn boundary_sizes() {
        for n in [0usize, 1, 2, 31, 32, 33, 63, 64, 65, 127, 1000] {
            let xs: Vec<i32> = generate(&mut Prng::new(n as u64), Distribution::Uniform, n);
            let mut got = xs.clone();
            merge_sort(&mut got);
            assert!(is_sorted_total(&got), "n={n}");
        }
    }

    #[test]
    fn agrees_with_radix() {
        let xs: Vec<i64> = generate(&mut Prng::new(14), Distribution::Uniform, 4096);
        let mut a = xs.clone();
        let mut b = xs;
        merge_sort(&mut a);
        super::super::radix::radix_sort(&mut b);
        assert_eq!(a, b);
    }
}
