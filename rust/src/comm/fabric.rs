//! The message fabric: rank endpoints, point-to-point send/recv, logical
//! clock accounting, and communication statistics.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

use crate::cfg::TransferMode;
use crate::cluster::{ClusterSpec, LinkKind, SimClocks};
use crate::dtype::SortKey;

use super::wire::{bytes_to_vec, vec_to_bytes};

/// One in-flight message.
struct Msg {
    src: usize,
    tag: u64,
    bytes: Vec<u8>,
    /// Simulated arrival time at the destination.
    arrive: f64,
}

/// Cumulative fabric statistics (shared across ranks).
#[derive(Debug, Default)]
pub struct CommStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    pub nvlink_bytes: AtomicU64,
    pub ib_bytes: AtomicU64,
    pub pcie_bytes: AtomicU64,
    pub hostmem_bytes: AtomicU64,
}

impl CommStats {
    fn record(&self, hops: &[LinkKind], bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        for h in hops {
            let slot = match h {
                LinkKind::NvLink => &self.nvlink_bytes,
                LinkKind::Infiniband => &self.ib_bytes,
                LinkKind::PcieD2H => &self.pcie_bytes,
                LinkKind::HostMem => &self.hostmem_bytes,
            };
            slot.fetch_add(bytes as u64, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }
}

struct Shared {
    spec: ClusterSpec,
    mode: TransferMode,
    clocks: SimClocks,
    stats: CommStats,
    /// Per-rank: does this rank host a device (GPU) or is it a CPU rank?
    device: Vec<bool>,
    barrier: Barrier,
    /// Compute token: measured-compute sections run one at a time so the
    /// wall time a rank observes is its own work, not oversubscription
    /// noise from the other rank threads sharing this host's cores.
    /// Logical clocks make the serialisation invisible in simulated time.
    compute: std::sync::Mutex<()>,
}

/// Builder for a set of connected [`Endpoint`]s.
pub struct Fabric;

impl Fabric {
    /// Create `ranks` endpoints. `device[r]` marks device ranks (affects
    /// link selection and the device model); pass all-true for GPU runs,
    /// all-false for the "CC-JB" CPU algorithm, or a mix for co-sorting.
    pub fn new(
        spec: ClusterSpec,
        mode: TransferMode,
        device: Vec<bool>,
    ) -> Vec<Endpoint> {
        let ranks = device.len();
        assert!(ranks > 0);
        let shared = Arc::new(Shared {
            spec,
            mode,
            clocks: SimClocks::new(ranks),
            stats: CommStats::default(),
            device,
            barrier: Barrier::new(ranks),
            compute: std::sync::Mutex::new(()),
        });
        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(ranks);
        let mut receivers: Vec<Receiver<Msg>> = Vec::with_capacity(ranks);
        for _ in 0..ranks {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint {
                rank,
                shared: shared.clone(),
                senders: senders.clone(),
                rx,
                pending: HashMap::new(),
                coll_seq: 0,
            })
            .collect()
    }
}

/// A rank's handle on the fabric. Not `Clone`: exactly one per rank.
pub struct Endpoint {
    rank: usize,
    shared: Arc<Shared>,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order stash: messages received before they were asked for.
    pending: HashMap<(usize, u64), VecDeque<Msg>>,
    /// Collective sequence number (advances identically on all ranks).
    pub(super) coll_seq: u64,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.senders.len()
    }

    pub fn is_device(&self) -> bool {
        self.shared.device[self.rank]
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.shared.spec
    }

    pub fn mode(&self) -> TransferMode {
        self.shared.mode
    }

    pub fn stats(&self) -> &CommStats {
        &self.shared.stats
    }

    /// Current simulated time of this rank.
    pub fn now(&self) -> f64 {
        self.shared.clocks.get(self.rank)
    }

    /// Advance this rank's simulated clock (compute accounting; callers
    /// convert measured time through `cluster::DeviceModel` first).
    pub fn advance(&self, dt: f64) {
        self.shared.clocks.advance(self.rank, dt);
    }

    /// Run a measured-compute section under the fabric's compute token:
    /// returns (result, accurate wall seconds). MUST NOT communicate
    /// inside `f` (the token would serialise against other ranks' compute
    /// and deadlock a collective).
    pub fn measured<R>(&self, f: impl FnOnce() -> R) -> (R, f64) {
        let _token = self.shared.compute.lock().unwrap();
        let t0 = std::time::Instant::now();
        let r = f();
        (r, t0.elapsed().as_secs_f64())
    }

    /// Point-to-point send. The sender's clock advances by the transfer
    /// time (its link is busy); the message carries its arrival time.
    /// Self-sends are free (stay in device memory).
    pub fn send_bytes(&self, dst: usize, tag: u64, bytes: Vec<u8>) {
        let t_send = self.now();
        let arrive = if dst == self.rank {
            t_send
        } else {
            let is_dev = self.is_device() && self.shared.device[dst];
            let hops = self.shared.spec.hops(self.rank, dst, self.shared.mode, is_dev);
            let dt: f64 =
                hops.iter().map(|&k| self.shared.spec.hop_time(k, bytes.len())).sum();
            self.shared.stats.record(&hops, bytes.len());
            self.shared.clocks.advance(self.rank, dt);
            t_send + dt
        };
        self.senders[dst]
            .send(Msg { src: self.rank, tag, bytes, arrive })
            .expect("fabric endpoint dropped");
    }

    /// Blocking receive of the next message from `src` with `tag`.
    /// Merges the arrival time into this rank's clock.
    pub fn recv_bytes(&mut self, src: usize, tag: u64) -> Vec<u8> {
        let key = (src, tag);
        let msg = loop {
            if let Some(q) = self.pending.get_mut(&key) {
                if let Some(m) = q.pop_front() {
                    break m;
                }
            }
            let m = self.rx.recv().expect("fabric senders dropped");
            if (m.src, m.tag) == key {
                break m;
            }
            self.pending.entry((m.src, m.tag)).or_default().push_back(m);
        };
        self.shared.clocks.merge_at_least(self.rank, msg.arrive);
        msg.bytes
    }

    /// Typed point-to-point send of a key slice.
    pub fn send<K: SortKey>(&self, dst: usize, tag: u64, xs: &[K]) {
        self.send_bytes(dst, tag, vec_to_bytes(xs));
    }

    /// Typed point-to-point receive.
    pub fn recv<K: SortKey>(&mut self, src: usize, tag: u64) -> Vec<K> {
        bytes_to_vec(&self.recv_bytes(src, tag))
    }

    /// Synchronise all ranks (thread barrier + clock max-merge).
    pub fn barrier(&mut self) {
        self.coll_seq += 1;
        let res = self.shared.barrier.wait();
        if res.is_leader() {
            self.shared.clocks.barrier_sync();
        }
        // Second phase: nobody proceeds until clocks are merged.
        self.shared.barrier.wait();
    }

    pub(super) fn next_coll_tag(&mut self) -> u64 {
        self.coll_seq += 1;
        // Collective tags live in the top half of the tag space.
        (1 << 63) | self.coll_seq
    }

    /// Reserve one collective tag for a caller-driven collective built
    /// from raw sends/recvs (e.g. the streamed chunk-at-a-time exchange
    /// in `mpisort::exchange`). Every rank must call this at the same
    /// point in the collective schedule — the sequence number advances
    /// in lockstep exactly like the built-in collectives, so tags can
    /// never cross-talk between phases.
    pub fn collective_tag(&mut self) -> u64 {
        self.next_coll_tag()
    }

    /// Simulated times snapshot (rank -> seconds); for metrics.
    pub fn sim_time_of(&self, rank: usize) -> f64 {
        self.shared.clocks.get(rank)
    }

    /// Global simulated makespan.
    pub fn sim_makespan(&self) -> f64 {
        self.shared.clocks.global_max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> Vec<Endpoint> {
        Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![true; n])
    }

    #[test]
    fn p2p_roundtrip() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || e1.recv::<i32>(0, 7));
        e0.send::<i32>(1, 7, &[1, 2, 3]);
        assert_eq!(h.join().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_on_transfer() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let payload = vec![0u8; 30 << 20]; // 30 MB over NVLink ≈ 100 µs
        let h = std::thread::spawn(move || {
            let b = e1.recv_bytes(0, 1);
            (b.len(), e1.now())
        });
        e0.send_bytes(1, 1, payload);
        assert!(e0.now() > 50e-6, "sender time {}", e0.now());
        let (len, t1) = h.join().unwrap();
        assert_eq!(len, 30 << 20);
        assert!(t1 >= e0.now() * 0.99, "receiver {} sender {}", t1, e0.now());
    }

    #[test]
    fn out_of_order_tags() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            // Ask for tag 2 first even though tag 1 arrives first.
            let b = e1.recv::<i32>(0, 2);
            let a = e1.recv::<i32>(0, 1);
            (a, b)
        });
        e0.send::<i32>(1, 1, &[10]);
        e0.send::<i32>(1, 2, &[20]);
        let (a, b) = h.join().unwrap();
        assert_eq!(a, vec![10]);
        assert_eq!(b, vec![20]);
    }

    #[test]
    fn self_send_is_free() {
        let mut eps = mk(1);
        let mut e0 = eps.pop().unwrap();
        e0.send::<i64>(0, 3, &[5, 6]);
        let t_before = e0.now();
        assert_eq!(e0.recv::<i64>(0, 3), vec![5, 6]);
        assert_eq!(e0.now(), t_before);
        assert_eq!(e0.stats().snapshot().0, 0); // not counted as traffic
    }

    #[test]
    fn stats_count_hops() {
        let mut eps = Fabric::new(
            ClusterSpec::baskerville(),
            TransferMode::CpuStaged,
            vec![true; 2],
        );
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || e1.recv::<i32>(0, 1));
        e0.send::<i32>(1, 1, &[1; 256]);
        h.join().unwrap();
        let stats = e0.stats();
        assert_eq!(stats.messages.load(Ordering::Relaxed), 1);
        assert_eq!(stats.bytes.load(Ordering::Relaxed), 1024);
        // Staged intra-node: 2 PCIe hops + hostmem hop.
        assert_eq!(stats.pcie_bytes.load(Ordering::Relaxed), 2048);
        assert_eq!(stats.hostmem_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(stats.nvlink_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn barrier_merges_clocks() {
        let eps = mk(3);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut e| {
                std::thread::spawn(move || {
                    e.advance(e.rank() as f64); // ranks at t=0,1,2
                    e.barrier();
                    e.now()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 2.0);
        }
    }
}
